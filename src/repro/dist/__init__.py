"""Distributed GST: row-sharded historical table (table.py), pluggable
table-exchange strategies ring | alltoall | bucketed (exchange.py),
shard_map data-parallel train/refresh/finetune steps (train.py), and the
async host→device segment pipeline (pipeline.py).

Force a multi-device host for CPU development/CI with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set BEFORE jax
initializes; ``python -m repro.launch.train_dist`` does it for you).
"""
from repro.dist.exchange import (EXCHANGES, PAYLOAD_DTYPES, Exchange,
                                 PayloadCodec, consumer_shards,
                                 make_exchange, measured_exchange_bytes,
                                 pad_ragged, plan_capacity,
                                 plan_patch_capacity, required_capacity,
                                 required_patch_capacity, select_exchange)
from repro.dist.pipeline import (AsyncSegmentFeeder, PrefetchLane,
                                 SyncSegmentFeeder, epoch_ids, make_feeder,
                                 segment_dataset_shared, shared_bucket)
from repro.dist.train import (AXIS, DistContext, batch_sharding, device_state,
                              device_table, host_table, make_context,
                              make_dist_eval_step, make_dist_finetune_step,
                              make_dist_mesh, make_dist_refresh_step,
                              make_dist_store, make_dist_train_step,
                              make_prefetch_lookup, replicate, shard_batch)

__all__ = [
    "AXIS", "AsyncSegmentFeeder", "DistContext", "EXCHANGES",
    "Exchange", "PAYLOAD_DTYPES", "PayloadCodec", "PrefetchLane",
    "SyncSegmentFeeder",
    "batch_sharding", "consumer_shards", "device_state", "device_table",
    "epoch_ids", "host_table",
    "make_context", "make_dist_eval_step", "make_dist_finetune_step",
    "make_dist_mesh", "make_dist_refresh_step", "make_dist_store",
    "make_dist_train_step", "make_exchange", "make_feeder",
    "make_prefetch_lookup",
    "measured_exchange_bytes", "pad_ragged", "plan_capacity",
    "plan_patch_capacity", "replicate",
    "required_capacity", "required_patch_capacity",
    "segment_dataset_shared", "select_exchange",
    "shard_batch", "shared_bucket",
]
