"""Async double-buffered host→device segment pipeline (dist subsystem).

Training steps should never wait on the host.  The synchronous loop does
``gather batch -> device_put -> step`` serially, so segment assembly (a
numpy gather over the SegmentedDataset) and the host→device copy sit on
the critical path every iteration.  ``AsyncSegmentFeeder`` moves both off
it: a background thread assembles the NEXT batch and ``jax.device_put``s
it onto the mesh (sharded on the batch dim) while the CURRENT step runs,
keeping up to ``depth`` device-resident batches in flight (depth=2 =
classic double buffering).

Both feeders expose the same iterator protocol (the async one is
single-shot — build one per epoch; the id schedule is the reusable part)
and count their host-blocked milliseconds — the time ``next()`` spends before a device
batch is available — so bench_dist.py can show the async pipeline beats
the synchronous feeder on the same trace (BENCH_gst_dist.json).

``put_fn`` owns what a delivered item IS: launch/train_dist.py's put
calls ``store.begin`` (tiered-table residency bookkeeping + staging,
safe on this producer thread) and returns ``(prep, device_batch)`` —
the consumer commits each staged migration in delivery order.  The
matching device→host lane is the AsyncHostWriter re-exported below.

Padding policy is SHARED with serving: ``shared_bucket`` picks the
(m_max, e_max) shape from the serve bucket ladder (serve/buckets.py) and
``segment_dataset_shared`` pads the training dataset to it via the same
``graphs/batching.py::pad_segment``.  One shared caveat inherited from
the ladder: training uses ONE static shape (the rung fitting
max_seg_nodes) while serving routes each segment to the smallest rung it
fits, so padded bytes — and serving-cache fingerprints — coincide exactly
for the segments serving routes to that same rung; smaller segments land
in smaller rungs with their own addresses.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator, List, Optional, Tuple

import numpy as np

from repro.core import gst as G
from repro.graphs import batching as Bt
from repro.obs.memory import get_probe, tree_nbytes
from repro.obs.metrics import get_registry
from repro.obs.trace import span
from repro.serve.buckets import BucketSpec, choose_bucket, default_ladder


# ---------------------------------------------------------------------------
# shared train/serve padding policy
# ---------------------------------------------------------------------------


def shared_bucket(max_seg_nodes: int, batch: int = 8,
                  ladder: Optional[Tuple[BucketSpec, ...]] = None) -> BucketSpec:
    """The serve-ladder bucket a training run pads to: smallest rung fitting
    ``max_seg_nodes`` (its e_max = 8x nodes covers the synthetic densities;
    oversized edge lists truncate exactly as in serving)."""
    ladder = ladder or default_ladder(max_seg_nodes, batch=batch)
    return ladder[choose_bucket(ladder, max_seg_nodes, 0)]


def segment_dataset_shared(graphs, max_seg_nodes: int = 64, *,
                           method: str = "bfs", seed: int = 0,
                           j_max: Optional[int] = None,
                           ) -> Tuple[Bt.SegmentedDataset, BucketSpec]:
    """``Bt.segment_dataset`` padded to the serve bucket ladder's shapes."""
    spec = shared_bucket(max_seg_nodes)
    ds = Bt.segment_dataset(graphs, spec.m_max, method=method, seed=seed,
                            j_max=j_max, e_max=spec.e_max)
    return ds, spec


# ---------------------------------------------------------------------------
# async device→host write-back lane
# ---------------------------------------------------------------------------

# The opposite lane of this pipeline: the tiered embedding store submits its
# eviction write-backs to an AsyncHostWriter so the device_get + host-array
# copy overlaps with the running step.  The class itself lives under store/
# (import-graph leaf); re-exported here because it IS the pipeline's
# device→host half.
from repro.store.writeback import AsyncHostWriter  # noqa: E402,F401


# ---------------------------------------------------------------------------
# feeders
# ---------------------------------------------------------------------------


@dataclass
class FeederStats:
    batches: int = 0
    host_blocked_ms: float = 0.0     # time next() waited on host work
    put_ms: float = 0.0              # device_put time (async: off-thread)
    blocked_per_batch: List[float] = field(default_factory=list)

    @property
    def host_blocked_ms_per_batch(self) -> float:
        return self.host_blocked_ms / max(self.batches, 1)

    def record_batch(self, blocked_ms: float) -> None:
        """One delivered batch: local stats + the registry mirror (the
        local lists/floats stay for bench_dist and tests)."""
        self.batches += 1
        self.host_blocked_ms += blocked_ms
        self.blocked_per_batch.append(blocked_ms)
        reg = get_registry()
        if reg.enabled:
            reg.inc("feeder.batches")
            reg.inc("feeder.host_blocked_ms", blocked_ms, unit="ms")


def _assemble(ds: Bt.SegmentedDataset, ids: np.ndarray) -> G.GSTBatch:
    """Host-side batch assembly (the numpy gather) as a GSTBatch of numpy
    arrays, batch_pos = global table rows' positions within this batch."""
    return G.GSTBatch(ds.seg_inputs(ids), ds.seg_valid[ids],
                      ids.astype(np.int32), ds.labels[ids],
                      np.arange(len(ids), dtype=np.int32))


def epoch_ids(ds: Bt.SegmentedDataset, batch_size: int, *,
              rng: np.random.Generator, shuffle: bool = True) -> List[np.ndarray]:
    """The id schedule of one epoch, precomputed so sync and async feeders
    can replay the IDENTICAL trace — same policy as ``batch_iterator``
    (one shared implementation: graphs/batching.py::batch_id_schedule)."""
    return Bt.batch_id_schedule(ds.n, batch_size, rng=rng, shuffle=shuffle)


class SyncSegmentFeeder:
    """Baseline feeder: assemble + device_put inline on the consumer thread
    (all host work is blocked time by construction)."""

    def __init__(self, ds: Bt.SegmentedDataset, id_schedule: List[np.ndarray],
                 put_fn: Callable[[G.GSTBatch], Any]):
        self._ds = ds
        self._sched = id_schedule
        self._put = put_fn
        self.stats = FeederStats()

    def __iter__(self) -> Iterator[G.GSTBatch]:
        for ids in self._sched:
            t0 = time.perf_counter()
            with span("feeder.assemble", batch=len(ids)):
                host = _assemble(self._ds, ids)
            p = get_probe()
            if p.enabled:
                p.observe_host("feeder.staging", tree_nbytes(host))
            t1 = time.perf_counter()
            with span("feeder.put"):
                dev = self._put(host)
            t2 = time.perf_counter()
            blocked = (t2 - t0) * 1e3
            self.stats.put_ms += (t2 - t1) * 1e3
            self.stats.record_batch(blocked)
            yield dev


class AsyncSegmentFeeder:
    """Double-buffered feeder: a daemon thread assembles and device_puts
    batch k+1..k+depth while the consumer runs step k; ``next()`` only
    blocks when the producer hasn't caught up.

    Abandoning the iterator mid-epoch (a step raising, a break) closes the
    feeder: the producer is signalled to stop and the queued device batches
    are dropped instead of staying referenced by a forever-blocked thread."""

    _DONE = object()

    def __init__(self, ds: Bt.SegmentedDataset, id_schedule: List[np.ndarray],
                 put_fn: Callable[[G.GSTBatch], Any], *, depth: int = 2):
        if depth < 1:
            raise ValueError("depth must be >= 1")
        self._ds = ds
        self._sched = id_schedule
        self._put = put_fn
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._exc: Optional[BaseException] = None
        self._stop = threading.Event()
        self._consumed = False
        self.stats = FeederStats()
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _put_q(self, item) -> bool:
        """Stop-aware blocking put; False when the feeder was closed."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _produce(self):
        try:
            for ids in self._sched:
                if self._stop.is_set():
                    return
                t1 = time.perf_counter()
                with span("feeder.assemble", batch=len(ids)):
                    host = _assemble(self._ds, ids)
                p = get_probe()
                if p.enabled:
                    p.observe_host("feeder.staging", tree_nbytes(host))
                with span("feeder.put"):
                    dev = self._put(host)
                self.stats.put_ms += (time.perf_counter() - t1) * 1e3
                if not self._put_q(dev):
                    return
        except BaseException as e:  # surfaced on the consumer side
            self._exc = e
        finally:
            self._put_q(self._DONE)

    def close(self) -> None:
        """Stop the producer and release the in-flight device batches."""
        self._stop.set()

        def drain():
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass

        drain()  # wake a put-blocked producer immediately
        self._thread.join(timeout=5.0)
        drain()  # a put racing past the first drain may have landed

    def __iter__(self) -> Iterator[G.GSTBatch]:
        if self._consumed:  # the producer ran once; a re-iteration would
            raise RuntimeError(  # block forever on the empty queue
                "AsyncSegmentFeeder is single-shot — construct a new feeder "
                "per epoch (the id schedule is the reusable part)")
        self._consumed = True
        try:
            while True:
                t0 = time.perf_counter()
                with span("feeder.wait"):
                    item = self._q.get()
                blocked = (time.perf_counter() - t0) * 1e3
                if item is self._DONE:
                    self._thread.join()
                    if self._exc is not None:
                        raise self._exc
                    return
                self.stats.record_batch(blocked)
                yield item
        finally:  # abandoned mid-epoch (break / step raised) -> shut down
            self.close()


def make_feeder(kind: str, ds: Bt.SegmentedDataset,
                id_schedule: List[np.ndarray],
                put_fn: Callable[[G.GSTBatch], Any], *,
                depth: int = 2):
    if kind == "async":
        return AsyncSegmentFeeder(ds, id_schedule, put_fn, depth=depth)
    if kind == "sync":
        return SyncSegmentFeeder(ds, id_schedule, put_fn)
    raise ValueError(f"unknown feeder kind {kind!r}")


# ---------------------------------------------------------------------------
# prefetch lane (lookahead exchange dispatch, ISSUE 9)
# ---------------------------------------------------------------------------


class PrefetchLane:
    """One-item lookahead over a feeder that dispatches the NEXT batch's
    exchange lookup before the CURRENT step launches.

    Wraps any feeder (sync or async) and calls ``dispatch_fn(item)``
    exactly once per delivered item, at pull time — i.e. for batch k+1
    this runs right BEFORE the driver launches step k, so the prefetch
    collective it issues (dist/train.py::make_prefetch_lookup) is
    enqueued ahead of the table-donating step and its hops overlap step
    k's compute.  The driver's dispatch closure is also where
    ``store.commit`` for the next migration belongs (the prefetch must
    read the post-commit table).

    Yields ``(item, handle, nxt_item, nxt_handle)``: the current item,
    the value ``dispatch_fn`` returned for it (the driver only consumes
    this on the FIRST batch — afterwards it carries the step's patched
    buffer instead), and the looked-ahead next pair (``None``/``None``
    on the last batch, where the step patches a dummy).

    Error propagation: a ``dispatch_fn`` failure (or an abandoned
    iteration) closes the wrapped feeder before the exception surfaces,
    so its producer thread never blocks on a dead consumer.  Counters:
    ``feeder.prefetch_batches`` / ``feeder.prefetch_dispatch_ms`` mirror
    to the metrics registry beside the wrapped feeder's own stats."""

    def __init__(self, feeder, dispatch_fn: Callable[[Any], Any]):
        self._feeder = feeder
        self._dispatch = dispatch_fn
        self.prefetch_batches = 0
        self.dispatch_ms = 0.0

    @property
    def stats(self) -> FeederStats:
        return self._feeder.stats

    def _dispatch_timed(self, item):
        t0 = time.perf_counter()
        with span("feeder.prefetch_dispatch"):
            handle = self._dispatch(item)
        dt = (time.perf_counter() - t0) * 1e3
        self.prefetch_batches += 1
        self.dispatch_ms += dt
        reg = get_registry()
        if reg.enabled:
            reg.inc("feeder.prefetch_batches")
            reg.inc("feeder.prefetch_dispatch_ms", dt, unit="ms")
        return handle

    def close(self) -> None:
        close = getattr(self._feeder, "close", None)
        if close is not None:
            close()

    def __iter__(self):
        it = iter(self._feeder)
        try:
            try:
                cur = next(it)
            except StopIteration:
                return
            cur_h = self._dispatch_timed(cur)
            while True:
                try:
                    nxt = next(it)
                except StopIteration:
                    nxt, nxt_h = None, None
                else:
                    nxt_h = self._dispatch_timed(nxt)
                yield cur, cur_h, nxt, nxt_h
                if nxt is None:
                    return
                cur, cur_h = nxt, nxt_h
        finally:
            self.close()
