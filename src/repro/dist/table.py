"""Row-sharded historical embedding table + ring exchange (dist subsystem).

The dense table T (n_graphs, J_max, d_h) of core/embedding_table.py is
partitioned BLOCK-wise on the graph-row axis across the data mesh axis:
device k owns rows [k·R, (k+1)·R) with R = ceil(n / D) (the row count is
padded to D·R; padding rows are never referenced — graph_ids < n).

FreshGNN / Bai et al. (PAPERS.md) motivate the design: the historical
embedding store is the scaling bottleneck, so it must be partitioned with
the compute instead of replicated.  Lookups and write-backs therefore run
as a RING exchange inside shard_map (jax.lax.ppermute), never an
all-gather of embedding data:

  * rows a device already owns are answered by a plain local gather on the
    first ring stop (zero communication for a perfectly-aligned batch);
  * remote rows ride the ring — the (ids, payload) buffers hop with
    shift +1 and every shard answers/applies the rows it owns as the
    buffer passes through: D hops for lookups (the answered buffer must
    come home), D-1 for writes (applied in place, nothing returns).

Per-device traffic is D · B_local · row_bytes per exchange (reported by
the *_exchange_bytes helpers and tracked in BENCH_gst_dist.json), vs
n · row_bytes for gathering a replicated table — independent of the table
size, which is the point.

Everything here runs INSIDE shard_map: ``table`` arguments are the local
(R, J, d) shard, ids are global graph ids, and ``axis_name`` is the data
axis.  Writes are applied with scatter mode="drop": non-owned rows are
redirected out of range and skipped, so each write lands exactly once
(graph ids are unique within a batch) and stays a donated in-place
scatter per PR 1.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import embedding_table as tbl
from repro.kernels.ops import pad_leading


# ---------------------------------------------------------------------------
# row partitioning (host-side, static) — canonical definitions live with the
# embedding store (store/base.py), which owns row geometry now; re-exported
# here because the ring exchange is phrased in terms of them
# ---------------------------------------------------------------------------

from repro.store.base import padded_rows, rows_per_shard  # noqa: E402,F401


def pad_table(table: tbl.EmbeddingTable, num_shards: int) -> tbl.EmbeddingTable:
    """Pad the row axis to a multiple of the shard count (no-op if aligned)."""
    n_pad = padded_rows(table.emb.shape[0], num_shards)
    return tbl.EmbeddingTable(*(pad_leading(x, n_pad) for x in table))


def unpad_table(table: tbl.EmbeddingTable, n_rows: int) -> tbl.EmbeddingTable:
    return tbl.EmbeddingTable(table.emb[:n_rows], table.age[:n_rows],
                              table.initialized[:n_rows])


# ---------------------------------------------------------------------------
# ring exchange (inside shard_map)
# ---------------------------------------------------------------------------


def _ring_perm(num_shards: int):
    return [(i, (i + 1) % num_shards) for i in range(num_shards)]


def _hop(axis_name, num_shards, *bufs):
    perm = _ring_perm(num_shards)
    return tuple(jax.lax.ppermute(b, axis_name, perm) for b in bufs)


def ring_lookup(table: tbl.EmbeddingTable, graph_ids, *, axis_name: str,
                num_shards: int, rows: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Distributed ``tbl.lookup``: global graph_ids (B_l,) against the local
    (R, J, d) shard.  Locally-owned rows are a plain gather; remote rows are
    collected as the query buffer rides the ring (D ppermute hops, the last
    one bringing the answered buffer home).  Pure row selection — no
    reductions — so the result is BIT-EXACT vs the dense single-device
    lookup (asserted in tests/test_dist.py)."""
    me = jax.lax.axis_index(axis_name)
    B = graph_ids.shape[0]
    emb = jnp.zeros((B,) + table.emb.shape[1:], table.emb.dtype)
    init = jnp.zeros((B,) + table.initialized.shape[1:],
                     table.initialized.dtype)
    ids = graph_ids
    for _ in range(num_shards):
        owner = ids // rows
        mine = owner == me
        local_row = jnp.clip(ids - me * rows, 0, rows - 1)
        e, i = tbl.lookup(table, local_row)
        emb = jnp.where(mine[:, None, None], e, emb)
        init = jnp.where(mine[:, None], i, init)
        if num_shards > 1:
            ids, emb, init = _hop(axis_name, num_shards, ids, emb, init)
    return emb, init


def ring_update_sampled(table: tbl.EmbeddingTable, graph_ids, seg_idx, h_new,
                        step, *, axis_name: str, num_shards: int,
                        rows: int) -> tbl.EmbeddingTable:
    """Distributed ``tbl.update_sampled``: the (ids, seg_idx, h_new) write
    buffer rides the ring; each shard applies the writes it owns in place
    (donated scatter, mode="drop" for everything else)."""
    ids, sidx, h = graph_ids, seg_idx, h_new
    me = jax.lax.axis_index(axis_name)
    for t in range(num_shards):
        mine = (ids // rows) == me
        local_row = jnp.where(mine, ids - me * rows, rows)  # rows => dropped
        table = tbl.update_sampled(table, local_row, sidx, h, step,
                                   mode="drop")
        if t < num_shards - 1:  # write buffers need no homecoming hop
            ids, sidx, h = _hop(axis_name, num_shards, ids, sidx, h)
    return table


def ring_update_all(table: tbl.EmbeddingTable, graph_ids, h_all, seg_valid,
                    step, *, axis_name: str, num_shards: int,
                    rows: int) -> tbl.EmbeddingTable:
    """Distributed ``tbl.update_all`` (refresh phase) over the ring."""
    ids, h, sv = graph_ids, h_all, seg_valid
    me = jax.lax.axis_index(axis_name)
    for t in range(num_shards):
        mine = (ids // rows) == me
        local_row = jnp.where(mine, ids - me * rows, rows)
        table = tbl.update_all(table, local_row, h, sv, step, mode="drop")
        if t < num_shards - 1:  # write buffers need no homecoming hop
            ids, h, sv = _hop(axis_name, num_shards, ids, h, sv)
    return table


# ---------------------------------------------------------------------------
# exchange-byte accounting (bench_dist.py / tests)
# ---------------------------------------------------------------------------


def lookup_exchange_bytes(num_shards: int, b_local: int, j_max: int,
                          d_h: int, itemsize: int = 4) -> int:
    """Per-device bytes moved through the ring for ONE lookup: D hops of the
    (ids int32, emb f32, initialized bool) buffer.  0 when unsharded."""
    if num_shards <= 1:
        return 0
    per_hop = b_local * (4 + j_max * d_h * itemsize + j_max * 1)
    return num_shards * per_hop


def update_sampled_exchange_bytes(num_shards: int, b_local: int, s: int,
                                  d_h: int, itemsize: int = 4) -> int:
    """Per-device ring bytes for ONE sampled write-back: (ids, seg_idx,
    h_new) buffers, D-1 hops (writes need no homecoming hop)."""
    if num_shards <= 1:
        return 0
    per_hop = b_local * (4 + s * 4 + s * d_h * itemsize)
    return (num_shards - 1) * per_hop


def update_all_exchange_bytes(num_shards: int, b_local: int, j_max: int,
                              d_h: int, itemsize: int = 4) -> int:
    """Per-device ring bytes for ONE full refresh write: (ids, h_all,
    seg_valid) buffers, D-1 hops (writes need no homecoming hop)."""
    if num_shards <= 1:
        return 0
    per_hop = b_local * (4 + j_max * d_h * itemsize + j_max * 4)
    return (num_shards - 1) * per_hop


def train_step_exchange_bytes(num_shards: int, b_local: int, j_max: int,
                              s: int, d_h: int, *, use_table: bool) -> int:
    """Total per-device ring traffic of one dist train step (lookup +
    sampled write-back when the variant uses the table)."""
    if not use_table:
        return 0
    return (lookup_exchange_bytes(num_shards, b_local, j_max, d_h)
            + update_sampled_exchange_bytes(num_shards, b_local, s, d_h))
