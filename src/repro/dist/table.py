"""Row-sharded historical embedding table geometry (dist subsystem).

The dense table T (n_graphs, J_max, d_h) of core/embedding_table.py is
partitioned BLOCK-wise on the graph-row axis across the data mesh axis:
device k owns rows [k·R, (k+1)·R) with R = ceil(n / D) (the row count is
padded to D·R; padding rows are never referenced — graph_ids < n).

FreshGNN / Bai et al. (PAPERS.md) motivate the design: the historical
embedding store is the scaling bottleneck, so it must be partitioned with
the compute instead of replicated.  HOW the shards exchange lookups and
write-backs is a pluggable strategy since ISSUE 5 — dist/exchange.py owns
the ring / alltoall / bucketed implementations and their bytes models;
this module keeps the row geometry (pad/unpad, rows_per_shard) and
re-exports the PR 3 ring API for its existing callers.
"""
from __future__ import annotations

from repro.core import embedding_table as tbl
from repro.kernels.ops import pad_leading

# canonical row-partition definitions live with the embedding store
# (store/base.py), which owns row geometry now
from repro.store.base import padded_rows, rows_per_shard  # noqa: F401

# byte accounting + the ring strategy moved to dist/exchange.py (ISSUE 5);
# re-exported here so PR 3-era callers keep working unchanged.  (The
# module-level *_exchange_bytes models are the PR 3 f32 ring; the
# strategy methods carry the compressed --payload-dtype models.)
from repro.dist.exchange import (  # noqa: F401
    PAYLOAD_DTYPES, PayloadCodec, RingExchange, lookup_exchange_bytes,
    train_step_exchange_bytes, update_all_exchange_bytes,
    update_sampled_exchange_bytes)

# prefetch-lane host planning (ISSUE 9) lives beside the row geometry it
# depends on: consumer_shards maps write rows to the shard whose slice of
# the next batch reads them (the contiguous split defined above)
from repro.dist.exchange import (  # noqa: F401
    consumer_shards, plan_patch_capacity, required_patch_capacity)


def pad_table(table: tbl.EmbeddingTable, num_shards: int) -> tbl.EmbeddingTable:
    """Pad the row axis to a multiple of the shard count (no-op if aligned)."""
    n_pad = padded_rows(table.emb.shape[0], num_shards)
    return tbl.EmbeddingTable(*(pad_leading(x, n_pad) for x in table))


def unpad_table(table: tbl.EmbeddingTable, n_rows: int) -> tbl.EmbeddingTable:
    return tbl.EmbeddingTable(table.emb[:n_rows], table.age[:n_rows],
                              table.initialized[:n_rows])


# ---------------------------------------------------------------------------
# PR 3 ring entry points (now thin wrappers over the ring strategy)
# ---------------------------------------------------------------------------


def _ring(axis_name: str, num_shards: int, rows: int) -> RingExchange:
    return RingExchange(axis_name=axis_name, num_shards=num_shards,
                        rows=rows)


def ring_lookup(table, graph_ids, *, axis_name: str, num_shards: int,
                rows: int):
    """Distributed ``tbl.lookup`` over the ring (see RingExchange)."""
    return _ring(axis_name, num_shards, rows).lookup(table, graph_ids)


def ring_update_sampled(table, graph_ids, seg_idx, h_new, step, *,
                        axis_name: str, num_shards: int, rows: int):
    """Distributed ``tbl.update_sampled`` over the ring (see RingExchange)."""
    return _ring(axis_name, num_shards, rows).update_sampled(
        table, graph_ids, seg_idx, h_new, step)


def ring_update_all(table, graph_ids, h_all, seg_valid, step, *,
                    axis_name: str, num_shards: int, rows: int):
    """Distributed ``tbl.update_all`` over the ring (see RingExchange)."""
    return _ring(axis_name, num_shards, rows).update_all(
        table, graph_ids, h_all, seg_valid, step)
