"""Pluggable table-exchange strategies for the row-sharded historical table.

The distributed GST lookups/write-backs (dist/table.py geometry: device k
owns rows [k·R, (k+1)·R)) used to be hard-wired to a ring of
``jax.lax.ppermute`` hops.  The ring's per-device traffic is
``D · B_local · row_bytes`` per exchange — every payload buffer visits
every shard — which stops winning as the shard count grows (ROADMAP's
ring-vs-all-to-all crossover).  This module makes the exchange a STRATEGY
behind one ``Exchange`` API, everything still running INSIDE ``shard_map``
on global row ids against the local (R, J, d) table shard:

  ``ring``      the original D-hop ppermute loop: the (ids, payload)
                buffers ride the ring, every shard answers/applies the
                rows it owns as the buffer passes through.  D hops for
                lookups (answers must come home), D-1 for writes.

  ``alltoall``  one-shot dissemination of the FULL local buffer: queries
                all_gather to every shard, each shard answers everything
                it owns, and one ``jax.lax.all_to_all`` brings the dense
                (D, B_local) answer block home (the requester selects its
                owner's answer — pure row selection, no reductions).
                Saves the ring's per-hop latency (2 collectives instead
                of D) and one payload hop, but still moves the dense
                answer block: ~(D-1)·B_local·row_bytes.

  ``bucketed``  owner-direct: queries are sorted by owner shard
                (device-side stable sort; the CAPACITY of the per-owner
                buckets is planned host-side — see ``plan_capacity``) and
                each row travels exactly one hop to its owner and one hop
                back, as two ``all_to_all`` s of (D, cap) buckets.  With a
                near-uniform owner distribution cap ≈ B_local/D and the
                traffic drops to ~2·B_local·row_bytes per device,
                independent of the shard count — the high-shard-count
                winner.

Every strategy ships an ANALYTIC per-device bytes-per-exchange model
(``lookup_bytes`` / ``update_sampled_bytes`` / ``update_all_bytes`` /
``train_step_bytes``) whose conventions match ``measured_exchange_bytes``,
which counts the actual collective traffic in a jaxpr — the parity of the
two is asserted per strategy in tests/test_exchange_props.py, and
``select_exchange`` ("--exchange=auto") picks the min-bytes strategy at
the current shard count (benchmarked into BENCH_gst_dist.json).

Bit-exactness contract (tests/test_exchange_props.py): every strategy is
pure row selection / single-owner scatter — no cross-shard reductions —
so lookups and write-backs are BIT-exact vs the dense single-device table
ops, and all 7 GST variants train to oracle parity through any of them.

Ragged batches: a global batch whose size doesn't divide the shard count
must be padded to one that does BEFORE sharding (``pad_ragged``).  Pad
rows carry the sentinel id ``num_shards · rows`` which every strategy's
write path drops and every strategy's lookup answers with zeros.

Compressed traffic (``--payload-dtype``): the embedding payloads crossing
the collectives — lookup answers and write-back rows — optionally travel
as bf16 or int8-with-per-row-scale wire rows (``PayloadCodec`` over
kernels/quant.py) and dequantize at the endpoint.  Lookups round
deterministically to nearest; write-backs round STOCHASTICALLY (keyed on
(step, shard)) so repeated round-trips stay unbiased.  ids / seg_idx /
seg_valid / initialized stay exact, f32 is the identity codec (the
bit-exactness contract above keeps its teeth), and the bytes models —
still asserted against ``measured_exchange_bytes`` — shrink accordingly,
moving the ``select_exchange`` crossover points per (shard count, dtype).

Prefetched lookups (``--prefetch-lookups``): the lookup for batch k+1 can
be dispatched as its OWN jitted collective while step k's device work is
in flight (``prefetch_lookup`` — same collectives as ``lookup``, so it
moves the same bytes, just earlier).  The buffer it returns is stale by
exactly the <= B_local*S rows step k itself writes back, so every
strategy grows ``update_sampled_patch``: the fused write-back that ALSO
patches the prefetched (B_local, J, d) buffer with the rows it is about
to write.  For ``ring`` and ``alltoall`` the write payload already
visits every shard, so the patch rides the existing hops and adds ZERO
wire bytes (``patch_bytes`` == 0, asserted vs the jaxpr).  ``bucketed``
writes travel owner-direct, so its patch is a genuinely tiny extra hop:
each wb row whose id reappears in the next batch travels once to the
shard that prefetched it (routing planned host-side — ``consumer_shards``
/ ``plan_patch_capacity`` — like the write buckets), costing
``(D-1) * patch_cap`` wb rows per device.  At f32 the patched buffer is
BIT-exact vs an inline lookup of the post-write table; under bf16/int8
the patch delivers the table's stored (write-rounded) value without the
read-side re-rounding — inside the existing bounded-error contract.
"""
from __future__ import annotations

from typing import Iterable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import embedding_table as tbl
from repro.kernels.ops import (dequantize_payload, iter_jaxpr_eqns,
                               quantize_payload)
from repro.kernels.quant import PAYLOAD_DTYPES

EXCHANGES = ("ring", "alltoall", "bucketed")

# collective primitives counted by measured_exchange_bytes, with the
# per-device send cost of each as a fraction of the operand:
#   ppermute   — the whole buffer leaves the device every hop:        1
#   all_to_all — (D-1) of the D leading-axis chunks leave:      (D-1)/D
#   all_gather — ring dissemination forwards D-1 chunks:           (D-1)
_COLLECTIVES = ("ppermute", "all_to_all", "all_gather")


# ---------------------------------------------------------------------------
# payload wire format
# ---------------------------------------------------------------------------


class PayloadCodec:
    """Wire format for the embedding payloads that cross the collectives.

    ``f32`` is the identity codec — encode/decode pass the array through
    untouched, so the default exchange stays bit-exact with an unchanged
    jaxpr.  ``bf16``/``int8`` pack rows via kernels/quant.py: encoded
    parts are ``(values,)`` for bf16 and ``(values int8, scale f32)`` for
    int8, with one scale per LEADING row (a bucket slot / batch row), 0
    for all-zero rows so ragged sentinel rows decode to exact zeros
    through every strategy.

    Read path (lookup answers): deterministic round-to-nearest — a repeated
    lookup of an unchanged row answers identically.  Write path: stochastic
    rounding with bits folded from ``(seed, step, shard)`` so repeated
    write round-trips stay unbiased and shards never share a bit stream.
    ``use_pallas`` routes the pack/unpack through the Pallas kernels
    (interpret mode off-TPU); the default jnp path computes the same bits
    exactly and lets XLA fuse the elementwise math into the step.
    """

    def __init__(self, dtype: str = "f32", *, axis_name: Optional[str] = None,
                 use_pallas: bool = False, seed: int = 0x6E57):
        if dtype not in PAYLOAD_DTYPES:
            raise ValueError(f"unknown payload dtype {dtype!r} — expected "
                             f"one of {PAYLOAD_DTYPES}")
        self.dtype = dtype
        self.axis_name = axis_name
        self.use_pallas = use_pallas
        self.seed = seed

    @property
    def itemsize(self) -> int:
        return {"f32": 4, "bf16": 2, "int8": 1}[self.dtype]

    @property
    def scale_bytes(self) -> int:
        """Per-leading-row side-channel bytes riding next to the values."""
        return 4 if self.dtype == "int8" else 0

    def row_bytes(self, n_elem: int) -> int:
        """Wire bytes of one leading row holding n_elem f32 elements."""
        return n_elem * self.itemsize + self.scale_bytes

    def encode_zeros(self, shape) -> Tuple[jnp.ndarray, ...]:
        """Encoded parts of an all-zero (B, ...) buffer — the ring
        lookup's riding answer buffer starts as this."""
        if self.dtype == "f32":
            return (jnp.zeros(shape, jnp.float32),)
        if self.dtype == "bf16":
            return (jnp.zeros(shape, jnp.bfloat16),)
        return (jnp.zeros(shape, jnp.int8),
                jnp.zeros(shape[:1], jnp.float32))

    def encode_read(self, x) -> Tuple[jnp.ndarray, ...]:
        """Lookup-answer packing: deterministic round-to-nearest."""
        if self.dtype == "f32":
            return (x,)
        return quantize_payload(x, dtype=self.dtype,
                                use_pallas=self.use_pallas)

    def encode_write(self, x, step) -> Tuple[jnp.ndarray, ...]:
        """Write-back packing: stochastic rounding, bits folded from
        (seed, step, shard) — deterministic given the step, distinct per
        shard, unbiased over repeated round-trips."""
        if self.dtype == "f32":
            return (x,)
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        if self.axis_name is not None:
            key = jax.random.fold_in(key, jax.lax.axis_index(self.axis_name))
        bits = jax.random.bits(key, x.shape, jnp.uint32)
        return quantize_payload(x, bits, dtype=self.dtype,
                                use_pallas=self.use_pallas)

    def decode(self, parts) -> jnp.ndarray:
        parts = tuple(parts)
        if self.dtype == "f32":
            return parts[0]
        return dequantize_payload(parts, dtype=self.dtype,
                                  use_pallas=self.use_pallas)


# ---------------------------------------------------------------------------
# strategy base
# ---------------------------------------------------------------------------


class Exchange:
    """One exchange strategy bound to a (axis_name, num_shards, rows) mesh
    geometry.  ``rows`` is the per-shard row count OF THE TABLE THE STEP
    SEES (``DistContext.table_rows`` — device-tier rows under a tiered
    store); owner arithmetic is ``id // rows`` throughout.

    ``cap`` (bucketed only): per-(device, owner) bucket capacity.  None
    falls back to the trace-time B_local — always safe, never smaller
    than needed — while a host-planned cap (``plan_capacity``) is what
    makes the strategy win; a batch exceeding the planned cap would be
    silently truncated, so drivers must validate with
    ``required_capacity`` before stepping.

    ``payload_dtype``: wire format for the embedding payloads
    (``PayloadCodec``).  Forced to the f32 identity at num_shards == 1,
    where nothing crosses a wire — single-shard runs stay bit-exact no
    matter the setting.

    ``patch_cap`` (bucketed only): per-(device, consumer) bucket capacity
    of the prefetch patch hop (``update_sampled_patch``).  Same contract
    as ``cap``: None falls back to the trace-time B_local, a host-planned
    value (``plan_patch_capacity``) makes it tiny, and exceeding it means
    silent truncation — validate with ``required_patch_capacity``.
    """

    name = "?"

    def __init__(self, *, axis_name: str, num_shards: int, rows: int,
                 cap: Optional[int] = None, payload_dtype: str = "f32",
                 patch_cap: Optional[int] = None):
        self.axis_name = axis_name
        self.num_shards = num_shards
        self.rows = rows
        self.cap = cap
        self.patch_cap = patch_cap
        self.payload_dtype = "f32" if num_shards <= 1 else payload_dtype
        self.codec = PayloadCodec(self.payload_dtype, axis_name=axis_name)

    @property
    def sentinel(self) -> int:
        """Row id used for ragged padding: out of every shard's range, so
        writes drop it and lookups answer zeros (``pad_ragged``)."""
        return self.num_shards * self.rows

    # -- table ops (inside shard_map) --------------------------------------

    def lookup(self, table: tbl.EmbeddingTable, graph_ids
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        raise NotImplementedError

    def lookup_ages(self, table: tbl.EmbeddingTable, graph_ids
                    ) -> jnp.ndarray:
        """Distributed read of the per-segment last-refresh-step plane
        (``table.age``, (R, J) int32) for global ``graph_ids`` (B,) —
        the age-weighted SED (``--sed-age-weighting``) input.  Pure row
        selection like ``lookup``'s init plane, always exact int32 on
        the wire (no payload codec), answering 0 for rows this exchange
        doesn't own (sentinel pads included — their η is masked anyway).
        Only traced when the decay is on, so the default train step's
        jaxpr is untouched."""
        raise NotImplementedError

    def update_sampled(self, table: tbl.EmbeddingTable, graph_ids, seg_idx,
                       h_new, step) -> tbl.EmbeddingTable:
        raise NotImplementedError

    def update_all(self, table: tbl.EmbeddingTable, graph_ids, h_all,
                   seg_valid, step) -> tbl.EmbeddingTable:
        raise NotImplementedError

    # -- prefetch lane (lookahead lookup + fused write-back patch) ---------

    def prefetch_lookup(self, table: tbl.EmbeddingTable, graph_ids
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """The lookup for the NEXT batch, dispatched as its own jitted
        collective while the current step is in flight.  Identical
        collectives (and bytes) to ``lookup`` — prefetch moves the same
        traffic EARLIER, it adds none.  The result is stale by exactly
        the rows the in-flight step writes back; ``update_sampled_patch``
        repairs those."""
        return self.lookup(table, graph_ids)

    def update_sampled_patch(self, table: tbl.EmbeddingTable, graph_ids,
                             seg_idx, h_new, step, pref, next_ids,
                             next_dest=None):
        """Fused ``update_sampled`` + prefetched-buffer patch.

        Applies the sampled write-back to the table exactly like
        ``update_sampled`` AND patches ``pref`` — the next batch's
        prefetched ``(emb (B, J, d), initialized (B, J))`` pair, looked
        up from the PRE-write table — with the rows this write is about
        to make stale.  ``next_ids`` is this device's (B,) slice of the
        next batch's global ids (sentinel-padded when ragged; sentinel
        slots are never patched).  ``next_dest`` is only consumed by the
        bucketed strategy: the host-planned (B_local,) consumer shard of
        each write row (``consumer_shards``; ``num_shards`` = no
        consumer).

        Returns ``(new_table, (patched_emb, patched_init))``.  At f32 the
        patched pair is bit-exact vs ``lookup(new_table, next_ids)``; at
        bf16/int8 it holds the table's stored (write-rounded) values —
        i.e. it SKIPS the read-side re-rounding an inline lookup would
        add, staying inside the bounded-error contract.
        """
        raise NotImplementedError

    def _local_update_patch(self, table, graph_ids, seg_idx, h_new, step,
                            pref, next_ids):
        """num_shards == 1 fused path: local scatter + local patch."""
        local_row = self._local_write_rows(graph_ids)
        table = tbl.update_sampled(table, local_row, seg_idx, h_new, step,
                                   mode="drop")
        emb, init = self._apply_patch(pref[0], pref[1], next_ids,
                                      graph_ids, seg_idx, h_new)
        return table, (emb, init)

    def _apply_patch(self, pref_emb, pref_init, next_ids, g_ids, g_sidx,
                     g_h):
        """Scatter decoded write rows ``(g_ids (G,), g_sidx (G, S),
        g_h (G, S, d))`` onto the prefetched ``(B, J, d)`` buffer wherever
        their id appears in ``next_ids`` (B,).  Purely local — no
        collectives.  Sentinel ids on either side never match (write-side
        sentinels are masked, a sentinel in ``next_ids`` exceeds every
        real id), so ragged padding no-ops.  Ids are unique within a
        batch, so each next-batch row has at most one matching write row
        and ``argmax`` over the match matrix is exact."""
        B, J = pref_init.shape[:2]
        match = ((g_ids[:, None] == next_ids[None, :])
                 & (g_ids[:, None] < self.sentinel))        # (G, B)
        has = match.any(axis=0)
        g_of = jnp.argmax(match, axis=0)                    # (B,)
        idx_j = jnp.where(has[:, None], g_sidx[g_of], J)    # J => dropped
        b_idx = jnp.arange(B)[:, None]
        emb = pref_emb.at[b_idx, idx_j].set(
            g_h[g_of].astype(pref_emb.dtype), mode="drop")
        init = pref_init.at[b_idx, idx_j].set(
            jnp.ones((), pref_init.dtype), mode="drop")
        return emb, init

    # -- analytic per-device bytes (match measured_exchange_bytes) ---------

    def lookup_bytes(self, b_local: int, j_max: int, d_h: int) -> int:
        raise NotImplementedError

    def update_sampled_bytes(self, b_local: int, s: int, d_h: int) -> int:
        raise NotImplementedError

    def update_all_bytes(self, b_local: int, j_max: int, d_h: int) -> int:
        raise NotImplementedError

    def train_step_bytes(self, b_local: int, j_max: int, s: int, d_h: int,
                         *, use_table: bool) -> int:
        """Per-device exchange traffic of one dist train step (lookup +
        sampled write-back when the variant uses the table), at this
        exchange's payload dtype."""
        if not use_table:
            return 0
        return (self.lookup_bytes(b_local, j_max, d_h)
                + self.update_sampled_bytes(b_local, s, d_h))

    def prefetch_lookup_bytes(self, b_local: int, j_max: int,
                              d_h: int) -> int:
        """Same collectives as ``lookup`` — prefetch moves bytes earlier,
        it adds none."""
        return self.lookup_bytes(b_local, j_max, d_h)

    def patch_bytes(self, b_local: int, s: int, d_h: int) -> int:
        """EXTRA wire bytes ``update_sampled_patch`` moves beyond
        ``update_sampled``.  0 for ring/alltoall: their write payload
        already visits every shard, so the patch rides the existing hops.
        Only bucketed (owner-direct writes never reach the consumers)
        pays a real — tiny, patch_cap-sized — extra hop."""
        return 0

    def update_sampled_patch_bytes(self, b_local: int, s: int,
                                   d_h: int) -> int:
        return (self.update_sampled_bytes(b_local, s, d_h)
                + self.patch_bytes(b_local, s, d_h))

    def prefetch_train_step_bytes(self, b_local: int, j_max: int, s: int,
                                  d_h: int, *, use_table: bool) -> int:
        """Per-device exchange traffic of one PREFETCHED dist train step:
        the next batch's prefetch lookup (same bytes as inline, just
        earlier) + the fused write-back-and-patch.  Net extra over
        ``train_step_bytes`` is exactly ``patch_bytes`` — 0 except
        bucketed."""
        if not use_table:
            return 0
        return (self.prefetch_lookup_bytes(b_local, j_max, d_h)
                + self.update_sampled_patch_bytes(b_local, s, d_h))

    # -- shared local fallbacks (num_shards == 1: no collectives) ----------

    def _local_lookup(self, table, graph_ids):
        mine = (graph_ids // self.rows) == 0
        local = jnp.clip(graph_ids, 0, self.rows - 1)
        e, i = tbl.lookup(table, local)
        return (jnp.where(mine[:, None, None], e, 0),
                jnp.where(mine[:, None], i, False))

    def _local_lookup_ages(self, table, graph_ids):
        mine = (graph_ids // self.rows) == 0
        local = jnp.clip(graph_ids, 0, self.rows - 1)
        return jnp.where(mine[:, None], table.age[local], 0)

    def _local_write_rows(self, graph_ids):
        mine = (graph_ids // self.rows) == 0
        return jnp.where(mine, graph_ids, self.rows)  # rows => dropped


# ---------------------------------------------------------------------------
# ring (the PR 3 exchange, now a strategy)
# ---------------------------------------------------------------------------


def _ring_perm(num_shards: int):
    return [(i, (i + 1) % num_shards) for i in range(num_shards)]


def _hop(axis_name, num_shards, *bufs):
    perm = _ring_perm(num_shards)
    return tuple(jax.lax.ppermute(b, axis_name, perm) for b in bufs)


class RingExchange(Exchange):
    """D-hop ppermute ring: rows a device owns are answered by a plain
    local gather on the first ring stop (zero communication for a
    perfectly-aligned batch); remote rows ride the ring — the (ids,
    payload) buffers hop with shift +1 and every shard answers/applies
    the rows it owns as the buffer passes through.  D hops for lookups
    (the answered buffer must come home), D-1 for writes (applied in
    place, nothing returns)."""

    name = "ring"

    def lookup(self, table, graph_ids):
        """Distributed ``tbl.lookup``: global graph_ids (B_l,) against the
        local (R, J, d) shard.  Pure row selection — no reductions — so at
        f32 the result is BIT-EXACT vs the dense single-device lookup; at
        bf16/int8 the answer buffer rides the ring in encoded form (each
        owner packs its answers in place) and decodes once at home."""
        me = jax.lax.axis_index(self.axis_name)
        rows, num_shards = self.rows, self.num_shards
        B = graph_ids.shape[0]
        parts = self.codec.encode_zeros((B,) + table.emb.shape[1:])
        init = jnp.zeros((B,) + table.initialized.shape[1:],
                         table.initialized.dtype)
        ids = graph_ids
        for _ in range(num_shards):
            owner = ids // rows
            mine = owner == me
            local_row = jnp.clip(ids - me * rows, 0, rows - 1)
            e, i = tbl.lookup(table, local_row)
            e_parts = self.codec.encode_read(e)
            parts = tuple(
                jnp.where(mine.reshape((B,) + (1,) * (p.ndim - 1)), ep, p)
                for p, ep in zip(parts, e_parts))
            init = jnp.where(mine[:, None], i, init)
            if num_shards > 1:
                ids, init, *parts = _hop(self.axis_name, num_shards,
                                         ids, init, *parts)
        return self.codec.decode(parts), init

    def lookup_ages(self, table, graph_ids):
        """Age plane over the same D ring hops as ``lookup``'s init plane:
        the (ids, ages) pair rides the ring, every owner answers its rows
        in place, exact int32 end to end."""
        me = jax.lax.axis_index(self.axis_name)
        rows, num_shards = self.rows, self.num_shards
        B = graph_ids.shape[0]
        ages = jnp.zeros((B,) + table.age.shape[1:], table.age.dtype)
        ids = graph_ids
        for _ in range(num_shards):
            mine = (ids // rows) == me
            local_row = jnp.clip(ids - me * rows, 0, rows - 1)
            ages = jnp.where(mine[:, None], table.age[local_row], ages)
            if num_shards > 1:
                ids, ages = _hop(self.axis_name, num_shards, ids, ages)
        return ages

    def update_sampled(self, table, graph_ids, seg_idx, h_new, step):
        """Distributed ``tbl.update_sampled``: the (ids, seg_idx, payload)
        write buffer rides the ring; each shard applies the writes it owns
        in place (donated scatter, mode="drop" for everything else).  The
        payload is packed ONCE at the source (stochastic rounding) and
        each shard decodes the passing buffer before its scatter."""
        ids, sidx = graph_ids, seg_idx
        parts = self.codec.encode_write(h_new, step)
        me = jax.lax.axis_index(self.axis_name)
        rows, num_shards = self.rows, self.num_shards
        for t in range(num_shards):
            mine = (ids // rows) == me
            local_row = jnp.where(mine, ids - me * rows, rows)  # => dropped
            table = tbl.update_sampled(table, local_row, sidx,
                                       self.codec.decode(parts), step,
                                       mode="drop")
            if t < num_shards - 1:  # write buffers need no homecoming hop
                ids, sidx, *parts = _hop(self.axis_name, num_shards,
                                         ids, sidx, *parts)
        return table

    def update_sampled_patch(self, table, graph_ids, seg_idx, h_new, step,
                             pref, next_ids, next_dest=None):
        """Fused write-back + patch on the SAME D-1 ring hops: the write
        buffer already visits every shard, so each shard patches its
        prefetched buffer with the passing rows as it applies the ones it
        owns — zero added wire bytes (asserted vs the jaxpr)."""
        emb, init = pref
        ids, sidx = graph_ids, seg_idx
        parts = self.codec.encode_write(h_new, step)
        me = jax.lax.axis_index(self.axis_name)
        rows, num_shards = self.rows, self.num_shards
        for t in range(num_shards):
            mine = (ids // rows) == me
            local_row = jnp.where(mine, ids - me * rows, rows)  # => dropped
            h_dec = self.codec.decode(parts)
            table = tbl.update_sampled(table, local_row, sidx, h_dec, step,
                                       mode="drop")
            emb, init = self._apply_patch(emb, init, next_ids,
                                          ids, sidx, h_dec)
            if t < num_shards - 1:
                ids, sidx, *parts = _hop(self.axis_name, num_shards,
                                         ids, sidx, *parts)
        return table, (emb, init)

    def update_all(self, table, graph_ids, h_all, seg_valid, step):
        """Distributed ``tbl.update_all`` (refresh phase) over the ring."""
        ids, sv = graph_ids, seg_valid
        parts = self.codec.encode_write(h_all, step)
        me = jax.lax.axis_index(self.axis_name)
        rows, num_shards = self.rows, self.num_shards
        for t in range(num_shards):
            mine = (ids // rows) == me
            local_row = jnp.where(mine, ids - me * rows, rows)
            table = tbl.update_all(table, local_row,
                                   self.codec.decode(parts), sv, step,
                                   mode="drop")
            if t < num_shards - 1:  # write buffers need no homecoming hop
                ids, sv, *parts = _hop(self.axis_name, num_shards,
                                       ids, sv, *parts)
        return table

    def lookup_bytes(self, b_local, j_max, d_h):
        # D hops of the (ids i32, init bool, payload) buffer
        if self.num_shards <= 1:
            return 0
        per_hop = b_local * (
            4 + j_max * 1 + self.codec.row_bytes(j_max * d_h))
        return self.num_shards * per_hop

    def update_sampled_bytes(self, b_local, s, d_h):
        if self.num_shards <= 1:
            return 0
        per_hop = b_local * (4 + s * 4 + self.codec.row_bytes(s * d_h))
        return (self.num_shards - 1) * per_hop

    def update_all_bytes(self, b_local, j_max, d_h):
        if self.num_shards <= 1:
            return 0
        per_hop = b_local * (
            4 + j_max * 4 + self.codec.row_bytes(j_max * d_h))
        return (self.num_shards - 1) * per_hop


# ---------------------------------------------------------------------------
# alltoall (full-buffer dissemination, one payload round-trip)
# ---------------------------------------------------------------------------


def _a2a(x, axis_name):
    """Transpose-exchange: x (D, cap, ...) where x[j] is destined to device
    j; the result's row j is what device j sent here."""
    return jax.lax.all_to_all(x, axis_name, 0, 0, tiled=True)


class AllToAllExchange(Exchange):
    """Full-buffer dissemination: queries ``all_gather`` to every shard
    (ids are cheap), each shard answers the dense (D, B_local) block for
    the rows it owns, and ONE ``all_to_all`` brings the answers home —
    the requester selects its owner's answer by direct indexing (no
    masked sums, so -0.0 and NaN payloads stay bit-identical).  Writes
    are the dual: the full (ids, payload) buffers all_gather to every
    shard and each shard applies the rows it owns with mode="drop".

    vs ring: 2 collectives instead of D hops and one payload leg fewer
    on lookups ((D-1) vs D), but the dense answer block still scales
    with D·B_local."""

    name = "alltoall"

    def lookup(self, table, graph_ids):
        rows, D, ax = self.rows, self.num_shards, self.axis_name
        B = graph_ids.shape[0]
        if D == 1:
            return self._local_lookup(table, graph_ids)
        me = jax.lax.axis_index(ax)
        all_ids = jax.lax.all_gather(graph_ids, ax)          # (D, B)
        local = jnp.clip(all_ids - me * rows, 0, rows - 1).reshape(-1)
        owned = (all_ids // rows).reshape(-1) == me
        e, i = tbl.lookup(table, local)
        # zero non-owned answers so ragged/padded positions come home as
        # zeros no matter which shard they were clipped into (an all-zero
        # row packs to scale 0 under int8, so it decodes to exact zeros)
        e = jnp.where(owned[:, None, None], e, 0)
        i = jnp.where(owned[:, None], i, False)
        parts = self.codec.encode_read(e)                    # leading D·B
        parts_back = tuple(_a2a(p.reshape((D, B) + p.shape[1:]), ax)
                           for p in parts)
        i_back = _a2a(i.reshape((D, B) + table.initialized.shape[1:]), ax)
        owner = jnp.clip(graph_ids // rows, 0, D - 1)
        r = jnp.arange(B)
        return (self.codec.decode(tuple(p[owner, r] for p in parts_back)),
                i_back[owner, r])

    def lookup_ages(self, table, graph_ids):
        """Age plane over the same all_gather + all_to_all pair as
        ``lookup``'s init plane — owner answers, one a2a home, direct
        [owner, r] selection."""
        rows, D, ax = self.rows, self.num_shards, self.axis_name
        B = graph_ids.shape[0]
        if D == 1:
            return self._local_lookup_ages(table, graph_ids)
        me = jax.lax.axis_index(ax)
        all_ids = jax.lax.all_gather(graph_ids, ax)          # (D, B)
        local = jnp.clip(all_ids - me * rows, 0, rows - 1).reshape(-1)
        owned = (all_ids // rows).reshape(-1) == me
        a = jnp.where(owned[:, None], table.age[local], 0)
        a_back = _a2a(a.reshape((D, B) + table.age.shape[1:]), ax)
        owner = jnp.clip(graph_ids // rows, 0, D - 1)
        return a_back[owner, jnp.arange(B)]

    def _gathered_writes(self, graph_ids, *payloads):
        """all_gather the global write buffers; returns the RAW gathered
        ids too (the fused patch reuses them — no second gather)."""
        ax = self.axis_name
        ids = jax.lax.all_gather(graph_ids, ax).reshape(-1)
        flat = [jax.lax.all_gather(p, ax).reshape((-1,) + p.shape[1:])
                for p in payloads]
        me = jax.lax.axis_index(ax)
        mine = (ids // self.rows) == me
        local_row = jnp.where(mine, ids - me * self.rows, self.rows)
        return (ids, local_row, *flat)

    def update_sampled(self, table, graph_ids, seg_idx, h_new, step):
        if self.num_shards == 1:
            local_row = self._local_write_rows(graph_ids)
            return tbl.update_sampled(table, local_row, seg_idx, h_new,
                                      step, mode="drop")
        parts = self.codec.encode_write(h_new, step)
        _, local_row, sidx, *eparts = self._gathered_writes(
            graph_ids, seg_idx, *parts)
        return tbl.update_sampled(table, local_row, sidx,
                                  self.codec.decode(eparts), step,
                                  mode="drop")

    def update_sampled_patch(self, table, graph_ids, seg_idx, h_new, step,
                             pref, next_ids, next_dest=None):
        """Fused write-back + patch on the SAME all_gathers: every shard
        already receives the full global write buffer, so the patch is a
        local scatter over it — zero added wire bytes (asserted vs the
        jaxpr)."""
        emb, init = pref
        if self.num_shards == 1:
            return self._local_update_patch(table, graph_ids, seg_idx,
                                            h_new, step, pref, next_ids)
        parts = self.codec.encode_write(h_new, step)
        ids, local_row, sidx, *eparts = self._gathered_writes(
            graph_ids, seg_idx, *parts)
        h_dec = self.codec.decode(eparts)
        table = tbl.update_sampled(table, local_row, sidx, h_dec, step,
                                   mode="drop")
        emb, init = self._apply_patch(emb, init, next_ids, ids, sidx,
                                      h_dec)
        return table, (emb, init)

    def update_all(self, table, graph_ids, h_all, seg_valid, step):
        if self.num_shards == 1:
            local_row = self._local_write_rows(graph_ids)
            return tbl.update_all(table, local_row, h_all, seg_valid, step,
                                  mode="drop")
        parts = self.codec.encode_write(h_all, step)
        _, local_row, sv, *eparts = self._gathered_writes(
            graph_ids, seg_valid, *parts)
        return tbl.update_all(table, local_row, self.codec.decode(eparts),
                              sv, step, mode="drop")

    def lookup_bytes(self, b_local, j_max, d_h):
        if self.num_shards <= 1:
            return 0
        # ids all_gather + (payload, init bool) answers all_to_all
        return (self.num_shards - 1) * b_local * (
            4 + self.codec.row_bytes(j_max * d_h) + j_max * 1)

    def update_sampled_bytes(self, b_local, s, d_h):
        if self.num_shards <= 1:
            return 0
        # (ids, seg_idx, payload) all_gathered to every shard
        return (self.num_shards - 1) * b_local * (
            4 + s * 4 + self.codec.row_bytes(s * d_h))

    def update_all_bytes(self, b_local, j_max, d_h):
        if self.num_shards <= 1:
            return 0
        # (ids, payload, seg_valid f32) all_gathered to every shard
        return (self.num_shards - 1) * b_local * (
            4 + self.codec.row_bytes(j_max * d_h) + j_max * 4)


# ---------------------------------------------------------------------------
# bucketed (owner-direct: one hop there, one hop back)
# ---------------------------------------------------------------------------


class BucketedExchange(Exchange):
    """Owner-direct exchange: local queries are stable-sorted by owner
    shard and scattered into (D, cap) per-owner buckets; ONE all_to_all
    delivers each bucket straight to its owner, which answers/applies it,
    and (for lookups) one all_to_all brings exactly the requested rows
    back.  Each row travels one hop to its owner and one hop home —
    traffic scales with the BUCKET capacity, not the shard count.

    ``cap`` is a static shape: None falls back to B_local (safe for any
    owner distribution, but then the buckets are as big as the alltoall
    block).  The win comes from host-side planning — ``plan_capacity``
    over the epoch's id schedule gives the tightest safe cap (≈ B_local/D
    for near-uniform batches).  A batch needing more than ``cap`` rows of
    one owner from one device would be silently truncated by the
    mode="drop" bucket scatter, so drivers MUST validate planned caps
    with ``required_capacity`` (launch/train_dist.py and the parity
    harness do)."""

    name = "bucketed"

    def _plan_by(self, key):
        """(order, sorted_key, rank-within-key) for a (B,) routing key.
        Keys may exceed num_shards - 1 (the patch's "no consumer" mark);
        the bucket scatter's mode="drop" discards those rows."""
        order = jnp.argsort(key, stable=True)
        sk = key[order]
        pos = jnp.arange(key.shape[0]) - jnp.searchsorted(sk, sk,
                                                          side="left")
        return order, sk, pos

    def _plan(self, graph_ids):
        """(order, sorted_owner, rank-within-owner) for the local batch."""
        owner = jnp.clip(graph_ids // self.rows, 0, self.num_shards - 1)
        return self._plan_by(owner)

    def _bucket(self, cap, so, pos, x_sorted, fill):
        b = jnp.full((self.num_shards, cap) + x_sorted.shape[1:], fill,
                     x_sorted.dtype)
        return b.at[so, pos].set(x_sorted, mode="drop")

    def lookup(self, table, graph_ids):
        rows, D, ax = self.rows, self.num_shards, self.axis_name
        B = graph_ids.shape[0]
        if D == 1:
            return self._local_lookup(table, graph_ids)
        cap = self.cap or B
        order, so, pos = self._plan(graph_ids)
        buckets = self._bucket(cap, so, pos, graph_ids[order],
                               jnp.int32(self.sentinel))
        q = _a2a(buckets, ax)                      # (D, cap) queries I own
        me = jax.lax.axis_index(ax)
        local = jnp.clip(q - me * rows, 0, rows - 1).reshape(-1)
        owned = (q // rows).reshape(-1) == me      # False for sentinel slots
        e, i = tbl.lookup(table, local)
        e = jnp.where(owned[:, None, None], e, 0)  # scale 0 under int8
        i = jnp.where(owned[:, None], i, False)
        parts = self.codec.encode_read(e)          # leading D·cap
        parts_back = tuple(_a2a(p.reshape((D, cap) + p.shape[1:]), ax)
                           for p in parts)
        i_back = _a2a(i.reshape((D, cap) + table.initialized.shape[1:]), ax)
        inv = jnp.argsort(order, stable=True)
        return (self.codec.decode(tuple(p[so, pos][inv]
                                        for p in parts_back)),
                i_back[so, pos][inv])

    def lookup_ages(self, table, graph_ids):
        """Age plane owner-direct: the same (D, cap) id buckets as
        ``lookup``, one all_to_all there, one back, inverse-permuted
        home."""
        rows, D, ax = self.rows, self.num_shards, self.axis_name
        B = graph_ids.shape[0]
        if D == 1:
            return self._local_lookup_ages(table, graph_ids)
        cap = self.cap or B
        order, so, pos = self._plan(graph_ids)
        buckets = self._bucket(cap, so, pos, graph_ids[order],
                               jnp.int32(self.sentinel))
        q = _a2a(buckets, ax)
        me = jax.lax.axis_index(ax)
        local = jnp.clip(q - me * rows, 0, rows - 1).reshape(-1)
        owned = (q // rows).reshape(-1) == me  # False for sentinel slots
        a = jnp.where(owned[:, None], table.age[local], 0)
        a_back = _a2a(a.reshape((D, cap) + table.age.shape[1:]), ax)
        inv = jnp.argsort(order, stable=True)
        return a_back[so, pos][inv]

    def _bucketed_writes(self, graph_ids, *payloads):
        cap = self.cap or graph_ids.shape[0]
        order, so, pos = self._plan(graph_ids)
        idb = self._bucket(cap, so, pos, graph_ids[order],
                           jnp.int32(self.sentinel))
        bufs = [self._bucket(cap, so, pos, p[order], p.dtype.type(0))
                for p in payloads]
        q = _a2a(idb, self.axis_name).reshape(-1)
        flat = [_a2a(b, self.axis_name).reshape((-1,) + b.shape[2:])
                for b in bufs]
        me = jax.lax.axis_index(self.axis_name)
        mine = (q // self.rows) == me              # sentinel never matches
        local_row = jnp.where(mine, q - me * self.rows, self.rows)
        return (local_row, *flat)

    def update_sampled(self, table, graph_ids, seg_idx, h_new, step):
        if self.num_shards == 1:
            local_row = self._local_write_rows(graph_ids)
            return tbl.update_sampled(table, local_row, seg_idx, h_new,
                                      step, mode="drop")
        parts = self.codec.encode_write(h_new, step)
        local_row, sidx, *eparts = self._bucketed_writes(
            graph_ids, seg_idx, *parts)
        return tbl.update_sampled(table, local_row, sidx,
                                  self.codec.decode(eparts), step,
                                  mode="drop")

    def update_sampled_patch(self, table, graph_ids, seg_idx, h_new, step,
                             pref, next_ids, next_dest=None):
        """Fused write-back + patch.  Owner-direct writes never reach the
        shards that prefetched the rows, so — alone among the strategies
        — bucketed pays a real (tiny) patch hop: each write row whose id
        reappears in the next batch is bucketed by its CONSUMER shard
        (``next_dest``, planned host-side like the write buckets — zero
        wire cost for the routing itself) and one all_to_all of
        ``patch_cap``-sized buckets delivers it for the local scatter.
        Rows with no consumer (next_dest == num_shards) are dropped by
        the bucket scatter and never travel."""
        emb, init = pref
        if self.num_shards == 1:
            return self._local_update_patch(table, graph_ids, seg_idx,
                                            h_new, step, pref, next_ids)
        if next_dest is None:
            raise ValueError(
                "bucketed update_sampled_patch needs next_dest — the "
                "host-planned consumer shard of each write row "
                "(consumer_shards)")
        ax = self.axis_name
        parts = self.codec.encode_write(h_new, step)
        local_row, sidx, *eparts = self._bucketed_writes(
            graph_ids, seg_idx, *parts)
        table = tbl.update_sampled(table, local_row, sidx,
                                   self.codec.decode(eparts), step,
                                   mode="drop")
        cap = self.patch_cap or graph_ids.shape[0]
        order, sd, pos = self._plan_by(next_dest)
        idb = self._bucket(cap, sd, pos, graph_ids[order],
                           jnp.int32(self.sentinel))
        sxb = self._bucket(cap, sd, pos, seg_idx[order], jnp.int32(0))
        pbufs = [self._bucket(cap, sd, pos, p[order], p.dtype.type(0))
                 for p in parts]
        q_ids = _a2a(idb, ax).reshape(-1)
        q_sidx = _a2a(sxb, ax).reshape((-1,) + seg_idx.shape[1:])
        q_parts = [_a2a(b, ax).reshape((-1,) + b.shape[2:])
                   for b in pbufs]
        emb, init = self._apply_patch(emb, init, next_ids, q_ids, q_sidx,
                                      self.codec.decode(q_parts))
        return table, (emb, init)

    def update_all(self, table, graph_ids, h_all, seg_valid, step):
        if self.num_shards == 1:
            local_row = self._local_write_rows(graph_ids)
            return tbl.update_all(table, local_row, h_all, seg_valid, step,
                                  mode="drop")
        parts = self.codec.encode_write(h_all, step)
        local_row, sv, *eparts = self._bucketed_writes(
            graph_ids, seg_valid, *parts)
        return tbl.update_all(table, local_row, self.codec.decode(eparts),
                              sv, step, mode="drop")

    def _cap(self, b_local: int) -> int:
        return self.cap if self.cap is not None else b_local

    def lookup_bytes(self, b_local, j_max, d_h):
        if self.num_shards <= 1:
            return 0
        c = self._cap(b_local)
        # id buckets one hop there + (payload, init bool) one hop back
        return (self.num_shards - 1) * c * (
            4 + self.codec.row_bytes(j_max * d_h) + j_max * 1)

    def update_sampled_bytes(self, b_local, s, d_h):
        if self.num_shards <= 1:
            return 0
        c = self._cap(b_local)
        return (self.num_shards - 1) * c * (
            4 + s * 4 + self.codec.row_bytes(s * d_h))

    def update_all_bytes(self, b_local, j_max, d_h):
        if self.num_shards <= 1:
            return 0
        c = self._cap(b_local)
        return (self.num_shards - 1) * c * (
            4 + self.codec.row_bytes(j_max * d_h) + j_max * 4)

    def patch_bytes(self, b_local, s, d_h):
        # one consumer-direct all_to_all of (ids, seg_idx, payload)
        # patch_cap-sized buckets — the only strategy with a nonzero
        # prefetch surcharge
        if self.num_shards <= 1:
            return 0
        c = self.patch_cap if self.patch_cap is not None else b_local
        return (self.num_shards - 1) * c * (
            4 + s * 4 + self.codec.row_bytes(s * d_h))


# ---------------------------------------------------------------------------
# construction / auto selection
# ---------------------------------------------------------------------------

_STRATEGIES = {cls.name: cls
               for cls in (RingExchange, AllToAllExchange, BucketedExchange)}


def make_exchange(name: str, *, axis_name: str, num_shards: int, rows: int,
                  cap: Optional[int] = None,
                  payload_dtype: str = "f32",
                  patch_cap: Optional[int] = None) -> Exchange:
    """Strategy by name.  "auto" is a DRIVER-side policy — resolve it with
    ``select_exchange`` (it needs the batch geometry) before building."""
    if name == "auto":
        raise ValueError(
            '"auto" must be resolved before building steps: call '
            "select_exchange(num_shards, b_local, j_max, s, d_h) with the "
            "batch geometry and pass the returned strategy name")
    if name not in _STRATEGIES:
        raise ValueError(f"unknown exchange strategy {name!r} — expected "
                         f"one of {EXCHANGES} or 'auto'")
    return _STRATEGIES[name](axis_name=axis_name, num_shards=num_shards,
                             rows=rows, cap=cap, payload_dtype=payload_dtype,
                             patch_cap=patch_cap)


def select_exchange(num_shards: int, b_local: int, j_max: int, s: int,
                    d_h: int, *, cap: Optional[int] = None,
                    payload_dtype: str = "f32") -> str:
    """The "--exchange=auto" policy: the strategy with the fewest analytic
    per-device train-step bytes at this (shard count, payload dtype) —
    first of EXCHANGES wins ties, so 1 shard — where every model is 0 —
    stays on the ring.  Precision-aware: compression shrinks the payload
    term but not the fixed id/seg_idx/init overhead, so the ring/alltoall/
    bucketed break-even points shift with the dtype.

    ``cap``: the bucketed strategy's planned bucket capacity; defaults to
    the uniform-owner estimate ceil(b_local / num_shards), which is what a
    host-planned cap converges to for shuffled batches."""
    if num_shards <= 1:
        return "ring"
    cap_est = cap if cap is not None else -(-b_local // num_shards)
    best_name, best_bytes = None, None
    for name in EXCHANGES:
        ex = make_exchange(name, axis_name="_model", num_shards=num_shards,
                           rows=1, cap=cap_est, payload_dtype=payload_dtype)
        b = ex.train_step_bytes(b_local, j_max, s, d_h, use_table=True)
        if best_bytes is None or b < best_bytes:
            best_name, best_bytes = name, b
    return best_name


# ---------------------------------------------------------------------------
# host-side planning: ragged batches + bucket capacity
# ---------------------------------------------------------------------------


def pad_ragged(num_shards: int, rows: int, ids, *payloads):
    """Pad a GLOBAL exchange batch to a shard-divisible size.

    The shard_map batch specs split the leading axis evenly, so a batch
    whose global size doesn't divide the shard count (a ragged last
    shard) used to be the CALLER's problem.  This is the guard: ids are
    padded with the strategies' sentinel (``num_shards · rows`` — out of
    every shard's owner range, so writes drop the pad rows and lookups
    answer zeros there) and payloads with zeros.

    Returns ``(padded_ids, *padded_payloads, n_real)``; slice exchange
    results back to ``[:n_real]``.
    """
    ids = np.asarray(ids)
    B = ids.shape[0]
    Bp = -(-B // num_shards) * num_shards
    if Bp == B:
        return (ids, *[np.asarray(p) for p in payloads], B)
    out = [np.concatenate(
        [ids, np.full(Bp - B, num_shards * rows, ids.dtype)])]
    for p in payloads:
        p = np.asarray(p)
        out.append(np.concatenate(
            [p, np.zeros((Bp - B,) + p.shape[1:], p.dtype)]))
    return (*out, B)


def required_capacity(global_ids, *, num_shards: int, rows: int) -> int:
    """Smallest per-(device, owner) bucket capacity that fits ONE global
    batch under the contiguous batch split (device k gets batch rows
    [k·B_local, (k+1)·B_local)).  Out-of-range/sentinel ids count against
    the last shard's bucket, matching the clipped owner arithmetic."""
    ids = np.asarray(global_ids).ravel()
    if ids.size % num_shards:
        ids = pad_ragged(num_shards, rows, ids)[0]
    per_dev = ids.reshape(num_shards, -1)
    owner = np.clip(per_dev // rows, 0, num_shards - 1)
    cap = 1
    for dev in range(num_shards):
        counts = np.bincount(owner[dev], minlength=num_shards)
        cap = max(cap, int(counts.max()))
    return cap


def plan_capacity(id_batches: Iterable, *, num_shards: int,
                  rows: int) -> int:
    """Bucket capacity covering EVERY batch of an id schedule — the
    host-side planning step that makes ``bucketed`` beat the ring (the
    cap, not the shard count, sizes its buckets)."""
    cap = 1
    for ids in id_batches:
        cap = max(cap, required_capacity(ids, num_shards=num_shards,
                                         rows=rows))
    return cap


def consumer_shards(cur_ids, next_ids, *, num_shards: int,
                    rows: int) -> np.ndarray:
    """For each row of the CURRENT global batch, the shard whose slice of
    the NEXT global batch contains the same id — the bucketed patch's
    host-planned routing (``next_dest``), computed under the contiguous
    batch split.  ``num_shards`` marks rows with no next-batch consumer
    (they never travel); sentinel pad rows on either side never match.
    Ragged batches are sentinel-padded first, like the device path."""
    sent = num_shards * rows
    cur = np.asarray(cur_ids).ravel()
    if cur.size % num_shards:
        cur = pad_ragged(num_shards, rows, cur)[0]
    nxt = np.asarray(next_ids).ravel()
    if nxt.size % num_shards:
        nxt = pad_ragged(num_shards, rows, nxt)[0]
    b_next = nxt.size // num_shards
    shard_of = {int(r): i // b_next for i, r in enumerate(nxt)
                if int(r) != sent}
    return np.asarray([shard_of.get(int(r), num_shards) for r in cur],
                      np.int32)


def required_patch_capacity(cur_ids, next_ids, *, num_shards: int,
                            rows: int) -> int:
    """Smallest per-(device, consumer) patch bucket capacity for ONE
    (batch k, batch k+1) pair — how many of one device's write rows
    reappear in one consumer shard's next-batch slice."""
    dest = consumer_shards(cur_ids, next_ids, num_shards=num_shards,
                           rows=rows)
    cap = 1
    for per_dev in dest.reshape(num_shards, -1):
        real = per_dev[per_dev < num_shards]
        if real.size:
            cap = max(cap, int(np.bincount(real).max()))
    return cap


def plan_patch_capacity(id_batches: Iterable, *, num_shards: int,
                        rows: int) -> int:
    """Patch bucket capacity covering every CONSECUTIVE pair of an id
    schedule — the prefetch lane patches step k's writes onto batch
    k+1's buffer, so only adjacent batches matter.  Near-disjoint
    shuffled schedules plan to ~1; an all-overlap schedule degenerates
    to ``required_capacity``-sized buckets."""
    cap = 1
    batches = [np.asarray(b) for b in id_batches]
    for a, b in zip(batches, batches[1:]):
        cap = max(cap, required_patch_capacity(
            a, b, num_shards=num_shards, rows=rows))
    return cap


# ---------------------------------------------------------------------------
# measured collective traffic (validates the analytic models)
# ---------------------------------------------------------------------------


def measured_exchange_bytes(fn, num_shards: int, *args, **kwargs) -> int:
    """Per-device bytes moved through the collective eqns of ``fn``'s
    jaxpr (recursing through shard_map/pjit).  Counting conventions match
    the analytic models: a ppermute sends its whole operand every hop, an
    all_to_all keeps 1/D of its operand home, an all_gather forwards D-1
    chunks of its input.  tests/test_exchange_props.py asserts equality
    with every strategy's ``*_bytes`` model."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    total = 0
    for eqn in iter_jaxpr_eqns(closed.jaxpr):
        if eqn.primitive.name not in _COLLECTIVES:
            continue
        for v in eqn.invars:
            aval = getattr(v, "aval", None)
            if aval is None or not hasattr(aval, "shape"):
                continue
            nbytes = int(np.prod(aval.shape, dtype=np.int64)) * \
                np.dtype(aval.dtype).itemsize
            if eqn.primitive.name == "ppermute":
                total += nbytes
            elif eqn.primitive.name == "all_to_all":
                total += nbytes * (num_shards - 1) // num_shards
            else:  # all_gather
                total += nbytes * (num_shards - 1)
    return total


# ---------------------------------------------------------------------------
# ring byte accounting (module-level: the PR 3 names, re-exported by
# dist/table.py for backward compatibility)
# ---------------------------------------------------------------------------


def lookup_exchange_bytes(num_shards: int, b_local: int, j_max: int,
                          d_h: int, itemsize: int = 4) -> int:
    """Per-device bytes moved through the ring for ONE lookup: D hops of the
    (ids int32, emb f32, initialized bool) buffer.  0 when unsharded."""
    if num_shards <= 1:
        return 0
    per_hop = b_local * (4 + j_max * d_h * itemsize + j_max * 1)
    return num_shards * per_hop


def update_sampled_exchange_bytes(num_shards: int, b_local: int, s: int,
                                  d_h: int, itemsize: int = 4) -> int:
    """Per-device ring bytes for ONE sampled write-back: (ids, seg_idx,
    h_new) buffers, D-1 hops (writes need no homecoming hop)."""
    if num_shards <= 1:
        return 0
    per_hop = b_local * (4 + s * 4 + s * d_h * itemsize)
    return (num_shards - 1) * per_hop


def update_all_exchange_bytes(num_shards: int, b_local: int, j_max: int,
                              d_h: int, itemsize: int = 4) -> int:
    """Per-device ring bytes for ONE full refresh write: (ids, h_all,
    seg_valid) buffers, D-1 hops (writes need no homecoming hop)."""
    if num_shards <= 1:
        return 0
    per_hop = b_local * (4 + j_max * d_h * itemsize + j_max * 4)
    return (num_shards - 1) * per_hop


def train_step_exchange_bytes(num_shards: int, b_local: int, j_max: int,
                              s: int, d_h: int, *, use_table: bool) -> int:
    """Total per-device ring traffic of one dist train step (lookup +
    sampled write-back when the variant uses the table)."""
    if not use_table:
        return 0
    return (lookup_exchange_bytes(num_shards, b_local, j_max, d_h)
            + update_sampled_exchange_bytes(num_shards, b_local, s, d_h))
