"""shard_map data-parallel GST training (dist subsystem).

Wraps the UNCHANGED step builders of core/gst.py in ``shard_map`` over a
1-D ``data`` device mesh:

  * backbone / head / opt_state / step — replicated (P());
  * historical table — row-sharded (P("data") on the graph axis, see
    dist/table.py).  Since the store refactor the sharded array is
    whatever device tier the context's EmbeddingStore provides
    (``make_dist_store``): the full table (DeviceStore, default) or each
    shard's bounded LRU slice of it (TieredStore, ``device_rows=``), with
    the table exchange — a pluggable ring/alltoall/bucketed strategy
    since ISSUE 5 (dist/exchange.py, ``ctx.exchange``) — routing on
    device-row ids via ``ctx.table_rows``;
  * batch — sharded on the leading batch dim, carrying ``batch_pos`` so
    every row draws the same per-row RNG stream as the single-device
    oracle (core/segment.py::per_row_keys);
  * gradients / loss / metrics — pmean'd across the axis inside the step
    (core/gst.py ``axis_name=``), so the replicated optimizer update is
    identical on every shard.

The batched Pallas kernels of PR 1 run per-shard unchanged — shard_map
hands each device its (B/D)·S segment slice and the kernels never see the
mesh.  The whole step stays jit-donated: table shards scatter in place.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import embedding_table as tbl
from repro.core import gst as G
from repro.dist import table as dtbl
from repro.obs import probe_jit
from repro.dist.exchange import EXCHANGES, PAYLOAD_DTYPES, make_exchange
from repro.store import DeviceStore, EmbeddingStore, TieredStore
from repro.store import base as store_base

AXIS = "data"


# ---------------------------------------------------------------------------
# mesh / context
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DistContext:
    """Static facts every dist step closure needs."""
    mesh: Mesh
    num_shards: int
    n_rows: int          # unpadded historical-table rows (n_graphs)
    rows_per_shard: int
    # device-resident rows PER SHARD when the table is tiered (store/),
    # None = fully device-resident.  The table exchange routes by
    # ``id // table_rows``; with a tiered store the ids the step sees are
    # the store's device-row ("slot") ids, whose owner arithmetic uses the
    # device-tier row count instead of the full shard row count.
    device_rows_per_shard: Optional[int] = None
    # table-exchange strategy (dist/exchange.py): "ring", "alltoall" or
    # "bucketed" — "auto" is resolved by the driver via select_exchange
    # BEFORE make_context (it needs the batch geometry)
    exchange: str = "ring"
    # bucketed-only: host-planned per-(device, owner) bucket capacity
    # (exchange.plan_capacity over the id schedule); None = B_local, safe
    # for any owner distribution but no smaller than the alltoall block
    exchange_cap: Optional[int] = None
    # wire format for embedding payloads crossing the exchange collectives
    # (exchange.PayloadCodec): "f32" (identity, bit-exact), "bf16" or
    # "int8" (per-row scale, stochastic rounding on write-backs)
    payload_dtype: str = "f32"
    # lookahead prefetch lane (--prefetch-lookups): the train step consumes
    # a prefetched next-batch lookup buffer (make_prefetch_lookup) instead
    # of running the exchange inline, and its fused write-back patches the
    # buffer for batch k+1 (exchange.update_sampled_patch) — bit-exact vs
    # the inline oracle at f32
    prefetch: bool = False
    # bucketed-only: host-planned per-(device, consumer) patch bucket
    # capacity (exchange.plan_patch_capacity over consecutive batches);
    # None = B_local, always safe
    patch_cap: Optional[int] = None

    @property
    def axis_name(self) -> str:
        return AXIS

    @property
    def table_rows(self) -> int:
        """Rows per shard OF THE TABLE THE STEP SEES (exchange owner
        arithmetic)."""
        return self.device_rows_per_shard or self.rows_per_shard


def make_dist_mesh(num_devices: Optional[int] = None) -> Mesh:
    """1-D data mesh over the first ``num_devices`` local devices."""
    devs = jax.devices()
    nd = num_devices or len(devs)
    if nd > len(devs):
        raise RuntimeError(
            f"requested {nd} devices, found {len(devs)} — force a multi-"
            "device host with XLA_FLAGS=--xla_force_host_platform_device_"
            "count=N before importing jax")
    return Mesh(np.asarray(devs[:nd]), (AXIS,))


def make_context(mesh: Mesh, n_rows: int,
                 device_rows: Optional[int] = None, *,
                 exchange: str = "ring",
                 exchange_cap: Optional[int] = None,
                 payload_dtype: str = "f32",
                 prefetch: bool = False,
                 patch_cap: Optional[int] = None) -> DistContext:
    """``device_rows``: total device-resident row cap (the
    --table-device-rows knob); None keeps the table fully resident.
    ``exchange``/``exchange_cap``: table-exchange strategy + its planned
    bucket capacity (see DistContext).  ``payload_dtype``: exchange wire
    format (--payload-dtype) — f32, bf16 or int8.  ``prefetch``/
    ``patch_cap``: the lookahead prefetch lane (see DistContext)."""
    if exchange not in EXCHANGES:
        raise ValueError(
            f"unknown exchange strategy {exchange!r} — expected one of "
            f"{EXCHANGES}; resolve 'auto' with exchange.select_exchange "
            "before make_context")
    if payload_dtype not in PAYLOAD_DTYPES:
        raise ValueError(
            f"unknown payload dtype {payload_dtype!r} — expected one of "
            f"{PAYLOAD_DTYPES}")
    d = mesh.shape[AXIS]
    per_shard = None if device_rows is None else \
        store_base.device_rows_per_shard(n_rows, d, device_rows)
    return DistContext(mesh=mesh, num_shards=d, n_rows=n_rows,
                       rows_per_shard=dtbl.rows_per_shard(n_rows, d),
                       device_rows_per_shard=per_shard,
                       exchange=exchange, exchange_cap=exchange_cap,
                       payload_dtype=payload_dtype,
                       prefetch=prefetch, patch_cap=patch_cap)


def make_dist_store(ctx: DistContext, j_max: int, d_h: int,
                    dtype=jnp.float32, evict_policy: str = "lru",
                    wb_threshold: float = 0.0,
                    stale_forecast: bool = False) -> EmbeddingStore:
    """The context's embedding store: tiered per-shard slices when the
    context carries a device-row cap, the dense device-resident backend
    otherwise.  Either way the device tier is row-sharded over the mesh
    (P(AXIS)) and the table exchange runs unchanged on its rows.
    ``evict_policy``: the tiered device tier's eviction policy
    (store/slots.py — "lru" or "stale-first").  ``wb_threshold``: the
    delta-gated write-back admission threshold (--wb-threshold; 0 keeps
    every eviction bit-exact).  ``stale_forecast``: fault stale host rows
    in EXTRAPOLATED forward by the store's online per-row predictor
    (--stale-forecast, store/forecast.py) — only meaningful for the
    tiered store, whose host tier is where rows go stale."""
    sh = batch_sharding(ctx)
    if ctx.device_rows_per_shard is None:
        return DeviceStore(ctx.n_rows, j_max, d_h, num_shards=ctx.num_shards,
                           dtype=dtype, sharding=sh)
    return TieredStore(ctx.n_rows, j_max, d_h,
                       device_rows=ctx.device_rows_per_shard * ctx.num_shards,
                       num_shards=ctx.num_shards, dtype=dtype, sharding=sh,
                       evict_policy=evict_policy, wb_threshold=wb_threshold,
                       stale_forecast=stale_forecast)


# ---------------------------------------------------------------------------
# placement helpers
# ---------------------------------------------------------------------------


def replicate(ctx: DistContext, tree: Any) -> Any:
    sh = NamedSharding(ctx.mesh, P())
    # device_put takes numpy/jnp leaves directly — no staging copy through
    # the default device
    return jax.tree_util.tree_map(lambda x: jax.device_put(x, sh), tree)


def batch_sharding(ctx: DistContext) -> NamedSharding:
    return NamedSharding(ctx.mesh, P(AXIS))


def device_table(ctx: DistContext, table: tbl.EmbeddingTable) -> tbl.EmbeddingTable:
    """Pad the row axis to D·R and block-shard it over the data axis."""
    padded = dtbl.pad_table(table, ctx.num_shards)
    sh = batch_sharding(ctx)
    return jax.tree_util.tree_map(lambda x: jax.device_put(x, sh), padded)


def host_table(ctx: DistContext, table: tbl.EmbeddingTable) -> tbl.EmbeddingTable:
    """Gather the sharded table back to host numpy, padding stripped."""
    return dtbl.unpad_table(
        jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)), table),
        ctx.n_rows)


def device_state(ctx: DistContext, state: G.TrainState,
                 store: Optional[EmbeddingStore] = None) -> G.TrainState:
    """Replicate everything except the row-sharded table.  ``state.table``
    is the full dense table; with a ``store`` it seeds the store's tiers
    (store.restore) and the TrainState carries the store's device tier —
    possibly a bounded slice of it — instead of the whole thing."""
    table = (store.restore(state.table) if store is not None
             else device_table(ctx, state.table))
    return G.TrainState(
        backbone=replicate(ctx, state.backbone),
        head=replicate(ctx, state.head),
        opt_state=replicate(ctx, state.opt_state),
        table=table,
        step=replicate(ctx, state.step))


def shard_batch(ctx: DistContext, batch: G.GSTBatch) -> G.GSTBatch:
    """Move a host batch onto the mesh, sharded on the batch dim, filling
    ``batch_pos`` with global positions so shards and the single-device
    oracle draw identical per-row RNG streams."""
    B = batch.seg_valid.shape[0]
    if B % ctx.num_shards:
        raise ValueError(f"batch size {B} must divide over {ctx.num_shards} "
                         "shards (drop-last batching guarantees this)")
    if batch.batch_pos is None:
        batch = batch._replace(batch_pos=np.arange(B, dtype=np.int32))
    sh = batch_sharding(ctx)
    # one copy per shard, straight from the host buffers (this is the async
    # feeder's per-step hot path — no staging copy through device 0)
    return jax.tree_util.tree_map(lambda x: jax.device_put(x, sh), batch)


def _state_spec() -> G.TrainState:
    return G.TrainState(
        backbone=P(), head=P(), opt_state=P(),
        table=tbl.EmbeddingTable(P(AXIS), P(AXIS), P(AXIS)),
        step=P())


def _batch_spec() -> G.GSTBatch:
    return G.GSTBatch(P(AXIS), P(AXIS), P(AXIS), P(AXIS), P(AXIS))


def _make_ctx_exchange(ctx: DistContext):
    return make_exchange(ctx.exchange, axis_name=AXIS,
                         num_shards=ctx.num_shards, rows=ctx.table_rows,
                         cap=ctx.exchange_cap,
                         payload_dtype=ctx.payload_dtype,
                         patch_cap=ctx.patch_cap)


def _table_ops(ctx: DistContext):
    ex = _make_ctx_exchange(ctx)
    return ex.lookup, ex.update_sampled, ex.update_all


# ---------------------------------------------------------------------------
# step builders (drop-in parallels of core/gst.py's)
# ---------------------------------------------------------------------------


def make_dist_train_step(encode_fn, optimizer, variant: G.GSTVariant, *,
                         ctx: DistContext, donate: bool = True, **kwargs):
    """Data-parallel ``G.make_train_step``: same signature
    ``step(state, batch, rng) -> (state, metrics)``, state placed via
    ``device_state`` and batches via ``shard_batch``/the async pipeline.

    With ``ctx.prefetch`` the step is the PREFETCHED variant instead:

      step(state, batch, rng, pref, nxt, next_ids, next_dest)
        -> (state, metrics, patched)

    where ``pref = (emb, init)`` is THIS batch's prefetched lookup
    (already patched by the previous step), consumed in place of the
    inline exchange; ``nxt`` is the NEXT batch's freshly-prefetched pair
    (``make_prefetch_lookup``, dispatched before this call so the hops
    overlap the previous step's compute); ``next_ids``/``next_dest``
    route the fused write-back patch (exchange.update_sampled_patch);
    and ``patched`` is ``nxt`` with this step's write-back folded in —
    the ``pref`` of the NEXT call.  The inline path (prefetch=False) is
    unchanged and serves as the bit-exactness oracle."""
    # age-weighted SED (--sed-age-weighting): the per-segment age plane
    # travels its own exchange collective (lookup_ages) — only injected
    # when the decay is on, so the default step's jaxpr is untouched
    if kwargs.get("sed_decay", 0.0) > 0.0:
        kwargs.setdefault("table_lookup_age",
                          _make_ctx_exchange(ctx).lookup_ages)
    if not ctx.prefetch:
        lookup, update, _ = _table_ops(ctx)
        inner = G.make_train_step(encode_fn, optimizer, variant,
                                  table_lookup=lookup, table_update=update,
                                  axis_name=AXIS, **kwargs)
        smapped = shard_map(inner, mesh=ctx.mesh,
                            in_specs=(_state_spec(), _batch_spec(), P()),
                            out_specs=(_state_spec(), P()),
                            check_rep=False)
        return probe_jit(
            "dist.train_step",
            jax.jit(smapped, donate_argnums=(0,) if donate else ()))

    ex = _make_ctx_exchange(ctx)

    def inner(state, batch, rng, pref, nxt, next_ids, next_dest):
        # the injected table ops close over the prefetched buffers: the
        # lookup answers from ``pref`` without touching the wire, and the
        # update runs the fused write-back-and-patch, handing the patched
        # next-batch buffer out through a trace-time side channel (the
        # core step builder's return signature stays (state, metrics))
        patched = {}

        def t_lookup(table, graph_ids):
            return pref

        def t_update(table, graph_ids, seg_idx, h_new, step):
            table, patched["pref"] = ex.update_sampled_patch(
                table, graph_ids, seg_idx, h_new, step, nxt, next_ids,
                next_dest)
            return table

        step_fn = G.make_train_step(encode_fn, optimizer, variant,
                                    table_lookup=t_lookup,
                                    table_update=t_update,
                                    axis_name=AXIS, **kwargs)
        new_state, metrics = step_fn(state, batch, rng)
        # table-free variants never call the update: next buffer unpatched
        return new_state, metrics, patched.get("pref", nxt)

    pair = (P(AXIS), P(AXIS))
    smapped = shard_map(
        inner, mesh=ctx.mesh,
        in_specs=(_state_spec(), _batch_spec(), P(), pair, pair,
                  P(AXIS), P(AXIS)),
        out_specs=(_state_spec(), P(), pair),
        check_rep=False)
    # donate the consumed pref pair (3) and the to-be-patched nxt pair (4)
    # — the patch scatter aliases in place, and the driver only ever keeps
    # the returned patched pair
    return probe_jit(
        "dist.train_step",
        jax.jit(smapped, donate_argnums=(0, 3, 4) if donate else ()))


def make_prefetch_lookup(ctx: DistContext):
    """The prefetch lane's own jitted collective: ``fn(table, ids) ->
    (emb, init)`` — exactly the strategy's lookup, NOT donated (the table
    stays live for the in-flight step), dispatched by the driver while
    the previous step's device work runs so the exchange hops overlap
    compute.  ``ids`` is the next GLOBAL batch's (B,) ids, sharded like a
    batch."""
    ex = _make_ctx_exchange(ctx)

    def fn(table, graph_ids):
        return ex.prefetch_lookup(table, graph_ids)

    smapped = shard_map(
        fn, mesh=ctx.mesh,
        in_specs=(tbl.EmbeddingTable(P(AXIS), P(AXIS), P(AXIS)), P(AXIS)),
        out_specs=(P(AXIS), P(AXIS)),
        check_rep=False)
    return probe_jit("dist.prefetch_lookup", jax.jit(smapped))


def make_dist_eval_step(encode_fn, *, ctx: DistContext, **kwargs):
    inner = G.make_eval_step(encode_fn, axis_name=AXIS, **kwargs)
    smapped = shard_map(inner, mesh=ctx.mesh,
                        in_specs=(_state_spec(), _batch_spec()),
                        out_specs=P(), check_rep=False)
    return probe_jit("dist.eval_step", jax.jit(smapped))


def make_dist_refresh_step(encode_fn, *, ctx: DistContext,
                           donate: bool = True):
    _, _, update_all = _table_ops(ctx)
    inner = G.make_refresh_step(encode_fn, table_update_all=update_all)
    smapped = shard_map(inner, mesh=ctx.mesh,
                        in_specs=(_state_spec(), _batch_spec()),
                        out_specs=_state_spec(), check_rep=False)
    return probe_jit("dist.refresh_step",
                     jax.jit(smapped, donate_argnums=(0,) if donate else ()))


def make_dist_finetune_step(optimizer, *, ctx: DistContext,
                            donate: bool = True, **kwargs):
    lookup, _, _ = _table_ops(ctx)
    inner = G.make_finetune_step(optimizer, table_lookup=lookup,
                                 axis_name=AXIS, **kwargs)
    smapped = shard_map(inner, mesh=ctx.mesh,
                        in_specs=(_state_spec(), _batch_spec()),
                        out_specs=(_state_spec(), P()), check_rep=False)
    return probe_jit("dist.finetune_step",
                     jax.jit(smapped, donate_argnums=(0,) if donate else ()))
