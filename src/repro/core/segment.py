"""Segment sampling and Stale Embedding Dropout (paper §3.1, §3.4).

All functions are mask-aware: graphs have up to ``J_max`` segments with a
validity mask (XLA static shapes — DESIGN.md §4.1).  ``J^(i)`` in the paper
is ``num_valid`` here.

SED weights (Eq. 1), with keep probability p and S backprop segments:
    η = p + (1-p)·J/S   for sampled (fresh) segments
    η = 0               for stale segments dropped  (prob 1-p)
    η = 1               for stale segments kept     (prob p)
This keeps the aggregated embedding unbiased in the fresh part while damping
the stale bias by the factor p (Theorem 4.1; see core/theory.py).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def sample_segments(rng, seg_valid: jnp.ndarray, num_sampled: int) -> jnp.ndarray:
    """Sample S distinct segment indices per graph (Gumbel top-k over valid).

    seg_valid: (B, J) bool/0-1.  Returns idx: (B, S) int32 — indices of the
    segments chosen for backprop.  Invalid slots are never chosen as long as
    the graph has >= 1 valid segment (guaranteed by construction).
    """
    g = jax.random.gumbel(rng, seg_valid.shape)
    scores = jnp.where(seg_valid > 0, g, -jnp.inf)
    _, idx = jax.lax.top_k(scores, num_sampled)
    return idx.astype(jnp.int32)


# ---------------------------------------------------------------------------
# per-row randomness (distributed training)
#
# A (B, J) draw from one key is a function of the whole batch shape, so a
# data-parallel shard drawing (B/D, J) would see a different stream than the
# single-device step.  Deriving one key per batch ROW from its global batch
# position makes the stream a function of the row alone: the dist/ shard_map
# steps and the single-device oracle sample identical segments and SED drops
# (tests/test_dist.py asserts this row-for-row).
# ---------------------------------------------------------------------------


def per_row_keys(rng, batch_pos: jnp.ndarray) -> jnp.ndarray:
    """One PRNG key per batch row, derived from the row's GLOBAL position.

    batch_pos: (B,) int32 — position of each row in the global batch (just
    ``arange(B)`` on a single device; the device's slice of it under data
    parallelism)."""
    return jax.vmap(lambda p: jax.random.fold_in(rng, p))(batch_pos)


def sample_segments_rowwise(row_keys, seg_valid: jnp.ndarray,
                            num_sampled: int) -> jnp.ndarray:
    """``sample_segments`` with an independent key per row (see per_row_keys)."""
    J = seg_valid.shape[-1]

    def one(key, sv):
        g = jax.random.gumbel(key, (J,))
        scores = jnp.where(sv > 0, g, -jnp.inf)
        _, idx = jax.lax.top_k(scores, num_sampled)
        return idx.astype(jnp.int32)

    return jax.vmap(one)(row_keys, seg_valid)


def sed_weights_rowwise(row_keys, seg_valid, fresh_mask, keep_prob: float,
                        num_sampled: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """``sed_weights`` with an independent key per row (see per_row_keys)."""
    J = seg_valid.shape[-1]
    u = jax.vmap(lambda k: jax.random.uniform(k, (J,)))(row_keys)
    return _sed_from_uniform(u, seg_valid, fresh_mask, keep_prob, num_sampled)


def sampled_mask(idx: jnp.ndarray, J: int) -> jnp.ndarray:
    """(B, S) indices -> (B, J) 0/1 mask of sampled segments."""
    return jnp.sum(jax.nn.one_hot(idx, J, dtype=jnp.float32), axis=1)


def sed_weights(rng, seg_valid, fresh_mask, keep_prob: float,
                num_sampled: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Eq. 1 weights.  Returns (eta (B, J), drop_mask (B, J)).

    seg_valid:  (B, J) 1 where the segment exists.
    fresh_mask: (B, J) 1 where the segment was sampled for backprop.
    drop_mask:  1 where a *stale* segment is dropped by SED.
    """
    u = jax.random.uniform(rng, seg_valid.shape)
    return _sed_from_uniform(u, seg_valid, fresh_mask, keep_prob, num_sampled)


def _sed_from_uniform(u, seg_valid, fresh_mask, keep_prob: float,
                      num_sampled: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Eq. 1 weights from precomputed uniform draws u (B, J)."""
    seg_valid = seg_valid.astype(jnp.float32)
    fresh_mask = fresh_mask.astype(jnp.float32)
    J_i = jnp.sum(seg_valid, axis=-1, keepdims=True)            # (B, 1)
    S = float(num_sampled)
    drop = (u > keep_prob).astype(jnp.float32)
    stale = seg_valid * (1.0 - fresh_mask)
    eta_fresh = keep_prob + (1.0 - keep_prob) * J_i / S
    eta = fresh_mask * eta_fresh + stale * (1.0 - drop)
    return eta * seg_valid, drop * stale


def aggregate(h_segments, weights, seg_valid, mode: str = "mean"):
    """⊕ with weights.  h_segments: (B, J, d); weights/seg_valid: (B, J).

    mean: Σ η_j h_j / J^(i)  (the paper's mean-pooling ⊕, η-weighted)
    sum:  Σ η_j h_j          (TpuGraphs: per-segment predictions summed)
    """
    w = (weights * seg_valid.astype(weights.dtype))[..., None]
    s = jnp.sum(h_segments * w.astype(h_segments.dtype), axis=1)
    if mode == "sum":
        return s
    J_i = jnp.sum(seg_valid.astype(jnp.float32), axis=-1, keepdims=True)
    return s / jnp.maximum(J_i, 1.0).astype(s.dtype)
