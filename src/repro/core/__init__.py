"""The paper's primary contribution: Graph Segment Training (GST+EFD)."""
from repro.core.gst import (
    GSTBatch,
    GSTVariant,
    TrainState,
    VARIANTS,
    head_init,
    head_apply,
    make_eval_step,
    make_finetune_step,
    make_refresh_step,
    make_train_step,
)
from repro.core.embedding_table import EmbeddingTable, init_table
from repro.core.segment import aggregate, sample_segments, sampled_mask, sed_weights

__all__ = [
    "GSTBatch", "GSTVariant", "TrainState", "VARIANTS",
    "head_init", "head_apply",
    "make_eval_step", "make_finetune_step", "make_refresh_step", "make_train_step",
    "EmbeddingTable", "init_table",
    "aggregate", "sample_segments", "sampled_mask", "sed_weights",
]
