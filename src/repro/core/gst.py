"""GST train/eval/finetune step builders — the paper's Algorithm 1 & 2.

Generic over the backbone: ``encode_fn(backbone_params, seg_inputs_flat)``
maps a flat batch of segments (leading dim N) to embeddings (N, d_h) plus an
auxiliary loss (e.g. MoE load-balance).  The same builders therefore drive
the GNN track (padded-CSR segments) and all 10 assigned transformer
architectures (token-chunk segments) — DESIGN.md §3.

Variants (paper §5.1 "Methods"):
    full     — all segments require grad (Full Graph Training analogue)
    gst      — sampled segments with grad; rest recomputed under stop_grad
    gst_one  — only sampled segments, no aggregation of the rest
    gst_e    — historical embedding table for the rest
    gst_ef   — +E with head finetuning at the end (schedule, same step)
    gst_ed   — +E with Stale Embedding Dropout (Eq. 1)
    gst_efd  — the complete method
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import embedding_table as tbl
from repro.core import segment as seg
from repro.kernels import ops as kops
from repro.models.common import dense_init


# ---------------------------------------------------------------------------
# variants
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GSTVariant:
    name: str
    use_table: bool          # E: stale embeddings come from the table
    recompute_stale: bool    # GST: stop-grad forward for non-sampled segments
    use_sed: bool            # D: Eq. 1 dropout/up-weighting
    sampled_only: bool       # GST-One: drop all non-sampled segments
    finetune_head: bool      # F: head finetuning phase at end of training


VARIANTS: Dict[str, GSTVariant] = {
    "full":    GSTVariant("full", False, False, False, False, False),
    "gst":     GSTVariant("gst", False, True, False, False, False),
    "gst_one": GSTVariant("gst_one", False, False, False, True, False),
    "gst_e":   GSTVariant("gst_e", True, False, False, False, False),
    "gst_ef":  GSTVariant("gst_ef", True, False, False, False, True),
    "gst_ed":  GSTVariant("gst_ed", True, False, True, False, False),
    "gst_efd": GSTVariant("gst_efd", True, False, True, False, True),
}


# ---------------------------------------------------------------------------
# heads and losses
# ---------------------------------------------------------------------------


def head_init(key, d_h: int, num_out: int, mode: str, dtype=jnp.float32):
    """mode 'mlp': 2-layer MLP graph head F'.  mode 'segment_sum': linear
    per-segment scalar head (part of F; F' = Σ, paper §5.3)."""
    k1, k2 = jax.random.split(key)
    if mode == "mlp":
        return {
            "w1": dense_init(k1, d_h, d_h, dtype),
            "b1": jnp.zeros((d_h,), dtype),
            "w2": dense_init(k2, d_h, num_out, dtype),
            "b2": jnp.zeros((num_out,), dtype),
        }
    return {"w": dense_init(k1, d_h, 1, dtype), "b": jnp.zeros((1,), dtype)}


def head_apply(p, h, mode: str):
    if mode == "mlp":
        z = jax.nn.relu(h @ p["w1"] + p["b1"])
        return z @ p["w2"] + p["b2"]
    return (h @ p["w"] + p["b"])[..., 0]


def ce_loss(logits, labels):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    acc = (jnp.argmax(logits, -1) == labels).astype(jnp.float32)
    return jnp.mean(nll), jnp.mean(acc)


def pairwise_hinge_loss(preds, labels):
    """PairwiseHinge within batch (paper Appendix B) + OPA metric."""
    dy = preds[:, None] - preds[None, :]
    gt = (labels[:, None] > labels[None, :]).astype(jnp.float32)
    loss = jnp.sum(gt * jnp.maximum(0.0, 1.0 - dy)) / jnp.maximum(jnp.sum(gt), 1.0)
    opa = jnp.sum(gt * (dy > 0)) / jnp.maximum(jnp.sum(gt), 1.0)
    return loss, opa


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def gather_segments(seg_inputs, idx):
    """Pytree (B, J, ...) gathered at idx (B, S) -> (B, S, ...)."""
    def g(x):
        expand = idx.reshape(idx.shape + (1,) * (x.ndim - 2))
        return jnp.take_along_axis(x, expand.astype(jnp.int32), axis=1)
    return jax.tree_util.tree_map(g, seg_inputs)


def _flatten_bs(tree):
    return jax.tree_util.tree_map(lambda x: x.reshape((-1,) + x.shape[2:]), tree)


class GSTBatch(NamedTuple):
    """One batch of segmented inputs.

    seg_inputs: pytree, leaves (B, J_max, ...) — per-segment model inputs.
    seg_valid:  (B, J_max) 1/0.
    graph_ids:  (B,) int32 row in the historical table.
    labels:     (B,) int32 (ce) or float32 (ranking).
    batch_pos:  optional (B,) int32 — each row's position in the GLOBAL
                batch.  When set, segment sampling / SED draws use one key
                per row (seg.per_row_keys) so a data-parallel shard of the
                batch sees the same stream as the whole batch on one device.
    """
    seg_inputs: Any
    seg_valid: jnp.ndarray
    graph_ids: jnp.ndarray
    labels: jnp.ndarray
    batch_pos: Optional[jnp.ndarray] = None


class TrainState(NamedTuple):
    backbone: Any
    head: Any
    opt_state: Any
    table: tbl.EmbeddingTable
    step: jnp.ndarray


def _fused_sed_pool(h, seg_valid, fresh_mask, drop_mask, stale_valid, *,
                    keep_prob: float, num_sampled: int, agg: str,
                    ages=None, decay: float = 0.0):
    """Eq. 1 η-weighting + ⊕ pooling in ONE fused kernel pass (sed_pool).

    Uninitialized stale slots are folded into the drop mask (η = 0), which is
    exactly what the reference path's ``eta * where(fresh, 1, stale_valid)``
    correction does.  ``ages``/``decay`` thread the optional staleness decay
    into the kernel's stale branch (ref.sed_eta); λ=0 keeps the historical
    4-operand dispatch bit-exact.
    """
    drop_arg = 1.0 - (1.0 - drop_mask) * stale_valid.astype(jnp.float32)
    return kops.sed_aggregate(
        h, seg_valid.astype(jnp.float32), fresh_mask.astype(jnp.float32),
        drop_arg, ages, keep_prob=keep_prob, num_sampled=num_sampled, agg=agg,
        decay=decay, use_pallas=True)


def _fused_plain_pool(h, seg_valid, *, agg: str):
    """η = 1 pooling through the same fused kernel (eval / finetune path):
    with keep_prob = 1 every Eq.-1 weight collapses to the validity mask."""
    valid = seg_valid.astype(jnp.float32)
    return kops.sed_aggregate(h, valid, valid, jnp.zeros_like(valid),
                              keep_prob=1.0, num_sampled=1, agg=agg,
                              use_pallas=True)


def _scalar_head_preds(scal, seg_valid, eta, agg: str, pool=None):
    """Pool (B, J) per-segment scalar predictions into (B,) graph preds.

    pool: optional fused (B, J, 1) -> (B, 1) kernel pooling (already carrying
    its η weighting); None = the reference η-weighted sum.  Shared by the
    train / eval / finetune steps so the two paths can't drift per-step.
    """
    if pool is not None:
        return pool(scal[..., None])[..., 0]
    denom = (jnp.maximum(jnp.sum(seg_valid, -1), 1.0)
             if agg == "mean" else 1.0)
    return jnp.sum(scal * eta, axis=-1) / denom


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def make_train_step(
    encode_fn: Callable,
    optimizer,
    variant: GSTVariant,
    *,
    num_sampled: int = 1,
    keep_prob: float = 0.5,
    head_mode: str = "mlp",
    loss_kind: str = "ce",
    agg: str = "mean",
    aux_weight: float = 1e-2,
    use_pallas: bool = False,
    table_lookup: Optional[Callable] = None,
    table_update: Optional[Callable] = None,
    table_lookup_age: Optional[Callable] = None,
    sed_decay: float = 0.0,
    axis_name: Optional[str] = None,
):
    """Returns ``step(state, batch, rng) -> (state, metrics)`` implementing
    Algorithm 1 (gst*) / Algorithm 2 lines 1-10 (e-variants).

    use_pallas: for the SED variants (gst_ed / gst_efd) the Eq.-1 η-weighting
    and the ⊕ pooling run as ONE fused sed_pool kernel pass over the
    (B, J, d) tensor instead of the multi-HBM-pass jnp composition.  The jnp
    path stays the oracle (parity asserted in tests/test_fused_path.py).

    table_lookup / table_update: alternative historical-table accessors with
    the signatures of ``tbl.lookup`` / ``tbl.update_sampled``.  dist/train.py
    injects the ring-exchange ops of dist/table.py here so the SAME step
    body runs per shard with a row-sharded table.  These are the store
    layer's device-access points: ``state.table`` is whatever device tier
    the driver's EmbeddingStore (store/) provides, and ``batch.graph_ids``
    are that store's device-row ids — a TieredStore renames rows host-side
    (store.prepare) so nothing inside the jitted step knows the table is
    capped.

    sed_decay / table_lookup_age: λ of the staleness decay exp(-λ·age)
    folded into the stale branch of Eq. 1 (--sed-age-weighting).  λ=0 (the
    default) traces the exact historical step — no age lookup, no extra
    operand, bit-exact.  ``table_lookup_age(table, graph_ids) -> (B, J)``
    reads the per-segment last-refresh step (dist/train.py injects the
    exchange's ``lookup_ages``); the default reads ``table.age`` directly.

    axis_name: when set the step body is assumed to run inside shard_map /
    pmap over that axis — gradients, loss and metrics are pmean'd across it
    before the (replicated) optimizer update.
    """
    S = num_sampled
    loss_pair = ce_loss if loss_kind == "ce" else pairwise_hinge_loss
    fused_sed = use_pallas and variant.use_sed and not variant.sampled_only
    t_lookup = table_lookup or tbl.lookup
    t_update = table_update or tbl.update_sampled
    use_age = variant.use_sed and variant.use_table and sed_decay > 0.0
    t_age = table_lookup_age or (lambda table, ids: table.age[ids])

    def step(state: TrainState, batch: GSTBatch, rng):
        B, J = batch.seg_valid.shape
        r_sample, r_sed = jax.random.split(jax.random.fold_in(rng, state.step))
        if batch.batch_pos is None:
            idx = seg.sample_segments(r_sample, batch.seg_valid, S)   # (B, S)
        else:
            idx = seg.sample_segments_rowwise(
                seg.per_row_keys(r_sample, batch.batch_pos),
                batch.seg_valid, S)
        fresh_mask = seg.sampled_mask(idx, J) * batch.seg_valid       # (B, J)
        sampled_inputs = _flatten_bs(gather_segments(batch.seg_inputs, idx))

        # ---- stale embeddings (no grad) ---------------------------------
        age_steps = None
        if variant.use_table:
            h_stale, initialized = t_lookup(state.table, batch.graph_ids)
            stale_valid = batch.seg_valid * initialized.astype(batch.seg_valid.dtype)
            if use_age:
                age_steps = jnp.maximum(
                    state.step - t_age(state.table, batch.graph_ids),
                    0).astype(jnp.float32)
        elif variant.recompute_stale:
            h_all, _ = encode_fn(state.backbone, _flatten_bs(batch.seg_inputs))
            h_stale = jax.lax.stop_gradient(h_all.reshape(B, J, -1))
            stale_valid = batch.seg_valid
        else:  # full / gst_one: no stale path
            h_stale = None
            stale_valid = jnp.zeros_like(batch.seg_valid)

        # ---- SED / η weights (Eq. 1) ------------------------------------
        drop_mask = None
        if variant.use_sed:
            if batch.batch_pos is None:
                eta, drop_mask = seg.sed_weights(r_sed, batch.seg_valid,
                                                 fresh_mask, keep_prob, S)
            else:
                eta, drop_mask = seg.sed_weights_rowwise(
                    seg.per_row_keys(r_sed, batch.batch_pos),
                    batch.seg_valid, fresh_mask, keep_prob, S)
            eta = eta * jnp.where(
                fresh_mask > 0, 1.0,
                stale_valid.astype(jnp.float32))  # uninitialized stale -> 0
            if age_steps is not None:
                # staleness decay on the stale branch only — fresh segments
                # have age 0 by definition (ref.sed_eta's aged formula)
                eta = eta * jnp.where(fresh_mask > 0, 1.0,
                                      jnp.exp(-sed_decay * age_steps))
        elif variant.sampled_only:
            eta = fresh_mask
        elif variant.name == "full":
            eta = batch.seg_valid.astype(jnp.float32)
        else:
            eta = (fresh_mask + (1.0 - fresh_mask) * stale_valid).astype(jnp.float32)

        def loss_fn(trainable):
            backbone, head = trainable
            if variant.name == "full":
                h_flat, aux = encode_fn(backbone, _flatten_bs(batch.seg_inputs))
                h_comb = h_flat.reshape(B, J, -1)
            else:
                h_s_flat, aux = encode_fn(backbone, sampled_inputs)
                h_s = h_s_flat.reshape(B, S, -1)
                if h_stale is None:
                    base = jnp.zeros((B, J, h_s.shape[-1]), h_s.dtype)
                else:
                    base = h_stale.astype(h_s.dtype)
                # scatter fresh embeddings over the stale base
                b_idx = jnp.arange(B)[:, None]
                h_comb = base.at[b_idx, idx].set(h_s)

            if head_mode == "segment_sum":
                # per-segment scalar predictions; F' = Σ (paper §5.3)
                scal = head_apply(head, h_comb, "segment_sum")        # (B, J)
                pool = (lambda x: _fused_sed_pool(
                    x, batch.seg_valid, fresh_mask, drop_mask, stale_valid,
                    keep_prob=keep_prob, num_sampled=S, agg=agg,
                    ages=age_steps, decay=sed_decay)
                ) if fused_sed else None
                preds = _scalar_head_preds(scal, batch.seg_valid, eta, agg,
                                           pool)
                loss, metric = loss_pair(preds, batch.labels)
            else:
                if variant.sampled_only:
                    # GST-One: mean over the sampled segments only
                    h_graph = jnp.sum(
                        h_comb * fresh_mask[..., None].astype(h_comb.dtype), 1) / S
                elif fused_sed:
                    h_graph = _fused_sed_pool(
                        h_comb, batch.seg_valid, fresh_mask, drop_mask,
                        stale_valid, keep_prob=keep_prob, num_sampled=S,
                        agg=agg, ages=age_steps, decay=sed_decay)
                else:
                    h_graph = seg.aggregate(h_comb, eta, batch.seg_valid, agg)
                out = head_apply(head, h_graph, "mlp")
                if loss_kind == "ce":
                    loss, metric = loss_pair(out, batch.labels)
                else:
                    loss, metric = loss_pair(out[..., 0] if out.ndim > 1 else out,
                                             batch.labels)
            return loss + aux_weight * aux, (metric, h_comb)

        (loss, (metric, h_comb)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)((state.backbone, state.head))
        if axis_name is not None:
            # data-parallel: per-shard means -> global means (params and
            # opt_state stay replicated because every shard applies the
            # identical pmean'd update)
            grads = jax.lax.pmean(grads, axis_name)
            loss = jax.lax.pmean(loss, axis_name)
            metric = jax.lax.pmean(metric, axis_name)
        (new_backbone, new_head), new_opt, opt_metrics = optimizer.update(
            (state.backbone, state.head), grads, state.opt_state)

        new_table = state.table
        if variant.use_table:
            h_s_new = jax.lax.stop_gradient(
                jnp.take_along_axis(h_comb, idx[..., None], axis=1))  # (B,S,d)
            new_table = t_update(
                state.table, batch.graph_ids, idx, h_s_new, state.step)

        new_state = TrainState(new_backbone, new_head, new_opt, new_table,
                               state.step + 1)
        metrics = {"loss": loss, "metric": metric, **opt_metrics}
        return new_state, metrics

    return step


def make_eval_step(encode_fn: Callable, *, head_mode: str = "mlp",
                   loss_kind: str = "ce", agg: str = "mean",
                   use_pallas: bool = False,
                   axis_name: Optional[str] = None):
    """Test-time: every segment fresh (paper's P(⊕ h_j, y) distribution)."""
    loss_pair = ce_loss if loss_kind == "ce" else pairwise_hinge_loss

    def step(state: TrainState, batch: GSTBatch):
        B, J = batch.seg_valid.shape
        h_flat, _ = encode_fn(state.backbone, _flatten_bs(batch.seg_inputs))
        h_all = h_flat.reshape(B, J, -1)
        eta = batch.seg_valid.astype(jnp.float32)
        if head_mode == "segment_sum":
            scal = head_apply(state.head, h_all, "segment_sum")
            pool = (lambda x: _fused_plain_pool(x, batch.seg_valid, agg=agg)
                    ) if use_pallas else None
            preds = _scalar_head_preds(scal, batch.seg_valid, eta, agg, pool)
            loss, metric = loss_pair(preds, batch.labels)
        else:
            if use_pallas:
                h_graph = _fused_plain_pool(h_all, batch.seg_valid, agg=agg)
            else:
                h_graph = seg.aggregate(h_all, eta, batch.seg_valid, agg)
            out = head_apply(state.head, h_graph, "mlp")
            if loss_kind == "ce":
                loss, metric = loss_pair(out, batch.labels)
            else:
                loss, metric = loss_pair(out[..., 0] if out.ndim > 1 else out,
                                         batch.labels)
        if axis_name is not None:
            loss = jax.lax.pmean(loss, axis_name)
            metric = jax.lax.pmean(metric, axis_name)
        return {"loss": loss, "metric": metric}

    return step


def make_refresh_step(encode_fn: Callable,
                      table_update_all: Optional[Callable] = None):
    """Algorithm 2 line 12: refresh T with the final backbone.

    table_update_all: alternative writer with the ``tbl.update_all``
    signature (dist/train.py injects the ring-exchange writer)."""
    t_update_all = table_update_all or tbl.update_all

    def step(state: TrainState, batch: GSTBatch):
        B, J = batch.seg_valid.shape
        h_flat, _ = encode_fn(state.backbone, _flatten_bs(batch.seg_inputs))
        h_all = h_flat.reshape(B, J, -1)
        table = t_update_all(state.table, batch.graph_ids, h_all,
                             batch.seg_valid, state.step)
        return state._replace(table=table)

    return step


def make_finetune_step(optimizer, *, head_mode: str = "mlp",
                       loss_kind: str = "ce", agg: str = "mean",
                       use_pallas: bool = False,
                       table_lookup: Optional[Callable] = None,
                       axis_name: Optional[str] = None):
    """Algorithm 2 lines 13-18: train F' only, inputs from the (fresh) table.

    Supports both heads: the MLP graph head F' (pool then predict) and the
    per-segment scalar head of the TpuGraphs track (predict then Σ / mean),
    so gst_ef / gst_efd no longer silently skip the finetuning phase on the
    segment_sum track.
    """
    loss_pair = ce_loss if loss_kind == "ce" else pairwise_hinge_loss
    t_lookup = table_lookup or tbl.lookup

    def step(state: TrainState, batch: GSTBatch):
        h_all, _ = t_lookup(state.table, batch.graph_ids)
        h_all = h_all.astype(jnp.float32)
        eta = batch.seg_valid.astype(jnp.float32)
        if head_mode != "segment_sum":
            if use_pallas:
                h_graph = _fused_plain_pool(h_all, batch.seg_valid, agg=agg)
            else:
                h_graph = seg.aggregate(h_all, eta, batch.seg_valid, agg)

        def loss_fn(head):
            if head_mode == "segment_sum":
                scal = head_apply(head, h_all, "segment_sum")      # (B, J)
                pool = (lambda x: _fused_plain_pool(x, batch.seg_valid,
                                                    agg=agg)
                        ) if use_pallas else None
                preds = _scalar_head_preds(scal, batch.seg_valid, eta, agg,
                                           pool)
                return loss_pair(preds, batch.labels)
            out = head_apply(head, h_graph, "mlp")
            if loss_kind == "ce":
                return loss_pair(out, batch.labels)
            return loss_pair(out[..., 0] if out.ndim > 1 else out, batch.labels)

        (loss, metric), grads = jax.value_and_grad(loss_fn, has_aux=True)(state.head)
        if axis_name is not None:
            grads = jax.lax.pmean(grads, axis_name)
            loss = jax.lax.pmean(loss, axis_name)
            metric = jax.lax.pmean(metric, axis_name)
        new_head, new_opt, _ = optimizer.update(state.head, grads, state.opt_state)
        return state._replace(head=new_head, opt_state=new_opt,
                              step=state.step + 1), {"loss": loss, "metric": metric}

    return step
