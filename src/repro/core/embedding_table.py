"""Historical segment-embedding table T : (graph i, segment j) -> R^{d_h}.

Paper §3.2.  TPU adaptation (DESIGN.md §4.2): the PyTorch reference keeps a
host-side hash table written from a side thread; here T is a dense device
array (n_graphs, J_max, d_h) **sharded over the data mesh axis** and
**donated** through the train step, so the scatter update overlaps with the
backward pass under XLA — same overhead-hiding effect, jit-native.

An age array tracks staleness (in steps) for diagnostics and tests: the
paper's observation that the most outdated entry is ~ n·J/S steps stale is
asserted empirically in tests/test_gst_core.py.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EmbeddingTable(NamedTuple):
    emb: jnp.ndarray        # (n, J_max, d_h)
    age: jnp.ndarray        # (n, J_max) int32 — step of last refresh
    initialized: jnp.ndarray  # (n, J_max) bool — written at least once


def init_table(n_graphs: int, j_max: int, d_h: int, dtype=jnp.float32) -> EmbeddingTable:
    return EmbeddingTable(
        emb=jnp.zeros((n_graphs, j_max, d_h), dtype),
        age=jnp.zeros((n_graphs, j_max), jnp.int32),
        initialized=jnp.zeros((n_graphs, j_max), bool),
    )


def lookup(table: EmbeddingTable, graph_ids: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """graph_ids: (B,) -> (emb (B, J, d), initialized (B, J))."""
    return table.emb[graph_ids], table.initialized[graph_ids]


def update_sampled(table: EmbeddingTable, graph_ids, seg_idx, h_new, step,
                   *, mode: str = None) -> EmbeddingTable:
    """Write back fresh embeddings of the sampled segments.

    graph_ids: (B,); seg_idx: (B, S); h_new: (B, S, d) — stop-gradded by the
    caller.  Scatter via .at[] — under pjit this lowers to a sharded scatter
    on the data axis (graph_ids are data-sharded with the batch).

    mode: forwarded to ``.at[].set`` — the dist/ table shard passes "drop" so
    rows owned by other shards (redirected out of range) are skipped.
    """
    b_idx = jnp.broadcast_to(graph_ids[:, None], seg_idx.shape)
    emb = table.emb.at[b_idx, seg_idx].set(h_new.astype(table.emb.dtype), mode=mode)
    age = table.age.at[b_idx, seg_idx].set(step, mode=mode)
    init = table.initialized.at[b_idx, seg_idx].set(True, mode=mode)
    return EmbeddingTable(emb, age, init)


def update_all(table: EmbeddingTable, graph_ids, h_all, seg_valid, step,
               *, mode: str = None) -> EmbeddingTable:
    """Refresh every segment of the given graphs (head-finetuning phase)."""
    emb = table.emb.at[graph_ids].set(h_all.astype(table.emb.dtype), mode=mode)
    age = table.age.at[graph_ids].set(step, mode=mode)
    init = table.initialized.at[graph_ids].set(seg_valid.astype(bool), mode=mode)
    return EmbeddingTable(emb, age, init)


# ---------------------------------------------------------------------------
# slot-addressed view (serving cache)
#
# serve/cache.py layers a content-addressed segment cache on the same table:
# rows are cache SLOTS (one segment each, J_max == 1) keyed host-side by
# segment content hash.  These helpers give the (slots,) <-> (slots, 1, d)
# view without the callers carrying the dummy J axis around.
# ---------------------------------------------------------------------------


def lookup_rows(table: EmbeddingTable, rows) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """rows: (B,) slot ids -> (emb (B, d), initialized (B,))."""
    emb, init = lookup(table, rows)
    return emb[:, 0], init[:, 0]


def update_rows(table: EmbeddingTable, rows, h_new, step) -> EmbeddingTable:
    """Write h_new (B, d) into slots (B,) — one scatter, jit-friendly.
    An empty row set is a no-op (no zero-size scatter to compile)."""
    if rows.shape[0] == 0:
        return table
    return update_sampled(table, rows, jnp.zeros((rows.shape[0], 1), jnp.int32),
                          h_new[:, None, :], step)


def evict_rows(table: EmbeddingTable, rows) -> EmbeddingTable:
    """Mark slots free (initialized=False); embeddings are left in place and
    simply overwritten on reuse.  An empty row set is a no-op."""
    if rows.shape[0] == 0:
        return table
    init = table.initialized.at[rows, 0].set(False)
    return EmbeddingTable(table.emb, table.age, init)
