"""Empirical estimators for the Taylor-expansion analysis (paper §4, App. A).

Theorem 4.1: with keep ratio p, SED reduces the first-order (bias) term
introduced by stale embeddings by a factor p, while adding a regularization
term.  These estimators compute E[δ] and E[δδᵀ] diagonals under the ET and
SED perturbation distributions by direct enumeration of the probabilities in
Appendix A — tests/test_theory.py checks the Monte-Carlo simulation against
them and verifies the factor-p bias reduction and the p→0 / p→1 limits.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def delta_moments_et(h, h_tilde, J: int, S: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """E[δ_j] and E[δ_j⊙²] for plain embedding-table training (no SED).

    δ_j = 0 w.p. S/J (segment fresh); δ_j = h̃_j - h_j w.p. (J-S)/J.
    h, h_tilde: (..., d) true / stale embedding of one segment.
    """
    q = (J - S) / J
    diff = h_tilde - h
    return q * diff, q * jnp.square(diff)


def delta_moments_sed(h, h_tilde, J: int, S: int, p: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """E[δ_j] and E[δ_j⊙²] under SED (Appendix A):

        δ_j = (1-p)(J-S)/S · h_j        w.p. S/J        (fresh, up-weighted)
        δ_j = -h_j                      w.p. (1-p)(J-S)/J  (stale, dropped)
        δ_j = h̃_j - h_j                w.p. p(J-S)/J      (stale, kept)
    """
    w_fresh = S / J
    w_drop = (1 - p) * (J - S) / J
    w_keep = p * (J - S) / J
    d_fresh = (1 - p) * (J - S) / S * h
    d_drop = -h
    d_keep = h_tilde - h
    mean = w_fresh * d_fresh + w_drop * d_drop + w_keep * d_keep
    second = (w_fresh * jnp.square(d_fresh) + w_drop * jnp.square(d_drop)
              + w_keep * jnp.square(d_keep))
    return mean, second


def bias_reduction_factor(h, h_tilde, J: int, S: int, p: float) -> jnp.ndarray:
    """Ratio ||E[δ^SED]_bias|| / ||E[δ^ET]|| restricted to the stale-difference
    direction — Theorem 4.1 says the h̃-h component scales by exactly p."""
    et_mean, _ = delta_moments_et(h, h_tilde, J, S)
    sed_mean, _ = delta_moments_sed(h, h_tilde, J, S, p)
    # project out the fresh-part contribution (which is mean-zero in h over
    # the dataset); the stale component of SED is p * ET by construction:
    diff = h_tilde - h
    denom = jnp.vdot(diff, diff)
    et_c = jnp.vdot(et_mean, diff) / jnp.maximum(denom, 1e-12)
    sed_c = jnp.vdot(sed_mean - (S / J) * (1 - p) * (J - S) / S * h
                     + (1 - p) * (J - S) / J * h, diff) / jnp.maximum(denom, 1e-12)
    return sed_c / jnp.maximum(et_c, 1e-12)
