"""The ONE historical-embedding store (paper §3.2's table T, unified).

Three former implementations — the replicated training table, the
row-sharded dist table, and the serving cache's slot pool — now share this
residency layer: ``DeviceStore`` keeps the whole table in device memory
(the oracle), ``TieredStore`` caps device residency at a bounded LRU of
hot rows spilled to a host-RAM tier, with async device→host write-back on
the pipeline's writer thread.  Jitted step code sees only a plain
``EmbeddingTable`` of device rows; bit-exactness vs the oracle is the
contract (tests/test_store.py, tests/test_store_props.py).
"""
from repro.store.base import (  # noqa: F401
    DeviceStore,
    EmbeddingStore,
    PreparedMigration,
    StoreCounters,
    padded_rows,
    rows_per_shard,
)
from repro.store.forecast import RowForecaster  # noqa: F401
from repro.store.slots import SlotMap  # noqa: F401
from repro.store.tiered import TieredStore  # noqa: F401
from repro.store.writeback import AsyncHostWriter, delta_gate  # noqa: F401

__all__ = [
    "AsyncHostWriter", "DeviceStore", "EmbeddingStore", "PreparedMigration",
    "RowForecaster", "SlotMap", "StoreCounters", "TieredStore", "delta_gate",
    "padded_rows", "rows_per_shard",
]
