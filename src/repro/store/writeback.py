"""Async device→host write-back lane (re-exported by dist/pipeline.py).

The host→device half of the pipeline is the segment feeder
(dist/pipeline.py); this is the opposite lane: a FIFO thunk executor on a
daemon thread that the tiered embedding store (store/tiered.py) submits
eviction write-backs to, so the blocking device_get + host-array copy
overlaps with the running train step instead of sitting on the critical
path.  It lives under store/ (not dist/) purely to keep the import graph
acyclic — dist and serve both build on the store.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Optional

import numpy as np


def delta_gate(emb_new, emb_old, init_new, init_old,
               threshold: float) -> np.ndarray:
    """FreshGNN write-back admission: which evicted rows are WORTH writing
    back to the host tier.

    Returns a (n,) bool mask over the leading row axis: True where the
    row's embedding moved by at least ``threshold`` (max-abs over the
    row's elements) since it last left the host tier, or where any
    initialized flag flipped (a first write or an invalidation must never
    be dropped, whatever its magnitude).  Rows gated out keep their stale
    host copy — the same staleness the GST paper already models with SED,
    now bounded by the threshold instead of one refresh period.
    """
    emb_new = np.asarray(emb_new)
    delta = np.max(np.abs(emb_new - np.asarray(emb_old)),
                   axis=tuple(range(1, emb_new.ndim)))
    init_new = np.asarray(init_new)
    flipped = np.any(init_new != np.asarray(init_old),
                     axis=tuple(range(1, init_new.ndim)))
    return (delta >= threshold) | flipped


class AsyncHostWriter:
    """FIFO thunk executor on a daemon thread.

    ``submit`` returns a monotonically increasing ticket; ``wait(ticket)``
    blocks until that submission (and everything before it — FIFO) has run.
    Exceptions raised by a thunk are re-raised on the next wait()/flush()
    so a failed write-back cannot be silently dropped.
    """

    def __init__(self):
        self._q: "queue.Queue" = queue.Queue()
        self._cv = threading.Condition()
        self._submitted = 0
        self._done = 0
        self._exc: Optional[BaseException] = None
        self._closed = False
        self.wait_ms = 0.0          # consumer time blocked in wait()/flush()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while True:
            fn = self._q.get()
            if fn is None:
                return
            try:
                fn()
            except BaseException as e:  # surfaced on the next wait()
                with self._cv:
                    if self._exc is None:
                        self._exc = e
            with self._cv:
                self._done += 1
                self._cv.notify_all()

    def submit(self, fn: Callable[[], None]) -> int:
        if self._closed:
            raise RuntimeError("AsyncHostWriter is closed")
        with self._cv:
            self._submitted += 1
            ticket = self._submitted
        self._q.put(fn)
        return ticket

    def wait(self, ticket: int) -> None:
        t0 = time.perf_counter()
        with self._cv:
            while self._done < ticket and self._exc is None:
                self._cv.wait(timeout=0.05)
            exc, self._exc = self._exc, None
        self.wait_ms += (time.perf_counter() - t0) * 1e3
        if exc is not None:
            raise exc

    def flush(self) -> None:
        """Wait for every submitted thunk to finish."""
        with self._cv:
            ticket = self._submitted
        self.wait(ticket)

    @property
    def pending(self) -> int:
        with self._cv:
            return self._submitted - self._done

    def close(self) -> None:
        """Drain and stop the thread.  Never raises — close() runs in
        callers' finally blocks and must not mask their exception; thunk
        errors surface through wait()/flush() during operation."""
        if self._closed:
            return
        self._closed = True
        try:
            self.flush()
        except BaseException:
            pass
        finally:
            self._q.put(None)
            self._thread.join(timeout=5.0)
