"""TieredStore: a bounded device tier (LRU of hot rows) over host RAM.

FreshGNN's regime (PAPERS.md): the historical table outgrows device HBM,
but historical embeddings are STABLE, so a small device-resident cache of
hot rows backed by host memory captures most traffic.  Layout per shard
(num_shards=1 collapses to the single-device case):

        host tier  (R rows, numpy, authoritative for non-resident rows)
            ▲  eviction write-back — async, on the pipeline's
            │  AsyncHostWriter thread, overlapped with the step
            ▼  miss fetch — staged in begin(), applied in commit()
      device tier  (C <= R rows = "slots", store/slots.SlotMap —
                    ``evict_policy`` "lru" or age-aware "stale-first")

A global row id r lives on shard ``r // R``; when resident it occupies
device row ``shard*C + slot``, so the dist ring exchange's owner
arithmetic (``id // rows``) works UNCHANGED on slot ids with rows=C.

Invariants (tests/test_store_props.py):
  * device-tier occupancy never exceeds C per shard;
  * every row is authoritative in EXACTLY one tier (resident rows on
    device, everything else in host RAM — pending write-backs count as
    in-flight device rows until the writer lands them);
  * the slot holds the row's (emb, age, initialized) triple bit-for-bit,
    so any eviction/fetch sequence is invisible to the training math.

Concurrency contract: ``begin`` may run on the feeder thread while a step
runs (it only touches host-side bookkeeping and fresh staging buffers);
``commit`` must run on the consumer thread in begin order — its jitted
migration reads/writes the live donated table, and XLA's data dependence
on the table chain orders it against the surrounding steps without host
syncs.  Eviction content is gathered BEFORE upload scatters inside one
jitted call, then handed to the writer thread; a later fetch of a
still-pending row waits for its write-back to land.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import embedding_table as tbl
from repro.kernels.ops import pad_rows_pow2, pad_leading
from repro.obs.memory import get_probe, probe_jit
from repro.obs.trace import span
from repro.store.base import (EmbeddingStore, PreparedMigration,
                              device_rows_per_shard)
from repro.store.forecast import RowForecaster
from repro.store.slots import SlotMap
from repro.store.writeback import AsyncHostWriter, delta_gate


class TieredStore(EmbeddingStore):
    def __init__(self, n_rows: int, j_max: int, d_h: int, *,
                 device_rows: int, num_shards: int = 1, dtype=jnp.float32,
                 sharding=None, writer: Optional[AsyncHostWriter] = None,
                 donate: bool = True, evict_policy: str = "lru",
                 wb_threshold: float = 0.0, stale_forecast: bool = False):
        super().__init__(n_rows, j_max, d_h, num_shards=num_shards,
                         dtype=dtype, sharding=sharding)
        self._C = device_rows_per_shard(n_rows, self.num_shards, device_rows)
        self.evict_policy = evict_policy
        # delta-gated write-back admission (--wb-threshold, FreshGNN): an
        # evicted row whose embedding moved less than this (max-abs vs the
        # stale host copy it faulted in from) skips the host-tier emb
        # write.  0.0 disables the gate — every eviction writes back and
        # the store stays bit-exact vs the device-resident oracle.
        self.wb_threshold = float(wb_threshold)
        # stale-row forecasting (--stale-forecast, Bai et al.): an online
        # per-row velocity EMA fed by the eviction delta stream; fault-ins
        # with a step hint are extrapolated forward by their age.  None
        # (the default) leaves every staged upload bit-identical.
        self._forecaster = RowForecaster(self.padded_rows, j_max, d_h) \
            if stale_forecast else None
        self._maps = [SlotMap(self._C, policy=evict_policy)
                      for _ in range(self.num_shards)]
        self._host = tbl.EmbeddingTable(
            emb=np.zeros((self.padded_rows, j_max, d_h), jnp.dtype(dtype)),
            age=np.zeros((self.padded_rows, j_max), np.int32),
            initialized=np.zeros((self.padded_rows, j_max), bool))
        self._writer = writer if writer is not None else AsyncHostWriter()
        self._own_writer = writer is None
        self._mu = threading.Condition()
        self._begin_mu = threading.RLock()
        self._pending: Dict[int, int] = {}   # row -> evicting begin ticket
        # lookahead pinning (--prefetch-lookups): tickets begun with
        # ``pin=True`` keep their rows displacement-proof until the driver
        # calls ``release(prep)`` — batch k+1's commit lands while step k
        # is still reading batch k's slots, so those rows must survive it
        self._live_pins: Dict[int, set] = {}  # ticket -> pinned rows
        self._begin_ticket = 0
        self._commit_next = 1
        self._done_ticket = 0
        self._wb_exc: Optional[BaseException] = None  # failed write-back
        donate_args = (0,) if donate else ()
        self._migrate = probe_jit("store.migrate", jax.jit(
            self._migrate_impl, donate_argnums=donate_args))
        self._upload = probe_jit("store.upload", jax.jit(
            self._upload_impl, donate_argnums=donate_args))
        self._gather_ev = probe_jit("store.gather", jax.jit(self._gather_impl))

    # -- geometry ----------------------------------------------------------

    @property
    def device_rows_per_shard(self) -> int:
        return self._C

    def occupancy(self) -> int:
        return sum(len(m) for m in self._maps)

    def resident_slot(self, row: int) -> Optional[int]:
        shard = int(row) // self.rows_per_shard
        slot = self._maps[shard].get(int(row), touch=False)
        return None if slot is None else shard * self._C + slot

    # -- jitted migration bodies (shapes pow2-padded by begin) -------------

    def _constrain(self, table: tbl.EmbeddingTable) -> tbl.EmbeddingTable:
        if self.sharding is None:
            return table
        return jax.tree_util.tree_map(
            lambda x: jax.lax.with_sharding_constraint(x, self.sharding),
            table)

    def _upload_impl(self, table, up_slots, up_emb, up_age, up_init):
        return self._constrain(tbl.EmbeddingTable(
            table.emb.at[up_slots].set(up_emb),
            table.age.at[up_slots].set(up_age),
            table.initialized.at[up_slots].set(up_init)))

    def _gather_impl(self, table, ev_slots):
        return (table.emb[ev_slots], table.age[ev_slots],
                table.initialized[ev_slots])

    def _migrate_impl(self, table, up_slots, up_emb, up_age, up_init,
                      ev_slots):
        ev = self._gather_impl(table, ev_slots)  # before the scatter lands
        return self._upload_impl(table, up_slots, up_emb, up_age, up_init), ev

    # -- residency ---------------------------------------------------------

    def begin(self, row_ids, *, fetch: bool = True,
              step: Optional[int] = None,
              pin: bool = False) -> PreparedMigration:
        """Host half of a migration: residency bookkeeping + staging.

        Safe to call on the feeder thread while a step runs.  With
        ``fetch=False`` missing rows are made resident WITHOUT copying
        host content up (their device slots hold garbage until the caller
        overwrites them — the serving cache's insert path, which writes
        the full row right after prepare).

        ``step``: optional refresh hint for stale-first eviction — the
        training step about to WRITE these rows (train/refresh paths,
        where a requested row is refreshed on device; pass nothing for
        read-only paths like finetune lookups).  Without it a resident
        row keeps the age it carried in from the host tier, so a
        long-resident hot row would score as stale as its last eviction
        left it.

        ``pin``: lookahead pinning for the prefetch lane — this batch's
        rows stay displacement-proof against LATER begins until the
        driver calls ``release(prep)`` (after its step is dispatched).
        Without it batch k+1's commit could evict batch k's still-in-use
        slots.  Every later begin honours existing live pins whether or
        not it pins itself."""
        with span("store.begin"):
            prep = self._begin_impl(row_ids, fetch=fetch, step=step, pin=pin)
        self.publish_counters()
        return prep

    def _begin_impl(self, row_ids, *, fetch: bool,
                    step: Optional[int], pin: bool) -> PreparedMigration:
        ids = np.asarray(row_ids).ravel()
        R, C = self.rows_per_shard, self._C
        with self._begin_mu:
            # validate the WHOLE batch before touching any residency state,
            # so a bad batch raises cleanly instead of leaving half-reserved
            # slots and an uncommittable ticket behind
            uniq = list(dict.fromkeys(int(r) for r in ids))
            live: set = set().union(*self._live_pins.values()) \
                if self._live_pins else set()
            per_shard: Dict[int, int] = {}
            for rid in uniq:
                if not 0 <= rid < self.n_rows:
                    raise IndexError(
                        f"row {rid} outside table [0, {self.n_rows})")
                per_shard[rid // R] = per_shard.get(rid // R, 0) + 1
            worst = max(per_shard.values(), default=0)
            if worst > C:
                raise RuntimeError(
                    f"device tier exhausted: shard {max(per_shard, key=per_shard.get)} "
                    f"needs {worst} resident rows for one batch but has only "
                    f"{C} device rows — raise the device-row cap "
                    "(--table-device-rows) to at least the per-shard batch "
                    "row count")
            if live:
                # a live-pinned previous batch shrinks the displaceable
                # pool: this batch's rows AND the pinned ones must coexist
                both: Dict[int, int] = {}
                for rid in set(uniq) | live:
                    both[rid // R] = both.get(rid // R, 0) + 1
                worst_b = max(both.values(), default=0)
                if worst_b > C:
                    raise RuntimeError(
                        f"device tier exhausted under lookahead pinning: "
                        f"shard {max(both, key=both.get)} needs {worst_b} "
                        f"resident rows (this batch + the pinned in-flight "
                        f"batch) but has only {C} device rows — "
                        "--prefetch-lookups needs a device-row cap of "
                        "about TWICE the per-shard batch row count "
                        "(--table-device-rows)")
            self._begin_ticket += 1
            ticket = self._begin_ticket
            pinned = set(uniq) | live
            if pin:
                self._live_pins[ticket] = set(uniq)
            slot_of: Dict[int, int] = {}
            uploads: List[tuple] = []   # (row, device_row)
            evicts: List[tuple] = []    # (row, device_row)
            deferred_age: List[int] = []
            n_hit = 0
            for rid in uniq:
                shard = rid // R
                m = self._maps[shard]
                slot = m.get(rid)
                if slot is None:
                    slot, displaced = m.reserve(rid, pinned=pinned)
                    # per-shard demand <= C was checked above, so a reserve
                    # can always displace a non-pinned entry
                    assert slot is not None
                    if displaced is not None:
                        evicts.append((displaced[0], shard * C + displaced[1]))
                    uploads.append((rid, shard * C + slot))
                    if self.evict_policy != "lru":
                        # stale-first scores by the age the row carried in
                        # from the host tier (its most recent segment
                        # refresh); a step hint means the step is about to
                        # rewrite the row — no host read needed
                        if step is not None:
                            m.set_age(rid, int(step))
                        else:
                            deferred_age.append(rid)
                else:
                    n_hit += 1
                    if self.evict_policy != "lru" and step is not None:
                        m.set_age(rid, int(step))  # about to be rewritten
                slot_of[rid] = shard * C + slot
            slots = np.asarray([slot_of[int(r)] for r in ids], np.int32)
            with self._mu:
                # lookups count UNIQUE rows, so hits + misses == lookups and
                # pow2-padding duplicates don't skew the hit-rate
                self.counters.lookups += len(uniq)
                self.counters.hits += n_hit
                self.counters.misses += len(uploads)
                for row, _ in evicts:
                    self._pending[row] = ticket
            if deferred_age:
                # host ages are only authoritative once any in-flight
                # write-back of these rows has landed — scoring before the
                # wait could read a row's PRE-write-back age
                self._wait_rows(deferred_age)
                for rid in deferred_age:
                    self._maps[rid // R].set_age(
                        rid, int(self._host.age[rid].max()))

            prep = dict(slots=slots, ticket=ticket)
            if evicts:
                (ev_slots_p,) = pad_rows_pow2([g for _, g in evicts])
                prep.update(n_ev=len(evicts), ev_slots=jnp.asarray(ev_slots_p),
                            ev_rows=np.asarray([r for r, _ in evicts]))
            if uploads and fetch:
                rows = [r for r, _ in uploads]
                self._wait_rows(rows)   # pending write-backs must land first
                gs_p, rs_p = pad_rows_pow2([g for _, g in uploads], rows)
                up_emb = self._host.emb[rs_p]
                if self._forecaster is not None and step is not None:
                    # stale-row forecasting: serve the extrapolated row on
                    # fault-in; the authoritative host copy is untouched
                    up_emb = self._forecaster.apply(
                        rs_p, up_emb, self._host.age[rs_p],
                        self._host.initialized[rs_p], int(step))
                prep.update(
                    n_up=len(uploads),
                    up_slots=jnp.asarray(gs_p),
                    up_emb=jnp.asarray(up_emb),
                    up_age=jnp.asarray(self._host.age[rs_p]),
                    up_init=jnp.asarray(self._host.initialized[rs_p]))
                with self._mu:
                    self.counters.bytes_h2d += len(uploads) * self.row_bytes
            return PreparedMigration(**prep)

    def release(self, prep: PreparedMigration) -> None:
        """Drop the lookahead pins ``begin(pin=True)`` took for this
        batch — call after its step is dispatched (the donated table
        chain orders the step before any later commit's migration, so
        the rows are safe to displace from then on)."""
        with self._begin_mu:
            self._live_pins.pop(prep.ticket, None)

    def commit(self, table: tbl.EmbeddingTable,
               prep: PreparedMigration) -> tbl.EmbeddingTable:
        """Device half: apply the staged migration to the live table (in
        begin order) and hand evicted content to the write-back thread."""
        with span("store.commit", n_up=prep.n_up, n_ev=prep.n_ev):
            table = self._commit_impl(table, prep)
        self.publish_counters()
        return table

    def _commit_impl(self, table: tbl.EmbeddingTable,
                     prep: PreparedMigration) -> tbl.EmbeddingTable:
        if prep.ticket != self._commit_next:
            raise RuntimeError(
                f"commit order violated: expected ticket {self._commit_next}, "
                f"got {prep.ticket}")
        self._commit_next += 1
        ev = None
        if prep.n_up and prep.n_ev:
            table, ev = self._migrate(table, prep.up_slots, prep.up_emb,
                                      prep.up_age, prep.up_init, prep.ev_slots)
        elif prep.n_up:
            table = self._upload(table, prep.up_slots, prep.up_emb,
                                 prep.up_age, prep.up_init)
        elif prep.n_ev:
            ev = self._gather_ev(table, prep.ev_slots)
        if prep.n_ev:
            with self._mu:
                self.counters.evictions += prep.n_ev
                self.counters.bytes_d2h += prep.n_ev * self.row_bytes
            self._writer.submit(self._writeback_thunk(
                ev, prep.ev_rows, prep.n_ev, prep.ticket))
        return table

    def _writeback_thunk(self, ev, rows, n, ticket):
        def write():
            with span("store.writeback", rows=int(n)):
                self._writeback_body(ev, rows, n, ticket)
            self.publish_counters()
        return write

    def _writeback_body(self, ev, rows, n, ticket):
        try:
            emb, age, init = (np.asarray(x)[:n] for x in ev)
            if self._forecaster is not None:
                # the host copy is still the fault-in-time content here
                # (read BEFORE the writes below), so this is exactly one
                # (Δemb, Δstep) residency observation per evicted row
                self._forecaster.observe(
                    rows, emb, self._host.emb[rows],
                    age, self._host.age[rows],
                    init, self._host.initialized[rows])
            if self.wb_threshold > 0.0:
                # the host copy is the row's content when it faulted in
                # (stale while resident), so this measures exactly how
                # far the row moved during its device residency
                admit = delta_gate(emb, self._host.emb[rows],
                                   init, self._host.initialized[rows],
                                   self.wb_threshold)
                nskip = int(n - admit.sum())
                if nskip:
                    # emb bytes of the skipped rows never cross to the
                    # host tier: settle the eager bytes_d2h from commit
                    # and surface the saving (ages/init still land, so
                    # staleness bookkeeping stays exact even gated)
                    emb_bytes = self.j_max * self.d_h * emb.dtype.itemsize
                    with self._mu:
                        self.counters.wb_skipped_rows += nskip
                        self.counters.wb_skipped_bytes += \
                            nskip * emb_bytes
                        self.counters.bytes_d2h -= nskip * emb_bytes
                    self._host.emb[rows[admit]] = emb[admit]
                else:
                    self._host.emb[rows] = emb
            else:
                self._host.emb[rows] = emb
            self._host.age[rows] = age
            self._host.initialized[rows] = init
        except BaseException as e:
            with self._mu:
                if self._wb_exc is None:
                    self._wb_exc = e
            raise   # AsyncHostWriter also records it for flush()
        finally:
            # ALWAYS advance the ticket (failure included) so a waiter
            # raises the stored exception instead of spinning forever
            with self._mu:
                self._done_ticket = ticket
                for r in rows:
                    if self._pending.get(int(r)) == ticket:
                        del self._pending[int(r)]
                self._mu.notify_all()

    def _raise_wb_exc_locked(self):
        if self._wb_exc is not None:
            exc, self._wb_exc = self._wb_exc, None
            raise RuntimeError("eviction write-back failed — the host tier "
                               "is no longer trustworthy") from exc

    def _wait_rows(self, rows) -> None:
        """Block until pending write-backs covering ``rows`` have landed."""
        with self._mu:
            need = max((self._pending.get(int(r), 0) for r in rows), default=0)
            self._raise_wb_exc_locked()
        if not need:
            return
        t0 = time.perf_counter()
        with self._mu:
            while self._done_ticket < need:
                self._mu.wait(timeout=0.05)
            self._raise_wb_exc_locked()
            self.counters.writeback_wait_ms += (time.perf_counter() - t0) * 1e3

    # -- lifecycle ---------------------------------------------------------

    def _assert_quiescent(self):
        if self._begin_ticket != self._commit_next - 1:
            raise RuntimeError(
                "store has begun-but-uncommitted migrations — drain the "
                "feeder before snapshot/restore")

    def flush_writebacks(self) -> None:
        self._writer.flush()

    def host_tier_bytes(self) -> int:
        """Bytes of the host-tier numpy arrays over the LOGICAL n_rows —
        matches ``snapshot()``'s nbytes exactly (the pow2 row padding is an
        allocation detail, not table state)."""
        return sum(int(x[:self.n_rows].nbytes) for x in self._host)

    def publish_counters(self) -> None:
        super().publish_counters()
        p = get_probe()
        if p.enabled:
            p.observe_host("store.host_tier", self.host_tier_bytes())

    def snapshot(self, table: tbl.EmbeddingTable) -> tbl.EmbeddingTable:
        """Dense (n_rows, J, d) host view: host tier overlaid with every
        device-resident row — the checkpointable whole table."""
        self._assert_quiescent()
        self._writer.flush()
        host = jax.tree_util.tree_map(np.copy, self._host)
        dev = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), table)
        rows, gs = self._resident_index()
        if rows.size:
            host.emb[rows] = dev.emb[gs]
            host.age[rows] = dev.age[gs]
            host.initialized[rows] = dev.initialized[gs]
        return tbl.EmbeddingTable(*(x[:self.n_rows] for x in host))

    def _resident_index(self):
        """(rows, device_rows) index arrays over every resident row — one
        vectorized fancy-index merge instead of a per-row Python loop."""
        rows, gs = [], []
        for shard, m in enumerate(self._maps):
            for row, slot in m.items():
                rows.append(row)
                gs.append(shard * self._C + slot)
        return np.asarray(rows, np.int64), np.asarray(gs, np.int64)

    def restore(self, snap: tbl.EmbeddingTable) -> tbl.EmbeddingTable:
        """Reset from a dense snapshot: everything starts in the host tier,
        the device tier comes back empty (residency is not semantic state —
        the first batches re-fault their rows)."""
        self._assert_quiescent()
        self._writer.flush()
        for m in self._maps:
            m.clear()
        with self._begin_mu:
            self._live_pins.clear()
        with self._mu:
            self._pending.clear()
        self._host = tbl.EmbeddingTable(
            *(pad_leading(np.array(jax.device_get(x)), self.padded_rows)
              for x in snap))
        return self.init_device_table()

    def invalidate_rows(self, table: tbl.EmbeddingTable,
                        rows) -> tbl.EmbeddingTable:
        if len(rows) == 0:
            return table
        dev_rows, host_rows = [], []
        for r in rows:
            slot = self.resident_slot(r)
            if slot is not None:
                dev_rows.append(slot)
            else:
                host_rows.append(int(r))
        if host_rows:
            self._wait_rows(host_rows)
            self._host.initialized[host_rows] = False
        if dev_rows:
            (dev_p,) = pad_rows_pow2(dev_rows)
            table = self._evict_jit(table, jnp.asarray(dev_p))
        return table

    def refresh_ages(self, table: tbl.EmbeddingTable) -> None:
        """Re-report TRUE ages for every device-resident row to the
        eviction SlotMaps (the PR 5 readback nuance: SlotMap ages are
        otherwise only fed at fault-in / step-hinted begins, so a row
        refreshed while resident — a training write that advanced its
        device age plane — would keep scoring as stale as its fault-in
        copy and stay the stale-first eviction victim).  Reads the
        device age planes back (one transfer), so call it at epoch
        granularity, not per step.  No-op under plain LRU, where ages
        don't drive eviction."""
        if self.evict_policy == "lru":
            return
        dev_age = np.asarray(jax.device_get(table.age))
        rows, gs = self._resident_index()
        R = self.rows_per_shard
        for row, g in zip(rows, gs):
            self._maps[int(row) // R].set_age(int(row),
                                              int(dev_age[g].max()))

    def ages_init(self, table):
        # stats-grade view: no writer flush (a flush here would serialize
        # the serving hot path against the async write-back lane every
        # window).  Rows with an in-flight write-back may read one
        # migration stale — fine for monitoring; snapshot() is the
        # consistent view.
        age = np.copy(self._host.age)
        init = np.copy(self._host.initialized)
        dev_age = np.asarray(jax.device_get(table.age))
        dev_init = np.asarray(jax.device_get(table.initialized))
        rows, gs = self._resident_index()
        if rows.size:
            age[rows] = dev_age[gs]
            init[rows] = dev_init[gs]
        return age[:self.n_rows], init[:self.n_rows]

    def close(self) -> None:
        if self._own_writer:
            self._writer.close()

    def stats(self) -> dict:
        d = super().stats()
        d.update({
            "device_rows_per_shard": self._C,
            "host_rows": self.padded_rows,
            "occupancy_frac": self.occupancy() / max(self.device_rows, 1),
            "pending_writebacks": self._writer.pending,
            "evict_policy": self.evict_policy,
            "wb_threshold": self.wb_threshold,
            "stale_forecast": self._forecaster is not None,
        })
        if self._forecaster is not None:
            d["forecast"] = self._forecaster.stats()
        return d
