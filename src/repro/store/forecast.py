"""Online per-row dynamic-embedding forecasting for the tiered store.

"Staleness-Alleviated Distributed GNN Training via Online Dynamic-
Embedding Prediction" (Bai et al., PAPERS.md): a historical embedding
that sat in the host tier for ``age`` steps is not served as-is — it is
extrapolated forward by a per-row velocity estimate before the training
step consumes it.  The estimate is maintained ONLINE from the delta
stream the store already computes: every eviction write-back compares
the evicted row against the host copy it faulted in from (the same
comparison the PR 6 ``--wb-threshold`` delta gate runs), which is one
(Δemb, Δstep) observation per residency — an EMA of Δemb/Δstep is the
row's velocity.

The forecast is strictly read-side: ``apply`` patches the STAGED upload
buffer on fault-in, never the authoritative host arrays, so turning the
flag off (the default — ``--stale-forecast``) leaves every byte of store
state and every staged upload bit-identical to main.
"""
from __future__ import annotations

import numpy as np


class RowForecaster:
    """Per-row linear (EMA-velocity) extrapolator over the host tier.

    vel[r] ≈ EMA of (emb_evicted - emb_fault_in) / steps_resident — the
    row's drift per training step.  ``observe`` feeds it one eviction's
    delta; ``apply`` extrapolates rows whose age (vs ``now_step``) is at
    least ``min_age`` forward by exactly that age.  An age-0 (or
    never-observed) row forecasts to the identity.
    """

    def __init__(self, n_rows: int, j_max: int, d_h: int, *,
                 alpha: float = 0.5, min_age: int = 1, dtype=np.float32):
        self.alpha = float(alpha)
        self.min_age = int(min_age)
        # velocity is only meaningful for rows that completed >= 1
        # observed residency; _seen gates apply() to those
        self._vel = np.zeros((n_rows, j_max, d_h), dtype)
        self._seen = np.zeros((n_rows, j_max), bool)
        self.observed_rows = 0
        self.forecast_rows = 0

    def observe(self, rows, emb_new, emb_old, age_new, age_old,
                init_new, init_old) -> None:
        """One eviction write-back's delta stream: ``rows`` (n,) global
        row ids, ``*_new`` the evicted device content, ``*_old`` the host
        copy the residency faulted in from (read BEFORE the write-back
        lands).  Slots initialized on both sides contribute a velocity
        observation; fresh initializations have no baseline and only
        reset the EMA gate."""
        rows = np.asarray(rows)
        both = np.asarray(init_new) & np.asarray(init_old)      # (n, J)
        if not both.any():
            return
        elapsed = np.maximum(
            np.asarray(age_new, np.float32) - np.asarray(age_old, np.float32),
            1.0)                                                 # (n, J)
        step_vel = (np.asarray(emb_new, np.float32)
                    - np.asarray(emb_old, np.float32)) / elapsed[..., None]
        prev = self._vel[rows]
        seen = self._seen[rows]                                  # (n, J)
        # first observation seeds the EMA, later ones blend
        blended = np.where(seen[..., None],
                           (1.0 - self.alpha) * prev
                           + self.alpha * step_vel,
                           step_vel)
        self._vel[rows] = np.where(both[..., None], blended, prev)
        self._seen[rows] = seen | both
        self.observed_rows += int(both.any(axis=-1).sum())

    def apply(self, rows, emb, age, init, now_step: int) -> np.ndarray:
        """Extrapolate a staged fault-in buffer forward: rows (n,) global
        ids, emb (n, J, d) the host copies, age (n, J) their last-refresh
        steps.  Slots that are initialized, velocity-observed, and at
        least ``min_age`` steps old get ``emb + vel * age_steps``; all
        others — age 0 included — pass through untouched (the identity
        round-trip contract)."""
        rows = np.asarray(rows)
        age_steps = np.maximum(
            float(now_step) - np.asarray(age, np.float32), 0.0)  # (n, J)
        hit = (np.asarray(init) & self._seen[rows]
               & (age_steps >= self.min_age))                    # (n, J)
        if not hit.any():
            return emb
        out = np.array(emb, np.float32, copy=True)
        fwd = out + self._vel[rows] * age_steps[..., None]
        out = np.where(hit[..., None], fwd, out)
        self.forecast_rows += int(hit.any(axis=-1).sum())
        return out.astype(emb.dtype)

    def stats(self) -> dict:
        return {"observed_rows": self.observed_rows,
                "forecast_rows": self.forecast_rows}
