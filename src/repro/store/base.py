"""One embedding-store API over the historical table's three former lives.

The historical segment-embedding table T (paper §3.2) used to exist three
times — replicated (core/embedding_table.py consumers), row-sharded
(dist/table.py) and as the serving cache's slot pool (serve/cache.py).
``EmbeddingStore`` unifies them behind a single residency contract:

  * the jitted step code keeps operating on a plain device-resident
    ``EmbeddingTable`` through the existing ``tbl.lookup`` /
    ``tbl.update_sampled`` / ``tbl.update_all`` accessors (or the
    dist/table.py ring versions) — nothing inside jit knows about tiers;
  * the store owns WHICH rows that device table holds.  Before a step, the
    driver hands it the batch's global row ids; the store returns the
    device rows ("slots") to address instead, migrating rows between the
    device tier and a host-RAM tier as needed (TieredStore) or passing ids
    straight through (DeviceStore, where row == slot).

Because the indirection is pure host-side row renaming — the slot holds
bit-for-bit the row's (emb, age, initialized) triple — a capped-capacity
TieredStore trains bitwise identically to the device-resident oracle
(tests/test_store.py asserts this for all 7 GST variants).

The two-phase ``begin``/``commit`` split exists for the async pipeline:
``begin`` does all host work (residency bookkeeping, host-tier gather,
staging device_put) and is safe on the feeder thread while a step runs;
``commit`` applies the staged migration to the live table and must run in
``begin`` order on the consumer thread.  ``prepare`` fuses both for
synchronous drivers.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import embedding_table as tbl
from repro.kernels.ops import pad_leading, pad_rows_pow2
from repro.obs.metrics import get_registry


# -- block row partition (canonical home; dist/table.py re-exports) ---------


def rows_per_shard(n_rows: int, num_shards: int) -> int:
    """R such that D·R >= n (block row partition, last shard may pad)."""
    return -(-n_rows // max(num_shards, 1))


def padded_rows(n_rows: int, num_shards: int) -> int:
    return rows_per_shard(n_rows, num_shards) * max(num_shards, 1)


def device_rows_per_shard(n_rows: int, num_shards: int,
                          device_rows: int) -> int:
    """Device-tier rows per shard for a TOTAL cap of ``device_rows``:
    the cap split evenly over shards, clamped to [1, rows_per_shard]."""
    num_shards = max(num_shards, 1)
    per = -(-min(device_rows, padded_rows(n_rows, num_shards)) // num_shards)
    return max(1, min(rows_per_shard(n_rows, num_shards), per))


@dataclass
class StoreCounters:
    """Residency-traffic counters (satellite: surfaced by the CLIs and the
    store benchmark)."""
    lookups: int = 0         # batch rows requested
    hits: int = 0            # already device-resident
    misses: int = 0          # faulted host -> device
    evictions: int = 0       # spilled device -> host
    bytes_h2d: int = 0
    bytes_d2h: int = 0
    writeback_wait_ms: float = 0.0   # begin() blocked on pending write-backs
    # delta-gated write-back admission (TieredStore ``wb_threshold``):
    # evicted rows whose embedding moved less than the threshold skip the
    # host-tier emb write; bytes_d2h is settled down by the skipped emb
    # bytes when the writer thread lands the eviction
    wb_skipped_rows: int = 0
    wb_skipped_bytes: int = 0

    def as_dict(self) -> dict:
        total = max(self.lookups, 1)
        return {
            "lookups": self.lookups,
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total,
            "evictions": self.evictions,
            "bytes_h2d": self.bytes_h2d,
            "bytes_d2h": self.bytes_d2h,
            "migration_bytes": self.bytes_h2d + self.bytes_d2h,
            "wb_skipped_rows": self.wb_skipped_rows,
            "wb_skipped_bytes": self.wb_skipped_bytes,
            "writeback_wait_ms": round(self.writeback_wait_ms, 3),
        }


# registry mirror of StoreCounters: (field, published metric name, unit).
# ``misses`` surfaces as ``store.faults`` — the residency fault count.
_COUNTER_METRICS = (
    ("lookups", "store.lookups", "rows"),
    ("hits", "store.hits", "rows"),
    ("misses", "store.faults", "rows"),
    ("evictions", "store.evictions", "rows"),
    ("bytes_h2d", "store.bytes_h2d", "bytes"),
    ("bytes_d2h", "store.bytes_d2h", "bytes"),
    ("writeback_wait_ms", "store.writeback_wait_ms", "ms"),
    ("wb_skipped_rows", "store.wb_skipped_rows", "rows"),
    ("wb_skipped_bytes", "store.wb_skipped_bytes", "bytes"),
)


class PreparedMigration(NamedTuple):
    """Output of ``begin``: the batch's device rows plus the staged data
    movement ``commit`` will apply.  Device staging buffers live here so
    the host->device copy overlaps with the running step."""
    slots: np.ndarray                      # (B,) device rows for the batch
    ticket: int
    n_up: int = 0
    n_ev: int = 0
    up_slots: Optional[jnp.ndarray] = None     # pow2-padded scatter rows
    up_emb: Optional[jnp.ndarray] = None
    up_age: Optional[jnp.ndarray] = None
    up_init: Optional[jnp.ndarray] = None
    ev_slots: Optional[jnp.ndarray] = None     # pow2-padded gather rows
    ev_rows: Optional[np.ndarray] = None       # (n_ev,) global rows going home


class EmbeddingStore:
    """Base geometry + the no-op residency contract (see module docstring).

    Subclasses override the begin/commit pair; everything is sized by
    ``n_rows`` logical rows split block-wise over ``num_shards`` (shard s
    owns rows [s*R, (s+1)*R), the dist/table.py partition), with
    ``device_rows_per_shard`` of them device-resident at a time.
    """

    def __init__(self, n_rows: int, j_max: int, d_h: int, *,
                 num_shards: int = 1, dtype=jnp.float32, sharding=None):
        self.n_rows = n_rows
        self.j_max = j_max
        self.d_h = d_h
        self.num_shards = max(num_shards, 1)
        self.dtype = dtype
        self.sharding = sharding
        self.rows_per_shard = rows_per_shard(n_rows, self.num_shards)
        self.padded_rows = padded_rows(n_rows, self.num_shards)
        self.counters = StoreCounters()
        self._evict_jit = jax.jit(tbl.evict_rows)

    # ``store.counters`` stays the mutation surface (callers reset it by
    # assigning a fresh StoreCounters); the registry carries a cumulative
    # mirror published by diffing, so resets of the view never rewind the
    # process-wide counters.
    @property
    def counters(self) -> StoreCounters:
        return self._counters

    @counters.setter
    def counters(self, c: StoreCounters) -> None:
        if not hasattr(self, "_publish_mu"):   # first call is from __init__
            self._publish_mu = threading.Lock()
        with self._publish_mu:
            self._counters = c
            self._published = {f: getattr(c, f)
                               for f, _, _ in _COUNTER_METRICS}

    def publish_counters(self) -> None:
        """Mirror counter movement since the last publish into the metrics
        registry (host-side; no-op when metrics are disabled).  Callable
        from any thread — begin runs on the feeder, commit on the
        consumer, delta-gate settlement on the writer."""
        reg = get_registry()
        if not reg.enabled:
            return
        with self._publish_mu:
            for field, name, unit in _COUNTER_METRICS:
                cur = getattr(self._counters, field)
                moved = cur - self._published[field]
                if moved:
                    reg.inc(name, moved, unit=unit)
                    self._published[field] = cur

    # bytes of one (emb, age, init) row triple — the migration-unit size
    @property
    def row_bytes(self) -> int:
        item = jnp.dtype(self.dtype).itemsize
        return self.j_max * (self.d_h * item + 4 + 1)

    @property
    def device_rows_per_shard(self) -> int:
        return self.rows_per_shard

    @property
    def device_rows(self) -> int:
        return self.device_rows_per_shard * self.num_shards

    def _place(self, table: tbl.EmbeddingTable) -> tbl.EmbeddingTable:
        if self.sharding is None:
            return jax.tree_util.tree_map(jnp.asarray, table)
        return jax.tree_util.tree_map(
            lambda x: jax.device_put(x, self.sharding), table)

    # -- residency ---------------------------------------------------------

    def begin(self, row_ids, *, fetch: bool = True,
              step: Optional[int] = None,
              pin: bool = False) -> PreparedMigration:
        raise NotImplementedError

    def commit(self, table: tbl.EmbeddingTable,
               prep: PreparedMigration) -> tbl.EmbeddingTable:
        raise NotImplementedError

    def prepare(self, table: tbl.EmbeddingTable, row_ids, *,
                fetch: bool = True, step: Optional[int] = None,
                ) -> Tuple[tbl.EmbeddingTable, np.ndarray]:
        """begin + commit in one call (synchronous drivers).  ``step``:
        refresh hint for stale-first eviction (see TieredStore.begin)."""
        prep = self.begin(row_ids, fetch=fetch, step=step)
        return self.commit(table, prep), prep.slots

    def release(self, prep: PreparedMigration) -> None:
        """Drop the residency pins ``begin(pin=True)`` took for this
        batch.  Only meaningful under lookahead pinning (the
        --prefetch-lookups lane, where batch k+1's commit lands while
        batch k's rows must stay resident); a no-op everywhere else."""

    def resident_slot(self, row: int) -> Optional[int]:
        """Device row currently holding ``row`` (no LRU side effects), or
        None when the row lives in the host tier."""
        raise NotImplementedError

    # -- lifecycle ---------------------------------------------------------

    def init_device_table(self) -> tbl.EmbeddingTable:
        """The fresh device tier that goes into TrainState."""
        return self._place(tbl.init_table(
            self.device_rows, self.j_max, self.d_h, self.dtype))

    def snapshot(self, table: tbl.EmbeddingTable) -> tbl.EmbeddingTable:
        """Full dense host copy (n_rows, J, d) — both tiers merged; the
        checkpointable view of the store."""
        raise NotImplementedError

    def restore(self, snap: tbl.EmbeddingTable) -> tbl.EmbeddingTable:
        """Reset residency from a dense snapshot; returns the new device
        table to place into TrainState."""
        raise NotImplementedError

    def invalidate_rows(self, table: tbl.EmbeddingTable,
                        rows) -> tbl.EmbeddingTable:
        """Clear ``initialized`` for the given global rows in whichever tier
        holds them (the serving keying layer's eviction)."""
        raise NotImplementedError

    def ages_init(self, table: tbl.EmbeddingTable):
        """(ages (n_rows, J), initialized (n_rows, J)) numpy — the staleness
        bookkeeping merged across tiers (serving stats)."""
        raise NotImplementedError

    def refresh_ages(self, table: tbl.EmbeddingTable) -> None:
        """Re-report device-plane ages to the eviction bookkeeping (the
        TieredStore stale-first readback); a no-op for backends whose
        eviction never consults ages."""

    def flush_writebacks(self) -> None:
        """Wait until every pending device->host write-back has landed."""

    def close(self) -> None:
        pass

    def stats(self) -> dict:
        self.publish_counters()
        d = self.counters.as_dict()
        d.update({
            "backend": type(self).__name__,
            "n_rows": self.n_rows,
            "device_rows": min(self.device_rows, self.padded_rows),
            "occupancy": self.occupancy(),
        })
        return d

    def occupancy(self) -> int:
        return 0


class DeviceStore(EmbeddingStore):
    """The device-resident oracle backend: the whole (padded) table lives in
    device memory and global row ids ARE the device rows — ``begin`` /
    ``commit`` are pure bookkeeping no-ops, preserving the donated in-place
    scatter semantics of the original core/embedding_table.py path."""

    def begin(self, row_ids, *, fetch: bool = True,
              step: Optional[int] = None,
              pin: bool = False) -> PreparedMigration:
        slots = np.asarray(row_ids, np.int32)
        # count UNIQUE rows like TieredStore.begin, so the counters the
        # CLIs/bench print are comparable across backends (callers pass
        # pow2-padded row arrays whose padding repeats the last row)
        uniq = len(set(slots.tolist()))
        self.counters.lookups += uniq
        self.counters.hits += uniq
        self.publish_counters()
        return PreparedMigration(slots=slots, ticket=0)

    def commit(self, table, prep):
        return table

    def resident_slot(self, row: int) -> Optional[int]:
        return int(row)

    def snapshot(self, table: tbl.EmbeddingTable) -> tbl.EmbeddingTable:
        host = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), table)
        return tbl.EmbeddingTable(*(x[:self.n_rows] for x in host))

    def restore(self, snap: tbl.EmbeddingTable) -> tbl.EmbeddingTable:
        padded = tbl.EmbeddingTable(
            *(pad_leading(np.asarray(x), self.padded_rows) for x in snap))
        return self._place(padded)

    def invalidate_rows(self, table, rows) -> tbl.EmbeddingTable:
        if len(rows) == 0:
            return table
        (rows_p,) = pad_rows_pow2(list(rows))
        return self._evict_jit(table, jnp.asarray(rows_p))

    def ages_init(self, table):
        age = np.asarray(jax.device_get(table.age))[:self.n_rows]
        init = np.asarray(jax.device_get(table.initialized))[:self.n_rows]
        return age, init

    def occupancy(self) -> int:
        return min(self.n_rows, self.device_rows)
