"""Slot allocation with pluggable eviction — the machinery generalized out
of serve/cache.py.

A ``SlotMap`` owns ``capacity`` integer slots and maps hashable keys onto
them: the serving cache keys slots by segment content hash, the tiered
store (store/tiered.py) keys each shard's device slots by the global
table row resident in them.  Only bookkeeping lives here — what a slot
physically holds (a device row, a cache entry) is the caller's business,
which is exactly why both tiers can share it.

Eviction policies (the ``--evict-policy`` knob):

  ``lru``          evict the least-recently-used key (insertion/touch
                   order) — the original behavior.
  ``stale-first``  VISAGNN direction (PAPERS.md): rows already carry a
                   refresh age, so score evictions by (age, coldness) —
                   the victim is the key with the OLDEST caller-reported
                   age (``set_age``; keys with no reported age count as
                   stalest), ties broken by LRU coldness.  Fresh-and-hot
                   rows stay resident; stale-and-cold rows leave first.

Either way the policy only picks WHICH row migrates — the migration
itself is bit-preserving, so the training math never sees it
(tests/test_store_props.py).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Hashable, Iterator, List, Optional, Tuple

POLICIES = ("lru", "stale-first")


class SlotMap:
    """key -> slot map with pinned-key-aware eviction.

    Keys are kept in LRU order (OrderedDict); ``reserve`` picks its
    victim by the configured policy among the keys not in the caller's
    pinned set and reports the displaced (key, slot) pair so the caller
    can migrate/drop whatever the slot held.
    """

    def __init__(self, capacity: int, *, policy: str = "lru"):
        if capacity < 1:
            raise ValueError("slot capacity must be >= 1")
        if policy not in POLICIES:
            raise ValueError(f"unknown eviction policy {policy!r} — "
                             f"expected one of {POLICIES}")
        self.capacity = capacity
        self.policy = policy
        self._slots: "OrderedDict[Hashable, int]" = OrderedDict()
        self._age: Dict[Hashable, int] = {}
        self._free: List[int] = list(range(capacity - 1, -1, -1))

    def __len__(self) -> int:
        return len(self._slots)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._slots

    def items(self) -> Iterator[Tuple[Hashable, int]]:
        return iter(self._slots.items())

    def get(self, key: Hashable, *, touch: bool = True) -> Optional[int]:
        """Slot of ``key`` or None; ``touch`` refreshes its LRU position."""
        slot = self._slots.get(key)
        if slot is not None and touch:
            self._slots.move_to_end(key)
        return slot

    def touch(self, key: Hashable) -> None:
        self._slots.move_to_end(key)

    def set_age(self, key: Hashable, age: int) -> None:
        """Record ``key``'s refresh age (a monotonic step counter) for the
        stale-first victim scan.  No-op bookkeeping under lru."""
        if key in self._slots:
            self._age[key] = int(age)

    def age_of(self, key: Hashable) -> Optional[int]:
        return self._age.get(key)

    def _victim(self, pinned) -> Optional[Hashable]:
        if self.policy == "lru":
            for key in self._slots:  # iteration order == coldness
                if key not in pinned:
                    return key
            return None
        # stale-first: min reported age wins (unreported == stalest);
        # scanning in LRU order makes the COLDEST of equally-stale keys
        # the victim without a second pass
        best, best_age = None, None
        for key in self._slots:
            if key in pinned:
                continue
            age = self._age.get(key, -1)
            if best is None or age < best_age:
                best, best_age = key, age
        return best

    def reserve(self, key: Hashable, pinned=frozenset(),
                ) -> Tuple[Optional[int], Optional[Tuple[Hashable, int]]]:
        """Allocate a slot for a NEW key (appended at the MRU end).

        Returns ``(slot, evicted)``: ``evicted`` is the displaced
        ``(old_key, slot)`` pair when a live entry had to make room, None
        when a free slot was used.  ``(None, None)`` when the map is full
        and every live key is pinned.
        """
        if key in self._slots:
            raise KeyError(f"key already mapped: {key!r}")
        if self._free:
            slot = self._free.pop()
            self._slots[key] = slot
            return slot, None
        old_key = self._victim(pinned)
        if old_key is None:
            return None, None
        slot = self._slots.pop(old_key)
        self._age.pop(old_key, None)
        self._slots[key] = slot
        return slot, (old_key, slot)

    def release(self, key: Hashable) -> int:
        """Drop ``key`` and return its slot to the free list."""
        slot = self._slots.pop(key)
        self._age.pop(key, None)
        self._free.append(slot)
        return slot

    def clear(self) -> None:
        self._slots.clear()
        self._age.clear()
        self._free = list(range(self.capacity - 1, -1, -1))
