"""LRU slot allocation — the machinery generalized out of serve/cache.py.

A ``SlotMap`` owns ``capacity`` integer slots and maps hashable keys onto
them in LRU order: the serving cache keys slots by segment content hash,
the tiered store (store/tiered.py) keys each shard's device slots by the
global table row resident in them.  Only bookkeeping lives here — what a
slot physically holds (a device row, a cache entry) is the caller's
business, which is exactly why both tiers can share it.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Hashable, Iterator, List, Optional, Tuple


class SlotMap:
    """key -> slot map, LRU-ordered, with pinned-key-aware eviction.

    Eviction picks the least-recently-used key not in the caller's pinned
    set; ``reserve`` reports the displaced (key, slot) pair so the caller
    can migrate/drop whatever the slot held.
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError("slot capacity must be >= 1")
        self.capacity = capacity
        self._slots: "OrderedDict[Hashable, int]" = OrderedDict()
        self._free: List[int] = list(range(capacity - 1, -1, -1))

    def __len__(self) -> int:
        return len(self._slots)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._slots

    def items(self) -> Iterator[Tuple[Hashable, int]]:
        return iter(self._slots.items())

    def get(self, key: Hashable, *, touch: bool = True) -> Optional[int]:
        """Slot of ``key`` or None; ``touch`` refreshes its LRU position."""
        slot = self._slots.get(key)
        if slot is not None and touch:
            self._slots.move_to_end(key)
        return slot

    def touch(self, key: Hashable) -> None:
        self._slots.move_to_end(key)

    def reserve(self, key: Hashable, pinned=frozenset(),
                ) -> Tuple[Optional[int], Optional[Tuple[Hashable, int]]]:
        """Allocate a slot for a NEW key (appended at the MRU end).

        Returns ``(slot, evicted)``: ``evicted`` is the displaced
        ``(old_key, slot)`` pair when a live entry had to make room, None
        when a free slot was used.  ``(None, None)`` when the map is full
        and every live key is pinned.
        """
        if key in self._slots:
            raise KeyError(f"key already mapped: {key!r}")
        if self._free:
            slot = self._free.pop()
            self._slots[key] = slot
            return slot, None
        for old_key in self._slots:
            if old_key not in pinned:
                slot = self._slots.pop(old_key)
                self._slots[key] = slot
                return slot, (old_key, slot)
        return None, None

    def release(self, key: Hashable) -> int:
        """Drop ``key`` and return its slot to the free list."""
        slot = self._slots.pop(key)
        self._free.append(slot)
        return slot

    def clear(self) -> None:
        self._slots.clear()
        self._free = list(range(self.capacity - 1, -1, -1))
