"""Synthetic token pipelines for the sequence (transformer) GST track.

``make_property_docs`` mirrors the MalNet-like construction at token level:
a document is J segments, each drawn from a latent *topic*'s unigram
distribution; the label is the majority topic — a whole-input property no
single segment determines reliably, which is GST's use case (DESIGN.md §3).

``make_lm_stream`` is a deterministic-pattern LM stream used by smoke tests
(loss must drop) and the plain-LM objective of train.py.
"""
from __future__ import annotations

from typing import Dict, Iterator, Tuple

import numpy as np


def make_property_docs(
    n_docs: int = 64,
    n_segments: int = 4,
    seg_len: int = 64,
    vocab: int = 256,
    n_topics: int = 5,
    seed: int = 0,
) -> Dict[str, np.ndarray]:
    """Returns dict of arrays: tokens (n, J, L), labels (n,), seg_valid (n, J)."""
    rng = np.random.default_rng(seed)
    # topic unigram distributions over disjoint-ish vocab bands
    topics = []
    for t in range(n_topics):
        w = np.full(vocab, 0.2 / vocab)
        band = slice((t * vocab) // n_topics, ((t + 1) * vocab) // n_topics)
        w[band] += 0.8 / max(band.stop - band.start, 1)
        topics.append(w / w.sum())
    tokens = np.zeros((n_docs, n_segments, seg_len), np.int32)
    labels = np.zeros((n_docs,), np.int32)
    for i in range(n_docs):
        seg_topics = rng.integers(0, n_topics, n_segments)
        for j, t in enumerate(seg_topics):
            tokens[i, j] = rng.choice(vocab, size=seg_len, p=topics[t])
        labels[i] = int(np.argmax(np.bincount(seg_topics, minlength=n_topics)))
    return {
        "tokens": tokens,
        "labels": labels,
        "seg_valid": np.ones((n_docs, n_segments), np.float32),
    }


def doc_batch_iterator(docs: Dict[str, np.ndarray], batch_size: int, *,
                       rng: np.random.Generator, shuffle: bool = True
                       ) -> Iterator[Tuple[Dict, np.ndarray, np.ndarray, np.ndarray]]:
    n = docs["tokens"].shape[0]
    order = rng.permutation(n) if shuffle else np.arange(n)
    for i in range(0, n - batch_size + 1, batch_size):
        ids = order[i : i + batch_size]
        yield ({"tokens": docs["tokens"][ids]}, docs["seg_valid"][ids],
               ids.astype(np.int32), docs["labels"][ids])


def make_lm_stream(n_seqs: int, seq_len: int, vocab: int, seed: int = 0
                   ) -> np.ndarray:
    """Learnable pattern: x_{t+1} = (x_t * 3 + noise) % vocab, noise sparse."""
    rng = np.random.default_rng(seed)
    out = np.zeros((n_seqs, seq_len), np.int32)
    x = rng.integers(0, vocab, n_seqs)
    for t in range(seq_len):
        out[:, t] = x
        jump = rng.random(n_seqs) < 0.05
        x = np.where(jump, rng.integers(0, vocab, n_seqs), (x * 3 + 1) % vocab)
    return out
