from repro.data.tokens import make_property_docs, doc_batch_iterator, make_lm_stream

__all__ = ["make_property_docs", "doc_batch_iterator", "make_lm_stream"]
