"""Pallas TPU kernel: blockwise sliding-window flash attention (prefill).

Used by the dense architectures' long-context variant (DESIGN.md §Skips):
window W bounds the key range per query, so prefill cost is O(S·W) instead
of O(S²) — the sub-quadratic requirement of the long_500k shape.

Flash-attention-style online softmax in VMEM scratch; the kv range per query
block is static: nkv = W/blk + 1 trailing blocks, so the grid is
(B·H, S/blk, nkv) and BlockSpec index maps slide the kv window.  Out-of-range
(clamped) kv blocks are neutralised through the *virtual* position mask.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BLK = 128
NEG_INF = -1e30


def _swa_kernel(q_ref, k_ref, v_ref, out_ref, m_scr, l_scr, acc_scr, *,
                blk: int, nkv: int, window: int, scale: float):
    qb = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)              # (blk, D)
    k = k_ref[0].astype(jnp.float32)              # (blk, D)
    v = v_ref[0].astype(jnp.float32)
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    vb = qb - (nkv - 1) + kb                       # virtual kv block index
    q_pos = qb * blk + jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 0)
    k_pos = vb * blk + jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 1)
    mask = (k_pos <= q_pos) & (k_pos > q_pos - window) & (vb >= 0)
    scores = jnp.where(mask, scores, NEG_INF)

    m_prev = m_scr[...]                            # (blk, 1)
    l_prev = l_scr[...]
    m_cur = jnp.max(scores, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(scores - m_new)                    # (blk, blk)
    alpha = jnp.exp(m_prev - m_new)                # (blk, 1)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kb == nkv - 1)
    def _finalize():
        out_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                      ).astype(out_ref.dtype)


def swa_attention(q, k, v, *, window: int, blk: int = DEFAULT_BLK,
                  interpret: bool = False):
    """q/k/v: (B, S, H, D) -> (B, S, H, D), causal sliding-window attention."""
    B, S, H, D = q.shape
    blk = min(blk, S)
    assert S % blk == 0, (S, blk)
    assert window % blk == 0 or window >= S, (window, blk)
    nkv = min(window // blk + 1, S // blk) if window < S else S // blk
    nkv = max(nkv, 1)
    scale = 1.0 / math.sqrt(D)
    # (B, S, H, D) -> (B*H, S, D)
    qr = jnp.moveaxis(q, 2, 1).reshape(B * H, S, D)
    kr = jnp.moveaxis(k, 2, 1).reshape(B * H, S, D)
    vr = jnp.moveaxis(v, 2, 1).reshape(B * H, S, D)

    def kv_map(bh, qb, kb):
        vb = qb - (nkv - 1) + kb
        return (bh, jnp.maximum(vb, 0), 0)

    out = pl.pallas_call(
        functools.partial(_swa_kernel, blk=blk, nkv=nkv, window=window,
                          scale=scale),
        grid=(B * H, S // blk, nkv),
        in_specs=[
            pl.BlockSpec((1, blk, D), lambda bh, qb, kb: (bh, qb, 0)),
            pl.BlockSpec((1, blk, D), kv_map),
            pl.BlockSpec((1, blk, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, blk, D), lambda bh, qb, kb: (bh, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk, 1), jnp.float32),
            pltpu.VMEM((blk, 1), jnp.float32),
            pltpu.VMEM((blk, D), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return jnp.moveaxis(out.reshape(B, H, S, D), 1, 2)
