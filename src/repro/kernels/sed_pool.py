"""Pallas TPU kernel: fused Stale-Embedding-Dropout + segment pooling.

The GST aggregation h = ⊕_j η_j h_j (Eq. 1) is small compute but, executed
naively, makes four HBM passes over the (B, J, d) segment-embedding tensor
(η build, mask, weighted sum, normalize).  This kernel fuses the whole thing
into one pass: the η weights are computed in-register from the three masks
and keep-prob, and the J-reduction happens in VMEM.

Grid: (batch blocks, feature blocks); J (≤ J_max, small) is unrolled inside
the kernel body as part of the block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_B_BLK = 8
DEFAULT_D_BLK = 128


def _sed_pool_kernel(h_ref, valid_ref, fresh_ref, drop_ref, out_ref, *,
                     keep_prob: float, num_sampled: int, agg: str):
    h = h_ref[...]                           # (b_blk, J, d_blk)
    valid = valid_ref[...].astype(jnp.float32)   # (b_blk, J)
    fresh = fresh_ref[...].astype(jnp.float32)
    drop = drop_ref[...].astype(jnp.float32)
    J_i = jnp.sum(valid, axis=-1, keepdims=True)           # (b_blk, 1)
    eta_fresh = keep_prob + (1.0 - keep_prob) * J_i / float(num_sampled)
    stale = valid * (1.0 - fresh)
    eta = (fresh * eta_fresh + stale * (1.0 - drop)) * valid  # (b_blk, J)
    s = jnp.sum(h.astype(jnp.float32) * eta[..., None], axis=1)  # (b_blk, d_blk)
    if agg == "mean":
        s = s / jnp.maximum(J_i, 1.0)
    out_ref[...] = s.astype(out_ref.dtype)


def sed_pool(h, seg_valid, fresh_mask, drop_mask, *, keep_prob: float,
             num_sampled: int, agg: str = "mean", b_blk: int = DEFAULT_B_BLK,
             d_blk: int = DEFAULT_D_BLK, interpret: bool = False):
    """h: (B, J, d); masks: (B, J) -> (B, d) pooled graph embedding."""
    B, J, d = h.shape
    b_blk = min(b_blk, B)
    d_blk = min(d_blk, d)
    pad_b = (-B) % b_blk
    pad_d = (-d) % d_blk
    if pad_b:
        h = jnp.pad(h, ((0, pad_b), (0, 0), (0, 0)))
        seg_valid = jnp.pad(seg_valid, ((0, pad_b), (0, 0)))
        fresh_mask = jnp.pad(fresh_mask, ((0, pad_b), (0, 0)))
        drop_mask = jnp.pad(drop_mask, ((0, pad_b), (0, 0)))
    if pad_d:
        h = jnp.pad(h, ((0, 0), (0, 0), (0, pad_d)))
    grid = ((B + pad_b) // b_blk, (d + pad_d) // d_blk)
    out = pl.pallas_call(
        functools.partial(_sed_pool_kernel, keep_prob=keep_prob,
                          num_sampled=num_sampled, agg=agg),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b_blk, J, d_blk), lambda bb, db: (bb, 0, db)),
            pl.BlockSpec((b_blk, J), lambda bb, db: (bb, 0)),
            pl.BlockSpec((b_blk, J), lambda bb, db: (bb, 0)),
            pl.BlockSpec((b_blk, J), lambda bb, db: (bb, 0)),
        ],
        out_specs=pl.BlockSpec((b_blk, d_blk), lambda bb, db: (bb, db)),
        out_shape=jax.ShapeDtypeStruct((B + pad_b, d + pad_d), h.dtype),
        interpret=interpret,
    )(h, seg_valid, fresh_mask, drop_mask)
    return out[:B, :d]
