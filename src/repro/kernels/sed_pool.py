"""Pallas TPU kernel: fused Stale-Embedding-Dropout + segment pooling.

The GST aggregation h = ⊕_j η_j h_j (Eq. 1) is small compute but, executed
naively, makes four HBM passes over the (B, J, d) segment-embedding tensor
(η build, mask, weighted sum, normalize).  This kernel fuses the whole thing
into one pass: the η weights are computed in-register from the three masks
and keep-prob, and the J-reduction happens in VMEM.

Grid: (batch blocks, feature blocks); J (≤ J_max, small) is unrolled inside
the kernel body as part of the block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import sed_eta


DEFAULT_B_BLK = 8
DEFAULT_D_BLK = 128


def _sed_pool_kernel(h_ref, valid_ref, fresh_ref, drop_ref, out_ref, *,
                     keep_prob: float, num_sampled: int, agg: str):
    h = h_ref[...]                           # (b_blk, J, d_blk)
    # η built in-register from the three (b_blk, J) mask blocks — same shared
    # formula as the oracle and the custom VJP (ref.sed_eta)
    eta, J_i = sed_eta(valid_ref[...], fresh_ref[...], drop_ref[...],
                       keep_prob, num_sampled)
    s = jnp.sum(h.astype(jnp.float32) * eta[..., None], axis=1)  # (b_blk, d_blk)
    if agg == "mean":
        s = s / jnp.maximum(J_i, 1.0)
    out_ref[...] = s.astype(out_ref.dtype)


def _sed_pool_aged_kernel(h_ref, valid_ref, fresh_ref, drop_ref, age_ref,
                          out_ref, *, keep_prob: float, num_sampled: int,
                          agg: str, decay: float):
    h = h_ref[...]                           # (b_blk, J, d_blk)
    # age-weighted η: the stale branch carries the extra exp(-λ·age)
    # factor, still through the shared ref.sed_eta formula
    eta, J_i = sed_eta(valid_ref[...], fresh_ref[...], drop_ref[...],
                       keep_prob, num_sampled, age_ref[...], decay)
    s = jnp.sum(h.astype(jnp.float32) * eta[..., None], axis=1)  # (b_blk, d_blk)
    if agg == "mean":
        s = s / jnp.maximum(J_i, 1.0)
    out_ref[...] = s.astype(out_ref.dtype)


def _sed_pool_raw(h, seg_valid, fresh_mask, drop_mask, keep_prob: float,
                  num_sampled: int, agg: str, b_blk: int, d_blk: int,
                  interpret: bool):
    B, J, d = h.shape
    b_blk = min(b_blk, B)
    d_blk = min(d_blk, d)
    pad_b = (-B) % b_blk
    pad_d = (-d) % d_blk
    if pad_b:
        h = jnp.pad(h, ((0, pad_b), (0, 0), (0, 0)))
        seg_valid = jnp.pad(seg_valid, ((0, pad_b), (0, 0)))
        fresh_mask = jnp.pad(fresh_mask, ((0, pad_b), (0, 0)))
        drop_mask = jnp.pad(drop_mask, ((0, pad_b), (0, 0)))
    if pad_d:
        h = jnp.pad(h, ((0, 0), (0, 0), (0, pad_d)))
    grid = ((B + pad_b) // b_blk, (d + pad_d) // d_blk)
    out = pl.pallas_call(
        functools.partial(_sed_pool_kernel, keep_prob=keep_prob,
                          num_sampled=num_sampled, agg=agg),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b_blk, J, d_blk), lambda bb, db: (bb, 0, db)),
            pl.BlockSpec((b_blk, J), lambda bb, db: (bb, 0)),
            pl.BlockSpec((b_blk, J), lambda bb, db: (bb, 0)),
            pl.BlockSpec((b_blk, J), lambda bb, db: (bb, 0)),
        ],
        out_specs=pl.BlockSpec((b_blk, d_blk), lambda bb, db: (bb, db)),
        out_shape=jax.ShapeDtypeStruct((B + pad_b, d + pad_d), h.dtype),
        interpret=interpret,
    )(h, seg_valid, fresh_mask, drop_mask)
    return out[:B, :d]


# ``pallas_call`` has no transpose rule, so reverse-mode AD through the fused
# pooling needs an explicit VJP.  ∂(Σ_j η_j h_j)/∂h_j = η_j (broadcast over d);
# the masks are sampling artifacts with no useful cotangent (they come from
# top_k / comparisons, where grads vanish anyway) and get zeros.
@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9))
def _sed_pool(h, seg_valid, fresh_mask, drop_mask, keep_prob, num_sampled,
              agg, b_blk, d_blk, interpret):
    return _sed_pool_raw(h, seg_valid, fresh_mask, drop_mask, keep_prob,
                         num_sampled, agg, b_blk, d_blk, interpret)


def _sed_fwd(h, seg_valid, fresh_mask, drop_mask, keep_prob, num_sampled,
             agg, b_blk, d_blk, interpret):
    out = _sed_pool_raw(h, seg_valid, fresh_mask, drop_mask, keep_prob,
                        num_sampled, agg, b_blk, d_blk, interpret)
    dtype_token = jnp.zeros((0,), h.dtype)
    return out, (seg_valid, fresh_mask, drop_mask, dtype_token)


def _sed_bwd(keep_prob, num_sampled, agg, b_blk, d_blk, interpret, res, g):
    seg_valid, fresh_mask, drop_mask, dtype_token = res
    eta, J_i = sed_eta(seg_valid, fresh_mask, drop_mask, keep_prob,
                       num_sampled)
    g = g.astype(jnp.float32)
    if agg == "mean":
        g = g / jnp.maximum(J_i, 1.0)
    dh = (g[:, None, :] * eta[..., None]).astype(dtype_token.dtype)
    return (dh, jnp.zeros_like(seg_valid), jnp.zeros_like(fresh_mask),
            jnp.zeros_like(drop_mask))


_sed_pool.defvjp(_sed_fwd, _sed_bwd)


def _sed_pool_aged_raw(h, seg_valid, fresh_mask, drop_mask, ages,
                       keep_prob: float, num_sampled: int, agg: str,
                       decay: float, b_blk: int, d_blk: int,
                       interpret: bool):
    B, J, d = h.shape
    b_blk = min(b_blk, B)
    d_blk = min(d_blk, d)
    pad_b = (-B) % b_blk
    pad_d = (-d) % d_blk
    ages = ages.astype(jnp.float32)
    if pad_b:
        h = jnp.pad(h, ((0, pad_b), (0, 0), (0, 0)))
        seg_valid = jnp.pad(seg_valid, ((0, pad_b), (0, 0)))
        fresh_mask = jnp.pad(fresh_mask, ((0, pad_b), (0, 0)))
        drop_mask = jnp.pad(drop_mask, ((0, pad_b), (0, 0)))
        ages = jnp.pad(ages, ((0, pad_b), (0, 0)))
    if pad_d:
        h = jnp.pad(h, ((0, 0), (0, 0), (0, pad_d)))
    grid = ((B + pad_b) // b_blk, (d + pad_d) // d_blk)
    out = pl.pallas_call(
        functools.partial(_sed_pool_aged_kernel, keep_prob=keep_prob,
                          num_sampled=num_sampled, agg=agg, decay=decay),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b_blk, J, d_blk), lambda bb, db: (bb, 0, db)),
            pl.BlockSpec((b_blk, J), lambda bb, db: (bb, 0)),
            pl.BlockSpec((b_blk, J), lambda bb, db: (bb, 0)),
            pl.BlockSpec((b_blk, J), lambda bb, db: (bb, 0)),
            pl.BlockSpec((b_blk, J), lambda bb, db: (bb, 0)),
        ],
        out_specs=pl.BlockSpec((b_blk, d_blk), lambda bb, db: (bb, db)),
        out_shape=jax.ShapeDtypeStruct((B + pad_b, d + pad_d), h.dtype),
        interpret=interpret,
    )(h, seg_valid, fresh_mask, drop_mask, ages)
    return out[:B, :d]


# Separate custom_vjp for the aged path: the λ=0 path above keeps its
# historical jaxpr untouched (bit-exactness by construction), and ages —
# like the masks — are sampling/bookkeeping artifacts with zero cotangent.
@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9, 10, 11))
def _sed_pool_aged(h, seg_valid, fresh_mask, drop_mask, ages, keep_prob,
                   num_sampled, agg, decay, b_blk, d_blk, interpret):
    return _sed_pool_aged_raw(h, seg_valid, fresh_mask, drop_mask, ages,
                              keep_prob, num_sampled, agg, decay, b_blk,
                              d_blk, interpret)


def _sed_aged_fwd(h, seg_valid, fresh_mask, drop_mask, ages, keep_prob,
                  num_sampled, agg, decay, b_blk, d_blk, interpret):
    out = _sed_pool_aged_raw(h, seg_valid, fresh_mask, drop_mask, ages,
                             keep_prob, num_sampled, agg, decay, b_blk,
                             d_blk, interpret)
    dtype_token = jnp.zeros((0,), h.dtype)
    return out, (seg_valid, fresh_mask, drop_mask, ages, dtype_token)


def _sed_aged_bwd(keep_prob, num_sampled, agg, decay, b_blk, d_blk,
                  interpret, res, g):
    seg_valid, fresh_mask, drop_mask, ages, dtype_token = res
    eta, J_i = sed_eta(seg_valid, fresh_mask, drop_mask, keep_prob,
                       num_sampled, ages, decay)
    g = g.astype(jnp.float32)
    if agg == "mean":
        g = g / jnp.maximum(J_i, 1.0)
    dh = (g[:, None, :] * eta[..., None]).astype(dtype_token.dtype)
    return (dh, jnp.zeros_like(seg_valid), jnp.zeros_like(fresh_mask),
            jnp.zeros_like(drop_mask), jnp.zeros(ages.shape, jnp.float32))


_sed_pool_aged.defvjp(_sed_aged_fwd, _sed_aged_bwd)


def sed_pool(h, seg_valid, fresh_mask, drop_mask, *, keep_prob: float,
             num_sampled: int, agg: str = "mean", ages=None,
             decay: float = 0.0, b_blk: int = DEFAULT_B_BLK,
             d_blk: int = DEFAULT_D_BLK, interpret: bool = False):
    """h: (B, J, d); masks: (B, J) -> (B, d) pooled graph embedding.

    One fused pallas_call; differentiable wrt h (custom VJP — the mask
    cotangents are zero, matching the reference path where gradients die at
    the top_k / comparison that produced them).

    ``ages``/``decay``: optional (B, J) per-segment age-in-steps and λ for
    the staleness-decayed η (ref.sed_eta).  λ=0 (or no ages) dispatches to
    the historical 4-operand kernel — identical jaxpr, bit-exact.
    """
    if ages is not None and decay > 0.0:
        return _sed_pool_aged(h, seg_valid, fresh_mask, drop_mask, ages,
                              keep_prob, num_sampled, agg, decay, b_blk,
                              d_blk, interpret)
    return _sed_pool(h, seg_valid, fresh_mask, drop_mask, keep_prob,
                     num_sampled, agg, b_blk, d_blk, interpret)
