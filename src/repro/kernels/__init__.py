"""Pallas TPU kernels for the framework's compute hot spots.

segment_spmm  -- GNN neighbor aggregation as one-hot MXU matmuls; the batched
                 variant runs every segment of a GST batch in ONE launch
sed_pool      -- fused SED (Eq. 1) + segment pooling, custom-VJP differentiable
swa_attention -- blockwise sliding-window flash attention (long_500k prefill)

ops.py holds the jit'd wrappers (interpret=True on CPU); ref.py the oracles.
"""
from repro.kernels.ops import (
    batched_neighbor_sum,
    count_pallas_calls,
    neighbor_aggregate,
    sed_aggregate,
    sliding_window_attention,
)

__all__ = [
    "batched_neighbor_sum", "count_pallas_calls", "neighbor_aggregate",
    "sed_aggregate", "sliding_window_attention",
]
