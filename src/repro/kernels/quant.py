"""Pallas TPU kernels: pack/unpack embedding rows for the compressed wire
format (dist/exchange.py ``--payload-dtype``).

Historical embeddings are approximate by design (stale snapshots the GST
paper already perturbs via SED), so the payloads that move them — exchange
hops, eviction write-backs — tolerate reduced precision (FreshGNN,
PAPERS.md).  Two row formats over a float32 source row of N elements:

  ``bf16``  round-to-nearest on the read path, STOCHASTIC rounding on the
            write path: 16 uniform random bits are added below the bf16
            mantissa boundary before truncation, so E[packed] == exact and
            repeated write round-trips stay unbiased.  Values already
            representable in bf16 (zero low mantissa bits — including
            ±0.0) are preserved exactly: the added bits can never carry.

  ``int8``  symmetric per-row scale s = max|row| / 127 (float32, rides the
            wire next to the values; 0 for all-zero rows so zero rows
            decode to exact zeros), values stochastically or RNE-rounded
            to [-127, 127].  Integer-valued rows whose scale is exactly 1
            round-trip exactly.

Both follow the segment_spmm / sed_pool pattern: a jnp reference path
(``quantize_rows_ref`` / ``dequantize_rows_ref``) is the parity oracle for
the Pallas kernels (tests/test_quant.py), the kernels run in interpret
mode off-TPU, and ``kernels/ops.py`` owns the jit'd public wrappers.
Randomness is an EXPLICIT uint32 input (callers derive it from the train
step with jax.random.bits) — no in-kernel PRNG state, so pallas and
reference paths agree bit-for-bit given the same bits.

Quantization is row-wise over the LEADING axis: x (R, ...) packs to
values (R, ...) in the target dtype plus, for int8, one f32 scale per
leading row.  Nothing here is differentiated — the exchange write path
packs ``stop_gradient``-ed embeddings and lookups enter the loss as
constants — so the kernels carry no custom VJP.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

PAYLOAD_DTYPES = ("f32", "bf16", "int8")

# row-block sizes: int8 output tiling wants 32 sublanes, the lane dim is
# padded to 128 (pallas_guide.md dtype min tiles)
ROW_BLK = 32
LANE = 128

# masks are numpy scalars: they lower to jaxpr literals, so kernel bodies
# don't capture array constants (pallas_call rejects captured ShapedArrays)
_MANT_MASK = np.uint32(0xFFFF)         # bits below the bf16 boundary
_BF16_KEEP = np.uint32(0xFFFF0000)


# ---------------------------------------------------------------------------
# shared rounding math (kernel bodies AND the jnp reference call these)
# ---------------------------------------------------------------------------


def _bf16_stochastic(x, bits):
    """f32 -> bf16 by adding 16 uniform bits below the mantissa boundary
    and truncating.  Unbiased in magnitude; exact when the low bits are
    already zero (bf16-representable values, ±0.0 included)."""
    u = jax.lax.bitcast_convert_type(x, jnp.uint32)
    u = (u + (bits & _MANT_MASK)) & _BF16_KEEP
    return jax.lax.bitcast_convert_type(u, jnp.float32).astype(jnp.bfloat16)


def _uniform01(bits):
    """uint32 -> uniform [0, 1) f32 from the high 24 bits."""
    return (bits >> 8).astype(jnp.float32) * (1.0 / (1 << 24))


def _int8_quantize(x, bits):
    """x (r, n) f32 -> (values int8, scale (r, 1) f32).  ``bits`` None =
    round-to-nearest-even (read path), else stochastic (write path)."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)        # (r, 1)
    scale = amax * (1.0 / 127.0)
    v = x / jnp.where(scale > 0, scale, 1.0)                  # [-127, 127]
    if bits is None:
        q = jnp.round(v)
    else:
        lo = jnp.floor(v)
        q = lo + (_uniform01(bits) < (v - lo)).astype(jnp.float32)
    q = jnp.clip(q, -127.0, 127.0).astype(jnp.int8)
    return q, scale


# ---------------------------------------------------------------------------
# jnp reference (the parity oracle; also the path the exchange runs by
# default — XLA fuses the elementwise math into the surrounding step)
# ---------------------------------------------------------------------------


def _rows(x) -> Tuple[jnp.ndarray, Tuple[int, ...]]:
    shape = x.shape
    return x.reshape(shape[0], -1), shape


def quantize_rows_ref(x, dtype: str, rand_bits=None):
    """x (R, ...) f32 -> wire parts: (values,) for bf16, (values, scale)
    for int8 (scale (R,) f32).  ``rand_bits`` uint32 of x's shape turns on
    stochastic rounding (the write path); None rounds to nearest."""
    x2, shape = _rows(x)
    if dtype == "bf16":
        if rand_bits is None:
            return (x2.astype(jnp.bfloat16).reshape(shape),)
        return (_bf16_stochastic(
            x2, rand_bits.reshape(x2.shape)).reshape(shape),)
    if dtype == "int8":
        bits = None if rand_bits is None else rand_bits.reshape(x2.shape)
        q, scale = _int8_quantize(x2, bits)
        return q.reshape(shape), scale[:, 0]
    raise ValueError(f"quantize dtype {dtype!r} not in ('bf16', 'int8')")


def dequantize_rows_ref(parts, dtype: str):
    """Inverse of quantize_rows_ref: wire parts -> f32 (R, ...)."""
    if dtype == "bf16":
        (v,) = parts
        return v.astype(jnp.float32)
    if dtype == "int8":
        v, scale = parts
        return v.astype(jnp.float32) * scale.reshape(
            (-1,) + (1,) * (v.ndim - 1))
    raise ValueError(f"dequantize dtype {dtype!r} not in ('bf16', 'int8')")


# ---------------------------------------------------------------------------
# pallas kernels (grid over row blocks; each block sees whole rows so the
# per-row amax reduction stays in VMEM)
# ---------------------------------------------------------------------------


def _pack_bf16_kernel(x_ref, bits_ref, out_ref):
    out_ref[...] = _bf16_stochastic(x_ref[...], bits_ref[...])


def _pack_bf16_det_kernel(x_ref, out_ref):
    out_ref[...] = x_ref[...].astype(jnp.bfloat16)


def _pack_int8_kernel(x_ref, bits_ref, v_ref, s_ref):
    q, scale = _int8_quantize(x_ref[...], bits_ref[...])
    v_ref[...] = q
    s_ref[...] = scale


def _pack_int8_det_kernel(x_ref, v_ref, s_ref):
    q, scale = _int8_quantize(x_ref[...], None)
    v_ref[...] = q
    s_ref[...] = scale


def _unpack_bf16_kernel(v_ref, out_ref):
    out_ref[...] = v_ref[...].astype(jnp.float32)


def _unpack_int8_kernel(v_ref, s_ref, out_ref):
    out_ref[...] = v_ref[...].astype(jnp.float32) * s_ref[...]


def _pad2(x2, r_blk):
    R, N = x2.shape
    pad_r, pad_n = (-R) % r_blk, (-N) % LANE
    if pad_r or pad_n:
        x2 = jnp.pad(x2, ((0, pad_r), (0, pad_n)))
    return x2, R + pad_r, N + pad_n


def quantize_rows(x, dtype: str, rand_bits=None, *,
                  use_pallas: bool = False, interpret: bool = True,
                  r_blk: int = ROW_BLK):
    """Pack f32 rows into the compressed wire format (see module docstring
    for the formats).  Returns the wire-parts tuple of
    ``quantize_rows_ref``; ``use_pallas`` routes through the Pallas pack
    kernel (interpret mode off-TPU) instead of the fused-jnp reference."""
    if not use_pallas:
        return quantize_rows_ref(x, dtype, rand_bits)
    x2, shape = _rows(x)
    R, N = x2.shape
    r_blk = min(r_blk, max(R, 1))
    x2, Rp, Np = _pad2(x2, r_blk)
    grid = (Rp // r_blk,)
    row_spec = pl.BlockSpec((r_blk, Np), lambda rb: (rb, 0))
    bits = None
    if rand_bits is not None:
        bits, _, _ = _pad2(rand_bits.reshape(R, N), r_blk)
    if dtype == "bf16":
        out_shape = jax.ShapeDtypeStruct((Rp, Np), jnp.bfloat16)
        if bits is None:
            v = pl.pallas_call(_pack_bf16_det_kernel, grid=grid,
                               in_specs=[row_spec], out_specs=row_spec,
                               out_shape=out_shape, interpret=interpret)(x2)
        else:
            v = pl.pallas_call(_pack_bf16_kernel, grid=grid,
                               in_specs=[row_spec, row_spec],
                               out_specs=row_spec, out_shape=out_shape,
                               interpret=interpret)(x2, bits)
        return (v[:R, :N].reshape(shape),)
    if dtype == "int8":
        out_shapes = (jax.ShapeDtypeStruct((Rp, Np), jnp.int8),
                      jax.ShapeDtypeStruct((Rp, 1), jnp.float32))
        out_specs = (row_spec, pl.BlockSpec((r_blk, 1), lambda rb: (rb, 0)))
        if bits is None:
            v, s = pl.pallas_call(_pack_int8_det_kernel, grid=grid,
                                  in_specs=[row_spec], out_specs=out_specs,
                                  out_shape=out_shapes,
                                  interpret=interpret)(x2)
        else:
            v, s = pl.pallas_call(_pack_int8_kernel, grid=grid,
                                  in_specs=[row_spec, row_spec],
                                  out_specs=out_specs, out_shape=out_shapes,
                                  interpret=interpret)(x2, bits)
        return v[:R, :N].reshape(shape), s[:R, 0]
    raise ValueError(f"quantize dtype {dtype!r} not in ('bf16', 'int8')")


def dequantize_rows(parts, dtype: str, *, use_pallas: bool = False,
                    interpret: bool = True, r_blk: int = ROW_BLK):
    """Unpack wire parts back to f32 rows (inverse of ``quantize_rows``)."""
    if not use_pallas:
        return dequantize_rows_ref(parts, dtype)
    v = parts[0]
    v2, shape = _rows(v)
    R, N = v2.shape
    r_blk = min(r_blk, max(R, 1))
    v2, Rp, Np = _pad2(v2, r_blk)
    grid = (Rp // r_blk,)
    row_spec = pl.BlockSpec((r_blk, Np), lambda rb: (rb, 0))
    out_shape = jax.ShapeDtypeStruct((Rp, Np), jnp.float32)
    if dtype == "bf16":
        out = pl.pallas_call(_unpack_bf16_kernel, grid=grid,
                             in_specs=[row_spec], out_specs=row_spec,
                             out_shape=out_shape, interpret=interpret)(v2)
    elif dtype == "int8":
        s = jnp.pad(parts[1].reshape(R, 1), ((0, Rp - R), (0, 0)))
        out = pl.pallas_call(
            _unpack_int8_kernel, grid=grid,
            in_specs=[row_spec, pl.BlockSpec((r_blk, 1), lambda rb: (rb, 0))],
            out_specs=row_spec, out_shape=out_shape,
            interpret=interpret)(v2, s)
    else:
        raise ValueError(f"dequantize dtype {dtype!r} not in "
                         "('bf16', 'int8')")
    return out[:R, :N].reshape(shape)
