"""Jit'd public wrappers over the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on TPU —
the kernels are written for the TPU target and validated in interpret mode
against the pure-jnp oracles in ref.py.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.quant import dequantize_rows as _dequantize_rows
from repro.kernels.quant import quantize_rows as _quantize_rows
from repro.kernels.sed_pool import sed_pool as _sed_pool
from repro.kernels.segment_spmm import segment_spmm as _segment_spmm
from repro.kernels.segment_spmm import segment_spmm_batched as _segment_spmm_batched
from repro.kernels.swa_attention import swa_attention as _swa_attention


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# shape-padding helpers (shared by serve/cache.py, dist/table.py, store/)
#
# Scatter/gather row sets vary per batch; padding their length to the next
# power of two keeps the jitted-shape set O(log capacity) instead of one
# compile per distinct row count.  Padding repeats the LAST entry, so a
# padded scatter writes the same (row, value) pair twice — a deterministic
# no-op — and a padded gather reads rows the caller then ignores.
# ---------------------------------------------------------------------------


def next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


def prev_pow2(n: int) -> int:
    """Largest power of two <= n (n >= 1) — chunking a pow2-padded row set
    by a non-pow2 capacity without minting new jitted shapes."""
    return 1 << (n.bit_length() - 1)


def pad_rows_pow2(rows: Sequence[int], *alongside: Sequence,
                  ) -> Tuple[np.ndarray, ...]:
    """Pad ``rows`` (and any parallel index lists) to the next power of two
    by repeating the last entry.  Returns int-typed numpy arrays ready for a
    padded scatter/gather; ``rows`` must be non-empty."""
    n = next_pow2(len(rows))
    out = []
    for seq in (rows,) + alongside:
        seq = list(seq)
        out.append(np.asarray(seq + [seq[-1]] * (n - len(seq)), np.int32))
    return tuple(out)


def pad_leading(x, target: int):
    """Zero-pad the leading axis of ``x`` to ``target`` rows (no-op when
    already there) — the block-row padding shared by the sharded table and
    the tiered store's host tier."""
    n = x.shape[0]
    if n == target:
        return x
    if isinstance(x, np.ndarray):
        pad = np.zeros((target - n,) + x.shape[1:], x.dtype)
        return np.concatenate([x, pad], axis=0)
    return jnp.concatenate(
        [x, jnp.zeros((target - n,) + x.shape[1:], x.dtype)], axis=0)


@partial(jax.jit, static_argnames=("use_pallas",))
def batched_neighbor_sum(h, src, dst, w, *, use_pallas: bool = True):
    """Batched weighted scatter-add over N segments in ONE kernel launch.

    h: (N, m, d); src/dst/w: (N, e).  The GNN hot path: every message-passing
    layer of graphs/gnn.py::_encode_batched makes exactly one call here,
    and this wrapper owns the interpret-on-CPU decision.
    """
    if use_pallas:
        return _segment_spmm_batched(h, src, dst, w,
                                     interpret=_default_interpret())
    return ref.segment_spmm_batched_ref(h, src, dst, w)


def iter_jaxpr_eqns(jaxpr):
    """Depth-first iterator over every eqn of ``jaxpr``, recursing into
    EVERY Jaxpr-valued eqn param — pjit, scan/while bodies, custom-VJP
    wrappers AND ``shard_map``.  Shared by ``count_pallas_calls`` (kernel
    launch contracts) and ``dist/exchange.py::measured_exchange_bytes``
    (collective-traffic accounting against the analytic bytes models)."""
    try:  # jax >= 0.5 moved the jaxpr types; 0.4.x only has jax.core
        from jax.extend import core as jcore
    except ImportError:  # pragma: no cover
        from jax import core as jcore

    def subjaxprs(params):
        for v in params.values():
            vs = v if isinstance(v, (tuple, list)) else (v,)
            for u in vs:
                if isinstance(u, jcore.ClosedJaxpr):
                    yield u.jaxpr
                elif isinstance(u, jcore.Jaxpr):
                    yield u

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            yield eqn
            for sub in subjaxprs(eqn.params):
                yield from walk(sub)

    yield from walk(jaxpr)


def count_pallas_calls(fn, *args, **kwargs) -> int:
    """Number of ``pallas_call`` eqns in fn's jaxpr (recursing into sub-jaxprs).

    The fused-path contract (one batched kernel launch per message-passing
    layer rather than one per vmapped segment) is asserted with this in
    tests/test_fused_path.py and recorded by benchmarks/bench_step.py.

    The recursion (iter_jaxpr_eqns) sees through pjit, scan/while bodies,
    custom-VJP wrappers AND ``shard_map`` — the dist/ subsystem uses that
    to assert its per-shard step launches exactly the same batched kernels
    as the single-device step
    (tests/test_dist.py::test_dist_step_kernel_launch_contract).
    """
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return sum(1 for eqn in iter_jaxpr_eqns(closed.jaxpr)
               if eqn.primitive.name == "pallas_call")


def max_intermediate_bytes(fn, *args, **kwargs) -> int:
    """Size (bytes) of the largest intermediate buffer any eqn of fn's jaxpr
    produces (recursing into sub-jaxprs: scan/while bodies, pjit calls).

    The serving engine's constant-memory contract — a lax.scan over segment
    chunks allocates one chunk's activations regardless of how many chunks
    the graph has — is asserted with this in tests/test_serve.py: the max
    live buffer must not grow with the chunk count, while the one-shot
    encoder's grows linearly with the segment count.
    """
    try:  # jax >= 0.5 moved the jaxpr types; 0.4.x only has jax.core
        from jax.extend import core as jcore
    except ImportError:  # pragma: no cover
        from jax import core as jcore

    def subjaxprs(params):
        for v in params.values():
            vs = v if isinstance(v, (tuple, list)) else (v,)
            for u in vs:
                if isinstance(u, jcore.ClosedJaxpr):
                    yield u.jaxpr
                elif isinstance(u, jcore.Jaxpr):
                    yield u

    def nbytes(aval) -> int:
        shape = getattr(aval, "shape", None)
        dtype = getattr(aval, "dtype", None)
        if shape is None or dtype is None:
            return 0
        return int(np.prod(shape, dtype=np.int64)) * dtype.itemsize

    def walk(jaxpr) -> int:
        m = 0
        for eqn in jaxpr.eqns:
            for v in eqn.outvars:
                m = max(m, nbytes(v.aval))
            for sub in subjaxprs(eqn.params):
                m = max(m, walk(sub))
        return m

    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return walk(closed.jaxpr)


@partial(jax.jit, static_argnames=("num_nodes", "use_pallas"))
def neighbor_aggregate(h, src, dst, edge_valid, *, num_nodes: int,
                       use_pallas: bool = True):
    """Masked neighbor mean (GNN message aggregation).

    Returns (mean (m, d), deg (m,)).  Sum runs on the MXU via segment_spmm;
    degree is a cheap O(e) reduction kept in jnp.
    """
    if use_pallas:
        s = _segment_spmm(h, src, dst, edge_valid, interpret=_default_interpret())
    else:
        s = ref.segment_spmm_ref(h, src, dst, edge_valid, num_nodes)
    deg = jax.ops.segment_sum(edge_valid, dst, num_segments=num_nodes)
    return s / jnp.maximum(deg, 1.0)[:, None], deg


@partial(jax.jit, static_argnames=("keep_prob", "num_sampled", "agg",
                                   "decay", "use_pallas"))
def sed_aggregate(h, seg_valid, fresh_mask, drop_mask, ages=None, *,
                  keep_prob: float, num_sampled: int, agg: str = "mean",
                  decay: float = 0.0, use_pallas: bool = True):
    """Fused Eq.-1 η-weighting + ⊕ pooling over segments.

    ``ages``/``decay``: optional (B, J) age-in-steps + λ for the
    staleness-decayed stale branch (ref.sed_eta); λ=0 keeps the exact
    historical 4-operand dispatch."""
    if use_pallas:
        return _sed_pool(h, seg_valid, fresh_mask, drop_mask,
                         keep_prob=keep_prob, num_sampled=num_sampled, agg=agg,
                         ages=ages, decay=decay,
                         interpret=_default_interpret())
    return ref.sed_pool_ref(h, seg_valid, fresh_mask, drop_mask, keep_prob,
                            num_sampled, agg, ages, decay)


@partial(jax.jit, static_argnames=("dtype", "use_pallas"))
def quantize_payload(x, rand_bits=None, *, dtype: str,
                     use_pallas: bool = True):
    """Pack f32 rows into the compressed exchange wire format (bf16, or
    int8 + per-leading-row f32 scale).  ``rand_bits`` (uint32, x.shape)
    turns on stochastic rounding — the write path; None rounds to nearest
    (the read path, deterministic).  Returns the wire-parts tuple."""
    return _quantize_rows(x, dtype, rand_bits, use_pallas=use_pallas,
                          interpret=_default_interpret())


@partial(jax.jit, static_argnames=("dtype", "use_pallas"))
def dequantize_payload(parts, *, dtype: str, use_pallas: bool = True):
    """Unpack compressed wire parts back to f32 rows."""
    return _dequantize_rows(tuple(parts), dtype, use_pallas=use_pallas,
                            interpret=_default_interpret())


@partial(jax.jit, static_argnames=("window", "use_pallas"))
def sliding_window_attention(q, k, v, *, window: int, use_pallas: bool = True):
    """Causal sliding-window flash attention (sub-quadratic prefill)."""
    if use_pallas:
        return _swa_attention(q, k, v, window=window,
                              interpret=_default_interpret())
    return ref.swa_attention_ref(q, k, v, window)
