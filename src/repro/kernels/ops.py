"""Jit'd public wrappers over the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on TPU —
the kernels are written for the TPU target and validated in interpret mode
against the pure-jnp oracles in ref.py.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.sed_pool import sed_pool as _sed_pool
from repro.kernels.segment_spmm import segment_spmm as _segment_spmm
from repro.kernels.swa_attention import swa_attention as _swa_attention


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("num_nodes", "use_pallas"))
def neighbor_aggregate(h, src, dst, edge_valid, *, num_nodes: int,
                       use_pallas: bool = True):
    """Masked neighbor mean (GNN message aggregation).

    Returns (mean (m, d), deg (m,)).  Sum runs on the MXU via segment_spmm;
    degree is a cheap O(e) reduction kept in jnp.
    """
    if use_pallas:
        s = _segment_spmm(h, src, dst, edge_valid, interpret=_default_interpret())
    else:
        s = ref.segment_spmm_ref(h, src, dst, edge_valid, num_nodes)
    deg = jax.ops.segment_sum(edge_valid, dst, num_segments=num_nodes)
    return s / jnp.maximum(deg, 1.0)[:, None], deg


@partial(jax.jit, static_argnames=("keep_prob", "num_sampled", "agg", "use_pallas"))
def sed_aggregate(h, seg_valid, fresh_mask, drop_mask, *, keep_prob: float,
                  num_sampled: int, agg: str = "mean", use_pallas: bool = True):
    """Fused Eq.-1 η-weighting + ⊕ pooling over segments."""
    if use_pallas:
        return _sed_pool(h, seg_valid, fresh_mask, drop_mask,
                         keep_prob=keep_prob, num_sampled=num_sampled, agg=agg,
                         interpret=_default_interpret())
    return ref.sed_pool_ref(h, seg_valid, fresh_mask, drop_mask, keep_prob,
                            num_sampled, agg)


@partial(jax.jit, static_argnames=("window", "use_pallas"))
def sliding_window_attention(q, k, v, *, window: int, use_pallas: bool = True):
    """Causal sliding-window flash attention (sub-quadratic prefill)."""
    if use_pallas:
        return _swa_attention(q, k, v, window=window,
                              interpret=_default_interpret())
    return ref.swa_attention_ref(q, k, v, window)
