"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_spmm_ref(h, src, dst, w, num_nodes: int):
    """Weighted neighbor scatter-add:  out[v] = Σ_{e: dst_e = v} w_e · h[src_e].

    h: (m, d); src/dst: (e,) int32; w: (e,) float — padding edges carry w=0.
    """
    msg = h[src] * w[:, None]
    return jax.ops.segment_sum(msg, dst, num_segments=num_nodes)


def segment_spmm_batched_ref(h, src, dst, w):
    """Batched oracle: out[n, v] = Σ_{e: dst[n,e]=v} w[n,e] · h[n, src[n,e]].

    h: (N, m, d); src/dst: (N, e) int32; w: (N, e) float.
    """
    m = h.shape[1]
    return jax.vmap(lambda hh, ss, dd, ww: segment_spmm_ref(hh, ss, dd, ww, m))(
        h, src, dst, w)


def sed_eta(seg_valid, fresh_mask, drop_mask, keep_prob: float,
            num_sampled: int, ages=None, decay: float = 0.0):
    """The Eq.-1 η weights from the three masks: (eta (B, J), J_i (B, 1)).

    Single source of truth shared by the sed_pool oracle AND the kernel's
    custom VJP (sed_pool.py) so forward reference and backward cannot drift;
    the in-kernel computation mirrors this formula in-register.

    ``ages``/``decay``: optional staleness decay (VISAGNN-style).  When a
    per-segment age-in-steps array (B, J) and λ = decay > 0 are given, the
    STALE branch of Eq. 1 is continuously down-weighted by exp(-λ·age) on
    top of the SED drop draw — fresh segments are untouched (their age is
    0 by definition).  The branch is a static Python ``if`` so λ=0 (the
    default) traces the exact historical jaxpr, keeping the bit-exactness
    contract by construction.
    """
    valid = seg_valid.astype(jnp.float32)
    fresh = fresh_mask.astype(jnp.float32)
    drop = drop_mask.astype(jnp.float32)
    J_i = jnp.sum(valid, axis=-1, keepdims=True)
    eta_fresh = keep_prob + (1.0 - keep_prob) * J_i / float(num_sampled)
    stale = valid * (1.0 - fresh)
    stale_term = stale * (1.0 - drop)
    if ages is not None and decay > 0.0:
        stale_term = stale_term * jnp.exp(-decay * ages.astype(jnp.float32))
    eta = (fresh * eta_fresh + stale_term) * valid
    return eta, J_i


def sed_pool_ref(h, seg_valid, fresh_mask, drop_mask, keep_prob: float,
                 num_sampled: int, agg: str = "mean", ages=None,
                 decay: float = 0.0):
    """Fused SED η-weighting (Eq. 1) + segment aggregation ⊕.

    h: (B, J, d); masks: (B, J).  Matches core.segment.sed_weights +
    core.segment.aggregate composed (given the same drop draw).
    ``ages``/``decay`` add the optional staleness decay (see ``sed_eta``).
    """
    eta, J_i = sed_eta(seg_valid, fresh_mask, drop_mask, keep_prob,
                       num_sampled, ages, decay)
    s = jnp.sum(h * eta[..., None].astype(h.dtype), axis=1)
    if agg == "sum":
        return s
    return s / jnp.maximum(J_i, 1.0).astype(s.dtype)


def swa_attention_ref(q, k, v, window: int):
    """Causal sliding-window attention oracle.

    q/k/v: (B, S, H, D); key j visible to query i iff  i-window < j <= i.
    """
    import math
    B, S, H, D = q.shape
    scale = 1.0 / math.sqrt(D)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = (j <= i) & (j > i - window)
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)
