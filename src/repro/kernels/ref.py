"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_spmm_ref(h, src, dst, w, num_nodes: int):
    """Weighted neighbor scatter-add:  out[v] = Σ_{e: dst_e = v} w_e · h[src_e].

    h: (m, d); src/dst: (e,) int32; w: (e,) float — padding edges carry w=0.
    """
    msg = h[src] * w[:, None]
    return jax.ops.segment_sum(msg, dst, num_segments=num_nodes)


def sed_pool_ref(h, seg_valid, fresh_mask, drop_mask, keep_prob: float,
                 num_sampled: int, agg: str = "mean"):
    """Fused SED η-weighting (Eq. 1) + segment aggregation ⊕.

    h: (B, J, d); masks: (B, J).  Matches core.segment.sed_weights +
    core.segment.aggregate composed (given the same drop draw).
    """
    seg_valid = seg_valid.astype(jnp.float32)
    fresh = fresh_mask.astype(jnp.float32)
    drop = drop_mask.astype(jnp.float32)
    J_i = jnp.sum(seg_valid, axis=-1, keepdims=True)
    eta_fresh = keep_prob + (1.0 - keep_prob) * J_i / float(num_sampled)
    stale = seg_valid * (1.0 - fresh)
    eta = (fresh * eta_fresh + stale * (1.0 - drop)) * seg_valid
    s = jnp.sum(h * eta[..., None].astype(h.dtype), axis=1)
    if agg == "sum":
        return s
    return s / jnp.maximum(J_i, 1.0).astype(s.dtype)


def swa_attention_ref(q, k, v, window: int):
    """Causal sliding-window attention oracle.

    q/k/v: (B, S, H, D); key j visible to query i iff  i-window < j <= i.
    """
    import math
    B, S, H, D = q.shape
    scale = 1.0 / math.sqrt(D)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = (j <= i) & (j > i - window)
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)
