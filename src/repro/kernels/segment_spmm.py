"""Pallas TPU kernel: padded-edge-list neighbor aggregation (GNN hot spot).

GPU GNN frameworks implement scatter-add with atomics.  TPU adaptation
(DESIGN.md §4.3): express gather AND scatter as **one-hot matmuls** so the
whole message-passing reduction runs on the MXU with no dynamic memory:

    G[e, n] = 1{src_e = n}            (gather matrix,  built via iota compare)
    S[e, n] = 1{dst_e = n}            (scatter matrix)
    out     = Sᵀ @ (diag(w) @ (G @ h))

Grid: (edge blocks, feature blocks).  The node dimension m (= the paper's
bounded segment size m_GST) stays resident in VMEM — this is exactly why GST
bounds the segment size: the working set (m × d_blk block of h and out plus
an e_blk × m one-hot tile) fits VMEM for m ≤ 1024 at d_blk = 128.

Accumulation over edge blocks relies on TPU Pallas' sequential grid:
the out block is zero-initialised at the first edge block and accumulated
in-place afterwards.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_E_BLK = 256
DEFAULT_D_BLK = 128


def _spmm_kernel(src_ref, dst_ref, w_ref, h_ref, out_ref, *, m: int):
    eb = pl.program_id(0)
    src = src_ref[:, 0]                    # (e_blk,)
    dst = dst_ref[:, 0]
    w = w_ref[:, 0]                        # (e_blk,) float, 0 on padding
    h = h_ref[...]                         # (m, d_blk)
    e_blk = src.shape[0]
    node_ids = jax.lax.broadcasted_iota(jnp.int32, (e_blk, m), 1)
    gather = (src[:, None] == node_ids).astype(h.dtype)     # (e_blk, m)
    scatter = (dst[:, None] == node_ids).astype(h.dtype)    # (e_blk, m)
    msgs = jnp.dot(gather, h, preferred_element_type=jnp.float32)
    msgs = msgs * w[:, None].astype(jnp.float32)
    contrib = jnp.dot(scatter.T, msgs.astype(h.dtype),
                      preferred_element_type=jnp.float32)   # (m, d_blk)

    @pl.when(eb == 0)
    def _init():
        out_ref[...] = contrib.astype(out_ref.dtype)

    @pl.when(eb != 0)
    def _acc():
        out_ref[...] = out_ref[...] + contrib.astype(out_ref.dtype)


def segment_spmm(h, src, dst, w, *, e_blk: int = DEFAULT_E_BLK,
                 d_blk: int = DEFAULT_D_BLK, interpret: bool = False):
    """out[v] = Σ_{e: dst_e=v} w_e · h[src_e].   h: (m, d); src/dst/w: (e,)."""
    m, d = h.shape
    e = src.shape[0]
    e_blk = min(e_blk, e)
    d_blk = min(d_blk, d)
    # pad edge dim to a multiple of e_blk (w = 0 ⇒ no contribution)
    pad_e = (-e) % e_blk
    if pad_e:
        src = jnp.pad(src, (0, pad_e))
        dst = jnp.pad(dst, (0, pad_e))
        w = jnp.pad(w, (0, pad_e))
    pad_d = (-d) % d_blk
    if pad_d:
        h = jnp.pad(h, ((0, 0), (0, pad_d)))
    grid = ((e + pad_e) // e_blk, (d + pad_d) // d_blk)
    out = pl.pallas_call(
        functools.partial(_spmm_kernel, m=m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((e_blk, 1), lambda eb, db: (eb, 0)),
            pl.BlockSpec((e_blk, 1), lambda eb, db: (eb, 0)),
            pl.BlockSpec((e_blk, 1), lambda eb, db: (eb, 0)),
            pl.BlockSpec((m, d_blk), lambda eb, db: (0, db)),
        ],
        out_specs=pl.BlockSpec((m, d_blk), lambda eb, db: (0, db)),
        out_shape=jax.ShapeDtypeStruct((m, d + pad_d), jnp.float32),
        interpret=interpret,
    )(src[:, None], dst[:, None], w[:, None], h)
    return out[:, :d].astype(h.dtype)
