"""Pallas TPU kernel: padded-edge-list neighbor aggregation (GNN hot spot).

GPU GNN frameworks implement scatter-add with atomics.  TPU adaptation
(DESIGN.md §4.3): express gather AND scatter as **one-hot matmuls** so the
whole message-passing reduction runs on the MXU with no dynamic memory:

    G[e, n] = 1{src_e = n}            (gather matrix,  built via iota compare)
    S[e, n] = 1{dst_e = n}            (scatter matrix)
    out     = Sᵀ @ (diag(w) @ (G @ h))

The kernel is **batched**: all ``N = B·S`` padded segments of a GST batch run
in ONE ``pallas_call`` with a 3D grid ``(segment, feature block, edge block)``
— the per-segment edge windows are selected purely through BlockSpec index
maps on the padded ``(N, e)`` edge arrays, so there is a single kernel launch
per message-passing layer instead of one per vmapped segment.  The edge-block
axis is the reduction and sits innermost so consecutive grid steps revisit
the same output block (the TPU-sequential accumulation contract); the segment
and feature axes are embarrassingly parallel.

The node dimension m (= the paper's bounded segment size m_GST) stays
resident in VMEM — this is exactly why GST bounds the segment size: the
working set (m × d_blk block of h and out plus an e_blk × m one-hot tile)
fits VMEM for m ≤ 1024 at d_blk = 128.

Reverse-mode AD: ``pallas_call`` has no transpose rule, but the SpMM
transpose is itself an SpMM with src/dst swapped —

    out[n, v] = Σ_{e: dst_e = v} w_e · h[n, src_e]
    ∂L/∂h[n, u] = Σ_{e: src_e = u} w_e · g[n, dst_e]

so the backward pass is one more batched kernel launch (custom_vjp below).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_E_BLK = 256
DEFAULT_D_BLK = 128
# Segments per grid step.  The per-segment compute (two e_blk×m×d_blk dots)
# is small, so several segments share one grid step to amortize the per-step
# block-shuffling overhead (dominant in interpret mode on CPU; on TPU it
# lengthens the inner unrolled loop while keeping the VMEM working set
# n_blk·m·d_blk·2 — fine for m ≤ 1024 at the defaults).
DEFAULT_N_BLK = 8


def _spmm_batched_kernel(src_ref, dst_ref, w_ref, h_ref, out_ref, *,
                         m: int, n_blk: int):
    eb = pl.program_id(2)                  # edge-block = innermost (reduction)
    e_blk = src_ref.shape[1]
    node_ids = jax.lax.broadcasted_iota(jnp.int32, (e_blk, m), 1)
    for i in range(n_blk):                 # static unroll over the seg block
        src = src_ref[i, :]                # (e_blk,)
        dst = dst_ref[i, :]
        w = w_ref[i, :]                    # (e_blk,) float, 0 on padding
        h = h_ref[i]                       # (m, d_blk)
        gather = (src[:, None] == node_ids).astype(h.dtype)     # (e_blk, m)
        scatter = (dst[:, None] == node_ids).astype(h.dtype)    # (e_blk, m)
        msgs = jnp.dot(gather, h, preferred_element_type=jnp.float32)
        msgs = msgs * w[:, None].astype(jnp.float32)
        contrib = jnp.dot(scatter.T, msgs.astype(h.dtype),
                          preferred_element_type=jnp.float32)   # (m, d_blk)

        @pl.when(eb == 0)
        def _init(i=i, contrib=contrib):
            out_ref[i] = contrib.astype(out_ref.dtype)

        @pl.when(eb != 0)
        def _acc(i=i, contrib=contrib):
            out_ref[i] = out_ref[i] + contrib.astype(out_ref.dtype)


def _spmm_batched_raw(h, src, dst, w, e_blk: int, d_blk: int, n_blk,
                      interpret: bool):
    N, m, d = h.shape
    e = src.shape[1]
    e_blk = min(e_blk, e)
    d_blk = min(d_blk, d)
    if n_blk is None:
        if interpret:
            # interpret mode pays per-grid-step overhead, not VMEM: use big
            # segment blocks (capped — the kernel body unrolls n_blk times,
            # so unbounded blocks explode trace/compile time)
            n_blk = min(N, 32)
        else:
            # keep the n_blk·(h + out) working set within a VMEM budget
            budget = 2 * 1024 * 1024
            n_blk = max(1, min(DEFAULT_N_BLK, budget // (m * d_blk * 4 * 2)))
    n_blk = min(n_blk, N)
    # pad edge dim to a multiple of e_blk (w = 0 ⇒ no contribution)
    pad_e = (-e) % e_blk
    if pad_e:
        src = jnp.pad(src, ((0, 0), (0, pad_e)))
        dst = jnp.pad(dst, ((0, 0), (0, pad_e)))
        w = jnp.pad(w, ((0, 0), (0, pad_e)))
    pad_d = (-d) % d_blk
    if pad_d:
        h = jnp.pad(h, ((0, 0), (0, 0), (0, pad_d)))
    # pad segment dim to a multiple of n_blk (all-zero w ⇒ zero rows)
    pad_n = (-N) % n_blk
    if pad_n:
        h = jnp.pad(h, ((0, pad_n), (0, 0), (0, 0)))
        src = jnp.pad(src, ((0, pad_n), (0, 0)))
        dst = jnp.pad(dst, ((0, pad_n), (0, 0)))
        w = jnp.pad(w, ((0, pad_n), (0, 0)))
    grid = ((N + pad_n) // n_blk, (d + pad_d) // d_blk, (e + pad_e) // e_blk)
    out = pl.pallas_call(
        functools.partial(_spmm_batched_kernel, m=m, n_blk=n_blk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_blk, e_blk), lambda n, db, eb: (n, eb)),
            pl.BlockSpec((n_blk, e_blk), lambda n, db, eb: (n, eb)),
            pl.BlockSpec((n_blk, e_blk), lambda n, db, eb: (n, eb)),
            pl.BlockSpec((n_blk, m, d_blk), lambda n, db, eb: (n, 0, db)),
        ],
        out_specs=pl.BlockSpec((n_blk, m, d_blk), lambda n, db, eb: (n, 0, db)),
        out_shape=jax.ShapeDtypeStruct((N + pad_n, m, d + pad_d), jnp.float32),
        interpret=interpret,
    )(src, dst, w, h)
    return out[:N, :, :d].astype(h.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _spmm_batched(h, src, dst, w, e_blk, d_blk, n_blk, interpret):
    return _spmm_batched_raw(h, src, dst, w, e_blk, d_blk, n_blk, interpret)


def _spmm_fwd(h, src, dst, w, e_blk, d_blk, n_blk, interpret):
    out = _spmm_batched_raw(h, src, dst, w, e_blk, d_blk, n_blk, interpret)
    return out, (h, src, dst, w)


def _spmm_bwd(e_blk, d_blk, n_blk, interpret, res, g):
    h, src, dst, w = res
    g = g.astype(h.dtype)
    # transpose of the weighted scatter-add: swap src/dst roles
    dh = _spmm_batched_raw(g, dst, src, w, e_blk, d_blk, n_blk, interpret)
    dh = dh.astype(h.dtype)
    g_dst = jnp.take_along_axis(g, dst[..., None].astype(jnp.int32), axis=1)
    h_src = jnp.take_along_axis(h, src[..., None].astype(jnp.int32), axis=1)
    dw = jnp.sum(g_dst.astype(jnp.float32) * h_src.astype(jnp.float32),
                 axis=-1).astype(w.dtype)
    return dh, None, None, dw


_spmm_batched.defvjp(_spmm_fwd, _spmm_bwd)


def segment_spmm_batched(h, src, dst, w, *, e_blk: int = DEFAULT_E_BLK,
                         d_blk: int = DEFAULT_D_BLK,
                         n_blk=None, interpret: bool = False):
    """Batched weighted neighbor scatter-add over N padded segments.

    out[n, v] = Σ_{e: dst[n,e]=v} w[n,e] · h[n, src[n,e]].

    h: (N, m, d); src/dst: (N, e) int32; w: (N, e) float, 0 on padding.
    One ``pallas_call`` for the whole batch; differentiable wrt h and w.
    n_blk=None picks automatically: the whole batch per grid step in
    interpret mode, a VMEM-budgeted block (≤ DEFAULT_N_BLK) when compiled.
    """
    return _spmm_batched(h, src, dst, w, e_blk, d_blk, n_blk, interpret)


def segment_spmm(h, src, dst, w, *, e_blk: int = DEFAULT_E_BLK,
                 d_blk: int = DEFAULT_D_BLK, interpret: bool = False):
    """out[v] = Σ_{e: dst_e=v} w_e · h[src_e].   h: (m, d); src/dst/w: (e,).

    Single-segment convenience wrapper over the batched kernel (N = 1).
    """
    return _spmm_batched(h[None], src[None], dst[None], w[None],
                         e_blk, d_blk, 1, interpret)[0]
