"""Mixture-of-Experts FFN with GShard-style one-hot dispatch.

TPU adaptation: instead of GPU-style token permutation + grouped GEMM, we
build dispatch/combine one-hot tensors and route with einsums — this lowers
to MXU matmuls plus (under expert sharding on the ``model`` mesh axis)
reduce-scatter/all-reduce collectives, the standard JAX/TPU MoE formulation
(GShard / Switch / Mesh-TF lineage).

Supports:
  * top-k routing with capacity factor + token dropping (capacity-bounded),
  * optional always-on shared experts (DeepSeek-V3 [arXiv:2412.19437]),
  * optional dense residual FFN in parallel (Arctic [hf:Snowflake/...]),
  * load-balance auxiliary loss (Switch-style).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.common import dense_init, mlp_forward, mlp_params


def moe_params(key, d_model: int, cfg: MoEConfig, act: str, dtype=jnp.float32):
    keys = jax.random.split(key, 6)
    E, F = cfg.num_experts, cfg.expert_d_ff
    scale_in = 1.0 / math.sqrt(d_model)
    scale_out = 1.0 / math.sqrt(F)
    p = {
        "router": dense_init(keys[0], d_model, E, dtype=jnp.float32, scale=scale_in),
        "experts": {
            "w_in": (jax.random.truncated_normal(keys[1], -2, 2, (E, d_model, F)) * scale_in).astype(dtype),
            "w_gate": (jax.random.truncated_normal(keys[2], -2, 2, (E, d_model, F)) * scale_in).astype(dtype),
            "w_out": (jax.random.truncated_normal(keys[3], -2, 2, (E, F, d_model)) * scale_out).astype(dtype),
        },
    }
    if cfg.num_shared_experts:
        p["shared"] = mlp_params(keys[4], d_model, F * cfg.num_shared_experts, act, dtype)
    if cfg.dense_d_ff:
        p["dense"] = mlp_params(keys[5], d_model, cfg.dense_d_ff, act, dtype)
    return p


def _top_k_gating(logits, k: int):
    """logits: (T, E) float32 -> (gates (T,E), mask (T,E) in {0,1})."""
    T, E = logits.shape
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, k)  # (T, k)
    mask = jnp.sum(jax.nn.one_hot(top_idx, E, dtype=probs.dtype), axis=1)  # (T, E)
    gates = probs * mask
    denom = jnp.sum(gates, axis=-1, keepdims=True)
    gates = gates / jnp.maximum(denom, 1e-9)  # renormalize over selected
    return gates, mask, probs


# Dispatch implementation toggle (see EXPERIMENTS.md §Perf):
#   "einsum" — GShard-style one-hot dispatch/combine einsums.  Paper-era
#              baseline; dispatch matmul costs O(T·E·C·d) FLOPs, which
#              DWARFS the expert FFN at DeepSeek scale (E=256, C~5k).
#   "gather" — scatter/gather dispatch: expert_in built with .at[].add on
#              (expert, slot) indices, combine via take + weighted sum.
#              O(T·k·d) data movement, zero dispatch matmul FLOPs.
DISPATCH_MODE = "einsum"


def _expert_ffn(we, expert_in, act):
    if act in ("silu", "swiglu"):
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, we["w_gate"])) * \
            jnp.einsum("ecd,edf->ecf", expert_in, we["w_in"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", expert_in, we["w_in"]))
    return jnp.einsum("ecf,efd->ecd", h, we["w_out"])  # (E, C, D)


def moe_forward(p, x, cfg: MoEConfig, act: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    Capacity-bounded dispatch: each expert processes at most
    C = ceil(T/E * capacity_factor * k) tokens; overflow tokens are dropped
    (their routed contribution is zero — shared/dense paths still apply).
    """
    B, S, D = x.shape
    T = B * S
    E, K = cfg.num_experts, cfg.top_k
    xt = x.reshape(T, D)
    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # (T, E)
    gates, mask, probs = _top_k_gating(logits, K)

    # Switch-style load balance aux loss
    frac_tokens = jnp.mean(mask, axis=0)            # (E,)
    frac_probs = jnp.mean(probs, axis=0)            # (E,)
    aux = jnp.sum(frac_tokens * frac_probs) * (E / K)

    cap = max(int(math.ceil(T / E * cfg.capacity_factor * K)), K)
    cap = min(cap, T)
    # position of each token within its expert queue (per expert, over tokens)
    pos_in_expert = jnp.cumsum(mask, axis=0) * mask - 1.0  # (T, E), -1 where unrouted
    keep = (pos_in_expert < cap) & (mask > 0)
    pos_c = jnp.clip(pos_in_expert, 0, cap - 1).astype(jnp.int32)
    we = p["experts"]

    if DISPATCH_MODE == "einsum":
        # dispatch: (T, E, C) one-hot over capacity slot
        oh_cap = jax.nn.one_hot(pos_c, cap, dtype=x.dtype) * keep[..., None].astype(x.dtype)
        combine = oh_cap * gates[..., None].astype(x.dtype)  # (T, E, C)
        expert_in = jnp.einsum("tec,td->ecd", oh_cap, xt)  # (E, C, D)
        expert_out = _expert_ffn(we, expert_in, act)
        routed = jnp.einsum("tec,ecd->td", combine, expert_out)  # (T, D)
    else:
        # gather/scatter dispatch: per (token, k) assignment indices
        top_gates, top_idx = jax.lax.top_k(gates, K)            # (T, K)
        slot = jnp.take_along_axis(pos_c, top_idx, axis=1)      # (T, K)
        kept = jnp.take_along_axis(keep, top_idx, axis=1)       # (T, K)
        e_flat = top_idx.reshape(-1)                            # (T*K,)
        s_flat = slot.reshape(-1)
        w_flat = jnp.where(kept, top_gates, 0.0).reshape(-1).astype(x.dtype)
        # dropped tokens scatter into a sacrificial overflow slot (cap index
        # C) that is sliced off before the FFN
        s_safe = jnp.where(kept.reshape(-1), s_flat, cap)
        x_rep = jnp.repeat(xt, K, axis=0)                       # (T*K, D)
        expert_in = jnp.zeros((E, cap + 1, D), x.dtype).at[e_flat, s_safe].add(
            jnp.where(kept.reshape(-1)[:, None], x_rep, 0))
        expert_out = _expert_ffn(we, expert_in[:, :cap], act)   # (E, C, D)
        gathered = expert_out[e_flat, jnp.minimum(s_flat, cap - 1)]  # (T*K, D)
        routed = jnp.sum((gathered * w_flat[:, None]).reshape(T, K, D), axis=1)

    out = routed
    if "shared" in p:
        out = out + mlp_forward(p["shared"], xt, act)
    if "dense" in p:
        out = out + mlp_forward(p["dense"], xt, act)
    return out.reshape(B, S, D), aux.astype(jnp.float32)
