"""Mixture-of-Experts FFN with GShard-style one-hot dispatch.

TPU adaptation: instead of GPU-style token permutation + grouped GEMM, we
build dispatch/combine one-hot tensors and route with einsums — this lowers
to MXU matmuls plus (under expert sharding on the ``model`` mesh axis)
reduce-scatter/all-reduce collectives, the standard JAX/TPU MoE formulation
(GShard / Switch / Mesh-TF lineage).

Capacity accounting is **per batch row** (each sequence is one GShard
dispatch group): token t of row b is dropped iff the number of earlier
tokens of the SAME row routed to the expert already fills the row's
capacity ``capacity(S, cfg)``.  This makes dropping causal in the token
order, so incremental decode can reproduce it exactly: ``moe_decode``
threads a per-(row, expert) routed-token counter through the layer cache
and drops the current token iff the counter has reached the capacity
computed from the cache length.  Parity with the teacher-forced forward is
asserted in tests/test_models.py.

Supports:
  * top-k routing with capacity factor + token dropping (capacity-bounded),
  * optional always-on shared experts (DeepSeek-V3 [arXiv:2412.19437]),
  * optional dense residual FFN in parallel (Arctic [hf:Snowflake/...]),
  * load-balance auxiliary loss (Switch-style).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.common import dense_init, mlp_forward, mlp_params


def moe_params(key, d_model: int, cfg: MoEConfig, act: str, dtype=jnp.float32):
    keys = jax.random.split(key, 6)
    E, F = cfg.num_experts, cfg.expert_d_ff
    scale_in = 1.0 / math.sqrt(d_model)
    scale_out = 1.0 / math.sqrt(F)
    p = {
        "router": dense_init(keys[0], d_model, E, dtype=jnp.float32, scale=scale_in),
        "experts": {
            "w_in": (jax.random.truncated_normal(keys[1], -2, 2, (E, d_model, F)) * scale_in).astype(dtype),
            "w_gate": (jax.random.truncated_normal(keys[2], -2, 2, (E, d_model, F)) * scale_in).astype(dtype),
            "w_out": (jax.random.truncated_normal(keys[3], -2, 2, (E, F, d_model)) * scale_out).astype(dtype),
        },
    }
    if cfg.num_shared_experts:
        p["shared"] = mlp_params(keys[4], d_model, F * cfg.num_shared_experts, act, dtype)
    if cfg.dense_d_ff:
        p["dense"] = mlp_params(keys[5], d_model, cfg.dense_d_ff, act, dtype)
    return p


def capacity(tokens_per_row: int, cfg: MoEConfig) -> int:
    """Per-row expert capacity C = ceil(S/E * capacity_factor * k), >= k.

    The decode path must call this with the SAME ``tokens_per_row`` the
    forward used (the cache length) to reproduce the forward's dropping.
    """
    cap = max(int(math.ceil(tokens_per_row / cfg.num_experts
                            * cfg.capacity_factor * cfg.top_k)), cfg.top_k)
    return min(cap, tokens_per_row)


def _top_k_gating(logits, k: int):
    """logits: (..., E) float32 -> (gates (...,E), mask (...,E) in {0,1})."""
    E = logits.shape[-1]
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, k)  # (..., k)
    mask = jnp.sum(jax.nn.one_hot(top_idx, E, dtype=probs.dtype), axis=-2)  # (..., E)
    gates = probs * mask
    denom = jnp.sum(gates, axis=-1, keepdims=True)
    gates = gates / jnp.maximum(denom, 1e-9)  # renormalize over selected
    return gates, mask, probs


# Dispatch implementation toggle (see EXPERIMENTS.md §Perf):
#   "einsum" — GShard-style one-hot dispatch/combine einsums.  Paper-era
#              baseline; dispatch matmul costs O(T·E·C·d) FLOPs, which
#              DWARFS the expert FFN at DeepSeek scale (E=256, C~5k).
#   "gather" — scatter/gather dispatch: expert_in built with .at[].add on
#              (expert, slot) indices, combine via take + weighted sum.
#              O(T·k·d) data movement, zero dispatch matmul FLOPs.
DISPATCH_MODE = "einsum"


def _expert_ffn(we, expert_in, act):
    """expert_in: (..., E, C, D) -> (..., E, C, D)."""
    if act in ("silu", "swiglu"):
        h = jax.nn.silu(jnp.einsum("...ecd,edf->...ecf", expert_in, we["w_gate"])) * \
            jnp.einsum("...ecd,edf->...ecf", expert_in, we["w_in"])
    else:
        h = jax.nn.gelu(jnp.einsum("...ecd,edf->...ecf", expert_in, we["w_in"]))
    return jnp.einsum("...ecf,efd->...ecd", h, we["w_out"])  # (..., E, C, D)


def moe_forward(p, x, cfg: MoEConfig, act: str, *, with_counts: bool = False):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar[, counts (B, E)]).

    Capacity-bounded dispatch per row: each expert processes at most
    C = capacity(S, cfg) tokens of each sequence; overflow tokens are
    dropped (their routed contribution is zero — shared/dense paths still
    apply).  ``counts`` (returned when with_counts=True) is the number of
    tokens each row ROUTED to each expert — dropped tokens included, since
    a token's queue position counts all earlier routed tokens — for seeding
    ``moe_decode``'s counters after a prefill.
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    logits = (x.reshape(B * S, D).astype(jnp.float32)
              @ p["router"].astype(jnp.float32)).reshape(B, S, E)
    gates, mask, probs = _top_k_gating(logits, K)

    # Switch-style load balance aux loss (over all tokens)
    frac_tokens = jnp.mean(mask, axis=(0, 1))       # (E,)
    frac_probs = jnp.mean(probs, axis=(0, 1))       # (E,)
    aux = jnp.sum(frac_tokens * frac_probs) * (E / K)

    cap = capacity(S, cfg)
    # position of each token within its row's expert queue (causal cumsum)
    pos_in_expert = jnp.cumsum(mask, axis=1) * mask - 1.0  # (B, S, E), -1 unrouted
    keep = (pos_in_expert < cap) & (mask > 0)
    pos_c = jnp.clip(pos_in_expert, 0, cap - 1).astype(jnp.int32)
    we = p["experts"]

    if DISPATCH_MODE == "einsum":
        # dispatch: (B, S, E, C) one-hot over capacity slot
        oh_cap = jax.nn.one_hot(pos_c, cap, dtype=x.dtype) * keep[..., None].astype(x.dtype)
        combine = oh_cap * gates[..., None].astype(x.dtype)  # (B, S, E, C)
        expert_in = jnp.einsum("bsec,bsd->becd", oh_cap, x)  # (B, E, C, D)
        expert_out = _expert_ffn(we, expert_in, act)
        routed = jnp.einsum("bsec,becd->bsd", combine, expert_out)  # (B, S, D)
    else:
        # gather/scatter dispatch: per (token, k) assignment indices
        top_gates, top_idx = jax.lax.top_k(gates, K)            # (B, S, K)
        slot = jnp.take_along_axis(pos_c, top_idx, axis=2)      # (B, S, K)
        kept = jnp.take_along_axis(keep, top_idx, axis=2)       # (B, S, K)
        e_flat = top_idx.reshape(B, -1)                         # (B, S*K)
        s_flat = slot.reshape(B, -1)
        k_flat = kept.reshape(B, -1)
        w_flat = jnp.where(kept, top_gates, 0.0).reshape(B, -1).astype(x.dtype)
        # dropped tokens scatter into a sacrificial overflow slot (cap index
        # C) that is sliced off before the FFN
        s_safe = jnp.where(k_flat, s_flat, cap)
        x_rep = jnp.repeat(x, K, axis=1)                        # (B, S*K, D)
        b_idx = jnp.arange(B)[:, None]
        expert_in = jnp.zeros((B, E, cap + 1, D), x.dtype).at[
            b_idx, e_flat, s_safe].add(jnp.where(k_flat[..., None], x_rep, 0))
        expert_out = _expert_ffn(we, expert_in[:, :, :cap], act)  # (B, E, C, D)
        gathered = expert_out[b_idx, e_flat, jnp.minimum(s_flat, cap - 1)]
        routed = jnp.sum((gathered * w_flat[..., None]).reshape(B, S, K, D), axis=2)

    out = routed
    xt = x.reshape(B * S, D)
    if "shared" in p:
        out = out + mlp_forward(p["shared"], xt, act).reshape(B, S, D)
    if "dense" in p:
        out = out + mlp_forward(p["dense"], xt, act).reshape(B, S, D)
    if with_counts:
        counts = jnp.sum(mask, axis=1).astype(jnp.int32)        # (B, E)
        return out, aux.astype(jnp.float32), counts
    return out, aux.astype(jnp.float32)


def moe_decode(p, x, cfg: MoEConfig, act: str, counts, cap: int):
    """One-token step: x (B, 1, d), counts (B, E) routed-token counters.

    Reproduces ``moe_forward``'s per-row dropping exactly: the token is
    dropped at expert e iff counts[b, e] >= cap, where cap must equal the
    forward's ``capacity(seq_len, cfg)``.  Experts run via weight gather —
    O(k) FFNs per token, no (T, E, C) dispatch tensor on the decode path.

    Returns (out (B, 1, d), aux scalar, new_counts (B, E)).
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    xt = x.reshape(B, D)
    logits = xt.astype(jnp.float32) @ p["router"].astype(jnp.float32)  # (B, E)
    gates, mask, probs = _top_k_gating(logits, K)
    aux = jnp.sum(jnp.mean(mask, axis=0) * jnp.mean(probs, axis=0)) * (E / K)

    keep = (counts < cap) & (mask > 0)                           # (B, E)
    top_gates, top_idx = jax.lax.top_k(gates, K)                 # (B, K)
    kept = jnp.take_along_axis(keep, top_idx, axis=1)            # (B, K)
    we = p["experts"]
    w_in = we["w_in"][top_idx]                                   # (B, K, D, F)
    w_out = we["w_out"][top_idx]                                 # (B, K, F, D)
    xk = xt.astype(we["w_in"].dtype)
    if act in ("silu", "swiglu"):
        w_gate = we["w_gate"][top_idx]
        h = jax.nn.silu(jnp.einsum("bd,bkdf->bkf", xk, w_gate)) * \
            jnp.einsum("bd,bkdf->bkf", xk, w_in)
    else:
        h = jax.nn.gelu(jnp.einsum("bd,bkdf->bkf", xk, w_in))
    y = jnp.einsum("bkf,bkfd->bkd", h, w_out)                    # (B, K, D)
    w_eff = jnp.where(kept, top_gates, 0.0).astype(y.dtype)
    routed = jnp.sum(y * w_eff[..., None], axis=1)               # (B, D)

    out = routed
    if "shared" in p:
        out = out + mlp_forward(p["shared"], xt, act)
    if "dense" in p:
        out = out + mlp_forward(p["dense"], xt, act)
    new_counts = counts + mask.astype(counts.dtype)
    return out.reshape(B, S, D), aux.astype(jnp.float32), new_counts
