"""Uniform block interface over all layer kinds.

Every block kind exposes:
    block_init(kind, key, cfg, dtype)                     -> params pytree
    block_forward(kind, p, x, cfg, mode, ...)             -> (x, new_cache, aux)
    init_block_cache(kind, cfg, batch, cache_len, dtype)  -> cache pytree
with a *kind-stable pytree structure*, so a run of equal-kind layers can be
stacked and driven by ``lax.scan`` (see transformer.py).

Kinds:
    attn       — pre-norm GQA attention + dense MLP (window-maskable)
    mla_dense  — MLA attention + dense MLP            (DeepSeek-V3 dense layers)
    mla_moe    — MLA attention + MoE                  (DeepSeek-V3 MoE layers)
    gqa_moe    — GQA attention + MoE (+ dense residual)        (Arctic)
    mamba      — Mamba2/SSD block                     (Zamba2 backbone)
    rwkv       — RWKV6 time-mix + channel-mix
    shared_attn — same structure as ``attn``; parameters shared across
                  occurrences (Zamba2), caches per-occurrence.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import mla as mla_mod
from repro.models import mamba2 as mamba_mod
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.common import (
    attn_decode,
    attn_forward,
    attn_params,
    make_norm,
    mlp_forward,
    mlp_params,
)


def _norm(cfg: ArchConfig, d: int, dtype):
    return make_norm(cfg.norm, d, dtype)


def block_init(kind: str, key, cfg: ArchConfig, dtype=jnp.float32):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    n1, _ = _norm(cfg, d, dtype)
    n2, _ = _norm(cfg, d, dtype)
    if kind in ("attn", "shared_attn"):
        return {
            "norm1": n1,
            "attn": attn_params(k1, d, cfg.num_heads, cfg.num_kv_heads, hd, dtype),
            "norm2": n2,
            "mlp": mlp_params(k2, d, cfg.d_ff, cfg.act, dtype),
        }
    if kind == "mla_dense":
        return {
            "norm1": n1,
            "mla": mla_mod.mla_params(k1, cfg, dtype),
            "norm2": n2,
            "mlp": mlp_params(k2, d, cfg.d_ff, cfg.act, dtype),
        }
    if kind == "mla_moe":
        return {
            "norm1": n1,
            "mla": mla_mod.mla_params(k1, cfg, dtype),
            "norm2": n2,
            "moe": moe_mod.moe_params(k2, d, cfg.moe, cfg.act, dtype),
        }
    if kind == "gqa_moe":
        return {
            "norm1": n1,
            "attn": attn_params(k1, d, cfg.num_heads, cfg.num_kv_heads, hd, dtype),
            "norm2": n2,
            "moe": moe_mod.moe_params(k2, d, cfg.moe, cfg.act, dtype),
        }
    if kind == "mamba":
        return {"norm1": n1, "mamba": mamba_mod.mamba2_params(k1, cfg, dtype)}
    if kind == "rwkv":
        return {
            "norm1": n1,
            "tm": rwkv_mod.rwkv_timemix_params(k1, cfg, dtype),
            "norm2": n2,
            "cm": rwkv_mod.rwkv_channelmix_params(k2, cfg, dtype),
        }
    raise ValueError(kind)


def init_block_cache(kind: str, cfg: ArchConfig, batch: int, cache_len: int, dtype):
    hd = cfg.resolved_head_dim
    # MoE kinds carry per-(row, expert) routed-token counters so decode
    # reproduces the forward's capacity dropping (see moe.moe_decode)
    moe_counts = lambda: jnp.zeros((batch, cfg.moe.num_experts), jnp.int32)
    if kind in ("attn", "shared_attn", "gqa_moe"):
        shp = (batch, cache_len, cfg.num_kv_heads, hd)
        c = {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}
        if kind == "gqa_moe":
            c["moe_counts"] = moe_counts()
        return c
    if kind in ("mla_dense", "mla_moe"):
        c = {
            "ckv": jnp.zeros((batch, cache_len, cfg.mla_kv_lora_rank), dtype),
            "kr": jnp.zeros((batch, cache_len, cfg.mla_rope_head_dim), dtype),
        }
        if kind == "mla_moe":
            c["moe_counts"] = moe_counts()
        return c
    if kind == "mamba":
        d_inner, H, P, N = mamba_mod.mamba2_dims(cfg)
        W = cfg.ssm.conv_width
        return {
            "conv": jnp.zeros((batch, W - 1, d_inner), dtype),
            "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
        }
    if kind == "rwkv":
        H, N = rwkv_mod.rwkv_dims(cfg)
        return {
            "state": jnp.zeros((batch, H, N, N), jnp.float32),
            "shift_tm": jnp.zeros((batch, cfg.d_model), dtype),
            "shift_cm": jnp.zeros((batch, cfg.d_model), dtype),
        }
    raise ValueError(kind)


def _apply_norm(cfg: ArchConfig, p, x):
    _, fn = make_norm(cfg.norm, cfg.d_model, x.dtype)
    return fn(p, x)


def _moe_ffn(p, h, cfg: ArchConfig, *, mode, cache, new_cache, cache_len,
             moe_cap_len):
    """Shared MoE dispatch for the gqa_moe / mla_moe blocks.

    Decode reproduces the forward's per-row capacity dropping via the
    counters in the cache; the capacity defaults to ``capacity(cache_len)``
    — exact parity with a teacher-forced forward over ``cache_len`` tokens —
    and ``moe_cap_len`` overrides it when the cache is allocated longer than
    the reference sequence.  Adds 'moe_counts' to new_cache when present.
    """
    if mode == "full":
        o, aux, counts = moe_mod.moe_forward(
            p["moe"], h, cfg.moe, cfg.act, with_counts=True)
        if new_cache is not None:
            new_cache["moe_counts"] = counts
    else:
        cap = moe_mod.capacity(moe_cap_len or cache_len, cfg.moe)
        o, aux, counts = moe_mod.moe_decode(
            p["moe"], h, cfg.moe, cfg.act, cache["moe_counts"], cap)
        new_cache["moe_counts"] = counts
    return o, aux


def block_forward(
    kind: str,
    p,
    x,
    cfg: ArchConfig,
    *,
    mode: str,                      # "full" | "decode"
    positions=None,                 # (B, S) absolute positions (full mode)
    positions_thw=None,             # (B, S, 3) M-RoPE ids (vlm)
    cache=None,
    cache_pos=None,                 # (B,) decode position
    window: int = 0,                # sliding-window size; 0 = full attention
    ring: bool = False,             # decode cache is a ring buffer
    emit_cache: bool = False,       # full mode: return (k, v) as cache (prefill)
    moe_cap_len: int = 0,           # MoE decode capacity sequence length;
                                    # 0 = use the cache length
) -> Tuple[jnp.ndarray, Optional[dict], jnp.ndarray]:
    aux = jnp.zeros((), jnp.float32)
    hd = cfg.resolved_head_dim
    mrope = cfg.mrope_sections if cfg.family == "vlm" else ()

    if kind in ("attn", "shared_attn", "gqa_moe"):
        h = _apply_norm(cfg, p["norm1"], x)
        if mode == "full":
            o, (k, v) = attn_forward(
                p["attn"], h, num_heads=cfg.num_heads, num_kv=cfg.num_kv_heads,
                head_dim=hd, positions=positions, rope_theta=cfg.rope_theta,
                causal=True, window=window, mrope_sections=mrope,
                positions_thw=positions_thw)
            new_cache = {"k": k, "v": v} if emit_cache else None
        else:
            o, ck, cv = attn_decode(
                p["attn"], h, cache["k"], cache["v"], cache_pos,
                num_heads=cfg.num_heads, num_kv=cfg.num_kv_heads, head_dim=hd,
                rope_theta=cfg.rope_theta, window=window, ring=ring,
                mrope_sections=mrope, positions_thw=positions_thw)
            new_cache = {"k": ck, "v": cv}
        x = x + o
        h = _apply_norm(cfg, p["norm2"], x)
        if kind == "gqa_moe":
            o, aux = _moe_ffn(p, h, cfg, mode=mode, cache=cache,
                              new_cache=new_cache,
                              cache_len=cache["k"].shape[1] if cache else 0,
                              moe_cap_len=moe_cap_len)
        else:
            o = mlp_forward(p["mlp"], h, cfg.act)
        return x + o, new_cache, aux

    if kind in ("mla_dense", "mla_moe"):
        h = _apply_norm(cfg, p["norm1"], x)
        if mode == "full":
            o, (ckv, kr) = mla_mod.mla_forward(p["mla"], h, cfg, positions)
            new_cache = {"ckv": ckv, "kr": kr} if emit_cache else None
        else:
            o, ckv, kr = mla_mod.mla_decode(
                p["mla"], h, cache["ckv"], cache["kr"], cache_pos, cfg,
                absorbed=mla_mod.ABSORBED_DECODE)
            new_cache = {"ckv": ckv, "kr": kr}
        x = x + o
        h = _apply_norm(cfg, p["norm2"], x)
        if kind == "mla_moe":
            o, aux = _moe_ffn(p, h, cfg, mode=mode, cache=cache,
                              new_cache=new_cache,
                              cache_len=cache["ckv"].shape[1] if cache else 0,
                              moe_cap_len=moe_cap_len)
        else:
            o = mlp_forward(p["mlp"], h, cfg.act)
        return x + o, new_cache, aux

    if kind == "mamba":
        h = _apply_norm(cfg, p["norm1"], x)
        if mode == "full":
            o, (conv, ssm) = mamba_mod.mamba2_forward(p["mamba"], h, cfg)
            new_cache = {"conv": conv, "ssm": ssm} if emit_cache else None
        else:
            o, (conv, ssm) = mamba_mod.mamba2_decode(
                p["mamba"], h, cache["conv"], cache["ssm"], cfg)
            new_cache = {"conv": conv, "ssm": ssm}
        return x + o, new_cache, aux

    if kind == "rwkv":
        h = _apply_norm(cfg, p["norm1"], x)
        if mode == "full":
            o, (state, last) = rwkv_mod.rwkv_timemix(p["tm"], h, cfg)
            x = x + o
            h2 = _apply_norm(cfg, p["norm2"], x)
            o2, last2 = rwkv_mod.rwkv_channelmix(p["cm"], h2)
            new_cache = (
                {"state": state, "shift_tm": last, "shift_cm": last2}
                if emit_cache else None)
            return x + o2, new_cache, aux
        o, (state, last) = rwkv_mod.rwkv_timemix(
            p["tm"], h, cfg, state=cache["state"], shift_prev=cache["shift_tm"])
        x = x + o
        h2 = _apply_norm(cfg, p["norm2"], x)
        o2, last2 = rwkv_mod.rwkv_channelmix(p["cm"], h2, shift_prev=cache["shift_cm"])
        new_cache = {"state": state, "shift_tm": last, "shift_cm": last2}
        return x + o2, new_cache, aux

    raise ValueError(kind)


def resolve_kind(cfg: ArchConfig, raw_kind: str) -> str:
    """Map a config-level layer kind to a block kind."""
    if raw_kind == "attn":
        return "attn"
    if raw_kind == "dense":
        return "mla_dense" if cfg.use_mla else "attn"
    if raw_kind == "moe":
        return "mla_moe" if cfg.use_mla else "gqa_moe"
    if raw_kind in ("mamba", "rwkv", "shared_attn"):
        return raw_kind
    raise ValueError(raw_kind)
