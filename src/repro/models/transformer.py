"""Generic decoder-only model assembled from blocks, scan-over-layers.

Layers are grouped into *runs* of consecutive equal block kinds
(e.g. DeepSeek-V3: [mla_dense x3, mla_moe x58]; Zamba2:
[mamba x5, shared_attn x1] repeated).  Each run's parameters are stacked
along a leading axis and driven with ``lax.scan`` — one traced body per run,
keeping compile time O(#runs) instead of O(#layers).

``shared_attn`` runs reference a single shared parameter set (Zamba2's
shared block); their caches are still per-occurrence.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import blocks as B
from repro.models.common import embed_init, dense_init, make_norm


# Dry-run accounting flag: XLA's cost_analysis counts a while-loop body ONCE
# regardless of trip count, so the roofline pass unrolls the layer scans to
# get honest FLOP/byte/collective totals (launch/specs.py sets this).  Real
# training keeps scan (compile-time win); the lowered math is identical.
SCAN_UNROLL = False


def layer_runs(cfg: ArchConfig) -> List[Tuple[str, int]]:
    kinds = [B.resolve_kind(cfg, k) for k in cfg.layer_kinds()]
    runs: List[Tuple[str, int]] = []
    for k in kinds:
        if runs and runs[-1][0] == k and k != "shared_attn":
            runs[-1] = (k, runs[-1][1] + 1)
        else:
            runs.append((k, 1))
    return runs


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(key, cfg: ArchConfig, dtype=jnp.float32):
    runs = layer_runs(cfg)
    keys = jax.random.split(key, len(runs) + 4)
    params: dict = {"embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype)}
    has_shared = any(k == "shared_attn" for k, _ in runs)
    if has_shared:
        params["shared_attn"] = B.block_init("shared_attn", keys[1], cfg, dtype)
    run_params = []
    for i, (kind, n) in enumerate(runs):
        if kind == "shared_attn":
            run_params.append({})  # parameters live in params["shared_attn"]
            continue
        ks = jax.random.split(keys[2 + i], n)
        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[B.block_init(kind, k, cfg, dtype) for k in ks])
        run_params.append(stacked)
    params["runs"] = run_params
    nparams, _ = make_norm(cfg.norm, cfg.d_model, dtype)
    params["final_norm"] = nparams
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[-1], cfg.d_model, cfg.vocab_size, dtype)
    return params


def init_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype):
    caches = []
    for kind, n in layer_runs(cfg):
        one = B.init_block_cache(kind, cfg, batch, cache_len, dtype)
        if kind == "shared_attn":
            caches.append(one)
        else:
            caches.append(jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x, (n,) + x.shape), one))
    return caches


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------


def _mrope_ids(cfg: ArchConfig, idx):
    """Purely positional M-RoPE id mapping [arXiv:2409.12191]: the first
    ``vision_prefix_len`` positions are a (t=0, h, w) grid; text positions
    continue sequentially on all three axes after the max spatial id.  Shared
    by full-forward and decode so caches stay consistent."""
    P = cfg.vision_prefix_len
    side = max(int(P ** 0.5), 1)
    is_vis = idx < P
    h_id = jnp.where(is_vis, (idx % max(P, 1)) // side, 0)
    w_id = jnp.where(is_vis, (idx % max(P, 1)) % side, 0)
    t_txt = idx - P + side  # text starts after max spatial id
    return jnp.stack([jnp.where(is_vis, 0, t_txt),
                      jnp.where(is_vis, h_id, t_txt),
                      jnp.where(is_vis, w_id, t_txt)], axis=-1)


def _build_positions(cfg: ArchConfig, batch: int, seq: int, offset=0):
    pos = jnp.arange(seq)[None, :] + offset
    pos = jnp.broadcast_to(pos, (batch, seq))
    if cfg.family != "vlm":
        return pos, None
    thw = _mrope_ids(cfg, jnp.arange(seq) + offset)
    thw = jnp.broadcast_to(thw[None], (batch, seq, 3))
    return pos, thw


def _embed(params, cfg: ArchConfig, tokens, patches=None):
    x = params["embed"][tokens]
    if patches is not None and cfg.vision_prefix_len:
        # stub modality frontend: precomputed patch embeddings overwrite the
        # first vision_prefix_len slots (the carve-out allowed by the brief)
        P = patches.shape[1]
        x = lax.dynamic_update_slice(x, patches.astype(x.dtype), (0, 0, 0))
    return x


def _run_scan(kind, stacked_p, shared_p, x, cfg, *, mode, positions, positions_thw,
              caches, cache_pos, window, ring, emit_cache, moe_cap_len=0):
    """Apply one run. For shared_attn the (single) block applies once with the
    shared params; otherwise scan over the stacked per-layer params."""
    if kind == "shared_attn":
        x, new_c, aux = B.block_forward(
            kind, shared_p, x, cfg, mode=mode, positions=positions,
            positions_thw=positions_thw, cache=caches, cache_pos=cache_pos,
            window=window, ring=ring, emit_cache=emit_cache,
            moe_cap_len=moe_cap_len)
        return x, new_c, aux

    if caches is None:
        def body_nc(carry, p_i):
            h, aux_acc = carry
            h, new_c, aux = B.block_forward(
                kind, p_i, h, cfg, mode=mode, positions=positions,
                positions_thw=positions_thw, cache=None, cache_pos=cache_pos,
                window=window, ring=ring, emit_cache=emit_cache,
                moe_cap_len=moe_cap_len)
            return (h, aux_acc + aux), new_c
        (x, aux), new_caches = lax.scan(body_nc, (x, jnp.zeros((), jnp.float32)),
                                        stacked_p, unroll=SCAN_UNROLL)
        return x, new_caches, aux

    def body(carry, xs):
        h, aux_acc = carry
        p_i, c_i = xs
        h, new_c, aux = B.block_forward(
            kind, p_i, h, cfg, mode=mode, positions=positions,
            positions_thw=positions_thw, cache=c_i, cache_pos=cache_pos,
            window=window, ring=ring, emit_cache=emit_cache,
            moe_cap_len=moe_cap_len)
        return (h, aux_acc + aux), new_c

    (x, aux), new_caches = lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                    (stacked_p, caches), unroll=SCAN_UNROLL)
    return x, new_caches, aux


def forward_hidden(params, cfg: ArchConfig, tokens, *, patches=None,
                   caches=None, cache_pos=None, mode="full", window: int = 0,
                   ring: bool = False, emit_cache: bool = False,
                   moe_cap_len: int = 0):
    """Core stack application.  Returns (hidden, new_caches, aux_loss).

    moe_cap_len: decode-mode MoE capacity reference length (0 = the cache
    length) — pin to the teacher-forced sequence length when the cache is
    allocated longer than the sequence being reproduced."""
    batch, seq = tokens.shape
    if mode == "decode":
        positions = cache_pos[:, None]
        thw = _mrope_ids(cfg, cache_pos)[:, None, :] if cfg.family == "vlm" else None
    else:
        positions, thw = _build_positions(cfg, batch, seq)
    x = _embed(params, cfg, tokens, patches)
    runs = layer_runs(cfg)
    new_caches = []
    aux_total = jnp.zeros((), jnp.float32)
    for i, (kind, n) in enumerate(runs):
        run_p = params["runs"][i] if kind != "shared_attn" else None
        shared_p = params.get("shared_attn")
        c = caches[i] if caches is not None else None
        x, nc, aux = _run_scan(
            kind, run_p, shared_p, x, cfg, mode=mode, positions=positions,
            positions_thw=thw, caches=c, cache_pos=cache_pos, window=window,
            ring=ring, emit_cache=emit_cache or mode == "decode",
            moe_cap_len=moe_cap_len)
        new_caches.append(nc)
        aux_total = aux_total + aux
    _, norm_fn = make_norm(cfg.norm, cfg.d_model, x.dtype)
    x = norm_fn(params["final_norm"], x)
    return x, new_caches, aux_total


def lm_logits(params, cfg: ArchConfig, hidden):
    if cfg.tie_embeddings:
        return hidden @ params["embed"].T
    return hidden @ params["lm_head"]
