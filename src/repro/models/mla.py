"""Multi-head Latent Attention (DeepSeek-V3). [arXiv:2412.19437]

MLA compresses K/V into a low-rank latent c_kv (rank r_kv) plus a shared
RoPE key (rope_head_dim); Q is likewise generated through a low-rank
projection.  The decode cache stores only (c_kv, k_rope):
  cache bytes per token = r_kv + rope_head_dim  (vs 2 * H * head_dim for MHA)
— the paper's key serving win; our decode path exploits exactly that.

Two execution modes:
  * ``naive``  — expand the latent to per-head K/V, standard SDPA
                 (train / prefill; simple & matmul-friendly).
  * ``absorbed`` — fold W_uk into the query and W_uv into the output
                 projection so decode attends directly in latent space;
                 per-step FLOPs drop from O(H*dh*S) expansion to O(r_kv*S).
                 This is a §Perf optimization toggle (see EXPERIMENTS.md).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import apply_rope, dense_init


# Decode-mode toggle (EXPERIMENTS.md §Perf): absorbed is exact and cheaper;
# naive is the paper-era baseline formulation.
ABSORBED_DECODE = True


def mla_params(key, cfg: ArchConfig, dtype=jnp.float32):
    d = cfg.d_model
    H = cfg.num_heads
    r_kv, r_q = cfg.mla_kv_lora_rank, cfg.mla_q_lora_rank
    dn, dr, dv = cfg.mla_nope_head_dim, cfg.mla_rope_head_dim, cfg.mla_v_head_dim
    ks = jax.random.split(key, 8)
    return {
        "wq_a": dense_init(ks[0], d, r_q, dtype),                  # d -> q latent
        "wq_b": dense_init(ks[1], r_q, H * (dn + dr), dtype),      # q latent -> per-head q
        "wkv_a": dense_init(ks[2], d, r_kv + dr, dtype),           # d -> kv latent + shared rope k
        "wk_b": dense_init(ks[3], r_kv, H * dn, dtype),            # latent -> per-head k_nope
        "wv_b": dense_init(ks[4], r_kv, H * dv, dtype),            # latent -> per-head v
        "wo": dense_init(ks[5], H * dv, d, dtype, scale=1.0 / math.sqrt(H * dv)),
    }


def _project_qkv(p, x, cfg: ArchConfig, positions):
    B, S, _ = x.shape
    H = cfg.num_heads
    dn, dr, dv = cfg.mla_nope_head_dim, cfg.mla_rope_head_dim, cfg.mla_v_head_dim
    q = (x @ p["wq_a"]) @ p["wq_b"]
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    kv = x @ p["wkv_a"]  # (B, S, r_kv + dr)
    c_kv, k_rope = kv[..., : cfg.mla_kv_lora_rank], kv[..., cfg.mla_kv_lora_rank:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, c_kv, k_rope


def mla_forward(p, x, cfg: ArchConfig, positions, causal: bool = True):
    """Naive (expanded) MLA over a full sequence. Returns (out, (c_kv, k_rope))
    so prefill can emit the compressed cache."""
    B, S, _ = x.shape
    H = cfg.num_heads
    dn, dr, dv = cfg.mla_nope_head_dim, cfg.mla_rope_head_dim, cfg.mla_v_head_dim
    q_nope, q_rope, c_kv, k_rope = _project_qkv(p, x, cfg, positions)
    k_nope = (c_kv @ p["wk_b"]).reshape(B, S, H, dn)
    v = (c_kv @ p["wv_b"]).reshape(B, S, H, dv)
    scale = 1.0 / math.sqrt(dn + dr)
    logits = (
        jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope)
        + jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope)
    ).astype(jnp.float32) * scale
    if causal:
        qp = jnp.arange(S)
        mask = qp[None, :] <= qp[:, None]
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    out = out.reshape(B, S, H * dv) @ p["wo"]
    return out, (c_kv, k_rope)


def mla_decode(p, x, cache_ckv, cache_krope, cache_pos, cfg: ArchConfig,
               absorbed: bool = True):
    """One-token MLA decode against the compressed cache.

    cache_ckv: (B, C, r_kv); cache_krope: (B, C, dr); cache_pos: (B,).
    ``absorbed=True`` computes attention in latent space:
        logits = (q_nope @ W_uk^T) @ c_kv^T + q_rope @ k_rope^T
        out    = (probs @ c_kv) @ W_uv  then head-merge through wo.
    """
    B = x.shape[0]
    C = cache_ckv.shape[1]
    H = cfg.num_heads
    dn, dr, dv = cfg.mla_nope_head_dim, cfg.mla_rope_head_dim, cfg.mla_v_head_dim
    r_kv = cfg.mla_kv_lora_rank
    pos = cache_pos[:, None]
    q_nope, q_rope, c_kv_new, k_rope_new = _project_qkv(p, x, cfg, pos)
    # write new latent into cache
    from repro.models.common import write_cache
    write_idx = jnp.minimum(cache_pos, C - 1)
    cache_ckv = write_cache(cache_ckv, c_kv_new, write_idx)
    cache_krope = write_cache(cache_krope, k_rope_new, write_idx)
    valid = jnp.minimum(cache_pos + 1, C)
    scale = 1.0 / math.sqrt(dn + dr)
    if absorbed:
        wk_b = p["wk_b"].reshape(r_kv, H, dn)
        # absorb W_uk into q: (B,1,H,dn) x (r,H,dn) -> (B,1,H,r)
        q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, wk_b)
        logits = (
            jnp.einsum("bqhr,bkr->bhqk", q_lat, cache_ckv)
            + jnp.einsum("bqhd,bkd->bhqk", q_rope, cache_krope)
        ).astype(jnp.float32) * scale
    else:
        k_nope = (cache_ckv @ p["wk_b"]).reshape(B, C, H, dn)
        logits = (
            jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope)
            + jnp.einsum("bqhd,bkd->bhqk", q_rope, cache_krope)
        ).astype(jnp.float32) * scale
    k_idx = jnp.arange(C)[None, :]
    logits = jnp.where((k_idx < valid[:, None])[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    if absorbed:
        ctx = jnp.einsum("bhqk,bkr->bqhr", probs, cache_ckv)  # (B,1,H,r)
        wv_b = p["wv_b"].reshape(r_kv, H, dv)
        out = jnp.einsum("bqhr,rhd->bqhd", ctx, wv_b)
    else:
        v = (cache_ckv @ p["wv_b"]).reshape(B, C, H, dv)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    out = out.reshape(B, 1, H * dv) @ p["wo"]
    return out, cache_ckv, cache_krope
