"""RWKV-6 "Finch" block — attention-free time-mix with data-dependent decay.

[arXiv:2404.05892]

Per-head (head size N) linear-attention-style state S ∈ R^{N×N} mapping
key-channels to value-channels:
    S_t[n, m] = w_t[n] * S_{t-1}[n, m] + k_t[n] * v_t[m]
    y_t[m]    = Σ_n r_t[n] * (S_{t-1}[n, m] + u[n] * k_t[n] * v_t[m])
where the decay w_t = exp(-exp(w0 + lora(x_t))) is **data-dependent** — the
Finch contribution — and u is the per-channel "bonus" for the current token.

Training/prefill runs a ``lax.scan`` over tokens (TPU adaptation: the GPU
reference fuses this into a CUDA kernel; on TPU the per-step outer products
batch into (B*H, N, N) VPU ops — a chunked matmul form is a §Perf follow-up).
Decode is the same recurrence with a carried state, O(1) in sequence length.
Channel-mix is a squared-ReLU MLP with token shift.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models.common import dense_init, layernorm, layernorm_params


def rwkv_dims(cfg: ArchConfig):
    N = cfg.ssm.state_size  # head size (64 for rwkv6-7b)
    H = cfg.d_model // N
    return H, N


def rwkv_timemix_params(key, cfg: ArchConfig, dtype=jnp.float32):
    d = cfg.d_model
    H, N = rwkv_dims(cfg)
    lora = max(32, d // 64)
    ks = jax.random.split(key, 10)
    return {
        # token-shift interpolation weights (static part; data-dep part via lora)
        "mix_r": (jnp.ones((d,)) * 0.5).astype(dtype),
        "mix_k": (jnp.ones((d,)) * 0.5).astype(dtype),
        "mix_v": (jnp.ones((d,)) * 0.5).astype(dtype),
        "mix_w": (jnp.ones((d,)) * 0.5).astype(dtype),
        "mix_g": (jnp.ones((d,)) * 0.5).astype(dtype),
        "wr": dense_init(ks[0], d, d, dtype),
        "wk": dense_init(ks[1], d, d, dtype),
        "wv": dense_init(ks[2], d, d, dtype),
        "wg": dense_init(ks[3], d, d, dtype),
        "w_decay_a": dense_init(ks[4], d, lora, dtype),      # decay lora in
        "w_decay_b": dense_init(ks[5], lora, d, dtype),      # decay lora out
        "decay_base": (jnp.zeros((d,)) - 0.6).astype(dtype),  # w0
        "bonus_u": (jnp.zeros((d,)) + 0.3).astype(dtype),
        "out_gn_scale": jnp.ones((d,), dtype),               # per-head groupnorm
        "out_gn_bias": jnp.zeros((d,), dtype),
        "wo": dense_init(ks[6], d, d, dtype, scale=1.0 / math.sqrt(d)),
    }


def _token_shift(x, prev):
    """x: (B, S, d); prev: (B, d) last token of previous window (zeros at t=0).
    Returns x shifted right by one along S."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _group_norm(p, y, H: int, N: int, eps: float = 1e-5):
    B, S, d = y.shape
    yh = y.reshape(B, S, H, N).astype(jnp.float32)
    mu = jnp.mean(yh, axis=-1, keepdims=True)
    var = jnp.var(yh, axis=-1, keepdims=True)
    yh = (yh - mu) * lax.rsqrt(var + eps)
    yh = yh.reshape(B, S, d)
    return (yh * p["out_gn_scale"].astype(jnp.float32)
            + p["out_gn_bias"].astype(jnp.float32)).astype(y.dtype)


def rwkv_timemix(p, x, cfg: ArchConfig, state=None, shift_prev=None):
    """x: (B, S, d). state: (B, H, N, N); shift_prev: (B, d).
    Returns (out, (state, last_x))."""
    B, S, d = x.shape
    H, N = rwkv_dims(cfg)
    if shift_prev is None:
        shift_prev = jnp.zeros((B, d), x.dtype)
    xp = _token_shift(x, shift_prev)

    def mixed(name):
        m = p[f"mix_{name}"]
        return x * m + xp * (1.0 - m)

    r = (mixed("r") @ p["wr"]).reshape(B, S, H, N)
    k = (mixed("k") @ p["wk"]).reshape(B, S, H, N)
    v = (mixed("v") @ p["wv"]).reshape(B, S, H, N)
    g = mixed("g") @ p["wg"]
    # data-dependent decay (Finch): w in (0, 1)
    dd = jnp.tanh(mixed("w") @ p["w_decay_a"]) @ p["w_decay_b"]
    w = jnp.exp(-jnp.exp((p["decay_base"] + dd).astype(jnp.float32)))  # (B,S,d)
    w = w.reshape(B, S, H, N)
    u = p["bonus_u"].astype(jnp.float32).reshape(H, N)

    if state is None:
        state = jnp.zeros((B, H, N, N), jnp.float32)

    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))

    def step(S_prev, inp):
        r_t, k_t, v_t, w_t = inp  # (B,H,N) each
        kv = k_t[..., :, None] * v_t[..., None, :]          # (B,H,N,N)
        y = jnp.einsum("bhn,bhnm->bhm", r_t, S_prev + u[None, :, :, None] * kv)
        S_new = w_t[..., :, None] * S_prev + kv
        return S_new, y

    xs = (jnp.moveaxis(rf, 1, 0), jnp.moveaxis(kf, 1, 0),
          jnp.moveaxis(vf, 1, 0), jnp.moveaxis(w.astype(jnp.float32), 1, 0))
    state_new, ys = lax.scan(step, state.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, d).astype(x.dtype)
    y = _group_norm(p, y, H, N)
    y = y * jax.nn.silu(g)
    return y @ p["wo"], (state_new, x[:, -1, :])


def rwkv_channelmix_params(key, cfg: ArchConfig, dtype=jnp.float32):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mix_k": (jnp.ones((d,)) * 0.5).astype(dtype),
        "mix_r": (jnp.ones((d,)) * 0.5).astype(dtype),
        "w_in": dense_init(ks[0], d, f, dtype),
        "w_rec": dense_init(ks[1], d, d, dtype),
        "w_out": dense_init(ks[2], f, d, dtype, scale=1.0 / math.sqrt(f)),
    }


def rwkv_channelmix(p, x, shift_prev=None):
    B, S, d = x.shape
    if shift_prev is None:
        shift_prev = jnp.zeros((B, d), x.dtype)
    xp = _token_shift(x, shift_prev)
    xk = x * p["mix_k"] + xp * (1.0 - p["mix_k"])
    xr = x * p["mix_r"] + xp * (1.0 - p["mix_r"])
    h = jnp.square(jax.nn.relu(xk @ p["w_in"])) @ p["w_out"]
    return jax.nn.sigmoid(xr @ p["w_rec"]) * h, x[:, -1, :]
