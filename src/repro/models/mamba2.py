"""Mamba2 (SSD) block — chunked-scan training, O(1)-state decode.

[arXiv:2411.15242 uses Mamba2 blocks; SSD formulation from Mamba2 paper.]

TPU adaptation: the GPU reference implements a fused CUDA scan.  We use the
SSD *matmul* form — intra-chunk attention-like matmuls (MXU-friendly) plus an
inter-chunk ``lax.scan`` over chunk states — which is the TPU-native way to
express a selective scan (systolic matmuls instead of warp-level scans).

State-space recurrence per head h with scalar decay:
    a_t = exp(A_h * dt_t)                        (A_h < 0, dt_t > 0)
    H_t = a_t * H_{t-1} + dt_t * B_t ⊗ x_t       H: (d_head, d_state)
    y_t = H_t @ C_t + D_h * x_t
with B_t, C_t shared across heads (n_groups = 1).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models.common import dense_init, rmsnorm, rmsnorm_params


def mamba2_dims(cfg: ArchConfig):
    d_inner = cfg.ssm.expand * cfg.d_model
    heads = cfg.ssm.num_ssm_heads
    assert d_inner % heads == 0, (d_inner, heads)
    return d_inner, heads, d_inner // heads, cfg.ssm.state_size


def mamba2_params(key, cfg: ArchConfig, dtype=jnp.float32):
    d = cfg.d_model
    d_inner, H, P, N = mamba2_dims(cfg)
    ks = jax.random.split(key, 5)
    # in_proj emits [x (d_inner), z (d_inner), B (N), C (N), dt (H)]
    return {
        "w_in": dense_init(ks[0], d, 2 * d_inner + 2 * N + H, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm.conv_width, d_inner)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dtype),  # A = -exp(A_log)
        "D": jnp.ones((H,), dtype),
        "out_norm": rmsnorm_params(d_inner, dtype),
        "w_out": dense_init(ks[2], d_inner, d, dtype, scale=1.0 / math.sqrt(d_inner)),
    }


def _split_in(p, x, cfg: ArchConfig):
    d_inner, H, P, N = mamba2_dims(cfg)
    z = x @ p["w_in"]
    xs = z[..., :d_inner]
    gate = z[..., d_inner : 2 * d_inner]
    Bm = z[..., 2 * d_inner : 2 * d_inner + N]
    Cm = z[..., 2 * d_inner + N : 2 * d_inner + 2 * N]
    dt = z[..., 2 * d_inner + 2 * N :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    return xs, gate, Bm, Cm, dt


def _causal_conv(p, xs, conv_state=None):
    """Depthwise causal conv, width W.  xs: (B, S, d_inner).
    conv_state: (B, W-1, d_inner) rolling buffer for decode."""
    W = p["conv_w"].shape[0]
    if conv_state is None:
        pad = jnp.zeros(xs.shape[:1] + (W - 1,) + xs.shape[2:], xs.dtype)
        xp = jnp.concatenate([pad, xs], axis=1)
        new_state = xp[:, -(W - 1):] if W > 1 else None
    else:
        xp = jnp.concatenate([conv_state.astype(xs.dtype), xs], axis=1)
        new_state = xp[:, -(W - 1):] if W > 1 else None
    out = sum(xp[:, i : i + xs.shape[1]] * p["conv_w"][i] for i in range(W))
    out = jax.nn.silu(out + p["conv_b"])
    return out, new_state


def ssd_chunked(xs, Bm, Cm, dt, A, init_state=None, chunk: int = 256):
    """Chunked SSD scan.

    xs: (B, S, H, P); Bm/Cm: (B, S, N); dt: (B, S, H); A: (H,) negative.
    Returns y: (B, S, H, P) and final state (B, H, P, N).
    """
    Bsz, S, H, P = xs.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk
    f32 = jnp.float32

    xs_c = xs.reshape(Bsz, n_chunks, chunk, H, P).astype(f32)
    B_c = Bm.reshape(Bsz, n_chunks, chunk, N).astype(f32)
    C_c = Cm.reshape(Bsz, n_chunks, chunk, N).astype(f32)
    dt_c = dt.reshape(Bsz, n_chunks, chunk, H).astype(f32)

    log_a = A[None, None, None, :] * dt_c                       # (B, nc, q, H) <= 0
    cum = jnp.cumsum(log_a, axis=2)                             # L_t
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]         # L_t - L_s (B,nc,q,q,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    # clamp BEFORE exp: exp of the (positive, huge) upper-triangular entries
    # would overflow and poison gradients through the mask (NaN = inf * 0)
    seg = jnp.where(tri[None, None, :, :, None], seg, -1e30)
    decay = jnp.exp(seg)

    # intra-chunk: y[t] = sum_{s<=t} (C_t.B_s) decay[t,s] dt_s x_s
    cb = jnp.einsum("bctn,bcsn->bcts", C_c, B_c)                # (B,nc,q,q)
    xdt = xs_c * dt_c[..., None]                                # (B,nc,q,H,P)
    y_intra = jnp.einsum("bcts,bctsh,bcshp->bcthp", cb, decay, xdt)

    # chunk-boundary contributions
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)             # exp(L_Q - L_s) (B,nc,q,H)
    chunk_state = jnp.einsum("bcsn,bcsh,bcshp->bchpn", B_c, dt_c * decay_to_end, xs_c)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                     # (B,nc,H)
    state0 = jnp.zeros((Bsz, H, P, N), f32) if init_state is None else init_state.astype(f32)

    def carry_fn(h_prev, inp):
        cs, cd = inp  # (B,H,P,N), (B,H)
        h_new = h_prev * cd[:, :, None, None] + cs
        return h_new, h_prev

    # scan over chunks (time axis first)
    cs_t = jnp.moveaxis(chunk_state, 1, 0)                      # (nc,B,H,P,N)
    cd_t = jnp.moveaxis(chunk_decay, 1, 0)                      # (nc,B,H)
    final_state, h_prevs = lax.scan(carry_fn, state0, (cs_t, cd_t))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                       # (B,nc,H,P,N) state entering chunk

    # inter-chunk: y[t] += C_t . (exp(L_t) * h_prev)
    y_inter = jnp.einsum("bctn,bcth,bchpn->bcthp", C_c, jnp.exp(cum), h_prevs)

    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y.astype(xs.dtype), final_state


def mamba2_forward(p, x, cfg: ArchConfig, init_state=None, conv_state=None):
    """Full-sequence forward.  x: (B, S, d_model).
    Returns (out, (conv_state, ssm_state))."""
    d_inner, H, P, N = mamba2_dims(cfg)
    xs, gate, Bm, Cm, dt = _split_in(p, x, cfg)
    xs, new_conv = _causal_conv(p, xs, conv_state)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, state = ssd_chunked(
        xs.reshape(x.shape[0], x.shape[1], H, P), Bm, Cm, dt, A,
        init_state=init_state, chunk=cfg.ssm.chunk_size,
    )
    y = y + (p["D"].astype(jnp.float32)[None, None, :, None]
             * xs.reshape(y.shape).astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(x.shape[0], x.shape[1], d_inner)
    y = rmsnorm(p["out_norm"], y) * jax.nn.silu(gate)
    return y @ p["w_out"], (new_conv, state)


def mamba2_decode(p, x, conv_state, ssm_state, cfg: ArchConfig):
    """Single-token decode.  x: (B, 1, d_model);
    conv_state: (B, W-1, d_inner); ssm_state: (B, H, P, N)."""
    d_inner, H, P, N = mamba2_dims(cfg)
    Bsz = x.shape[0]
    xs, gate, Bm, Cm, dt = _split_in(p, x, cfg)
    xs, new_conv = _causal_conv(p, xs, conv_state)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xs_h = xs.reshape(Bsz, H, P).astype(jnp.float32)
    dt1 = dt[:, 0]                                              # (B, H)
    a = jnp.exp(A[None] * dt1)                                  # (B, H)
    upd = jnp.einsum("bhp,bn->bhpn", xs_h * dt1[..., None], Bm[:, 0].astype(jnp.float32))
    new_state = ssm_state.astype(jnp.float32) * a[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cm[:, 0].astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xs_h
    y = y.reshape(Bsz, 1, d_inner).astype(x.dtype)
    y = rmsnorm(p["out_norm"], y) * jax.nn.silu(gate)
    return y @ p["w_out"], (new_conv, new_state.astype(ssm_state.dtype))
