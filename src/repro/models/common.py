"""Shared model components: init helpers, norms, rotary / M-RoPE, attention
(full / sliding-window / cached decode), and MLP blocks.

All components are pure functions over nested-dict parameter pytrees — no
module framework.  Naming convention for parameters matters: the launch-layer
sharding rules (``repro.launch.sharding``) match on path substrings like
``w_in``/``w_out``/``embed``/``experts`` to assign PartitionSpecs.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale: float | None = None):
    """Truncated-normal fan-in init (LeCun-ish), matmul weight (d_in, d_out)."""
    if scale is None:
        scale = 1.0 / math.sqrt(d_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_params(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_params(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * lax.rsqrt(var + eps)
    if p is not None and "scale" in p:
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(dt)


def nonparam_ln(x, eps: float = 1e-5):
    """OLMo-style LayerNorm without learnable affine. [arXiv:2402.00838]"""
    return layernorm(None, x, eps)


def make_norm(kind: str, d: int, dtype=jnp.float32):
    """Returns (params, apply_fn). ``nonparam_ln`` carries an empty dict so the
    pytree structure stays uniform across layer kinds."""
    if kind == "rmsnorm":
        return rmsnorm_params(d, dtype), rmsnorm
    if kind == "layernorm":
        return layernorm_params(d, dtype), layernorm
    if kind == "nonparam_ln":
        return {}, lambda p, x: nonparam_ln(x)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    half = x.shape[-1] // 2
    inv = rope_freqs(x.shape[-1], theta)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., seq, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., seq, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


def apply_mrope(x, positions_thw, theta: float, sections: Tuple[int, ...]):
    """Qwen2-VL M-RoPE. [arXiv:2409.12191]

    x: (B, S, H, D); positions_thw: (B, S, 3) temporal/height/width position ids.
    ``sections`` splits the D//2 rotary frequencies into (t, h, w) groups; each
    group rotates by its own position id.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    inv = rope_freqs(x.shape[-1], theta)  # (half,)
    # per-frequency position: section 0 -> t, 1 -> h, 2 -> w
    sec_id = jnp.repeat(jnp.arange(3), jnp.asarray(sections), total_repeat_length=half)  # (half,)
    pos = jnp.take_along_axis(
        positions_thw.astype(jnp.float32),
        jnp.broadcast_to(sec_id[None, None, :], positions_thw.shape[:2] + (half,)).astype(jnp.int32),
        axis=-1,
    )  # (B, S, half)
    ang = pos * inv  # (B, S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


def sinusoidal_positions(seq: int, d: int, dtype=jnp.float32):
    """Whisper-style sinusoidal embeddings."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / max(d // 2 - 1, 1)))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

# Decode-cache write implementation (EXPERIMENTS.md §Perf):
#   "onehot" — cache*(1-oh) + oh*new: two full-cache reads + one write.
#   "dus"    — vmapped dynamic_update_slice: one slice write; with buffer
#              donation the cache is updated in place (3x less HBM traffic).
CACHE_UPDATE = "onehot"


def write_cache(cache, new, idx):
    """cache: (B, C, ...); new: (B, 1, ...); idx: (B,) target slot."""
    if CACHE_UPDATE == "onehot":
        oh = jax.nn.one_hot(idx, cache.shape[1], dtype=cache.dtype)  # (B, C)
        oh = oh.reshape(oh.shape + (1,) * (cache.ndim - 2))
        return cache * (1.0 - oh) + oh * new
    def one(c, n, i):  # c: (C, ...) per-example slice
        return lax.dynamic_update_slice(c, n.astype(c.dtype),
                                        (i,) + (0,) * (c.ndim - 1))
    return jax.vmap(one)(cache, new, idx)


def attn_params(key, d_model: int, num_heads: int, num_kv: int, head_dim: int, dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, d_model, num_heads * head_dim, dtype),
        "wk": dense_init(kk, d_model, num_kv * head_dim, dtype),
        "wv": dense_init(kv, d_model, num_kv * head_dim, dtype),
        "wo": dense_init(ko, num_heads * head_dim, d_model, dtype, scale=1.0 / math.sqrt(num_heads * head_dim)),
    }


def _repeat_kv(k, num_heads: int):
    """(B, S, KV, D) -> (B, S, H, D) by repeating kv groups."""
    num_kv = k.shape[-2]
    if num_kv == num_heads:
        return k
    rep = num_heads // num_kv
    return jnp.repeat(k, rep, axis=-2)


# GQA attention implementation (EXPERIMENTS.md §Perf):
#   "repeat"  — materialize K/V repeated to H heads (paper-era baseline;
#               at decode this re-reads the cache x(H/KV)).
#   "grouped" — einsum directly against the KV-head cache with a query-group
#               axis: exact same math, no repeated cache materialization.
GQA_IMPL = "repeat"


def _grouped_attn(q, k, v, mask_fn, dtype):
    """q: (B,Sq,H,D); k/v: (B,Sk,KV,D); mask_fn(logits (B,KV,G,Sq,Sk))."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, D)
    scale = 1.0 / math.sqrt(D)
    logits = jnp.einsum("bqkgd,bckd->bkgqc", qg, k).astype(jnp.float32) * scale
    logits = mask_fn(logits)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqc,bckd->bqkgd", probs, v)
    return out.reshape(B, Sq, H, D)


def sdpa(q, k, v, *, causal: bool, window: int = 0, q_offset=0, kv_valid_len=None):
    """Reference scaled-dot-product attention with optional causal +
    sliding-window masking.  q: (B, Sq, H, D), k/v: (B, Sk, KV, D).

    ``q_offset``: absolute position of q[0] relative to k[0] (decode: Sk-1).
    ``window``: if > 0, keys older than ``window`` positions are masked.
    ``kv_valid_len``: (B,) number of valid cache entries (decode).
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    q_pos = jnp.arange(Sq) + q_offset  # (Sq,)
    k_pos = jnp.arange(Sk)  # (Sk,)
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= k_pos[None, :] > q_pos[:, None] - window

    if GQA_IMPL == "grouped" and k.shape[2] != H:
        def mask_fn(logits):  # (B, KV, G, Sq, Sk)
            lg = jnp.where(mask[None, None, None], logits, -1e30)
            if kv_valid_len is not None:
                vm = k_pos[None, :] < kv_valid_len[:, None]
                lg = jnp.where(vm[:, None, None, None, :], lg, -1e30)
            return lg
        return _grouped_attn(q, k, v, mask_fn, q.dtype)

    k = _repeat_kv(k, H)
    v = _repeat_kv(v, H)
    scale = 1.0 / math.sqrt(D)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    logits = jnp.where(mask[None, None], logits, -1e30)
    if kv_valid_len is not None:
        vmask = k_pos[None, :] < kv_valid_len[:, None]  # (B, Sk)
        logits = jnp.where(vmask[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


# Prefill attention implementation (EXPERIMENTS.md §Perf):
#   "naive"   — materializes the (B, H, S, S) logits tensor.
#   "chunked" — flash-style online softmax over KV chunks (lax.scan):
#               peak activation O(S·chunk) instead of O(S²).  The Pallas
#               swa_attention kernel is the TPU-tiled realization of the
#               same schedule; this is its jnp lowering for any backend.
ATTN_IMPL = "naive"
ATTN_CHUNK = 1024


def chunked_causal_attention(q, k, v, *, window: int = 0, chunk: int = 1024):
    """Online-softmax causal (optionally windowed) attention over KV chunks.
    q/k/v: (B, S, H|KV, D) -> (B, S, H, D)."""
    B, S, H, D = q.shape
    k = _repeat_kv(k, H)
    v = _repeat_kv(v, H)
    scale = 1.0 / math.sqrt(D)
    nc = S // chunk
    q_pos = jnp.arange(S)
    qf = q.astype(jnp.float32)

    def body(carry, i):
        m_prev, l_prev, acc = carry
        ks = lax.dynamic_slice_in_dim(k, i * chunk, chunk, axis=1)
        vs = lax.dynamic_slice_in_dim(v, i * chunk, chunk, axis=1)
        logits = jnp.einsum("bqhd,bkhd->bhqk", qf, ks.astype(jnp.float32)) * scale
        k_pos = i * chunk + jnp.arange(chunk)
        mask = k_pos[None, :] <= q_pos[:, None]
        if window > 0:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        logits = jnp.where(mask[None, None], logits, -1e30)
        m_cur = jnp.max(logits, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha.swapaxes(1, 2) + jnp.einsum(
            "bhqk,bkhd->bqhd", p, vs.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, H, S, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((B, H, S, 1), jnp.float32)
    a0 = jnp.zeros((B, S, H, D), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), jnp.arange(nc))
    out = acc / jnp.maximum(l, 1e-30).swapaxes(1, 2)
    return out.astype(q.dtype)


def attn_forward(p, x, *, num_heads: int, num_kv: int, head_dim: int,
                 positions, rope_theta: float, causal: bool = True,
                 window: int = 0, mrope_sections: Tuple[int, ...] = (),
                 positions_thw=None, kv_override=None):
    """Full attention over a sequence (train / prefill).  Returns (out, (k, v))
    so the prefill path can emit the cache.  ``kv_override``: (k, v) from an
    encoder for cross-attention."""
    B, S, _ = x.shape
    q = x @ p["wq"]
    q = q.reshape(B, S, num_heads, head_dim)
    if kv_override is None:
        k = (x @ p["wk"]).reshape(B, S, num_kv, head_dim)
        v = (x @ p["wv"]).reshape(B, S, num_kv, head_dim)
        if rope_theta:
            if mrope_sections:
                q = apply_mrope(q, positions_thw, rope_theta, mrope_sections)
                k = apply_mrope(k, positions_thw, rope_theta, mrope_sections)
            else:
                q = apply_rope(q, positions, rope_theta)
                k = apply_rope(k, positions, rope_theta)
    else:
        k, v = kv_override
        if rope_theta:
            q = apply_rope(q, positions, rope_theta)
    if (ATTN_IMPL == "chunked" and causal and kv_override is None
            and S > ATTN_CHUNK and S % ATTN_CHUNK == 0):
        out = chunked_causal_attention(q, k, v, window=window, chunk=ATTN_CHUNK)
    else:
        out = sdpa(q, k, v, causal=causal and kv_override is None, window=window)
    out = out.reshape(B, S, num_heads * head_dim) @ p["wo"]
    return out, (k, v)


def attn_decode(p, x, cache_k, cache_v, cache_pos, *, num_heads: int, num_kv: int,
                head_dim: int, rope_theta: float, window: int = 0,
                ring: bool = False, mrope_sections: Tuple[int, ...] = (),
                positions_thw=None):
    """One-token cached decode.  x: (B, 1, d); cache_k/v: (B, C, KV, D);
    cache_pos: (B,) int32 absolute position of the new token.

    ``ring``: cache is a ring buffer of size C (sliding-window archs at 500k):
    the write index is ``cache_pos % C`` and all C slots attend once full.
    Keys are stored post-RoPE so ring eviction needs no re-rotation.
    """
    B = x.shape[0]
    C = cache_k.shape[1]
    q = (x @ p["wq"]).reshape(B, 1, num_heads, head_dim)
    k = (x @ p["wk"]).reshape(B, 1, num_kv, head_dim)
    v = (x @ p["wv"]).reshape(B, 1, num_kv, head_dim)
    if rope_theta:
        pos = cache_pos[:, None]
        if mrope_sections:
            q = apply_mrope(q, positions_thw, rope_theta, mrope_sections)
            k = apply_mrope(k, positions_thw, rope_theta, mrope_sections)
        else:
            q = apply_rope(q, pos, rope_theta)
            k = apply_rope(k, pos, rope_theta)
    write_idx = (cache_pos % C) if ring else jnp.minimum(cache_pos, C - 1)
    cache_k = write_cache(cache_k, k, write_idx)
    cache_v = write_cache(cache_v, v, write_idx)
    valid = jnp.minimum(cache_pos + 1, C)  # (B,)
    k_pos = jnp.arange(C)[None, :]  # slot index
    vmask = k_pos < valid[:, None]  # (B, C)
    if GQA_IMPL == "grouped" and num_kv != num_heads:
        def mask_fn(logits):  # (B, KV, G, 1, C)
            return jnp.where(vmask[:, None, None, None, :], logits, -1e30)
        out = _grouped_attn(q, cache_k, cache_v, mask_fn, x.dtype)
    else:
        kh = _repeat_kv(cache_k, num_heads)
        vh = _repeat_kv(cache_v, num_heads)
        scale = 1.0 / math.sqrt(head_dim)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, kh).astype(jnp.float32) * scale
        logits = jnp.where(vmask[:, None, None, :], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, vh)
    out = out.reshape(B, 1, num_heads * head_dim) @ p["wo"]
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLP / FFN
# ---------------------------------------------------------------------------


def mlp_params(key, d_model: int, d_ff: int, act: str, dtype=jnp.float32):
    if act in ("silu", "swiglu"):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "w_in": dense_init(k1, d_model, d_ff, dtype),
            "w_gate": dense_init(k2, d_model, d_ff, dtype),
            "w_out": dense_init(k3, d_ff, d_model, dtype, scale=1.0 / math.sqrt(d_ff)),
        }
    k1, k2 = jax.random.split(key, 2)
    return {
        "w_in": dense_init(k1, d_model, d_ff, dtype),
        "w_out": dense_init(k2, d_ff, d_model, dtype, scale=1.0 / math.sqrt(d_ff)),
    }


def mlp_forward(p, x, act: str):
    if act in ("silu", "swiglu"):
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_in"])) @ p["w_out"]
    if act == "gelu":
        return jax.nn.gelu(x @ p["w_in"]) @ p["w_out"]
    if act == "relu_sq":
        return jnp.square(jax.nn.relu(x @ p["w_in"])) @ p["w_out"]
    raise ValueError(act)
