"""Model registry — a uniform functional API over all 10 architectures.

Model methods (all pure, jit-able):
    init(rng, dtype)                        -> params
    forward(params, batch_inputs)           -> hidden (B, S, d_model)
    logits(params, hidden)                  -> (B, S, vocab)
    encode_segment(params, seg_inputs)      -> (B, d_model)   GST backbone F
    prefill(params, batch_inputs)           -> (last_logits, caches)
    init_cache(batch, cache_len, dtype)     -> caches
    decode_step(params, token, caches, pos) -> (logits, caches)

``batch_inputs`` is a dict: {"tokens": (B, S) int32, optional "patches"
(VLM stub embeddings), optional "frames" (audio stub embeddings)}.
``window`` (sliding-window attention) is a call-time option used by the
long_500k variant for dense archs (see DESIGN.md §Skips).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, transformer


@dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # -- init -------------------------------------------------------------
    def init(self, rng, dtype=jnp.float32):
        if self.cfg.is_encoder_decoder:
            return encdec.init_params(rng, self.cfg, dtype)
        return transformer.init_params(rng, self.cfg, dtype)

    # -- full-sequence forward (train / GST segment encode) ---------------
    def forward(self, params, inputs: Dict[str, Any], *, window: int = 0):
        cfg = self.cfg
        if cfg.is_encoder_decoder:
            enc_out = encdec.encode(params, cfg, inputs["frames"])
            hidden, _ = encdec.decoder_forward(params, cfg, inputs["tokens"], enc_out)
            return hidden
        hidden, _, aux = transformer.forward_hidden(
            params, cfg, inputs["tokens"], patches=inputs.get("patches"),
            mode="full", window=window)
        return hidden

    def forward_with_aux(self, params, inputs: Dict[str, Any], *, window: int = 0):
        cfg = self.cfg
        if cfg.is_encoder_decoder:
            return self.forward(params, inputs), jnp.zeros((), jnp.float32)
        hidden, _, aux = transformer.forward_hidden(
            params, cfg, inputs["tokens"], patches=inputs.get("patches"),
            mode="full", window=window)
        return hidden, aux

    def logits(self, params, hidden):
        if self.cfg.is_encoder_decoder:
            return hidden @ params["lm_head"]
        return transformer.lm_logits(params, self.cfg, hidden)

    # -- GST backbone F: segment -> embedding ------------------------------
    def encode_segment(self, params, inputs: Dict[str, Any]):
        """Mean-pooled final hidden state = segment embedding h_j (GST's F)."""
        if self.cfg.is_encoder_decoder:
            # audio GST: the *encoder* embeds frame segments (DESIGN.md §3)
            enc = encdec.encode(params, self.cfg, inputs["frames"])
            out = jnp.mean(enc, axis=1)
            return out, jnp.zeros((), jnp.float32)
        hidden, aux = self.forward_with_aux(params, inputs)
        return jnp.mean(hidden, axis=1), aux

    # -- serving ------------------------------------------------------------
    def init_cache(self, batch: int, cache_len: int, dtype):
        if self.cfg.is_encoder_decoder:
            return encdec.init_self_cache(self.cfg, batch, cache_len, dtype)
        return transformer.init_cache(self.cfg, batch, cache_len, dtype)

    def prefill(self, params, inputs: Dict[str, Any], *, window: int = 0):
        cfg = self.cfg
        if cfg.is_encoder_decoder:
            enc_out = encdec.encode(params, cfg, inputs["frames"])
            hidden, kv = encdec.decoder_forward(params, cfg, inputs["tokens"],
                                                enc_out, emit_cache=True)
            logits = hidden[:, -1:] @ params["lm_head"]
            xkv = encdec.cross_kv(params, cfg, enc_out)
            return logits, {"self": {"k": kv[0], "v": kv[1]}, "cross": xkv}
        hidden, caches, _ = transformer.forward_hidden(
            params, cfg, inputs["tokens"], patches=inputs.get("patches"),
            mode="full", window=window, emit_cache=True)
        logits = transformer.lm_logits(params, cfg, hidden[:, -1:])
        return logits, caches

    def decode_step(self, params, token, caches, cache_pos, *,
                    extras: Optional[Dict[str, Any]] = None,
                    window: int = 0, ring: bool = False,
                    moe_cap_len: int = 0):
        """moe_cap_len (MoE archs): sequence length the per-row expert
        capacity is computed from; 0 = the allocated cache length.  Pin it
        to the reference sequence length to reproduce a teacher-forced
        forward exactly when the cache is over-allocated."""
        cfg = self.cfg
        if cfg.is_encoder_decoder:
            logits, new_self = encdec.decode_step(
                params, cfg, token, caches["self"], caches["cross"], cache_pos)
            return logits, {"self": new_self, "cross": caches["cross"]}
        hidden, new_caches, _ = transformer.forward_hidden(
            params, cfg, token, mode="decode", caches=caches,
            cache_pos=cache_pos, window=window, ring=ring,
            moe_cap_len=moe_cap_len)
        logits = transformer.lm_logits(params, cfg, hidden)
        return logits, new_caches


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
