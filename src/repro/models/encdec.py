"""Whisper-style encoder-decoder backbone. [arXiv:2212.04356]

Per the assignment, the mel-spectrogram + conv feature extractor is a STUB:
the model consumes precomputed frame embeddings (B, encoder_seq_len, d_model)
supplied by ``input_specs()``.  We implement the transformer backbone:
  * encoder — non-causal self-attention blocks over frames (+ sinusoidal pos),
  * decoder — causal self-attention + cross-attention to encoder output,
  * decode path — self-attn KV cache + precomputed cross-attn K/V.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig


def _unroll() -> bool:
    from repro.models import transformer
    return transformer.SCAN_UNROLL
from repro.models.common import (
    attn_decode,
    attn_forward,
    attn_params,
    dense_init,
    embed_init,
    layernorm,
    layernorm_params,
    mlp_forward,
    mlp_params,
    sinusoidal_positions,
)


def _enc_block_init(key, cfg: ArchConfig, dtype):
    k1, k2 = jax.random.split(key)
    hd = cfg.resolved_head_dim
    return {
        "norm1": layernorm_params(cfg.d_model, dtype),
        "attn": attn_params(k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, hd, dtype),
        "norm2": layernorm_params(cfg.d_model, dtype),
        "mlp": mlp_params(k2, cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def _dec_block_init(key, cfg: ArchConfig, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    hd = cfg.resolved_head_dim
    return {
        "norm1": layernorm_params(cfg.d_model, dtype),
        "self_attn": attn_params(k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, hd, dtype),
        "norm_x": layernorm_params(cfg.d_model, dtype),
        "cross_attn": attn_params(k2, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, hd, dtype),
        "norm2": layernorm_params(cfg.d_model, dtype),
        "mlp": mlp_params(k3, cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def init_params(key, cfg: ArchConfig, dtype=jnp.float32):
    ke, kd, kt, kh = jax.random.split(key, 4)
    enc_keys = jax.random.split(ke, cfg.num_encoder_layers)
    dec_keys = jax.random.split(kd, cfg.num_layers)
    stack = lambda blocks: jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)
    return {
        "embed": embed_init(kt, cfg.vocab_size, cfg.d_model, dtype),
        "enc_blocks": stack([_enc_block_init(k, cfg, dtype) for k in enc_keys]),
        "enc_final": layernorm_params(cfg.d_model, dtype),
        "dec_blocks": stack([_dec_block_init(k, cfg, dtype) for k in dec_keys]),
        "final_norm": layernorm_params(cfg.d_model, dtype),
        "lm_head": dense_init(kh, cfg.d_model, cfg.vocab_size, dtype),
    }


def encode(params, cfg: ArchConfig, frames):
    """frames: (B, T, d_model) stub frame embeddings -> (B, T, d_model)."""
    hd = cfg.resolved_head_dim
    x = frames + sinusoidal_positions(frames.shape[1], cfg.d_model, frames.dtype)[None]
    pos = jnp.broadcast_to(jnp.arange(frames.shape[1])[None], frames.shape[:2])

    def body(h, p):
        a = layernorm(p["norm1"], h)
        o, _ = attn_forward(p["attn"], a, num_heads=cfg.num_heads,
                            num_kv=cfg.num_kv_heads, head_dim=hd, positions=pos,
                            rope_theta=0.0, causal=False)
        h = h + o
        m = layernorm(p["norm2"], h)
        return h + mlp_forward(p["mlp"], m, cfg.act), None

    x, _ = lax.scan(body, x, params["enc_blocks"], unroll=_unroll())
    return layernorm(params["enc_final"], x)


def cross_kv(params, cfg: ArchConfig, enc_out):
    """Precompute per-layer cross-attention K/V from encoder output."""
    hd = cfg.resolved_head_dim

    def body(_, p):
        B, T, _ = enc_out.shape
        k = (enc_out @ p["cross_attn"]["wk"]).reshape(B, T, cfg.num_kv_heads, hd)
        v = (enc_out @ p["cross_attn"]["wv"]).reshape(B, T, cfg.num_kv_heads, hd)
        return None, (k, v)

    _, kv = lax.scan(body, None, params["dec_blocks"], unroll=_unroll())
    return kv  # pytree with leading layer axis


def decoder_forward(params, cfg: ArchConfig, tokens, enc_out, *, emit_cache=False):
    """Teacher-forced decoder pass. Returns (hidden, self_kv_cache or None)."""
    hd = cfg.resolved_head_dim
    B, S = tokens.shape
    x = params["embed"][tokens]
    x = x + sinusoidal_positions(S, cfg.d_model, x.dtype)[None]
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    xkv = cross_kv(params, cfg, enc_out)

    def body(h, xs):
        p, (ck, cv) = xs
        a = layernorm(p["norm1"], h)
        o, (k, v) = attn_forward(p["self_attn"], a, num_heads=cfg.num_heads,
                                 num_kv=cfg.num_kv_heads, head_dim=hd,
                                 positions=pos, rope_theta=0.0, causal=True)
        h = h + o
        c = layernorm(p["norm_x"], h)
        o, _ = attn_forward(p["cross_attn"], c, num_heads=cfg.num_heads,
                            num_kv=cfg.num_kv_heads, head_dim=hd, positions=pos,
                            rope_theta=0.0, causal=False, kv_override=(ck, cv))
        h = h + o
        m = layernorm(p["norm2"], h)
        h = h + mlp_forward(p["mlp"], m, cfg.act)
        return h, (k, v) if emit_cache else None

    x, kv = lax.scan(body, x, (params["dec_blocks"], xkv), unroll=_unroll())
    return layernorm(params["final_norm"], x), kv


def init_self_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype):
    hd = cfg.resolved_head_dim
    shp = (cfg.num_layers, batch, cache_len, cfg.num_kv_heads, hd)
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}


def decode_step(params, cfg: ArchConfig, token, self_cache, xkv, cache_pos):
    """token: (B, 1); self_cache: stacked (L, B, C, KV, hd); xkv: cross K/V."""
    hd = cfg.resolved_head_dim
    B = token.shape[0]
    x = params["embed"][token]
    # sinusoidal position embedding gathered at the current step
    d = cfg.d_model
    full = sinusoidal_positions(self_cache["k"].shape[2], d, x.dtype)
    x = x + full[cache_pos][:, None, :]

    def body(h, xs):
        p, ck_l, cv_l, (xk, xv) = xs
        a = layernorm(p["norm1"], h)
        o, nk, nv = attn_decode(p["self_attn"], a, ck_l, cv_l, cache_pos,
                                num_heads=cfg.num_heads, num_kv=cfg.num_kv_heads,
                                head_dim=hd, rope_theta=0.0)
        h = h + o
        c = layernorm(p["norm_x"], h)
        pos = cache_pos[:, None]
        o, _ = attn_forward(p["cross_attn"], c, num_heads=cfg.num_heads,
                            num_kv=cfg.num_kv_heads, head_dim=hd, positions=pos,
                            rope_theta=0.0, causal=False, kv_override=(xk, xv))
        h = h + o
        m = layernorm(p["norm2"], h)
        h = h + mlp_forward(p["mlp"], m, cfg.act)
        return h, (nk, nv)

    x, (nk, nv) = lax.scan(body, x, (params["dec_blocks"], self_cache["k"],
                            self_cache["v"], xkv), unroll=_unroll())
    x = layernorm(params["final_norm"], x)
    logits = x @ params["lm_head"]
    return logits, {"k": nk, "v": nv}
