"""Distributed GST training launcher (data-parallel shard_map).

Runs Algorithm 1/2 over a 1-D data mesh with the row-sharded historical
table and the async host→device segment pipeline:

    # 8 forced host devices, complete method, async double buffering
    PYTHONPATH=src python -m repro.launch.train_dist \
        --devices 8 --variant gst_efd --backbone sage --epochs 5

    # synchronous feeder baseline on the same trace
    PYTHONPATH=src python -m repro.launch.train_dist \
        --devices 8 --feeder sync --epochs 5

    # owner-direct table exchange, capacity planned over the schedules
    PYTHONPATH=src python -m repro.launch.train_dist \
        --devices 8 --exchange bucketed --epochs 5

    # lookahead prefetch: batch k+1's exchange lookup dispatched while
    # step k runs, write-back patched (bit-exact at f32 payloads)
    PYTHONPATH=src python -m repro.launch.train_dist \
        --devices 8 --prefetch-lookups --epochs 5

``--devices N`` forces an N-device host via XLA_FLAGS when jax has not
initialized yet (CPU development / CI; on a real TPU slice leave it unset
to use the attached devices).
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def _force_device_count(n: int) -> None:
    if "jax" in sys.modules:
        return  # too late — use whatever is attached
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip())


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=None,
                    help="data-parallel width (forces an N-device host on "
                         "CPU when jax is not yet initialized)")
    ap.add_argument("--dataset", default="malnet", choices=["malnet"])
    ap.add_argument("--backbone", default="sage", choices=["gcn", "sage"])
    ap.add_argument("--variant", default="gst_efd")
    ap.add_argument("--n-graphs", type=int, default=64)
    ap.add_argument("--max-seg-nodes", type=int, default=32)
    ap.add_argument("--epochs", type=int, default=5)
    ap.add_argument("--finetune-epochs", type=int, default=3)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--lr", type=float, default=5e-3)
    ap.add_argument("--keep-prob", type=float, default=0.5)
    ap.add_argument("--num-sampled", type=int, default=1,
                    help="segments sampled for backprop per graph (S)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--feeder", default="async", choices=["async", "sync"],
                    help="host→device pipeline: async double buffering "
                         "(default) or the synchronous baseline")
    ap.add_argument("--depth", type=int, default=2,
                    help="async pipeline depth (in-flight device batches)")
    ap.add_argument("--exchange", default="ring",
                    choices=["ring", "alltoall", "bucketed", "auto"],
                    help="table-exchange strategy (dist/exchange.py): the "
                         "D-hop ppermute ring, full-buffer all_to_all "
                         "dissemination, owner-direct bucketed routing, or "
                         "auto = fewest analytic bytes per step at this "
                         "shard count")
    ap.add_argument("--exchange-cap", type=int, default=None,
                    help="bucketed only: per-(device, owner) bucket "
                         "capacity.  Default: planned host-side over the "
                         "run's precomputed id schedules "
                         "(exchange.plan_capacity — the tightest safe cap)")
    ap.add_argument("--payload-dtype", default="f32",
                    choices=["f32", "bf16", "int8"],
                    help="wire format for embedding payloads crossing the "
                         "exchange collectives (exchange.PayloadCodec): "
                         "f32 = identity (bit-exact), bf16, or int8 with a "
                         "per-row scale; write-backs use stochastic "
                         "rounding.  --exchange=auto re-picks the min-"
                         "bytes strategy at this dtype")
    ap.add_argument("--prefetch-lookups", action="store_true",
                    help="hide the exchange: dispatch batch k+1's table "
                         "lookup as its own collective while step k's "
                         "compute runs (dist.make_prefetch_lookup), and "
                         "restore read-after-write correctness with the "
                         "fused write-back patch "
                         "(exchange.update_sampled_patch).  Bit-exact vs "
                         "the inline exchange at --payload-dtype f32; "
                         "bounded-error under bf16/int8 like the inline "
                         "path.  Train loop only — refresh/finetune/eval "
                         "stay inline")
    ap.add_argument("--patch-cap", type=int, default=None,
                    help="bucketed + --prefetch-lookups only: per-(device, "
                         "consumer) bucket capacity of the patch hop.  "
                         "Default: planned host-side over the train "
                         "schedules (exchange.plan_patch_capacity)")
    ap.add_argument("--table-device-rows", type=int, default=None,
                    help="cap on device-resident historical-table rows "
                         "(total, split over shards; clamped up so every "
                         "shard can pin one batch).  The rest spill to a "
                         "host-RAM tier with async write-back.  Default: "
                         "whole table on device")
    ap.add_argument("--evict-policy", default="lru",
                    choices=["lru", "stale-first"],
                    help="tiered-store device-tier eviction policy under "
                         "--table-device-rows (store/slots.py)")
    ap.add_argument("--wb-threshold", type=float, default=0.0,
                    help="delta-gated write-back admission under "
                         "--table-device-rows: skip the host-tier emb "
                         "write for evicted rows whose embedding moved "
                         "less than this (max-abs) while resident "
                         "(store/writeback.delta_gate).  0 = gate off, "
                         "bit-exact store")
    ap.add_argument("--sed-age-weighting", type=float, default=0.0,
                    help="λ of the exp(-λ·age) staleness decay folded into "
                         "the stale branch of Eq.-1 η (use_sed+use_table "
                         "variants; ages read exactly through the exchange "
                         "collective).  0 = off, bit-exact to the "
                         "unweighted step")
    ap.add_argument("--stale-forecast", action="store_true",
                    help="extrapolate stale host-tier rows forward by "
                         "their age on fault-in via the online per-row "
                         "velocity forecaster (store/forecast.py); needs "
                         "--table-device-rows")
    # repro.obs is jax-free, so this is safe before _force_device_count
    from repro.obs import (Obs, StalenessProbe, add_obs_args,
                           record_exchange_bytes, record_prefetch_exchange)
    from repro.obs.trace import span
    add_obs_args(ap)
    args = ap.parse_args(argv)

    if args.devices:
        _force_device_count(args.devices)

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import dist as DT
    from repro.core import gst as G
    from repro.core.embedding_table import init_table
    from repro.dist import exchange as EXC
    from repro.dist import pipeline as DP
    from repro.dist import table as dtbl
    from repro.graphs import data as D
    from repro.graphs.gnn import GNNConfig, gnn_init, make_encode_fn
    from repro.optim import make_optimizer

    n_dev = args.devices or jax.device_count()
    if args.batch_size % n_dev:
        ap.error(f"--batch-size {args.batch_size} must be divisible by the "
                 f"device count {n_dev}")
    if args.epochs < 1:
        ap.error("--epochs must be >= 1")
    if args.n_graphs < args.batch_size:
        ap.error(f"--n-graphs {args.n_graphs} yields an empty drop-last "
                 f"epoch at --batch-size {args.batch_size}")

    graphs = D.make_malnet_like(n_graphs=args.n_graphs, seed=args.seed)
    ds, spec = DP.segment_dataset_shared(graphs, args.max_seg_nodes,
                                         seed=args.seed)
    var = G.VARIANTS[args.variant]
    cfg = GNNConfig(backbone=args.backbone, n_feat=graphs[0].x.shape[1],
                    hidden=args.hidden, use_pallas=args.use_pallas)
    enc = make_encode_fn(cfg)
    key = jax.random.key(args.seed)
    bb = gnn_init(key, cfg)
    head = G.head_init(jax.random.fold_in(key, 1), args.hidden, 5, "mlp")
    opt = make_optimizer("adam", lr=args.lr)
    state = G.TrainState(bb, head, opt.init((bb, head)),
                         init_table(ds.n, ds.j_max, args.hidden),
                         jnp.zeros((), jnp.int32))

    mesh = DT.make_dist_mesh(n_dev)
    device_rows = None
    if args.table_device_rows is not None:
        # every shard must be able to pin one batch's rows at once; the
        # prefetch lane keeps lookahead batches pinned (store.begin
        # pin=True, released after their step), so it needs room for the
        # in-flight window too: the running step, the prefetched next
        # batch, and up to --depth feeder batches begun ahead of them
        window = 1 if not args.prefetch_lookups else (
            2 if args.feeder == "sync" else args.depth + 2)
        device_rows = max(args.table_device_rows,
                          window * n_dev * args.batch_size)

    # precompute every id schedule up front (same rng draw order as the
    # former per-epoch draws, so traces are unchanged): the bucketed
    # exchange sizes its per-owner buckets host-side over the WHOLE run
    # (exchange.plan_capacity) before any step is built
    rng = np.random.default_rng(args.seed + 3)
    train_scheds = [DP.epoch_ids(ds, args.batch_size, rng=rng)
                    for _ in range(args.epochs)]
    refresh_sched = DP.epoch_ids(ds, args.batch_size, rng=rng, shuffle=False)
    ft_scheds = [DP.epoch_ids(ds, args.batch_size, rng=rng)
                 for _ in range(args.finetune_epochs)] \
        if var.finetune_head else []
    eval_sched = DP.epoch_ids(ds, args.batch_size, rng=rng, shuffle=False)

    # owner histograms are identical in graph-row and tiered slot space
    # (a row's slot stays on its owner shard), so capacity planned on
    # graph ids is exact for either table the step sees
    rows_per_shard = dtbl.rows_per_shard(ds.n, n_dev)
    exchange_batches = [ids for sched in
                        (*train_scheds, refresh_sched, *ft_scheds)
                        for ids in sched]
    need_cap = EXC.plan_capacity(exchange_batches, num_shards=n_dev,
                                 rows=rows_per_shard)
    cap = args.exchange_cap
    if cap is None:
        cap = need_cap
    elif cap < need_cap:
        ap.error(f"--exchange-cap {cap} is below the {need_cap} rows one "
                 "owner bucket needs for this run's schedules — the "
                 "bucketed exchange would silently truncate writes")
    b_local = args.batch_size // n_dev
    exchange = args.exchange
    if exchange == "auto":
        exchange = EXC.select_exchange(n_dev, b_local, ds.j_max,
                                       args.num_sampled, args.hidden,
                                       cap=cap,
                                       payload_dtype=args.payload_dtype)
    patch_cap = None
    if args.prefetch_lookups and exchange == "bucketed":
        # the patch hop routes this batch's write-backs to the shards
        # holding the NEXT batch's prefetched buffer — plan its bucket
        # capacity over consecutive pairs of each train epoch's schedule
        # (same graph-id/slot-space equivalence as plan_capacity above)
        need_patch = max(EXC.plan_patch_capacity(sched, num_shards=n_dev,
                                                 rows=rows_per_shard)
                         for sched in train_scheds)
        patch_cap = args.patch_cap
        if patch_cap is None:
            patch_cap = need_patch
        elif patch_cap < need_patch:
            ap.error(f"--patch-cap {patch_cap} is below the {need_patch} "
                     "rows one consumer bucket needs for this run's "
                     "schedules — the patch hop would silently drop "
                     "write-back repairs")
    ctx = DT.make_context(mesh, ds.n, device_rows=device_rows,
                          exchange=exchange,
                          exchange_cap=cap if exchange == "bucketed"
                          else None,
                          payload_dtype=args.payload_dtype,
                          prefetch=args.prefetch_lookups,
                          patch_cap=patch_cap)
    store = DT.make_dist_store(ctx, ds.j_max, args.hidden,
                               evict_policy=args.evict_policy,
                               wb_threshold=args.wb_threshold,
                               stale_forecast=args.stale_forecast)
    state = DT.device_state(ctx, state, store=store)
    step = DT.make_dist_train_step(enc, opt, var, ctx=ctx,
                                   keep_prob=args.keep_prob,
                                   num_sampled=args.num_sampled,
                                   use_pallas=args.use_pallas,
                                   sed_decay=args.sed_age_weighting)
    eval_step = DT.make_dist_eval_step(enc, ctx=ctx,
                                       use_pallas=args.use_pallas)
    ex_model = EXC.make_exchange(exchange, axis_name=DT.AXIS,
                                 num_shards=ctx.num_shards,
                                 rows=ctx.table_rows, cap=ctx.exchange_cap,
                                 payload_dtype=ctx.payload_dtype,
                                 patch_cap=ctx.patch_cap)
    xbytes = ex_model.train_step_bytes(b_local, ds.j_max, args.num_sampled,
                                       args.hidden, use_table=var.use_table)
    pxbytes = ex_model.prefetch_train_step_bytes(
        b_local, ds.j_max, args.num_sampled, args.hidden,
        use_table=var.use_table)
    print(f"[dist] devices={ctx.num_shards} rows/shard={ctx.rows_per_shard} "
          f"device-rows/shard={ctx.table_rows} "
          f"bucket={spec.key} feeder={args.feeder} "
          f"exchange={exchange} (payload={ex_model.payload_dtype}, "
          f"{xbytes / 1024:.1f} KiB/step/device"
          + (f", cap={cap}" if exchange == "bucketed" else "")
          + (f", prefetch {pxbytes / 1024:.1f} KiB"
             + (f", patch-cap={ctx.patch_cap}"
                if exchange == "bucketed" else "")
             if args.prefetch_lookups else "") + ")")

    obs = Obs.from_args(args, run="train_dist", variant=args.variant,
                        devices=ctx.num_shards, exchange=exchange,
                        payload_dtype=ex_model.payload_dtype,
                        epochs=args.epochs, batch_size=args.batch_size)
    probe = StalenessProbe(keep_prob=args.keep_prob,
                           num_sampled=args.num_sampled,
                           seg_valid=ds.seg_valid,
                           sed_decay=args.sed_age_weighting,
                           forecast=args.stale_forecast)

    try:
        # monotone per-begin counter, same clock the jitted steps write
        # ages with — the stale-first refresh hint for rows a train/
        # refresh step is about to rewrite (finetune only READS the
        # table, so its put passes no hint)
        step_counter = {"t": 0}

        def _put(b, counting, pin=False):
            # route graph ids -> store device rows on the feeder thread, so the
            # host-tier gather + staging device_put overlap with the running
            # step; the consumer commits the staged migration in order below
            hint = None
            if counting:
                hint = step_counter["t"]
                step_counter["t"] += 1
            prep = store.begin(np.asarray(b.graph_ids), step=hint, pin=pin)
            return prep, DT.shard_batch(ctx, b._replace(graph_ids=prep.slots))

        def put(b):
            return _put(b, True)

        def put_pinned(b):
            # prefetch train loop: lookahead batches stay pinned on the
            # device tier (later begins may not evict them) until the
            # driver releases them after their step is dispatched
            return _put(b, True, pin=True)

        def put_readonly(b):
            return _put(b, False)

        def print_store_line():
            s = store.stats()
            if ctx.device_rows_per_shard is not None:
                gate = (f", delta-gate skipped {s['wb_skipped_rows']} rows "
                        f"({s['wb_skipped_bytes'] / 1024:.1f} KiB)"
                        if s.get("wb_threshold", 0.0) > 0.0 else "")
                print(f"  store [{s['backend']}] device rows {s['device_rows']}/"
                      f"{s['n_rows']}  hit-rate {s['hit_rate']:.2f} "
                      f"({s['misses']} faults), {s['evictions']} evictions, "
                      f"{s['migration_bytes'] / 1024:.1f} KiB migrated, "
                      f"occupancy {s['occupancy']}{gate}", flush=True)

        if args.prefetch_lookups:
            prefetch_fn = DT.make_prefetch_lookup(ctx)
            bsh = DT.batch_sharding(ctx)
            sentinel = ctx.num_shards * ctx.table_rows

            def prefetch_dispatch(item):
                # runs at lane pull time, BEFORE the previous item's step
                # is launched: commit the staged migration, then dispatch
                # the lookup collective so it executes (same stream) ahead
                # of the donating step that would overwrite the table
                nonlocal state
                prep, batch = item
                with span("train.commit"):
                    state = state._replace(
                        table=store.commit(state.table, prep))
                return prefetch_fn(state.table, batch.graph_ids)

        def run_epoch_inline(epoch, feeder):
            nonlocal state
            losses = []
            for prep, batch in feeder:
                with span("train.commit"):
                    state = state._replace(
                        table=store.commit(state.table, prep))
                with span("train.step", epoch=epoch):
                    state, m = step(state, batch, jax.random.PRNGKey(epoch))
                record_exchange_bytes(exchange, ex_model.payload_dtype,
                                      xbytes)
                losses.append(m["loss"])
            return losses, feeder.stats

        def run_epoch_prefetch(epoch, feeder):
            nonlocal state
            lane = DP.PrefetchLane(feeder, prefetch_dispatch)
            rng = jax.random.PRNGKey(epoch)
            losses, pref = [], None
            for (prep, batch), cur_h, nxt, nxt_h in lane:
                if pref is None:
                    pref = cur_h   # first batch: nothing patched it yet
                if nxt is not None:
                    nprep, nbatch = nxt
                    next_ids, next_pair = nbatch.graph_ids, nxt_h
                    dest = EXC.consumer_shards(
                        np.asarray(prep.slots), np.asarray(nprep.slots),
                        num_shards=ctx.num_shards, rows=ctx.table_rows)
                else:
                    # epoch tail: sentinel consumers — the patch no-ops
                    # into a throwaway zero buffer
                    B = args.batch_size
                    next_ids = jax.device_put(
                        np.full((B,), sentinel, np.int32), bsh)
                    next_pair = (
                        jax.device_put(np.zeros((B, ds.j_max, args.hidden),
                                                np.float32), bsh),
                        jax.device_put(np.zeros((B, ds.j_max), bool), bsh))
                    dest = np.full((B,), ctx.num_shards, np.int32)
                patched_rows = int((dest != ctx.num_shards).sum())
                dest_dev = jax.device_put(np.asarray(dest, np.int32), bsh)
                with span("train.step", epoch=epoch):
                    state, m, pref = step(state, batch,
                                          rng, pref, next_pair,
                                          next_ids, dest_dev)
                store.release(prep)
                # exchange.bytes.* stays the run's total-traffic family
                # (prefetch moves the same bytes earlier; bucketed adds
                # its patch hop), exchange.prefetch.* is the lane's own
                record_exchange_bytes(exchange, ex_model.payload_dtype,
                                      pxbytes)
                record_prefetch_exchange(exchange, ex_model.payload_dtype,
                                         pxbytes, patched_rows)
                losses.append(m["loss"])
            return losses, lane.stats

        t_start = time.perf_counter()
        last_stats = None
        run_epoch = (run_epoch_prefetch if args.prefetch_lookups
                     else run_epoch_inline)
        for epoch, sched in enumerate(train_scheds):
            feeder = DP.make_feeder(
                args.feeder, ds, sched,
                put_pinned if args.prefetch_lookups else put,
                depth=args.depth)
            losses, last_stats = run_epoch(epoch, feeder)
            jax.block_until_ready(losses[-1])
            print(f"epoch {epoch}: loss={float(losses[-1]):.4f} "
                  f"host_blocked={last_stats.host_blocked_ms_per_batch:.2f} "
                  f"ms/batch", flush=True)
            # resident rows rewritten this epoch re-report their true
            # device-plane ages to the eviction bookkeeping (no-op under
            # plain LRU)
            store.refresh_ages(state.table)
            if obs.enabled:
                # per-epoch observability: staleness probe over the merged
                # table view + registry delta() — PER-EPOCH rates, not the
                # cumulative counters the old store line reported
                store.publish_counters()
                stale = probe.observe(store, state.table, step_counter["t"])
                d = (obs.tick(step=step_counter["t"], epoch=epoch,
                              loss=float(losses[-1]),
                              staleness=stale) or {}).get("delta") \
                    or obs.registry.delta()
                print(f"  obs epoch {epoch}: faults {d.get('store.faults', 0):.0f} "
                      f"evictions {d.get('store.evictions', 0):.0f} "
                      f"exch KiB {sum(v for k, v in d.items() if k.startswith('exchange.bytes.')) / 1024:.1f} "
                      f"row-age p99 {stale['row_age_steps']['p99']:.0f} steps "
                      f"sed-drop {stale['sed_drop_rate']:.3f}", flush=True)
        print_store_line()

        if var.finetune_head:
            refresh = DT.make_dist_refresh_step(enc, ctx=ctx)
            for prep, batch in DP.make_feeder("sync", ds, refresh_sched, put):
                state = state._replace(table=store.commit(state.table, prep))
                state = refresh(state, batch)
            ft_opt = make_optimizer("adam", lr=args.lr * 0.5)
            state = state._replace(
                opt_state=DT.replicate(ctx, ft_opt.init(jax.device_get(state.head))))
            ft = DT.make_dist_finetune_step(ft_opt, ctx=ctx,
                                            use_pallas=args.use_pallas)
            m = None
            for sched in ft_scheds:
                for prep, batch in DP.make_feeder(
                        args.feeder, ds, sched, put_readonly,
                        depth=args.depth):
                    state = state._replace(table=store.commit(state.table, prep))
                    state, m = ft(state, batch)
            if m is not None:
                print(f"finetune: loss={float(m['loss']):.4f}")

        # eval never reads the table — no store routing (a begun-but-uncommitted
        # migration would corrupt residency bookkeeping)
        metrics = []
        for batch in DP.make_feeder("sync", ds, eval_sched,
                                    lambda b: DT.shard_batch(ctx, b)):
            metrics.append(float(eval_step(state, batch)["metric"]))
        # surface any failed async write-back BEFORE reporting success
        store.flush_writebacks()
        wall = time.perf_counter() - t_start
        print(f"[dist] done in {wall:.1f}s — train metric "
              f"{float(np.mean(metrics)):.3f}, host blocked "
              f"{last_stats.host_blocked_ms_per_batch:.2f} ms/batch "
              f"({args.feeder})")
        print_store_line()
        if obs.enabled:
            store.publish_counters()
            probe.observe_store_counters(store.counters.as_dict())
        obs.close(wall_s=wall, train_metric=float(np.mean(metrics)))
    finally:
        store.close()   # stop the write-back thread even on error
        obs.close()


if __name__ == "__main__":
    main()
