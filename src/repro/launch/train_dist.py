"""Distributed GST training launcher (data-parallel shard_map).

Runs Algorithm 1/2 over a 1-D data mesh with the row-sharded historical
table and the async host→device segment pipeline:

    # 8 forced host devices, complete method, async double buffering
    PYTHONPATH=src python -m repro.launch.train_dist \
        --devices 8 --variant gst_efd --backbone sage --epochs 5

    # synchronous feeder baseline on the same trace
    PYTHONPATH=src python -m repro.launch.train_dist \
        --devices 8 --feeder sync --epochs 5

``--devices N`` forces an N-device host via XLA_FLAGS when jax has not
initialized yet (CPU development / CI; on a real TPU slice leave it unset
to use the attached devices).
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def _force_device_count(n: int) -> None:
    if "jax" in sys.modules:
        return  # too late — use whatever is attached
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip())


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=None,
                    help="data-parallel width (forces an N-device host on "
                         "CPU when jax is not yet initialized)")
    ap.add_argument("--dataset", default="malnet", choices=["malnet"])
    ap.add_argument("--backbone", default="sage", choices=["gcn", "sage"])
    ap.add_argument("--variant", default="gst_efd")
    ap.add_argument("--n-graphs", type=int, default=64)
    ap.add_argument("--max-seg-nodes", type=int, default=32)
    ap.add_argument("--epochs", type=int, default=5)
    ap.add_argument("--finetune-epochs", type=int, default=3)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--lr", type=float, default=5e-3)
    ap.add_argument("--keep-prob", type=float, default=0.5)
    ap.add_argument("--num-sampled", type=int, default=1,
                    help="segments sampled for backprop per graph (S)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--feeder", default="async", choices=["async", "sync"],
                    help="host→device pipeline: async double buffering "
                         "(default) or the synchronous baseline")
    ap.add_argument("--depth", type=int, default=2,
                    help="async pipeline depth (in-flight device batches)")
    ap.add_argument("--table-device-rows", type=int, default=None,
                    help="cap on device-resident historical-table rows "
                         "(total, split over shards; clamped up so every "
                         "shard can pin one batch).  The rest spill to a "
                         "host-RAM tier with async write-back.  Default: "
                         "whole table on device")
    args = ap.parse_args(argv)

    if args.devices:
        _force_device_count(args.devices)

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import dist as DT
    from repro.core import gst as G
    from repro.core.embedding_table import init_table
    from repro.dist import pipeline as DP
    from repro.dist import table as dtbl
    from repro.graphs import data as D
    from repro.graphs.gnn import GNNConfig, gnn_init, make_encode_fn
    from repro.optim import make_optimizer

    n_dev = args.devices or jax.device_count()
    if args.batch_size % n_dev:
        ap.error(f"--batch-size {args.batch_size} must be divisible by the "
                 f"device count {n_dev}")
    if args.epochs < 1:
        ap.error("--epochs must be >= 1")
    if args.n_graphs < args.batch_size:
        ap.error(f"--n-graphs {args.n_graphs} yields an empty drop-last "
                 f"epoch at --batch-size {args.batch_size}")

    graphs = D.make_malnet_like(n_graphs=args.n_graphs, seed=args.seed)
    ds, spec = DP.segment_dataset_shared(graphs, args.max_seg_nodes,
                                         seed=args.seed)
    var = G.VARIANTS[args.variant]
    cfg = GNNConfig(backbone=args.backbone, n_feat=graphs[0].x.shape[1],
                    hidden=args.hidden, use_pallas=args.use_pallas)
    enc = make_encode_fn(cfg)
    key = jax.random.key(args.seed)
    bb = gnn_init(key, cfg)
    head = G.head_init(jax.random.fold_in(key, 1), args.hidden, 5, "mlp")
    opt = make_optimizer("adam", lr=args.lr)
    state = G.TrainState(bb, head, opt.init((bb, head)),
                         init_table(ds.n, ds.j_max, args.hidden),
                         jnp.zeros((), jnp.int32))

    mesh = DT.make_dist_mesh(n_dev)
    device_rows = None
    if args.table_device_rows is not None:
        # every shard must be able to pin one batch's rows at once
        device_rows = max(args.table_device_rows, n_dev * args.batch_size)
    ctx = DT.make_context(mesh, ds.n, device_rows=device_rows)
    store = DT.make_dist_store(ctx, ds.j_max, args.hidden)
    state = DT.device_state(ctx, state, store=store)
    step = DT.make_dist_train_step(enc, opt, var, ctx=ctx,
                                   keep_prob=args.keep_prob,
                                   num_sampled=args.num_sampled,
                                   use_pallas=args.use_pallas)
    eval_step = DT.make_dist_eval_step(enc, ctx=ctx,
                                       use_pallas=args.use_pallas)
    xbytes = dtbl.train_step_exchange_bytes(
        ctx.num_shards, args.batch_size // ctx.num_shards, ds.j_max,
        args.num_sampled, args.hidden, use_table=var.use_table)
    print(f"[dist] devices={ctx.num_shards} rows/shard={ctx.rows_per_shard} "
          f"device-rows/shard={ctx.table_rows} "
          f"bucket={spec.key} feeder={args.feeder} "
          f"exchange={xbytes / 1024:.1f} KiB/step/device")

    try:
        rng = np.random.default_rng(args.seed + 3)

        def put(b):
            # route graph ids -> store device rows on the feeder thread, so the
            # host-tier gather + staging device_put overlap with the running
            # step; the consumer commits the staged migration in order below
            prep = store.begin(np.asarray(b.graph_ids))
            return prep, DT.shard_batch(ctx, b._replace(graph_ids=prep.slots))

        def print_store_line():
            s = store.stats()
            if ctx.device_rows_per_shard is not None:
                print(f"  store [{s['backend']}] device rows {s['device_rows']}/"
                      f"{s['n_rows']}  hit-rate {s['hit_rate']:.2f} "
                      f"({s['misses']} faults), {s['evictions']} evictions, "
                      f"{s['migration_bytes'] / 1024:.1f} KiB migrated, "
                      f"occupancy {s['occupancy']}", flush=True)

        t_start = time.perf_counter()
        last_stats = None
        for epoch in range(args.epochs):
            feeder = DP.make_feeder(args.feeder, ds,
                                    DP.epoch_ids(ds, args.batch_size, rng=rng),
                                    put, depth=args.depth)
            losses = []
            for prep, batch in feeder:
                state = state._replace(table=store.commit(state.table, prep))
                state, m = step(state, batch, jax.random.PRNGKey(epoch))
                losses.append(m["loss"])
            jax.block_until_ready(losses[-1])
            last_stats = feeder.stats
            print(f"epoch {epoch}: loss={float(losses[-1]):.4f} "
                  f"host_blocked={last_stats.host_blocked_ms_per_batch:.2f} "
                  f"ms/batch", flush=True)
        print_store_line()

        if var.finetune_head:
            refresh = DT.make_dist_refresh_step(enc, ctx=ctx)
            for prep, batch in DP.make_feeder(
                    "sync", ds,
                    DP.epoch_ids(ds, args.batch_size, rng=rng, shuffle=False),
                    put):
                state = state._replace(table=store.commit(state.table, prep))
                state = refresh(state, batch)
            ft_opt = make_optimizer("adam", lr=args.lr * 0.5)
            state = state._replace(
                opt_state=DT.replicate(ctx, ft_opt.init(jax.device_get(state.head))))
            ft = DT.make_dist_finetune_step(ft_opt, ctx=ctx,
                                            use_pallas=args.use_pallas)
            m = None
            for fe in range(args.finetune_epochs):
                for prep, batch in DP.make_feeder(
                        args.feeder, ds,
                        DP.epoch_ids(ds, args.batch_size, rng=rng), put,
                        depth=args.depth):
                    state = state._replace(table=store.commit(state.table, prep))
                    state, m = ft(state, batch)
            if m is not None:
                print(f"finetune: loss={float(m['loss']):.4f}")

        # eval never reads the table — no store routing (a begun-but-uncommitted
        # migration would corrupt residency bookkeeping)
        metrics = []
        for batch in DP.make_feeder(
                "sync", ds, DP.epoch_ids(ds, args.batch_size, rng=rng,
                                         shuffle=False),
                lambda b: DT.shard_batch(ctx, b)):
            metrics.append(float(eval_step(state, batch)["metric"]))
        # surface any failed async write-back BEFORE reporting success
        store.flush_writebacks()
        wall = time.perf_counter() - t_start
        print(f"[dist] done in {wall:.1f}s — train metric "
              f"{float(np.mean(metrics)):.3f}, host blocked "
              f"{last_stats.host_blocked_ms_per_batch:.2f} ms/batch "
              f"({args.feeder})")
        print_store_line()
    finally:
        store.close()   # stop the write-back thread even on error


if __name__ == "__main__":
    main()
