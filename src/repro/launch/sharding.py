"""Sharding rules: parameter-path patterns -> PartitionSpecs (DESIGN.md §5).

Scheme: FSDP over the ('pod','data') axes × tensor parallel over 'model'.
Rules give a spec for the TRAILING dims of a leaf; leading dims (e.g. the
stacked layer axis of scan runs, the expert axis handled explicitly) are
replicated by padding with None.  Every sharded dim is divisibility-checked
against the mesh axis size and falls back to replication when it does not
divide — head counts like 56 or 20 on a 16-way model axis replicate rather
than fail to lower.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import fsdp_axes, model_axis

# (regex over param path, trailing-dim logical spec)
# logical axes: "fsdp" -> ('pod','data'), "model" -> 'model', None -> replicate
_RULES: Sequence[Tuple[str, Tuple]] = (
    (r"experts/w_(in|gate)$", ("model", "fsdp", None)),
    (r"experts/w_out$", ("model", None, "fsdp")),
    (r"router$", ("fsdp", "model")),
    (r"(^|/)embed$", ("model", "fsdp")),           # (vocab, d)
    (r"lm_head$", ("fsdp", "model")),              # (d, vocab)
    (r"conv_w$", (None, "model")),                 # (W, d_inner)
    # column-parallel projections (d_in, d_out): out dim over model
    (r"(wq|wk|wv|wg|wr|wq_a|wq_b|wkv_a|wk_b|wv_b|w_in|w_gate|w_msg|"
     r"w_gate_src|w_gate_dst|w_decay_a|mlp_in|w1)$", ("fsdp", "model")),
    # row-parallel projections (d_in, d_out): in dim over model
    (r"(wo|w_out|w_rec|w_decay_b|mlp_out|w2)$", ("model", "fsdp")),
)


def _axis_size(mesh: Mesh, logical, multi: bool) -> int:
    if logical is None:
        return 1
    if logical == "fsdp":
        n = 1
        for a in fsdp_axes(mesh):
            n *= mesh.shape[a]
        return n
    return mesh.shape.get(logical, 1)


def _resolve(mesh: Mesh, logical):
    if logical is None:
        return None
    if logical == "fsdp":
        ax = fsdp_axes(mesh)
        return ax if len(ax) > 1 else (ax[0] if ax else None)
    return logical if logical in mesh.axis_names else None


# §Perf override hook: (regex, trailing spec) entries checked BEFORE _RULES.
# Used by the head-aligned-sharding experiment: when head counts don't divide
# the model axis (56 or 20 heads on 16-way TP; kv=8 on 16), column-sharding
# the QKV projections splits heads across devices and GSPMD re-aligns them
# with all-gathers around every attention — replicating those columns trades
# parameter memory for the collectives.  Set via launch/dryrun.py
# --head-aligned-sharding; cleared by default.
OVERRIDES: list = []


def head_aligned_overrides(cfg, mesh) -> list:
    n_model = mesh.shape.get("model", 1)
    o = []
    misaligned = (cfg.num_heads and cfg.num_heads % n_model) or \
                 (cfg.num_kv_heads and cfg.num_kv_heads % n_model)
    if misaligned:
        # Q sharding must tile the KV-group structure or GSPMD reshards the
        # whole cache around every attention; when either head count doesn't
        # divide the model axis, replicate the whole attention projection set
        # (the model axis still shards the FFN, which is the FLOPs majority).
        o.append((r"(wq|wq_b)$", ("fsdp", None)))
        o.append((r"(wk|wv)$", ("fsdp", None)))
        o.append((r"wo$", (None, "fsdp")))
    return o


def spec_for_path(mesh: Mesh, path: str, shape: Tuple[int, ...]) -> P:
    for pat, trailing in list(OVERRIDES) + list(_RULES):
        if re.search(pat, path):
            trailing = trailing[-len(shape):] if len(shape) < len(trailing) else trailing
            full = (None,) * (len(shape) - len(trailing)) + tuple(trailing)
            axes = []
            for dim, logical in zip(shape, full):
                if logical is not None and dim % _axis_size(mesh, logical, True) == 0:
                    axes.append(_resolve(mesh, logical))
                else:
                    axes.append(None)
            return P(*axes)
    return P()  # replicate (norm scales, biases, 1-D params)


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def tree_shardings(mesh: Mesh, tree_shapes: Any) -> Any:
    """Map a pytree of ShapeDtypeStructs/arrays to NamedShardings via rules."""
    def f(path, leaf):
        spec = spec_for_path(mesh, _path_str(path), tuple(leaf.shape))
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(f, tree_shapes)


# ---------------------------------------------------------------------------
# data / state shardings
# ---------------------------------------------------------------------------


def batch_spec(mesh: Mesh, batch: int, ndim: int) -> P:
    """Shard leading batch dim over the fsdp axes when divisible."""
    n = _axis_size(mesh, "fsdp", True)
    lead = _resolve(mesh, "fsdp") if (n > 1 and batch % n == 0) else None
    return P(lead, *([None] * (ndim - 1)))


def batch_sharding(mesh: Mesh, tree_shapes: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda l: NamedSharding(mesh, batch_spec(mesh, l.shape[0], l.ndim)),
        tree_shapes)


def table_sharding(mesh: Mesh, table_shapes) -> Any:
    """Historical embedding table: graph-id rows over the fsdp axes."""
    return jax.tree_util.tree_map(
        lambda l: NamedSharding(mesh, batch_spec(mesh, l.shape[0], l.ndim)),
        table_shapes)


def cache_spec(mesh: Mesh, path: str, shape: Tuple[int, ...],
               seq_shard: bool = False) -> P:
    """KV/state caches.

    Cache kinds by leaf name; a leading stacked-layer axis (scan runs,
    whisper stacked decoder) is detected by ndim and always replicated:

        k/v    (L?, B, C, KV, hd) — batch over fsdp, KV heads over model
        ckv/kr (L?, B, C, r)      — batch over fsdp (latent replicated)
        conv   (L?, B, W-1, d_in) — batch over fsdp, channels over model
        ssm    (L?, B, H, P, N)   — batch over fsdp, heads over model
        state  (L?, B, H, N, N)   — batch over fsdp, heads over model
        shift* (L?, B, d)         — batch over fsdp

    ``seq_shard=True`` (long_500k, batch=1): shard the *sequence* dim of
    attention caches over the fsdp axes instead — sequence-parallel decode
    (DESIGN.md §5); XLA partitions the softmax reduction across shards.
    """
    n_fsdp = _axis_size(mesh, "fsdp", True)
    n_model = _axis_size(mesh, "model", True)
    fsdp = _resolve(mesh, "fsdp")
    model = _resolve(mesh, "model")
    name = path.rsplit("/", 1)[-1]
    ndim = len(shape)
    # (kind, base ndim without the stacked-layer axis)
    if name in ("k", "v") or (name not in ("ckv", "kr", "conv", "ssm", "state",
                                           "shift_tm", "shift_cm") and ndim >= 5):
        kind, base = "kv", 4
    elif name in ("ckv", "kr"):
        kind, base = "latent", 3
    elif name == "conv":
        kind, base = "conv", 3
    elif name in ("ssm", "state"):
        kind, base = "heads", 4
    elif name in ("shift_tm", "shift_cm"):
        kind, base = "shift", 2
    else:
        kind, base = "other", ndim
    off = ndim - base  # 1 if a stacked-layer axis leads, else 0
    axes: list = [None] * ndim
    if off < 0 or off > 1:
        return P(*axes)
    b_i = off  # batch dim index
    if kind == "kv":
        seq_i, kv_i = off + 1, off + 2
        if seq_shard:
            if shape[seq_i] % n_fsdp == 0:
                axes[seq_i] = fsdp
        elif n_fsdp > 1 and shape[b_i] % n_fsdp == 0:
            axes[b_i] = fsdp
        if shape[kv_i] % n_model == 0:
            axes[kv_i] = model
    elif kind == "latent":
        seq_i = off + 1
        if seq_shard:
            if shape[seq_i] % n_fsdp == 0:
                axes[seq_i] = fsdp
        elif n_fsdp > 1 and shape[b_i] % n_fsdp == 0:
            axes[b_i] = fsdp
    elif kind == "conv":
        if n_fsdp > 1 and shape[b_i] % n_fsdp == 0:
            axes[b_i] = fsdp
        if shape[off + 2] % n_model == 0:
            axes[off + 2] = model
    elif kind == "heads":
        if n_fsdp > 1 and shape[b_i] % n_fsdp == 0:
            axes[b_i] = fsdp
        if shape[off + 1] % n_model == 0:
            axes[off + 1] = model
    elif kind == "shift":
        if n_fsdp > 1 and shape[b_i] % n_fsdp == 0:
            axes[b_i] = fsdp
    return P(*axes)


def cache_sharding(mesh: Mesh, cache_shapes, *, seq_shard: bool = False):
    def f(path, leaf):
        return NamedSharding(
            mesh, cache_spec(mesh, _path_str(path), tuple(leaf.shape), seq_shard))
    return jax.tree_util.tree_map_with_path(f, cache_shapes)


def replicated(mesh: Mesh, tree_shapes: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda l: NamedSharding(mesh, P()), tree_shapes)
