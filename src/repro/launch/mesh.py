"""Production meshes (assignment spec).

Axes:
    single pod : (data=16, model=16)              — 256 chips (TPU v5e pod)
    multi-pod  : (pod=2, data=16, model=16)       — 512 chips

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; tests see 1 CPU).
"""
from __future__ import annotations

import jax


def _mesh_kwargs(n_axes: int) -> dict:
    """axis_types only where this jax has it (jax.sharding.AxisType landed
    after 0.4.x; older versions default to Auto semantics anyway)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 1
    for s in shape:
        need *= s
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devs)} — the "
            "dry-run entrypoint must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before importing jax (launch/dryrun.py does).")
    return jax.make_mesh(shape, axes, devices=devs[:need],
                         **_mesh_kwargs(len(axes)))


def make_debug_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1],
                         **_mesh_kwargs(2))


def fsdp_axes(mesh) -> tuple:
    """The batch/FSDP axes: ('pod','data') on multipod, ('data',) otherwise."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str | None:
    return "model" if "model" in mesh.axis_names else None
