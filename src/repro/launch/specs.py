"""Per-(architecture × input-shape) step functions, argument specs and
shardings for the multi-pod dry-run and the launchers.

train_4k    lowers the **GST+EFD train step** (the paper's technique, §3):
            sampled-segment backprop + historical-table lookup + SED +
            table write-back + AdamW update.
prefill_32k lowers ``prefill``   (full forward, emits KV caches).
decode_32k  lowers ``serve_step`` (1 token, cache of seq_len).
long_500k   lowers ``serve_step`` with the long-context plan per family:
            SSM state / ring-buffer sliding window / full (seq-sharded)
            latent cache for MLA — DESIGN.md §Skips.

Everything is built from ShapeDtypeStructs via jax.eval_shape — no
allocation happens for the full-size configs.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape, INPUT_SHAPES
from repro.core import gst as G
from repro.core.embedding_table import EmbeddingTable
from repro.launch import sharding as SH
from repro.models import build_model
from repro.optim import make_optimizer


# ---------------------------------------------------------------------------
# long-context decode plan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DecodePlan:
    cache_len: int
    window: int = 0
    ring: bool = False
    seq_shard: bool = False   # shard cache sequence dim over fsdp axes (B=1)


def decode_plan(cfg: ArchConfig, shape: InputShape) -> DecodePlan:
    if shape.name != "long_500k":
        return DecodePlan(cache_len=shape.seq_len)
    if cfg.family == "ssm":
        return DecodePlan(cache_len=1)  # recurrent state only
    if cfg.use_mla:
        # DeepSeek MLA: the compressed latent cache IS the long-context
        # feature — keep the full 524k latent, sequence-sharded over data.
        return DecodePlan(cache_len=shape.seq_len, seq_shard=True)
    if cfg.name == "arctic-480b":
        # GQA kv=8 @ 524k fits when sequence-sharded (DESIGN.md §Skips)
        return DecodePlan(cache_len=shape.seq_len, seq_shard=True)
    # dense / vlm / hybrid: ring-buffer sliding window (sub-quadratic variant)
    return DecodePlan(cache_len=cfg.sliding_window, window=cfg.sliding_window,
                      ring=True)


# ---------------------------------------------------------------------------
# GST segmentation of the train shape
# ---------------------------------------------------------------------------


def gst_geometry(cfg: ArchConfig, shape: InputShape) -> Tuple[int, int]:
    """(J segments, segment length) for the train shape."""
    J = cfg.gst_num_segments
    assert shape.seq_len % J == 0
    return J, shape.seq_len // J


def _f(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def seg_input_specs(cfg: ArchConfig, B: int, J: int, L: int, dtype):
    """ShapeDtypeStructs for one GST batch's segment inputs."""
    spec: Dict[str, Any] = {"tokens": _f((B, J, L), jnp.int32)}
    if cfg.family == "vlm":
        spec["patches"] = _f((B, J, cfg.vision_prefix_len, cfg.d_model), dtype)
    if cfg.is_encoder_decoder:
        spec = {"frames": _f((B, J, L, cfg.d_model), dtype)}  # audio: frames only
    return spec


def serve_input_specs(cfg: ArchConfig, B: int, S: int, dtype):
    spec: Dict[str, Any] = {"tokens": _f((B, S), jnp.int32)}
    if cfg.family == "vlm":
        spec["patches"] = _f((B, cfg.vision_prefix_len, cfg.d_model), dtype)
    if cfg.is_encoder_decoder:
        spec["frames"] = _f((B, cfg.encoder_seq_len, cfg.d_model), dtype)
    return spec


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


@dataclass
class StepSpec:
    """Everything jax.jit needs: fn, arg specs, shardings, donations."""
    name: str
    fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()


def make_encode_fn(model, cfg: ArchConfig):
    def encode(backbone, seg_inputs):
        return model.encode_segment(backbone, seg_inputs)
    return encode


def build_train_spec(cfg: ArchConfig, shape: InputShape, mesh, *,
                     dtype=jnp.bfloat16, variant: str = "gst_efd") -> StepSpec:
    model = build_model(cfg)
    B = shape.global_batch
    J, L = gst_geometry(cfg, shape)
    d_h = cfg.d_model
    n_table = max(cfg.gst_table_size, B)

    opt = make_optimizer("adamw", lr=1e-4, weight_decay=0.01, max_grad_norm=1.0)
    encode = make_encode_fn(model, cfg)
    gst_step = G.make_train_step(
        encode, opt, G.VARIANTS[variant], num_sampled=cfg.gst_backprop_segments,
        keep_prob=cfg.gst_keep_prob, head_mode="mlp", loss_kind="ce", agg="mean")

    def train_step(state: G.TrainState, batch: G.GSTBatch, seed):
        rng = jax.random.PRNGKey(seed)
        return gst_step(state, batch, rng)

    # ---- arg shapes via eval_shape (no allocation) -----------------------
    backbone_shapes = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0), dtype))
    head_shapes = jax.eval_shape(
        lambda: G.head_init(jax.random.PRNGKey(1), d_h, cfg.gst_num_classes,
                            "mlp", dtype))
    opt_shapes = jax.eval_shape(
        lambda: opt.init((backbone_shapes, head_shapes)))
    table_shapes = EmbeddingTable(
        emb=_f((n_table, J, d_h), dtype),
        age=_f((n_table, J), jnp.int32),
        initialized=_f((n_table, J), jnp.bool_),
    )
    state_shapes = G.TrainState(
        backbone=backbone_shapes, head=head_shapes, opt_state=opt_shapes,
        table=table_shapes, step=_f((), jnp.int32))
    batch_shapes = G.GSTBatch(
        seg_inputs=seg_input_specs(cfg, B, J, L, dtype),
        seg_valid=_f((B, J), jnp.float32),
        graph_ids=_f((B,), jnp.int32),
        labels=_f((B,), jnp.int32))
    seed_shape = _f((), jnp.int32)

    # ---- shardings --------------------------------------------------------
    state_sh = G.TrainState(
        backbone=SH.tree_shardings(mesh, backbone_shapes),
        head=SH.tree_shardings(mesh, head_shapes),
        opt_state={
            "step": NamedSharding(mesh, P()),
            "mu": SH.tree_shardings(mesh, opt_shapes["mu"]),
            "nu": SH.tree_shardings(mesh, opt_shapes["nu"]),
        },
        table=SH.table_sharding(mesh, table_shapes),
        step=NamedSharding(mesh, P()))
    batch_sh = G.GSTBatch(
        seg_inputs=SH.batch_sharding(mesh, batch_shapes.seg_inputs),
        seg_valid=NamedSharding(mesh, SH.batch_spec(mesh, B, 2)),
        graph_ids=NamedSharding(mesh, SH.batch_spec(mesh, B, 1)),
        labels=NamedSharding(mesh, SH.batch_spec(mesh, B, 1)))
    metrics_sh = {"loss": NamedSharding(mesh, P()),
                  "metric": NamedSharding(mesh, P()),
                  "grad_norm": NamedSharding(mesh, P())}
    return StepSpec(
        name=f"{cfg.name}:{shape.name}:{variant}",
        fn=train_step,
        args=(state_shapes, batch_shapes, seed_shape),
        in_shardings=(state_sh, batch_sh, NamedSharding(mesh, P())),
        out_shardings=(state_sh, metrics_sh),
        donate_argnums=(0,))


def build_prefill_spec(cfg: ArchConfig, shape: InputShape, mesh, *,
                       dtype=jnp.bfloat16) -> StepSpec:
    model = build_model(cfg)
    B, S = shape.global_batch, shape.seq_len

    def prefill_step(params, inputs):
        return model.prefill(params, inputs)

    param_shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0), dtype))
    input_shapes = serve_input_specs(cfg, B, S, dtype)
    out_shapes = jax.eval_shape(prefill_step, param_shapes, input_shapes)
    param_sh = SH.tree_shardings(mesh, param_shapes)
    input_sh = SH.batch_sharding(mesh, input_shapes)
    logits_sh = NamedSharding(mesh, SH.batch_spec(mesh, B, 3))
    caches_sh = SH.cache_sharding(mesh, out_shapes[1])
    return StepSpec(
        name=f"{cfg.name}:{shape.name}",
        fn=prefill_step,
        args=(param_shapes, input_shapes),
        in_shardings=(param_sh, input_sh),
        out_shardings=(logits_sh, caches_sh))


def build_decode_spec(cfg: ArchConfig, shape: InputShape, mesh, *,
                      dtype=jnp.bfloat16) -> StepSpec:
    model = build_model(cfg)
    B = shape.global_batch
    plan = decode_plan(cfg, shape)

    def decode_step(params, token, caches, cache_pos):
        return model.decode_step(params, token, caches, cache_pos,
                                 window=plan.window, ring=plan.ring)

    param_shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0), dtype))
    cache_shapes = jax.eval_shape(
        lambda: model.init_cache(B, plan.cache_len, dtype))
    if cfg.is_encoder_decoder:
        # cross-attention K/V computed at prefill; static shape here
        from repro.models import encdec
        hd = cfg.resolved_head_dim
        xkv = (_f((cfg.num_layers, B, cfg.encoder_seq_len, cfg.num_kv_heads, hd), dtype),
               _f((cfg.num_layers, B, cfg.encoder_seq_len, cfg.num_kv_heads, hd), dtype))
        cache_shapes = {"self": cache_shapes, "cross": xkv}
    token_shape = _f((B, 1), jnp.int32)
    pos_shape = _f((B,), jnp.int32)
    out_shapes = jax.eval_shape(decode_step, param_shapes, token_shape,
                                cache_shapes, pos_shape)
    param_sh = SH.tree_shardings(mesh, param_shapes)
    cache_sh = SH.cache_sharding(mesh, cache_shapes, seq_shard=plan.seq_shard)
    return StepSpec(
        name=f"{cfg.name}:{shape.name}",
        fn=decode_step,
        args=(param_shapes, token_shape, cache_shapes, pos_shape),
        in_shardings=(param_sh,
                      NamedSharding(mesh, SH.batch_spec(mesh, B, 2)),
                      cache_sh,
                      NamedSharding(mesh, SH.batch_spec(mesh, B, 1))),
        out_shardings=(NamedSharding(mesh, SH.batch_spec(mesh, B, 3)), cache_sh),
        donate_argnums=(2,))


def build_step_spec(cfg: ArchConfig, shape_name: str, mesh, *,
                    dtype=jnp.bfloat16, variant: str = "gst_efd") -> StepSpec:
    shape = INPUT_SHAPES[shape_name]
    if not cfg.supports_shape(shape):
        raise ValueError(f"{cfg.name} skips {shape.name} (DESIGN.md §Skips)")
    if shape.kind == "train":
        return build_train_spec(cfg, shape, mesh, dtype=dtype, variant=variant)
    if shape.kind == "prefill":
        return build_prefill_spec(cfg, shape, mesh, dtype=dtype)
    return build_decode_spec(cfg, shape, mesh, dtype=dtype)


def input_specs(cfg: ArchConfig, shape_name: str, *, dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-ins for every model input of this shape —
    the public helper named by the assignment brief."""
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "train":
        J, L = gst_geometry(cfg, shape)
        return {
            "seg_inputs": seg_input_specs(cfg, shape.global_batch, J, L, dtype),
            "seg_valid": _f((shape.global_batch, J), jnp.float32),
            "graph_ids": _f((shape.global_batch,), jnp.int32),
            "labels": _f((shape.global_batch,), jnp.int32),
        }
    if shape.kind == "prefill":
        return serve_input_specs(cfg, shape.global_batch, shape.seq_len, dtype)
    plan = decode_plan(cfg, shape)
    return {
        "token": _f((shape.global_batch, 1), jnp.int32),
        "cache_pos": _f((shape.global_batch,), jnp.int32),
        "cache_len": plan.cache_len,
    }
