"""Serving launcher: batched prefill + autoregressive decode on CPU at
reduced scale (the serve-side counterpart of the dry-run's serve_step).

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --reduced \
        --batch 4 --prompt-len 16 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced as reduce_cfg
from repro.models import build_model


def serve(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    B = args.batch
    total = args.prompt_len + args.gen
    rng = np.random.default_rng(args.seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, args.prompt_len)),
                         jnp.int32)
    inputs = {"tokens": tokens}
    if cfg.family == "vlm":
        inputs["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.vision_prefix_len, cfg.d_model)), jnp.float32)
    if cfg.is_encoder_decoder:
        inputs["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq_len, cfg.d_model)), jnp.float32)

    decode = jax.jit(lambda p, t, c, pos: model.decode_step(p, t, c, pos))

    t0 = time.time()
    if cfg.is_encoder_decoder:
        from repro.models import encdec
        enc_out = encdec.encode(params, cfg, inputs["frames"])
        caches = {"self": model.init_cache(B, total, jnp.float32),
                  "cross": encdec.cross_kv(params, cfg, enc_out)}
        pos0 = 0
    else:
        # prefill by running decode over the prompt (cache len = total)
        caches = model.init_cache(B, total, jnp.float32)
        pos0 = 0
    out_tokens = []
    cur = tokens[:, :1]
    for t in range(total - 1):
        pos = jnp.full((B,), pos0 + t, jnp.int32)
        logits, caches = decode(params, cur, caches, pos)
        nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        if t + 1 < args.prompt_len:
            cur = tokens[:, t + 1 : t + 2]  # teacher-forced prompt
        else:
            cur = nxt
            out_tokens.append(np.asarray(nxt[:, 0]))
    dt = time.time() - t0
    gen = np.stack(out_tokens, 1) if out_tokens else np.zeros((B, 0), np.int32)
    print(f"[{cfg.name}] generated {gen.shape} in {dt:.1f}s "
          f"({dt / max(total - 1, 1) * 1e3:.0f} ms/token incl. compile)")
    print("sample:", gen[0][:16].tolist())
    return gen


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    serve(args)


if __name__ == "__main__":
    main()
