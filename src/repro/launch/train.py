"""Training launcher — both tracks, CPU-runnable at reduced scale.

Examples:
    # paper track: GST+EFD on synthetic MalNet with a SAGE backbone
    PYTHONPATH=src python -m repro.launch.train --track graph \
        --backbone sage --variant gst_efd --epochs 30

    # sequence track: GST+EFD property training with a reduced assigned arch
    PYTHONPATH=src python -m repro.launch.train --track seq \
        --arch internlm2-1.8b --reduced --steps 200

    # plain-LM objective (the non-GST baseline of the framework)
    PYTHONPATH=src python -m repro.launch.train --track lm \
        --arch olmo-1b --reduced --steps 100
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config, reduced as reduce_cfg
from repro.core import gst as G
from repro.data.tokens import doc_batch_iterator, make_lm_stream, make_property_docs
from repro.models import build_model
from repro.obs import Obs, StalenessProbe, add_obs_args
from repro.obs.trace import span
from repro.optim import cosine_schedule, make_optimizer
from repro.store import DeviceStore, TieredStore


def train_graph(args, obs):
    from repro.graphs.experiment import run_experiment
    r = run_experiment(
        dataset=args.dataset, backbone=args.backbone, variant=args.variant,
        n_graphs=args.n_graphs, epochs=args.epochs,
        finetune_epochs=args.finetune_epochs, keep_prob=args.keep_prob,
        seed=args.seed, use_pallas=args.use_pallas,
        table_device_rows=args.table_device_rows,
        evict_policy=args.evict_policy,
        wb_threshold=args.wb_threshold,
        sed_age_weighting=args.sed_age_weighting,
        stale_forecast=args.stale_forecast, obs=obs)
    print(f"[graph/{args.dataset}] {args.backbone} {args.variant}"
          f"{' [pallas]' if args.use_pallas else ''}: "
          f"train={r.train_metric:.3f} test={r.test_metric:.3f} "
          f"{r.ms_per_iter:.1f} ms/iter")
    if r.store_stats and args.table_device_rows:
        s = r.store_stats
        print(f"  store [{s['backend']}] device rows {s['device_rows']}/"
              f"{s['n_rows']}  hit-rate {s['hit_rate']:.2f} "
              f"({s['hits']} hits / {s['misses']} faults), "
              f"{s['evictions']} evictions, "
              f"{s['migration_bytes'] / 1024:.1f} KiB migrated")
    return r


def train_seq(args, obs):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = build_model(cfg)
    J, L = cfg.gst_num_segments, args.seg_len
    docs = make_property_docs(n_docs=args.n_docs, n_segments=J, seg_len=L,
                              vocab=cfg.vocab_size,
                              n_topics=cfg.gst_num_classes, seed=args.seed)
    key = jax.random.key(args.seed)
    params = model.init(key)
    head = G.head_init(jax.random.fold_in(key, 1), cfg.d_model,
                       cfg.gst_num_classes, "mlp")
    opt = make_optimizer("adamw", lr=args.lr, weight_decay=0.01)
    # the (n_docs, J, d_model) table sits behind the embedding store —
    # --table-device-rows caps how many doc rows stay in device memory
    store = (TieredStore(args.n_docs, J, cfg.d_model,
                         device_rows=max(args.table_device_rows,
                                         args.batch_size),
                         evict_policy=args.evict_policy,
                         wb_threshold=args.wb_threshold,
                         stale_forecast=args.stale_forecast)
             if args.table_device_rows
             else DeviceStore(args.n_docs, J, cfg.d_model))
    state = G.TrainState(params, head, opt.init((params, head)),
                         store.init_device_table(),
                         jnp.zeros((), jnp.int32))

    def encode(backbone, seg_inputs):
        return model.encode_segment(backbone, seg_inputs)

    # donate the state so the device-tier table updates in place
    step = jax.jit(G.make_train_step(
        encode, opt, G.VARIANTS[args.variant], keep_prob=args.keep_prob,
        use_pallas=args.use_pallas, sed_decay=args.sed_age_weighting),
        donate_argnums=(0,))
    try:
        rng = np.random.default_rng(args.seed)
        probe = StalenessProbe(keep_prob=args.keep_prob, num_sampled=1,
                               sed_decay=args.sed_age_weighting,
                               forecast=args.stale_forecast)
        it = 0
        t0 = time.time()
        while it < args.steps:
            for tup in doc_batch_iterator(docs, args.batch_size, rng=rng):
                # step hint: the train step about to WRITE these rows —
                # feeds stale-first scoring and the stale-row forecaster
                table, slots = store.prepare(state.table, np.asarray(tup[2]),
                                             step=it)
                state = state._replace(table=table)
                batch = G.GSTBatch({"tokens": jnp.asarray(tup[0]["tokens"])},
                                   jnp.asarray(tup[1]), jnp.asarray(slots),
                                   jnp.asarray(tup[3]))
                with span("train.step", step=it):
                    state, m = step(state, batch, jax.random.key(it))
                it += 1
                if it % args.log_every == 0:
                    print(f"step {it}: loss={float(m['loss']):.4f} "
                          f"acc={float(m['metric']):.3f} "
                          f"({(time.time()-t0)/it*1e3:.0f} ms/step)", flush=True)
                    if obs.enabled:
                        store.publish_counters()
                        stale = probe.observe(store, state.table, it)
                        obs.tick(step=it, loss=float(m["loss"]),
                                 staleness=stale)
                if it >= args.steps:
                    break
        # surface any failed async write-back BEFORE reporting success
        store.flush_writebacks()
        if args.table_device_rows:
            s = store.stats()
            print(f"store [{s['backend']}] device rows {s['device_rows']}/"
                  f"{s['n_rows']}  hit-rate {s['hit_rate']:.2f}, "
                  f"{s['evictions']} evictions, "
                  f"{s['migration_bytes'] / 1024:.1f} KiB migrated")
        if args.ckpt_dir:
            save_checkpoint(args.ckpt_dir, it, {"backbone": state.backbone,
                                                "head": state.head})
    finally:
        store.close()   # stop the write-back thread even on error
    return state


def train_lm(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = build_model(cfg)
    data = make_lm_stream(args.n_docs, args.seg_len + 1, cfg.vocab_size,
                          seed=args.seed)
    params = model.init(jax.random.key(args.seed))
    opt = make_optimizer("adamw", lr=args.lr,
                         schedule=cosine_schedule(args.lr, args.steps, 10))
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, tokens):
        def loss_fn(p):
            h, aux = model.forward_with_aux(p, {"tokens": tokens[:, :-1]})
            logits = model.logits(p, h)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            nll = -jnp.take_along_axis(logp, tokens[:, 1:, None], -1)[..., 0]
            return jnp.mean(nll) + 1e-2 * aux
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, _ = opt.update(params, grads, opt_state)
        return params, opt_state, loss

    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for it in range(args.steps):
        ids = rng.integers(0, len(data), args.batch_size)
        params, opt_state, loss = step(params, opt_state, jnp.asarray(data[ids]))
        if (it + 1) % args.log_every == 0:
            print(f"step {it+1}: lm_loss={float(loss):.4f} "
                  f"({(time.time()-t0)/(it+1)*1e3:.0f} ms/step)", flush=True)
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--track", default="graph", choices=["graph", "seq", "lm"])
    # graph track
    ap.add_argument("--dataset", default="malnet", choices=["malnet", "tpugraphs"])
    ap.add_argument("--backbone", default="sage", choices=["gcn", "sage", "gps"])
    ap.add_argument("--n-graphs", type=int, default=100)
    ap.add_argument("--epochs", type=int, default=30)
    ap.add_argument("--finetune-epochs", type=int, default=10)
    # shared
    ap.add_argument("--variant", default="gst_efd", choices=list(G.VARIANTS))
    ap.add_argument("--use-pallas", action="store_true",
                    help="route the hot path through the fused Pallas kernels "
                         "(batched segment_spmm + sed_pool; interpret mode "
                         "when not on TPU)")
    ap.add_argument("--keep-prob", type=float, default=0.5)
    ap.add_argument("--table-device-rows", type=int, default=None,
                    help="cap device-resident historical-table rows; the "
                         "rest spill to a host-RAM tier (store/tiered.py). "
                         "Clamped up to the batch size. Default: whole "
                         "table on device")
    ap.add_argument("--wb-threshold", type=float, default=0.0,
                    help="delta-gated write-back under --table-device-rows: "
                         "skip the host-tier emb write for evicted rows "
                         "whose embedding moved less than this (max-abs) "
                         "while resident (store/writeback.delta_gate). "
                         "0 = gate off, bit-exact store")
    ap.add_argument("--evict-policy", default="lru",
                    choices=["lru", "stale-first"],
                    help="tiered-store eviction policy under "
                         "--table-device-rows (stale_first scores by the "
                         "row's true last-write step)")
    ap.add_argument("--sed-age-weighting", type=float, default=0.0,
                    help="λ of the exp(-λ·age) staleness decay folded into "
                         "the stale branch of Eq.-1 η (graph track, "
                         "use_sed+use_table variants). 0 = off, bit-exact "
                         "to the unweighted step")
    ap.add_argument("--stale-forecast", action="store_true",
                    help="extrapolate stale host-tier rows forward by their "
                         "age on fault-in via the online per-row velocity "
                         "forecaster (store/forecast.py); needs "
                         "--table-device-rows")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lr", type=float, default=1e-3)
    # seq/lm track
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seg-len", type=int, default=64)
    ap.add_argument("--n-docs", type=int, default=64)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    add_obs_args(ap)
    args = ap.parse_args()
    obs = Obs.from_args(args, run="train", track=args.track,
                        variant=args.variant)
    try:
        if args.track == "graph":
            train_graph(args, obs)
        elif args.track == "seq":
            train_seq(args, obs)
        else:
            train_lm(args)
    finally:
        obs.close()


if __name__ == "__main__":
    main()
