import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): prove every (architecture × input
shape × mesh) combination lowers AND compiles under the production meshes,
and extract the roofline terms (deliverable g) from the compiled artifact.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out EXPERIMENTS_dryrun.json

The XLA_FLAGS line above MUST run before any jax import (jax pins the
device count at first init) — which is why it is the first statement of the
module and why this flag is set nowhere else (tests/benches see 1 device).
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_step_spec, decode_plan, gst_geometry
from repro.roofline.analysis import (analyze_compiled, compiled_memory_stats,
                                     param_counts)


def run_one(arch_id: str, shape_name: str, multi_pod: bool, *,
            variant: str = "gst_efd", dtype=jnp.bfloat16, verbose: bool = True,
            unroll: bool = True, dispatch: str = "einsum",
            cache_update: str = "onehot", attn_impl: str = "naive",
            mla_absorbed: bool = True, head_aligned: bool = False,
            gqa: str = "repeat"):
    # Unroll layer scans so cost_analysis counts every layer (XLA counts a
    # while-loop body once; see models/transformer.py SCAN_UNROLL).
    from repro.models import transformer as _T
    from repro.models import common as _C
    from repro.models import moe as _M
    from repro.models import mla as _MLA
    _T.SCAN_UNROLL = unroll
    _M.DISPATCH_MODE = dispatch
    _C.CACHE_UPDATE = cache_update
    _C.ATTN_IMPL = attn_impl
    _C.GQA_IMPL = gqa
    _MLA.ABSORBED_DECODE = mla_absorbed
    cfg = get_config(arch_id)
    from repro.launch import sharding as _SH
    _SH.OVERRIDES = (_SH.head_aligned_overrides(
        cfg, make_production_mesh(multi_pod=multi_pod)) if head_aligned else [])
    shape = INPUT_SHAPES[shape_name]
    if not cfg.supports_shape(shape):
        return {"arch": arch_id, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped (DESIGN.md §Skips)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 1
    for s in mesh.devices.shape:
        chips *= s
    t0 = time.time()
    spec = build_step_spec(cfg, shape_name, mesh, dtype=dtype, variant=variant)
    with mesh:
        jitted = jax.jit(
            spec.fn,
            in_shardings=spec.in_shardings,
            out_shardings=spec.out_shardings,
            donate_argnums=spec.donate_argnums)
        lowered = jitted.lower(*spec.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    # useful-FLOPs accounting
    moe = cfg.moe
    # params tree is the first arg's backbone for train, else the params arg
    param_shapes = spec.args[0].backbone if shape.kind == "train" else spec.args[0]
    n_total, n_active = param_counts(param_shapes, moe.top_k, moe.num_experts)
    if shape.kind == "train":
        J, L = gst_geometry(cfg, shape)
        tokens = shape.global_batch * L * cfg.gst_backprop_segments
        kind = "train"
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        kind = "infer"
    else:
        tokens = shape.global_batch * 1
        kind = "infer"

    rep = analyze_compiled(compiled, chips=chips, n_active=n_active,
                           tokens=tokens, kind=kind)
    rep.update({
        "arch": arch_id, "shape": shape_name,
        "mesh": "multi(2,16,16)" if multi_pod else "single(16,16)",
        "status": "ok", "variant": variant if shape.kind == "train" else None,
        "opts": {"dispatch": dispatch, "cache_update": cache_update,
                 "attn_impl": attn_impl, "mla_absorbed": mla_absorbed,
                 "unroll": unroll, "head_aligned": head_aligned,
                 "gqa": gqa},
        "params_total": n_total, "params_active": n_active,
        "tokens_per_step": tokens,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
    })
    if shape.kind == "decode":
        plan = decode_plan(cfg, shape)
        rep["decode_plan"] = {"cache_len": plan.cache_len, "window": plan.window,
                              "ring": plan.ring, "seq_shard": plan.seq_shard}
    if verbose:
        # one extraction path for everyone (roofline.analysis helper) —
        # rep["memory_analysis"] already came through it; re-derive here
        # only to keep the print honest when extraction degraded
        ma = compiled_memory_stats(compiled) or {}
        print(f"[{rep['mesh']}] {arch_id} x {shape_name}: OK "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s) "
              f"dominant={rep['dominant']} "
              f"terms={ {k: f'{v:.3e}' for k, v in rep['terms_seconds'].items()} } "
              f"args/dev={ma.get('argument_size_in_bytes', 0)/1e9:.2f}GB "
              f"temp/dev={ma.get('temp_size_in_bytes', 0)/1e9:.2f}GB",
              flush=True)
    return rep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="gst_efd")
    ap.add_argument("--out", default=None)
    ap.add_argument("--no-unroll", action="store_true",
                    help="keep layer scans rolled (fast compile; FLOP/byte "
                         "totals count scan bodies once — lowering proof only)")
    ap.add_argument("--dispatch", default="einsum", choices=["einsum", "gather"])
    ap.add_argument("--cache-update", default="onehot", choices=["onehot", "dus"])
    ap.add_argument("--attn-impl", default="naive", choices=["naive", "chunked"])
    ap.add_argument("--mla-naive", action="store_true")
    ap.add_argument("--head-aligned-sharding", action="store_true")
    ap.add_argument("--gqa", default="repeat", choices=["repeat", "grouped"])
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    shapes = list(INPUT_SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    results.append(run_one(
                        arch, shape, mp, variant=args.variant,
                        unroll=not args.no_unroll, dispatch=args.dispatch,
                        cache_update=args.cache_update,
                        attn_impl=args.attn_impl,
                        mla_absorbed=not args.mla_naive,
                        head_aligned=args.head_aligned_sharding,
                        gqa=args.gqa))
                except Exception as e:
                    traceback.print_exc()
                    results.append({"arch": arch, "shape": shape,
                                    "mesh": "multi" if mp else "single",
                                    "status": f"FAIL: {e}"})
                    print(f"FAIL {arch} x {shape} multi={mp}: {e}", flush=True)
                if args.out:
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1, default=str)
    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_skip = sum(1 for r in results if "skip" in r["status"])
    print(f"\n{n_ok} ok, {n_skip} skipped, {len(results) - n_ok - n_skip} failed "
          f"of {len(results)}")
    return results


if __name__ == "__main__":
    main()
