"""Graph-property serving launcher: replay synthetic request traffic through
the segment-streaming inference engine (serve/engine.py).

    PYTHONPATH=src python -m repro.launch.serve_graphs \
        --requests 64 --unique 24 --duplicate-rate 0.5 --window 8

Reports p50/p99 request latency, throughput, cross-request cache hit-rate,
and encode-kernel launch counts.  ``--check-parity`` verifies a sample of
engine predictions against the one-shot batch encoder and exits nonzero on
mismatch; ``--min-hit-rate`` turns the hit-rate into an assertion — both are
what the CI serve-smoke job runs.
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np


def build_engine(args):
    from repro.serve import ServeConfig, ServeEngine

    cfg = ServeConfig(
        backbone=args.backbone,
        use_pallas=args.use_pallas,
        max_seg_nodes=args.max_seg_nodes,
        cache_capacity=args.cache_capacity,
        cache_enabled=not args.no_cache,
        table_device_rows=args.table_device_rows,
        evict_policy=args.evict_policy,
        wb_threshold=args.wb_threshold,
        stale_forecast=args.stale_forecast,
        stream_chunk=args.stream_chunk,
    )
    return ServeEngine(cfg, seed=args.seed)


def check_parity(engine, graphs, atol: float) -> float:
    """Engine predictions vs the one-shot batch encoder (training-style
    padding, every segment encoded in one flat batch)."""
    from repro.core import gst as G
    from repro.graphs.batching import segment_dataset
    from repro.graphs.gnn import encode_segments
    from repro.graphs.partition import partition_graph

    worst = 0.0
    for g in graphs:
        res = engine.process([g], window=1)[0]
        segs = partition_graph(len(g.x), g.edges, engine.cfg.max_seg_nodes,
                               engine.cfg.partition, engine.cfg.partition_seed)
        ds = segment_dataset([g], engine.cfg.max_seg_nodes,
                             method=engine.cfg.partition,
                             seed=engine.cfg.partition_seed)
        si = {k: jnp.asarray(v[0]) for k, v in ds.seg_inputs(np.array([0])).items()}
        h = encode_segments(engine.params, engine.gnn_cfg, si)[:len(segs)]
        ref = G.head_apply(engine.head, h.mean(axis=0), "mlp")
        worst = max(worst, float(np.abs(res.pred - np.asarray(ref)).max()))
    if worst > atol:
        raise SystemExit(f"PARITY FAIL: engine vs one-shot max diff {worst:.3e} "
                         f"> atol {atol:.1e}")
    return worst


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--unique", type=int, default=24)
    ap.add_argument("--duplicate-rate", type=float, default=0.5)
    ap.add_argument("--window", type=int, default=8)
    ap.add_argument("--backbone", default="sage", choices=["gcn", "sage", "gps"])
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--cache-capacity", type=int, default=512)
    ap.add_argument("--table-device-rows", type=int, default=None,
                    help="cap device-resident cache rows; cold entries "
                         "spill to a host-RAM tier and fault back on hit "
                         "instead of being re-encoded (store/tiered.py). "
                         "Default: all cache rows on device")
    ap.add_argument("--evict-policy", default="lru",
                    choices=["lru", "stale-first"],
                    help="device-tier eviction policy under "
                         "--table-device-rows: pure LRU or age-aware "
                         "stale-first (evict stale-and-cold rows before "
                         "fresh-and-hot ones)")
    ap.add_argument("--wb-threshold", type=float, default=0.0,
                    help="delta-gated write-back under --table-device-rows: "
                         "skip the host-tier emb write for spilled rows "
                         "whose embedding moved less than this (max-abs) "
                         "while device-resident. 0 = gate off, bit-exact")
    ap.add_argument("--stale-forecast", action="store_true",
                    help="back the cache's tiered store with the online "
                         "per-row velocity forecaster (store/forecast.py); "
                         "a no-op for the offline replay, whose cache rows "
                         "never drift — train-while-serve plumbing")
    ap.add_argument("--popularity", type=float, default=0.0,
                    help="repeat-request skew: P(graph) ∝ "
                         "times_served**popularity over distinct seen "
                         "graphs (0 = uniform, 1 = rich-get-richer)")
    ap.add_argument("--max-seg-nodes", type=int, default=64)
    ap.add_argument("--stream-chunk", type=int, default=8)
    ap.add_argument("--warmup", type=int, default=4,
                    help="requests replayed first to absorb jit compiles "
                         "(stats are reset afterwards; cache is NOT reset, "
                         "pass --cold-cache to flush it)")
    ap.add_argument("--cold-cache", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check-parity", action="store_true")
    ap.add_argument("--parity-atol", type=float, default=1e-5)
    ap.add_argument("--min-hit-rate", type=float, default=None)
    from repro.obs import Obs, add_obs_args
    add_obs_args(ap)
    args = ap.parse_args(argv)

    from repro.serve import TrafficConfig, make_request_stream

    engine = build_engine(args)
    tc = TrafficConfig(n_unique=args.unique, n_requests=args.requests,
                       duplicate_rate=args.duplicate_rate,
                       popularity=args.popularity, seed=args.seed)
    stream = make_request_stream(tc)
    obs = Obs.from_args(args, run="serve_graphs",
                        backbone=args.backbone, requests=args.requests,
                        window=args.window)

    try:
        return _run(args, engine, stream, obs)
    finally:
        # the tiered store owns a write-back thread — release it even when
        # the parity / hit-rate gates raise SystemExit
        engine.close()
        obs.close()


def _run(args, engine, stream, obs):
    if args.warmup:
        engine.process(stream[:args.warmup], window=args.window)
        engine.reset_stats()
        # warmup compiles/misses must not count against the SLO gates
        obs.registry.reset()
        if args.cold_cache and engine.cache is not None:
            engine.cache.flush()  # cold contents, warm compile caches

    # replay window-by-window (behaviorally identical to one process()
    # call, which windows internally) so the JSONL stream gets one
    # per-window delta tick
    for wi, w0 in enumerate(range(0, len(stream), args.window)):
        engine.process(stream[w0:w0 + args.window], window=args.window)
        if obs.should_tick(wi):
            obs.tick(step=wi,
                     requests_done=min(w0 + args.window, len(stream)))
    s = engine.stats.summary()
    obs.close(serve=s)

    print(f"[serve_graphs] backend={jax.default_backend()} "
          f"backbone={args.backbone} pallas={args.use_pallas} "
          f"cache={'off' if args.no_cache else 'on'}")
    print(f"  requests          {s['n_requests']}  ({s['n_segments']} segments)")
    print(f"  throughput        {s['throughput_req_s']:.1f} req/s")
    print(f"  latency p50/p99   {s['latency_p50_ms']:.1f} / {s['latency_p99_ms']:.1f} ms")
    print(f"  encode launches   {s['encode_launches']} "
          f"({s['encoded_segments']} segments encoded, "
          f"{s['pallas_launches']} pallas kernel launches)")
    if s.get("truncated_nodes") or s.get("truncated_edges"):
        print(f"  TRUNCATED         {s['truncated_nodes']} nodes, "
              f"{s['truncated_edges']} edges dropped by catch-all "
              f"bucket overflow (repro.obs.gate fails on this)")
    if s["cache"]:
        c = s["cache"]
        print(f"  cache             hit-rate {c['hit_rate']:.2f} "
              f"({c['hits']} hits / {c['misses']} misses), "
              f"{c['size']}/{c['capacity']} slots, "
              f"{c['evictions']} evictions, "
              f"age mean/max {c['age_mean_steps']:.1f}/{c['age_max_steps']} steps")
        st = c.get("store", {})
        if st:
            print(f"  store             [{st['backend']}] device rows "
                  f"{st['occupancy']}/{st['device_rows']} "
                  f"(of {st['n_rows']} total), tier hit-rate "
                  f"{st['hit_rate']:.2f}, {st['evictions']} spills, "
                  f"{st['migration_bytes'] / 1024:.1f} KiB migrated")

    if args.check_parity:
        worst = check_parity(engine, stream[:3], args.parity_atol)
        print(f"  parity            OK (max |engine - one-shot| = {worst:.2e})")
    if args.min_hit_rate is not None:
        hr = s["cache"].get("hit_rate", 0.0) if s["cache"] else 0.0
        if hr <= args.min_hit_rate:
            raise SystemExit(f"HIT-RATE FAIL: {hr:.3f} <= {args.min_hit_rate}")
        print(f"  hit-rate check    OK ({hr:.2f} > {args.min_hit_rate})")
    return s


if __name__ == "__main__":
    main()
