"""Cross-request segment-embedding cache (content-addressed, LRU-bounded).

FreshGNN's observation (PAPERS.md) — stable historical embeddings can be
reused across iterations — applied at serving time: a segment whose padded
content hash was seen before skips the GNN encode entirely; only the cheap
head runs on a full-hit request.  The device-side store IS the training
code's historical table (core/embedding_table.py) with rows repurposed as
cache slots (J_max == 1): lookups/updates are the same gather/scatter the
train step uses, and ``age`` doubles as the insertion step for staleness
accounting.

Host side keeps the hash -> slot map (an OrderedDict in LRU order) plus
hit/miss/eviction counters.  Eviction frees the least-recently-used slot;
the embedding stays in device memory and is overwritten on reuse.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import embedding_table as tbl


def next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


class SegmentCache:
    def __init__(self, capacity: int, d_h: int, dtype=jnp.float32):
        if capacity < 1:
            raise ValueError("cache capacity must be >= 1")
        self.capacity = capacity
        self.d_h = d_h
        self.table = tbl.init_table(capacity, 1, d_h, dtype)
        self._slots: "OrderedDict[bytes, int]" = OrderedDict()  # key -> slot, LRU order
        self._free: List[int] = list(range(capacity - 1, -1, -1))
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.skipped_inserts = 0
        self.step = 0  # monotonically increasing insertion step (age base)
        # jitted table ops: each (B,) shape compiles once (the pow2 padding
        # below keeps the shape set O(log capacity)); step rides along as a
        # traced scalar so it never bakes into the executable
        self._update = jax.jit(tbl.update_rows)
        self._lookup = jax.jit(tbl.lookup_rows)
        self._evict = jax.jit(tbl.evict_rows)

    def __len__(self) -> int:
        return len(self._slots)

    def flush(self):
        """Empty the cache (contents + counters) while KEEPING the jitted
        table ops and their compile caches — a flushed cache measures cold
        contents, not cold compiles."""
        self.table = tbl.init_table(self.capacity, 1, self.d_h,
                                    self.table.emb.dtype)
        self._slots.clear()
        self._free = list(range(self.capacity - 1, -1, -1))
        self.hits = self.misses = self.evictions = self.skipped_inserts = 0
        self.step = 0

    def get(self, key: bytes) -> Optional[int]:
        """Slot of a cached segment (refreshes LRU position), or None.
        Counts a hit/miss."""
        slot = self._slots.get(key)
        if slot is None:
            self.misses += 1
            return None
        self._slots.move_to_end(key)
        self.hits += 1
        return slot

    def peek(self, key: bytes) -> Optional[int]:
        """Like get() but with no counter / LRU side effects."""
        return self._slots.get(key)

    def _reserve(self, key: bytes, pinned: set) -> Optional[int]:
        if self._free:
            return self._free.pop()
        # evict the least-recently-used slot not pinned by the current batch
        for old_key in self._slots:
            if old_key not in pinned:
                slot = self._slots.pop(old_key)
                self.evictions += 1
                self.table = self._evict(self.table, jnp.asarray([slot]))
                return slot
        return None  # every live slot is pinned by this batch

    def put(self, keys: List[bytes], embs, pinned=()) -> List[Optional[int]]:
        """Best-effort insert of freshly-encoded embeddings (len(keys), d_h);
        returns the slot per key, None where the insert was skipped (batch of
        new keys larger than the capacity — the cache keeps what fits and the
        caller falls back to its fresh embedding).  Duplicate keys in the
        batch write once.  ``pinned``: extra keys that must NOT be evicted —
        the engine passes the window's hit keys, whose slots it gathers
        after this insert.  The device scatter is padded to the next power
        of two (repeating the last row) so steady-state serving compiles
        O(log capacity) scatter shapes."""
        self.step += 1
        # never evict a key being inserted in this batch, nor a caller-pinned
        # one (a hit slot evicted here would be silently reused before the
        # caller's gather)
        pinned = set(keys) | set(pinned)
        slots, rows, idx = [], [], []
        for i, key in enumerate(keys):
            slot = self._slots.get(key)
            if slot is None:
                slot = self._reserve(key, pinned)
                if slot is None:
                    self.skipped_inserts += 1
                    slots.append(None)
                    continue
                self._slots[key] = slot
                rows.append(slot)
                idx.append(i)
            self._slots.move_to_end(key)
            slots.append(slot)
        if rows:
            n = next_pow2(len(rows))
            rows_p = np.asarray(rows + [rows[-1]] * (n - len(rows)), np.int32)
            idx_p = np.asarray(idx + [idx[-1]] * (n - len(idx)))
            self.table = self._update(
                self.table, jnp.asarray(rows_p),
                jnp.asarray(embs)[idx_p], jnp.int32(self.step))
        return slots

    def gather(self, slots, valid=None) -> jnp.ndarray:
        """(len(slots), d_h) embeddings — the stored device values, so a hit
        returns bit-identical bytes to what was inserted.  ``valid`` (0/1,
        same length) limits the liveness assertion to real entries when the
        caller padded ``slots`` to a static shape."""
        emb, init = self._lookup(self.table, jnp.asarray(slots, jnp.int32))
        live = init if valid is None else jnp.where(jnp.asarray(valid) > 0,
                                                    init, True)
        assert bool(live.all()), "gather() of an evicted/uninitialized slot"
        return emb

    def stats(self) -> Dict:
        total = self.hits + self.misses
        ages = np.asarray(self.table.age[:, 0])
        init = np.asarray(self.table.initialized[:, 0])
        live_ages = (self.step - ages[init]) if init.any() else np.zeros(0)
        return {
            "capacity": self.capacity,
            "size": len(self._slots),
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": (self.hits / total) if total else 0.0,
            "evictions": self.evictions,
            "skipped_inserts": self.skipped_inserts,
            "age_mean_steps": float(live_ages.mean()) if live_ages.size else 0.0,
            "age_max_steps": int(live_ages.max()) if live_ages.size else 0,
        }
