"""Cross-request segment-embedding cache (content-addressed, LRU-bounded).

FreshGNN's observation (PAPERS.md) — stable historical embeddings can be
reused across iterations — applied at serving time: a segment whose padded
content hash was seen before skips the GNN encode entirely; only the cheap
head runs on a full-hit request.

Since the tiered-store refactor this file is a THIN KEYING LAYER: it maps
content hashes onto logical rows of an ``EmbeddingStore``
(store/base.py) with a ``SlotMap`` (store/slots.py — the LRU machinery
that started life here), and the store decides where those rows physically
live.  With the default ``DeviceStore`` every row is device-resident —
exactly the old behavior.  Handed a ``TieredStore`` (the
``--table-device-rows`` path, or the very store a trainer is using), cold
entries spill to host RAM instead of burning device memory, and a hit on
a spilled row faults it back instead of re-encoding — one deployment can
train and serve from one store instance.  The cache addresses segment-slot
0 of each row, so trainer-shaped geometry (j_max > 1) works unchanged;
sharing a LIVE concurrently-training instance additionally needs the
read-only lookup path noted in ROADMAP.md, since rows would be contended.

Host side keeps hash -> row in LRU order plus hit/miss/eviction counters.
Keying-layer eviction frees the least-recently-used row; its embedding
stays wherever it lives and is overwritten on reuse.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import embedding_table as tbl
from repro.kernels.ops import (next_pow2, pad_rows_pow2,  # noqa: F401
                               prev_pow2)
from repro.obs.metrics import get_registry
from repro.store import DeviceStore, EmbeddingStore, SlotMap, StoreCounters


class SegmentCache:
    def __init__(self, capacity: int, d_h: int, dtype=jnp.float32,
                 store: Optional[EmbeddingStore] = None):
        if capacity < 1:
            raise ValueError("cache capacity must be >= 1")
        self.capacity = capacity
        self.d_h = d_h
        self.store = store if store is not None \
            else DeviceStore(capacity, 1, d_h, dtype=dtype)
        # the cache keys SEGMENT-SLOT 0 of each store row (lookup_rows /
        # update_rows address (row, 0)), so a trainer-shaped store with
        # j_max > 1 works too — extra segment slots just ride along unused
        if (self.store.n_rows, self.store.d_h) != (capacity, d_h):
            raise ValueError(
                f"backing store geometry {(self.store.n_rows, self.store.d_h)}"
                f" != cache ({capacity}, {d_h})")
        self.table = self.store.init_device_table()
        self._slots = SlotMap(capacity)   # content key -> logical row, LRU
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.skipped_inserts = 0
        self.step = 0  # monotonically increasing insertion step (age base)
        self._published: Dict[str, int] = {}  # registry mirror baselines
        # jitted table ops: each (B,) shape compiles once (pow2 padding keeps
        # the shape set O(log capacity)); step rides along as a traced scalar
        self._update = jax.jit(tbl.update_rows)
        self._lookup = jax.jit(tbl.lookup_rows)

    def __len__(self) -> int:
        return len(self._slots)

    def close(self):
        """Release the backing store (stops a TieredStore's write-back
        thread; no-op for a DeviceStore)."""
        self.store.close()

    def flush(self):
        """Empty the cache (contents + counters) while KEEPING the jitted
        table ops and their compile caches — a flushed cache measures cold
        contents, not cold compiles."""
        self.table = self.store.restore(tbl.init_table(
            self.capacity, self.store.j_max, self.d_h, self.store.dtype))
        self._slots.clear()
        self.hits = self.misses = self.evictions = self.skipped_inserts = 0
        self.store.counters = StoreCounters()
        self.step = 0

    def publish_counters(self) -> None:
        """Mirror keying-layer counter movement into the metrics registry
        (``serve.cache.*``; no-op when metrics are disabled).  The local
        ints stay the mutation surface — callers reset them freely
        (reset_stats/flush) and the diff re-baselines instead of
        rewinding the cumulative registry counters."""
        reg = get_registry()
        if not reg.enabled:
            return
        for name, cur in (("serve.cache.hits", self.hits),
                          ("serve.cache.misses", self.misses),
                          ("serve.cache.evictions", self.evictions),
                          ("serve.cache.skipped_inserts",
                           self.skipped_inserts)):
            moved = cur - self._published.get(name, 0)
            if moved > 0:
                reg.inc(name, moved)
            self._published[name] = cur

    def get(self, key: bytes) -> Optional[int]:
        """Logical row of a cached segment (refreshes LRU position), or
        None.  Counts a hit/miss."""
        row = self._slots.get(key)
        if row is None:
            self.misses += 1
            return None
        self.hits += 1
        return row

    def peek(self, key: bytes) -> Optional[int]:
        """Like get() but with no counter / LRU side effects."""
        return self._slots.get(key, touch=False)

    def put(self, keys: List[bytes], embs, pinned=()) -> List[Optional[int]]:
        """Best-effort insert of freshly-encoded embeddings (len(keys), d_h);
        returns the row per key, None where the insert was skipped (batch of
        new keys larger than the capacity — the cache keeps what fits and the
        caller falls back to its fresh embedding).  Duplicate keys in the
        batch write once.  ``pinned``: extra keys that must NOT be evicted —
        the engine passes the window's hit keys, whose rows it gathers
        after this insert.  The device scatter is padded to the next power
        of two (kernels/ops.py::pad_rows_pow2) so steady-state serving
        compiles O(log capacity) scatter shapes."""
        self.step += 1
        # never evict a key being inserted in this batch, nor a caller-pinned
        # one (a hit row evicted here would be silently reused before the
        # caller's gather)
        pinned = set(keys) | set(pinned)
        slots, rows, idx, displaced_rows = [], [], [], []
        for i, key in enumerate(keys):
            row = self._slots.get(key)
            if row is None:
                row, displaced = self._slots.reserve(key, pinned=pinned)
                if row is None:
                    self.skipped_inserts += 1
                    slots.append(None)
                    continue
                if displaced is not None:
                    self.evictions += 1
                    displaced_rows.append(displaced[1])
                rows.append(row)
                idx.append(i)
            slots.append(row)
        if displaced_rows:
            # one batched invalidation per put(), not one per eviction
            self.table = self.store.invalidate_rows(self.table,
                                                    displaced_rows)
        if rows:
            embs = jnp.asarray(embs)
            # the store's device tier bounds how many rows one migration can
            # pin at once; insert in tier-sized chunks
            chunk = min(len(rows), self.store.device_rows)
            for i0 in range(0, len(rows), chunk):
                rows_p, idx_p = pad_rows_pow2(rows[i0:i0 + chunk],
                                              idx[i0:i0 + chunk])
                # rows about to be fully overwritten: residency only, no
                # host->device content fetch
                self.table, dev_rows = self.store.prepare(
                    self.table, rows_p, fetch=False)
                self.table = self._update(self.table, jnp.asarray(dev_rows),
                                          embs[idx_p], jnp.int32(self.step))
        return slots

    def gather(self, slots, valid=None) -> jnp.ndarray:
        """(len(slots), d_h) embeddings — the stored values, so a hit
        returns bit-identical bytes to what was inserted (spilled rows are
        faulted back host->device first).  ``valid`` (0/1, same length)
        limits the liveness assertion to real entries when the caller padded
        ``slots`` to a static shape.  Gathers wider than the store's device
        tier run in tier-sized chunks (pow2-floored so the jitted-shape set
        stays O(log capacity))."""
        rows = np.asarray(slots, np.int32)
        if len(rows) == 0:
            return jnp.zeros((0, self.d_h), self.store.dtype)
        chunk = min(prev_pow2(self.store.device_rows), len(rows))
        embs, inits = [], []
        for i0 in range(0, len(rows), chunk):
            self.table, dev_rows = self.store.prepare(self.table,
                                                      rows[i0:i0 + chunk])
            e, i = self._lookup(self.table, jnp.asarray(dev_rows))
            embs.append(e)
            inits.append(i)
        emb = embs[0] if len(embs) == 1 else jnp.concatenate(embs)
        init = inits[0] if len(inits) == 1 else jnp.concatenate(inits)
        live = init if valid is None else jnp.where(jnp.asarray(valid) > 0,
                                                    init, True)
        assert bool(live.all()), "gather() of an evicted/uninitialized slot"
        return emb

    def stats(self) -> Dict:
        total = self.hits + self.misses
        ages, init = self.store.ages_init(self.table)
        ages, init = ages[:, 0], init[:, 0]
        live_ages = (self.step - ages[init]) if init.any() else np.zeros(0)
        return {
            "capacity": self.capacity,
            "size": len(self._slots),
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": (self.hits / total) if total else 0.0,
            "evictions": self.evictions,
            "skipped_inserts": self.skipped_inserts,
            "age_mean_steps": float(live_ages.mean()) if live_ages.size else 0.0,
            "age_max_steps": int(live_ages.max()) if live_ages.size else 0,
            "store": self.store.stats(),
        }
