"""Padded-CSR segment buckets for the serving engine.

Incoming segments (arbitrary node/edge counts, from arbitrary graphs) are
routed into a small ladder of static (m_max, e_max, batch) shapes so the
jitted encode step compiles ONCE per bucket and segments from different
requests share a device batch.  This is the serving analogue of the training
pipeline's single (m_max, e_max) padding in graphs/batching.py — the same
``pad_segment`` does the padding; the ladder just picks which static shape a
segment lands in.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.graphs.batching import pad_segment
from repro.graphs.data import SyntheticGraph


@dataclass(frozen=True)
class BucketSpec:
    """One static encode shape: segments padded to (m_max, e_max), batched
    ``batch`` at a time (short batches are padded with invalid segments)."""
    m_max: int
    e_max: int
    batch: int = 8

    @property
    def key(self) -> str:
        return f"m{self.m_max}_e{self.e_max}_b{self.batch}"


def default_ladder(max_seg_nodes: int = 64, batch: int = 8,
                   edge_factor: int = 8, n_buckets: int = 3) -> Tuple[BucketSpec, ...]:
    """Doubling node-size ladder ending at max_seg_nodes, edges ~8x nodes
    (comfortably above the synthetic datasets' density so the catch-all
    bucket almost never truncates; oversized edge lists are truncated by
    pad_segment exactly as in training)."""
    sizes = [max(max_seg_nodes >> (n_buckets - 1 - i), 4) for i in range(n_buckets)]
    sizes = sorted(set(sizes))
    return tuple(BucketSpec(m, m * edge_factor, batch) for m in sizes)


def choose_bucket(ladder: Sequence[BucketSpec], n_nodes: int, n_edges: int) -> int:
    """Smallest bucket that fits the segment; the LAST bucket is the
    catch-all (node lists/edge lists beyond its shape are truncated, matching
    the training-side pad_segment semantics)."""
    for i, spec in enumerate(ladder):
        if n_nodes <= spec.m_max and n_edges <= spec.e_max:
            return i
    return len(ladder) - 1


def truncation_counts(n_nodes: int, n_edges: int,
                      spec: BucketSpec) -> Tuple[int, int]:
    """How many nodes/edges ``pad_to_bucket`` will DROP for a segment of
    this size routed to ``spec`` — nonzero only for catch-all overflow
    (choose_bucket routes every fitting segment to a bucket that holds
    it).  The engine counts these per request so silent truncation
    becomes a published counter the obs gate can fail on."""
    return (max(n_nodes - spec.m_max, 0), max(n_edges - spec.e_max, 0))


def count_local_edges(graph: SyntheticGraph, node_ids: np.ndarray) -> int:
    sel = np.isin(graph.edges[:, 0], node_ids) & np.isin(graph.edges[:, 1], node_ids)
    return int(sel.sum())


def pad_to_bucket(graph: SyntheticGraph, node_ids: np.ndarray,
                  spec: BucketSpec) -> Dict[str, np.ndarray]:
    """One segment -> the bucket's static shapes (x, edges, edge_valid,
    node_valid), via the training pipeline's pad_segment."""
    x, e, ev, nv = pad_segment(graph, node_ids, spec.m_max, spec.e_max)
    return {"x": x, "edges": e, "edge_valid": ev, "node_valid": nv}


def batch_bucket(padded: List[Dict[str, np.ndarray]],
                 spec: BucketSpec) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
    """Stack <= spec.batch padded segments into one device batch, padding the
    batch dim to spec.batch.  Returns (seg_inputs, seg_valid (batch,))."""
    n = len(padded)
    assert 0 < n <= spec.batch
    out = {}
    for k in ("x", "edges", "edge_valid", "node_valid"):
        first = padded[0][k]
        arr = np.zeros((spec.batch,) + first.shape, first.dtype)
        for i, seg in enumerate(padded):
            arr[i] = seg[k]
        out[k] = arr
    valid = np.zeros((spec.batch,), np.float32)
    valid[:n] = 1.0
    return out, valid


def segment_fingerprint(padded: Dict[str, np.ndarray], bucket_idx: int) -> bytes:
    """Content address of a padded segment: identical subgraphs (same local
    node features, same local edge list, same bucket) map to the same key —
    the cross-request cache key."""
    import hashlib
    h = hashlib.blake2b(digest_size=16)
    h.update(bucket_idx.to_bytes(4, "little"))
    for k in ("x", "edges", "edge_valid", "node_valid"):
        a = np.ascontiguousarray(padded[k])
        h.update(a.tobytes())
    return h.digest()
