"""Synthetic request traffic for the serving engine.

Models the serving-side distribution the ROADMAP's "millions of users" north
star implies: a pool of unique graphs with a heavy-tailed size mix, replayed
as a request stream in which a configurable fraction of requests repeat an
earlier graph (duplicate_rate) — the knob that exercises the cross-request
segment cache.  Repeated requests reference the SAME graph object, so the
deterministic partitioner reproduces identical segments and the cache keys
match by content.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

from repro.graphs.data import SyntheticGraph, make_malnet_like


@dataclass(frozen=True)
class TrafficConfig:
    n_unique: int = 24            # unique graphs in the pool
    n_requests: int = 64          # total request stream length
    duplicate_rate: float = 0.5   # P(request repeats an already-seen graph)
    comm_range: Tuple[int, int] = (2, 12)    # wide -> mixed graph sizes
    comm_size_range: Tuple[int, int] = (12, 48)
    n_types: int = 5
    n_feat: int = 8
    seed: int = 0


def make_graph_pool(cfg: TrafficConfig) -> List[SyntheticGraph]:
    """Unique graphs with mixed sizes (small requests land in small buckets,
    large ones span several segments) — the training dataset's generator, so
    serving traffic follows the training distribution by construction."""
    pool = make_malnet_like(
        n_graphs=cfg.n_unique, n_classes=cfg.n_types, n_feat=cfg.n_feat,
        comm_range=cfg.comm_range, comm_size_range=cfg.comm_size_range,
        seed=cfg.seed)
    for gi, g in enumerate(pool):
        g.meta["pool_id"] = gi
    return pool


def make_request_stream(cfg: TrafficConfig) -> List[SyntheticGraph]:
    """Request stream over the pool.  The first occurrence of each graph is
    always a cold miss; with probability duplicate_rate a request re-serves a
    uniformly chosen already-seen graph."""
    pool = make_graph_pool(cfg)
    rng = np.random.default_rng(cfg.seed + 1)
    stream: List[SyntheticGraph] = []
    seen: List[int] = []
    fresh = list(range(len(pool)))
    for _ in range(cfg.n_requests):
        if seen and (not fresh or rng.random() < cfg.duplicate_rate):
            gi = int(seen[int(rng.integers(len(seen)))])
        else:
            gi = fresh.pop(0)
        seen.append(gi)
        stream.append(pool[gi])
    return stream
