"""Synthetic request traffic for the serving engine.

Models the serving-side distribution the ROADMAP's "millions of users" north
star implies: a pool of unique graphs with a heavy-tailed size mix, replayed
as a request stream in which a configurable fraction of requests repeat an
earlier graph (duplicate_rate) — the knob that exercises the cross-request
segment cache.  Repeated requests reference the SAME graph object, so the
deterministic partitioner reproduces identical segments and the cache keys
match by content.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

from repro.graphs.data import SyntheticGraph, make_malnet_like


@dataclass(frozen=True)
class TrafficConfig:
    n_unique: int = 24            # unique graphs in the pool
    n_requests: int = 64          # total request stream length
    duplicate_rate: float = 0.5   # P(request repeats an already-seen graph)
    popularity: float = 0.0       # repeat-pick skew over distinct seen
                                  # graphs: P(g) ∝ times_served(g)**popularity.
                                  # 0 = uniform over distinct seen ids (the
                                  # documented default), 1 = proportional
                                  # rich-get-richer (the old accidental
                                  # behavior), >1 = steeper head
    comm_range: Tuple[int, int] = (2, 12)    # wide -> mixed graph sizes
    comm_size_range: Tuple[int, int] = (12, 48)
    n_types: int = 5
    n_feat: int = 8
    seed: int = 0


def make_graph_pool(cfg: TrafficConfig) -> List[SyntheticGraph]:
    """Unique graphs with mixed sizes (small requests land in small buckets,
    large ones span several segments) — the training dataset's generator, so
    serving traffic follows the training distribution by construction."""
    pool = make_malnet_like(
        n_graphs=cfg.n_unique, n_classes=cfg.n_types, n_feat=cfg.n_feat,
        comm_range=cfg.comm_range, comm_size_range=cfg.comm_size_range,
        seed=cfg.seed)
    for gi, g in enumerate(pool):
        g.meta["pool_id"] = gi
    return pool


def make_request_stream(cfg: TrafficConfig) -> List[SyntheticGraph]:
    """Request stream over the pool.  The first occurrence of each graph is
    always a cold miss; with probability duplicate_rate a request re-serves
    an already-seen graph — uniformly over DISTINCT seen ids by default,
    or skewed ∝ times_served**popularity when cfg.popularity > 0.

    (The stream used to sample from the seen list WITH duplicates, which
    silently compounded popularity — every repeat made the next repeat of
    the same graph more likely — inflating cache hit-rates beyond what the
    docstring promised.  That behavior is now the explicit popularity=1
    setting.)"""
    pool = make_graph_pool(cfg)
    rng = np.random.default_rng(cfg.seed + 1)
    stream: List[SyntheticGraph] = []
    seen: List[int] = []              # distinct seen ids, arrival order
    count: dict = {}                  # id -> times served
    fresh = list(range(len(pool)))
    for _ in range(cfg.n_requests):
        if seen and (not fresh or rng.random() < cfg.duplicate_rate):
            if cfg.popularity > 0.0:
                w = np.array([count[g] for g in seen], np.float64)
                w = w ** cfg.popularity
                gi = int(rng.choice(seen, p=w / w.sum()))
            else:
                gi = int(seen[int(rng.integers(len(seen)))])
        else:
            gi = fresh.pop(0)
        if gi not in count:
            seen.append(gi)
        count[gi] = count.get(gi, 0) + 1
        stream.append(pool[gi])
    return stream
