"""Graph-property serving engine: constant-memory segment-streaming inference.

GST's Eq.-1 structure — encode segments independently, aggregate, then run a
small head — means inference never needs the whole graph in device memory.
The engine exploits that twice:

* ``make_stream_encoder``: a ``lax.scan`` over fixed-size chunks of one
  graph's padded segments, accumulating only the pooled readout carry
  (d_h floats + a count).  Peak live activation memory is bounded by ONE
  chunk of one bucket shape no matter how large the graph is — the scan
  body's buffers are reused across iterations (asserted by buffer-size
  accounting in tests/test_serve.py).

* ``ServeEngine.process``: bucketed dynamic batching across requests.
  Segments from all requests in a window are routed into a small ladder of
  padded-CSR buckets (serve/buckets.py), deduplicated against the
  cross-request segment cache (serve/cache.py), and only the misses are
  encoded — batched per bucket so the jitted encode compiles once per
  bucket shape.  On a full cache hit only the cheap head runs.

Both paths go through graphs/gnn.py::encode_segments, so the Pallas fused
kernels and the jnp reference produce the same serving numbers as training.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import gst as G
from repro.graphs.data import SyntheticGraph
from repro.graphs.gnn import GNNConfig, encode_segments, gnn_init
from repro.graphs.partition import partition_graph
from repro.kernels.ops import count_pallas_calls
from repro.obs.metrics import (AGE_BUCKETS_STEPS, LATENCY_BUCKETS_MS,
                               Histogram, get_registry, summarize)
from repro.obs.memory import probe_jit
from repro.obs.trace import span
from repro.serve.buckets import (
    BucketSpec,
    batch_bucket,
    choose_bucket,
    count_local_edges,
    default_ladder,
    pad_to_bucket,
    segment_fingerprint,
    truncation_counts,
)
from repro.serve.cache import SegmentCache, next_pow2
from repro.store import StoreCounters, TieredStore

SEG_KEYS = ("x", "edges", "edge_valid", "node_valid")


# ---------------------------------------------------------------------------
# streaming encoder (constant-memory single-graph path)
# ---------------------------------------------------------------------------


def graph_to_chunks(graph: SyntheticGraph, spec: BucketSpec, chunk: int, *,
                    partition: str = "bfs", seed: int = 0,
                    partition_max_nodes: int = 0,
                    pad_chunks_pow2: bool = True) -> Dict[str, np.ndarray]:
    """Partition + pad one graph into scan-ready chunks: leaves
    (n_chunks, chunk, ...) plus ``seg_valid`` (n_chunks, chunk).

    partition_max_nodes: segment size cap for the partitioner (default: the
    bucket's m_max).  The engine passes its cfg.max_seg_nodes so the
    streaming path sees the SAME segmentation as the bucketed path.
    n_chunks is padded to the next power of two (invalid chunks are
    all-zero) so the jitted scan compiles O(log J) times, not once per J.
    """
    segs = partition_graph(len(graph.x), graph.edges,
                           partition_max_nodes or spec.m_max, partition, seed)
    padded = [pad_to_bucket(graph, s, spec) for s in segs]
    n = len(padded)
    n_chunks = max((n + chunk - 1) // chunk, 1)
    if pad_chunks_pow2:
        n_chunks = next_pow2(n_chunks)
    out: Dict[str, np.ndarray] = {}
    for k in SEG_KEYS:
        first = padded[0][k]
        arr = np.zeros((n_chunks, chunk) + first.shape, first.dtype)
        for i, seg in enumerate(padded):
            arr[i // chunk, i % chunk] = seg[k]
        out[k] = arr
    valid = np.zeros((n_chunks, chunk), np.float32)
    valid.reshape(-1)[:n] = 1.0
    out["seg_valid"] = valid
    return out


def make_stream_encoder(cfg: GNNConfig, *, head_mode: str = "mlp",
                        agg: str = "mean"):
    """Returns jitted ``stream(params, head, chunks) -> (pred, pooled)``.

    chunks: dict with SEG_KEYS leaves (C, chunk, ...) and seg_valid
    (C, chunk).  The scan carry is only the pooled accumulator — (d_h,) for
    the MLP head, a scalar for the per-segment head — so live memory is one
    chunk's activations regardless of C.
    """

    def stream(params, head, chunks):
        seg_valid = chunks["seg_valid"]

        def body(carry, ch):
            h = encode_segments(params, cfg,
                                {k: ch[k] for k in SEG_KEYS})     # (chunk, d)
            w = ch["seg_valid"]
            s, cnt = carry
            if head_mode == "segment_sum":
                scal = G.head_apply(head, h, "segment_sum")       # (chunk,)
                s = s + jnp.sum(scal * w)
            else:
                s = s + jnp.sum(h * w[:, None], axis=0)
            return (s, cnt + jnp.sum(w)), None

        if head_mode == "segment_sum":
            init_s = jnp.zeros((), jnp.float32)
        else:
            # carry width = hidden dim, recovered from the head params
            init_s = jnp.zeros((head["w1"].shape[0],), jnp.float32)
        (s, cnt), _ = lax.scan(body, (init_s, jnp.zeros((), jnp.float32)),
                               dict(chunks))
        denom = jnp.maximum(cnt, 1.0) if agg == "mean" else 1.0
        pooled = s / denom
        if head_mode == "segment_sum":
            return pooled, pooled          # pred IS the pooled scalar (F' = Σ)
        return G.head_apply(head, pooled, "mlp"), pooled

    return jax.jit(stream)


# ---------------------------------------------------------------------------
# serving engine (bucketed batching + cross-request cache)
# ---------------------------------------------------------------------------


@dataclass
class ServeConfig:
    backbone: str = "sage"             # gcn | sage | gps
    n_feat: int = 8
    hidden: int = 64
    use_pallas: bool = False
    head_mode: str = "mlp"             # mlp | segment_sum
    agg: str = "mean"                  # mean | sum
    n_out: int = 5
    max_seg_nodes: int = 64
    partition: str = "bfs"
    partition_seed: int = 0            # fixed -> identical graphs re-partition
                                       # identically -> cache hits
    ladder: Optional[Tuple[BucketSpec, ...]] = None
    cache_capacity: int = 512
    cache_enabled: bool = True
    # cap on DEVICE-resident cache rows: None keeps all cache_capacity rows
    # in device memory (DeviceStore); an int backs the cache with a
    # TieredStore — cold entries spill to host RAM and fault back on hit
    # instead of being re-encoded
    table_device_rows: Optional[int] = None
    # device-tier eviction policy when table_device_rows is set
    # (store/slots.py: "lru" or age-aware "stale-first")
    evict_policy: str = "lru"
    # delta-gated write-back: skip the host-tier emb write for spilled rows
    # that moved less than this while device-resident (store/writeback.py);
    # 0 keeps the store bit-exact
    wb_threshold: float = 0.0
    # online per-row forecasting of stale host-tier cache rows on fault-in
    # (store/forecast.py).  The offline engine's cache rows are written
    # once and never drift, and its store path passes no step hints, so
    # this is plumbing for the train-while-serve deployment — a no-op
    # (bit-exact) for the offline replay either way
    stale_forecast: bool = False
    stream_chunk: int = 8

    def resolved_ladder(self) -> Tuple[BucketSpec, ...]:
        return self.ladder or default_ladder(self.max_seg_nodes)


@dataclass
class RequestResult:
    request_id: int
    pred: np.ndarray                   # () scalar or (n_out,) logits
    latency_ms: float
    n_segments: int
    n_cache_hits: int


def _latency_hist() -> Histogram:
    return Histogram("latency_ms", buckets=LATENCY_BUCKETS_MS, unit="ms")


@dataclass
class ServeStats:
    n_requests: int = 0
    n_segments: int = 0
    encode_launches: int = 0           # jitted bucket-encode invocations
    encoded_segments: int = 0          # segments that actually ran the GNN
    pallas_launches: int = 0           # encode kernel launches (pallas path)
    truncated_nodes: int = 0           # nodes dropped by catch-all overflow
    truncated_edges: int = 0           # edges dropped by catch-all overflow
    wall_s: float = 0.0
    # fixed-bucket histogram, not a per-request list: a replay of any
    # length summarizes in O(buckets) memory (obs.metrics)
    latency: Histogram = field(default_factory=_latency_hist)
    cache: Dict = field(default_factory=dict)

    def summary(self) -> Dict:
        lat = summarize(self.latency)
        return {
            "n_requests": self.n_requests,
            "n_segments": self.n_segments,
            "throughput_req_s": self.n_requests / self.wall_s if self.wall_s else 0.0,
            "latency_p50_ms": lat["p50"],
            "latency_p99_ms": lat["p99"],
            "latency_mean_ms": lat["mean"],
            "encode_launches": self.encode_launches,
            "encoded_segments": self.encoded_segments,
            "pallas_launches": self.pallas_launches,
            "truncated_nodes": self.truncated_nodes,
            "truncated_edges": self.truncated_edges,
            "cache": dict(self.cache),
        }


class ServeEngine:
    """Answers streams of graph-property requests with constant device memory.

    Request flow:  partition -> bucket -> cache probe -> batched encode of
    the misses (one jitted call per bucket shape) -> cache insert ->
    η=1 aggregate -> head.
    """

    def __init__(self, cfg: ServeConfig, params: Any = None, head: Any = None,
                 seed: int = 0):
        self.cfg = cfg
        self.gnn_cfg = GNNConfig(backbone=cfg.backbone, n_feat=cfg.n_feat,
                                 hidden=cfg.hidden, use_pallas=cfg.use_pallas)
        key = jax.random.key(seed)
        self.params = params if params is not None else gnn_init(key, self.gnn_cfg)
        self.head = head if head is not None else G.head_init(
            jax.random.fold_in(key, 1), cfg.hidden, cfg.n_out, cfg.head_mode)
        self.ladder = cfg.resolved_ladder()
        store = None
        if cfg.cache_enabled and cfg.table_device_rows is not None:
            store = TieredStore(cfg.cache_capacity, 1, cfg.hidden,
                                device_rows=cfg.table_device_rows,
                                evict_policy=cfg.evict_policy,
                                wb_threshold=cfg.wb_threshold,
                                stale_forecast=cfg.stale_forecast)
        self.cache = (SegmentCache(cfg.cache_capacity, cfg.hidden, store=store)
                      if cfg.cache_enabled else None)
        self.stats = ServeStats()
        self._encode_jit: Dict[int, Any] = {}
        self._pallas_per_launch: Dict[int, int] = {}
        self._head_fn = probe_jit("serve.head", jax.jit(self._head_impl))
        self._request_counter = 0

    def close(self):
        """Release the cache's backing store (the TieredStore write-back
        thread when --table-device-rows is set)."""
        if self.cache is not None:
            self.cache.close()

    def reset_stats(self):
        """Zero the counters (post-warmup), keeping compiled fns and cache
        contents; cache hit/miss counters restart too."""
        self.stats = ServeStats()
        if self.cache is not None:
            self.cache.hits = self.cache.misses = 0
            self.cache.evictions = self.cache.skipped_inserts = 0
            self.cache.store.counters = StoreCounters()

    # -- encode ------------------------------------------------------------

    def _encode_bucket(self, bi: int, seg_inputs: Dict[str, np.ndarray]) -> jnp.ndarray:
        if bi not in self._encode_jit:
            gc = self.gnn_cfg
            self._encode_jit[bi] = probe_jit(
                f"serve.encode.{self.ladder[bi].key}",
                jax.jit(lambda p, si: encode_segments(p, gc, si)))
            dev_inputs = {k: jnp.asarray(v) for k, v in seg_inputs.items()}
            self._pallas_per_launch[bi] = count_pallas_calls(
                lambda p: encode_segments(p, gc, dev_inputs), self.params)
        with span("serve.encode", bucket=bi):
            emb = self._encode_jit[bi](self.params,
                                       {k: jnp.asarray(v) for k, v in seg_inputs.items()})
        self.stats.encode_launches += 1
        self.stats.pallas_launches += self._pallas_per_launch[bi]
        return emb

    # -- request processing ------------------------------------------------

    def _segment_request(self, graph: SyntheticGraph):
        """Partition + route one graph; returns [(key, bucket_idx, padded)].

        Catch-all overflow is counted, not silent: segments larger than the
        last bucket's shape lose their overflow nodes/edges to pad_segment's
        truncation — a prediction-accuracy hazard the obs gate fails on
        (``repro.obs.gate --check serve``) unless --allow-truncation."""
        segs = partition_graph(len(graph.x), graph.edges, self.cfg.max_seg_nodes,
                               self.cfg.partition, self.cfg.partition_seed)
        items = []
        tn = te = 0
        for s in segs:
            ne = count_local_edges(graph, s)
            bi = choose_bucket(self.ladder, len(s), ne)
            dn, de = truncation_counts(len(s), ne, self.ladder[bi])
            tn += dn
            te += de
            padded = pad_to_bucket(graph, s, self.ladder[bi])
            items.append((segment_fingerprint(padded, bi), bi, padded))
        if tn or te:
            self.stats.truncated_nodes += tn
            self.stats.truncated_edges += te
            reg = get_registry()
            if reg.enabled:
                if tn:
                    reg.inc("serve.bucket.truncated_nodes", tn, unit="nodes")
                if te:
                    reg.inc("serve.bucket.truncated_edges", te, unit="edges")
        return items

    def process(self, graphs: Sequence[SyntheticGraph],
                window: int = 8) -> List[RequestResult]:
        """Serve a stream of requests in arrival order, ``window`` at a time
        (the dynamic-batching window: segments of all requests in a window
        share device batches)."""
        results: List[RequestResult] = []
        for w0 in range(0, len(graphs), window):
            chunk = graphs[w0:w0 + window]
            with span("serve.window", requests=len(chunk)):
                results.extend(self._process_window(chunk))
        return results

    def _process_window(self, graphs: Sequence[SyntheticGraph]) -> List[RequestResult]:
        t0 = time.perf_counter()
        launches0 = self.stats.encode_launches
        with span("serve.partition", requests=len(graphs)):
            requests = [self._segment_request(g) for g in graphs]

        # cache probe (per segment occurrence) + miss dedup (per content key)
        key_slot: Dict[bytes, int] = {}
        miss_by_bucket: Dict[int, List[Tuple[bytes, Dict]]] = {}
        seen_miss = set()
        hits_per_req = []
        for items in requests:
            n_hits = 0
            for key, bi, padded in items:
                if self.cache is not None:
                    slot = key_slot.get(key)
                    if slot is None:
                        slot = self.cache.get(key)
                    else:
                        self.cache.hits += 1  # in-window duplicate of a hit
                    if slot is not None:
                        key_slot[key] = slot
                        n_hits += 1
                        continue
                if key not in seen_miss:
                    seen_miss.add(key)
                    miss_by_bucket.setdefault(bi, []).append((key, padded))
            hits_per_req.append(n_hits)

        # batched encode of the misses, one jitted call per bucket batch
        fresh: Dict[bytes, jnp.ndarray] = {}
        for bi, misses in sorted(miss_by_bucket.items()):
            spec = self.ladder[bi]
            for i in range(0, len(misses), spec.batch):
                chunk = misses[i:i + spec.batch]
                seg_inputs, _valid = batch_bucket([p for _, p in chunk], spec)
                emb = self._encode_bucket(bi, seg_inputs)       # (batch, d)
                for j, (key, _) in enumerate(chunk):
                    fresh[key] = emb[j]
                self.stats.encoded_segments += len(chunk)

        # cross-request insert (best-effort: over-capacity batches keep what
        # fits): the next window (or request) hits these.  This window's hit
        # keys are pinned — their slots are gathered below.
        if self.cache is not None and fresh:
            with span("serve.insert", segments=len(fresh)):
                keys = list(fresh)
                slots = self.cache.put(keys,
                                       jnp.stack([fresh[k] for k in keys]),
                                       pinned=key_slot.keys())
                for k, s in zip(keys, slots):
                    if s is not None:
                        key_slot[k] = s

        # per-request aggregate + head: J is padded to the next power of two
        # with a validity mask so the jitted head compiles O(log J) shapes.
        # This window's misses aggregate from ``fresh`` (bit-identical to
        # what was just inserted); hits gather from the cache table.
        out: List[RequestResult] = []
        reg = get_registry()
        hit_rows: List[int] = []       # cache rows this window's hits read
        n_fresh_reads = 0              # fresh-embedding reads (staleness 0)
        for ri, (graph, items) in enumerate(zip(graphs, requests)):
            J = len(items)
            Jp = next_pow2(J)
            mask = np.zeros((Jp,), np.float32)
            mask[:J] = 1.0
            cached_pos = [j for j, (key, _, _) in enumerate(items)
                          if key not in fresh]
            cemb = None
            if cached_pos:
                cp = next_pow2(len(cached_pos))
                cmask = np.zeros((cp,), np.float32)
                cmask[:len(cached_pos)] = 1.0
                cslots = [key_slot[items[j][0]] for j in cached_pos]
                hit_rows.extend(cslots)
                cslots += [cslots[0]] * (cp - len(cslots))
                with span("serve.gather", rows=len(cached_pos)):
                    cemb = self.cache.gather(cslots, valid=cmask)  # (cp, d)
            rows, ci = [], 0
            for key, _, _ in items:
                if key in fresh:
                    rows.append(fresh[key])
                    n_fresh_reads += 1
                else:
                    rows.append(cemb[ci])
                    ci += 1
            h = jnp.stack(rows + [rows[0]] * (Jp - J))           # (Jp, d)
            with span("serve.head", segments=J):
                pred = self._head_fn(self.head, h, jnp.asarray(mask))
                pred_np = np.asarray(jax.block_until_ready(pred))
            latency_ms = (time.perf_counter() - t0) * 1e3
            out.append(RequestResult(
                request_id=self._request_counter, pred=pred_np,
                latency_ms=latency_ms, n_segments=len(items),
                n_cache_hits=hits_per_req[ri]))
            self._request_counter += 1
            self.stats.latency.observe(latency_ms)
            reg.observe("serve.latency_ms", latency_ms,
                        buckets=LATENCY_BUCKETS_MS, unit="ms")
            self.stats.n_segments += len(items)
        self.stats.n_requests += len(graphs)
        self.stats.wall_s += time.perf_counter() - t0
        if reg.enabled:
            self._publish_window(reg, n_requests=len(graphs),
                                 n_launches=self.stats.encode_launches
                                 - launches0, hit_rows=hit_rows,
                                 n_fresh_reads=n_fresh_reads)
        if self.cache is not None:
            self.stats.cache = self.cache.stats()
        return out

    def _publish_window(self, reg, *, n_requests: int, n_launches: int,
                        hit_rows: List[int], n_fresh_reads: int) -> None:
        """Registry mirror of one window (only on the --metrics path).

        ``serve.prediction_staleness``: the age, in cache insertion steps,
        of every table row the window's served predictions actually read —
        hits gather rows stamped ``cache.step`` at insert time, fresh
        encodes read age-0 embeddings.  The ROADMAP's train-while-serve
        staleness metric, landed first in the offline engine."""
        reg.inc("serve.windows")
        reg.inc("serve.requests", n_requests)
        reg.inc("serve.encode_launches", n_launches)
        if self.cache is not None:
            self.cache.publish_counters()
            hist = reg.histogram("serve.prediction_staleness",
                                 buckets=AGE_BUCKETS_STEPS, unit="steps")
            if hit_rows:
                # stats-grade ages_init (no write-back flush on the hot
                # path); slot 0 is the segment slot the cache addresses
                age, _ = self.cache.store.ages_init(self.cache.table)
                hist.observe_many(self.cache.step
                                  - age[np.asarray(hit_rows, np.int64), 0])
            if n_fresh_reads:
                hist.observe_many(np.zeros(n_fresh_reads))

    def _head_impl(self, head, h: jnp.ndarray, mask: jnp.ndarray):
        """η=1 aggregate + head over one request's segment embeddings
        (Jp, d) with validity mask (Jp,) — the paper's test-time
        distribution P(F'(⊕ h_j), y)."""
        J = jnp.maximum(jnp.sum(mask), 1.0)
        if self.cfg.head_mode == "segment_sum":
            scal = G.head_apply(head, h, "segment_sum")          # (Jp,)
            s = jnp.sum(scal * mask)
            return s / J if self.cfg.agg == "mean" else s
        pooled = jnp.sum(h * mask[:, None], axis=0)
        pooled = pooled / J if self.cfg.agg == "mean" else pooled
        return G.head_apply(head, pooled, "mlp")

    # -- streaming single-graph path --------------------------------------

    def predict_streaming(self, graph: SyntheticGraph) -> np.ndarray:
        """Constant-memory prediction for one (arbitrarily large) graph via
        the lax.scan streaming encoder; bypasses the cache."""
        spec = self.ladder[-1]
        chunks = graph_to_chunks(graph, spec, self.cfg.stream_chunk,
                                 partition=self.cfg.partition,
                                 seed=self.cfg.partition_seed,
                                 partition_max_nodes=self.cfg.max_seg_nodes)
        if not hasattr(self, "_stream"):
            self._stream = probe_jit("serve.stream", make_stream_encoder(
                self.gnn_cfg, head_mode=self.cfg.head_mode, agg=self.cfg.agg))
        pred, _ = self._stream(self.params, self.head,
                               {k: jnp.asarray(v) for k, v in chunks.items()})
        return np.asarray(pred)
