"""Graph-property serving: segment-streaming inference with a cross-request
segment-embedding cache (the inference-side face of GST's Eq. 1)."""
from repro.serve.buckets import (  # noqa: F401
    BucketSpec,
    batch_bucket,
    choose_bucket,
    default_ladder,
    pad_to_bucket,
    segment_fingerprint,
)
from repro.serve.cache import SegmentCache  # noqa: F401
from repro.serve.engine import (  # noqa: F401
    RequestResult,
    ServeConfig,
    ServeEngine,
    ServeStats,
    graph_to_chunks,
    make_stream_encoder,
)
from repro.serve.traffic import TrafficConfig, make_graph_pool, make_request_stream  # noqa: F401
