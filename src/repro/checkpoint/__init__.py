from repro.checkpoint.io import (load_checkpoint, load_store_checkpoint,
                                 latest_checkpoint, save_checkpoint,
                                 save_store_checkpoint)

__all__ = ["save_checkpoint", "load_checkpoint", "latest_checkpoint",
           "save_store_checkpoint", "load_store_checkpoint"]
