"""Pytree checkpointing via msgpack (+ numpy buffers).

Layout: <dir>/step_<N>.ckpt — a single msgpack file holding the flattened
pytree (paths -> {dtype, shape, raw bytes}).  Device arrays are pulled to
host; restore re-creates jnp arrays (placement/sharding is the caller's job,
e.g. jax.device_put with the target sharding after load).
"""
from __future__ import annotations

import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        out[key] = {"dtype": str(arr.dtype), "shape": list(arr.shape),
                    "data": arr.tobytes()}
    return out


def save_checkpoint(path_dir: str, step: int, tree: Any, keep: int = 3) -> str:
    os.makedirs(path_dir, exist_ok=True)
    path = os.path.join(path_dir, f"step_{step:08d}.ckpt")
    payload = _flatten(tree)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb({"step": step, "arrays": payload}))
    os.replace(tmp, path)
    # rotate
    ckpts = sorted(f for f in os.listdir(path_dir) if re.match(r"step_\d+\.ckpt$", f))
    for old in ckpts[:-keep]:
        os.remove(os.path.join(path_dir, old))
    return path


def latest_checkpoint(path_dir: str) -> Optional[str]:
    if not os.path.isdir(path_dir):
        return None
    ckpts = sorted(f for f in os.listdir(path_dir) if re.match(r"step_\d+\.ckpt$", f))
    return os.path.join(path_dir, ckpts[-1]) if ckpts else None


def load_checkpoint(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (paths must match)."""
    with open(path, "rb") as f:
        blob = msgpack.unpackb(f.read())
    arrays = blob["arrays"]
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_elems, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_elems)
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key}")
        rec = arrays[key]
        arr = np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"])).reshape(rec["shape"])
        leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves])


# ---------------------------------------------------------------------------
# embedding-store checkpointing (store/): the historical table's rows, ages
# and init flags — BOTH tiers — so a capped-capacity run is resumable
# ---------------------------------------------------------------------------


def save_store_checkpoint(path_dir: str, step: int, store, table,
                          extra: Any = None, keep: int = 3) -> str:
    """Checkpoint an EmbeddingStore's full logical table.

    ``store.snapshot(table)`` merges the device tier into the host tier
    (flushing pending async write-backs first), so the file holds the
    dense (n_rows, J, d) embeddings + ages + initialized flags regardless
    of backend or how rows were split across tiers at save time.
    ``extra``: optional dict pytree saved alongside (params, opt state…);
    its keys must not include "table".
    """
    extra = dict(extra or {})
    if "table" in extra:
        raise ValueError('"table" is reserved for the store snapshot')
    snap = store.snapshot(table)
    return save_checkpoint(path_dir, step, {"table": snap._asdict(), **extra},
                           keep=keep)


def load_store_checkpoint(path: str, store, extra_like: Any = None):
    """Restore a ``save_store_checkpoint`` file into ``store``.

    Returns ``(device_table, extra)``: the store's new device tier (seed it
    into TrainState) and the restored extra pytree matching ``extra_like``.
    Residency is reset — a TieredStore restarts with every row in the host
    tier and re-faults working sets on demand; since residency is not
    semantic state, training resumes bit-exactly either way
    (tests/test_store.py::test_checkpoint_roundtrip_*).
    """
    from repro.core.embedding_table import EmbeddingTable

    extra_like = dict(extra_like or {})
    like_table = {
        "emb": np.zeros((store.n_rows, store.j_max, store.d_h),
                        jnp.dtype(store.dtype)),
        "age": np.zeros((store.n_rows, store.j_max), np.int32),
        "initialized": np.zeros((store.n_rows, store.j_max), bool),
    }
    tree = load_checkpoint(path, {"table": like_table, **extra_like})
    snap = EmbeddingTable(**{k: tree["table"][k] for k in like_table})
    device_table = store.restore(snap)
    return device_table, {k: tree[k] for k in extra_like}
