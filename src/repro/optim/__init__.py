from repro.optim.adamw import (
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
    constant_schedule,
    make_optimizer,
)

__all__ = [
    "adamw_init",
    "adamw_update",
    "clip_by_global_norm",
    "cosine_schedule",
    "constant_schedule",
    "make_optimizer",
]
