"""AdamW + schedules + gradient clipping, pure JAX (no optax in this env).

The paper trains with Adam (MalNet GCN/SAGE, TpuGraphs) and AdamW + cosine
(GraphGPS) [Appendix B]; both are covered here.  Optimizer state is a pytree
mirroring params, so it shards with the same PartitionSpecs (FSDP-friendly).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


def cosine_schedule(base_lr: float, total_steps: int, warmup: int = 0,
                    final_frac: float = 0.0) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1), 0.0, 1.0)
        cos = base_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def constant_schedule(base_lr: float) -> Callable:
    return lambda step: jnp.asarray(base_lr, jnp.float32)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree_util.tree_map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


def adamw_init(params, state_dtype=jnp.float32):
    zeros = lambda p: jnp.zeros(p.shape, state_dtype)
    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
    }


def adamw_update(params, grads, state, *, lr, b1=0.9, b2=0.999, eps=1e-8,
                 weight_decay=0.0, max_grad_norm: float = 0.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = jnp.zeros((), jnp.float32)
    if max_grad_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state["step"] + 1
    sf = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** sf
    bc2 = 1.0 - b2 ** sf

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["mu"])
    flat_v = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"step": step, "mu": new_m, "nu": new_v}, {"grad_norm": gnorm}


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def make_optimizer(name: str = "adamw", *, lr=1e-3, schedule: Optional[Callable] = None,
                   b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                   max_grad_norm: float = 1.0) -> Optimizer:
    sched = schedule or constant_schedule(lr)
    if name not in ("adam", "adamw"):
        raise ValueError(name)
    wd = weight_decay if name == "adamw" else 0.0

    def update(params, grads, state):
        return adamw_update(params, grads, state,
                            lr=sched(state["step"]), b1=b1, b2=b2, eps=eps,
                            weight_decay=wd, max_grad_norm=max_grad_norm)

    return Optimizer(init=adamw_init, update=update)
