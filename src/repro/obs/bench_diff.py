"""Benchmark drift report: fresh BENCH_*.json vs the committed baseline.

The tracked benchmark writers (benchmarks/bench_*.py) merge runs into
``{"benchmark", "unit", "runs": {run_key: entry}}`` keyed by (config,
backend, jax version, device count).  This tool joins a freshly-written
file against the committed baseline ON THOSE SAME KEYS and reports every
numeric leaf whose relative delta exceeds the tolerance:

    python -m repro.obs.bench_diff \\
        --fresh BENCH_gst_memory_ci.json --baseline BENCH_gst_memory.json \\
        --tolerance 0.25

Exit code is 0 even when drift is found (a WARNING step in CI — wall-
clock noise on shared runners must not fail the build); ``--strict``
turns drift into exit 1 for local use and for byte-exact metrics like
the memory benchmark.  Run keys present on only one side are reported
but never fatal: configs legitimately come and go.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Iterator, List, Optional, Tuple

# leaves that identify the run rather than measure it — never diffed
_SKIP_KEYS = {"config", "env"}


def _numeric_leaves(obj, prefix: str = "") -> Iterator[Tuple[str, float]]:
    """Depth-first (path, value) over every numeric leaf; bools excluded
    (they are claims, not measurements — compared separately)."""
    if isinstance(obj, dict):
        for k in sorted(obj):
            if not prefix and k in _SKIP_KEYS:
                continue
            yield from _numeric_leaves(obj[k], f"{prefix}{k}." if prefix
                                       else f"{k}.")
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            yield from _numeric_leaves(v, f"{prefix}{i}.")
    elif isinstance(obj, bool):
        yield prefix.rstrip("."), float(obj)
    elif isinstance(obj, (int, float)) and obj == obj:  # NaN-safe
        yield prefix.rstrip("."), float(obj)


def load_bench(path: str) -> Dict:
    with open(path) as f:
        payload = json.load(f)
    if not isinstance(payload.get("runs"), dict):
        raise ValueError(f"{path}: not a merge-keyed BENCH file "
                         "(no 'runs' dict)")
    return payload


def diff_entries(fresh: Dict, baseline: Dict,
                 tolerance: float) -> List[Dict]:
    """Per-metric deltas between two run entries; returns only the leaves
    whose relative change exceeds ``tolerance`` (appeared/vanished leaves
    always count)."""
    f_leaves = dict(_numeric_leaves(fresh))
    b_leaves = dict(_numeric_leaves(baseline))
    out = []
    for path in sorted(f_leaves.keys() | b_leaves.keys()):
        fv, bv = f_leaves.get(path), b_leaves.get(path)
        if fv is None or bv is None:
            out.append({"metric": path, "fresh": fv, "baseline": bv,
                        "rel_delta": None,
                        "note": "missing in " +
                                ("baseline" if bv is None else "fresh")})
            continue
        denom = max(abs(bv), 1e-12)
        rel = (fv - bv) / denom
        if abs(rel) > tolerance:
            out.append({"metric": path, "fresh": fv, "baseline": bv,
                        "rel_delta": round(rel, 4)})
    return out


def diff_files(fresh_path: str, baseline_path: str, *,
               tolerance: float) -> Dict:
    fresh = load_bench(fresh_path)
    baseline = load_bench(baseline_path)
    report = {"benchmark": fresh.get("benchmark"),
              "tolerance": tolerance, "common": [],
              "only_fresh": [], "only_baseline": []}
    if fresh.get("benchmark") != baseline.get("benchmark"):
        raise ValueError(
            f"benchmark mismatch: fresh={fresh.get('benchmark')!r} "
            f"baseline={baseline.get('benchmark')!r}")
    f_runs, b_runs = fresh["runs"], baseline["runs"]
    report["only_fresh"] = sorted(f_runs.keys() - b_runs.keys())
    report["only_baseline"] = sorted(b_runs.keys() - f_runs.keys())
    for run_key in sorted(f_runs.keys() & b_runs.keys()):
        drifted = diff_entries(f_runs[run_key], b_runs[run_key], tolerance)
        report["common"].append({"run_key": run_key, "drift": drifted})
    return report


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="report drift between a fresh BENCH_*.json and the "
                    "committed baseline")
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="relative delta beyond which a leaf is reported")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any drift (default: report-only)")
    args = ap.parse_args(argv)

    try:
        report = diff_files(args.fresh, args.baseline,
                            tolerance=args.tolerance)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"[bench-diff] ERROR {e}", file=sys.stderr)
        return 1

    n_drift = 0
    for rk in report["only_fresh"]:
        print(f"[bench-diff] NOTE run only in fresh: {rk}")
    for rk in report["only_baseline"]:
        print(f"[bench-diff] NOTE run only in baseline: {rk}")
    for item in report["common"]:
        drift = item["drift"]
        if not drift:
            print(f"[bench-diff] OK {item['run_key'][:80]}: within "
                  f"{args.tolerance:.0%}")
            continue
        n_drift += len(drift)
        print(f"[bench-diff] DRIFT {item['run_key'][:80]}:")
        for d in drift:
            if d.get("rel_delta") is None:
                print(f"[bench-diff]   {d['metric']}: {d['note']} "
                      f"(fresh={d['fresh']}, baseline={d['baseline']})")
            else:
                print(f"[bench-diff]   {d['metric']}: "
                      f"{d['baseline']} -> {d['fresh']} "
                      f"({d['rel_delta']:+.1%})")
    if not report["common"]:
        print("[bench-diff] WARNING no common run keys — nothing compared "
              "(config/backend/jax-version changed?)")
    if n_drift:
        print(f"[bench-diff] {n_drift} drifted metrics "
              f"(tolerance {args.tolerance:.0%})")
        return 1 if args.strict else 0
    return 0


if __name__ == "__main__":
    sys.exit(main())
