"""CI observability gate: assert SLOs against a run's telemetry stream.

Reads the JSONL stream(s) written via ``--metrics-out`` and the Chrome
trace(s) written via ``--trace-out`` and fails (exit 1) when a budget is
blown, so perf/staleness regressions fail CI instead of silently
shifting BENCH_*.json.  Usage (the obs-smoke CI job):

    python -m repro.obs.gate \\
        --train-jsonl obs_train.jsonl --j-max 8 --num-sampled 2 \\
        --steps-per-epoch 16 \\
        --serve-jsonl obs_serve.jsonl --serve-p99-ms 2000 \\
        --max-encode-launches 64 \\
        --trace obs_train_trace.json --trace obs_serve_trace.json

Checks:
  * every JSONL stream parses, ends with a ``summary`` record, and that
    summary carries the required metric families;
  * serve: ``serve.latency_ms`` p99 <= --serve-p99-ms and
    ``serve.encode_launches`` <= --max-encode-launches; nonzero
    ``serve.bucket.truncated_*`` counters fail unless --allow-truncation;
  * train: ``staleness.row_age`` p99 <= the SED-implied bound
    (:func:`repro.obs.staleness.sed_age_bound` over the run geometry);
    --effective-age-below-row-age additionally requires the weighted/
    forecast run's ``staleness.effective_age`` p99 strictly below the
    row-age p99 (of --baseline-jsonl when given, else the same stream);
  * every trace passes :func:`repro.obs.trace.validate_chrome_trace`;
  * memory (``--memory-json BENCH_gst_memory.json``, the bench_memory.py
    sweep): the GST train-step temp (activation) bytes stay flat while
    graph size grows (max/min ratio <= 1 + --mem-epsilon), the full-graph
    control actually grows (>= --mem-growth-floor, proving the sweep has
    teeth), the streaming-encoder temp is chunk-count-independent
    (ratio <= 1 + --stream-epsilon) and >= its jaxpr-walk accounting
    bound, and the serve bucket-ladder total peak fits
    --ladder-budget-bytes when given.  ``--expect-mem`` additionally
    requires the ``mem.`` gauge family in the train stream (the
    --mem-probe wiring canary).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional

from repro.obs.staleness import sed_age_bound
from repro.obs.trace import validate_chrome_trace

# single-device train runs (repro.launch.train) publish the staleness
# families but have no exchange and no write-back gate; the dist extras
# are required when the stream actually came from a dist run (any
# exchange.* metric present) or when --expect-dist pins them explicitly.
TRAIN_FAMILIES = ("staleness.row_age", "staleness.sed_drop_rate")
DIST_FAMILIES = ("store.wb_skip_rate", "exchange.bytes.")
# required when the stream advertises the prefetch lane (any
# exchange.prefetch.* metric present) or --expect-prefetch pins them
PREFETCH_FAMILIES = ("exchange.prefetch.bytes.",
                     "exchange.prefetch.patched_rows")
MEM_FAMILIES = ("mem.device.peak_bytes.", "mem.device.temp_bytes.")
SERVE_FAMILIES = ("serve.latency_ms", "serve.prediction_staleness",
                  "serve.windows")


class GateFailure(Exception):
    pass


def load_jsonl(path: str) -> List[Dict]:
    records = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise GateFailure(f"{path}:{i + 1}: bad JSONL line: {e}")
    if not records:
        raise GateFailure(f"{path}: empty telemetry stream")
    return records


def final_summary(records: List[Dict], path: str) -> Dict:
    summaries = [r for r in records if r.get("type") == "summary"]
    if not summaries:
        raise GateFailure(f"{path}: no summary record (run did not close "
                          "its Obs bundle)")
    return summaries[-1]


def require_families(summary: Dict, families, path: str) -> List[str]:
    metrics = summary.get("metrics", {})
    missing = [fam for fam in families
               if not any(name == fam or
                          (fam.endswith(".") and name.startswith(fam))
                          for name in metrics)]
    if missing:
        raise GateFailure(f"{path}: summary missing metric families: "
                          f"{', '.join(missing)}")
    return sorted(metrics)


def metric_value(summary: Dict, name: str, field: Optional[str],
                 path: str) -> float:
    metrics = summary.get("metrics", {})
    if name not in metrics:
        raise GateFailure(f"{path}: metric {name!r} absent from summary")
    val = metrics[name]
    if isinstance(val, dict):
        if field is None or field not in val:
            raise GateFailure(f"{path}: metric {name!r} has no "
                              f"field {field!r} (has {sorted(val)})")
        val = val[field]
    if val is None:
        raise GateFailure(f"{path}: metric {name!r}.{field} is null "
                          "(no observations)")
    return float(val)


def check_memory_json(path: str, *, mem_epsilon: float,
                      stream_epsilon: float, growth_floor: float,
                      ladder_budget: Optional[float]) -> List[str]:
    """Assert the constant-memory claims against one bench_memory.py file
    (every tracked run config in it must pass)."""
    with open(path) as f:
        payload = json.load(f)
    if payload.get("benchmark") != "gst_memory":
        raise GateFailure(f"{path}: not a gst_memory benchmark file "
                          f"(benchmark={payload.get('benchmark')!r})")
    runs = payload.get("runs") or {}
    if not runs:
        raise GateFailure(f"{path}: no tracked runs")
    lines = []
    for run_key, entry in sorted(runs.items()):
        s = entry.get("summary", {})
        where = f"{path} [{run_key}]"

        def summary_ratio(name: str) -> float:
            v = s.get(name)
            if v is None:
                raise GateFailure(f"{where}: summary missing {name!r}")
            return float(v)

        gst = summary_ratio("gst_temp_ratio_max_over_min")
        if gst > 1.0 + mem_epsilon:
            raise GateFailure(
                f"{where}: GST train-step temp bytes grew {gst:.3f}x across "
                f"the graph-size sweep (budget {1 + mem_epsilon:.3f}x) — "
                "the constant-memory claim regressed (activations now "
                "scale with graph size)")
        full = summary_ratio("full_temp_ratio_max_over_min")
        if full < growth_floor:
            raise GateFailure(
                f"{where}: full-graph control temp grew only {full:.3f}x "
                f"(floor {growth_floor:.3f}x) — the sweep no longer "
                "exercises graph-size scaling, so the flat-GST gate above "
                "is vacuous")
        stream = summary_ratio("streaming_temp_ratio_max_over_min")
        if stream > 1.0 + stream_epsilon:
            raise GateFailure(
                f"{where}: streaming-encoder temp varies {stream:.4f}x "
                f"with the chunk count (budget {1 + stream_epsilon:.4f}x) "
                "— the lax.scan no longer holds one chunk's activations")
        if not s.get("streaming_bound_ok", False):
            raise GateFailure(
                f"{where}: streaming temp fell below the jaxpr-walk "
                "max_intermediate_bytes bound — the compiled stats and "
                "the accounting model disagree")
        if ladder_budget is not None:
            total = float(s.get("ladder_total_peak_bytes") or 0)
            if total > ladder_budget:
                raise GateFailure(
                    f"{where}: serve bucket-ladder total peak "
                    f"{total:.0f}B exceeds the device budget "
                    f"{ladder_budget:.0f}B")
        lines.append(f"memory {run_key[:60]}...: gst x{gst:.3f} flat, "
                     f"full x{full:.2f} grows, stream x{stream:.3f}")
    return lines


def check_trace(path: str) -> int:
    with open(path) as f:
        payload = json.load(f)
    problems = validate_chrome_trace(payload)
    if problems:
        head = "; ".join(problems[:5])
        raise GateFailure(f"{path}: invalid Chrome trace "
                          f"({len(problems)} problems: {head})")
    n = sum(1 for ev in payload.get("traceEvents", [])
            if ev.get("ph") != "M")
    if n == 0:
        raise GateFailure(f"{path}: trace contains no span events")
    return n


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="assert SLO gates against repro.obs telemetry")
    ap.add_argument("--train-jsonl", default=None)
    ap.add_argument("--serve-jsonl", default=None)
    ap.add_argument("--trace", action="append", default=[],
                    help="Chrome trace JSON to validate (repeatable)")
    ap.add_argument("--serve-p99-ms", type=float, default=None,
                    help="serve.latency_ms p99 budget")
    ap.add_argument("--max-encode-launches", type=float, default=None,
                    help="serve.encode_launches budget (compile/launch "
                         "count, the bucketing regression canary)")
    ap.add_argument("--j-max", type=int, default=None)
    ap.add_argument("--num-sampled", type=int, default=None)
    ap.add_argument("--steps-per-epoch", type=int, default=None)
    ap.add_argument("--age-safety", type=float, default=2.0)
    ap.add_argument("--memory-json", action="append", default=[],
                    help="bench_memory.py BENCH_gst_memory.json to gate "
                         "the constant-memory claims against (repeatable)")
    ap.add_argument("--mem-epsilon", type=float, default=0.25,
                    help="allowed fractional growth of GST train-step temp "
                         "bytes across the graph-size sweep")
    ap.add_argument("--stream-epsilon", type=float, default=0.01,
                    help="allowed fractional variation of streaming-"
                         "encoder temp bytes across chunk counts")
    ap.add_argument("--mem-growth-floor", type=float, default=2.0,
                    help="minimum growth of the full-graph control — "
                         "proves the sweep actually scales graph size")
    ap.add_argument("--ladder-budget-bytes", type=float, default=None,
                    help="serve bucket-ladder total compiled peak budget")
    ap.add_argument("--expect-mem", action="store_true",
                    help="require the mem. gauge family in the train "
                         "stream (--mem-probe wiring canary)")
    ap.add_argument("--expect-dist", action="store_true",
                    help="require the dist-run metric families "
                         "(store.wb_skip_rate, exchange.bytes.*) in the "
                         "train stream even if no exchange metric is "
                         "present — CI pins this so a silently-missing "
                         "exchange instrumentation fails the gate")
    ap.add_argument("--expect-prefetch", action="store_true",
                    help="require the prefetch-lane metric families "
                         "(exchange.prefetch.bytes.*, exchange.prefetch."
                         "patched_rows) in the train stream — CI pins "
                         "this on the --prefetch-lookups leg")
    ap.add_argument("--effective-age-below-row-age", action="store_true",
                    help="require staleness.effective_age p99 STRICTLY "
                         "below staleness.row_age p99 — the staleness-"
                         "intelligence acceptance gate: age weighting / "
                         "forecasting must reduce the age the training "
                         "step experiences, not just relabel it")
    ap.add_argument("--baseline-jsonl", default=None,
                    help="unweighted baseline train stream: its "
                         "staleness.row_age p99 becomes the reference the "
                         "--effective-age-below-row-age check compares "
                         "against (default: the --train-jsonl stream's "
                         "own row_age)")
    ap.add_argument("--allow-truncation", action="store_true",
                    help="tolerate nonzero serve.bucket.truncated_* "
                         "counters in the serve stream (catch-all bucket "
                         "overflow drops nodes/edges from predictions; "
                         "fails the gate by default)")
    args = ap.parse_args(argv)

    checks = []
    try:
        if args.train_jsonl:
            records = load_jsonl(args.train_jsonl)
            summary = final_summary(records, args.train_jsonl)
            families = TRAIN_FAMILIES
            is_dist = args.expect_dist or any(
                name.startswith("exchange.")
                for name in summary.get("metrics", {}))
            if is_dist:
                families = families + DIST_FAMILIES
            # a stream that advertises the prefetch lane must carry ALL
            # its families — a half-wired lane (bytes without the
            # patched-rows histogram, or vice versa) fails the gate
            has_prefetch = args.expect_prefetch or any(
                name.startswith("exchange.prefetch.")
                for name in summary.get("metrics", {}))
            if has_prefetch:
                families = families + PREFETCH_FAMILIES
            if args.expect_mem:
                families = families + MEM_FAMILIES
            names = require_families(summary, families, args.train_jsonl)
            checks.append(f"train stream ok: {len(records)} records, "
                          f"{len(names)} metrics")
            if args.j_max and args.num_sampled and args.steps_per_epoch:
                bound = sed_age_bound(j_max=args.j_max,
                                      num_sampled=args.num_sampled,
                                      steps_per_epoch=args.steps_per_epoch,
                                      safety=args.age_safety)
                p99 = metric_value(summary, "staleness.row_age", "p99",
                                   args.train_jsonl)
                if p99 > bound:
                    raise GateFailure(
                        f"staleness.row_age p99 {p99:.1f} steps exceeds the "
                        f"SED-implied bound {bound:.1f} (j_max={args.j_max}, "
                        f"num_sampled={args.num_sampled}) — staleness "
                        "bookkeeping or the refresh pass regressed")
                checks.append(f"row-age p99 {p99:.1f} <= bound {bound:.1f}")
            if args.effective_age_below_row_age:
                eff_p99 = metric_value(summary, "staleness.effective_age",
                                       "p99", args.train_jsonl)
                if args.baseline_jsonl:
                    base = final_summary(load_jsonl(args.baseline_jsonl),
                                         args.baseline_jsonl)
                    row_p99 = metric_value(base, "staleness.row_age", "p99",
                                           args.baseline_jsonl)
                    ref = args.baseline_jsonl
                else:
                    row_p99 = metric_value(summary, "staleness.row_age",
                                           "p99", args.train_jsonl)
                    ref = args.train_jsonl
                if not eff_p99 < row_p99:
                    raise GateFailure(
                        f"staleness.effective_age p99 {eff_p99:.2f} is not "
                        f"strictly below staleness.row_age p99 {row_p99:.2f} "
                        f"(reference {ref}) — age weighting/forecasting is "
                        "not reducing the staleness the step experiences")
                checks.append(f"effective-age p99 {eff_p99:.2f} < "
                              f"row-age p99 {row_p99:.2f}")

        if args.serve_jsonl:
            records = load_jsonl(args.serve_jsonl)
            summary = final_summary(records, args.serve_jsonl)
            names = require_families(summary, SERVE_FAMILIES,
                                     args.serve_jsonl)
            checks.append(f"serve stream ok: {len(records)} records, "
                          f"{len(names)} metrics")
            if args.serve_p99_ms is not None:
                p99 = metric_value(summary, "serve.latency_ms", "p99",
                                   args.serve_jsonl)
                if p99 > args.serve_p99_ms:
                    raise GateFailure(
                        f"serve.latency_ms p99 {p99:.2f}ms exceeds budget "
                        f"{args.serve_p99_ms:.2f}ms")
                checks.append(f"serve p99 {p99:.2f}ms <= "
                              f"{args.serve_p99_ms:.2f}ms")
            if args.max_encode_launches is not None:
                launches = metric_value(summary, "serve.encode_launches",
                                        None, args.serve_jsonl)
                if launches > args.max_encode_launches:
                    raise GateFailure(
                        f"serve.encode_launches {launches:.0f} exceeds "
                        f"budget {args.max_encode_launches:.0f} — bucket "
                        "padding/batching regressed")
                checks.append(f"encode launches {launches:.0f} <= "
                              f"{args.max_encode_launches:.0f}")
            # catch-all bucket overflow: absent counters = nothing was
            # truncated (the engine only publishes them on overflow)
            metrics = summary.get("metrics", {})
            trunc = {name: float(metrics[name] or 0)
                     for name in ("serve.bucket.truncated_nodes",
                                  "serve.bucket.truncated_edges")
                     if name in metrics}
            dropped = sum(trunc.values())
            if dropped and not args.allow_truncation:
                detail = ", ".join(f"{k.rsplit('.', 1)[-1]}={v:.0f}"
                                   for k, v in sorted(trunc.items()))
                raise GateFailure(
                    f"serve catch-all bucket truncated input ({detail}) — "
                    "predictions silently dropped graph structure; size "
                    "the ladder up or pass --allow-truncation")
            checks.append(
                "serve truncation: none" if not dropped else
                f"serve truncation: {dropped:.0f} dropped (allowed)")

        for mem_path in args.memory_json:
            checks.extend(check_memory_json(
                mem_path, mem_epsilon=args.mem_epsilon,
                stream_epsilon=args.stream_epsilon,
                growth_floor=args.mem_growth_floor,
                ladder_budget=args.ladder_budget_bytes))

        for trace_path in args.trace:
            n = check_trace(trace_path)
            checks.append(f"trace {trace_path}: valid, {n} events")
    except GateFailure as e:
        for line in checks:
            print(f"[obs-gate] PASS {line}")
        print(f"[obs-gate] FAIL {e}", file=sys.stderr)
        return 1

    if not checks:
        print("[obs-gate] FAIL nothing to check (pass --train-jsonl / "
              "--serve-jsonl / --trace)", file=sys.stderr)
        return 1
    for line in checks:
        print(f"[obs-gate] PASS {line}")
    print(f"[obs-gate] all {len(checks)} gates passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
