"""Staleness observability — the paper-facing metrics layer.

GST-EFD's whole contribution is *managing* the staleness of historical
segment embeddings (Eq.-1 η weighting + SED exist to bound its bias);
this module makes that quantity measured instead of implied.  Everything
here is host-side arithmetic over the store's merged age/init view
(``store.ages_init``) or over already-known run shape — nothing touches
jitted code.

Published metric families (all through the process-wide registry):

  staleness.row_age           histogram, steps — age of every initialized
                              (row, segment) slot of the table at probe
                              time (``step - age``)
  staleness.effective_age     histogram, steps — the age the training step
                              *experiences* once staleness intelligence is
                              on: age·exp(-λ·age) under --sed-age-weighting
                              (a decayed slot contributes proportionally
                              less signal), 0 for forecast-eligible slots
                              under --stale-forecast.  Published only when
                              either knob is on.
  staleness.init_fraction     gauge — fraction of valid segment slots
                              initialized
  staleness.sed_drop_rate     gauge — the SED effective drop rate: the
                              expected fraction of VALID segments whose
                              Eq.-1 η lands on the dropped branch this
                              epoch (stale share x (1 - keep_prob); the
                              realized Bernoulli mask lives inside jit
                              where we never record, and its expectation
                              is exactly this by construction)
  staleness.sed.eligible      counter, segments — stale segments SED could
  staleness.sed.dropped       have dropped / expectation of how many it
                              did drop
  store.wb_skip_rate          gauge — delta-gate write-back skip rate
                              (skipped rows / evictions)
  exchange.bytes.<strategy>.<dtype>
                              counter, bytes — analytic wire traffic per
                              device, keyed by (strategy, payload dtype)
  serve.prediction_staleness  histogram, steps — age distribution of the
                              table rows each served prediction actually
                              read (serve/engine.py records it; the
                              train-while-serve ROADMAP metric, landed
                              first in the offline engine)
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.obs.metrics import (AGE_BUCKETS_STEPS, MetricsRegistry,
                               get_registry, summarize)


def sed_drop_stats(seg_valid, init_mask, *, num_sampled: int,
                   keep_prob: float) -> Dict[str, float]:
    """SED effective-drop accounting for one batch/epoch of rows.

    seg_valid: (B, J) 0/1 — valid segment slots per row.
    init_mask: (B, J) bool — slots whose historical embedding is
    initialized (uninitialized stale slots get η = 0 regardless of SED,
    so they are not SED-eligible).

    Per row, ``num_sampled`` segments are fresh (encoded this step); the
    remaining valid+initialized ones are served stale and each survives
    with probability ``keep_prob`` (paper Eq. 1).  Returns the eligible
    count, the expected dropped count, and the effective drop rate over
    ALL valid segments — the fraction of the graph's signal SED removes.
    """
    valid = np.asarray(seg_valid) > 0
    init = np.asarray(init_mask) > 0
    n_valid = int(valid.sum())
    per_row_valid = valid.sum(axis=-1)
    per_row_stale = np.maximum((valid & init).sum(axis=-1)
                               - np.minimum(per_row_valid, num_sampled), 0)
    eligible = int(per_row_stale.sum())
    dropped = float(eligible) * (1.0 - keep_prob)
    return {
        "valid_segments": n_valid,
        "sed_eligible": eligible,
        "sed_dropped_expected": dropped,
        "sed_drop_rate": dropped / n_valid if n_valid else 0.0,
    }


def wb_skip_rate(store_stats: Dict) -> float:
    """Delta-gate write-back skip rate from a store stats/counters dict."""
    ev = store_stats.get("evictions", 0)
    return store_stats.get("wb_skipped_rows", 0) / ev if ev else 0.0


def record_exchange_bytes(strategy: str, payload_dtype: str, nbytes: int,
                          registry: Optional[MetricsRegistry] = None) -> None:
    """Wire traffic by (strategy, payload dtype): one counter per pair, so
    a run that re-picks strategies (--exchange=auto per phase) keeps the
    split visible."""
    reg = registry if registry is not None else get_registry()
    reg.inc(f"exchange.bytes.{strategy}.{payload_dtype}", nbytes,
            unit="bytes")


# bucket edges for the patched-rows histogram: patches are tiny by design
# (0 on disjoint schedules, <= B_local*S when adjacent batches fully
# overlap), so the resolution lives at the small end
PATCHED_ROWS_BUCKETS = (0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0,
                        256.0, 1024.0)


def record_prefetch_exchange(strategy: str, payload_dtype: str, nbytes: int,
                             patched_rows: int,
                             registry: Optional[MetricsRegistry] = None,
                             ) -> None:
    """One prefetched train step's exchange telemetry: the analytic wire
    bytes of the prefetch path (``exchange.prefetch.bytes.<strategy>.
    <dtype>`` — same total as inline plus the bucketed patch surcharge)
    and how many write-back rows the fused patch actually repaired in the
    next batch's buffer (host-side count of planned consumers — no device
    readback)."""
    reg = registry if registry is not None else get_registry()
    reg.inc(f"exchange.prefetch.bytes.{strategy}.{payload_dtype}", nbytes,
            unit="bytes")
    reg.histogram("exchange.prefetch.patched_rows",
                  buckets=PATCHED_ROWS_BUCKETS,
                  unit="rows").observe(float(patched_rows))


class StalenessProbe:
    """Periodic staleness snapshot over a store-backed training table.

    ``observe(store, table, step)`` reads the merged age/init view
    (host-side; one device_get of the age/init planes — call it per
    epoch / per export tick, not per step) and publishes the row-age
    histogram, init fraction, SED drop expectation and delta-gate skip
    rate.  Returns the summary dict it published, for prints/benches.

    The histogram observes every (row, segment) slot age, so its counts
    are bit-consistent with ``store.snapshot()`` ages by construction
    (asserted in tests/test_obs.py — ``ages_init`` and ``snapshot`` agree
    once write-backs are flushed).
    """

    def __init__(self, *, keep_prob: float = 0.5, num_sampled: int = 1,
                 seg_valid=None, registry: Optional[MetricsRegistry] = None,
                 sed_decay: float = 0.0, forecast: bool = False,
                 forecast_min_age: int = 1):
        self.keep_prob = keep_prob
        self.num_sampled = num_sampled
        # (n_rows, J) validity of the dataset's segment slots; None = every
        # slot counts (geometry without padding info)
        self.seg_valid = None if seg_valid is None else np.asarray(seg_valid)
        self._registry = registry
        # staleness-intelligence knobs: with age-weighted SED the model only
        # *feels* age through exp(-λ·age), and with forecasting a stale row
        # is extrapolated to the present before it is consumed — the
        # effective-age histogram records what the training step actually
        # experiences, next to the raw row_age it is derived from
        self.sed_decay = float(sed_decay)
        self.forecast = bool(forecast)
        self.forecast_min_age = int(forecast_min_age)

    @property
    def registry(self) -> MetricsRegistry:
        return self._registry if self._registry is not None else get_registry()

    def observe(self, store, table, step: int) -> Dict:
        age, init = store.ages_init(table)
        return self.observe_ages(age, init, step)

    def observe_ages(self, age, init, step: int) -> Dict:
        """The pure-array half of ``observe`` (tests feed snapshot ages
        directly to prove bit-consistency)."""
        reg = self.registry
        age = np.asarray(age)
        init = np.asarray(init) > 0
        valid = (np.ones_like(init) if self.seg_valid is None
                 else (self.seg_valid > 0))
        live = init & valid
        ages_steps = (int(step) - age[live]).astype(np.float64)
        hist = reg.histogram("staleness.row_age", buckets=AGE_BUCKETS_STEPS,
                             unit="steps")
        hist.observe_many(ages_steps)
        eff = None
        if self.sed_decay > 0.0 or self.forecast:
            # the age the step EXPERIENCES: η-decay scales a stale slot's
            # contribution by exp(-λ·age), so its effective age (the age
            # weighted by how much of it survives into the loss) is
            # age·exp(-λ·age); a forecast-eligible slot is extrapolated to
            # the present, so its effective age is 0.  Published only when
            # a knob is on — default telemetry streams stay identical.
            eff = ages_steps * np.exp(-self.sed_decay * ages_steps)
            if self.forecast:
                eff = np.where(ages_steps >= self.forecast_min_age, 0.0, eff)
            reg.histogram("staleness.effective_age",
                          buckets=AGE_BUCKETS_STEPS,
                          unit="steps").observe_many(eff)
        n_valid = int(valid.sum())
        init_frac = float(live.sum()) / n_valid if n_valid else 0.0
        reg.set("staleness.init_fraction", init_frac)
        sed = sed_drop_stats(valid, init, num_sampled=self.num_sampled,
                             keep_prob=self.keep_prob)
        reg.inc("staleness.sed.eligible", sed["sed_eligible"], unit="segments")
        reg.inc("staleness.sed.dropped", sed["sed_dropped_expected"],
                unit="segments")
        reg.set("staleness.sed_drop_rate", sed["sed_drop_rate"])
        out = {
            "step": int(step),
            "row_age_steps": summarize(ages_steps),
            "init_fraction": init_frac,
            **sed,
        }
        if eff is not None:
            out["effective_age_steps"] = summarize(eff)
        return out

    def observe_store_counters(self, store_stats: Dict) -> None:
        """Publish the delta-gate skip rate gauge from a store stats dict
        (the counters themselves stream through store/base.py)."""
        self.registry.set("store.wb_skip_rate", wb_skip_rate(store_stats))


def sed_age_bound(*, j_max: int, num_sampled: int,
                  steps_per_epoch: int, safety: float = 2.0) -> float:
    """The SED-implied row-age bound the CI obs gate asserts p99 against.

    Under Algorithm 1 every graph is visited once per epoch and
    ``num_sampled`` of its ``j_max`` segment slots are re-encoded (age
    reset), so a slot's refresh interval is geometric with mean
    ``j_max / num_sampled`` epochs; the Algorithm-2 refresh pass
    (gst_ef/gst_efd) additionally rewrites EVERY slot before finetuning.
    p99 of a geometric(p = num_sampled/j_max) is ~ln(100)/p visits; in
    steps that is ``ln(100) * j_max / num_sampled * steps_per_epoch``.
    ``safety`` doubles it so the gate flags broken staleness bookkeeping
    (ages never advancing, refresh not landing), not sampling noise.
    """
    p = min(max(num_sampled, 1) / max(j_max, 1), 1.0)
    return float(np.log(100.0) / p * steps_per_epoch * safety)
