"""Telemetry export: periodic JSONL event log, final summaries, CLI glue.

The three CLIs (launch/train.py, launch/train_dist.py,
launch/serve_graphs.py) share one flag set (``add_obs_args``) and one
lifecycle object (:class:`Obs`):

    add_obs_args(ap)
    args = ap.parse_args()
    obs = Obs.from_args(args)          # installs registry + tracer globals
    ...
    obs.tick(step=..., epoch=...)      # JSONL line: per-interval deltas
    ...
    summary = obs.close(run_meta)      # summary JSONL line + trace export

JSONL stream format (one JSON object per line):

    {"type": "meta", "wall_time": ..., "argv": ..., **run_meta}
    {"type": "tick", "step": N, "wall_s": ..., "delta": {name: change},
     "gauges": {...}, **extra}         # delta() since the previous tick
    {"type": "event", "event": "...", **payload}
    {"type": "summary", "wall_s": ..., "metrics": {name: value|summary},
     **extra}                          # cumulative, report-grade

The final summary dict is also RETURNED so the tracked-benchmark writers
(benchmarks/bench_*.py) merge it into their BENCH_*.json entries, and the
CI obs gate (``python -m repro.obs.gate``) asserts SLOs against the same
stream.
"""
from __future__ import annotations

import json
import sys
import time
from typing import Dict, Optional

from repro.obs.memory import MemoryProbe, null_probe, set_probe
from repro.obs.metrics import (MetricsRegistry, NullRegistry, get_registry,
                               null_registry, set_registry)
from repro.obs.trace import NullTracer, Tracer, null_tracer, set_tracer


def add_obs_args(ap) -> None:
    """The shared observability flag set (no-cost defaults: everything
    off)."""
    g = ap.add_argument_group("observability (repro.obs)")
    g.add_argument("--metrics", action="store_true",
                   help="enable the process-wide metrics registry "
                        "(store/exchange/feeder/serve counters, staleness "
                        "histograms); off = null registry, zero overhead")
    g.add_argument("--metrics-out", default=None, metavar="PATH",
                   help="write the JSONL telemetry stream (per-interval "
                        "ticks + final summary) here; implies --metrics")
    g.add_argument("--metrics-interval", type=int, default=1,
                   help="emit a JSONL tick every N intervals (epochs for "
                        "the trainers, windows for the serve replay)")
    g.add_argument("--trace-out", default=None, metavar="PATH",
                   help="record spans (train step, feeder, write-back "
                        "lane, serve request path) and write a Chrome-"
                        "trace JSON here (chrome://tracing / Perfetto)")
    g.add_argument("--jax-trace-annotations", action="store_true",
                   help="also enter jax.profiler.TraceAnnotation for each "
                        "span so span names line up inside a captured "
                        "device profile")
    g.add_argument("--mem-probe", action="store_true",
                   help="capture compiled.memory_analysis() / "
                        "cost_analysis() at every probed jit entry point "
                        "(train/refresh/finetune steps, serve bucket "
                        "encodes, store migrations), keyed by (site, "
                        "shape signature), publishing mem.device.* / "
                        "mem.host.* gauges; costs one extra AOT compile "
                        "per compiled shape while on.  Implies --metrics")


class JsonlExporter:
    """Append-only JSONL event stream over one registry."""

    def __init__(self, path: str, registry: MetricsRegistry):
        self.path = path
        self.registry = registry
        self._f = open(path, "w")
        self._t0 = time.perf_counter()
        self._n_ticks = 0

    def _emit(self, obj: Dict) -> None:
        self._f.write(json.dumps(obj) + "\n")
        self._f.flush()

    def meta(self, **run_meta) -> None:
        self._emit({"type": "meta", "wall_time": time.time(),
                    "argv": sys.argv, **run_meta})

    def tick(self, step: Optional[int] = None, **extra) -> Dict:
        """One per-interval line: the registry's delta() since the last
        tick (per-interval rates, the counter-reset fix) plus any extras
        (epoch number, loss, staleness summary...)."""
        self._n_ticks += 1
        rec = {"type": "tick",
               "wall_s": round(time.perf_counter() - self._t0, 6)}
        if step is not None:
            rec["step"] = int(step)
        rec["delta"] = _jsonable(self.registry.delta())
        rec.update(_jsonable(extra))
        self._emit(rec)
        return rec

    def event(self, event: str, **payload) -> None:
        self._emit({"type": "event", "event": event,
                    "wall_s": round(time.perf_counter() - self._t0, 6),
                    **_jsonable(payload)})

    def summary(self, **extra) -> Dict:
        rec = {"type": "summary",
               "wall_s": round(time.perf_counter() - self._t0, 6),
               "n_ticks": self._n_ticks,
               "metrics": _jsonable(self.registry.summary())}
        rec.update(_jsonable(extra))
        self._emit(rec)
        return rec

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


def _jsonable(obj):
    """Round-trip-safe coercion (numpy scalars/arrays -> python)."""
    import numpy as np
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.generic):
        return obj.item()
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, float) and (obj != obj or obj in (float("inf"),
                                                         float("-inf"))):
        return None
    return obj


class Obs:
    """One run's telemetry bundle: registry + tracer + JSONL exporter,
    installed process-wide on construction so every subsystem (store,
    exchange, feeders, serve engine) publishes without plumbing."""

    def __init__(self, *, metrics: bool = False,
                 metrics_out: Optional[str] = None,
                 trace_out: Optional[str] = None,
                 metrics_interval: int = 1,
                 jax_annotations: bool = False,
                 mem_probe: bool = False,
                 install: bool = True):
        # --mem-probe implies a live registry: the probe's gauges need
        # somewhere to land even without --metrics
        self.enabled = bool(metrics or metrics_out or mem_probe)
        self.trace_out = trace_out
        self.interval = max(int(metrics_interval), 1)
        self.registry = MetricsRegistry() if self.enabled else null_registry()
        self.tracer = (Tracer(jax_annotations=jax_annotations)
                       if trace_out else null_tracer())
        self.probe = MemoryProbe() if mem_probe else null_probe()
        self.exporter = (JsonlExporter(metrics_out, self.registry)
                         if metrics_out else None)
        self._prev_registry = None
        self._prev_tracer = None
        self._prev_probe = None
        self._installed = False
        self._closed = False
        if install:
            self.install()

    @classmethod
    def from_args(cls, args, **run_meta) -> "Obs":
        obs = cls(metrics=getattr(args, "metrics", False),
                  metrics_out=getattr(args, "metrics_out", None),
                  trace_out=getattr(args, "trace_out", None),
                  metrics_interval=getattr(args, "metrics_interval", 1),
                  jax_annotations=getattr(args, "jax_trace_annotations",
                                          False),
                  mem_probe=getattr(args, "mem_probe", False))
        if obs.exporter is not None:
            obs.exporter.meta(**run_meta)
        return obs

    # -- lifecycle ---------------------------------------------------------

    def install(self) -> "Obs":
        if not self._installed:
            self._prev_registry = set_registry(self.registry)
            self._prev_tracer = set_tracer(self.tracer)
            self._prev_probe = set_probe(self.probe)
            self._installed = True
        return self

    def uninstall(self) -> None:
        if self._installed:
            set_registry(self._prev_registry or null_registry())
            set_tracer(self._prev_tracer or null_tracer())
            set_probe(self._prev_probe or null_probe())
            self._installed = False

    def __enter__(self) -> "Obs":
        return self.install()

    def __exit__(self, *exc):
        self.close()
        return False

    # -- recording ---------------------------------------------------------

    def should_tick(self, interval_index: int) -> bool:
        return self.exporter is not None and \
            interval_index % self.interval == 0

    def tick(self, step: Optional[int] = None, **extra) -> Optional[Dict]:
        if self.exporter is None:
            return None
        return self.exporter.tick(step=step, **extra)

    def event(self, event: str, **payload) -> None:
        if self.exporter is not None:
            self.exporter.event(event, **payload)

    def summary(self, **extra) -> Dict:
        """Cumulative report-grade dict (registry summary + extras) —
        what the BENCH_*.json writers merge; does NOT close anything."""
        return {"metrics": _jsonable(self.registry.summary()),
                **_jsonable(extra)}

    def close(self, **summary_extra) -> Optional[Dict]:
        """Final summary JSONL line, trace export, uninstall.  Returns the
        summary record (None when telemetry was fully disabled)."""
        if self._closed:
            return None
        self._closed = True
        rec = None
        if self.exporter is not None:
            if self.probe.enabled:
                # per-(site, signature) compiled memory records, ahead of
                # the summary so gate/bench readers still see the summary
                # as the final record
                self.exporter.event("memory", **self.probe.snapshot())
            rec = self.exporter.summary(**summary_extra)
            self.exporter.close()
        elif self.enabled:
            rec = {"type": "summary",
                   "metrics": _jsonable(self.registry.summary()),
                   **_jsonable(summary_extra)}
        if self.trace_out and len(self.tracer):
            self.tracer.export(self.trace_out)
        self.uninstall()
        return rec
