"""Process-wide metrics registry — counters, gauges, fixed-bucket histograms.

One telemetry spine for every subsystem: the store, the exchange, the
feeders, and the serve engine all publish through ONE registry under
hierarchical dotted names (``store.faults``, ``exchange.bytes.ring.f32``,
``serve.latency_ms``), so a run's residency traffic, wire bytes and
latency distributions come out of a single ``snapshot()`` instead of
N ad-hoc counter dicts.

Design rules:

* **Host-side only.**  Nothing here is ever called inside a traced/jitted
  function — instrumented code records around jit boundaries, so the
  jaxpr of an instrumented step is bit-identical to the uninstrumented
  one (asserted in tests/test_obs.py).
* **The disabled path is a no-op.**  The module-global registry defaults
  to :class:`NullRegistry`, whose record methods are empty and whose
  metric handles are shared no-op singletons — code can call
  ``get_registry().inc("store.faults")`` unconditionally.
* **Thread-safe.**  The store's begin() runs on the feeder thread,
  write-backs land on the AsyncHostWriter thread, and the consumer reads
  snapshots — every mutation takes the registry's lock (one lock: these
  are per-batch events, not per-element ones).
* **Cumulative counters + ``delta()``.**  Counters never self-reset;
  per-interval rates (a per-epoch fault count, a per-window hit-rate)
  come from ``delta()``, which diffs against the previous ``delta()``
  call — fixing the old per-epoch prints that reported cumulative
  counts as rates.

``summarize()`` is the one percentile/latency-summary implementation
(replacing the hand-rolled copies in serve/bench/launch): it accepts a
:class:`Histogram` (p50/p99 interpolated from the buckets — O(buckets)
memory no matter how long the replay) or a plain value sequence.
"""
from __future__ import annotations

import threading
from bisect import bisect_left
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np


# ---------------------------------------------------------------------------
# bucket ladders
# ---------------------------------------------------------------------------


def exponential_buckets(start: float, factor: float, count: int) -> Tuple[float, ...]:
    """``count`` upper bounds start, start*factor, ... (an implicit +inf
    overflow bucket always follows)."""
    if start <= 0 or factor <= 1 or count < 1:
        raise ValueError("need start > 0, factor > 1, count >= 1")
    return tuple(start * factor ** i for i in range(count))


# 0.1 ms .. ~52 s in x2 steps — covers a CPU-interpret serve request and a
# TPU train step with the same ladder
LATENCY_BUCKETS_MS = exponential_buckets(0.1, 2.0, 20)
# 1 .. ~5e5 steps in x2 steps — row ages / prediction staleness in steps
AGE_BUCKETS_STEPS = exponential_buckets(1.0, 2.0, 20)
BYTES_BUCKETS = exponential_buckets(64.0, 4.0, 16)


# ---------------------------------------------------------------------------
# metric kinds
# ---------------------------------------------------------------------------


class Counter:
    """Monotonic cumulative count (events, rows, bytes, milliseconds)."""

    __slots__ = ("name", "unit", "_lock", "_value")
    kind = "counter"

    def __init__(self, name: str, unit: str = "", lock: Optional[threading.Lock] = None):
        self.name = name
        self.unit = unit
        self._lock = lock or threading.Lock()
        self._value = 0.0

    def inc(self, v: float = 1.0) -> None:
        with self._lock:
            self._value += v

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def snapshot(self) -> Dict:
        return {"type": self.kind, "unit": self.unit, "value": self.value}


class Gauge:
    """Last-write-wins instantaneous value (occupancy, queue depth)."""

    __slots__ = ("name", "unit", "_lock", "_value")
    kind = "gauge"

    def __init__(self, name: str, unit: str = "", lock: Optional[threading.Lock] = None):
        self.name = name
        self.unit = unit
        self._lock = lock or threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def snapshot(self) -> Dict:
        return {"type": self.kind, "unit": self.unit, "value": self.value}


class Histogram:
    """Fixed-bucket distribution: O(len(buckets)) memory however many
    observations land — the replacement for unbounded per-event lists.

    ``buckets`` are ascending upper bounds; an overflow bucket is
    implicit.  Percentiles interpolate linearly inside a bucket (the
    first bucket's lower edge is the observed min, the overflow bucket's
    upper edge the observed max), so ``percentile`` is exact at the
    bucket resolution.
    """

    __slots__ = ("name", "unit", "buckets", "_lock", "counts", "_count",
                 "_sum", "_min", "_max")
    kind = "histogram"

    def __init__(self, name: str, buckets: Sequence[float] = LATENCY_BUCKETS_MS,
                 unit: str = "", lock: Optional[threading.Lock] = None):
        bs = tuple(float(b) for b in buckets)
        if list(bs) != sorted(set(bs)):
            raise ValueError(f"buckets must be strictly ascending: {bs}")
        self.name = name
        self.unit = unit
        self.buckets = bs
        self._lock = lock or threading.Lock()
        self.counts = [0] * (len(bs) + 1)
        self._count = 0
        self._sum = 0.0
        self._min = float("inf")
        self._max = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        i = bisect_left(self.buckets, v)
        with self._lock:
            self.counts[i] += 1
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    def observe_many(self, values) -> None:
        """Vectorized observe for array-sized recordings (row-age sweeps)."""
        arr = np.asarray(values, np.float64).ravel()
        if arr.size == 0:
            return
        idx = np.searchsorted(self.buckets, arr, side="left")
        binned = np.bincount(idx, minlength=len(self.counts))
        with self._lock:
            for i, c in enumerate(binned):
                self.counts[i] += int(c)
            self._count += arr.size
            self._sum += float(arr.sum())
            self._min = min(self._min, float(arr.min()))
            self._max = max(self._max, float(arr.max()))

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def mean(self) -> float:
        with self._lock:
            return self._sum / self._count if self._count else 0.0

    def percentile(self, q: float) -> float:
        """q in [0, 100]; linear interpolation within the target bucket."""
        with self._lock:
            counts = list(self.counts)
            total, lo, hi = self._count, self._min, self._max
        if total == 0:
            return 0.0
        target = (q / 100.0) * total
        seen = 0.0
        for i, c in enumerate(counts):
            if c == 0:
                continue
            if seen + c >= target:
                lower = self.buckets[i - 1] if i > 0 else lo
                upper = self.buckets[i] if i < len(self.buckets) else hi
                lower = max(lower, lo)
                upper = min(upper, hi) if hi >= lower else lower
                frac = (target - seen) / c
                return float(lower + (upper - lower) * min(max(frac, 0.0), 1.0))
            seen += c
        return float(hi)

    def snapshot(self) -> Dict:
        with self._lock:
            return {
                "type": self.kind, "unit": self.unit,
                "buckets": list(self.buckets), "counts": list(self.counts),
                "count": self._count, "sum": self._sum,
                "min": self._min if self._count else 0.0,
                "max": self._max if self._count else 0.0,
            }


Metric = Union[Counter, Gauge, Histogram]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


class MetricsRegistry:
    """Get-or-create metric handles by dotted name + snapshot/delta/reset."""

    enabled = True

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, Metric] = {}
        self._delta_mark: Dict[str, float] = {}

    # -- handles -----------------------------------------------------------

    def _get_or_create(self, name: str, cls, **kw) -> Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, **kw)
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str, unit: str = "") -> Counter:
        return self._get_or_create(name, Counter, unit=unit)

    def gauge(self, name: str, unit: str = "") -> Gauge:
        return self._get_or_create(name, Gauge, unit=unit)

    def histogram(self, name: str, buckets: Sequence[float] = LATENCY_BUCKETS_MS,
                  unit: str = "") -> Histogram:
        return self._get_or_create(name, Histogram, buckets=buckets, unit=unit)

    # -- convenience recorders (the null registry overrides these) ---------

    def inc(self, name: str, v: float = 1.0, unit: str = "") -> None:
        self.counter(name, unit=unit).inc(v)

    def set(self, name: str, v: float, unit: str = "") -> None:
        self.gauge(name, unit=unit).set(v)

    def observe(self, name: str, v: float,
                buckets: Sequence[float] = LATENCY_BUCKETS_MS,
                unit: str = "") -> None:
        self.histogram(name, buckets=buckets, unit=unit).observe(v)

    # -- views -------------------------------------------------------------

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def get(self, name: str) -> Optional[Metric]:
        with self._lock:
            return self._metrics.get(name)

    def snapshot(self) -> Dict[str, Dict]:
        with self._lock:
            metrics = dict(self._metrics)
        return {name: m.snapshot() for name, m in sorted(metrics.items())}

    def delta(self) -> Dict[str, float]:
        """Per-interval change since the PREVIOUS delta() call: counters
        diff their cumulative value, histograms diff their observation
        count (``<name>.count``) and sum (``<name>.sum``), gauges report
        their current value.  This is the primitive every per-epoch /
        per-window rate print goes through — cumulative counters stop
        masquerading as rates."""
        out: Dict[str, float] = {}
        with self._lock:
            metrics = dict(self._metrics)
        for name, m in sorted(metrics.items()):
            if isinstance(m, Counter):
                cur = m.value
                out[name] = cur - self._delta_mark.get(name, 0.0)
                self._delta_mark[name] = cur
            elif isinstance(m, Gauge):
                out[name] = m.value
            else:
                snap = m.snapshot()
                for part in ("count", "sum"):
                    key = f"{name}.{part}"
                    cur = float(snap[part])
                    out[key] = cur - self._delta_mark.get(key, 0.0)
                    self._delta_mark[key] = cur
        return out

    def reset(self) -> None:
        """Drop every metric AND the delta marks (a fresh run phase)."""
        with self._lock:
            self._metrics.clear()
            self._delta_mark.clear()

    def summary(self) -> Dict[str, object]:
        """Flat report-grade dict: counters/gauges -> value, histograms ->
        summarize() dict.  This is what the BENCH_*.json writers merge."""
        out: Dict[str, object] = {}
        with self._lock:
            metrics = dict(self._metrics)
        for name, m in sorted(metrics.items()):
            out[name] = summarize(m) if isinstance(m, Histogram) else m.value
        return out


class _NullMetric:
    """Shared do-nothing handle: inc/set/observe all no-ops, reads zero."""

    __slots__ = ()
    name = ""
    unit = ""
    value = 0.0
    count = 0
    mean = 0.0

    def inc(self, v: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def observe_many(self, values) -> None:
        pass

    def percentile(self, q: float) -> float:
        return 0.0

    def snapshot(self) -> Dict:
        return {}


_NULL_METRIC = _NullMetric()


class NullRegistry(MetricsRegistry):
    """The disabled path: every handle is the shared no-op singleton and
    every recorder is an empty method — instrumented code pays one Python
    call, no allocation, no locking."""

    enabled = False

    def __init__(self):
        pass  # no lock, no dicts — nothing to mutate

    def counter(self, name: str, unit: str = ""):
        return _NULL_METRIC

    def gauge(self, name: str, unit: str = ""):
        return _NULL_METRIC

    def histogram(self, name: str, buckets=LATENCY_BUCKETS_MS, unit: str = ""):
        return _NULL_METRIC

    def inc(self, name: str, v: float = 1.0, unit: str = "") -> None:
        pass

    def set(self, name: str, v: float, unit: str = "") -> None:
        pass

    def observe(self, name: str, v: float, buckets=LATENCY_BUCKETS_MS,
                unit: str = "") -> None:
        pass

    def names(self) -> List[str]:
        return []

    def get(self, name: str):
        return None

    def snapshot(self) -> Dict[str, Dict]:
        return {}

    def delta(self) -> Dict[str, float]:
        return {}

    def reset(self) -> None:
        pass

    def summary(self) -> Dict[str, object]:
        return {}


_NULL_REGISTRY = NullRegistry()
_registry: MetricsRegistry = _NULL_REGISTRY


def get_registry() -> MetricsRegistry:
    """The process-wide registry every subsystem publishes to (a
    NullRegistry until someone enables metrics)."""
    return _registry


def set_registry(registry: MetricsRegistry) -> MetricsRegistry:
    """Install ``registry`` as the process-wide registry; returns the
    previous one so callers (tests, benches) can restore it."""
    global _registry
    prev = _registry
    _registry = registry
    return prev


def null_registry() -> NullRegistry:
    return _NULL_REGISTRY


def enable_metrics() -> MetricsRegistry:
    """Install and return a fresh live registry (the --metrics path)."""
    reg = MetricsRegistry()
    set_registry(reg)
    return reg


# ---------------------------------------------------------------------------
# the one latency/percentile summary implementation
# ---------------------------------------------------------------------------


def summarize(data: Union[Histogram, Iterable[float]],
              percentiles: Sequence[float] = (50, 99)) -> Dict[str, float]:
    """count/mean/min/max + requested percentiles, from a Histogram
    (bucket-interpolated — constant memory) or a raw value sequence
    (exact).  Keys: ``count, mean, min, max, p50, p99, ...``."""
    if isinstance(data, (Histogram, _NullMetric)):
        if isinstance(data, _NullMetric) or data.count == 0:
            base = {"count": 0, "mean": 0.0, "min": 0.0, "max": 0.0}
            base.update({f"p{_fmt_q(q)}": 0.0 for q in percentiles})
            return base
        snap = data.snapshot()
        out = {"count": snap["count"], "mean": snap["sum"] / snap["count"],
               "min": snap["min"], "max": snap["max"]}
        for q in percentiles:
            out[f"p{_fmt_q(q)}"] = data.percentile(q)
        return out
    arr = np.asarray(list(data), np.float64)
    if arr.size == 0:
        base = {"count": 0, "mean": 0.0, "min": 0.0, "max": 0.0}
        base.update({f"p{_fmt_q(q)}": 0.0 for q in percentiles})
        return base
    out = {"count": int(arr.size), "mean": float(arr.mean()),
           "min": float(arr.min()), "max": float(arr.max())}
    for q in percentiles:
        out[f"p{_fmt_q(q)}"] = float(np.percentile(arr, q))
    return out


def _fmt_q(q: float) -> str:
    return str(int(q)) if float(q).is_integer() else str(q).replace(".", "_")


def dict_delta(cur: Dict, prev: Optional[Dict]) -> Dict:
    """Numeric diff of two flat stat dicts (non-numeric keys pass through
    from ``cur``) — the per-interval view of a cumulative counter dict,
    for code still reading the legacy dict accessors."""
    if prev is None:
        return dict(cur)
    out = {}
    for k, v in cur.items():
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            out[k] = v
        else:
            p = prev.get(k, 0)
            out[k] = v - p if isinstance(p, (int, float)) else v
    return out
