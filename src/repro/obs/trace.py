"""Span-based tracing -> Chrome-trace JSON (chrome://tracing / Perfetto).

One tracer covers every thread of a run: the consumer's train step and
store commits, the feeder thread's batch assembly + device_put, the
AsyncHostWriter's eviction write-backs, and the serve request path
(window -> bucket encode -> cache insert -> gather -> head).  Spans are
recorded as *complete* ("X") events — one event per finished span with
``ts``/``dur`` in microseconds on a single monotonic clock — which both
viewers load directly and which keeps the in-memory form one dict per
span.

Like the metrics registry, tracing is host-side only (spans wrap jit
*dispatch*, never run inside traced code) and the disabled path is free:
the module-global tracer defaults to :class:`NullTracer`, whose
``span()`` returns one shared reusable no-op context manager.

``jax_annotations=True`` additionally enters
``jax.profiler.TraceAnnotation(name)`` for every span, so the same span
names line up inside a captured device profile when one is taken.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Dict, List, Optional


class _NullSpan:
    """Reusable no-op context manager (the disabled-tracing path)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tracer", "name", "args", "_t0", "_jax_ctx")

    def __init__(self, tracer: "Tracer", name: str, args: Optional[Dict]):
        self._tracer = tracer
        self.name = name
        self.args = args
        self._t0 = 0
        self._jax_ctx = None

    def __enter__(self):
        if self._tracer._jax_annotations:
            ctx = _jax_annotation(self.name)
            if ctx is not None:
                self._jax_ctx = ctx
                ctx.__enter__()
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        if self._jax_ctx is not None:
            self._jax_ctx.__exit__(*exc)
        self._tracer._record(self.name, self._t0, t1, self.args)
        return False


def _jax_annotation(name: str):
    """jax.profiler.TraceAnnotation passthrough, or None when jax (or the
    profiler) is unavailable — tracing must not import-require jax."""
    try:
        from jax.profiler import TraceAnnotation
        return TraceAnnotation(name)
    except Exception:
        return None


class Tracer:
    """Collects spans from any thread; ``export()`` writes Chrome JSON."""

    enabled = True

    def __init__(self, *, jax_annotations: bool = False):
        self._lock = threading.Lock()
        self._events: List[Dict] = []
        self._thread_names: Dict[int, str] = {}
        self._jax_annotations = jax_annotations
        self._epoch_ns = time.perf_counter_ns()
        self._pid = os.getpid()

    # -- recording ---------------------------------------------------------

    def span(self, name: str, **args) -> _Span:
        """``with tracer.span("train.step", epoch=3): ...`` — records one
        complete event when the block exits (exception included, so a
        failing step still shows its span)."""
        return _Span(self, name, args or None)

    def instant(self, name: str, **args) -> None:
        """Zero-duration marker event (epoch boundaries, flushes)."""
        ts = (time.perf_counter_ns() - self._epoch_ns) // 1000
        self._append({"name": name, "ph": "i", "s": "t", "ts": ts,
                      **self._ids(), **({"args": args} if args else {})})

    def counter(self, name: str, **values) -> None:
        """Chrome "C" counter event: each kwarg is one numeric series under
        ``name``, rendered by the viewers as a timeline counter track —
        live bytes (the obs.memory probe), queue depths, occupancy.  Only
        numeric values are recorded; at least one is required."""
        series = {k: float(v) for k, v in values.items()
                  if isinstance(v, (int, float)) and not isinstance(v, bool)}
        if not series:
            raise ValueError(f"counter {name!r} needs at least one numeric "
                             f"series (got {sorted(values)})")
        ts = (time.perf_counter_ns() - self._epoch_ns) // 1000
        self._append({"name": name, "ph": "C", "ts": ts, **self._ids(),
                      "args": series})

    def _record(self, name: str, t0_ns: int, t1_ns: int,
                args: Optional[Dict]) -> None:
        ev = {"name": name, "ph": "X",
              "ts": (t0_ns - self._epoch_ns) // 1000,
              "dur": max((t1_ns - t0_ns) // 1000, 1),
              **self._ids()}
        if args:
            ev["args"] = args
        self._append(ev)

    def _ids(self) -> Dict:
        t = threading.current_thread()
        tid = t.ident or 0
        if tid not in self._thread_names:
            with self._lock:
                self._thread_names.setdefault(tid, t.name)
        return {"pid": self._pid, "tid": tid}

    def _append(self, ev: Dict) -> None:
        with self._lock:
            self._events.append(ev)

    # -- views / export ----------------------------------------------------

    def events(self) -> List[Dict]:
        with self._lock:
            return list(self._events)

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    # export order at equal ts: spans before counters before instants, and
    # longer spans (parents) before shorter ones — spans are appended at
    # EXIT while counters are appended live, so raw append order from
    # multiple threads interleaves them nondeterministically
    _PH_ORDER = {"X": 0, "C": 1, "i": 2, "I": 2}

    def export(self, path: str) -> str:
        """Write ``{"traceEvents": [...]}`` Chrome/Perfetto JSON: the
        recorded spans plus one thread-name metadata event per thread
        seen, sorted on a total deterministic key (ts, phase, -dur, tid)
        so the stream is ts-monotonic — and stable across reruns — even
        when counter and span events interleave from multiple threads."""
        with self._lock:
            events = sorted(
                self._events,
                key=lambda e: (e["ts"], self._PH_ORDER.get(e["ph"], 3),
                               -e.get("dur", 0), e.get("tid", 0)))
            names = dict(self._thread_names)
        meta = [{"name": "thread_name", "ph": "M", "pid": self._pid,
                 "tid": tid, "args": {"name": tname}}
                for tid, tname in sorted(names.items())]
        payload = {"traceEvents": meta + events, "displayTimeUnit": "ms"}
        with open(path, "w") as f:
            json.dump(payload, f)
            f.write("\n")
        return path


class NullTracer:
    """The disabled path: span() hands back one shared no-op context."""

    enabled = False

    def span(self, name: str, **args) -> _NullSpan:
        return _NULL_SPAN

    def instant(self, name: str, **args) -> None:
        pass

    def counter(self, name: str, **values) -> None:
        pass

    def events(self) -> List[Dict]:
        return []

    def __len__(self) -> int:
        return 0

    def export(self, path: str) -> str:
        raise RuntimeError("NullTracer has nothing to export — enable "
                           "tracing (--trace-out) first")


_NULL_TRACER = NullTracer()
_tracer = _NULL_TRACER


def get_tracer():
    return _tracer


def set_tracer(tracer) -> object:
    """Install ``tracer`` process-wide; returns the previous tracer."""
    global _tracer
    prev = _tracer
    _tracer = tracer
    return prev


def null_tracer() -> NullTracer:
    return _NULL_TRACER


def span(name: str, **args):
    """``with span("serve.encode", bucket=2): ...`` against the current
    process-wide tracer — the one-liner instrumented code uses."""
    return _tracer.span(name, **args)


def instant(name: str, **args) -> None:
    _tracer.instant(name, **args)


def counter(name: str, **values) -> None:
    """``counter("mem.device_bytes", train_step=4.2e5)`` against the
    current process-wide tracer (no-op on the NullTracer)."""
    _tracer.counter(name, **values)


def validate_chrome_trace(payload: Dict) -> List[str]:
    """Structural checks a Chrome-trace consumer relies on; returns a list
    of problems (empty = valid).  Used by tests and the CI obs gate."""
    problems: List[str] = []
    events = payload.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    begins: Dict = {}
    last_ts = None
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph == "M":
            continue
        if not isinstance(ev.get("ts"), int) or ev["ts"] < 0:
            problems.append(f"event {i}: bad ts {ev.get('ts')!r}")
            continue
        if last_ts is not None and ev["ts"] < last_ts:
            problems.append(f"event {i}: ts not monotonic ({ev['ts']} < {last_ts})")
        last_ts = ev["ts"]
        if ph == "X":
            if not isinstance(ev.get("dur"), int) or ev["dur"] < 0:
                problems.append(f"event {i}: X event with bad dur")
        elif ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args:
                problems.append(f"event {i}: C event without args series")
            elif not all(isinstance(v, (int, float))
                         and not isinstance(v, bool)
                         for v in args.values()):
                problems.append(f"event {i}: C event with non-numeric "
                                "series values")
        elif ph == "B":
            begins.setdefault((ev.get("pid"), ev.get("tid")), []).append(ev)
        elif ph == "E":
            stack = begins.get((ev.get("pid"), ev.get("tid")), [])
            if not stack:
                problems.append(f"event {i}: E without matching B")
            else:
                stack.pop()
        elif ph not in ("i", "I"):
            problems.append(f"event {i}: unsupported phase {ph!r}")
        if ph != "M" and ("pid" not in ev or "tid" not in ev):
            problems.append(f"event {i}: missing pid/tid")
    for key, stack in begins.items():
        if stack:
            problems.append(f"{len(stack)} unmatched B events on {key}")
    return problems
