"""Memory observability: measure the constant-memory claim, don't argue it.

GST's headline promise is that segment training predicts large-graph
properties with a constant device-memory footprint.  Until now the repo
only argued this analytically (``kernels/ops.py::max_intermediate_bytes``
buffer accounting); this module measures it from the compiled artifacts
and feeds the PR 7 telemetry spine so CI can gate on it.

:class:`MemoryProbe` captures ``compiled.memory_analysis()`` /
``cost_analysis()`` from every jit entry point it is hooked into —
train/refresh/finetune steps (core + dist), every serve bucket compile,
the streaming encoder, the tiered-store migrate jits — keyed by
``(site, shape signature)``, so one record exists per compiled shape.
Capture is AOT-on-the-side: the probe runs ``jitted.lower(*args)
.compile()`` purely to read the stats, then the ORIGINAL jitted callable
executes the step — the traced jaxpr is bit-identical with the probe
installed or not (tests/test_obs_memory.py), and the extra compile
happens once per (site, signature) only while probing.

Per capture the probe publishes into the metrics registry:

    mem.device.peak_bytes.<site>   argument + output + temp − alias
    mem.device.temp_bytes.<site>   XLA temp (intermediate) buffers
    mem.host.rss_bytes             process RSS at capture time

and emits a Chrome-trace "C" counter event (``obs/trace.py``) so live
bytes render as a timeline counter track.  Host-side byte tracking
(tiered-store host tier, feeder staging buffers) goes through
:meth:`MemoryProbe.observe_host` → ``mem.host.<site>_bytes`` gauges.

On backends / jax versions where ``memory_analysis`` is unavailable the
shared extraction helper (``roofline/analysis.py``) returns ``None`` and
the probe degrades to accounting-only: the record carries the jaxpr-walk
``max_intermediate_bytes`` lower bound instead of compiled stats.

Like the registry and tracer, the probe is a process-wide global
defaulting to :class:`NullProbe`; instrumented call sites use
:func:`probe_jit`, whose disabled path is one global read + branch per
call (batch-grained, never inside traced code).
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.obs.metrics import get_registry
from repro.obs.trace import get_tracer


# ---------------------------------------------------------------------------
# shape signatures + host-side byte helpers (jax-free until actually used)
# ---------------------------------------------------------------------------


def shape_signature(tree) -> str:
    """Canonical dtype[shape] signature of a pytree of arrays — the probe's
    dedup key: two calls with the same signature hit the same compiled
    executable, so they share one capture."""
    import jax

    parts = []
    for leaf in jax.tree_util.tree_leaves(tree):
        dtype = getattr(leaf, "dtype", None)
        shape = getattr(leaf, "shape", None)
        if dtype is None or shape is None:
            parts.append(type(leaf).__name__)
        else:
            parts.append(f"{dtype}[{','.join(str(s) for s in shape)}]")
    return ";".join(parts)


def tree_nbytes(tree) -> int:
    """Total bytes of every array leaf (host staging buffers, numpy tiers)."""
    import jax

    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        nb = getattr(leaf, "nbytes", None)
        if nb is not None:
            total += int(nb)
    return total


def process_rss_bytes() -> int:
    """Resident-set size of this process, in bytes (0 when unreadable)."""
    try:
        with open("/proc/self/statm") as f:
            pages = int(f.read().split()[1])
        import os
        return pages * os.sysconf("SC_PAGE_SIZE")
    except Exception:
        try:
            import resource
            # ru_maxrss is KiB on Linux (bytes on macOS — close enough for
            # a monitoring gauge; the gates never read RSS)
            return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024
        except Exception:
            return 0


# ---------------------------------------------------------------------------
# the probe
# ---------------------------------------------------------------------------


class MemoryProbe:
    """Captures compiled memory/cost stats per (site, shape signature)."""

    enabled = True

    def __init__(self, *, accounting_fallback: bool = True):
        self._lock = threading.Lock()
        self._records: Dict[Tuple[str, str], Dict[str, Any]] = {}
        self._host_bytes: Dict[str, int] = {}
        self.accounting_fallback = accounting_fallback

    # -- device-side capture ----------------------------------------------

    def observe_call(self, site: str, jitted: Callable, args, kwargs) -> None:
        """Record one call of a probed jit entry point: on the first call
        per (site, signature) run the AOT lower→compile on the side and
        extract stats; afterwards just count calls."""
        sig = shape_signature((args, kwargs))
        key = (site, sig)
        with self._lock:
            rec = self._records.get(key)
            if rec is not None:
                rec["calls"] += 1
                return
            # reserve before the (slow, lock-free) measurement so a racing
            # second caller with the same signature doesn't compile twice
            rec = {"site": site, "signature": sig, "calls": 1,
                   "memory": None, "cost": None, "mode": "pending"}
            self._records[key] = rec
        measured = self._measure(jitted, args, kwargs)
        with self._lock:
            rec.update(measured)
        self._publish(site, rec)

    def _measure(self, jitted, args, kwargs) -> Dict[str, Any]:
        from repro.roofline.analysis import (compiled_cost_stats,
                                             compiled_memory_stats,
                                             device_peak_bytes)
        try:
            compiled = jitted.lower(*args, **kwargs).compile()
        except Exception as e:
            return {"mode": "error", "error": str(e)}
        mem = compiled_memory_stats(compiled)
        cost = compiled_cost_stats(compiled)
        out: Dict[str, Any] = {"cost": cost}
        if mem is not None:
            out.update(mode="compiled", memory=mem,
                       peak_bytes=device_peak_bytes(mem),
                       temp_bytes=mem.get("temp_size_in_bytes", 0))
            return out
        # accounting-only degrade: the jaxpr-walk largest-intermediate
        # bound stands in for the unavailable compiled temp stats
        out["mode"] = "accounting"
        if self.accounting_fallback:
            try:
                from repro.kernels.ops import max_intermediate_bytes
                bound = int(max_intermediate_bytes(jitted, *args, **kwargs))
                out.update(temp_bytes=bound, peak_bytes=bound,
                           accounting_bound_bytes=bound)
            except Exception as e:
                out.update(mode="error", error=str(e))
        return out

    def _publish(self, site: str, rec: Dict[str, Any]) -> None:
        peak = rec.get("peak_bytes")
        if peak is None:
            return
        temp = rec.get("temp_bytes", 0)
        reg = get_registry()
        reg.set(f"mem.device.peak_bytes.{site}", float(peak), unit="bytes")
        reg.set(f"mem.device.temp_bytes.{site}", float(temp), unit="bytes")
        rss = process_rss_bytes()
        if rss:
            reg.set("mem.host.rss_bytes", float(rss), unit="bytes")
        get_tracer().counter("mem.device.temp_bytes", **{site: temp})

    # -- host-side gauges --------------------------------------------------

    def observe_host(self, site: str, nbytes: int) -> None:
        """Host-memory gauge for ``site`` (tiered-store host tier, feeder
        staging buffers): ``mem.host.<site>_bytes`` + a trace counter."""
        nbytes = int(nbytes)
        with self._lock:
            self._host_bytes[site] = nbytes
        reg = get_registry()
        reg.set(f"mem.host.{site}_bytes", float(nbytes), unit="bytes")
        rss = process_rss_bytes()
        if rss:
            reg.set("mem.host.rss_bytes", float(rss), unit="bytes")
        get_tracer().counter("mem.host_bytes", **{site: nbytes})

    # -- views -------------------------------------------------------------

    def records(self) -> List[Dict[str, Any]]:
        with self._lock:
            return [dict(r) for r in self._records.values()]

    def host_bytes(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._host_bytes)

    def sites(self) -> List[str]:
        with self._lock:
            return sorted({site for site, _ in self._records})

    def site_records(self, prefix: str) -> List[Dict[str, Any]]:
        with self._lock:
            return [dict(r) for (site, _), r in self._records.items()
                    if site.startswith(prefix)]

    def ladder_total_bytes(self, prefix: str = "serve.encode.") -> int:
        """Sum of per-bucket (peak) bytes across every compiled bucket of
        the serve ladder — the number the bucket-ladder device-budget gate
        compares against; 0 until a bucket compiles."""
        with self._lock:
            return sum(int(r.get("peak_bytes", 0))
                       for (site, _), r in self._records.items()
                       if site.startswith(prefix))

    def snapshot(self) -> Dict[str, Any]:
        """Report-grade dict: per-(site, signature) records, host gauges,
        the serve-ladder total, and current RSS — what Obs.close() writes
        into the JSONL stream as a ``memory`` event."""
        return {
            "records": self.records(),
            "host_bytes": self.host_bytes(),
            "serve_ladder_peak_bytes": self.ladder_total_bytes(),
            "rss_bytes": process_rss_bytes(),
        }


class NullProbe:
    """The disabled path: observe calls are empty, views are empty."""

    enabled = False

    def observe_call(self, site, jitted, args, kwargs) -> None:
        pass

    def observe_host(self, site: str, nbytes: int) -> None:
        pass

    def records(self) -> List[Dict[str, Any]]:
        return []

    def host_bytes(self) -> Dict[str, int]:
        return {}

    def sites(self) -> List[str]:
        return []

    def site_records(self, prefix: str) -> List[Dict[str, Any]]:
        return []

    def ladder_total_bytes(self, prefix: str = "serve.encode.") -> int:
        return 0

    def snapshot(self) -> Dict[str, Any]:
        return {"records": [], "host_bytes": {},
                "serve_ladder_peak_bytes": 0, "rss_bytes": 0}


_NULL_PROBE = NullProbe()
_probe = _NULL_PROBE


def get_probe():
    """The process-wide memory probe (a NullProbe until --mem-probe)."""
    return _probe


def set_probe(probe) -> object:
    """Install ``probe`` process-wide; returns the previous probe."""
    global _probe
    prev = _probe
    _probe = probe
    return prev


def null_probe() -> NullProbe:
    return _NULL_PROBE


class _ProbedJit:
    """Call-through wrapper around one jitted entry point: late-binds the
    process-wide probe at call time (so hooks built before the probe is
    installed still report) and NEVER wraps the traced computation — it
    measures on the side, then delegates to the original callable."""

    __slots__ = ("site", "_jitted")

    def __init__(self, site: str, jitted: Callable):
        self.site = site
        self._jitted = jitted

    def __call__(self, *args, **kwargs):
        p = _probe
        if p.enabled:
            p.observe_call(self.site, self._jitted, args, kwargs)
        return self._jitted(*args, **kwargs)

    def __getattr__(self, name):  # .lower / .trace passthrough
        return getattr(self._jitted, name)


def probe_jit(site: str, jitted: Callable) -> Callable:
    """Hook one jitted callable into the memory probe under ``site``.

    The returned wrapper is signature-transparent and adds one global
    read + branch per call when probing is disabled.  Sites: train.step,
    train.refresh, dist.train_step, serve.encode.<bucket>, serve.stream,
    store.migrate, ...
    """
    return _ProbedJit(site, jitted)
