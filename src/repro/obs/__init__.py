"""repro.obs — the telemetry spine: metrics registry, span tracing,
staleness observability, JSONL/trace export.

Import surface kept flat so instrumented code needs only::

    from repro.obs import get_registry, span

and CLIs only::

    from repro.obs import Obs, add_obs_args
"""
from repro.obs.metrics import (AGE_BUCKETS_STEPS, BYTES_BUCKETS, Counter,
                               Gauge, Histogram, LATENCY_BUCKETS_MS,
                               MetricsRegistry, NullRegistry, dict_delta,
                               enable_metrics, exponential_buckets,
                               get_registry, null_registry, set_registry,
                               summarize)
from repro.obs.trace import (NullTracer, Tracer, counter, get_tracer,
                             instant, null_tracer, set_tracer, span,
                             validate_chrome_trace)
from repro.obs.memory import (MemoryProbe, NullProbe, get_probe, null_probe,
                              probe_jit, process_rss_bytes, set_probe,
                              shape_signature, tree_nbytes)
from repro.obs.staleness import (StalenessProbe, record_exchange_bytes,
                                 record_prefetch_exchange, sed_age_bound,
                                 sed_drop_stats, wb_skip_rate)
from repro.obs.export import JsonlExporter, Obs, add_obs_args

__all__ = [
    "AGE_BUCKETS_STEPS", "BYTES_BUCKETS", "LATENCY_BUCKETS_MS",
    "Counter", "Gauge", "Histogram",
    "MetricsRegistry", "NullRegistry",
    "dict_delta", "enable_metrics", "exponential_buckets",
    "get_registry", "null_registry", "set_registry", "summarize",
    "NullTracer", "Tracer", "counter", "get_tracer", "instant",
    "null_tracer", "set_tracer", "span", "validate_chrome_trace",
    "MemoryProbe", "NullProbe", "get_probe", "null_probe", "probe_jit",
    "process_rss_bytes", "set_probe", "shape_signature", "tree_nbytes",
    "StalenessProbe", "record_exchange_bytes", "record_prefetch_exchange",
    "sed_age_bound", "sed_drop_stats", "wb_skip_rate",
    "JsonlExporter", "Obs", "add_obs_args",
]
