from repro.configs.base import (
    ARCH_IDS,
    INPUT_SHAPES,
    ArchConfig,
    InputShape,
    MoEConfig,
    SSMConfig,
    all_configs,
    get_config,
    reduced,
)

__all__ = [
    "ARCH_IDS",
    "INPUT_SHAPES",
    "ArchConfig",
    "InputShape",
    "MoEConfig",
    "SSMConfig",
    "all_configs",
    "get_config",
    "reduced",
]
