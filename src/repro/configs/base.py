"""Architecture & run configuration for the repro framework.

Every assigned architecture gets one ``<id>.py`` in this package exporting
``CONFIG: ArchConfig`` built from the exact public spec (source cited in the
file).  ``reduced()`` derives the CPU-smoke-test variant (2 layers,
d_model<=512, <=4 experts) from the same family so the smoke test exercises
the identical code path as the full config.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0          # routed experts
    top_k: int = 0
    expert_d_ff: int = 0          # per-expert FFN hidden
    num_shared_experts: int = 0   # DeepSeek-style always-on shared experts
    dense_d_ff: int = 0           # Arctic-style dense residual FFN alongside MoE
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-2


@dataclass(frozen=True)
class SSMConfig:
    state_size: int = 0           # per-head SSM state (Mamba2) / rwkv head size
    num_ssm_heads: int = 0
    conv_width: int = 4           # Mamba2 local conv
    chunk_size: int = 256         # chunked-scan block length
    expand: int = 2               # Mamba2 inner expansion


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    # attention
    attn_kinds: Tuple[str, ...] = ("full",)   # per-layer pattern, cycled
    rope_theta: float = 10_000.0
    use_mla: bool = False
    mla_kv_lora_rank: int = 512
    mla_q_lora_rank: int = 1536
    mla_rope_head_dim: int = 64
    mla_nope_head_dim: int = 128
    mla_v_head_dim: int = 128
    # norms / misc
    norm: str = "rmsnorm"         # rmsnorm | layernorm | nonparam_ln (olmo)
    act: str = "silu"             # silu (swiglu) | gelu (plain mlp)
    tie_embeddings: bool = False
    # family extras
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    # hybrid (zamba2): pattern of block kinds cycled over layers
    block_pattern: Tuple[str, ...] = ()        # e.g. ("mamba",)*5 + ("shared_attn",)
    # enc-dec (whisper)
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 1_500               # whisper frame count after conv stub
    # vlm (qwen2-vl)
    mrope_sections: Tuple[int, ...] = ()       # M-RoPE (t, h, w) split of head_dim/2
    vision_prefix_len: int = 0                 # stub patch-embedding prefix tokens
    # sliding window (used for long_500k dense variant & any swa layers)
    sliding_window: int = 8_192
    # GST (paper technique) integration for train shape
    gst_num_segments: int = 8                  # J
    gst_backprop_segments: int = 1             # S
    gst_keep_prob: float = 0.5                 # p  (SED, Eq. 1)
    gst_num_classes: int = 16                  # property-head output dim
    gst_table_size: int = 4_096                # n_graphs rows in historical table
    # citation
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm" and self.num_heads == 0

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind, length == num_layers."""
        if self.block_pattern:
            pat = self.block_pattern
            return tuple(pat[i % len(pat)] for i in range(self.num_layers))
        if self.family == "ssm":
            return ("rwkv",) * self.num_layers
        if self.family == "moe":
            return ("moe",) * self.num_layers
        return ("attn",) * self.num_layers

    def supports_shape(self, shape: InputShape) -> bool:
        if shape.name == "long_500k":
            # enc-dec decoder context is bounded by design -> documented skip
            return not self.is_encoder_decoder
        return True


def reduced(cfg: ArchConfig) -> ArchConfig:
    """CPU smoke-test variant of the same family (2L, d_model<=512, <=4 experts)."""
    d_model = min(cfg.d_model, 256)
    heads = min(cfg.num_heads, 4) if cfg.num_heads else 0
    kv = min(cfg.num_kv_heads, heads) if heads else 0
    kv = max(kv, 1) if heads else 0
    moe = cfg.moe
    if moe.num_experts:
        moe = replace(
            moe,
            num_experts=min(moe.num_experts, 4),
            top_k=min(moe.top_k, 2),
            expert_d_ff=min(moe.expert_d_ff, 512),
            dense_d_ff=min(moe.dense_d_ff, 512) if moe.dense_d_ff else 0,
            num_shared_experts=min(moe.num_shared_experts, 1),
        )
    ssm = cfg.ssm
    if ssm.state_size or cfg.family in ("ssm", "hybrid"):
        ssm = replace(
            ssm,
            state_size=min(ssm.state_size or 16, 16),
            num_ssm_heads=min(ssm.num_ssm_heads or 4, 4),
            chunk_size=64,
        )
    pattern = cfg.block_pattern
    if pattern:
        # keep one of each kind so the smoke test covers every block type
        kinds = []
        for k in pattern:
            if k not in kinds:
                kinds.append(k)
        pattern = tuple(kinds[:2]) if len(kinds) >= 2 else tuple(kinds)
    return replace(
        cfg,
        num_layers=2,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 1024),
        head_dim=64 if heads else 0,
        moe=moe,
        ssm=ssm,
        block_pattern=pattern,
        num_encoder_layers=2 if cfg.is_encoder_decoder else 0,
        encoder_seq_len=64 if cfg.is_encoder_decoder else cfg.encoder_seq_len,
        mla_kv_lora_rank=min(cfg.mla_kv_lora_rank, 64),
        mla_q_lora_rank=min(cfg.mla_q_lora_rank, 64),
        mla_rope_head_dim=32 if cfg.use_mla else cfg.mla_rope_head_dim,
        mla_nope_head_dim=32 if cfg.use_mla else cfg.mla_nope_head_dim,
        mla_v_head_dim=32 if cfg.use_mla else cfg.mla_v_head_dim,
        mrope_sections=(16, 8, 8) if cfg.mrope_sections else (),
        vision_prefix_len=min(cfg.vision_prefix_len, 16),
        sliding_window=128,
        gst_table_size=64,
        gst_num_segments=4,
        gst_num_classes=5,
        source=cfg.source,
    )


ARCH_IDS = (
    "arctic-480b",
    "internlm2-1.8b",
    "internlm2-20b",
    "zamba2-1.2b",
    "olmo-1b",
    "rwkv6-7b",
    "deepseek-v3-671b",
    "deepseek-coder-33b",
    "whisper-large-v3",
    "qwen2-vl-7b",
)

_MOD_NAMES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MOD_NAMES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MOD_NAMES[arch_id]}")
    return mod.CONFIG


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCH_IDS}
