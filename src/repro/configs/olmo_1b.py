"""OLMo 1B dense config (non-parametric LayerNorm). [arXiv:2402.00838]

Assigned spec: 16L d_model=2048 16H (GQA kv=16, i.e. MHA) d_ff=8192 vocab=50304.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    head_dim=128,
    norm="nonparam_ln",      # OLMo: LayerNorm without learnable affine
    act="silu",
    tie_embeddings=True,
    source="arXiv:2402.00838",
)
