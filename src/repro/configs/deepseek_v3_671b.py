"""DeepSeek-V3 671B MoE config (MLA + shared/routed experts). [arXiv:2412.19437]

Assigned spec: 61L d_model=7168 128H d_ff=2048(moe expert) vocab=129280,
MoE 256e top-8, 1 shared expert, MLA attention, MTP (multi-token prediction
head implemented as an extra scan depth-1 module).
First 3 layers are dense (d_ff=18432 in the release; we keep the assigned
expert d_ff for routed layers and the release's dense d_ff for dense layers).
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,         # MLA: per-head latent KV (kv=128 in assignment)
    d_ff=18432,               # dense layers' FFN hidden (first 3 layers)
    vocab_size=129280,
    head_dim=128,
    use_mla=True,
    mla_kv_lora_rank=512,
    mla_q_lora_rank=1536,
    mla_rope_head_dim=64,
    mla_nope_head_dim=128,
    mla_v_head_dim=128,
    norm="rmsnorm",
    act="silu",
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        expert_d_ff=2048,
        num_shared_experts=1,
        capacity_factor=1.25,
    ),
    block_pattern=("dense", "dense", "dense") + ("moe",) * 58,
    rope_theta=10_000.0,
    source="arXiv:2412.19437",
)
