"""Snowflake Arctic (480B MoE) backbone config.

[hf:Snowflake/snowflake-arctic-base] — dense-MoE hybrid: every layer has a
dense residual FFN in parallel with a 128-expert top-2 MoE FFN.
Assigned spec: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128e top-2 + dense residual.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,               # dense residual FFN hidden
    vocab_size=32000,
    head_dim=128,
    norm="rmsnorm",
    act="silu",
    moe=MoEConfig(
        num_experts=128,
        top_k=2,
        expert_d_ff=4864,
        dense_d_ff=4864,      # dense residual path alongside MoE
        capacity_factor=1.25,
    ),
    block_pattern=("moe",),
    rope_theta=1_000_000.0,
    source="hf:Snowflake/snowflake-arctic-base",
)
