"""Zamba2 1.2B hybrid (Mamba2 + shared attention blocks). [arXiv:2411.15242]

Assigned spec: 38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000,
ssm_state=64.  Zamba2 interleaves Mamba2 blocks with a *shared* full-attention
block applied periodically (we cycle 5 mamba : 1 shared-attn).
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    head_dim=64,
    norm="rmsnorm",
    act="silu",
    ssm=SSMConfig(state_size=64, num_ssm_heads=32, conv_width=4, chunk_size=256, expand=2),
    block_pattern=("mamba", "mamba", "mamba", "mamba", "mamba", "shared_attn"),
    source="arXiv:2411.15242",
)
