"""Qwen2-VL 7B VLM backbone config (M-RoPE). [arXiv:2409.12191]

Assigned spec: 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064 —
M-RoPE (temporal/height/width rotary sections), dynamic resolution.  The
ViT vision encoder + projector are a STUB per the assignment:
``input_specs()`` supplies precomputed patch embeddings prefixed to text.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    head_dim=128,
    norm="rmsnorm",
    act="silu",
    mrope_sections=(16, 24, 24),   # t/h/w split of head_dim//2 = 64
    vision_prefix_len=256,          # stub patch-embedding prefix tokens
    rope_theta=1_000_000.0,
    source="arXiv:2409.12191",
)
