"""RWKV-6 "Finch" 7B attention-free config. [arXiv:2404.05892]

Assigned spec: 32L d_model=4096 (attn-free) d_ff=14336 vocab=65536 —
data-dependent decay time-mix + channel-mix blocks, head size 64.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=0,              # attention-free
    num_kv_heads=0,
    d_ff=14336,
    vocab_size=65536,
    norm="layernorm",
    act="relu_sq",            # rwkv channel-mix uses squared relu
    ssm=SSMConfig(state_size=64, num_ssm_heads=64, chunk_size=256),
    block_pattern=("rwkv",),
    source="arXiv:2404.05892",
)
