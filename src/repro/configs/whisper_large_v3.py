"""Whisper large-v3 encoder-decoder backbone config. [arXiv:2212.04356]

Assigned spec: 32L d_model=1280 20H (kv=20, MHA) d_ff=5120 vocab=51866 —
enc-dec; the mel-spectrogram + conv frontend is a STUB per the assignment:
``input_specs()`` supplies precomputed frame embeddings (1500, d_model).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,            # decoder layers
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    head_dim=64,
    norm="layernorm",
    act="gelu",
    is_encoder_decoder=True,
    num_encoder_layers=32,
    encoder_seq_len=1500,
    rope_theta=0.0,           # whisper uses learned/sinusoidal positions
    source="arXiv:2212.04356",
)
