"""Synthetic graph-property datasets mirroring the paper's benchmarks.

The container is offline, so MalNet / TpuGraphs are *modeled*, preserving the
properties GST exercises (this is what the paper's claims hinge on):

* MalNet-like (classification): each graph is a union of communities, each
  community has a latent type visible in its nodes' (noisy) features, and the
  **label depends on the multiset of community types across the whole graph**
  (majority type, ties to the smaller id).  A single segment sees ~one
  community, so it carries insufficient information — exactly the "graph
  diameter" argument of the paper's introduction — and GST-One must
  underperform while aggregated GST matches full-graph training.

* TpuGraphs-like (ranking/regression): the target "runtime" is a sum of
  per-community costs (cost = nonlinear function of the community's type and
  size, modulated by a per-graph "configuration" feature that is broadcast to
  node features, as TpuGraphs featurizes layout configs into node features).
  Sum-decomposability matches the paper's §5.3 observation that predicting
  per-segment runtimes and sum-pooling works best; OPA is the metric.

Graphs are plain numpy (host-side preprocessing, like the paper's METIS
pass); the padded-CSR batching in batching.py produces the static-shape
device arrays.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np


@dataclass
class SyntheticGraph:
    x: np.ndarray          # (n_nodes, n_feat) float32
    edges: np.ndarray      # (n_edges, 2) int32, undirected (both dirs present)
    label: float           # class id (int) or runtime (float)
    community: np.ndarray  # (n_nodes,) int32 — ground-truth community id
    meta: dict = field(default_factory=dict)


def _community_graph(rng: np.random.Generator, n_comm: int, comm_size_rng,
                     n_types: int, n_feat: int, p_in: float, p_out_edges: int):
    """Build a noisy-feature community graph; returns (x, edges, types, comm)."""
    sizes = [int(rng.integers(*comm_size_rng)) for _ in range(n_comm)]
    types = rng.integers(0, n_types, size=n_comm)
    n = sum(sizes)
    x = np.zeros((n, n_feat), np.float32)
    comm = np.zeros((n,), np.int32)
    edges = []
    offset = 0
    for c, (sz, t) in enumerate(zip(sizes, types)):
        idx = np.arange(offset, offset + sz)
        comm[idx] = c
        # noisy one-hot of the community type in the first n_types dims
        feats = rng.normal(0, 0.4, size=(sz, n_feat)).astype(np.float32)
        feats[:, t % n_feat] += 1.0
        x[idx] = feats
        # intra-community edges: random tree + extra random edges (connected,
        # locality-preserving — what METIS-style partitioners can exploit)
        for i in range(1, sz):
            j = int(rng.integers(0, i))
            edges.append((idx[i], idx[j]))
        extra = int(p_in * sz)
        for _ in range(extra):
            a, b = rng.integers(0, sz, 2)
            if a != b:
                edges.append((idx[a], idx[b]))
        offset += sz
    # sparse inter-community edges
    for _ in range(p_out_edges):
        a = int(rng.integers(0, n))
        b = int(rng.integers(0, n))
        if comm[a] != comm[b]:
            edges.append((a, b))
    e = np.asarray(edges, np.int32)
    e = np.concatenate([e, e[:, ::-1]], axis=0)  # symmetrize
    return x, e, types, comm


def make_malnet_like(
    n_graphs: int = 120,
    n_classes: int = 5,
    n_feat: int = 8,
    comm_range: Tuple[int, int] = (4, 9),
    comm_size_range: Tuple[int, int] = (24, 56),
    seed: int = 0,
) -> List[SyntheticGraph]:
    """Label = majority community type (ties -> smaller id) — global info."""
    rng = np.random.default_rng(seed)
    graphs = []
    for _ in range(n_graphs):
        n_comm = int(rng.integers(*comm_range))
        x, e, types, comm = _community_graph(
            rng, n_comm, comm_size_range, n_classes, n_feat, p_in=2.0,
            p_out_edges=max(2, n_comm // 2))
        label = int(np.argmax(np.bincount(types, minlength=n_classes)))
        graphs.append(SyntheticGraph(x, e, label, comm,
                                     meta={"types": types}))
    return graphs


def make_tpugraphs_like(
    n_graphs: int = 96,
    n_feat: int = 8,
    n_types: int = 5,
    comm_range: Tuple[int, int] = (4, 9),
    comm_size_range: Tuple[int, int] = (24, 56),
    n_configs: int = 4,
    seed: int = 1,
) -> List[SyntheticGraph]:
    """Runtime = Σ_c cost(type_c, size_c) · (1 + 0.3·config·type_c/n_types).

    Each (graph, config) pair is one example (the paper: "a graph together
    with a configuration defines one G^(i)"); the config scalar is broadcast
    into the last node-feature column.
    """
    rng = np.random.default_rng(seed)
    base_cost = rng.uniform(0.5, 2.0, size=n_types)
    graphs = []
    for _ in range(n_graphs // n_configs):
        n_comm = int(rng.integers(*comm_range))
        x, e, types, comm = _community_graph(
            rng, n_comm, comm_size_range, n_types, n_feat, p_in=2.0,
            p_out_edges=max(2, n_comm // 2))
        sizes = np.bincount(comm, minlength=len(types)).astype(np.float32)
        for k in range(n_configs):
            cfgval = k / max(n_configs - 1, 1)
            runtime = float(np.sum(
                base_cost[types] * np.sqrt(sizes) * (1 + 0.3 * cfgval * types / n_types)))
            xc = x.copy()
            xc[:, -1] = cfgval
            graphs.append(SyntheticGraph(
                xc, e, runtime + float(rng.normal(0, 0.01)), comm,
                meta={"config": cfgval, "types": types}))
    return graphs
