"""GNN backbones for the paper-faithful track: GCN, SAGE, GraphGPS-lite.

GraphGym-style design space (paper Table 5): pre-process MLP layers, message
passing layers, post-process MLP layers, PReLU, mean aggregation.  The
backbone F maps one padded segment -> one embedding (mean-pooled over valid
nodes); batching over segments is a vmap.

GraphGPS-lite follows the GPS recipe (local MPNN + global attention per
layer) [25]; the Performer approximation is unnecessary at segment size
(<= m_GST nodes), so global attention is exact over the segment — same
asymptotics as the paper's setup because segments are size-bounded.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


@dataclass(frozen=True)
class GNNConfig:
    backbone: str = "sage"       # gcn | sage | gps
    n_feat: int = 8
    hidden: int = 64
    n_pre: int = 1
    n_mp: int = 2
    n_post: int = 1
    num_heads: int = 4           # gps global attention heads
    use_pallas: bool = False     # route neighbor aggregation through the
                                 # batched segment_spmm Pallas kernel: ONE
                                 # kernel launch per message-passing layer
                                 # over all B·S segments (TPU target;
                                 # interpret mode on CPU).  gcn + sage only;
                                 # gps falls back to the jnp path (its
                                 # per-edge vector messages don't fit the
                                 # scalar-edge-weight SpMM form).


def _prelu_init(dtype=jnp.float32):
    return {"a": jnp.full((1,), 0.25, dtype)}


def _prelu(p, x):
    return jnp.where(x >= 0, x, p["a"] * x)


def _mp_params(key, cfg: GNNConfig, dtype=jnp.float32):
    d = cfg.hidden
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if cfg.backbone == "gcn":
        return {"w": dense_init(k1, d, d, dtype), "prelu": _prelu_init(dtype)}
    if cfg.backbone == "sage":
        return {"w_self": dense_init(k1, d, d, dtype),
                "w_nbr": dense_init(k2, d, d, dtype),
                "prelu": _prelu_init(dtype)}
    if cfg.backbone == "gps":
        kq, kk, kv, ko = jax.random.split(k3, 4)
        return {
            "w_msg": dense_init(k1, d, d, dtype),
            "w_gate_src": dense_init(k2, d, d, dtype),
            "w_gate_dst": dense_init(k4, d, d, dtype),
            "attn": {"wq": dense_init(kq, d, d, dtype),
                     "wk": dense_init(kk, d, d, dtype),
                     "wv": dense_init(kv, d, d, dtype),
                     "wo": dense_init(ko, d, d, dtype)},
            "mlp_in": dense_init(jax.random.fold_in(k3, 1), d, 2 * d, dtype),
            "mlp_out": dense_init(jax.random.fold_in(k3, 2), 2 * d, d, dtype),
            "prelu": _prelu_init(dtype),
        }
    raise ValueError(cfg.backbone)


def gnn_init(key, cfg: GNNConfig, dtype=jnp.float32):
    keys = jax.random.split(key, cfg.n_pre + cfg.n_mp + cfg.n_post + 1)
    p = {"pre": [], "mp": [], "post": []}
    d_in = cfg.n_feat
    for i in range(cfg.n_pre):
        p["pre"].append({"w": dense_init(keys[i], d_in, cfg.hidden, dtype),
                         "b": jnp.zeros((cfg.hidden,), dtype),
                         "prelu": _prelu_init(dtype)})
        d_in = cfg.hidden
    for i in range(cfg.n_mp):
        p["mp"].append(_mp_params(keys[cfg.n_pre + i], cfg, dtype))
    for i in range(cfg.n_post):
        p["post"].append({"w": dense_init(keys[cfg.n_pre + cfg.n_mp + i],
                                          cfg.hidden, cfg.hidden, dtype),
                          "b": jnp.zeros((cfg.hidden,), dtype),
                          "prelu": _prelu_init(dtype)})
    return p


def _agg_mean(h_src, dst, edge_valid, m):
    """Masked mean aggregation of messages at dst nodes (jnp reference)."""
    msg = h_src * edge_valid[:, None]
    summed = jax.ops.segment_sum(msg, dst, num_segments=m)
    deg = jax.ops.segment_sum(edge_valid, dst, num_segments=m)
    return summed / jnp.maximum(deg, 1.0)[:, None], deg


def _mp_layer(p, cfg: GNNConfig, h, edges, edge_valid, node_valid):
    m = h.shape[0]
    src, dst = edges[:, 0], edges[:, 1]
    if cfg.backbone == "gcn":
        # symmetric-normalized aggregation with self loops
        deg = jax.ops.segment_sum(edge_valid, dst, num_segments=m) + 1.0
        norm = jax.lax.rsqrt(deg)
        msg = (h * norm[:, None])[src] * edge_valid[:, None]
        agg = jax.ops.segment_sum(msg, dst, num_segments=m) * norm[:, None]
        out = _prelu(p["prelu"], (h * (norm ** 2)[:, None] + agg) @ p["w"])
        return out * node_valid[:, None]
    if cfg.backbone == "sage":
        mean_nbr, _ = _agg_mean(h[src], dst, edge_valid, m)
        out = _prelu(p["prelu"], h @ p["w_self"] + mean_nbr @ p["w_nbr"])
        return out * node_valid[:, None]
    if cfg.backbone == "gps":
        # local: gated message passing (GatedGCN-flavored)
        gate = jax.nn.sigmoid(h[src] @ p["w_gate_src"] + h[dst] @ p["w_gate_dst"])
        msgs = gate * (h[src] @ p["w_msg"])
        local, _ = _agg_mean(msgs, dst, edge_valid, m)
        # global: exact masked self-attention over segment nodes
        d = cfg.hidden
        hd = d // cfg.num_heads
        q = (h @ p["attn"]["wq"]).reshape(m, cfg.num_heads, hd)
        k = (h @ p["attn"]["wk"]).reshape(m, cfg.num_heads, hd)
        v = (h @ p["attn"]["wv"]).reshape(m, cfg.num_heads, hd)
        logits = jnp.einsum("qhd,khd->hqk", q, k) / jnp.sqrt(hd)
        logits = jnp.where(node_valid[None, None, :] > 0, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        glob = jnp.einsum("hqk,khd->qhd", probs, v).reshape(m, d) @ p["attn"]["wo"]
        h = h + local + glob
        h = h + _prelu(p["prelu"], h @ p["mlp_in"]) @ p["mlp_out"]
        return h * node_valid[:, None]
    raise ValueError(cfg.backbone)


def _encode_one(params, cfg: GNNConfig, x, edges, edge_valid, node_valid):
    h = x
    for lp in params["pre"]:
        h = _prelu(lp["prelu"], h @ lp["w"] + lp["b"])
    h = h * node_valid[:, None]
    for lp in params["mp"]:
        h = _mp_layer(lp, cfg, h, edges, edge_valid, node_valid)
    for lp in params["post"]:
        h = _prelu(lp["prelu"], h @ lp["w"] + lp["b"])
    h = h * node_valid[:, None]
    denom = jnp.maximum(jnp.sum(node_valid), 1.0)
    return jnp.sum(h, axis=0) / denom  # mean pool over valid nodes


def _batched_degree(dst, edge_valid, m):
    """(N, e) dst/valid -> (N, m) in-degree per segment (cheap O(e) reduce)."""
    return jax.vmap(
        lambda d, v: jax.ops.segment_sum(v, d, num_segments=m))(dst, edge_valid)


def _encode_batched(params, cfg: GNNConfig, seg_inputs):
    """Fused execution path: every message-passing layer is ONE batched
    ``segment_spmm`` pallas_call over all N = B·S padded segments, instead of
    N vmapped launches.  Semantically identical to vmap(_encode_one)
    (asserted in tests/test_fused_path.py); gcn/sage only.

    GCN's symmetric normalization folds into the kernel's scalar edge
    weights:  w_e = norm[src_e] · norm[dst_e] · edge_valid_e, so
    Σ_e w_e h[src_e] = norm[v] · Σ_{e→v} norm[src_e] h[src_e].
    """
    from repro.kernels.ops import batched_neighbor_sum

    x = seg_inputs["x"]                       # (N, m, F)
    edges = seg_inputs["edges"]               # (N, e, 2)
    ev = seg_inputs["edge_valid"]             # (N, e)
    nv = seg_inputs["node_valid"]             # (N, m)
    src, dst = edges[..., 0], edges[..., 1]
    m = x.shape[1]

    h = x
    for lp in params["pre"]:
        h = _prelu(lp["prelu"], h @ lp["w"] + lp["b"])
    h = h * nv[..., None]
    # degree / norm / edge weights depend only on the graph structure —
    # loop-invariant across message-passing layers, computed once
    if cfg.backbone == "gcn":
        deg = _batched_degree(dst, ev, m) + 1.0
        norm = jax.lax.rsqrt(deg)                              # (N, m)
        w = (jnp.take_along_axis(norm, src, axis=1)
             * jnp.take_along_axis(norm, dst, axis=1) * ev)
    elif cfg.backbone == "sage":
        deg_c = jnp.maximum(_batched_degree(dst, ev, m), 1.0)
    else:
        raise ValueError(f"batched pallas path does not support "
                         f"backbone={cfg.backbone!r}")
    for lp in params["mp"]:
        if cfg.backbone == "gcn":
            agg = batched_neighbor_sum(h, src, dst, w)
            h = _prelu(lp["prelu"],
                       (h * (norm ** 2)[..., None] + agg) @ lp["w"])
        else:
            summed = batched_neighbor_sum(h, src, dst, ev)
            mean_nbr = summed / deg_c[..., None]
            h = _prelu(lp["prelu"], h @ lp["w_self"] + mean_nbr @ lp["w_nbr"])
        h = h * nv[..., None]
    for lp in params["post"]:
        h = _prelu(lp["prelu"], h @ lp["w"] + lp["b"])
    h = h * nv[..., None]
    denom = jnp.maximum(jnp.sum(nv, axis=1), 1.0)
    return jnp.sum(h, axis=1) / denom[:, None]


def encode_segments(params, cfg: GNNConfig, seg_inputs) -> jnp.ndarray:
    """Single-bucket encode entry point: one flat batch of padded segments
    (leaves (N, m, ...) of ONE padding shape) -> embeddings (N, hidden).

    This is the unit of work shared by the train loop (via make_encode_fn)
    and the serving engine (serve/engine.py encodes one padded-CSR bucket
    per call): cfg.use_pallas (gcn/sage) routes through the batched fused
    path — one pallas_call per message-passing layer for the whole batch —
    otherwise (or for gps) the jnp reference path, vmapped over segments.
    """
    if cfg.use_pallas and cfg.backbone in ("gcn", "sage"):
        return _encode_batched(params, cfg, seg_inputs)
    f = partial(_encode_one, params, cfg)
    return jax.vmap(f)(seg_inputs["x"], seg_inputs["edges"],
                       seg_inputs["edge_valid"], seg_inputs["node_valid"])


def make_encode_fn(cfg: GNNConfig) -> Callable:
    """Returns encode_fn(params, seg_inputs) -> (emb (N, hidden), aux=0.)
    matching the GST core's backbone interface (a thin wrapper around
    ``encode_segments`` adding the aux-loss slot)."""

    def encode(params, seg_inputs):
        return encode_segments(params, cfg, seg_inputs), jnp.zeros((), jnp.float32)

    return encode
