"""GNN backbones for the paper-faithful track: GCN, SAGE, GraphGPS-lite.

GraphGym-style design space (paper Table 5): pre-process MLP layers, message
passing layers, post-process MLP layers, PReLU, mean aggregation.  The
backbone F maps one padded segment -> one embedding (mean-pooled over valid
nodes); batching over segments is a vmap.

GraphGPS-lite follows the GPS recipe (local MPNN + global attention per
layer) [25]; the Performer approximation is unnecessary at segment size
(<= m_GST nodes), so global attention is exact over the segment — same
asymptotics as the paper's setup because segments are size-bounded.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


@dataclass(frozen=True)
class GNNConfig:
    backbone: str = "sage"       # gcn | sage | gps
    n_feat: int = 8
    hidden: int = 64
    n_pre: int = 1
    n_mp: int = 2
    n_post: int = 1
    num_heads: int = 4           # gps global attention heads
    use_pallas: bool = False     # route neighbor aggregation through the
                                 # segment_spmm Pallas kernel (TPU target;
                                 # interpret mode on CPU — tests only)


def _prelu_init(dtype=jnp.float32):
    return {"a": jnp.full((1,), 0.25, dtype)}


def _prelu(p, x):
    return jnp.where(x >= 0, x, p["a"] * x)


def _mp_params(key, cfg: GNNConfig, dtype=jnp.float32):
    d = cfg.hidden
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if cfg.backbone == "gcn":
        return {"w": dense_init(k1, d, d, dtype), "prelu": _prelu_init(dtype)}
    if cfg.backbone == "sage":
        return {"w_self": dense_init(k1, d, d, dtype),
                "w_nbr": dense_init(k2, d, d, dtype),
                "prelu": _prelu_init(dtype)}
    if cfg.backbone == "gps":
        kq, kk, kv, ko = jax.random.split(k3, 4)
        return {
            "w_msg": dense_init(k1, d, d, dtype),
            "w_gate_src": dense_init(k2, d, d, dtype),
            "w_gate_dst": dense_init(k4, d, d, dtype),
            "attn": {"wq": dense_init(kq, d, d, dtype),
                     "wk": dense_init(kk, d, d, dtype),
                     "wv": dense_init(kv, d, d, dtype),
                     "wo": dense_init(ko, d, d, dtype)},
            "mlp_in": dense_init(jax.random.fold_in(k3, 1), d, 2 * d, dtype),
            "mlp_out": dense_init(jax.random.fold_in(k3, 2), 2 * d, d, dtype),
            "prelu": _prelu_init(dtype),
        }
    raise ValueError(cfg.backbone)


def gnn_init(key, cfg: GNNConfig, dtype=jnp.float32):
    keys = jax.random.split(key, cfg.n_pre + cfg.n_mp + cfg.n_post + 1)
    p = {"pre": [], "mp": [], "post": []}
    d_in = cfg.n_feat
    for i in range(cfg.n_pre):
        p["pre"].append({"w": dense_init(keys[i], d_in, cfg.hidden, dtype),
                         "b": jnp.zeros((cfg.hidden,), dtype),
                         "prelu": _prelu_init(dtype)})
        d_in = cfg.hidden
    for i in range(cfg.n_mp):
        p["mp"].append(_mp_params(keys[cfg.n_pre + i], cfg, dtype))
    for i in range(cfg.n_post):
        p["post"].append({"w": dense_init(keys[cfg.n_pre + cfg.n_mp + i],
                                          cfg.hidden, cfg.hidden, dtype),
                          "b": jnp.zeros((cfg.hidden,), dtype),
                          "prelu": _prelu_init(dtype)})
    return p


def _agg_mean(h_src, dst, edge_valid, m, *, src=None, h_full=None,
              use_pallas=False):
    """Masked mean aggregation of messages at dst nodes.

    use_pallas (requires src + h_full=(m, d) node features): the reduction
    runs through the segment_spmm kernel (one-hot MXU matmuls) instead of
    jax.ops.segment_sum — identical semantics, TPU-tiled execution.
    """
    if use_pallas and src is not None and h_full is not None:
        from repro.kernels.segment_spmm import segment_spmm
        summed = segment_spmm(h_full, src, dst, edge_valid,
                              interpret=jax.default_backend() != "tpu")
    else:
        msg = h_src * edge_valid[:, None]
        summed = jax.ops.segment_sum(msg, dst, num_segments=m)
    deg = jax.ops.segment_sum(edge_valid, dst, num_segments=m)
    return summed / jnp.maximum(deg, 1.0)[:, None], deg


def _mp_layer(p, cfg: GNNConfig, h, edges, edge_valid, node_valid):
    m = h.shape[0]
    src, dst = edges[:, 0], edges[:, 1]
    if cfg.backbone == "gcn":
        # symmetric-normalized aggregation with self loops
        deg = jax.ops.segment_sum(edge_valid, dst, num_segments=m) + 1.0
        norm = jax.lax.rsqrt(deg)
        msg = (h * norm[:, None])[src] * edge_valid[:, None]
        agg = jax.ops.segment_sum(msg, dst, num_segments=m) * norm[:, None]
        out = _prelu(p["prelu"], (h * (norm ** 2)[:, None] + agg) @ p["w"])
        return out * node_valid[:, None]
    if cfg.backbone == "sage":
        mean_nbr, _ = _agg_mean(h[src], dst, edge_valid, m, src=src, h_full=h,
                                use_pallas=cfg.use_pallas)
        out = _prelu(p["prelu"], h @ p["w_self"] + mean_nbr @ p["w_nbr"])
        return out * node_valid[:, None]
    if cfg.backbone == "gps":
        # local: gated message passing (GatedGCN-flavored)
        gate = jax.nn.sigmoid(h[src] @ p["w_gate_src"] + h[dst] @ p["w_gate_dst"])
        msgs = gate * (h[src] @ p["w_msg"])
        local, _ = _agg_mean(msgs, dst, edge_valid, m)
        # global: exact masked self-attention over segment nodes
        d = cfg.hidden
        hd = d // cfg.num_heads
        q = (h @ p["attn"]["wq"]).reshape(m, cfg.num_heads, hd)
        k = (h @ p["attn"]["wk"]).reshape(m, cfg.num_heads, hd)
        v = (h @ p["attn"]["wv"]).reshape(m, cfg.num_heads, hd)
        logits = jnp.einsum("qhd,khd->hqk", q, k) / jnp.sqrt(hd)
        logits = jnp.where(node_valid[None, None, :] > 0, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        glob = jnp.einsum("hqk,khd->qhd", probs, v).reshape(m, d) @ p["attn"]["wo"]
        h = h + local + glob
        h = h + _prelu(p["prelu"], h @ p["mlp_in"]) @ p["mlp_out"]
        return h * node_valid[:, None]
    raise ValueError(cfg.backbone)


def _encode_one(params, cfg: GNNConfig, x, edges, edge_valid, node_valid):
    h = x
    for lp in params["pre"]:
        h = _prelu(lp["prelu"], h @ lp["w"] + lp["b"])
    h = h * node_valid[:, None]
    for lp in params["mp"]:
        h = _mp_layer(lp, cfg, h, edges, edge_valid, node_valid)
    for lp in params["post"]:
        h = _prelu(lp["prelu"], h @ lp["w"] + lp["b"])
    h = h * node_valid[:, None]
    denom = jnp.maximum(jnp.sum(node_valid), 1.0)
    return jnp.sum(h, axis=0) / denom  # mean pool over valid nodes


def make_encode_fn(cfg: GNNConfig) -> Callable:
    """Returns encode_fn(params, seg_inputs) -> (emb (N, hidden), aux=0.)
    matching the GST core's backbone interface."""

    def encode(params, seg_inputs):
        f = partial(_encode_one, params, cfg)
        emb = jax.vmap(f)(seg_inputs["x"], seg_inputs["edges"],
                          seg_inputs["edge_valid"], seg_inputs["node_valid"])
        return emb, jnp.zeros((), jnp.float32)

    return encode
