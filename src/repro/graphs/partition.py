"""Graph partitioners (paper §3.1 + Table 6 ablation).

The paper uses METIS as the canonical partitioner and ablates Louvain,
random edge-cut, and vertex-cut schemes (DBH, NE).  The container has no
METIS binding, so we implement:

  * ``bfs``        — METIS-like locality-preserving region growing: BFS from
                     random seeds, capped at max_size (greedy graph growing,
                     the seed heuristic inside METIS's coarsening).
  * ``louvain``    — networkx Louvain communities, split/merged to max_size.
  * ``random``     — random node assignment (random EDGE-CUT — the paper's
                     failure case: destroys locality).
  * ``vertex_cut`` — DBH-style edge partitioning by hashing the higher-degree
                     endpoint; nodes are replicated across segments [33].

All return List[np.ndarray] of node ids per segment (vertex-cut may repeat
nodes across segments).  Every node appears in >= 1 segment.
"""
from __future__ import annotations

from collections import defaultdict, deque
from typing import Callable, Dict, List

import numpy as np


def _adjacency(n: int, edges: np.ndarray) -> List[List[int]]:
    """Symmetrized adjacency: both directions of every edge are inserted so
    BFS region growing reaches a node regardless of the orientation callers
    hand us (a directed edge list no longer silently strands sink-only
    nodes in singleton segments)."""
    adj: List[List[int]] = [[] for _ in range(n)]
    for a, b in edges:
        a, b = int(a), int(b)
        adj[a].append(b)
        if a != b:
            adj[b].append(a)
    return adj


def bfs_partition(n: int, edges: np.ndarray, max_size: int,
                  seed: int = 0) -> List[np.ndarray]:
    """Locality-preserving region growing (METIS-like)."""
    rng = np.random.default_rng(seed)
    adj = _adjacency(n, edges)
    unassigned = np.ones(n, bool)
    order = rng.permutation(n)
    segments: List[np.ndarray] = []
    ptr = 0
    while unassigned.any():
        while ptr < n and not unassigned[order[ptr]]:
            ptr += 1
        seed_node = int(order[ptr])
        seg = []
        q = deque([seed_node])
        unassigned[seed_node] = False
        while q and len(seg) < max_size:
            u = q.popleft()
            seg.append(u)
            for v in adj[u]:
                if unassigned[v] and len(seg) + len(q) < max_size:
                    unassigned[v] = False
                    q.append(v)
        # drain queue into the segment (already marked assigned)
        while q and len(seg) < max_size:
            seg.append(q.popleft())
        for u in q:  # overflow back to the pool
            unassigned[u] = True
        segments.append(np.asarray(seg, np.int32))
    return segments


def louvain_partition(n: int, edges: np.ndarray, max_size: int,
                      seed: int = 0) -> List[np.ndarray]:
    try:
        import networkx as nx
    except ImportError:
        # minimal containers have no networkx; the BFS region grower is the
        # closest locality-preserving stand-in (same invariants, Table 6
        # shows both sit in the locality-preserving cluster)
        return bfs_partition(n, edges, max_size, seed)
    g = nx.Graph()
    g.add_nodes_from(range(n))
    g.add_edges_from(map(tuple, edges))
    comms = nx.algorithms.community.louvain_communities(g, seed=seed)
    segments: List[np.ndarray] = []
    bucket: List[int] = []
    for c in comms:
        nodes = sorted(c)
        # split oversized communities, merge small ones into buckets
        for i in range(0, len(nodes), max_size):
            chunk = nodes[i : i + max_size]
            if len(chunk) == max_size:
                segments.append(np.asarray(chunk, np.int32))
            else:
                bucket.extend(chunk)
                while len(bucket) >= max_size:
                    segments.append(np.asarray(bucket[:max_size], np.int32))
                    bucket = bucket[max_size:]
    if bucket:
        segments.append(np.asarray(bucket, np.int32))
    return segments


def random_partition(n: int, edges: np.ndarray, max_size: int,
                     seed: int = 0) -> List[np.ndarray]:
    """Random edge-cut: random node assignment, no locality."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    return [perm[i : i + max_size].astype(np.int32)
            for i in range(0, n, max_size)]


def vertex_cut_partition(n: int, edges: np.ndarray, max_size: int,
                         seed: int = 0) -> List[np.ndarray]:
    """DBH-style vertex-cut [33]: assign each edge to the hash bucket of its
    higher-degree endpoint; a segment's node set is the union of endpoints of
    its edges (nodes replicated across segments)."""
    deg = np.bincount(edges.reshape(-1), minlength=n)
    n_parts = max(1, int(np.ceil(n / max_size)))
    rng = np.random.default_rng(seed)
    salt = int(rng.integers(0, 2**31))
    part_nodes: Dict[int, set] = defaultdict(set)
    for a, b in edges:
        a, b = int(a), int(b)
        pivot = a if deg[a] >= deg[b] else b
        p = (pivot * 2654435761 + salt) % n_parts
        part_nodes[p].add(a)
        part_nodes[p].add(b)
    covered = set().union(*part_nodes.values()) if part_nodes else set()
    isolated = [u for u in range(n) if u not in covered]
    for u in isolated:
        part_nodes[(u * 2654435761 + salt) % n_parts].add(u)
    segments = []
    for p in sorted(part_nodes):
        nodes = sorted(part_nodes[p])
        for i in range(0, len(nodes), max_size):  # enforce the cap
            segments.append(np.asarray(nodes[i : i + max_size], np.int32))
    return segments


PARTITIONERS: Dict[str, Callable] = {
    "bfs": bfs_partition,          # METIS-like (default)
    "louvain": louvain_partition,
    "random": random_partition,    # random edge-cut (failure case)
    "vertex_cut": vertex_cut_partition,
}


def partition_graph(n: int, edges: np.ndarray, max_size: int,
                    method: str = "bfs", seed: int = 0) -> List[np.ndarray]:
    return PARTITIONERS[method](n, edges, max_size, seed)
