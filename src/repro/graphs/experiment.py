"""End-to-end experiment driver for the paper-faithful graph track.

Runs one (dataset, backbone, variant) cell of the paper's tables on the
synthetic MalNet-like / TpuGraphs-like datasets: GST training (Algorithm 1/2)
with optional head-finetuning phase, returning train/test metrics and
wall-clock per-iteration time (Table 3 analogue).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gst as G
from repro.graphs import batching as Bt
from repro.graphs import data as D
from repro.graphs.gnn import GNNConfig, gnn_init, make_encode_fn
from repro.obs import StalenessProbe, get_registry, probe_jit, span
from repro.optim import make_optimizer
from repro.store import DeviceStore, TieredStore


@dataclass
class ExperimentResult:
    variant: str
    backbone: str
    train_metric: float
    test_metric: float
    ms_per_iter: float
    use_pallas: bool = False
    finetuned: bool = False      # whether the Algorithm-2 head-finetuning
                                 # phase (lines 11-18) actually ran
    curve: List[Dict] = field(default_factory=list)
    store_stats: Optional[Dict] = None   # residency counters (store/)


def _to_batch(seg_inputs, seg_valid, ids, labels) -> G.GSTBatch:
    return G.GSTBatch(
        {k: jnp.asarray(v) for k, v in seg_inputs.items()},
        jnp.asarray(seg_valid), jnp.asarray(ids), jnp.asarray(labels))


def run_experiment(
    *,
    dataset: str = "malnet",          # malnet | tpugraphs
    backbone: str = "sage",           # gcn | sage | gps
    variant: str = "gst_efd",
    n_graphs: int = 80,
    max_seg_nodes: int = 64,
    partition: str = "bfs",
    epochs: int = 30,
    finetune_epochs: int = 10,
    batch_size: int = 8,
    hidden: int = 64,
    lr: float = 5e-3,
    keep_prob: float = 0.5,
    num_sampled: int = 1,
    seed: int = 0,
    test_frac: float = 0.25,
    record_curve: bool = False,
    use_pallas: bool = False,
    table_device_rows: Optional[int] = None,
    evict_policy: str = "lru",
    wb_threshold: float = 0.0,
    sed_age_weighting: float = 0.0,   # λ of the stale-branch exp(-λ·age)
                                      # decay in Eq. 1 (0 = off, bit-exact)
    stale_forecast: bool = False,     # extrapolate stale host rows forward
                                      # on fault-in (store/forecast.py)
    obs=None,                         # optional repro.obs.Obs bundle: gets a
                                      # per-epoch tick + staleness probe
) -> ExperimentResult:
    var = G.VARIANTS[variant]
    if dataset == "malnet":
        graphs = D.make_malnet_like(n_graphs=n_graphs, seed=seed)
        loss_kind, head_mode, agg, n_out = "ce", "mlp", "mean", 5
    else:
        graphs = D.make_tpugraphs_like(n_graphs=n_graphs, seed=seed)
        # paper §5.3: per-segment runtime, F' = sum; normalize targets
        loss_kind, head_mode, agg, n_out = "pairwise_hinge", "segment_sum", "sum", 1
        lab = np.asarray([g.label for g in graphs], np.float32)
        mu, sd = lab.mean(), lab.std() + 1e-6
        for g in graphs:
            g.label = float((g.label - mu) / sd)

    n_test = int(len(graphs) * test_frac)
    rng = np.random.default_rng(seed + 17)
    perm = rng.permutation(len(graphs))
    test_graphs = [graphs[i] for i in perm[:n_test]]
    train_graphs = [graphs[i] for i in perm[n_test:]]

    ds = Bt.segment_dataset(train_graphs, max_seg_nodes, method=partition, seed=seed)
    ds_test = Bt.segment_dataset(test_graphs, max_seg_nodes, method=partition,
                                 seed=seed, j_max=ds.j_max, e_max=ds.e_max)

    cfg = GNNConfig(backbone=backbone, n_feat=graphs[0].x.shape[1],
                    hidden=hidden, use_pallas=use_pallas)
    enc = make_encode_fn(cfg)
    key = jax.random.key(seed)
    bb = gnn_init(key, cfg)
    head = G.head_init(jax.random.fold_in(key, 1), hidden, n_out, head_mode)
    opt = make_optimizer("adam", lr=lr)
    # the historical table lives behind the embedding store: fully
    # device-resident by default, or a bounded LRU of hot rows over a
    # host-RAM tier when table_device_rows caps device residency —
    # bit-identical either way (tests/test_store.py)
    store = (TieredStore(ds.n, ds.j_max, hidden,
                         device_rows=max(table_device_rows, batch_size),
                         evict_policy=evict_policy,
                         wb_threshold=wb_threshold,
                         stale_forecast=stale_forecast)
             if table_device_rows else DeviceStore(ds.n, ds.j_max, hidden))
    state = G.TrainState(bb, head, opt.init((bb, head)),
                         store.init_device_table(),
                         jnp.zeros((), jnp.int32))

    # TrainState is donated through the hot steps so the (n, J, d) embedding
    # table scatters in-place instead of copying the largest array each iter.
    # probe_jit hooks each jit entry point into the obs.memory probe
    # (--mem-probe): compiled memory/cost stats per (site, shape signature),
    # a no-op branch when probing is off
    step = probe_jit("train.step", jax.jit(G.make_train_step(
        enc, opt, var, num_sampled=num_sampled, keep_prob=keep_prob,
        head_mode=head_mode, loss_kind=loss_kind, agg=agg,
        use_pallas=use_pallas, sed_decay=sed_age_weighting),
        donate_argnums=(0,)))
    eval_step = probe_jit("train.eval", jax.jit(
        G.make_eval_step(enc, head_mode=head_mode, loss_kind=loss_kind,
                         agg=agg, use_pallas=use_pallas)))
    refresh = probe_jit("train.refresh", jax.jit(
        G.make_refresh_step(enc), donate_argnums=(0,)))

    def evaluate(ds_, st):
        ms, ws = [], []
        for tup in Bt.batch_iterator(ds_, batch_size, rng=np.random.default_rng(0),
                                     shuffle=False):
            m = eval_step(st, _to_batch(*tup))
            ms.append(float(m["metric"]))
            ws.append(tup[1].shape[0])
        return float(np.average(ms, weights=ws)) if ms else float("nan")

    # host-side mirror of state.step: the step hint handed to the store on
    # write paths (train/refresh), so stale-first scoring and the stale-row
    # forecaster see the TRUE step without a device sync per batch
    step_counter = {"t": 0}

    def route(tup, step=None):
        """Map the batch's graph ids onto device rows through the store
        (migrating tiers as needed) — identity under the DeviceStore."""
        nonlocal state
        table, slots = store.prepare(state.table, tup[2], step=step)
        state = state._replace(table=table)
        return jnp.asarray(slots)

    def routed(tup, step=None):
        return _to_batch(*tup)._replace(graph_ids=route(tup, step=step))

    # the store owns a write-back thread when tiered — release it even
    # when training raises (try/finally), keeping repeated runs leak-free
    try:
        curve = []
        iter_times = []
        brng = np.random.default_rng(seed + 3)
        last_train = 0.0
        probe = StalenessProbe(keep_prob=keep_prob, num_sampled=num_sampled,
                               seg_valid=ds.seg_valid,
                               sed_decay=sed_age_weighting,
                               forecast=stale_forecast)
        for epoch in range(epochs):
            ep_metrics = []
            for tup in Bt.batch_iterator(ds, batch_size, rng=brng):
                batch = _to_batch(*tup)
                # the timed region includes the tier migration — it IS part of
                # the step cost of a capped-capacity table (bench_store.py)
                t0 = time.perf_counter()
                # replaces state.table before the step sees it; the hint is
                # the step about to WRITE these rows
                slots = route(tup, step=step_counter["t"])
                with span("train.step", epoch=epoch):
                    state, m = step(state, batch._replace(graph_ids=slots),
                                    jax.random.key(epoch))
                    jax.block_until_ready(m["loss"])
                step_counter["t"] += 1
                iter_times.append(time.perf_counter() - t0)
                ep_metrics.append(float(m["metric"]))
            last_train = float(np.mean(ep_metrics))
            # resident rows refreshed by this epoch's writes re-report their
            # true device-plane ages to the eviction bookkeeping (no-op
            # under plain LRU)
            store.refresh_ages(state.table)
            stale = None
            if get_registry().enabled:
                store.publish_counters()
                stale = probe.observe(store, state.table,
                                      int(jax.device_get(state.step)))
            if obs is not None:
                obs.tick(step=int(jax.device_get(state.step)), epoch=epoch,
                         train=last_train, staleness=stale)
            if record_curve:
                curve.append({"epoch": epoch, "train": last_train,
                              "test": evaluate(ds_test, state)})

        # ---- head finetuning phase (Algorithm 2 lines 11-18) -----------------
        # Runs for BOTH head modes: the MLP graph head and the TpuGraphs
        # per-segment scalar head finetune from the refreshed table.
        finetuned = False
        if var.finetune_head:
            for tup in Bt.batch_iterator(ds, batch_size, rng=brng, shuffle=False):
                # refresh WRITES every requested row at the current step
                batch = routed(tup, step=step_counter["t"])
                state = refresh(state, batch)
            ft_opt = make_optimizer("adam", lr=lr * 0.5)
            state = state._replace(opt_state=ft_opt.init(state.head))
            ft_step = probe_jit("train.finetune", jax.jit(G.make_finetune_step(
                ft_opt, head_mode=head_mode, loss_kind=loss_kind, agg=agg,
                use_pallas=use_pallas), donate_argnums=(0,)))
            for fe in range(finetune_epochs):
                for tup in Bt.batch_iterator(ds, batch_size, rng=brng):
                    batch = routed(tup)
                    state, m = ft_step(state, batch)
                    finetuned = True
                if record_curve:
                    curve.append({"epoch": epochs + fe, "train": float(m["metric"]),
                                  "test": evaluate(ds_test, state)})
            state = state._replace(opt_state=opt.init((state.backbone, state.head)))

        store.flush_writebacks()
        store_stats = store.stats()
    finally:
        store.close()
    # skip the first few compile-laden iterations in the timing
    ms_per_iter = float(np.median(iter_times[3:]) * 1e3) if len(iter_times) > 4 else float("nan")
    return ExperimentResult(
        variant=variant, backbone=backbone,
        train_metric=last_train,
        test_metric=evaluate(ds_test, state),
        ms_per_iter=ms_per_iter, use_pallas=use_pallas,
        finetuned=finetuned, curve=curve, store_stats=store_stats)
