"""Static-shape segment batching (the XLA adaptation of the paper's pipeline).

Each segment is padded to (m_max nodes, e_max edges) with validity masks;
each graph is padded to J_max segments with a segment mask.  Edges are local
to a segment (indices into the segment's node list); cross-segment edges are
dropped — the paper's Table 6 ablation shows locality-preserving partitions
make this information loss negligible.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.graphs.data import SyntheticGraph
from repro.graphs.partition import partition_graph


@dataclass
class SegmentedDataset:
    """All arrays are host numpy; leading dims (n_graphs, J_max, ...)."""
    x: np.ndarray          # (n, J, m_max, F)
    edges: np.ndarray      # (n, J, e_max, 2) int32 — local node indices
    edge_valid: np.ndarray  # (n, J, e_max) float32
    node_valid: np.ndarray  # (n, J, m_max) float32
    seg_valid: np.ndarray  # (n, J) float32
    labels: np.ndarray     # (n,) int32 or float32
    j_max: int
    m_max: int
    e_max: int

    @property
    def n(self):
        return self.x.shape[0]

    def seg_inputs(self, ids: np.ndarray) -> Dict[str, np.ndarray]:
        return {
            "x": self.x[ids],
            "edges": self.edges[ids],
            "edge_valid": self.edge_valid[ids],
            "node_valid": self.node_valid[ids],
        }


def pad_segment(graph: SyntheticGraph, node_ids: np.ndarray, m_max: int,
                e_max: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Extract one segment as padded arrays (x, edges_local, edge_valid, node_valid)."""
    node_ids = node_ids[:m_max]
    g2l = {int(g): l for l, g in enumerate(node_ids)}
    sel = np.isin(graph.edges[:, 0], node_ids) & np.isin(graph.edges[:, 1], node_ids)
    e = graph.edges[sel]
    if len(e) > e_max:
        e = e[np.random.default_rng(0).permutation(len(e))[:e_max]]
    e_local = np.asarray([[g2l[int(a)], g2l[int(b)]] for a, b in e], np.int32)
    x = np.zeros((m_max, graph.x.shape[1]), np.float32)
    x[: len(node_ids)] = graph.x[node_ids]
    edges = np.zeros((e_max, 2), np.int32)
    edge_valid = np.zeros((e_max,), np.float32)
    if len(e_local):
        edges[: len(e_local)] = e_local
        edge_valid[: len(e_local)] = 1.0
    node_valid = np.zeros((m_max,), np.float32)
    node_valid[: len(node_ids)] = 1.0
    return x, edges, edge_valid, node_valid


def segment_dataset(
    graphs: List[SyntheticGraph],
    max_seg_nodes: int = 64,
    method: str = "bfs",
    j_max: Optional[int] = None,
    e_max: Optional[int] = None,
    seed: int = 0,
) -> SegmentedDataset:
    """Preprocessing phase: partition every graph and pad (paper §3.1)."""
    all_segs = []
    for gi, g in enumerate(graphs):
        segs = partition_graph(len(g.x), g.edges, max_seg_nodes, method, seed + gi)
        all_segs.append(segs)
    J = j_max or max(len(s) for s in all_segs)
    m_max = max_seg_nodes
    if e_max is None:
        e_max = 0
        for g, segs in zip(graphs, all_segs):
            for s in segs:
                sel = np.isin(g.edges[:, 0], s) & np.isin(g.edges[:, 1], s)
                e_max = max(e_max, int(sel.sum()))
        e_max = max(e_max, 1)
    n, F = len(graphs), graphs[0].x.shape[1]
    X = np.zeros((n, J, m_max, F), np.float32)
    E = np.zeros((n, J, e_max, 2), np.int32)
    EV = np.zeros((n, J, e_max), np.float32)
    NV = np.zeros((n, J, m_max), np.float32)
    SV = np.zeros((n, J), np.float32)
    labels = np.asarray([g.label for g in graphs])
    labels = labels.astype(np.int32 if np.issubdtype(labels.dtype, np.integer) else np.float32)
    for gi, (g, segs) in enumerate(zip(graphs, all_segs)):
        for j, s in enumerate(segs[:J]):
            x, e, ev, nv = pad_segment(g, s, m_max, e_max)
            X[gi, j], E[gi, j], EV[gi, j], NV[gi, j] = x, e, ev, nv
            SV[gi, j] = 1.0
    return SegmentedDataset(X, E, EV, NV, SV, labels, J, m_max, e_max)


def batch_id_schedule(n: int, batch_size: int, *, rng: np.random.Generator,
                      shuffle: bool = True) -> List[np.ndarray]:
    """One epoch's id batches (drop-last) — THE batching policy, shared by
    ``batch_iterator`` and the dist feeders (dist/pipeline.py::epoch_ids)
    so the two paths cannot diverge."""
    order = rng.permutation(n) if shuffle else np.arange(n)
    return [order[i : i + batch_size]
            for i in range(0, n - batch_size + 1, batch_size)]


def batch_iterator(ds: SegmentedDataset, batch_size: int, *, rng: np.random.Generator,
                   shuffle: bool = True) -> Iterator[Tuple[Dict, np.ndarray, np.ndarray, np.ndarray]]:
    """Yields (seg_inputs, seg_valid, graph_ids, labels) batches (drop-last)."""
    for ids in batch_id_schedule(ds.n, batch_size, rng=rng, shuffle=shuffle):
        yield ds.seg_inputs(ids), ds.seg_valid[ids], ids.astype(np.int32), ds.labels[ids]


def whole_graph_dataset(graphs: List[SyntheticGraph]) -> SegmentedDataset:
    """Full Graph Training baseline: each graph is ONE segment padded to the
    dataset max — memory scales with the largest graph (the paper's OOM case)."""
    m_max = max(len(g.x) for g in graphs)
    e_max = max(len(g.edges) for g in graphs)
    n, F = len(graphs), graphs[0].x.shape[1]
    X = np.zeros((n, 1, m_max, F), np.float32)
    E = np.zeros((n, 1, e_max, 2), np.int32)
    EV = np.zeros((n, 1, e_max), np.float32)
    NV = np.zeros((n, 1, m_max), np.float32)
    SV = np.ones((n, 1), np.float32)
    labels = np.asarray([g.label for g in graphs])
    labels = labels.astype(np.int32 if np.issubdtype(labels.dtype, np.integer) else np.float32)
    for gi, g in enumerate(graphs):
        X[gi, 0, : len(g.x)] = g.x
        E[gi, 0, : len(g.edges)] = g.edges
        EV[gi, 0, : len(g.edges)] = 1.0
        NV[gi, 0, : len(g.x)] = 1.0
    return SegmentedDataset(X, E, EV, NV, SV, labels, 1, m_max, e_max)
