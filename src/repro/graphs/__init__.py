from repro.graphs.data import make_malnet_like, make_tpugraphs_like, SyntheticGraph
from repro.graphs.partition import partition_graph, PARTITIONERS
from repro.graphs.batching import (
    SegmentedDataset,
    pad_segment,
    segment_dataset,
    batch_iterator,
)

__all__ = [
    "make_malnet_like",
    "make_tpugraphs_like",
    "SyntheticGraph",
    "partition_graph",
    "PARTITIONERS",
    "SegmentedDataset",
    "pad_segment",
    "segment_dataset",
    "batch_iterator",
]
