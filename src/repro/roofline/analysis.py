"""Three-term roofline model from a compiled dry-run artifact (deliverable g).

    compute term    = HLO_FLOPs      / (chips × peak_FLOP/s)
    memory term     = HLO_bytes      / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

Hardware constants (assignment): TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

``cost_analysis()`` of an SPMD-partitioned executable reports *per-device*
FLOPs/bytes (the module is the per-device program).  We therefore multiply
by the device count to get global HLO_FLOPs before applying the formulas —
verified in tests/test_roofline.py against an analytically-known matmul.

Collective bytes are not in cost_analysis: we parse the optimized HLO text
and, for each collective op, take max(result bytes, operand bytes) as the
bytes moved per device — exact for all-reduce/all-to-all/collective-permute,
an upper bound for all-gather (result) and reduce-scatter (operand).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12      # bf16 FLOP/s per chip
    hbm_bw: float = 819e9           # B/s per chip
    link_bw: float = 50e9           # B/s per ICI link


_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# the CompiledMemoryStats fields every consumer reads (roofline report,
# dryrun print, obs.memory probe) — one list so they can never drift
MEMORY_STAT_FIELDS = (
    "argument_size_in_bytes", "output_size_in_bytes", "temp_size_in_bytes",
    "generated_code_size_in_bytes", "alias_size_in_bytes",
    "host_argument_size_in_bytes", "host_output_size_in_bytes",
    "host_temp_size_in_bytes",
)


def compiled_memory_stats(compiled) -> Optional[Dict[str, int]]:
    """THE memory_analysis() extraction path (roofline, dryrun, and the
    obs.memory probe all go through here).  Returns the available
    :data:`MEMORY_STAT_FIELDS` as ints, or ``None`` on backends / jax
    versions where ``memory_analysis`` is unavailable or empty — callers
    degrade to accounting-only (kernels.ops.max_intermediate_bytes)."""
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    if ma is None:
        return None
    out = {k: int(getattr(ma, k)) for k in MEMORY_STAT_FIELDS
           if hasattr(ma, k)}
    return out or None


def compiled_cost_stats(compiled) -> Optional[Dict[str, float]]:
    """The matching cost_analysis() extraction: ``{"flops", "bytes_accessed"}``
    per device, or ``None`` when the backend doesn't report costs."""
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return None
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else None
    if ca is None:
        return None
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0))}


def device_peak_bytes(mem_stats: Dict[str, int]) -> int:
    """Peak device-memory model from the extracted stats: live arguments +
    outputs + XLA temp buffers, minus donation-aliased bytes (an aliased
    output reuses its donated argument's buffer, so it must not count
    twice).  This is the number the constant-memory gates track."""
    return (mem_stats.get("argument_size_in_bytes", 0)
            + mem_stats.get("output_size_in_bytes", 0)
            + mem_stats.get("temp_size_in_bytes", 0)
            - mem_stats.get("alias_size_in_bytes", 0))


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum bytes moved per device, per collective kind, over the module."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
                      r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                      r"collective-permute)", stripped)
        if not m:
            continue
        kind = m.group(2)
        if f" {kind}(" not in stripped and f"{kind}(" not in stripped:
            continue
        if "-start" in stripped.split(kind)[0][-8:]:
            pass  # async start counted; the matching -done has no new bytes
        if f"{kind}-done" in stripped:
            continue
        # result bytes (may be a tuple type)
        res_bytes = sum(_shape_bytes(d, s) for d, s in
                        _SHAPE_RE.findall(m.group(1)))
        # operand types (present in verbose HLO operand lists)
        after = stripped.split(kind, 1)[1]
        op_bytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(after))
        out[kind] += float(max(res_bytes, op_bytes))
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def count_collective_ops(hlo_text: str) -> Dict[str, int]:
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        for k in _COLLECTIVES:
            if re.search(rf"=\s*[^=]*\b{k}(-start)?\(", line):
                counts[k] += 1
    return counts


def param_counts(param_shapes, moe_top_k: int = 0, moe_num_experts: int = 0
                 ) -> Tuple[float, float]:
    """(total params, active params).  Leaves under a path containing
    'experts' are scaled by top_k/num_experts for the active count."""
    import jax
    total = 0.0
    active = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(param_shapes)[0]:
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        n = float(np.prod(leaf.shape)) if leaf.shape else 1.0
        total += n
        if "experts" in pstr and moe_num_experts:
            active += n * moe_top_k / moe_num_experts
        else:
            active += n
    return total, active


def model_flops(n_active: float, tokens: float, kind: str) -> float:
    """Useful model FLOPs: 6·N·D for training, 2·N·D for inference."""
    return (6.0 if kind == "train" else 2.0) * n_active * tokens


def analyze_compiled(compiled, *, chips: int, hw: HW = HW(),
                     n_active: Optional[float] = None,
                     tokens: Optional[float] = None,
                     kind: str = "train") -> Dict[str, Any]:
    """Derive the three roofline terms + diagnostics from a compiled module."""
    cs = compiled_cost_stats(compiled) or {}
    flops_dev = cs.get("flops", 0.0)
    bytes_dev = cs.get("bytes_accessed", 0.0)
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    counts = count_collective_ops(hlo)

    flops_global = flops_dev * chips
    bytes_global = bytes_dev * chips
    t_compute = flops_global / (chips * hw.peak_flops)
    t_memory = bytes_global / (chips * hw.hbm_bw)
    t_collective = coll["total"] / hw.link_bw  # per-device bytes over one link
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_collective}
    dominant = max(terms, key=terms.get)

    mem_stats = compiled_memory_stats(compiled)
    if mem_stats is None:  # CPU backend / old jax may not implement it
        mem_stats = {"error": "memory_analysis unavailable on this backend"}

    result = {
        "chips": chips,
        "flops_per_device": flops_dev,
        "flops_global": flops_global,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll,
        "collective_op_counts": counts,
        "terms_seconds": terms,
        "dominant": dominant,
        "memory_analysis": mem_stats,
    }
    if n_active is not None and tokens is not None:
        mf = model_flops(n_active, tokens, kind)
        result["model_flops"] = mf
        result["useful_flops_ratio"] = mf / flops_global if flops_global else 0.0
        result["mfu_upper_bound"] = mf / (chips * hw.peak_flops) / max(
            max(terms.values()), 1e-30)
    return result
