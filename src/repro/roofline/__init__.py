from repro.roofline.analysis import (
    HW,
    analyze_compiled,
    collective_bytes,
    model_flops,
    param_counts,
)

__all__ = ["HW", "analyze_compiled", "collective_bytes", "model_flops",
           "param_counts"]
