"""Benchmark functions — one per paper table/figure (deliverable d).

Each returns a list of CSV rows ``(name, value, derived)`` and prints them.
Scales are CPU-sized; the *orderings and mechanisms* are what reproduce
(see EXPERIMENTS.md §Claims for the comparison against the paper's numbers).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, str]


def _emit(rows: List[Row]):
    for name, val, derived in rows:
        print(f"{name},{val},{derived}", flush=True)
    return rows


# ---------------------------------------------------------------------------
# Table 1 — MalNet accuracy across variants × backbones
# ---------------------------------------------------------------------------


def table1_malnet(quick: bool = False, seeds=(0, 1)) -> List[Row]:
    from repro.graphs.experiment import run_experiment
    backbones = ["sage"] if quick else ["gcn", "sage"]
    variants = ["gst", "gst_one", "gst_e", "gst_efd"] if quick else \
        ["full", "gst", "gst_one", "gst_e", "gst_ef", "gst_ed", "gst_efd"]
    seeds = seeds[:1] if quick else seeds
    rows: List[Row] = []
    for bb in backbones:
        for v in variants:
            accs = []
            for s in seeds:
                r = run_experiment(dataset="malnet", backbone=bb, variant=v,
                                   n_graphs=60 if quick else 120,
                                   epochs=12 if quick else 35,
                                   finetune_epochs=6 if quick else 15, seed=s)
                accs.append(r.test_metric)
            rows.append((f"table1/malnet/{bb}/{v}",
                         round(float(np.mean(accs)), 4),
                         f"test_acc±{np.std(accs):.3f}"))
    return _emit(rows)


# ---------------------------------------------------------------------------
# Table 2 — TpuGraphs OPA across variants
# ---------------------------------------------------------------------------


def table2_tpugraphs(quick: bool = False) -> List[Row]:
    from repro.graphs.experiment import run_experiment
    variants = ["gst", "gst_one", "gst_e", "gst_efd"]
    rows: List[Row] = []
    for v in variants:
        r = run_experiment(dataset="tpugraphs", backbone="sage", variant=v,
                           n_graphs=48 if quick else 80,
                           epochs=15 if quick else 30,
                           finetune_epochs=0, seed=0)
        rows.append((f"table2/tpugraphs/{v}/train",
                     round(r.train_metric, 4), "train_OPA"))
        rows.append((f"table2/tpugraphs/{v}/test",
                     round(r.test_metric, 4), "test_OPA"))
    return _emit(rows)


# ---------------------------------------------------------------------------
# Table 3 — runtime per training iteration across variants
# ---------------------------------------------------------------------------


def table3_runtime(quick: bool = False) -> List[Row]:
    from repro.graphs.experiment import run_experiment
    rows: List[Row] = []
    for v in ["full", "gst", "gst_one", "gst_e", "gst_efd"]:
        r = run_experiment(dataset="malnet", backbone="sage", variant=v,
                           n_graphs=40, epochs=4, finetune_epochs=0, seed=0)
        rows.append((f"table3/ms_per_iter/{v}", round(r.ms_per_iter, 2),
                     "median_train_iter_ms"))
    return _emit(rows)


# ---------------------------------------------------------------------------
# Figure 3 — SED keep-ratio sweep
# ---------------------------------------------------------------------------


def fig3_keep_ratio(quick: bool = False) -> List[Row]:
    from repro.graphs.experiment import run_experiment
    rows: List[Row] = []
    ps = [0.0, 0.5, 1.0] if quick else [0.0, 0.25, 0.5, 0.75, 1.0]
    for p in ps:
        r = run_experiment(dataset="malnet", backbone="sage", variant="gst_efd",
                           n_graphs=60 if quick else 100,
                           epochs=12 if quick else 30,
                           finetune_epochs=6 if quick else 12,
                           keep_prob=p, seed=0)
        rows.append((f"fig3/keep_ratio/{p}", round(r.test_metric, 4), "test_acc"))
    return _emit(rows)


# ---------------------------------------------------------------------------
# Figure 4 — max segment size sweep
# ---------------------------------------------------------------------------


def fig4_segment_size(quick: bool = False) -> List[Row]:
    from repro.graphs.experiment import run_experiment
    rows: List[Row] = []
    sizes = [32, 64] if quick else [24, 32, 48, 64, 96]
    for m in sizes:
        r = run_experiment(dataset="malnet", backbone="sage", variant="gst_efd",
                           n_graphs=60 if quick else 100, max_seg_nodes=m,
                           epochs=12 if quick else 30,
                           finetune_epochs=6 if quick else 12, seed=0)
        rows.append((f"fig4/seg_size/{m}", round(r.test_metric, 4), "test_acc"))
    return _emit(rows)


# ---------------------------------------------------------------------------
# Table 6 — partition-algorithm ablation
# ---------------------------------------------------------------------------


def table6_partitioners(quick: bool = False) -> List[Row]:
    from repro.graphs.experiment import run_experiment
    rows: List[Row] = []
    methods = ["bfs", "random"] if quick else ["bfs", "louvain", "random",
                                               "vertex_cut"]
    for m in methods:
        r = run_experiment(dataset="malnet", backbone="sage", variant="gst_efd",
                           n_graphs=60 if quick else 100, partition=m,
                           epochs=12 if quick else 30,
                           finetune_epochs=6 if quick else 12, seed=0)
        rows.append((f"table6/partition/{m}", round(r.test_metric, 4),
                     "test_acc"))
    return _emit(rows)


# ---------------------------------------------------------------------------
# Figure 1 / §5.1 — constant-memory claim (compiled temp bytes vs J)
# ---------------------------------------------------------------------------


def _fig1_setup(variant, J, m=48, B=4, hidden=32, n=16, seed=0):
    from repro.core import gst as G
    from repro.core.embedding_table import init_table
    from repro.graphs.gnn import GNNConfig, gnn_init, make_encode_fn
    from repro.optim import make_optimizer
    cfg = GNNConfig(backbone="sage", n_feat=8, hidden=hidden)
    enc = make_encode_fn(cfg)
    bb = gnn_init(jax.random.key(seed), cfg)
    head = G.head_init(jax.random.key(seed + 1), hidden, 5, "mlp")
    opt = make_optimizer("adam", lr=5e-3)
    state = G.TrainState(bb, head, opt.init((bb, head)),
                         init_table(n, J, hidden), jnp.zeros((), jnp.int32))
    step = G.make_train_step(enc, opt, G.VARIANTS[variant])
    rng = np.random.default_rng(seed)
    e = 64
    batch = G.GSTBatch(
        {"x": jnp.asarray(rng.normal(size=(B, J, m, 8)), jnp.float32),
         "edges": jnp.asarray(rng.integers(0, m, (B, J, e, 2)), jnp.int32),
         "edge_valid": jnp.ones((B, J, e), jnp.float32),
         "node_valid": jnp.ones((B, J, m), jnp.float32)},
        jnp.ones((B, J), jnp.float32), jnp.arange(B, dtype=jnp.int32),
        jnp.asarray(rng.integers(0, 5, B), jnp.int32))
    return state, batch, step


def fig1_memory(quick: bool = False) -> List[Row]:
    _setup = _fig1_setup
    rows: List[Row] = []
    Js = [4, 8, 16] if quick else [2, 4, 8, 16, 32]
    for variant in ["full", "gst_efd"]:
        for J in Js:
            state, batch, step = _setup(variant, J)
            c = jax.jit(step).lower(state, batch, jax.random.key(0)).compile()
            tmp = int(c.memory_analysis().temp_size_in_bytes)
            rows.append((f"fig1/temp_bytes/{variant}/J={J}", tmp,
                         "compiled_temp_bytes"))
    return _emit(rows)


# ---------------------------------------------------------------------------
# Kernels — µs/call (CPU interpret: structural check; TPU is the target)
# ---------------------------------------------------------------------------


def kernels_bench(quick: bool = False) -> List[Row]:
    from repro.kernels.ref import (sed_pool_ref, segment_spmm_ref,
                                   swa_attention_ref)
    rows: List[Row] = []
    rng = np.random.default_rng(0)

    def timeit(f, *args, n=3):
        f(*args)  # compile
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(f(*args))
        return (time.perf_counter() - t0) / n * 1e6

    m, d, e = 128, 128, 1024
    h = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
    src = jnp.asarray(rng.integers(0, m, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, m, e), jnp.int32)
    w = jnp.ones((e,), jnp.float32)
    rows.append(("kernels/segment_spmm_ref_us",
                 round(timeit(jax.jit(lambda *a: segment_spmm_ref(*a, m)),
                              h, src, dst, w), 1), f"m={m},d={d},e={e}"))
    B, J, dd = 64, 16, 256
    hh = jnp.asarray(rng.normal(size=(B, J, dd)), jnp.float32)
    ones = jnp.ones((B, J))
    rows.append(("kernels/sed_pool_ref_us",
                 round(timeit(jax.jit(lambda *a: sed_pool_ref(*a, 0.5, 1)),
                              hh, ones, ones, ones * 0), 1), f"B={B},J={J},d={dd}"))
    q = jnp.asarray(rng.normal(size=(1, 512, 4, 64)), jnp.float32)
    rows.append(("kernels/swa_ref_us",
                 round(timeit(jax.jit(lambda a, b, c: swa_attention_ref(
                     a, b, c, 256)), q, q, q), 1), "S=512,W=256"))
    return _emit(rows)


# ---------------------------------------------------------------------------
# Roofline — dump the dry-run table (single-pod baselines)
# ---------------------------------------------------------------------------


def roofline_table(quick: bool = False, path: str = None) -> List[Row]:
    import json
    import os
    rows: List[Row] = []
    if path is None:
        # prefer the unrolled-accounting sweep (exact per-layer totals);
        # fall back to the scan-mode lowering-proof sweep
        path = (".scratch/roofline_unrolled.json"
                if os.path.exists(".scratch/roofline_unrolled.json")
                else ".scratch/dryrun_single.json")
    if not os.path.exists(path):
        rows.append(("roofline/missing", 0.0,
                     f"run launch/dryrun.py --out {path} first"))
        return _emit(rows)
    with open(path) as f:
        results = json.load(f)
    for r in results:
        if r.get("status") != "ok":
            continue
        t = r["terms_seconds"]
        name = f"roofline/{r['arch']}/{r['shape']}"
        rows.append((name + "/compute_s", f"{t['compute']:.3e}", r["dominant"]))
        rows.append((name + "/memory_s", f"{t['memory']:.3e}", r["dominant"]))
        rows.append((name + "/collective_s", f"{t['collective']:.3e}",
                     r["dominant"]))
        if "useful_flops_ratio" in r:
            rows.append((name + "/useful_flops_ratio",
                         round(r["useful_flops_ratio"], 4), "6ND/HLO"))
    return _emit(rows)


ALL_BENCHES = {
    "table1": table1_malnet,
    "table2": table2_tpugraphs,
    "table3": table3_runtime,
    "fig3": fig3_keep_ratio,
    "fig4": fig4_segment_size,
    "table6": table6_partitioners,
    "fig1_memory": fig1_memory,
    "kernels": kernels_bench,
    "roofline": roofline_table,
}
