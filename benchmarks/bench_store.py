"""Tracked embedding-store benchmark — step time and migration traffic vs
device-tier size.

Runs the gst_efd train step over the same shuffled epoch trace with the
historical table behind a TieredStore whose device tier holds a FRACTION
of the table rows ({1.0, 0.5, 0.1}), plus the dense DeviceStore oracle
row.  Per fraction it records median step ms (INCLUDING the host-side
prepare/commit migration, which is the honest cost of a capped table),
host<->device migration bytes per step, tier hit-rate, and the store
counters; a parity gate asserts the 10%-tier run reproduces the oracle's
final loss bit-for-bit before anything is written.

A delta-gated leg re-runs the smallest tier with ``--wb-threshold`` so
evictions of barely-moved rows skip the device->host emb copy
(store/writeback.delta_gate); the run asserts the gated leg migrates
strictly fewer KiB/step than the ungated one and records the saving
under ``summary["delta_gate"]``.  Parity gates apply to the ungated
legs only — the gate intentionally trades bounded staleness for
traffic.

Usage:
    PYTHONPATH=src python benchmarks/bench_store.py           # full
    PYTHONPATH=src python benchmarks/bench_store.py --quick   # CI-sized

Writes ``BENCH_gst_store.json`` (repo root), merge-keyed by config +
backend + jax version like the other tracked benchmarks.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if os.path.isdir(os.path.join(_REPO, "src")) and \
        os.path.join(_REPO, "src") not in sys.path:
    sys.path.insert(0, os.path.join(_REPO, "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gst as G
from repro.dist import pipeline as DP
from repro.graphs import data as D
from repro.graphs.gnn import GNNConfig, gnn_init, make_encode_fn
from repro.obs import MetricsRegistry, StalenessProbe, summarize, wb_skip_rate
from repro.optim import make_optimizer
from repro.store import DeviceStore, TieredStore

FRACTIONS = (1.0, 0.5, 0.1)
VARIANT = "gst_efd"
BACKBONE = "sage"


def _fresh(ds, hidden):
    cfg = GNNConfig(backbone=BACKBONE, n_feat=ds.x.shape[-1], hidden=hidden)
    enc = make_encode_fn(cfg)
    key = jax.random.key(0)
    bb = gnn_init(key, cfg)
    head = G.head_init(jax.random.fold_in(key, 1), hidden, 5, "mlp")
    opt = make_optimizer("adam", lr=1e-3)
    return enc, opt, bb, head


def bench_store(ds, *, hidden: int, batch_size: int, n_iters: int,
                fraction=None, warmup: int = None,
                wb_threshold: float = 0.0, sed_decay: float = 0.0,
                stale_forecast: bool = False):
    """fraction None -> DeviceStore oracle; else TieredStore with
    device_rows = max(fraction * n, batch_size).  ``sed_decay`` turns on
    the age-weighted Eq.-1 stale branch; ``stale_forecast`` faults stale
    host rows in extrapolated by the online velocity predictor — both 0/
    off by default so the parity legs trace the historical step."""
    enc, opt, bb, head = _fresh(ds, hidden)
    staleness_on = sed_decay > 0.0 or stale_forecast
    if fraction is None:
        store = DeviceStore(ds.n, ds.j_max, hidden)
    else:
        store = TieredStore(ds.n, ds.j_max, hidden,
                            device_rows=max(int(round(fraction * ds.n)),
                                            batch_size),
                            wb_threshold=wb_threshold,
                            stale_forecast=stale_forecast)
    state = G.TrainState(bb, head, opt.init((bb, head)),
                         store.init_device_table(), jnp.zeros((), jnp.int32))
    step = jax.jit(G.make_train_step(enc, opt, G.VARIANTS[VARIANT],
                                     keep_prob=0.5, sed_decay=sed_decay),
                   donate_argnums=(0,))
    sched = DP.epoch_ids(ds, batch_size, rng=np.random.default_rng(0))
    batches = [(ids, jax.tree_util.tree_map(jnp.asarray,
                                            DP._assemble(ds, ids)))
               for ids in sched]

    def one(i, t):
        ids, batch = batches[i % len(batches)]
        # the staleness legs pass the step hint (true-age bookkeeping +
        # forecast clock); the parity legs keep the historical call
        table, slots = store.prepare(state_holder["s"].table, ids,
                                     step=t if staleness_on else None)
        s = state_holder["s"]._replace(table=table)
        s, m = step(s, batch._replace(graph_ids=jnp.asarray(slots)),
                    jax.random.key(t))
        state_holder["s"] = s
        return m["loss"]

    state_holder = {"s": state}
    # warm a FULL epoch (+2): jit compiles absorbed and — for a tier big
    # enough to hold every row — the whole table faulted in, so the timed
    # region measures steady-state migration only
    warmup = warmup if warmup is not None else len(batches) + 2
    for t in range(warmup):
        jax.block_until_ready(one(t, t))
    from repro.store import StoreCounters
    store.counters = StoreCounters()   # steady-state traffic only
    times = []
    loss = None
    for t in range(n_iters):
        t0 = time.perf_counter()
        loss = one(warmup + t, warmup + t)
        jax.block_until_ready(loss)
        times.append((time.perf_counter() - t0) * 1e3)
    store.flush_writebacks()
    stats = store.stats()
    # staleness of the final table, through the same probe the launchers
    # publish from (a throwaway registry keeps the benchmark side-effect
    # free for the process-wide one)
    probe = StalenessProbe(keep_prob=0.5, num_sampled=1,
                           seg_valid=ds.seg_valid,
                           registry=MetricsRegistry(),
                           sed_decay=sed_decay, forecast=stale_forecast)
    stale = probe.observe(store, state_holder["s"].table,
                          int(jax.device_get(state_holder["s"].step)))
    t = summarize(times)
    row = {
        "fraction": fraction if fraction is not None else "dense",
        "device_rows": stats["device_rows"],
        "n_rows": ds.n,
        "step_ms": round(t["p50"], 3),
        "step_ms_p99": round(t["p99"], 3),
        "migration_bytes_per_step":
            stats["migration_bytes"] // max(n_iters, 1),
        "tier_hit_rate": round(stats["hit_rate"], 4),
        "wb_skip_rate": round(wb_skip_rate(stats), 4),
        "staleness": stale,
        "store": stats,
    }
    store.close()
    return row, float(np.asarray(loss))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI-sized run")
    ap.add_argument("--out", default=os.path.join(_REPO, "BENCH_gst_store.json"))
    ap.add_argument("--n-graphs", type=int, default=None)
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--max-seg-nodes", type=int, default=32)
    ap.add_argument("--wb-threshold", type=float, default=0.1,
                    help="delta-gate threshold for the gated leg (max-abs "
                         "embedding movement below which an evicted row "
                         "skips the host write; embeddings here are O(1) "
                         "encoder outputs, so 0.1 skips the near-static "
                         "tail); 0 disables the leg")
    ap.add_argument("--sed-age-weighting", type=float, default=0.1,
                    help="λ for the age-weighted leg (exp(-λ·age) folded "
                         "into Eq.-1's stale branch on the smallest tier); "
                         "0 disables the leg")
    ap.add_argument("--no-forecast-leg", action="store_true",
                    help="skip the --stale-forecast leg")
    args = ap.parse_args()
    n_graphs = args.n_graphs or (48 if args.quick else 96)
    n_iters = args.iters or (6 if args.quick else 20)

    graphs = D.make_malnet_like(n_graphs=n_graphs, seed=0)
    ds, spec = DP.segment_dataset_shared(graphs, args.max_seg_nodes, seed=0)

    print(f"{'tier':>8s} {'dev rows':>8s} {'step ms':>8s} "
          f"{'migr B/step':>11s} {'hit':>5s}")
    results = []
    dense, dense_loss = bench_store(ds, hidden=args.hidden,
                                    batch_size=args.batch_size,
                                    n_iters=n_iters)
    results.append(dense)
    print(f"{'dense':>8s} {dense['device_rows']:8d} {dense['step_ms']:8.2f} "
          f"{dense['migration_bytes_per_step']:11d} "
          f"{dense['tier_hit_rate']:5.2f}")
    frac_loss = {}
    for f in FRACTIONS:
        row, loss = bench_store(ds, hidden=args.hidden,
                                batch_size=args.batch_size,
                                n_iters=n_iters, fraction=f)
        results.append(row)
        frac_loss[f] = loss
        print(f"{f:8.2f} {row['device_rows']:8d} {row['step_ms']:8.2f} "
              f"{row['migration_bytes_per_step']:11d} "
              f"{row['tier_hit_rate']:5.2f}", flush=True)

    # delta-gated leg: the smallest (churning) tier again, write-backs
    # admitted only for rows that actually moved
    gated = None
    if args.wb_threshold > 0:
        gated, _ = bench_store(ds, hidden=args.hidden,
                               batch_size=args.batch_size,
                               n_iters=n_iters, fraction=FRACTIONS[-1],
                               wb_threshold=args.wb_threshold)
        gated["fraction"] = f"{FRACTIONS[-1]}+gate"
        results.append(gated)
        print(f"{gated['fraction']:>8s} {gated['device_rows']:8d} "
              f"{gated['step_ms']:8.2f} "
              f"{gated['migration_bytes_per_step']:11d} "
              f"{gated['tier_hit_rate']:5.2f}  "
              f"(skipped {gated['store']['wb_skipped_rows']} rows, "
              f"{gated['store']['wb_skipped_bytes'] / 1024:.1f} KiB)",
              flush=True)

    # age-weighted leg: the churning tier with the exp(-λ·age) stale-branch
    # decay — ages read true (step hints), effective age measured by the
    # same probe the launchers publish from
    weighted = None
    if args.sed_age_weighting > 0:
        weighted, _ = bench_store(ds, hidden=args.hidden,
                                  batch_size=args.batch_size,
                                  n_iters=n_iters, fraction=FRACTIONS[-1],
                                  sed_decay=args.sed_age_weighting)
        weighted["fraction"] = f"{FRACTIONS[-1]}+age"
        results.append(weighted)
        print(f"{weighted['fraction']:>8s} {weighted['device_rows']:8d} "
              f"{weighted['step_ms']:8.2f} "
              f"{weighted['migration_bytes_per_step']:11d} "
              f"{weighted['tier_hit_rate']:5.2f}  "
              f"(eff-age p99 "
              f"{weighted['staleness']['effective_age_steps']['p99']:.1f} vs "
              f"row-age p99 "
              f"{weighted['staleness']['row_age_steps']['p99']:.1f})",
              flush=True)

    # forecast leg: stale host rows faulted in extrapolated forward by the
    # online per-row velocity predictor (store/forecast.py)
    forecast = None
    if not args.no_forecast_leg:
        forecast, _ = bench_store(ds, hidden=args.hidden,
                                  batch_size=args.batch_size,
                                  n_iters=n_iters, fraction=FRACTIONS[-1],
                                  stale_forecast=True)
        forecast["fraction"] = f"{FRACTIONS[-1]}+forecast"
        results.append(forecast)
        fc = forecast["store"].get("forecast", {})
        print(f"{forecast['fraction']:>8s} {forecast['device_rows']:8d} "
              f"{forecast['step_ms']:8.2f} "
              f"{forecast['migration_bytes_per_step']:11d} "
              f"{forecast['tier_hit_rate']:5.2f}  "
              f"(observed {fc.get('observed_rows', 0)} rows, "
              f"forecast {fc.get('forecast_rows', 0)} fault-ins)",
              flush=True)

    # contract gates BEFORE the write (a failing run must not pollute the
    # tracked file): tiering must be invisible to the math (ungated legs
    # only — the delta gate trades bounded staleness for traffic), and a
    # full-size device tier must go migration-free once warm
    assert all(loss == dense_loss for loss in frac_loss.values()), \
        f"tiered losses {frac_loss} != oracle {dense_loss} — bit-parity broken"
    full = next(r for r in results if r["fraction"] == 1.0)
    assert full["migration_bytes_per_step"] == 0, \
        "a device tier holding every row must not migrate after warmup"
    small = next(r for r in results if r["fraction"] == 0.1)
    assert small["store"]["evictions"] > 0, \
        "the 10% tier must actually churn"
    if gated is not None:
        assert gated["store"]["wb_skipped_rows"] > 0, \
            "the delta gate never skipped a write-back — threshold too low " \
            "for this trace"
        assert gated["migration_bytes_per_step"] < \
            small["migration_bytes_per_step"], \
            "delta-gated migration traffic must be strictly below ungated"
    if weighted is not None:
        eff_p99 = weighted["staleness"]["effective_age_steps"]["p99"]
        raw_p99 = weighted["staleness"]["row_age_steps"]["p99"]
        assert eff_p99 < raw_p99, \
            f"age-weighted effective-age p99 {eff_p99} must be strictly " \
            f"below row-age p99 {raw_p99} — the decay is not shrinking " \
            "the staleness the step experiences"
    if forecast is not None:
        assert forecast["store"]["forecast"]["observed_rows"] > 0, \
            "the forecaster never observed an eviction delta — the " \
            "churning tier should feed it every epoch after the first"

    summary = {
        "variant": VARIANT,
        "backbone": BACKBONE,
        "dense_step_ms": dense["step_ms"],
        "tiered_step_ms": {str(r["fraction"]): r["step_ms"]
                           for r in results if r["fraction"] != "dense"},
        "migration_bytes_per_step": {
            str(r["fraction"]): r["migration_bytes_per_step"]
            for r in results if r["fraction"] != "dense"},
        "bit_parity_with_oracle": True,
        "delta_gate": ({
            "wb_threshold": args.wb_threshold,
            "migration_bytes_per_step_gated":
                gated["migration_bytes_per_step"],
            "migration_bytes_per_step_ungated":
                small["migration_bytes_per_step"],
            "wb_skipped_rows": gated["store"]["wb_skipped_rows"],
            "wb_skipped_bytes": gated["store"]["wb_skipped_bytes"],
            "gated_below_ungated": True,
        } if gated is not None else None),
        "age_weighting": ({
            "sed_decay": args.sed_age_weighting,
            "step_ms": weighted["step_ms"],
            "effective_age_p99":
                weighted["staleness"]["effective_age_steps"]["p99"],
            "row_age_p99": weighted["staleness"]["row_age_steps"]["p99"],
            "effective_below_row": True,
        } if weighted is not None else None),
        "stale_forecast": ({
            "step_ms": forecast["step_ms"],
            "observed_rows": forecast["store"]["forecast"]["observed_rows"],
            "forecast_rows": forecast["store"]["forecast"]["forecast_rows"],
        } if forecast is not None else None),
    }
    config = {
        "n_graphs": n_graphs, "batch_size": args.batch_size,
        "hidden": args.hidden, "max_seg_nodes": args.max_seg_nodes,
        "bucket": spec.key, "j_max": ds.j_max, "iters": n_iters,
        "quick": args.quick, "wb_threshold": args.wb_threshold,
        "sed_age_weighting": args.sed_age_weighting,
    }
    env = {
        "backend": jax.default_backend(),
        "jax": jax.__version__,
        "device_count": jax.device_count(),
    }
    entry = {"summary": summary, "config": config, "env": env,
             "results": results}
    run_key = ",".join(f"{k}={v}" for k, v in sorted(config.items())) + \
        f",backend={env['backend']},jax={env['jax']}"
    payload = {"benchmark": "gst_store", "unit": "ms_per_iter", "runs": {}}
    if os.path.exists(args.out):
        try:
            with open(args.out) as f:
                prev = json.load(f)
            if prev.get("benchmark") == "gst_store" and \
                    isinstance(prev.get("runs"), dict):
                payload = prev
        except (json.JSONDecodeError, OSError):
            pass
    payload["runs"][run_key] = entry
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out} ({len(payload['runs'])} tracked run configs)")


if __name__ == "__main__":
    main()
