"""Tracked GST step-time benchmark — the repo's perf trajectory anchor.

Times one jitted train step and one eval step for every cell of
``{gst, gst_efd, full} × {sage, gcn} × {pallas, reference}`` on the synthetic
MalNet-like dataset, with the TrainState donated through the step (in-place
embedding-table updates).  Also records the pallas_call count of the forward
encode jaxpr — the fused path's contract is exactly one batched kernel
launch per message-passing layer.

Usage:
    PYTHONPATH=src python benchmarks/bench_step.py            # full grid
    PYTHONPATH=src python benchmarks/bench_step.py --quick    # CI-sized
    PYTHONPATH=src python benchmarks/bench_step.py --out custom.json

Writes ``BENCH_gst_step.json`` (repo root by default).  On CPU the kernels
run in Pallas interpret mode, so the pallas numbers measure the fused
*structure* (launch count, donation) rather than TPU silicon speed; the
reference rows are the apples-to-apples wall-clock baseline.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if os.path.isdir(os.path.join(_REPO, "src")) and \
        os.path.join(_REPO, "src") not in sys.path:
    sys.path.insert(0, os.path.join(_REPO, "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gst as G
from repro.core.embedding_table import init_table
from repro.graphs import batching as Bt
from repro.graphs import data as D
from repro.graphs.gnn import GNNConfig, gnn_init, make_encode_fn
from repro.kernels.ops import count_pallas_calls
from repro.obs import summarize
from repro.optim import make_optimizer

VARIANTS = ("gst", "gst_efd", "full")
BACKBONES = ("sage", "gcn")


def _median_ms(fn, n_iters: int) -> float:
    times = []
    for _ in range(n_iters):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e3)
    return summarize(times)["p50"]


def bench_cell(ds, variant: str, backbone: str, use_pallas: bool, *,
               batch_size: int, hidden: int, n_iters: int, warmup: int = 2,
               sed_decay: float = 0.0):
    tup = next(Bt.batch_iterator(ds, batch_size, rng=np.random.default_rng(0),
                                 shuffle=False))
    batch = G.GSTBatch({k: jnp.asarray(v) for k, v in tup[0].items()},
                       jnp.asarray(tup[1]), jnp.asarray(tup[2]),
                       jnp.asarray(tup[3]))
    cfg = GNNConfig(backbone=backbone, n_feat=ds.x.shape[-1], hidden=hidden,
                    use_pallas=use_pallas)
    enc = make_encode_fn(cfg)
    key = jax.random.key(0)
    bb = gnn_init(key, cfg)
    head = G.head_init(jax.random.fold_in(key, 1), hidden, 5, "mlp")
    opt = make_optimizer("adam", lr=1e-3)
    state = G.TrainState(bb, head, opt.init((bb, head)),
                         init_table(ds.n, ds.j_max, hidden),
                         jnp.zeros((), jnp.int32))
    step = jax.jit(G.make_train_step(
        enc, opt, G.VARIANTS[variant], keep_prob=0.5,
        use_pallas=use_pallas, sed_decay=sed_decay), donate_argnums=(0,))
    eval_step = jax.jit(G.make_eval_step(enc, use_pallas=use_pallas))

    seg_flat = {k: v.reshape((-1,) + v.shape[2:])
                for k, v in batch.seg_inputs.items()}
    n_kernel_calls = count_pallas_calls(lambda p: enc(p, seg_flat)[0], bb)

    # warmup (compile) then timed loop; state threads through donation
    holder = {"state": state, "i": 0}

    def one_train():
        holder["state"], m = step(holder["state"], batch,
                                  jax.random.key(holder["i"]))
        holder["i"] += 1
        return m["loss"]

    for _ in range(warmup):
        one_train()
    train_ms = _median_ms(one_train, n_iters)

    def one_eval():
        return eval_step(holder["state"], batch)["loss"]

    one_eval()
    eval_ms = _median_ms(one_eval, n_iters)
    return {
        "variant": variant if sed_decay == 0.0 else f"{variant}+age",
        "backbone": backbone,
        "use_pallas": use_pallas,
        "sed_decay": sed_decay,
        "device_count": jax.device_count(),
        "train_ms": round(train_ms, 3),
        "eval_ms": round(eval_ms, 3),
        "pallas_calls_encode_fwd": n_kernel_calls,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI-sized run")
    ap.add_argument("--out", default=os.path.join(_REPO, "BENCH_gst_step.json"))
    ap.add_argument("--n-graphs", type=int, default=None)
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--max-seg-nodes", type=int, default=32)
    args = ap.parse_args()
    n_graphs = args.n_graphs or (16 if args.quick else 32)
    n_iters = args.iters or (5 if args.quick else 20)

    graphs = D.make_malnet_like(n_graphs=n_graphs, seed=0)
    ds = Bt.segment_dataset(graphs, args.max_seg_nodes, method="bfs", seed=0)

    results = []
    print(f"{'variant':8s} {'backbone':8s} {'path':9s} "
          f"{'train ms':>9s} {'eval ms':>8s} {'kernels':>7s}")
    for variant in VARIANTS:
        for backbone in BACKBONES:
            for use_pallas in (False, True):
                row = bench_cell(ds, variant, backbone, use_pallas,
                                 batch_size=args.batch_size,
                                 hidden=args.hidden, n_iters=n_iters)
                results.append(row)
                print(f"{variant:8s} {backbone:8s} "
                      f"{'pallas' if use_pallas else 'reference':9s} "
                      f"{row['train_ms']:9.2f} {row['eval_ms']:8.2f} "
                      f"{row['pallas_calls_encode_fwd']:7d}", flush=True)

    # age-weighted leg: the complete method with the exp(-λ·age) stale-
    # branch decay threaded through both paths — the Eq.-1 extension's
    # step-time overhead (an extra age lookup + stale-branch multiply)
    for use_pallas in (False, True):
        row = bench_cell(ds, "gst_efd", "sage", use_pallas,
                         batch_size=args.batch_size, hidden=args.hidden,
                         n_iters=n_iters, sed_decay=0.1)
        results.append(row)
        print(f"{row['variant']:8s} {'sage':8s} "
              f"{'pallas' if use_pallas else 'reference':9s} "
              f"{row['train_ms']:9.2f} {row['eval_ms']:8.2f} "
              f"{row['pallas_calls_encode_fwd']:7d}", flush=True)

    by_key = {(r["variant"], r["backbone"], r["use_pallas"]): r
              for r in results}
    hot = []
    for backbone in BACKBONES:
        ref_row = by_key[("gst_efd", backbone, False)]
        pal_row = by_key[("gst_efd", backbone, True)]
        hot.append({
            "backbone": backbone,
            "train_ms_reference": ref_row["train_ms"],
            "train_ms_pallas": pal_row["train_ms"],
            "train_ratio_pallas_over_reference":
                round(pal_row["train_ms"] / max(ref_row["train_ms"], 1e-9), 3),
        })

    config = {
        "n_graphs": n_graphs, "batch_size": args.batch_size,
        "hidden": args.hidden, "max_seg_nodes": args.max_seg_nodes,
        "j_max": ds.j_max, "e_max": ds.e_max, "iters": n_iters,
        "quick": args.quick,
    }
    env = {
        "backend": jax.default_backend(),
        "jax": jax.__version__,
        "device_count": jax.device_count(),
        "pallas_interpret": jax.default_backend() != "tpu",
        "donated_train_state": True,
    }
    entry = {
        # gst_efd is the paper's complete method — the hot path this repo
        # optimizes.  On CPU both paths run the same jnp/XLA ops except the
        # kernels execute in Pallas interpret mode (structure check, not
        # silicon speed); on TPU the one-hot matmuls land on the MXU.
        "hot_path_summary": hot,
        "config": config,
        "env": env,
        "results": results,
    }
    # merge keyed by (config, backend, jax version, device count): single-
    # and multi-device runs (forced-host or real TPU slices) accumulate in
    # the same file instead of clobbering each other
    run_key = ",".join(f"{k}={v}" for k, v in sorted(config.items())) + \
        f",backend={env['backend']},jax={env['jax']}" + \
        f",device_count={env['device_count']}"
    payload = {"benchmark": "gst_step", "unit": "ms_per_iter", "runs": {}}
    if os.path.exists(args.out):
        try:
            with open(args.out) as f:
                prev = json.load(f)
            if prev.get("benchmark") == "gst_step":
                if isinstance(prev.get("runs"), dict):
                    payload = prev
                    # migrate pre-device_count keys (all were 1-device
                    # runs); if BOTH forms of a key exist (file touched by
                    # a pre-migration binary since), keep both entries
                    # rather than clobbering one
                    migrated = {}
                    for k, v in payload["runs"].items():
                        nk = k if "device_count=" in k else \
                            k + ",device_count=1"
                        migrated[k if nk in payload["runs"] and nk != k
                                 else nk] = v
                    payload["runs"] = migrated
                elif "results" in prev:  # migrate the pre-keyed flat format
                    old_cfg = prev.get("config", {})
                    old_env = prev.get("env", {})
                    old_key = ",".join(
                        f"{k}={v}" for k, v in sorted(old_cfg.items())) + \
                        f",backend={old_env.get('backend')}," \
                        f"jax={old_env.get('jax')},device_count=1"
                    payload["runs"][old_key] = {
                        k: prev[k] for k in
                        ("hot_path_summary", "config", "env", "results")
                        if k in prev}
        except (json.JSONDecodeError, OSError):
            pass
    payload["runs"][run_key] = entry
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out} ({len(payload['runs'])} tracked run configs)")


if __name__ == "__main__":
    main()
