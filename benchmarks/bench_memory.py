"""Tracked memory benchmark — measures GST's constant-memory claim.

The paper's headline promise is that segment training predicts properties
of arbitrarily large graphs with a CONSTANT device-memory footprint: only
the S sampled segments get activations/backprop, stale segments come from
the historical table, and at serve time the lax.scan streaming encoder
holds one chunk's activations regardless of graph size.

This benchmark measures that from the compiled artifacts
(``compiled.memory_analysis()`` via the shared roofline extraction
helpers), holding the segment budget fixed while growing the graph size
(``comm_range`` communities per graph -> more segments J per graph):

* ``full_step``   — full-graph training step (variant "full": every
                    segment gets activations + backprop).  Peak grows
                    roughly linearly with J: the anti-claim control.
* ``gst_step``    — GST training step (variant "gst_efd", the paper's
                    complete method).  Peak must stay ~flat.
* ``streaming``   — serve-side lax.scan streaming encoder across the SAME
                    size sweep (chunk count grows with the graph).  Temp
                    must be chunk-count-independent and at least the
                    jaxpr-walk ``max_intermediate_bytes`` lower bound.
* ``ladder``      — per-bucket compiled peak of every serve-ladder encode
                    shape + their total (the serve device budget).

Usage:
    PYTHONPATH=src python benchmarks/bench_memory.py            # full sweep
    PYTHONPATH=src python benchmarks/bench_memory.py --quick    # CI-sized
    PYTHONPATH=src python benchmarks/bench_memory.py --out custom.json

Writes ``BENCH_gst_memory.json`` (repo root by default), merge-keyed by
(config, backend, jax version, device count) exactly like bench_step.py.
``python -m repro.obs.gate --memory-json BENCH_gst_memory.json`` asserts
the flatness claims against the written numbers (CI: obs-smoke).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if os.path.isdir(os.path.join(_REPO, "src")) and \
        os.path.join(_REPO, "src") not in sys.path:
    sys.path.insert(0, os.path.join(_REPO, "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gst as G
from repro.core.embedding_table import init_table
from repro.graphs import batching as Bt
from repro.graphs import data as D
from repro.graphs.gnn import GNNConfig, gnn_init, make_encode_fn
from repro.kernels.ops import max_intermediate_bytes
from repro.optim import make_optimizer
from repro.roofline.analysis import (compiled_memory_stats,
                                     device_peak_bytes)
from repro.serve.buckets import batch_bucket, default_ladder, pad_to_bucket
from repro.serve.engine import graph_to_chunks, make_stream_encoder

# the size sweep: communities per graph (nodes grow ~linearly with this
# while max_seg_nodes stays fixed, so segments-per-graph J is what grows)
SWEEP_COMMS = (2, 4, 8, 16)
SWEEP_COMMS_QUICK = (2, 4, 8)


def _measure(jitted, *args) -> dict:
    """AOT lower->compile, return the compiled memory stats (peak model:
    argument + output + temp - alias, matching obs.memory)."""
    compiled = jitted.lower(*args).compile()
    mem = compiled_memory_stats(compiled)
    if mem is None:  # backend without memory_analysis: accounting only
        return {"mode": "accounting"}
    return {"mode": "compiled",
            "peak_bytes": device_peak_bytes(mem),
            "temp_bytes": mem.get("temp_size_in_bytes", 0),
            "arg_bytes": mem.get("argument_size_in_bytes", 0),
            "alias_bytes": mem.get("alias_size_in_bytes", 0)}


def _make_point(comm: int, *, n_graphs: int, max_seg_nodes: int,
                hidden: int, batch_size: int, backbone: str):
    """One size point: dataset + shared model pieces for both step legs."""
    graphs = D.make_malnet_like(n_graphs=n_graphs, seed=0,
                                comm_range=(comm, comm + 1))
    ds = Bt.segment_dataset(graphs, max_seg_nodes, method="bfs", seed=0)
    tup = next(Bt.batch_iterator(ds, batch_size,
                                 rng=np.random.default_rng(0), shuffle=False))
    batch = G.GSTBatch({k: jnp.asarray(v) for k, v in tup[0].items()},
                       jnp.asarray(tup[1]), jnp.asarray(tup[2]),
                       jnp.asarray(tup[3]))
    cfg = GNNConfig(backbone=backbone, n_feat=graphs[0].x.shape[1],
                    hidden=hidden)
    enc = make_encode_fn(cfg)
    key = jax.random.key(0)
    bb = gnn_init(key, cfg)
    head = G.head_init(jax.random.fold_in(key, 1), hidden, 5, "mlp")
    opt = make_optimizer("adam", lr=1e-3)
    state = G.TrainState(bb, head, opt.init((bb, head)),
                         init_table(ds.n, ds.j_max, hidden),
                         jnp.zeros((), jnp.int32))
    meta = {"comm": comm, "j_max": int(ds.j_max),
            "nodes_mean": round(float(np.mean([len(g.x) for g in graphs])), 1)}
    return graphs, ds, batch, cfg, enc, opt, state, head, meta


def bench_steps(comm: int, **kw) -> dict:
    """gst_efd vs full train-step compiled memory at one graph size."""
    _, _, batch, _, enc, opt, state, _, meta = _make_point(comm, **kw)
    out = dict(meta)
    for leg, variant in (("gst", "gst_efd"), ("full", "full")):
        step = jax.jit(G.make_train_step(enc, opt, G.VARIANTS[variant],
                                         keep_prob=0.5),
                       donate_argnums=(0,))
        out[leg] = _measure(step, state, batch, jax.random.key(0))
    return out


def bench_streaming(comm: int, *, chunk: int, **kw) -> dict:
    """Streaming-encoder compiled memory at one graph size: the chunk
    count C grows with the graph, temp must not."""
    graphs, _, _, cfg, _, _, _, head, meta = _make_point(comm, **kw)
    g = max(graphs, key=lambda gr: len(gr.x))
    spec = default_ladder(kw["max_seg_nodes"])[-1]
    chunks = graph_to_chunks(g, spec, chunk,
                             partition_max_nodes=kw["max_seg_nodes"])
    dev = {k: jnp.asarray(v) for k, v in chunks.items()}
    stream = make_stream_encoder(cfg)
    bb = gnn_init(jax.random.key(0), cfg)
    rec = _measure(stream, bb, head, dev)
    rec.update(meta, n_chunks=int(chunks["seg_valid"].shape[0]),
               accounting_bound_bytes=int(
                   max_intermediate_bytes(stream, bb, head, dev)))
    return rec


def bench_ladder(*, max_seg_nodes: int, hidden: int, backbone: str,
                 n_feat: int = 8) -> dict:
    """Per-bucket compiled peak of every serve-ladder encode shape."""
    from repro.graphs.gnn import encode_segments
    from repro.graphs.partition import partition_graph

    cfg = GNNConfig(backbone=backbone, n_feat=n_feat, hidden=hidden)
    bb = gnn_init(jax.random.key(0), cfg)
    g = D.make_malnet_like(n_graphs=1, seed=0)[0]
    buckets = []
    for spec in default_ladder(max_seg_nodes):
        segs = partition_graph(len(g.x), g.edges, spec.m_max, "bfs", 0)
        padded = [pad_to_bucket(g, s, spec) for s in segs[:spec.batch]]
        seg_inputs, _ = batch_bucket(padded, spec)
        dev = {k: jnp.asarray(v) for k, v in seg_inputs.items()}
        ejit = jax.jit(lambda p, si: encode_segments(p, cfg, si))
        rec = _measure(ejit, bb, dev)
        rec["key"] = spec.key
        buckets.append(rec)
    total = sum(b.get("peak_bytes", 0) for b in buckets)
    return {"buckets": buckets, "total_peak_bytes": int(total)}


def _ratio(points, leg, field="peak_bytes"):
    vals = [p[leg][field] for p in points if field in p.get(leg, {})]
    if not vals or min(vals) <= 0:
        return None
    return round(max(vals) / min(vals), 4)


def load_runs(path: str) -> dict:
    """Reader half of the merge-keyed format (used by tests + obs.gate)."""
    with open(path) as f:
        payload = json.load(f)
    if payload.get("benchmark") != "gst_memory":
        raise ValueError(f"{path} is not a gst_memory benchmark file")
    return payload.get("runs", {})


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI-sized sweep")
    ap.add_argument("--out",
                    default=os.path.join(_REPO, "BENCH_gst_memory.json"))
    ap.add_argument("--n-graphs", type=int, default=4)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--max-seg-nodes", type=int, default=32)
    ap.add_argument("--backbone", default="sage")
    ap.add_argument("--stream-chunk", type=int, default=4)
    args = ap.parse_args()
    comms = SWEEP_COMMS_QUICK if args.quick else SWEEP_COMMS
    kw = dict(n_graphs=args.n_graphs, max_seg_nodes=args.max_seg_nodes,
              hidden=args.hidden, batch_size=args.batch_size,
              backbone=args.backbone)

    print(f"{'comm':>5s} {'J':>4s} {'nodes':>7s} {'gst temp':>10s} "
          f"{'full temp':>10s} {'stream temp':>11s} {'chunks':>6s}")
    points, streaming = [], []
    for comm in comms:
        pt = bench_steps(comm, **kw)
        st = bench_streaming(comm, chunk=args.stream_chunk, **kw)
        points.append(pt)
        streaming.append(st)
        print(f"{comm:5d} {pt['j_max']:4d} {pt['nodes_mean']:7.1f} "
              f"{pt['gst'].get('temp_bytes', 0):10d} "
              f"{pt['full'].get('temp_bytes', 0):10d} "
              f"{st.get('temp_bytes', 0):11d} {st['n_chunks']:6d}",
              flush=True)
    ladder = bench_ladder(max_seg_nodes=args.max_seg_nodes,
                          hidden=args.hidden, backbone=args.backbone)

    summary = {
        # the gated claims; gate thresholds live in repro.obs.gate.  The
        # flatness claim is on TEMP (XLA activation/workspace) bytes: GST's
        # peak still carries one copy of the (n, J, d) historical table as
        # an argument, and that table is exactly what the tiered store caps
        # on device — the activations are what must not grow.
        "gst_temp_ratio_max_over_min": _ratio(points, "gst", "temp_bytes"),
        "full_temp_ratio_max_over_min": _ratio(points, "full", "temp_bytes"),
        "gst_peak_ratio_max_over_min": _ratio(points, "gst"),
        "full_peak_ratio_max_over_min": _ratio(points, "full"),
        "streaming_temp_ratio_max_over_min": (
            round(max(s["temp_bytes"] for s in streaming)
                  / max(min(s["temp_bytes"] for s in streaming), 1), 4)
            if all("temp_bytes" in s for s in streaming) else None),
        "streaming_bound_ok": all(
            s.get("temp_bytes", 0) >= s["accounting_bound_bytes"]
            for s in streaming),
        "ladder_total_peak_bytes": ladder["total_peak_bytes"],
    }
    print("summary:", json.dumps(summary))

    config = {
        "sweep_comms": list(comms), "n_graphs": args.n_graphs,
        "batch_size": args.batch_size, "hidden": args.hidden,
        "max_seg_nodes": args.max_seg_nodes, "backbone": args.backbone,
        "stream_chunk": args.stream_chunk, "quick": args.quick,
    }
    env = {
        "backend": jax.default_backend(),
        "jax": jax.__version__,
        "device_count": jax.device_count(),
    }
    entry = {"summary": summary, "config": config, "env": env,
             "gst_step": [{**{k: p[k] for k in ("comm", "j_max",
                                                "nodes_mean")}, **p["gst"]}
                          for p in points],
             "full_step": [{**{k: p[k] for k in ("comm", "j_max",
                                                 "nodes_mean")}, **p["full"]}
                           for p in points],
             "streaming": streaming,
             "ladder": ladder}
    # merge keyed like bench_step.py so configs accumulate, not clobber
    run_key = ",".join(f"{k}={v}" for k, v in sorted(config.items())) + \
        f",backend={env['backend']},jax={env['jax']}" + \
        f",device_count={env['device_count']}"
    payload = {"benchmark": "gst_memory", "unit": "bytes", "runs": {}}
    if os.path.exists(args.out):
        try:
            with open(args.out) as f:
                prev = json.load(f)
            if prev.get("benchmark") == "gst_memory" and \
                    isinstance(prev.get("runs"), dict):
                payload = prev
        except (json.JSONDecodeError, OSError):
            pass
    payload["runs"][run_key] = entry
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out} ({len(payload['runs'])} tracked run configs)")


if __name__ == "__main__":
    main()
