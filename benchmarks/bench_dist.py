"""Tracked distributed-GST benchmark — step time and table-exchange bytes
vs device count, exchange strategy, AND wire payload dtype, plus
async-vs-sync host-blocked milliseconds.

For each device count in {1, 2, 8} (intersected with what the host
exposes) it times the shard_map gst_efd train step once per exchange
strategy (ring | alltoall | bucketed, dist/exchange.py) per payload
dtype (f32 | bf16 | int8 — multi-device only; one shard never crosses
the wire so the codec pins f32 there), records each cell's analytic
bytes per step per device, and the strategy ``--exchange=auto`` would
pick at each dtype (the min-bytes one) — so both the ring-vs-owner-
direct crossover and the compressed-traffic saving (int8 ~0.3x f32)
are recorded numbers instead of ROADMAP guesses.  The feeder
comparison (sync vs async host-blocked ms on the SAME epoch trace)
runs once per device count through the f32 ring step.

Usage:
    PYTHONPATH=src python benchmarks/bench_dist.py            # full
    PYTHONPATH=src python benchmarks/bench_dist.py --quick    # CI-sized
    PYTHONPATH=src python benchmarks/bench_dist.py --exchange bucketed

Each cell is also timed through the lookahead prefetch lane
(``--prefetch-lookups``: next batch's lookup dispatched while the step
runs, write-back restored by the fused patch) and the summary gates
that prefetch-on step ms and host-blocked ms/batch are no worse than
inline (``--strict`` enforces, with --prefetch-tolerance slack for CPU
noise).

Forces an 8-device CPU host via XLA_FLAGS when run without one (set the
flag yourself to override).  Writes ``BENCH_gst_dist.json`` merge-keyed
by config+backend+device_kind+jax version, like BENCH_gst_step.json
(pre-device_kind keys are migrated as ``device_kind=cpu``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if os.path.isdir(os.path.join(_REPO, "src")) and \
        os.path.join(_REPO, "src") not in sys.path:
    sys.path.insert(0, os.path.join(_REPO, "src"))

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8").strip()

import jax
import jax.numpy as jnp
import numpy as np

from repro import dist as DT
from repro.core import gst as G
from repro.core.embedding_table import init_table
from repro.dist import exchange as EXC
from repro.dist import pipeline as DP
from repro.dist import table as dtbl
from repro.graphs import data as D
from repro.graphs.gnn import GNNConfig, gnn_init, make_encode_fn
from repro.obs import summarize
from repro.optim import make_optimizer

DEVICE_COUNTS = (1, 2, 8)
VARIANT = "gst_efd"          # the paper's complete method — the hot path
BACKBONE = "sage"
NUM_SAMPLED = 1              # S; feeds BOTH the step and the byte accounting


def _fresh_state(ds, hidden):
    cfg = GNNConfig(backbone=BACKBONE, n_feat=ds.x.shape[-1], hidden=hidden)
    enc = make_encode_fn(cfg)
    key = jax.random.key(0)
    bb = gnn_init(key, cfg)
    head = G.head_init(jax.random.fold_in(key, 1), hidden, 5, "mlp")
    opt = make_optimizer("adam", lr=1e-3)
    state = G.TrainState(bb, head, opt.init((bb, head)),
                         init_table(ds.n, ds.j_max, hidden),
                         jnp.zeros((), jnp.int32))
    return enc, opt, state


def _make_step(ds, ctx, *, hidden: int):
    """The gst_efd dist train step under ``ctx``'s exchange strategy as a
    stateful one(batch) closure, so the feeder comparison can reuse the
    compiled ring step."""
    enc, opt, state = _fresh_state(ds, hidden)
    step = DT.make_dist_train_step(enc, opt, G.VARIANTS[VARIANT], ctx=ctx,
                                   keep_prob=0.5, num_sampled=NUM_SAMPLED)
    state = DT.device_state(ctx, state)
    holder = {"state": state, "i": 0}

    def one(batch):
        holder["state"], m = step(holder["state"], batch,
                                  jax.random.PRNGKey(holder["i"]))
        holder["i"] += 1
        return m["loss"]

    return one, step, holder


def _make_prefetch_parts(ds, ctx, *, hidden: int):
    """Prefetch-mode twin of ``_make_step``: the prefetched train step,
    the lane's lookup collective, and a fresh state holder."""
    enc, opt, state = _fresh_state(ds, hidden)
    pstep = DT.make_dist_train_step(enc, opt, G.VARIANTS[VARIANT], ctx=ctx,
                                    keep_prob=0.5, num_sampled=NUM_SAMPLED)
    pf = DT.make_prefetch_lookup(ctx)
    state = DT.device_state(ctx, state)
    return pstep, pf, {"state": state, "i": 0}


def _time_prefetch_cell(ds, ctx, ids, *, hidden: int, n_iters: int,
                        warmup: int):
    """Steady-state prefetch step time on one repeated batch: each timed
    iteration dispatches the NEXT lookup then runs the step that consumes
    the previous one — the launcher's per-step host work.  Repeating one
    batch makes every row its own next-batch consumer (all-overlap), the
    adversarial maximum for the fused patch."""
    pstep, pf, holder = _make_prefetch_parts(ds, ctx, hidden=hidden)
    batch = DT.shard_batch(ctx, DP._assemble(ds, ids))
    bsh = DT.batch_sharding(ctx)
    ids_np = np.asarray(ids)
    dest = EXC.consumer_shards(ids_np, ids_np, num_shards=ctx.num_shards,
                               rows=ctx.table_rows)
    dest_dev = jax.device_put(np.asarray(dest, np.int32), bsh)
    ids_dev = batch.graph_ids
    pref = pf(holder["state"].table, ids_dev)
    times = []
    for it in range(warmup + n_iters):
        t0 = time.perf_counter()
        nxt = pf(holder["state"].table, ids_dev)
        holder["state"], m, pref = pstep(
            holder["state"], batch, jax.random.PRNGKey(holder["i"]),
            pref, nxt, ids_dev, dest_dev)
        holder["i"] += 1
        jax.block_until_ready(m["loss"])
        if it >= warmup:
            times.append((time.perf_counter() - t0) * 1e3)
    return summarize(times)


def _prefetch_feeder_ms(ds, sched, ctx, *, hidden: int):
    """Host-blocked ms/batch of the async feeder driven through the
    prefetch lane + prefetched step over the whole epoch trace — the
    prefetch-on twin of the sync/async feeder comparison."""
    pstep, pf, holder = _make_prefetch_parts(ds, ctx, hidden=hidden)
    bsh = DT.batch_sharding(ctx)
    sentinel = ctx.num_shards * ctx.table_rows
    put = lambda b: (np.asarray(b.graph_ids), DT.shard_batch(ctx, b))
    feeder = DP.make_feeder("async", ds, sched, put, depth=2)
    lane = DP.PrefetchLane(
        feeder, lambda item: pf(holder["state"].table, item[1].graph_ids))
    pref, m = None, None
    for (ids, batch), cur_h, nxt, nxt_h in lane:
        if pref is None:
            pref = cur_h
        if nxt is not None:
            next_ids, next_pair = nxt[1].graph_ids, nxt_h
            dest = EXC.consumer_shards(ids, nxt[0],
                                       num_shards=ctx.num_shards,
                                       rows=ctx.table_rows)
        else:
            B = ids.shape[0]
            next_ids = jax.device_put(np.full((B,), sentinel, np.int32), bsh)
            next_pair = (
                jax.device_put(np.zeros((B, ds.j_max, hidden), np.float32),
                               bsh),
                jax.device_put(np.zeros((B, ds.j_max), bool), bsh))
            dest = np.full((B,), ctx.num_shards, np.int32)
        dest_dev = jax.device_put(np.asarray(dest, np.int32), bsh)
        holder["state"], m, pref = pstep(
            holder["state"], batch, jax.random.PRNGKey(holder["i"]),
            pref, next_pair, next_ids, dest_dev)
        holder["i"] += 1
    jax.block_until_ready(m["loss"])
    return round(feeder.stats.host_blocked_ms_per_batch, 3)


def bench_device_count(ds, n_dev: int, *, batch_size: int, hidden: int,
                       n_iters: int, warmup: int = 2, exchange="all",
                       payload="all", prefetch=True):
    mesh = DT.make_dist_mesh(n_dev)
    # deterministic shuffled trace: unshuffled contiguous batches are the
    # all-rows-on-one-owner adversarial case, which would pin the bucketed
    # capacity at B_local and hide the owner-direct win
    sched = DP.epoch_ids(ds, batch_size, rng=np.random.default_rng(0))
    rows_per_shard = dtbl.rows_per_shard(ds.n, n_dev)
    cap = EXC.plan_capacity(sched, num_shards=n_dev, rows=rows_per_shard)
    b_local = batch_size // n_dev
    # one shard never crosses the wire: the codec pins f32 there, so the
    # dtype sweep only runs multi-device
    if n_dev <= 1:
        dtypes = ("f32",)
    elif payload == "all":
        dtypes = EXC.PAYLOAD_DTYPES
    else:
        dtypes = (payload,)
    # the auto pick uses the SAME planned cap the timed bucketed run gets,
    # so "--exchange auto" times exactly the strategy the row reports —
    # re-picked per dtype (compression shifts the crossover)
    auto = {dt: EXC.select_exchange(n_dev, b_local, ds.j_max, NUM_SAMPLED,
                                    hidden, cap=cap, payload_dtype=dt)
            for dt in dtypes}
    if exchange == "all":
        strategies = EXC.EXCHANGES
    elif exchange == "auto":
        strategies = tuple(dict.fromkeys(auto.values()))
    else:
        strategies = (exchange,)
    per_strategy = {}
    feeder_parts = None
    for name in strategies:
        per_strategy[name] = {}
        for dt in dtypes:
            ctx = DT.make_context(mesh, ds.n, exchange=name,
                                  exchange_cap=cap if name == "bucketed"
                                  else None, payload_dtype=dt)
            one, step, holder = _make_step(ds, ctx, hidden=hidden)
            put = lambda b: DT.shard_batch(ctx, b)
            batch = put(DP._assemble(ds, sched[0]))
            for _ in range(warmup):
                one(batch)
            times = []
            for _ in range(n_iters):
                t0 = time.perf_counter()
                jax.block_until_ready(one(batch))
                times.append((time.perf_counter() - t0) * 1e3)
            t = summarize(times)
            cell = {
                "train_ms": round(t["p50"], 3),
                "train_ms_p99": round(t["p99"], 3),
            }
            if prefetch:
                # the same cell through the lookahead lane: repeated
                # batch => all-overlap, so the patch hop is maximal
                pcap = EXC.required_patch_capacity(
                    sched[0], sched[0], num_shards=n_dev,
                    rows=rows_per_shard) if name == "bucketed" else None
                pctx = DT.make_context(mesh, ds.n, exchange=name,
                                       exchange_cap=cap
                                       if name == "bucketed" else None,
                                       payload_dtype=dt, prefetch=True,
                                       patch_cap=pcap)
                pt = _time_prefetch_cell(ds, pctx, sched[0], hidden=hidden,
                                         n_iters=n_iters, warmup=warmup)
                pex = EXC.make_exchange(name, axis_name=DT.AXIS,
                                        num_shards=n_dev,
                                        rows=pctx.table_rows,
                                        cap=pctx.exchange_cap,
                                        payload_dtype=dt, patch_cap=pcap)
                cell["prefetch"] = {
                    "train_ms": round(pt["p50"], 3),
                    "train_ms_p99": round(pt["p99"], 3),
                    "bytes_per_step_per_device":
                        pex.prefetch_train_step_bytes(
                            b_local, ds.j_max, NUM_SAMPLED, hidden,
                            use_table=True),
                }
            ex = EXC.make_exchange(name, axis_name=DT.AXIS,
                                   num_shards=n_dev, rows=ctx.table_rows,
                                   cap=ctx.exchange_cap, payload_dtype=dt)
            cell["bytes_per_step_per_device"] = ex.train_step_bytes(
                b_local, ds.j_max, NUM_SAMPLED, hidden, use_table=True)
            per_strategy[name][dt] = cell
            if feeder_parts is None or (name == "ring" and dt == "f32"):
                feeder_parts = (ctx, one, holder, put, name)

    # feeder comparison on the SAME trace (async must beat sync on
    # host-blocked ms — CI enforces it via --strict), through the ring
    # step when timed, else the first timed strategy (feeder timing is
    # about host work, not the exchange)
    feeder_rows = {}
    ctx, one, holder, put, feeder_strategy = feeder_parts
    for kind in ("sync", "async"):
        feeder = DP.make_feeder(kind, ds, sched, put, depth=2)
        m = None
        for b in feeder:
            m = one(b)
        jax.block_until_ready(m)
        feeder_rows[kind] = round(feeder.stats.host_blocked_ms_per_batch, 3)
    if prefetch:
        # prefetch-on leg of the same trace through the same strategy
        pcap = EXC.plan_patch_capacity(sched, num_shards=n_dev,
                                       rows=rows_per_shard) \
            if feeder_strategy == "bucketed" else None
        pctx = DT.make_context(mesh, ds.n, exchange=feeder_strategy,
                               exchange_cap=cap
                               if feeder_strategy == "bucketed" else None,
                               payload_dtype=ctx.payload_dtype,
                               prefetch=True, patch_cap=pcap)
        feeder_rows["prefetch"] = _prefetch_feeder_ms(ds, sched, pctx,
                                                      hidden=hidden)

    flat_name = "ring" if "ring" in per_strategy else \
        next(iter(per_strategy))
    flat_dt = "f32" if "f32" in per_strategy[flat_name] else \
        next(iter(per_strategy[flat_name]))
    return {
        "device_count": n_dev,
        "rows_per_shard": rows_per_shard,
        "bucket_cap": cap,
        # nested per-(strategy, payload dtype) cells since ISSUE 6
        "exchange": per_strategy,
        "payload_dtypes": list(dtypes),
        "auto_exchange": auto.get("f32", next(iter(auto.values()))),
        "auto_exchange_by_dtype": auto,
        # PR 3-era flat keys kept for trend continuity (the f32 ring
        # numbers when timed; flat_keys_strategy names the source otherwise)
        "flat_keys_strategy": flat_name,
        "train_ms": per_strategy[flat_name][flat_dt]["train_ms"],
        "exchange_bytes_per_step_per_device":
            per_strategy[flat_name][flat_dt]["bytes_per_step_per_device"],
        "host_blocked_ms_sync": feeder_rows["sync"],
        "host_blocked_ms_async": feeder_rows["async"],
        "host_blocked_ms_prefetch": feeder_rows.get("prefetch"),
    }


def _prefetch_step_totals(results):
    """(inline_total_ms, prefetch_total_ms) over the timed prefetch
    cells; (None, None) if none timed.  The strict gate compares TOTALS
    — same reasoning as async_beats_sync_total: individual quick cells
    on a shared CPU host bounce tens of percent either way, the sum
    across strategies x dtypes x device counts is the stable signal."""
    inline, pref = 0.0, 0.0
    n = 0
    for r in results:
        for by_dt in r["exchange"].values():
            for cell in by_dt.values():
                p = cell.get("prefetch")
                if p:
                    inline += cell["train_ms"]
                    pref += p["train_ms"]
                    n += 1
    return (inline, pref) if n else (None, None)


def _prefetch_step_no_worse_per_cell(results, *, tol_frac, tol_abs_ms):
    """True iff every timed prefetch cell's p50 step ms is no worse than
    its inline twin (within CPU-noise tolerance); None if none timed.
    Informative (WARNING) only — per-cell quick timings are too noisy to
    gate on, the strict gate uses the totals."""
    checks = []
    for r in results:
        for by_dt in r["exchange"].values():
            for cell in by_dt.values():
                p = cell.get("prefetch")
                if p:
                    checks.append(
                        p["train_ms"] <= cell["train_ms"] * (1 + tol_frac)
                        + tol_abs_ms)
    return all(checks) if checks else None


def _auto_is_min_bytes(results):
    checks = []
    for r in results:
        for dt, pick in r["auto_exchange_by_dtype"].items():
            if pick not in r["exchange"] or dt not in r["exchange"][pick]:
                continue
            cells = [by_dt[dt]["bytes_per_step_per_device"]
                     for by_dt in r["exchange"].values() if dt in by_dt]
            checks.append(
                r["exchange"][pick][dt]["bytes_per_step_per_device"]
                == min(cells))
    return all(checks) if checks else None


def _compression_ratios(results):
    big = max(results, key=lambda r: r["device_count"], default=None)
    if big is None or big["device_count"] <= 1:
        return None
    out = {}
    for name, by_dt in big["exchange"].items():
        if "int8" in by_dt and "f32" in by_dt:
            out[name] = round(
                by_dt["int8"]["bytes_per_step_per_device"]
                / by_dt["f32"]["bytes_per_step_per_device"], 4)
    return out or None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI-sized run")
    ap.add_argument("--strict", action="store_true",
                    help="exit nonzero unless the async pipeline beats the "
                         "synchronous feeder on total host-blocked ms")
    ap.add_argument("--exchange", default="all",
                    choices=["all", "ring", "alltoall", "bucketed", "auto"],
                    help="which table-exchange strategies to time: the "
                         "full matrix (default), one strategy, or the one "
                         "the auto policy picks")
    ap.add_argument("--payload-dtype", default="all",
                    choices=["all", "f32", "bf16", "int8"],
                    help="which wire payload dtypes to sweep per strategy "
                         "(multi-device rows only; one shard is always f32)")
    ap.add_argument("--prefetch", default="on", choices=["on", "off"],
                    help="also time every cell through the lookahead "
                         "prefetch lane (--prefetch-lookups) and record "
                         "the prefetch-vs-inline step/host-blocked gate")
    ap.add_argument("--prefetch-tolerance", type=float, default=0.25,
                    help="fractional slack for the prefetch-no-worse "
                         "gates (CPU timing noise; 0.25 = within 25%%)")
    ap.add_argument("--out", default=os.path.join(_REPO, "BENCH_gst_dist.json"))
    ap.add_argument("--n-graphs", type=int, default=None)
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--max-seg-nodes", type=int, default=32)
    args = ap.parse_args()
    n_graphs = args.n_graphs or (24 if args.quick else 48)
    n_iters = args.iters or (5 if args.quick else 20)

    graphs = D.make_malnet_like(n_graphs=n_graphs, seed=0)
    ds, spec = DP.segment_dataset_shared(graphs, args.max_seg_nodes, seed=0)

    counts = [c for c in DEVICE_COUNTS
              if c <= jax.device_count() and args.batch_size % c == 0]
    results = []
    print(f"{'devices':>7s} {'strategy':>9s} {'payload':>7s} "
          f"{'train ms':>9s} {'pref ms':>8s} {'xchg KiB':>9s} "
          f"{'sync ms':>8s} {'async ms':>9s}")
    for n_dev in counts:
        row = bench_device_count(ds, n_dev, batch_size=args.batch_size,
                                 hidden=args.hidden, n_iters=n_iters,
                                 exchange=args.exchange,
                                 payload=args.payload_dtype,
                                 prefetch=args.prefetch == "on")
        results.append(row)
        for name, by_dt in row["exchange"].items():
            for dt, r in by_dt.items():
                mark = (" <- auto"
                        if name == row["auto_exchange_by_dtype"].get(dt)
                        else "")
                pref_ms = (f"{r['prefetch']['train_ms']:8.2f}"
                           if "prefetch" in r else f"{'-':>8s}")
                print(f"{row['device_count']:7d} {name:>9s} {dt:>7s} "
                      f"{r['train_ms']:9.2f} {pref_ms} "
                      f"{r['bytes_per_step_per_device'] / 1024:9.1f} "
                      f"{row['host_blocked_ms_sync']:8.2f} "
                      f"{row['host_blocked_ms_async']:9.2f}{mark}",
                      flush=True)

    sync_total = sum(r["host_blocked_ms_sync"] for r in results)
    async_total = sum(r["host_blocked_ms_async"] for r in results)
    summary = {
        "variant": VARIANT,
        "backbone": BACKBONE,
        # per-count win AND the (less noise-prone) total used by --strict
        "async_beats_sync": all(
            r["host_blocked_ms_async"] < r["host_blocked_ms_sync"]
            for r in results),
        "async_beats_sync_total": async_total < sync_total,
        "host_blocked_ms_sync_total": round(sync_total, 3),
        "host_blocked_ms_async_total": round(async_total, 3),
        "max_devices": max((r["device_count"] for r in results), default=0),
        # the auto pick per device count, and whether it is indeed the
        # min-bytes strategy of the recorded rows AT EVERY SWEPT DTYPE
        # (the acceptance gate; None when no auto pick was among the
        # timed strategies)
        "auto_exchange": {str(r["device_count"]): r["auto_exchange"]
                          for r in results},
        "auto_is_min_bytes": _auto_is_min_bytes(results),
        # compressed-traffic acceptance: int8 / f32 analytic bytes per
        # strategy at the largest timed device count (None unless both
        # dtypes were swept there)
        "int8_over_f32_bytes": _compression_ratios(results),
    }
    pref_hb = [r for r in results
               if r.get("host_blocked_ms_prefetch") is not None]
    tol = args.prefetch_tolerance
    step_inline_total, step_pref_total = _prefetch_step_totals(results)
    hb_pref_total = round(
        sum(r["host_blocked_ms_prefetch"] for r in pref_hb), 3) \
        if pref_hb else None
    hb_async_total = round(
        sum(r["host_blocked_ms_async"] for r in pref_hb), 3) \
        if pref_hb else None
    summary.update({
        # prefetch acceptance: the lookahead lane must be no worse than
        # inline on TOTAL step ms across timed cells and TOTAL
        # host-blocked ms/batch (vs the async feeder on the same trace);
        # None when not timed.  Per-cell step comparisons stay in the
        # summary as a WARNING-only signal (quick cells are noisy).
        "prefetch_step_no_worse": (
            None if step_pref_total is None else
            step_pref_total <= step_inline_total * (1 + tol) + 0.25),
        "prefetch_step_ms_inline_total": (
            None if step_inline_total is None
            else round(step_inline_total, 3)),
        "prefetch_step_ms_total": (
            None if step_pref_total is None else round(step_pref_total, 3)),
        "prefetch_step_no_worse_per_cell": _prefetch_step_no_worse_per_cell(
            results, tol_frac=tol, tol_abs_ms=0.25),
        "prefetch_host_blocked_no_worse": (
            None if hb_pref_total is None else
            hb_pref_total <= hb_async_total * (1 + tol) + 0.25),
        "host_blocked_ms_prefetch_total": hb_pref_total,
    })
    config = {
        "n_graphs": n_graphs, "batch_size": args.batch_size,
        "hidden": args.hidden, "max_seg_nodes": args.max_seg_nodes,
        "bucket": spec.key, "j_max": ds.j_max, "e_max": ds.e_max,
        "iters": n_iters, "quick": args.quick, "exchange": args.exchange,
        "payload": args.payload_dtype, "prefetch": args.prefetch,
    }
    env = {
        "backend": jax.default_backend(),
        "jax": jax.__version__,
        "device_count": jax.device_count(),
        "device_kind": jax.devices()[0].device_kind,
        "pallas_interpret": jax.default_backend() != "tpu",
    }
    entry = {"summary": summary, "config": config, "env": env,
             "results": results}
    run_key = ",".join(f"{k}={v}" for k, v in sorted(config.items())) + \
        f",backend={env['backend']},device_kind={env['device_kind']}," \
        f"jax={env['jax']},device_count={env['device_count']}"
    payload = {"benchmark": "gst_dist", "unit": "ms_per_iter", "runs": {}}
    if os.path.exists(args.out):
        try:
            with open(args.out) as f:
                prev = json.load(f)
            if prev.get("benchmark") == "gst_dist" and \
                    isinstance(prev.get("runs"), dict):
                # pre-device_kind keys were all CPU-host runs: re-key them
                # under device_kind=cpu so the same config timed on a real
                # accelerator tracks as its own row instead of clobbering
                prev["runs"] = {
                    (k if "device_kind=" in k
                     else k.replace(",jax=", ",device_kind=cpu,jax=", 1)): v
                    for k, v in prev["runs"].items()}
                payload = prev
        except (json.JSONDecodeError, OSError):
            pass
    payload["runs"][run_key] = entry
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out} ({len(payload['runs'])} tracked run configs)")
    if not summary["async_beats_sync"]:
        print("WARNING: async pipeline did not beat the synchronous feeder "
              "on host-blocked ms for every device count", file=sys.stderr)
    if summary["prefetch_step_no_worse_per_cell"] is False:
        print("WARNING: prefetch-on step ms exceeded the inline step "
              "beyond tolerance on at least one timed cell (totals gate "
              "below is the authoritative check)", file=sys.stderr)
    if args.strict and not summary["async_beats_sync_total"]:
        print(f"STRICT: async total host-blocked ms ({async_total:.2f}) did "
              f"not beat sync ({sync_total:.2f})", file=sys.stderr)
        sys.exit(2)
    if args.strict and (summary["prefetch_step_no_worse"] is False or
                        summary["prefetch_host_blocked_no_worse"] is False):
        print("STRICT: the prefetch lane was worse than the inline "
              "exchange (total step ms "
              f"{summary['prefetch_step_ms_total']} vs inline "
              f"{summary['prefetch_step_ms_inline_total']}, or total "
              f"host-blocked ms {summary['host_blocked_ms_prefetch_total']} "
              f"vs async, beyond {args.prefetch_tolerance:.0%} tolerance)",
              file=sys.stderr)
        sys.exit(2)


if __name__ == "__main__":
    main()
