"""Tracked serving benchmark — the cache-effect anchor for the serve engine.

Replays the same duplicate-heavy synthetic traffic trace through the engine
twice — cross-request segment cache ON vs OFF (same params, same stream) —
and records p50/p99 latency, throughput, hit-rate, and encode-kernel launch
counts, plus a streaming-vs-one-shot parity probe.  The contract asserted
downstream (CI serve-smoke): the cached run achieves hit_rate > 0 and
launches FEWER encode kernels than the uncached run on a duplicate-heavy
trace.

Usage:
    PYTHONPATH=src python benchmarks/bench_serve.py           # full trace
    PYTHONPATH=src python benchmarks/bench_serve.py --quick   # CI-sized

Writes ``BENCH_gst_serve.json`` (repo root by default), merged by config key
so repeated runs on different backends/configs accumulate instead of
clobbering.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if os.path.isdir(os.path.join(_REPO, "src")) and \
        os.path.join(_REPO, "src") not in sys.path:
    sys.path.insert(0, os.path.join(_REPO, "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gst as G
from repro.graphs.gnn import encode_segments
from repro.obs import MetricsRegistry, set_registry
from repro.serve import ServeConfig, ServeEngine, TrafficConfig, make_request_stream
from repro.serve.engine import SEG_KEYS, graph_to_chunks


def run_trace(stream, *, backbone, use_pallas, cache_enabled, window,
              cache_capacity, seed, warmup):
    """warmup: None -> replay the FULL stream once first (steady-state
    measurement: all jit shapes compiled, then the cache is flushed and
    stats reset); int -> replay only that many requests (cold-ish)."""
    cfg = ServeConfig(backbone=backbone, use_pallas=use_pallas,
                      cache_enabled=cache_enabled, cache_capacity=cache_capacity)
    # one registry per leg so serve.prediction_staleness / serve.* counters
    # land in the BENCH entry without the legs bleeding into each other
    reg = MetricsRegistry()
    prev = set_registry(reg)
    try:
        engine = ServeEngine(cfg, seed=seed)
        warm = stream if warmup is None else stream[:warmup]
        if warm:
            engine.process(warm, window=window)
            engine.reset_stats()
            if engine.cache is not None:
                engine.cache.flush()  # cold contents, warm compile caches
        reg.reset()  # warmup encodes must not count in the leg's obs summary
        engine.process(stream, window=window)
        summary = engine.stats.summary()
        summary["obs"] = {k: v for k, v in reg.summary().items()
                          if k.startswith("serve.")}
    finally:
        set_registry(prev)
    return engine, summary


def streaming_parity(engine, graph) -> float:
    """max |streaming - one-shot| at identical bucket padding."""
    spec = engine.ladder[-1]
    ch = graph_to_chunks(graph, spec, engine.cfg.stream_chunk,
                         partition=engine.cfg.partition,
                         seed=engine.cfg.partition_seed)
    flat = {k: jnp.asarray(ch[k].reshape((-1,) + ch[k].shape[2:]))
            for k in SEG_KEYS}
    h = encode_segments(engine.params, engine.gnn_cfg, flat)
    w = jnp.asarray(ch["seg_valid"].reshape(-1))
    pooled = (h * w[:, None]).sum(0) / jnp.maximum(w.sum(), 1.0)
    ref = np.asarray(G.head_apply(engine.head, pooled, "mlp"))
    got = engine.predict_streaming(graph)
    return float(np.abs(got - ref).max())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI-sized run")
    ap.add_argument("--out", default=os.path.join(_REPO, "BENCH_gst_serve.json"))
    ap.add_argument("--backbone", default="sage")
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--unique", type=int, default=None)
    ap.add_argument("--duplicate-rate", type=float, default=0.6)
    ap.add_argument("--window", type=int, default=8)
    ap.add_argument("--cache-capacity", type=int, default=512)
    ap.add_argument("--warmup", type=int, default=None,
                    help="warmup requests; default: full-stream warmup "
                         "(steady-state numbers)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    n_requests = args.requests or (24 if args.quick else 96)
    n_unique = args.unique or (8 if args.quick else 32)

    tc = TrafficConfig(n_unique=n_unique, n_requests=n_requests,
                       duplicate_rate=args.duplicate_rate, seed=args.seed)
    stream = make_request_stream(tc)

    rows = {}
    for label, cache_enabled in (("cache_on", True), ("cache_off", False)):
        engine, summary = run_trace(
            stream, backbone=args.backbone, use_pallas=args.use_pallas,
            cache_enabled=cache_enabled, window=args.window,
            cache_capacity=args.cache_capacity, seed=args.seed,
            warmup=args.warmup)
        rows[label] = summary
        c = summary.get("cache") or {}
        print(f"{label:10s} p50 {summary['latency_p50_ms']:8.2f} ms  "
              f"p99 {summary['latency_p99_ms']:8.2f} ms  "
              f"launches {summary['encode_launches']:4d}  "
              f"encoded {summary['encoded_segments']:5d}  "
              f"hit-rate {c.get('hit_rate', 0.0):.2f}", flush=True)

    parity = streaming_parity(engine, stream[0])
    print(f"streaming parity: max diff {parity:.2e}")

    on, off = rows["cache_on"], rows["cache_off"]
    pred_stale = (on.get("obs") or {}).get("serve.prediction_staleness") or {}
    cache_effect = {
        "hit_rate": on["cache"]["hit_rate"],
        # age (cache steps) of the rows served predictions actually read —
        # nonzero count iff the cache really served stale rows
        "prediction_staleness": pred_stale,
        "encode_launches_on": on["encode_launches"],
        "encode_launches_off": off["encode_launches"],
        "encoded_segments_on": on["encoded_segments"],
        "encoded_segments_off": off["encoded_segments"],
        "launch_ratio_on_over_off":
            round(on["encode_launches"] / max(off["encode_launches"], 1), 3),
    }

    config = {
        "backbone": args.backbone, "use_pallas": args.use_pallas,
        "n_requests": n_requests, "n_unique": n_unique,
        "duplicate_rate": args.duplicate_rate, "window": args.window,
        "cache_capacity": args.cache_capacity, "warmup": args.warmup,
        "seed": args.seed, "quick": args.quick,
    }
    env = {
        "backend": jax.default_backend(),
        "jax": jax.__version__,
        "pallas_interpret": jax.default_backend() != "tpu",
    }
    run_key = ",".join(f"{k}={v}" for k, v in sorted(config.items())) + \
        f",backend={env['backend']}"
    entry = {
        "config": config, "env": env, "runs": rows,
        "cache_effect": cache_effect,
        "streaming_parity_max_abs_diff": parity,
    }

    payload = {"benchmark": "gst_serve", "unit": "ms_per_request", "runs": {}}
    if os.path.exists(args.out):
        try:
            with open(args.out) as f:
                prev = json.load(f)
            if prev.get("benchmark") == "gst_serve" and isinstance(
                    prev.get("runs"), dict):
                payload = prev
        except (json.JSONDecodeError, OSError):
            pass
    # contract gates BEFORE the write: a failing run must not pollute the
    # tracked benchmark file / CI artifact
    assert cache_effect["hit_rate"] > 0, "duplicate-heavy trace must hit the cache"
    assert cache_effect["encode_launches_on"] < cache_effect["encode_launches_off"], \
        "cache must save encode launches on a duplicate-heavy trace"
    assert pred_stale.get("count", 0) > 0, \
        "cached leg must serve predictions from previously-cached rows " \
        "(serve.prediction_staleness never observed)"

    payload["runs"][run_key] = entry
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
