"""Splice generated tables into EXPERIMENTS.md at the HTML-comment markers.

    PYTHONPATH=src:. python -m benchmarks.splice_experiments
"""
import io
import re
import sys
from contextlib import redirect_stdout

from benchmarks.render_experiments import load, roofline_table


def main():
    path = "EXPERIMENTS.md"
    with open(path) as f:
        text = f.read()
    results = load(".scratch/roofline_unrolled.json")
    table = roofline_table(results)
    marker = "<!-- ROOFLINE_TABLE -->"
    if marker in text:
        text = text.replace(marker, table + "\n\n" + marker, 1)
    with open(path, "w") as f:
        f.write(text)
    print(f"spliced roofline table ({len(results)} results)")


if __name__ == "__main__":
    main()
