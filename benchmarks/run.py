"""Benchmark harness — one function per paper table/figure (deliverable d).

Usage:
    PYTHONPATH=src python -m benchmarks.run              # full suite
    PYTHONPATH=src python -m benchmarks.run --quick      # CI-sized
    PYTHONPATH=src python -m benchmarks.run --only table1,table3

Prints ``name,value,derived`` CSV rows per benchmark.
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args()

    from benchmarks.paper_tables import ALL_BENCHES
    names = list(ALL_BENCHES) if not args.only else args.only.split(",")
    print("name,value,derived")
    t0 = time.time()
    for name in names:
        if name not in ALL_BENCHES:
            print(f"unknown benchmark {name!r}; have {list(ALL_BENCHES)}",
                  file=sys.stderr)
            continue
        t1 = time.time()
        ALL_BENCHES[name](quick=args.quick)
        print(f"# {name} done in {time.time()-t1:.0f}s", flush=True)
    print(f"# total {time.time()-t0:.0f}s")


if __name__ == '__main__':
    main()
