"""Render EXPERIMENTS.md tables from the dry-run/roofline JSON artifacts.

    PYTHONPATH=src python -m benchmarks.render_experiments \
        --single .scratch/dryrun_single.json --multi .scratch/dryrun_multi.json \
        --roofline .scratch/roofline_unrolled.json
"""
import argparse
import json
import os


def load(path):
    if not path or not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def fmt_bytes(b):
    if b >= 1e9:
        return f"{b/1e9:.2f} GB"
    if b >= 1e6:
        return f"{b/1e6:.1f} MB"
    return f"{b/1e3:.0f} KB"


def dryrun_table(results, mesh_label):
    lines = [
        f"| arch | shape | status | lower+compile (s) | args/dev | temp/dev | collective ops |",
        f"|---|---|---|---|---|---|---|",
    ]
    for r in results:
        if "skip" in r.get("status", ""):
            lines.append(f"| {r['arch']} | {r['shape']} | SKIP (DESIGN.md §Skips) | — | — | — | — |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['status']} | — | — | — | — |")
            continue
        ma = r.get("memory_analysis", {})
        ops = r.get("collective_op_counts", {})
        opstr = " ".join(f"{k.split('-')[-1] if k!='all-to-all' else 'a2a'}:{v}"
                         for k, v in ops.items() if v)
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | "
            f"{r.get('lower_s', 0)}+{r.get('compile_s', 0)} | "
            f"{fmt_bytes(ma.get('argument_size_in_bytes', 0))} | "
            f"{fmt_bytes(ma.get('temp_size_in_bytes', 0))} | {opstr or '-'} |")
    return "\n".join(lines)


def roofline_table(results):
    lines = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | dominant |"
        " MODEL_FLOPs | useful ratio | what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    suggestions = {
        ("moe", "train"): "gather-based MoE dispatch removes the O(T·E·C·d) one-hot einsums",
        ("moe", "prefill"): "chunked attention + gather dispatch cut logits/dispatch traffic",
        ("moe", "decode"): "dus cache write + absorbed MLA decode cut cache passes",
        ("dense", "train"): "chunked attention removes the S² logits materialization",
        ("dense", "prefill"): "chunked (flash) attention; shard KV heads when divisible",
        ("dense", "decode"): "dus cache write (1 pass vs 3 over the cache)",
        ("ssm", "train"): "chunked RWKV recurrence (matmul form) lifts MXU utilization",
        ("ssm", "decode"): "state is O(1); reduce collective by replicating small states",
        ("hybrid", "train"): "SSD chunk matmuls already MXU-shaped; fuse conv+gate",
        ("audio", "train"): "encoder segments are independent — batch-parallel only",
        ("vlm", "prefill"): "chunked attention; M-RoPE tables precomputed",
    }
    for r in results:
        if r.get("status") != "ok":
            continue
        t = r["terms_seconds"]
        fam = {"arctic-480b": "moe", "deepseek-v3-671b": "moe",
               "internlm2-1.8b": "dense", "internlm2-20b": "dense",
               "deepseek-coder-33b": "dense", "olmo-1b": "dense",
               "rwkv6-7b": "ssm", "zamba2-1.2b": "hybrid",
               "whisper-large-v3": "audio", "qwen2-vl-7b": "vlm"}[r["arch"]]
        kind = ("train" if r["shape"].startswith("train") else
                "prefill" if r["shape"].startswith("prefill") else "decode")
        sug = suggestions.get((fam, kind)) or suggestions.get((fam, "train"), "")
        mf = r.get("model_flops", 0)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute']:.2e} | "
            f"{t['memory']:.2e} | {t['collective']:.2e} | **{r['dominant']}** | "
            f"{mf:.2e} | {r.get('useful_flops_ratio', 0):.3f} | {sug} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--single", default=".scratch/dryrun_single.json")
    ap.add_argument("--multi", default=".scratch/dryrun_multi.json")
    ap.add_argument("--roofline", default=".scratch/roofline_unrolled.json")
    ap.add_argument("--section", default="all",
                    choices=["all", "dryrun", "roofline"])
    args = ap.parse_args()
    if args.section in ("all", "dryrun"):
        print("### Single-pod mesh (16, 16) — 256 chips\n")
        print(dryrun_table(load(args.single), "single"))
        print("\n### Multi-pod mesh (2, 16, 16) — 512 chips\n")
        print(dryrun_table(load(args.multi), "multi"))
    if args.section in ("all", "roofline"):
        print("\n### Roofline (single-pod, unrolled accounting)\n")
        print(roofline_table(load(args.roofline)))


if __name__ == "__main__":
    main()
