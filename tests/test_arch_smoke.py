"""Per-architecture smoke tests (deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED variant
(2 layers, d_model<=512, <=4 experts — configs.base.reduced) and run
  * one forward pass  (shape + finite check),
  * one GST train step (the paper technique; loss finite, params updated),
  * one decode step    (shape + finite check; skipped for encoder-only: none here).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.core import gst as G
from repro.core.embedding_table import init_table
from repro.models import build_model
from repro.optim import make_optimizer


def _inputs_for(cfg, B, S, rng):
    inputs = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        inputs["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.vision_prefix_len, cfg.d_model)), jnp.float32)
    if cfg.is_encoder_decoder:
        inputs["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq_len, cfg.d_model)), jnp.float32)
    return inputs


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    B, S = 2, 32
    h = model.forward(params, _inputs_for(cfg, B, S, rng))
    assert h.shape == (B, S, cfg.d_model)
    logits = model.logits(params, h)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_gst_train_step(arch):
    """One GST+EFD step on the reduced config: loss finite, params move."""
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    rng = np.random.default_rng(1)
    B, J, L = 2, 4, 16
    params = model.init(jax.random.key(0))
    head = G.head_init(jax.random.key(1), cfg.d_model, cfg.gst_num_classes, "mlp")
    opt = make_optimizer("adamw", lr=1e-3)
    state = G.TrainState(params, head, opt.init((params, head)),
                         init_table(8, J, cfg.d_model), jnp.zeros((), jnp.int32))

    if cfg.is_encoder_decoder:
        seg_inputs = {"frames": jnp.asarray(
            rng.normal(size=(B, J, L, cfg.d_model)), jnp.float32)}
    else:
        seg_inputs = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, J, L)), jnp.int32)}
        if cfg.family == "vlm":
            seg_inputs["patches"] = jnp.asarray(
                rng.normal(size=(B, J, cfg.vision_prefix_len, cfg.d_model)),
                jnp.float32)
    batch = G.GSTBatch(seg_inputs, jnp.ones((B, J), jnp.float32),
                       jnp.arange(B, dtype=jnp.int32),
                       jnp.asarray(rng.integers(0, cfg.gst_num_classes, B), jnp.int32))

    def encode(bb, seg):
        return model.encode_segment(bb, seg)

    step = jax.jit(G.make_train_step(encode, opt, G.VARIANTS["gst_efd"]))
    new_state, metrics = step(state, batch, jax.random.key(2))
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: loss not finite"
    # at least one leaf moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state.backbone, new_state.backbone)
    assert max(jax.tree_util.tree_leaves(moved)) > 0, f"{arch}: params frozen"
    # the sampled segments' table rows were refreshed
    assert bool(new_state.table.initialized.any()), f"{arch}: table not updated"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    rng = np.random.default_rng(2)
    B, C = 2, 16
    params = model.init(jax.random.key(0))
    caches = model.init_cache(B, C, jnp.float32)
    if cfg.is_encoder_decoder:
        from repro.models import encdec
        frames = jnp.asarray(rng.normal(size=(B, cfg.encoder_seq_len, cfg.d_model)),
                             jnp.float32)
        enc_out = encdec.encode(params, cfg, frames)
        caches = {"self": caches, "cross": encdec.cross_kv(params, cfg, enc_out)}
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
    logits, new_caches = model.decode_step(
        params, tok, caches, jnp.zeros((B,), jnp.int32))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: decode logits not finite"
