"""Distributed GST subsystem (src/repro/dist/).

Contract under test (ISSUE 3):
  * ring lookup / write-back over the row-sharded table ≡ the dense
    single-device table ops, BIT-exact (pure row selection, no reductions)
  * shard_map train/refresh/finetune steps for ALL SEVEN variants track the
    single-device oracle over >= 5 steps: identical segment sampling and
    table bookkeeping, params/losses equal up to cross-shard reduction
    order (bitwise at 1 shard, <= a few ulps at 8)
  * the async double-buffered feeder delivers the exact same batches as
    the synchronous feeder on the same trace, and surfaces producer errors
  * train-side padding comes from the serve bucket ladder, so a segment's
    serving-cache fingerprint is identical when padded by either side

Runs at whatever device count the host exposes: tier-1 sees 1 device
(degenerate mesh, bitwise parity); the CI dist-smoke job re-runs this file
under XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import dist as DT
from repro.core import embedding_table as tbl
from repro.core import gst as G
from repro.core.embedding_table import init_table
from repro.dist import pipeline as DP
from repro.dist import table as dtbl
from repro.graphs import data as D
from repro.graphs.gnn import GNNConfig, gnn_init, make_encode_fn
from repro.optim import make_optimizer
from repro.serve.buckets import default_ladder, pad_to_bucket, segment_fingerprint

N_DEV = jax.device_count()
SHARD_COUNTS = [d for d in (1, 2, 4, 8) if d <= N_DEV]
HID = 8


def _tree_max_diff(a, b):
    diffs = jax.tree_util.tree_map(
        lambda x, y: float(np.max(np.abs(np.asarray(x) - np.asarray(y)))), a, b)
    return max(jax.tree_util.tree_leaves(diffs), default=0.0)


def _tree_bitwise(a, b):
    eq = jax.tree_util.tree_map(
        lambda x, y: bool((np.asarray(x) == np.asarray(y)).all()), a, b)
    return all(jax.tree_util.tree_leaves(eq))


@pytest.fixture(scope="module")
def dataset():
    graphs = D.make_malnet_like(n_graphs=16, seed=0)
    ds, spec = DP.segment_dataset_shared(graphs, 16, seed=0)
    return ds


def _state(ds, head_out=5):
    cfg = GNNConfig(backbone="sage", n_feat=ds.x.shape[-1], hidden=HID)
    enc = make_encode_fn(cfg)
    key = jax.random.key(0)
    bb = gnn_init(key, cfg)
    head = G.head_init(jax.random.fold_in(key, 1), HID, head_out, "mlp")
    opt = make_optimizer("adam", lr=5e-3)
    return enc, opt, G.TrainState(bb, head, opt.init((bb, head)),
                                  init_table(ds.n, ds.j_max, HID),
                                  jnp.zeros((), jnp.int32))


def _batch(ds, ids):
    return jax.tree_util.tree_map(jnp.asarray, DP._assemble(ds, ids))


# ---------------------------------------------------------------------------
# sharded table: ring ops ≡ dense ops, bit-exact
# ---------------------------------------------------------------------------


def _random_table(n, J, d, seed=0):
    rng = np.random.default_rng(seed)
    return tbl.EmbeddingTable(
        emb=jnp.asarray(rng.normal(size=(n, J, d)), jnp.float32),
        age=jnp.asarray(rng.integers(0, 9, (n, J)), jnp.int32),
        initialized=jnp.asarray(rng.integers(0, 2, (n, J)), bool))


@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_ring_lookup_bit_exact(n_shards):
    from functools import partial

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    n, J, d, B = 21, 3, 4, 8  # n deliberately not divisible by the shards
    table = _random_table(n, J, d)
    ids = jnp.asarray(np.random.default_rng(1).permutation(n)[:B], jnp.int32)
    ctx = DT.make_context(DT.make_dist_mesh(n_shards), n)
    dev = DT.device_table(ctx, table)
    f = shard_map(
        partial(dtbl.ring_lookup, axis_name=DT.AXIS,
                num_shards=ctx.num_shards, rows=ctx.rows_per_shard),
        mesh=ctx.mesh,
        in_specs=(tbl.EmbeddingTable(P(DT.AXIS), P(DT.AXIS), P(DT.AXIS)),
                  P(DT.AXIS)),
        out_specs=(P(DT.AXIS), P(DT.AXIS)), check_rep=False)
    emb_d, init_d = jax.jit(f)(dev, jax.device_put(
        ids, DT.batch_sharding(ctx)))
    emb, init = tbl.lookup(table, ids)
    assert (np.asarray(emb_d) == np.asarray(emb)).all()
    assert (np.asarray(init_d) == np.asarray(init)).all()


@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_ring_update_sampled_bit_exact(n_shards):
    from functools import partial

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    n, J, d, B, S = 21, 3, 4, 8, 2
    rng = np.random.default_rng(2)
    table = _random_table(n, J, d)
    ids = jnp.asarray(rng.permutation(n)[:B], jnp.int32)
    sidx = jnp.asarray(rng.integers(0, J, (B, S)), jnp.int32)
    h = jnp.asarray(rng.normal(size=(B, S, d)), jnp.float32)
    step = jnp.asarray(7, jnp.int32)
    ctx = DT.make_context(DT.make_dist_mesh(n_shards), n)
    tspec = tbl.EmbeddingTable(P(DT.AXIS), P(DT.AXIS), P(DT.AXIS))
    f = shard_map(
        partial(dtbl.ring_update_sampled, axis_name=DT.AXIS,
                num_shards=ctx.num_shards, rows=ctx.rows_per_shard),
        mesh=ctx.mesh,
        in_specs=(tspec, P(DT.AXIS), P(DT.AXIS), P(DT.AXIS), P()),
        out_specs=tspec, check_rep=False)
    bsh = DT.batch_sharding(ctx)
    got = jax.jit(f)(DT.device_table(ctx, table), jax.device_put(ids, bsh),
                     jax.device_put(sidx, bsh), jax.device_put(h, bsh), step)
    want = tbl.update_sampled(table, ids, sidx, h, step)
    got = DT.host_table(ctx, got)
    assert (np.asarray(got.emb) == np.asarray(want.emb)).all()
    assert (np.asarray(got.age) == np.asarray(want.age)).all()
    assert (np.asarray(got.initialized) == np.asarray(want.initialized)).all()


def test_exchange_bytes_accounting():
    assert dtbl.lookup_exchange_bytes(1, 8, 4, 16) == 0
    assert dtbl.update_sampled_exchange_bytes(1, 8, 1, 16) == 0
    # lookup: D hops of the (ids, emb, init) buffer (answers must come home)
    assert dtbl.lookup_exchange_bytes(4, 2, 3, 8) == \
        4 * 2 * (4 + 3 * 8 * 4 + 3)
    # writes: D-1 hops of the (ids, seg_idx, h_new) buffer (no homecoming)
    assert dtbl.update_sampled_exchange_bytes(4, 2, 1, 8) == \
        3 * 2 * (4 + 4 + 8 * 4)
    assert dtbl.train_step_exchange_bytes(4, 2, 3, 1, 8, use_table=False) == 0
    assert dtbl.train_step_exchange_bytes(4, 2, 3, 1, 8, use_table=True) == \
        dtbl.lookup_exchange_bytes(4, 2, 3, 8) + \
        dtbl.update_sampled_exchange_bytes(4, 2, 1, 8)


# ---------------------------------------------------------------------------
# train step: dist ≡ single-device oracle, all seven variants, 5 steps
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", list(G.VARIANTS))
def test_train_step_parity_all_variants(dataset, variant):
    ds = dataset
    n_shards = SHARD_COUNTS[-1]
    enc, opt, state0 = _state(ds)
    batch = _batch(ds, DP.epoch_ids(ds, 8, rng=np.random.default_rng(0),
                                    shuffle=False)[0])
    rng = jax.random.PRNGKey(3)
    var = G.VARIANTS[variant]

    oracle = jax.jit(G.make_train_step(enc, opt, var, keep_prob=0.5))
    s1 = state0
    for _ in range(5):
        s1, m1 = oracle(s1, batch, rng)

    ctx = DT.make_context(DT.make_dist_mesh(n_shards), ds.n)
    dstep = DT.make_dist_train_step(enc, opt, var, ctx=ctx, keep_prob=0.5,
                                    donate=False)
    s2 = DT.device_state(ctx, state0)
    b2 = DT.shard_batch(ctx, batch)
    for _ in range(5):
        s2, m2 = dstep(s2, b2, rng)

    t2 = DT.host_table(ctx, s2.table)
    # bookkeeping is pure row selection — identical segment sampling means
    # identical ages and init flags, bit for bit
    assert (np.asarray(s1.table.age) == np.asarray(t2.age)).all()
    assert (np.asarray(s1.table.initialized) ==
            np.asarray(t2.initialized)).all()
    tol = 0.0 if ctx.num_shards == 1 else 1e-5
    assert _tree_max_diff(s1.table.emb, t2.emb) <= tol
    assert _tree_max_diff((s1.backbone, s1.head),
                          jax.device_get((s2.backbone, s2.head))) <= tol
    assert abs(float(m1["loss"]) - float(m2["loss"])) <= tol
    if ctx.num_shards == 1:  # degenerate mesh: the whole step is bitwise
        assert _tree_bitwise((s1.backbone, s1.head),
                             jax.device_get((s2.backbone, s2.head)))


def test_refresh_step_bit_exact(dataset):
    ds = dataset
    enc, opt, state0 = _state(ds)
    batch = _batch(ds, DP.epoch_ids(ds, 8, rng=np.random.default_rng(0),
                                    shuffle=False)[0])
    s1 = jax.jit(G.make_refresh_step(enc))(state0, batch)
    ctx = DT.make_context(DT.make_dist_mesh(SHARD_COUNTS[-1]), ds.n)
    s2 = DT.make_dist_refresh_step(enc, ctx=ctx, donate=False)(
        DT.device_state(ctx, state0), DT.shard_batch(ctx, batch))
    t2 = DT.host_table(ctx, s2.table)
    # refresh is encode + row writes, no cross-row reductions: bit-exact
    assert (np.asarray(s1.table.emb) == np.asarray(t2.emb)).all()
    assert (np.asarray(s1.table.initialized) ==
            np.asarray(t2.initialized)).all()


def test_finetune_step_parity(dataset):
    ds = dataset
    enc, opt, state0 = _state(ds)
    batch = _batch(ds, DP.epoch_ids(ds, 8, rng=np.random.default_rng(0),
                                    shuffle=False)[0])
    state0 = jax.jit(G.make_refresh_step(enc))(state0, batch)
    ft_opt = make_optimizer("adam", lr=1e-3)
    s1 = state0._replace(opt_state=ft_opt.init(state0.head))
    step1 = jax.jit(G.make_finetune_step(ft_opt))
    for _ in range(5):
        s1, m1 = step1(s1, batch)

    ctx = DT.make_context(DT.make_dist_mesh(SHARD_COUNTS[-1]), ds.n)
    s2 = DT.device_state(ctx, state0._replace(
        opt_state=ft_opt.init(state0.head)))
    step2 = DT.make_dist_finetune_step(ft_opt, ctx=ctx, donate=False)
    b2 = DT.shard_batch(ctx, batch)
    for _ in range(5):
        s2, m2 = step2(s2, b2)
    tol = 0.0 if ctx.num_shards == 1 else 1e-5
    assert _tree_max_diff(s1.head, jax.device_get(s2.head)) <= tol
    assert abs(float(m1["loss"]) - float(m2["loss"])) <= tol


def test_eval_step_parity(dataset):
    ds = dataset
    enc, opt, state0 = _state(ds)
    batch = _batch(ds, DP.epoch_ids(ds, 8, rng=np.random.default_rng(0),
                                    shuffle=False)[0])
    m1 = jax.jit(G.make_eval_step(enc))(state0, batch)
    ctx = DT.make_context(DT.make_dist_mesh(SHARD_COUNTS[-1]), ds.n)
    m2 = DT.make_dist_eval_step(enc, ctx=ctx)(
        DT.device_state(ctx, state0), DT.shard_batch(ctx, batch))
    tol = 0.0 if ctx.num_shards == 1 else 1e-5
    assert abs(float(m1["loss"]) - float(m2["loss"])) <= tol


def test_donated_dist_step_frees_input_table(dataset):
    ds = dataset
    enc, opt, state0 = _state(ds)
    batch = _batch(ds, DP.epoch_ids(ds, 8, rng=np.random.default_rng(0),
                                    shuffle=False)[0])
    ctx = DT.make_context(DT.make_dist_mesh(SHARD_COUNTS[-1]), ds.n)
    step = DT.make_dist_train_step(enc, opt, G.VARIANTS["gst_efd"], ctx=ctx,
                                   keep_prob=0.5)  # donate=True default
    state = DT.device_state(ctx, state0)
    emb0 = state.table.emb
    state, _ = step(state, DT.shard_batch(ctx, batch), jax.random.PRNGKey(0))
    if not emb0.is_deleted():
        pytest.skip("backend does not implement input-output aliasing")
    assert state.table.emb.shape == emb0.shape  # scatter landed in place


def test_dist_step_kernel_launch_contract(dataset):
    """The batched Pallas kernels run per-shard UNCHANGED: the dist step's
    jaxpr (counted through the shard_map sub-jaxpr) contains exactly the
    same number of pallas_call launches as the single-device step — data
    parallelism adds collectives, never extra kernel launches."""
    from repro.kernels.ops import count_pallas_calls

    ds = dataset
    cfg = GNNConfig(backbone="sage", n_feat=ds.x.shape[-1], hidden=HID,
                    use_pallas=True)
    enc = make_encode_fn(cfg)
    key = jax.random.key(0)
    bb = gnn_init(key, cfg)
    head = G.head_init(jax.random.fold_in(key, 1), HID, 5, "mlp")
    opt = make_optimizer("adam", lr=5e-3)
    state = G.TrainState(bb, head, opt.init((bb, head)),
                         init_table(ds.n, ds.j_max, HID),
                         jnp.zeros((), jnp.int32))
    batch = _batch(ds, DP.epoch_ids(ds, 8, rng=np.random.default_rng(0),
                                    shuffle=False)[0])
    var = G.VARIANTS["gst_efd"]
    sstep = jax.jit(G.make_train_step(enc, opt, var, keep_prob=0.5,
                                      use_pallas=True))
    n_single = count_pallas_calls(
        lambda s, b: sstep(s, b, jax.random.PRNGKey(0)), state, batch)

    ctx = DT.make_context(DT.make_dist_mesh(SHARD_COUNTS[-1]), ds.n)
    dstep = DT.make_dist_train_step(enc, opt, var, ctx=ctx, keep_prob=0.5,
                                    use_pallas=True, donate=False)
    sd = DT.device_state(ctx, state)
    bd = DT.shard_batch(ctx, batch)
    n_dist = count_pallas_calls(
        lambda s, b: dstep(s, b, jax.random.PRNGKey(0)), sd, bd)
    assert n_single > 0
    assert n_dist == n_single


def test_batch_size_must_divide_shards(dataset):
    ds = dataset
    ctx = DT.make_context(DT.make_dist_mesh(SHARD_COUNTS[-1]), ds.n)
    if ctx.num_shards == 1:
        pytest.skip("any batch divides one shard")
    batch = _batch(ds, np.arange(ctx.num_shards + 1))
    with pytest.raises(ValueError, match="must divide"):
        DT.shard_batch(ctx, batch)


# ---------------------------------------------------------------------------
# async host→device pipeline
# ---------------------------------------------------------------------------


def _put_identity(b):
    return jax.tree_util.tree_map(jnp.asarray, b)


def test_async_feeder_delivers_sync_trace(dataset):
    ds = dataset
    sched = DP.epoch_ids(ds, 4, rng=np.random.default_rng(5))
    sync = list(DP.make_feeder("sync", ds, sched, _put_identity))
    asyn = list(DP.make_feeder("async", ds, sched, _put_identity, depth=2))
    assert len(sync) == len(asyn) == len(sched)
    for b1, b2 in zip(sync, asyn):
        assert _tree_bitwise(b1, b2)
        assert b1.batch_pos is not None  # per-row RNG positions travel along


def test_feeder_stats_populated(dataset):
    ds = dataset
    sched = DP.epoch_ids(ds, 4, rng=np.random.default_rng(5), shuffle=False)
    feeder = DP.make_feeder("async", ds, sched, _put_identity)
    n = sum(1 for _ in feeder)
    assert feeder.stats.batches == n == len(sched)
    assert len(feeder.stats.blocked_per_batch) == n
    assert feeder.stats.host_blocked_ms >= 0.0


def test_async_feeder_shuts_down_when_abandoned(dataset):
    """Breaking out of the consumer loop mid-epoch must stop the producer
    thread (no forever-blocked daemon pinning device batches)."""
    ds = dataset
    sched = DP.epoch_ids(ds, 4, rng=np.random.default_rng(5))
    feeder = DP.make_feeder("async", ds, sched, _put_identity, depth=1)
    it = iter(feeder)
    next(it)
    it.close()  # what an exception in the consumer's for-loop triggers
    feeder._thread.join(timeout=5.0)
    assert not feeder._thread.is_alive()


def test_async_feeder_is_single_shot(dataset):
    ds = dataset
    sched = DP.epoch_ids(ds, 4, rng=np.random.default_rng(5))
    feeder = DP.make_feeder("async", ds, sched, _put_identity)
    assert sum(1 for _ in feeder) == len(sched)
    with pytest.raises(RuntimeError, match="single-shot"):
        next(iter(feeder))  # would otherwise hang on the drained queue


def test_async_feeder_propagates_producer_errors(dataset):
    ds = dataset
    sched = DP.epoch_ids(ds, 4, rng=np.random.default_rng(5))

    def bad_put(b):
        raise RuntimeError("device_put exploded")

    with pytest.raises(RuntimeError, match="device_put exploded"):
        list(DP.make_feeder("async", ds, sched, bad_put))


def test_epoch_ids_drop_last_and_determinism(dataset):
    ds = dataset
    a = DP.epoch_ids(ds, 8, rng=np.random.default_rng(9))
    b = DP.epoch_ids(ds, 8, rng=np.random.default_rng(9))
    assert all((x == y).all() for x, y in zip(a, b))
    assert all(len(x) == 8 for x in a)


# ---------------------------------------------------------------------------
# shared train/serve padding policy
# ---------------------------------------------------------------------------


def test_train_padding_comes_from_serve_ladder():
    graphs = D.make_malnet_like(n_graphs=4, seed=1)
    ds, spec = DP.segment_dataset_shared(graphs, 32, seed=1)
    ladder = default_ladder(32)
    assert spec in ladder
    assert ds.m_max == spec.m_max and ds.e_max == spec.e_max


def test_segment_fingerprint_matches_across_train_and_serve():
    """Same-rung invariant: a segment padded by the training pipeline to
    the shared bucket spec is byte-identical (same fingerprint) to that
    segment padded by the serving side FOR THE SAME RUNG.  Serving routes
    smaller segments to smaller rungs — those get their own addresses, by
    design (training uses one static shape)."""
    graphs = D.make_malnet_like(n_graphs=2, seed=2)
    g = graphs[0]
    _, spec = DP.segment_dataset_shared(graphs, 32, seed=2)
    node_ids = np.arange(min(10, len(g.x)), dtype=np.int32)
    from repro.graphs.batching import pad_segment
    x, e, ev, nv = pad_segment(g, node_ids, spec.m_max, spec.e_max)
    train_side = {"x": x, "edges": e, "edge_valid": ev, "node_valid": nv}
    serve_side = pad_to_bucket(g, node_ids, spec)
    assert segment_fingerprint(train_side, 0) == \
        segment_fingerprint(serve_side, 0)
