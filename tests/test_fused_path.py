"""The fused GST hot path: batched segment_spmm, fused SED pooling, donation.

Contract under test (ISSUE 1):
  * batched segment_spmm ≡ per-segment oracle, forward AND reverse-mode
  * the cfg.use_pallas encode launches ONE batched pallas_call per
    message-passing layer (counted in the jaxpr), not one per segment
  * train/eval/finetune losses match the jnp path across all seven variants
  * donating TrainState through the jitted step reuses the embedding-table
    buffer in place (no per-step copy of the largest array in the system)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gst as G
from repro.core.embedding_table import init_table
from repro.graphs import batching as Bt
from repro.graphs import data as D
from repro.graphs.gnn import GNNConfig, gnn_init, make_encode_fn
from repro.kernels import ref
from repro.kernels.ops import count_pallas_calls
from repro.kernels.segment_spmm import segment_spmm_batched
from repro.optim import make_optimizer


# ---------------------------------------------------------------------------
# batched kernel vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("N,m,d,e,dtype", [
    (1, 16, 8, 5, jnp.float32),
    (5, 48, 40, 130, jnp.float32),
    (3, 64, 130, 300, jnp.float32),   # d > d_blk-pad boundary
    (4, 32, 64, 257, jnp.bfloat16),   # e not a block multiple
])
def test_batched_spmm_matches_oracle(N, m, d, e, dtype):
    rng = np.random.default_rng(N * 1000 + e)
    h = jnp.asarray(rng.normal(size=(N, m, d)), dtype)
    src = jnp.asarray(rng.integers(0, m, (N, e)), jnp.int32)
    dst = jnp.asarray(rng.integers(0, m, (N, e)), jnp.int32)
    w = jnp.asarray(rng.uniform(0, 1, (N, e)) * (rng.uniform(size=(N, e)) > 0.3),
                    dtype)
    out = segment_spmm_batched(h, src, dst, w, interpret=True)
    want = ref.segment_spmm_batched_ref(
        h.astype(jnp.float32), src, dst, w.astype(jnp.float32))
    tol = 1e-5 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(want),
                               rtol=tol, atol=tol)


def test_batched_spmm_gradients_match_oracle():
    """custom_vjp: ∂/∂h is the transposed SpMM, ∂/∂w the per-edge inner
    product — both must match jax.grad through the jnp oracle."""
    rng = np.random.default_rng(7)
    N, m, d, e = 4, 24, 12, 50
    h = jnp.asarray(rng.normal(size=(N, m, d)), jnp.float32)
    src = jnp.asarray(rng.integers(0, m, (N, e)), jnp.int32)
    dst = jnp.asarray(rng.integers(0, m, (N, e)), jnp.int32)
    w = jnp.asarray(rng.uniform(0, 1, (N, e)), jnp.float32)

    def f_kernel(h, w):
        return jnp.sum(jnp.sin(segment_spmm_batched(h, src, dst, w,
                                                    interpret=True)))

    def f_ref(h, w):
        return jnp.sum(jnp.sin(ref.segment_spmm_batched_ref(h, src, dst, w)))

    gk = jax.grad(f_kernel, argnums=(0, 1))(h, w)
    gr = jax.grad(f_ref, argnums=(0, 1))(h, w)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# batched encode path: parity + kernel-launch count
# ---------------------------------------------------------------------------


def _flat_segments(n_graphs=2, max_seg_nodes=48, seed=0):
    graphs = D.make_malnet_like(n_graphs=n_graphs, seed=seed)
    ds = Bt.segment_dataset(graphs, max_seg_nodes=max_seg_nodes)
    return {k: jnp.asarray(v.reshape((-1,) + v.shape[2:]))
            for k, v in ds.seg_inputs(np.arange(n_graphs)).items()}


@pytest.mark.parametrize("backbone", ["gcn", "sage"])
def test_batched_encode_matches_vmap_path(backbone):
    seg = _flat_segments()
    cfg0 = GNNConfig(backbone=backbone, n_feat=8, hidden=32, use_pallas=False)
    cfg1 = GNNConfig(backbone=backbone, n_feat=8, hidden=32, use_pallas=True)
    params = gnn_init(jax.random.key(0), cfg0)
    e0, _ = make_encode_fn(cfg0)(params, seg)
    e1, _ = make_encode_fn(cfg1)(params, seg)
    np.testing.assert_allclose(np.asarray(e0), np.asarray(e1),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("backbone", ["gcn", "sage"])
def test_batched_encode_grad_matches_vmap_path(backbone):
    seg = _flat_segments()
    cfg0 = GNNConfig(backbone=backbone, n_feat=8, hidden=16, use_pallas=False)
    cfg1 = GNNConfig(backbone=backbone, n_feat=8, hidden=16, use_pallas=True)
    params = gnn_init(jax.random.key(1), cfg0)

    def loss(cfg):
        return lambda p: jnp.sum(make_encode_fn(cfg)(p, seg)[0] ** 2)

    g0 = jax.tree_util.tree_leaves(jax.grad(loss(cfg0))(params))
    g1 = jax.tree_util.tree_leaves(jax.grad(loss(cfg1))(params))
    for a, b in zip(g0, g1):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-5)


@pytest.mark.parametrize("n_mp", [1, 3])
def test_one_pallas_call_per_mp_layer(n_mp):
    """The fused path's whole point: the forward jaxpr contains exactly n_mp
    pallas_calls (one batched launch per message-passing layer), regardless
    of how many segments are in the batch."""
    seg = _flat_segments()
    cfg = GNNConfig(backbone="sage", n_feat=8, hidden=16, n_mp=n_mp,
                    use_pallas=True)
    params = gnn_init(jax.random.key(0), cfg)
    enc = make_encode_fn(cfg)
    assert count_pallas_calls(lambda p: enc(p, seg)[0], params) == n_mp
    # reference path: zero kernel launches
    cfg0 = GNNConfig(backbone="sage", n_feat=8, hidden=16, n_mp=n_mp,
                     use_pallas=False)
    enc0 = make_encode_fn(cfg0)
    assert count_pallas_calls(lambda p: enc0(p, seg)[0], params) == 0


# ---------------------------------------------------------------------------
# fused train/eval steps vs jnp path, all variants
# ---------------------------------------------------------------------------


def _gnn_setup(variant, use_pallas, head_mode="mlp", loss_kind="ce",
               agg="mean", hidden=16, num_sampled=1):
    graphs = D.make_malnet_like(n_graphs=8, seed=0)
    ds = Bt.segment_dataset(graphs, max_seg_nodes=32)
    tup = next(Bt.batch_iterator(ds, 4, rng=np.random.default_rng(0),
                                 shuffle=False))
    batch = G.GSTBatch({k: jnp.asarray(v) for k, v in tup[0].items()},
                       jnp.asarray(tup[1]), jnp.asarray(tup[2]),
                       jnp.asarray(tup[3]) if loss_kind == "ce"
                       else jnp.asarray(tup[3], jnp.float32))
    cfg = GNNConfig(backbone="sage", n_feat=8, hidden=hidden,
                    use_pallas=use_pallas)
    enc = make_encode_fn(cfg)
    key = jax.random.key(0)
    bb = gnn_init(key, cfg)
    n_out = 5 if head_mode == "mlp" else 1
    head = G.head_init(jax.random.fold_in(key, 1), hidden, n_out, head_mode)
    opt = make_optimizer("adam", lr=1e-2)
    state = G.TrainState(bb, head, opt.init((bb, head)),
                         init_table(ds.n, ds.j_max, hidden),
                         jnp.zeros((), jnp.int32))
    step = jax.jit(G.make_train_step(
        enc, opt, G.VARIANTS[variant], num_sampled=num_sampled, keep_prob=0.5,
        head_mode=head_mode, loss_kind=loss_kind, agg=agg,
        use_pallas=use_pallas))
    eval_step = jax.jit(G.make_eval_step(enc, head_mode=head_mode,
                                         loss_kind=loss_kind, agg=agg,
                                         use_pallas=use_pallas))
    return state, batch, step, eval_step


@pytest.mark.parametrize("variant", list(G.VARIANTS))
def test_train_step_fused_matches_reference(variant):
    """Two optimizer steps (exercises table write-back + second-step lookup):
    losses and metrics must agree between the fused and jnp paths."""
    traces = {}
    for up in (False, True):
        state, batch, step, _ = _gnn_setup(variant, up)
        ls = []
        for i in range(2):
            state, m = step(state, batch, jax.random.key(3))
            ls.append((float(m["loss"]), float(m["metric"])))
        traces[up] = ls
    for (l0, m0), (l1, m1) in zip(traces[False], traces[True]):
        np.testing.assert_allclose(l0, l1, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(m0, m1, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("head_mode,loss_kind,agg", [
    ("mlp", "ce", "mean"),
    ("segment_sum", "pairwise_hinge", "sum"),
])
def test_eval_step_fused_matches_reference(head_mode, loss_kind, agg):
    outs = {}
    for up in (False, True):
        state, batch, step, eval_step = _gnn_setup(
            "gst_efd", up, head_mode=head_mode, loss_kind=loss_kind, agg=agg)
        state, _ = step(state, batch, jax.random.key(0))
        m = eval_step(state, batch)
        outs[up] = (float(m["loss"]), float(m["metric"]))
    np.testing.assert_allclose(outs[False], outs[True], rtol=1e-4, atol=1e-5)


def test_segment_sum_train_step_fused_matches_reference():
    """The TpuGraphs-track shape: scalar per-segment head, hinge loss, Σ-agg,
    SED variant — the fused path pools the (B, J) scalars through sed_pool."""
    traces = {}
    for up in (False, True):
        state, batch, step, _ = _gnn_setup(
            "gst_efd", up, head_mode="segment_sum",
            loss_kind="pairwise_hinge", agg="sum")
        state, m = step(state, batch, jax.random.key(1))
        traces[up] = (float(m["loss"]), float(m["metric"]))
    np.testing.assert_allclose(traces[False], traces[True],
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# finetuning with the scalar head (Algorithm 2 lines 11-18, TpuGraphs track)
# ---------------------------------------------------------------------------


def test_finetune_supports_segment_sum_head():
    state, batch, step, _ = _gnn_setup(
        "gst_efd", False, head_mode="segment_sum",
        loss_kind="pairwise_hinge", agg="sum")
    state, _ = step(state, batch, jax.random.key(0))
    cfg = GNNConfig(backbone="sage", n_feat=8, hidden=16)
    enc = make_encode_fn(cfg)
    refresh = jax.jit(G.make_refresh_step(enc))
    state = refresh(state, batch)
    ft_opt = make_optimizer("adam", lr=1e-2)
    state = state._replace(opt_state=ft_opt.init(state.head))
    ft = jax.jit(G.make_finetune_step(ft_opt, head_mode="segment_sum",
                                      loss_kind="pairwise_hinge", agg="sum"))
    bb_before, head_before = state.backbone, state.head
    state, m = ft(state, batch)
    assert np.isfinite(float(m["loss"]))
    # backbone untouched, head moved
    same = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), bb_before, state.backbone)
    assert max(jax.tree_util.tree_leaves(same)) == 0.0
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), head_before, state.head)
    assert max(jax.tree_util.tree_leaves(diffs)) > 0


def test_run_experiment_finetunes_segment_sum_track():
    """gst_efd on the TpuGraphs-like dataset must actually run the finetune
    phase (previously silently skipped) and report it."""
    from repro.graphs.experiment import run_experiment
    r = run_experiment(dataset="tpugraphs", backbone="sage", variant="gst_efd",
                       n_graphs=16, max_seg_nodes=24, epochs=1,
                       finetune_epochs=1, batch_size=4, hidden=8)
    assert r.finetuned
    assert np.isfinite(r.test_metric)


# ---------------------------------------------------------------------------
# donation: the table buffer is reused, not copied
# ---------------------------------------------------------------------------


def test_donated_state_reuses_table_buffer():
    def encode(w, seg_inputs):
        x = jax.nn.one_hot(seg_inputs["tokens"], 16) @ w
        return jnp.mean(x, axis=1), jnp.zeros((), jnp.float32)

    rng = np.random.default_rng(0)
    d, J, B, n = 8, 4, 4, 256
    w = jnp.asarray(rng.normal(size=(16, d)), jnp.float32)
    head = G.head_init(jax.random.key(1), d, 3, "mlp")
    opt = make_optimizer("adam", lr=1e-2)
    state = G.TrainState(w, head, opt.init((w, head)), init_table(n, J, d),
                         jnp.zeros((), jnp.int32))
    batch = G.GSTBatch(
        {"tokens": jnp.asarray(rng.integers(0, 16, (B, J, 5)), jnp.int32)},
        jnp.ones((B, J), jnp.float32), jnp.arange(B, dtype=jnp.int32),
        jnp.asarray(rng.integers(0, 3, B), jnp.int32))
    step = jax.jit(G.make_train_step(encode, opt, G.VARIANTS["gst_efd"]),
                   donate_argnums=(0,))
    emb0 = state.table.emb
    ptr0 = emb0.unsafe_buffer_pointer()
    state, _ = step(state, batch, jax.random.key(0))
    if not emb0.is_deleted():
        pytest.skip("backend does not implement input-output aliasing")
    # the scatter update must have landed in the SAME buffer — no copy of
    # the largest array in the system
    assert state.table.emb.unsafe_buffer_pointer() == ptr0
    ptr1 = state.table.emb.unsafe_buffer_pointer()
    state, _ = step(state, batch, jax.random.key(1))
    assert state.table.emb.unsafe_buffer_pointer() == ptr1


def test_run_experiment_pallas_smoke():
    """End-to-end: the plumbed use_pallas flag trains and evaluates."""
    from repro.graphs.experiment import run_experiment
    r = run_experiment(dataset="malnet", backbone="gcn", variant="gst_ed",
                       n_graphs=16, max_seg_nodes=24, epochs=1, batch_size=4,
                       hidden=8, use_pallas=True)
    assert r.use_pallas
    assert np.isfinite(r.test_metric)
