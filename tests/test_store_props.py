"""Property tests for the tiered embedding store's tier invariants.

Random (geometry, batch-sequence) draws — via hypothesis when installed,
the deterministic fallback otherwise (tests/_hypothesis_compat.py) —
checked after EVERY prepare/update against a dense oracle table:

  * device-tier occupancy never exceeds the per-shard capacity, and no
    two keys ever share a slot (SlotMap internal consistency);
  * every row is authoritative in exactly one tier: resident rows answer
    from the device tier, everything else from host RAM, and the merged
    snapshot equals the oracle bit for bit;
  * lookups after ANY eviction sequence are bit-exact vs the oracle —
    residency is invisible to the training math.
"""
import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, st

from repro.core import embedding_table as tbl
from repro.store import SlotMap, TieredStore


def _random_ops(store, table, oracle, rng, n_steps, batch):
    """Drive identical random lookups/updates through the tiered store and
    the dense oracle; yields after each op for invariant checks."""
    n, J, d = oracle.emb.shape
    R, C = store.rows_per_shard, store.device_rows_per_shard
    for t in range(n_steps):
        # per-shard draws so one batch never needs more than C rows of a
        # shard resident (the documented capacity contract)
        ids = []
        for s in range(store.num_shards):
            lo, hi = s * R, min((s + 1) * R, n)
            if lo >= n:
                continue
            k = min(batch, C, hi - lo)
            ids.extend(rng.choice(np.arange(lo, hi), size=k, replace=False))
        ids = np.asarray(ids, np.int64)
        h = rng.normal(size=(len(ids), 1, d)).astype(np.float32)
        sidx = rng.integers(0, J, (len(ids), 1)).astype(np.int32)

        table, slots = store.prepare(table, ids)
        e_t, i_t = tbl.lookup(table, jnp.asarray(slots))
        e_o, i_o = tbl.lookup(oracle, jnp.asarray(ids))

        table = tbl.update_sampled(table, jnp.asarray(slots),
                                   jnp.asarray(sidx), jnp.asarray(h), t)
        oracle = tbl.update_sampled(oracle, jnp.asarray(ids),
                                    jnp.asarray(sidx), jnp.asarray(h), t)
        yield table, oracle, ids, slots, (e_t, i_t), (e_o, i_o)


@settings(max_examples=8, deadline=None)
@given(n=st.integers(5, 30), device_frac=st.floats(0.1, 0.9),
       num_shards=st.sampled_from([1, 2, 4]), seed=st.integers(0, 10**6))
def test_tier_invariants_hold_under_random_churn(n, device_frac, num_shards,
                                                 seed):
    rng = np.random.default_rng(seed)
    J, d = 2, 4
    store = TieredStore(n, J, d, num_shards=num_shards,
                        device_rows=max(1, int(n * device_frac)))
    table = store.init_device_table()
    oracle = tbl.init_table(n, J, d)
    C = store.device_rows_per_shard

    for table, oracle, ids, slots, got, want in _random_ops(
            store, table, oracle, rng, n_steps=12, batch=3):
        # lookup bit-exact vs oracle after any eviction sequence
        assert np.array_equal(np.asarray(got[0]), np.asarray(want[0]))
        assert np.array_equal(np.asarray(got[1]), np.asarray(want[1]))
        # occupancy never exceeds per-shard capacity; slots never shared
        resident = {}
        for s, m in enumerate(store._maps):
            assert len(m) <= C
            entries = dict(m.items())
            assert len(set(entries.values())) == len(entries)
            for row, slot in entries.items():
                assert s * store.rows_per_shard <= row \
                    < min((s + 1) * store.rows_per_shard, n)
                resident[row] = s * C + slot
        # slot ids the batch got must agree with the residency map
        for rid, slot in zip(ids, slots):
            assert resident[int(rid)] == int(slot)
        # every row in exactly one tier: the merged snapshot IS the oracle
        # (residency must be invisible), and only non-resident rows answer
        # from the host tier
        assert store.occupancy() == len(resident)

    store.flush_writebacks()
    snap = store.snapshot(table)
    for a, b in zip(snap, oracle):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    store.close()


@settings(max_examples=10, deadline=None)
@given(capacity=st.integers(1, 8), n_keys=st.integers(1, 24),
       seed=st.integers(0, 10**6))
def test_slotmap_never_leaks_or_doubles_slots(capacity, n_keys, seed):
    rng = np.random.default_rng(seed)
    m = SlotMap(capacity)
    live = {}
    for i in range(n_keys):
        key = f"k{i}"
        slot, evicted = m.reserve(key)
        assert slot is not None          # nothing pinned -> always succeeds
        if evicted is not None:
            old_key, old_slot = evicted
            assert live.pop(old_key) == old_slot == slot
        live[key] = slot
        if live and rng.random() < 0.3:  # random release
            victim = rng.choice(sorted(live))
            m.release(victim)
            del live[victim]
        assert len(m) == len(live) <= capacity
        assert len(set(live.values())) == len(live)
        for k, s in live.items():
            assert m.get(k, touch=False) == s


@settings(max_examples=6, deadline=None)
@given(n=st.integers(4, 20), seed=st.integers(0, 10**6))
def test_min_capacity_single_slot_store_stays_exact(n, seed):
    """The degenerate 1-device-row tier: every step evicts, every lookup
    faults — still bit-exact."""
    rng = np.random.default_rng(seed)
    store = TieredStore(n, 1, 3, device_rows=1)
    table = store.init_device_table()
    oracle = tbl.init_table(n, 1, 3)
    for t in range(10):
        row = int(rng.integers(n))
        h = rng.normal(size=(1, 1, 3)).astype(np.float32)
        table, slots = store.prepare(table, np.asarray([row]))
        z = jnp.zeros((1, 1), jnp.int32)
        table = tbl.update_sampled(table, jnp.asarray(slots), z,
                                   jnp.asarray(h), t)
        oracle = tbl.update_sampled(oracle, jnp.asarray([row]), z,
                                    jnp.asarray(h), t)
    snap = store.snapshot(table)
    for a, b in zip(snap, oracle):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    store.close()
