"""Property tests for the tiered embedding store's tier invariants.

Random (geometry, batch-sequence) draws — via hypothesis when installed,
the deterministic fallback otherwise (tests/_hypothesis_compat.py) —
checked after EVERY prepare/update against a dense oracle table:

  * device-tier occupancy never exceeds the per-shard capacity, and no
    two keys ever share a slot (SlotMap internal consistency);
  * every row is authoritative in exactly one tier: resident rows answer
    from the device tier, everything else from host RAM, and the merged
    snapshot equals the oracle bit for bit;
  * lookups after ANY eviction sequence are bit-exact vs the oracle —
    residency is invisible to the training math;
  * the eviction POLICY (lru | stale-first, store/slots.py) only changes
    WHICH row migrates, never the math: the churn invariants hold under
    both, and under stale-first the stale-and-cold rows demonstrably
    leave the device tier before fresh-and-hot ones.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import embedding_table as tbl
from repro.store import SlotMap, TieredStore


def _random_ops(store, table, oracle, rng, n_steps, batch):
    """Drive identical random lookups/updates through the tiered store and
    the dense oracle; yields after each op for invariant checks."""
    n, J, d = oracle.emb.shape
    R, C = store.rows_per_shard, store.device_rows_per_shard
    for t in range(n_steps):
        # per-shard draws so one batch never needs more than C rows of a
        # shard resident (the documented capacity contract)
        ids = []
        for s in range(store.num_shards):
            lo, hi = s * R, min((s + 1) * R, n)
            if lo >= n:
                continue
            k = min(batch, C, hi - lo)
            ids.extend(rng.choice(np.arange(lo, hi), size=k, replace=False))
        ids = np.asarray(ids, np.int64)
        h = rng.normal(size=(len(ids), 1, d)).astype(np.float32)
        sidx = rng.integers(0, J, (len(ids), 1)).astype(np.int32)

        table, slots = store.prepare(table, ids)
        e_t, i_t = tbl.lookup(table, jnp.asarray(slots))
        e_o, i_o = tbl.lookup(oracle, jnp.asarray(ids))

        table = tbl.update_sampled(table, jnp.asarray(slots),
                                   jnp.asarray(sidx), jnp.asarray(h), t)
        oracle = tbl.update_sampled(oracle, jnp.asarray(ids),
                                    jnp.asarray(sidx), jnp.asarray(h), t)
        yield table, oracle, ids, slots, (e_t, i_t), (e_o, i_o)


@settings(max_examples=8, deadline=None)
@given(n=st.integers(5, 30), device_frac=st.floats(0.1, 0.9),
       num_shards=st.sampled_from([1, 2, 4]), seed=st.integers(0, 10**6),
       policy=st.sampled_from(["lru", "stale-first"]))
def test_tier_invariants_hold_under_random_churn(n, device_frac, num_shards,
                                                 seed, policy):
    rng = np.random.default_rng(seed)
    J, d = 2, 4
    store = TieredStore(n, J, d, num_shards=num_shards,
                        device_rows=max(1, int(n * device_frac)),
                        evict_policy=policy)
    table = store.init_device_table()
    oracle = tbl.init_table(n, J, d)
    C = store.device_rows_per_shard

    for table, oracle, ids, slots, got, want in _random_ops(
            store, table, oracle, rng, n_steps=12, batch=3):
        # lookup bit-exact vs oracle after any eviction sequence
        assert np.array_equal(np.asarray(got[0]), np.asarray(want[0]))
        assert np.array_equal(np.asarray(got[1]), np.asarray(want[1]))
        # occupancy never exceeds per-shard capacity; slots never shared
        resident = {}
        for s, m in enumerate(store._maps):
            assert len(m) <= C
            entries = dict(m.items())
            assert len(set(entries.values())) == len(entries)
            for row, slot in entries.items():
                assert s * store.rows_per_shard <= row \
                    < min((s + 1) * store.rows_per_shard, n)
                resident[row] = s * C + slot
        # slot ids the batch got must agree with the residency map
        for rid, slot in zip(ids, slots):
            assert resident[int(rid)] == int(slot)
        # every row in exactly one tier: the merged snapshot IS the oracle
        # (residency must be invisible), and only non-resident rows answer
        # from the host tier
        assert store.occupancy() == len(resident)

    store.flush_writebacks()
    snap = store.snapshot(table)
    for a, b in zip(snap, oracle):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    store.close()


@settings(max_examples=10, deadline=None)
@given(capacity=st.integers(1, 8), n_keys=st.integers(1, 24),
       seed=st.integers(0, 10**6))
def test_slotmap_never_leaks_or_doubles_slots(capacity, n_keys, seed):
    rng = np.random.default_rng(seed)
    m = SlotMap(capacity)
    live = {}
    for i in range(n_keys):
        key = f"k{i}"
        slot, evicted = m.reserve(key)
        assert slot is not None          # nothing pinned -> always succeeds
        if evicted is not None:
            old_key, old_slot = evicted
            assert live.pop(old_key) == old_slot == slot
        live[key] = slot
        if live and rng.random() < 0.3:  # random release
            victim = rng.choice(sorted(live))
            m.release(victim)
            del live[victim]
        assert len(m) == len(live) <= capacity
        assert len(set(live.values())) == len(live)
        for k, s in live.items():
            assert m.get(k, touch=False) == s


# ---------------------------------------------------------------------------
# staleness-aware eviction (--evict-policy=stale-first)
# ---------------------------------------------------------------------------


def _aged_store(policy):
    """A store restored from a snapshot whose per-row ages are crafted:
    rows 0-3 will fill the 4-slot device tier; rows 4-6 arrive later and
    force evictions.  Ages: 0->5, 1->1, 2->9, 3->1, 4..6->20."""
    n, J, d, C = 8, 2, 4, 4
    rng = np.random.default_rng(0)
    ages = np.array([5, 1, 9, 1, 20, 20, 20, 3])
    snap = tbl.EmbeddingTable(
        emb=rng.normal(size=(n, J, d)).astype(np.float32),
        age=np.tile(ages[:, None], (1, J)).astype(np.int32),
        initialized=np.ones((n, J), bool))
    store = TieredStore(n, J, d, device_rows=C, evict_policy=policy)
    return store, store.restore(snap), snap


def test_stale_first_evicts_stale_and_cold_rows_first():
    store, table, snap = _aged_store("stale-first")
    table, _ = store.prepare(table, np.asarray([0, 1, 2, 3]))  # tier full
    # rows 1 and 3 are equally stale (age 1); row 1 is colder (faulted
    # earlier), so it leaves first — NOT row 0, the pure-LRU victim
    table, _ = store.prepare(table, np.asarray([4]))
    assert store.resident_slot(1) is None
    assert all(store.resident_slot(r) is not None for r in (0, 2, 3, 4))
    table, _ = store.prepare(table, np.asarray([5]))
    assert store.resident_slot(3) is None                      # age 1
    table, _ = store.prepare(table, np.asarray([6]))
    assert store.resident_slot(0) is None                      # age 5
    assert store.resident_slot(2) is not None                  # fresh: 9
    # the policy never touched the math: the merged view is still the
    # restored snapshot, bit for bit, and an evicted row faults back exact
    store.flush_writebacks()
    got = store.snapshot(table)
    for a, b in zip(got, snap):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    table, slots = store.prepare(table, np.asarray([1]))
    e, i = tbl.lookup(table, jnp.asarray(slots))
    assert np.array_equal(np.asarray(e)[0], np.asarray(snap.emb)[1])
    assert np.array_equal(np.asarray(i)[0], np.asarray(snap.initialized)[1])
    store.close()


def test_stale_first_step_hint_keeps_rewritten_resident_rows():
    """A resident row a train step is about to rewrite (prepare's ``step``
    hint) must stop scoring as stale as its fault-in age — without the
    hint the stalest-at-fault-in row would be evicted even while hot."""
    store, table, _ = _aged_store("stale-first")
    table, _ = store.prepare(table, np.asarray([0, 1, 2, 3]))
    # row 1 (fault-in age 1, the stalest) is requested by a writing step
    table, _ = store.prepare(table, np.asarray([1]), step=100)
    # eviction pressure now spares it: the victim is row 3 (age 1)
    table, _ = store.prepare(table, np.asarray([4]))
    assert store.resident_slot(3) is None
    assert store.resident_slot(1) is not None
    store.close()


def test_lru_contrast_evicts_coldest_not_stalest():
    store, table, _ = _aged_store("lru")
    table, _ = store.prepare(table, np.asarray([0, 1, 2, 3]))
    table, _ = store.prepare(table, np.asarray([4]))
    assert store.resident_slot(0) is None     # coldest, despite mid age
    assert store.resident_slot(1) is not None  # stalest but newer in LRU
    store.close()


def test_slotmap_stale_first_scoring_and_pinning():
    m = SlotMap(2, policy="stale-first")
    assert m.reserve("a")[0] is not None
    m.set_age("a", 10)
    assert m.reserve("b")[0] is not None
    m.set_age("b", 2)
    slot, evicted = m.reserve("c")            # b is stalest
    assert evicted[0] == "b" and evicted[1] == slot
    # a key with NO reported age counts as stalest of all
    slot, evicted = m.reserve("d")
    assert evicted[0] == "c"
    # pinning excludes the stalest: the other key is displaced instead
    m.set_age("d", 0)
    slot, evicted = m.reserve("e", pinned={"d"})
    assert evicted[0] == "a"
    # full map, everything pinned -> (None, None)
    assert m.reserve("f", pinned={"d", "e"}) == (None, None)
    # release cleans the age bookkeeping too
    m.set_age("e", 7)
    m.release("e")
    assert m.age_of("e") is None


def test_slotmap_rejects_unknown_policy():
    with pytest.raises(ValueError, match="policy"):
        SlotMap(4, policy="freshest-first")


@settings(max_examples=6, deadline=None)
@given(n=st.integers(4, 20), seed=st.integers(0, 10**6))
def test_min_capacity_single_slot_store_stays_exact(n, seed):
    """The degenerate 1-device-row tier: every step evicts, every lookup
    faults — still bit-exact."""
    rng = np.random.default_rng(seed)
    store = TieredStore(n, 1, 3, device_rows=1)
    table = store.init_device_table()
    oracle = tbl.init_table(n, 1, 3)
    for t in range(10):
        row = int(rng.integers(n))
        h = rng.normal(size=(1, 1, 3)).astype(np.float32)
        table, slots = store.prepare(table, np.asarray([row]))
        z = jnp.zeros((1, 1), jnp.int32)
        table = tbl.update_sampled(table, jnp.asarray(slots), z,
                                   jnp.asarray(h), t)
        oracle = tbl.update_sampled(oracle, jnp.asarray([row]), z,
                                    jnp.asarray(h), t)
    snap = store.snapshot(table)
    for a, b in zip(snap, oracle):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    store.close()
