"""Deterministic fallback for ``hypothesis`` (tier-1 must collect without it).

When hypothesis is installed (see requirements-dev.txt) the real library is
re-exported unchanged.  When it is missing, ``given``/``settings``/``st``
degrade to a tiny deterministic-cases runner: each strategy draws from a
seeded numpy Generator and the test body runs ``max_examples`` times.  No
shrinking, no database — just fixed-case coverage so the kernel/GST property
tests keep running in minimal containers.

Usage in test modules:

    from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

try:  # real hypothesis wins when available
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import numpy as _np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class st:  # noqa: N801 - mimics `hypothesis.strategies` module name
        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda rng: options[int(rng.integers(len(options)))])

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

    def settings(max_examples: int = 10, deadline=None, **_ignored):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            n_default = getattr(fn, "_compat_max_examples", 10)

            # Deliberately takes no parameters: the wrapped test receives all
            # its arguments from the strategies, and a bare signature keeps
            # pytest from mistaking strategy names for fixtures.
            def wrapper():
                rng = _np.random.default_rng(0)
                for _ in range(n_default):
                    fn(**{k: s.draw(rng) for k, s in strategies.items()})

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco
