"""Monte-Carlo validation of Theorem 4.1 (paper §4, Appendix A).

SED with keep ratio p reduces the stale-embedding bias term by exactly the
factor p, at the cost of an extra regularization (second-moment) term.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import segment as seg
from repro.core.theory import delta_moments_et, delta_moments_sed


def _simulate_delta(h, h_tilde, J, S, p, n_iter, use_sed, seed=0):
    """Monte-Carlo E[δ_j] where δ = (η-weighted observed) - (true), per
    segment, under the actual sampling machinery in core.segment (vmapped)."""
    valid = jnp.ones((1, J))

    def one(key):
        k1, k2 = jax.random.split(key)
        idx = seg.sample_segments(k1, valid, S)
        fresh = seg.sampled_mask(idx, J)[0]  # (J,)
        if use_sed:
            eta, _ = seg.sed_weights(k2, valid, fresh[None], p, S)
            observed = eta[0][:, None] * jnp.where(fresh[:, None] > 0, h, h_tilde)
        else:
            observed = jnp.where(fresh[:, None] > 0, h, h_tilde)
        return observed - h

    keys = jax.random.split(jax.random.key(seed), n_iter)
    deltas = jax.jit(jax.vmap(one))(keys)
    return np.asarray(jnp.mean(deltas, axis=0))


@pytest.mark.parametrize("p", [0.25, 0.5, 0.75])
def test_bias_reduced_by_factor_p(p):
    rng = np.random.default_rng(0)
    J, S, d = 6, 1, 4
    h = jnp.asarray(rng.normal(size=(J, d)), jnp.float32)
    h_tilde = h + jnp.asarray(rng.normal(size=(J, d)) * 0.5, jnp.float32)

    # closed-form moments (theory.py)
    et_mean, _ = delta_moments_et(h, h_tilde, J, S)
    sed_mean, _ = delta_moments_sed(h, h_tilde, J, S, p)
    # the stale-difference component: ET carries (J-S)/J (h̃-h); SED carries
    # p (J-S)/J (h̃-h).  Verify the p factor on the closed forms:
    stale_et = (J - S) / J * np.asarray(h_tilde - h)
    np.testing.assert_allclose(np.asarray(et_mean), stale_et, rtol=1e-5)
    # SED mean = p * stale bias + mean-zero-in-expectation fresh part:
    fresh_part = (S / J) * (1 - p) * (J - S) / S * np.asarray(h) \
        - (1 - p) * (J - S) / J * np.asarray(h)
    np.testing.assert_allclose(np.asarray(sed_mean),
                               p * stale_et + fresh_part, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(fresh_part, 0.0, atol=1e-6)  # cancels exactly

    # Monte-Carlo through the real sampling code.  The SED estimator carries
    # the high-variance up-weighted fresh term (σ ∝ (1-p)(J-S)/S·|h|), so its
    # tolerance is scaled accordingly.
    n = 40_000
    mc_et = _simulate_delta(h, h_tilde, J, S, p, n, use_sed=False)
    mc_sed = _simulate_delta(h, h_tilde, J, S, p, n, use_sed=True)
    np.testing.assert_allclose(mc_et, stale_et, atol=0.05)
    sigma = (1 - p) * (J - S) / S * float(jnp.max(jnp.abs(h)))
    np.testing.assert_allclose(mc_sed, p * stale_et,
                               atol=max(0.05, 5 * sigma / np.sqrt(n)))


def test_limit_cases_match_theorem():
    """p=1 degrades to ET; p=0 removes the stale bias entirely."""
    rng = np.random.default_rng(1)
    J, S, d = 5, 1, 3
    h = jnp.asarray(rng.normal(size=(J, d)), jnp.float32)
    h_tilde = h + 1.0
    et_mean, et_second = delta_moments_et(h, h_tilde, J, S)
    sed1_mean, sed1_second = delta_moments_sed(h, h_tilde, J, S, 1.0)
    np.testing.assert_allclose(np.asarray(sed1_mean), np.asarray(et_mean),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(sed1_second),
                               np.asarray(et_second), rtol=1e-6)
    sed0_mean, sed0_second = delta_moments_sed(h, h_tilde, J, S, 0.0)
    # bias gone...
    stale_component = np.asarray(sed0_mean) @ np.asarray(h_tilde - h).T
    # ...but regularization (second moment) strictly larger than ET's
    assert float(jnp.sum(sed0_second)) > float(jnp.sum(et_second))


def test_regularizer_grows_as_p_drops():
    """The second-order term (regularizer) increases monotonically as p→0 —
    the tradeoff Theorem 4.1 describes."""
    rng = np.random.default_rng(2)
    J, S = 8, 1
    h = jnp.asarray(rng.normal(size=(J, 4)), jnp.float32)
    h_tilde = h + jnp.asarray(rng.normal(size=(J, 4)) * 0.3, jnp.float32)
    seconds = []
    for p in [1.0, 0.75, 0.5, 0.25, 0.0]:
        _, second = delta_moments_sed(h, h_tilde, J, S, p)
        seconds.append(float(jnp.sum(second)))
    assert all(seconds[i] <= seconds[i + 1] + 1e-6 for i in range(len(seconds) - 1))
