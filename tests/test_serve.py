"""Serving-subsystem invariants (serve/: engine, buckets, cache, traffic).

* streaming-vs-one-shot encoder parity for every GNN variant, Pallas and
  reference paths (acceptance: atol 1e-5; empirically bit-exact),
* the constant-memory contract via buffer-size accounting: the streaming
  scan's largest intermediate does not grow with the number of chunks,
  while the one-shot encoder's grows with the segment count,
* cache properties: hit returns the bit-identical embedding, eviction
  respects capacity with LRU order, and a full-hit request launches zero
  encode kernels,
* engine-vs-offline parity on traffic spanning multiple buckets.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gst as G
from repro.graphs import data as D
from repro.graphs.batching import segment_dataset
from repro.graphs.gnn import GNNConfig, encode_segments, gnn_init
from repro.graphs.partition import partition_graph
from repro.kernels.ops import max_intermediate_bytes
from repro.serve import (
    BucketSpec,
    SegmentCache,
    ServeConfig,
    ServeEngine,
    TrafficConfig,
    graph_to_chunks,
    make_request_stream,
    make_stream_encoder,
)
from repro.serve.engine import SEG_KEYS

# gps has no fused kernel path (falls back to reference inside
# encode_segments), so the pallas axis only applies to gcn/sage
ENCODER_VARIANTS = [("gcn", False), ("gcn", True),
                    ("sage", False), ("sage", True), ("gps", False)]

HID = 16


def _graph(seed=0):
    return D.make_malnet_like(n_graphs=2, comm_range=(6, 9),
                              comm_size_range=(14, 26), seed=seed)[seed % 2]


def _setup(backbone, use_pallas, head_mode="mlp", seed=0):
    cfg = GNNConfig(backbone=backbone, n_feat=8, hidden=HID,
                    use_pallas=use_pallas)
    key = jax.random.key(seed)
    params = gnn_init(key, cfg)
    head = G.head_init(jax.random.fold_in(key, 1), HID, 3, head_mode)
    return cfg, params, head


def _one_shot(cfg, params, head, chunks, head_mode="mlp", agg="mean"):
    """Reference: encode ALL segments in one flat batch, mask-pool, head."""
    flat = {k: jnp.asarray(chunks[k].reshape((-1,) + chunks[k].shape[2:]))
            for k in SEG_KEYS}
    h = encode_segments(params, cfg, flat)
    w = jnp.asarray(chunks["seg_valid"].reshape(-1))
    if head_mode == "segment_sum":
        scal = G.head_apply(head, h, "segment_sum")
        s = jnp.sum(scal * w)
        return s / jnp.maximum(w.sum(), 1.0) if agg == "mean" else s
    pooled = (h * w[:, None]).sum(0) / jnp.maximum(w.sum(), 1.0)
    return G.head_apply(head, pooled, "mlp")


# ---------------------------------------------------------------------------
# streaming encoder
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backbone,use_pallas", ENCODER_VARIANTS)
def test_streaming_matches_one_shot(backbone, use_pallas):
    cfg, params, head = _setup(backbone, use_pallas)
    spec = BucketSpec(m_max=32, e_max=256, batch=4)
    chunks = graph_to_chunks(_graph(0), spec, chunk=4)
    assert chunks["seg_valid"].shape[0] > 1, "graph must span multiple chunks"
    stream = make_stream_encoder(cfg)
    pred, _ = stream(params, head, {k: jnp.asarray(v) for k, v in chunks.items()})
    ref = _one_shot(cfg, params, head, chunks)
    np.testing.assert_allclose(np.asarray(pred), np.asarray(ref), atol=1e-5)


def test_streaming_matches_one_shot_segment_sum_head():
    cfg, params, head = _setup("sage", False, head_mode="segment_sum")
    spec = BucketSpec(m_max=32, e_max=256, batch=4)
    chunks = graph_to_chunks(_graph(1), spec, chunk=4)
    stream = make_stream_encoder(cfg, head_mode="segment_sum", agg="sum")
    pred, _ = stream(params, head, {k: jnp.asarray(v) for k, v in chunks.items()})
    ref = _one_shot(cfg, params, head, chunks, head_mode="segment_sum", agg="sum")
    np.testing.assert_allclose(np.asarray(pred), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_streaming_constant_memory(use_pallas):
    """Buffer-size accounting: the scan's largest live buffer is bounded by
    one chunk and does NOT grow with the chunk count; the one-shot encoder's
    grows with the total segment count."""
    cfg, params, head = _setup("sage", use_pallas)
    spec = BucketSpec(m_max=16, e_max=64, batch=4)
    chunk = 4
    big = D.make_malnet_like(n_graphs=1, comm_range=(10, 11),
                             comm_size_range=(14, 16), seed=3)[0]
    chunks_big = graph_to_chunks(big, spec, chunk=chunk)
    small = D.make_malnet_like(n_graphs=1, comm_range=(3, 4),
                               comm_size_range=(14, 16), seed=4)[0]
    chunks_small = graph_to_chunks(small, spec, chunk=chunk)
    c_small, c_big = chunks_small["seg_valid"].shape[0], chunks_big["seg_valid"].shape[0]
    assert c_big > c_small >= 1

    stream = make_stream_encoder(cfg)
    dev = lambda ch: {k: jnp.asarray(v) for k, v in ch.items()}
    m_small = max_intermediate_bytes(lambda c: stream(params, head, c),
                                     dev(chunks_small))
    m_big = max_intermediate_bytes(lambda c: stream(params, head, c),
                                   dev(chunks_big))
    assert m_big == m_small, (
        f"streaming peak buffer grew with chunk count: {m_small} -> {m_big}")

    flat = {k: jnp.asarray(chunks_big[k].reshape((-1,) + chunks_big[k].shape[2:]))
            for k in SEG_KEYS}
    m_one_shot = max_intermediate_bytes(
        lambda f: encode_segments(params, cfg, f), flat)
    assert m_big < m_one_shot, (
        f"one-shot ({m_one_shot}b) should dwarf streaming ({m_big}b) "
        f"for a {c_big}-chunk graph")


# ---------------------------------------------------------------------------
# cache properties
# ---------------------------------------------------------------------------


def test_cache_hit_returns_bit_identical_embedding():
    cache = SegmentCache(capacity=8, d_h=HID)
    rng = np.random.default_rng(0)
    keys = [bytes([i]) * 4 for i in range(5)]
    embs = jnp.asarray(rng.normal(size=(5, HID)), jnp.float32)
    cache.put(keys, embs)
    slots = [cache.get(k) for k in keys]
    assert all(s is not None for s in slots)
    got = np.asarray(cache.gather(slots))
    assert np.array_equal(got, np.asarray(embs)), "hit must be bit-identical"


def test_cache_eviction_respects_capacity_lru():
    cache = SegmentCache(capacity=4, d_h=HID)
    rng = np.random.default_rng(1)
    keys = [bytes([i]) * 4 for i in range(10)]
    for k in keys:
        cache.put([k], jnp.asarray(rng.normal(size=(1, HID)), jnp.float32))
        assert len(cache) <= 4
    assert cache.evictions == 6
    # LRU: only the 4 most recently inserted survive
    assert [cache.peek(k) is not None for k in keys] == [False] * 6 + [True] * 4
    st = cache.stats()
    assert st["size"] == 4 and st["capacity"] == 4


def test_cache_lru_refresh_on_hit():
    cache = SegmentCache(capacity=2, d_h=HID)
    e = jnp.ones((1, HID), jnp.float32)
    cache.put([b"a"], e)
    cache.put([b"b"], 2 * e)
    assert cache.get(b"a") is not None   # refresh 'a' -> 'b' becomes LRU
    cache.put([b"c"], 3 * e)
    assert cache.peek(b"a") is not None
    assert cache.peek(b"b") is None
    assert cache.peek(b"c") is not None


def test_cache_age_counters_advance():
    cache = SegmentCache(capacity=4, d_h=HID)
    e = jnp.ones((1, HID), jnp.float32)
    cache.put([b"old"], e)
    for i in range(3):
        cache.put([bytes([i])], e)
    st = cache.stats()
    assert st["age_max_steps"] == 3 and st["age_mean_steps"] > 0


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------


def _engine(use_pallas=False, cache_enabled=True, backbone="sage"):
    cfg = ServeConfig(backbone=backbone, hidden=32, use_pallas=use_pallas,
                      max_seg_nodes=32, cache_capacity=128,
                      cache_enabled=cache_enabled, stream_chunk=4)
    return ServeEngine(cfg, seed=0)


def _offline_ref(engine, g):
    """One-shot batch encode with training-style padding (graphs/batching)."""
    segs = partition_graph(len(g.x), g.edges, engine.cfg.max_seg_nodes,
                           engine.cfg.partition, engine.cfg.partition_seed)
    ds = segment_dataset([g], engine.cfg.max_seg_nodes,
                         method=engine.cfg.partition,
                         seed=engine.cfg.partition_seed)
    si = {k: jnp.asarray(v[0]) for k, v in ds.seg_inputs(np.array([0])).items()}
    h = encode_segments(engine.params, engine.gnn_cfg, si)[:len(segs)]
    return np.asarray(G.head_apply(engine.head, h.mean(axis=0), "mlp"))


@pytest.mark.parametrize("use_pallas", [False, True])
def test_engine_matches_one_shot_across_buckets(use_pallas):
    """Requests with mixed graph sizes span several buckets of the ladder;
    every prediction must match the one-shot batch encoder (atol 1e-5)."""
    engine = _engine(use_pallas=use_pallas)
    tc = TrafficConfig(n_unique=4, n_requests=6, duplicate_rate=0.5,
                       comm_range=(1, 6), comm_size_range=(6, 28), seed=2)
    stream = make_request_stream(tc)
    results = engine.process(stream, window=3)
    assert len({bi for items in map(engine._segment_request, stream[:4])
                for _, bi, _ in items}) > 1, "traffic must span buckets"
    for g, r in zip(stream, results):
        np.testing.assert_allclose(r.pred, _offline_ref(engine, g), atol=1e-5)


def test_engine_full_hit_launches_zero_encode_kernels():
    engine = _engine()
    g = _graph(0)
    r1 = engine.process([g], window=1)[0]
    launches_before = engine.stats.encode_launches
    pallas_before = engine.stats.pallas_launches
    r2 = engine.process([g], window=1)[0]
    assert engine.stats.encode_launches == launches_before, \
        "full cache hit must not launch the encoder"
    assert engine.stats.pallas_launches == pallas_before
    assert r2.n_cache_hits == r2.n_segments
    assert np.array_equal(r1.pred, r2.pred), \
        "hit-path prediction must be bit-identical"


def test_engine_hit_slot_survives_same_window_eviction_pressure():
    """Regression: a window whose hits coexist with >= capacity new misses
    must NOT evict the hit slots before the gather — the hit request's
    prediction must equal the cache-off reference exactly."""
    tc = TrafficConfig(n_unique=4, n_requests=4, duplicate_rate=0.0,
                       comm_range=(4, 7), comm_size_range=(10, 24), seed=7)
    pool = make_request_stream(tc)
    g0, rest = pool[0], pool[1:]

    def tiny_engine(cache_enabled):
        cfg = ServeConfig(backbone="sage", hidden=32, max_seg_nodes=32,
                          cache_capacity=2, cache_enabled=cache_enabled,
                          stream_chunk=4)
        return ServeEngine(cfg, seed=0)

    eng = tiny_engine(True)
    ref = tiny_engine(False)
    eng.process([g0], window=1)          # g0's segments (partially) cached
    preds = eng.process([g0] + rest, window=4)
    ref_preds = ref.process([g0] + rest, window=4)
    for p, r in zip(preds, ref_preds):
        np.testing.assert_array_equal(p.pred, r.pred)


def test_cache_flush_keeps_jitted_ops_and_empties_contents():
    cache = SegmentCache(capacity=4, d_h=HID)
    cache.put([b"k"], jnp.ones((1, HID), jnp.float32))
    assert cache.get(b"k") is not None
    update_fn = cache._update
    cache.flush()
    assert len(cache) == 0 and cache.hits == 0 and cache.step == 0
    assert cache._update is update_fn, "flush must keep compile caches"
    assert cache.get(b"k") is None
    cache.put([b"k2"], jnp.ones((1, HID), jnp.float32))
    assert cache.get(b"k2") is not None


def test_engine_cache_disabled_always_encodes():
    engine = _engine(cache_enabled=False)
    g = _graph(0)
    engine.process([g], window=1)
    n1 = engine.stats.encoded_segments
    engine.process([g], window=1)
    assert engine.stats.encoded_segments == 2 * n1
    assert engine.stats.cache == {}


def test_engine_streaming_prediction_matches_process():
    """The constant-memory path and the bucketed path agree when the graph's
    segments all land in the catch-all bucket."""
    cfg = ServeConfig(backbone="sage", hidden=32, max_seg_nodes=32,
                      ladder=(BucketSpec(32, 256, 8),), stream_chunk=4,
                      cache_capacity=64)
    engine = ServeEngine(cfg, seed=0)
    g = _graph(1)
    pred = engine.process([g], window=1)[0].pred
    sp = engine.predict_streaming(g)
    np.testing.assert_allclose(sp, pred, atol=1e-5)


def test_traffic_duplicate_rate_controls_hit_rate():
    tc_dup = TrafficConfig(n_unique=4, n_requests=24, duplicate_rate=0.8, seed=5)
    tc_uniq = TrafficConfig(n_unique=24, n_requests=24, duplicate_rate=0.0, seed=5)
    e1, e2 = _engine(), _engine()
    e1.process(make_request_stream(tc_dup), window=4)
    e2.process(make_request_stream(tc_uniq), window=4)
    hr1 = e1.stats.cache["hit_rate"]
    hr2 = e2.stats.cache["hit_rate"]
    assert hr1 > 0.5
    assert hr1 > hr2
    assert e1.stats.encoded_segments < e1.stats.n_segments


# ---------------------------------------------------------------------------
# traffic repeat sampling (ISSUE 10 bugfix) + catch-all truncation counting
# ---------------------------------------------------------------------------


def _repeat_counts(popularity: float, seed=11, n=3000):
    tc = TrafficConfig(n_unique=4, n_requests=n, duplicate_rate=0.6,
                       popularity=popularity, seed=seed)
    stream = make_request_stream(tc)
    ids = {}
    counts = np.zeros(4, np.int64)
    for g in stream:
        gi = ids.setdefault(id(g), len(ids))
        counts[gi] += 1
    return counts


def test_traffic_repeats_uniform_over_distinct():
    """popularity=0 (the default): repeats spread evenly over distinct
    seen graphs.  The pre-fix stream sampled the seen list WITH
    duplicates — a Polya urn where every repeat compounded — so its
    counts were heavily skewed; uniform sampling keeps max/min tight."""
    counts = _repeat_counts(0.0)
    assert counts.min() > 0
    assert counts.max() / counts.min() < 1.3


def test_traffic_popularity_knob_restores_skew():
    """popularity=1 is the explicit rich-get-richer (old) behavior; it
    must be visibly more skewed than the uniform default on the same
    seed, and the skew must grow with the exponent."""
    flat = _repeat_counts(0.0)
    rich = _repeat_counts(1.0)
    richer = _repeat_counts(3.0)

    def spread(c):
        return c.max() / max(c.min(), 1)

    assert spread(rich) > spread(flat)
    assert spread(richer) > spread(rich)


def test_truncation_counts_math():
    from repro.serve.buckets import truncation_counts
    spec = BucketSpec(32, 256, 8)
    assert truncation_counts(40, 300, spec) == (8, 44)
    assert truncation_counts(32, 256, spec) == (0, 0)   # exact fit
    assert truncation_counts(5, 7, spec) == (0, 0)      # under: never negative


def test_serve_stats_truncation_in_summary():
    from repro.serve.engine import ServeStats
    s = ServeStats()
    assert s.summary()["truncated_nodes"] == 0
    assert s.summary()["truncated_edges"] == 0
    s.truncated_nodes += 3
    s.truncated_edges += 1
    out = s.summary()
    assert out["truncated_nodes"] == 3 and out["truncated_edges"] == 1
