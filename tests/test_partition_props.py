"""Partitioner invariants, property-style across all four PARTITIONERS.

For random graphs (including DIRECTED edge lists — edges are no longer
assumed pre-symmetrized after the _adjacency fix) every partitioner must:
  * cover every node in >= 1 segment,
  * respect the max_size cap on every segment,
  * be deterministic under a fixed seed,
  * return int32 node ids within range.
Plus the specific regressions: BFS coverage on purely-directed star/chain
graphs, and louvain's BFS fallback when networkx is missing.
"""
import sys

import numpy as np

from _hypothesis_compat import given, settings, st
from repro.graphs.partition import (PARTITIONERS, bfs_partition,
                                    louvain_partition, partition_graph)


def _random_graph(n, avg_deg, seed, directed=True):
    rng = np.random.default_rng(seed)
    m = max(1, int(n * avg_deg / 2))
    edges = rng.integers(0, n, (m, 2)).astype(np.int64)
    edges = edges[edges[:, 0] != edges[:, 1]]
    if len(edges) == 0:
        edges = np.asarray([[0, min(1, n - 1)]], np.int64)
    if not directed:
        edges = np.concatenate([edges, edges[:, ::-1]], axis=0)
    return edges


@settings(max_examples=12, deadline=None)
@given(method=st.sampled_from(sorted(PARTITIONERS)),
       n=st.integers(2, 40),
       avg_deg=st.integers(1, 6),
       max_size=st.integers(2, 12),
       seed=st.integers(0, 10_000),
       directed=st.booleans())
def test_partitioner_invariants(method, n, avg_deg, max_size, seed, directed):
    edges = _random_graph(n, avg_deg, seed, directed)
    segs = partition_graph(n, edges, max_size, method, seed)
    assert len(segs) >= 1
    covered = set()
    for s in segs:
        assert s.dtype == np.int32
        assert len(s) >= 1
        assert len(s) <= max_size, f"{method} violated the max_size cap"
        assert (s >= 0).all() and (s < n).all()
        covered.update(int(u) for u in s)
    assert covered == set(range(n)), \
        f"{method} left nodes uncovered: {set(range(n)) - covered}"
    # determinism under a fixed seed
    again = partition_graph(n, edges, max_size, method, seed)
    assert len(again) == len(segs)
    assert all((a == b).all() for a, b in zip(segs, again))


def test_bfs_covers_directed_star():
    """Regression: with a one-directional edge list (hub -> leaves) the old
    _adjacency only walked forward edges; leaves whose only edge POINTS AT
    them were reachable, but a sink-only hub (leaves -> hub) never expanded.
    Both orientations must now grow identical locality regions."""
    n = 9
    hub_out = np.asarray([[0, i] for i in range(1, n)])   # hub -> leaves
    hub_in = hub_out[:, ::-1].copy()                      # leaves -> hub
    for edges in (hub_out, hub_in):
        segs = bfs_partition(n, edges, max_size=n, seed=0)
        assert sorted(int(u) for s in segs for u in s) == list(range(n))
        # the star is one connected region — a single BFS from any seed
        # should reach everything through the symmetrized adjacency
        assert len(segs) == 1


def test_bfs_directed_chain_locality():
    """A directed path 0->1->...->k must form contiguous BFS regions from
    either end (symmetrized adjacency), not one region per stranded node."""
    k = 12
    edges = np.asarray([[i, i + 1] for i in range(k)])
    segs = bfs_partition(k + 1, edges, max_size=4, seed=3)
    assert sorted(int(u) for s in segs for u in s) == list(range(k + 1))
    assert all(len(s) <= 4 for s in segs)
    # locality: every segment of a path graph spans a contiguous id range
    for s in segs:
        lo, hi = int(min(s)), int(max(s))
        assert hi - lo == len(s) - 1


def test_louvain_falls_back_to_bfs_without_networkx(monkeypatch):
    """louvain must degrade to the BFS partitioner instead of raising
    ImportError at call time when networkx is absent."""
    edges = _random_graph(20, 3, seed=4, directed=False)
    monkeypatch.setitem(sys.modules, "networkx", None)  # import -> ImportError
    segs = louvain_partition(20, edges, max_size=6, seed=4)
    expect = bfs_partition(20, edges, max_size=6, seed=4)
    assert len(segs) == len(expect)
    assert all((a == b).all() for a, b in zip(segs, expect))
    covered = {int(u) for s in segs for u in s}
    assert covered == set(range(20))
