"""The §Perf optimization toggles must be EXACT rewrites, not approximations:
every toggle's two modes produce allclose outputs (the hillclimb changes the
cost model only)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.common as C
import repro.models.moe as M
from repro.configs.base import MoEConfig


@pytest.fixture(autouse=True)
def _restore_toggles():
    yield
    C.CACHE_UPDATE = "onehot"
    C.ATTN_IMPL = "naive"
    C.GQA_IMPL = "repeat"
    M.DISPATCH_MODE = "einsum"


def test_moe_dispatch_modes_equal():
    rng = np.random.default_rng(0)
    for cf in (4.0, 0.4):  # ample + dropping capacity
        cfg = MoEConfig(num_experts=8, top_k=2, expert_d_ff=32,
                        capacity_factor=cf)
        p = M.moe_params(jax.random.key(0), 16, cfg, "silu")
        x = jnp.asarray(rng.normal(size=(2, 16, 16)), jnp.float32)
        M.DISPATCH_MODE = "einsum"
        o1, a1 = M.moe_forward(p, x, cfg, "silu")
        M.DISPATCH_MODE = "gather"
        o2, a2 = M.moe_forward(p, x, cfg, "silu")
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=3e-5, atol=3e-5)
        np.testing.assert_allclose(float(a1), float(a2), rtol=1e-6)


def test_cache_write_modes_equal():
    rng = np.random.default_rng(1)
    idx = jnp.asarray([0, 5, 7], jnp.int32)
    for shape, nshape in [((3, 8, 2, 4), (3, 1, 2, 4)), ((3, 8, 4), (3, 1, 4))]:
        cache = jnp.asarray(rng.normal(size=shape), jnp.float32)
        new = jnp.asarray(rng.normal(size=nshape), jnp.float32)
        C.CACHE_UPDATE = "onehot"
        a = C.write_cache(cache, new, idx)
        C.CACHE_UPDATE = "dus"
        b = C.write_cache(cache, new, idx)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_chunked_attention_equals_naive():
    rng = np.random.default_rng(2)
    B, S, H, KV, D = 2, 256, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, D)), jnp.float32)
    ref = C.sdpa(q, k, v, causal=True)
    out = C.chunked_causal_attention(q, k, v, chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    refw = C.sdpa(q, k, v, causal=True, window=96)
    outw = C.chunked_causal_attention(q, k, v, window=96, chunk=64)
    np.testing.assert_allclose(np.asarray(outw), np.asarray(refw),
                               rtol=2e-5, atol=2e-5)


def test_full_model_invariant_under_all_toggles():
    """End-to-end: a reduced MoE arch forward is identical under the
    optimized configuration (gather dispatch + chunked attention)."""
    from repro.configs import get_config, reduced
    from repro.models import build_model
    cfg = reduced(get_config("arctic-480b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    toks = jnp.asarray(np.random.default_rng(3).integers(
        0, cfg.vocab_size, (2, 32)), jnp.int32)
    M.DISPATCH_MODE, C.ATTN_IMPL = "einsum", "naive"
    h1 = model.forward(params, {"tokens": toks})
    M.DISPATCH_MODE, C.ATTN_IMPL = "gather", "chunked"
    h2 = model.forward(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=5e-5, atol=5e-5)


def test_grouped_gqa_equals_repeat():
    rng = np.random.default_rng(4)
    B, Sq, Sk, H, KV, D = 2, 16, 16, 8, 2, 32
    q = jnp.asarray(rng.normal(size=(B, Sq, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Sk, KV, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Sk, KV, D)), jnp.float32)
    for kwargs in [dict(causal=True), dict(causal=True, window=5),
                   dict(causal=False, kv_valid_len=jnp.asarray([7, 12]))]:
        C.GQA_IMPL = "repeat"
        a = C.sdpa(q, k, v, **kwargs)
        C.GQA_IMPL = "grouped"
        b = C.sdpa(q, k, v, **kwargs)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)
    p = C.attn_params(jax.random.key(0), 64, H, KV, D)
    x = jnp.asarray(rng.normal(size=(B, 1, 64)), jnp.float32)
    ck = jnp.asarray(rng.normal(size=(B, 8, KV, D)), jnp.float32)
    cv = jnp.asarray(rng.normal(size=(B, 8, KV, D)), jnp.float32)
    pos = jnp.asarray([3, 5], jnp.int32)
    C.GQA_IMPL = "repeat"
    o1, _, _ = C.attn_decode(p, x, ck, cv, pos, num_heads=H, num_kv=KV,
                             head_dim=D, rope_theta=1e4)
    C.GQA_IMPL = "grouped"
    o2, _, _ = C.attn_decode(p, x, ck, cv, pos, num_heads=H, num_kv=KV,
                             head_dim=D, rope_theta=1e4)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)
    C.GQA_IMPL = "repeat"
