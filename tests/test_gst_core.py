"""GST core semantics: sampling, SED (Eq. 1), table staleness, variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import gst as G
from repro.core import segment as seg
from repro.core import embedding_table as tbl

HSET = settings(max_examples=10, deadline=None)


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------


@given(B=st.integers(1, 8), J=st.integers(2, 16), S=st.integers(1, 3),
       seed=st.integers(0, 1000))
@HSET
def test_sample_segments_valid_and_distinct(B, J, S, seed):
    S = min(S, J)
    rng = np.random.default_rng(seed)
    valid = (rng.uniform(size=(B, J)) < 0.7).astype(np.float32)
    valid[:, 0] = 1.0
    n_valid = valid.sum(-1)
    idx = seg.sample_segments(jax.random.key(seed), jnp.asarray(valid), S)
    idx = np.asarray(idx)
    for b in range(B):
        chosen = idx[b]
        assert len(set(chosen.tolist())) == S  # distinct
        # only valid segments chosen while enough valid ones exist
        if n_valid[b] >= S:
            assert all(valid[b, c] == 1.0 for c in chosen)


def test_sampling_is_uniform_over_valid():
    B, J, n = 1, 5, 4000
    valid = jnp.ones((B, J)).at[0, 3].set(0.0)
    counts = np.zeros(J)
    for i in range(n):
        idx = seg.sample_segments(jax.random.key(i), valid, 1)
        counts[int(idx[0, 0])] += 1
    assert counts[3] == 0
    freq = counts[counts > 0] / n
    np.testing.assert_allclose(freq, 0.25, atol=0.03)


# ---------------------------------------------------------------------------
# SED (Eq. 1)
# ---------------------------------------------------------------------------


@given(J=st.integers(2, 12), p=st.floats(0.05, 0.95), seed=st.integers(0, 500))
@HSET
def test_sed_weights_values(J, p, seed):
    """η ∈ {p + (1-p)J/S, 0, 1} exactly as Eq. 1 prescribes."""
    B, S = 4, 1
    valid = jnp.ones((B, J))
    fresh = jnp.zeros((B, J)).at[jnp.arange(B), 0].set(1.0)
    eta, drop = seg.sed_weights(jax.random.key(seed), valid, fresh, p, S)
    eta = np.asarray(eta)
    expect_fresh = p + (1 - p) * J / S
    np.testing.assert_allclose(eta[:, 0], expect_fresh, rtol=1e-6)
    stale_vals = eta[:, 1:].reshape(-1)
    assert set(np.round(stale_vals, 6)).issubset({0.0, 1.0})


def test_sed_unbiased_fresh_expectation():
    """E[⊕ η h] == ⊕ h when stale == fresh (no staleness): the weighting
    must be an unbiased estimator of the true mean embedding."""
    rng = np.random.default_rng(0)
    B, J, d, p = 2, 6, 8, 0.35
    h = jnp.asarray(rng.normal(size=(B, J, d)), jnp.float32)
    valid = jnp.ones((B, J))
    acc = 0
    n = 3000
    for i in range(n):
        k1, k2 = jax.random.split(jax.random.key(i))
        idx = seg.sample_segments(k1, valid, 1)
        fresh = seg.sampled_mask(idx, J)
        eta, _ = seg.sed_weights(k2, valid, fresh, p, 1)
        acc = acc + seg.aggregate(h, eta, valid, "mean")
    mc = np.asarray(acc) / n
    true = np.asarray(jnp.mean(h, axis=1))
    np.testing.assert_allclose(mc, true, atol=0.05)


def test_sed_limits():
    """p=1 keeps all stale (η=1 everywhere); p=0 drops all stale (GST-One)."""
    B, J = 3, 5
    valid = jnp.ones((B, J))
    fresh = jnp.zeros((B, J)).at[:, 2].set(1.0)
    eta1, _ = seg.sed_weights(jax.random.key(0), valid, fresh, 1.0, 1)
    np.testing.assert_allclose(np.asarray(eta1), 1.0)
    eta0, _ = seg.sed_weights(jax.random.key(0), valid, fresh, 0.0, 1)
    expect = np.zeros((B, J)); expect[:, 2] = J
    np.testing.assert_allclose(np.asarray(eta0), expect)


# ---------------------------------------------------------------------------
# embedding table
# ---------------------------------------------------------------------------


def test_table_update_and_staleness_age():
    t = tbl.init_table(5, 3, 4)
    ids = jnp.asarray([1, 3])
    idx = jnp.asarray([[0], [2]])
    h = jnp.ones((2, 1, 4))
    t = tbl.update_sampled(t, ids, idx, h, jnp.asarray(7, jnp.int32))
    assert bool(t.initialized[1, 0]) and bool(t.initialized[3, 2])
    assert int(t.age[1, 0]) == 7
    assert not bool(t.initialized[0, 0])
    emb, init = tbl.lookup(t, jnp.asarray([1]))
    np.testing.assert_allclose(np.asarray(emb[0, 0]), 1.0)


def test_staleness_grows_like_paper_bound():
    """Visiting each graph once per epoch with S=1 of J segments, the oldest
    entry is ~ n·J/S iterations stale (paper §3.4)."""
    n, J, d = 8, 4, 2
    t = tbl.init_table(n, J, d)
    step = 0
    rng = np.random.default_rng(0)
    for epoch in range(40):
        for g in range(n):
            j = rng.integers(0, J)
            t = tbl.update_sampled(t, jnp.asarray([g]), jnp.asarray([[j]]),
                                   jnp.zeros((1, 1, d)), jnp.asarray(step))
            step += 1
    ages = step - np.asarray(t.age)[np.asarray(t.initialized)]
    assert ages.max() > n  # at least n-iterations stale (paper's lower bound)
    # "approximately nJ/S-iteration stale" (paper §3.4) — the bulk of entries,
    # allowing a geometric tail for the max
    assert np.quantile(ages, 0.9) < 3 * n * J
    assert ages.max() < 10 * n * J


# ---------------------------------------------------------------------------
# variant semantics
# ---------------------------------------------------------------------------


def _tiny_setup(variant, J=4, d=8, B=4, n=16):
    from repro.optim import make_optimizer

    def encode(w, seg_inputs):
        # linear "backbone": mean of tokens one-hot embedded by w
        x = jax.nn.one_hot(seg_inputs["tokens"], 16) @ w  # (N, L, d)
        return jnp.mean(x, axis=1), jnp.zeros((), jnp.float32)

    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(16, d)), jnp.float32)
    head = G.head_init(jax.random.key(1), d, 3, "mlp")
    opt = make_optimizer("adam", lr=1e-2)
    state = G.TrainState(w, head, opt.init((w, head)),
                         tbl.init_table(n, J, d), jnp.zeros((), jnp.int32))
    batch = G.GSTBatch(
        {"tokens": jnp.asarray(rng.integers(0, 16, (B, J, 5)), jnp.int32)},
        jnp.ones((B, J), jnp.float32), jnp.arange(B, dtype=jnp.int32),
        jnp.asarray(rng.integers(0, 3, B), jnp.int32))
    step = G.make_train_step(encode, opt, G.VARIANTS[variant])
    return state, batch, step, encode, opt


@pytest.mark.parametrize("variant", list(G.VARIANTS))
def test_all_variants_run_and_learn_shape(variant):
    state, batch, step, *_ = _tiny_setup(variant)
    new_state, m = jax.jit(step)(state, batch, jax.random.key(0))
    assert np.isfinite(float(m["loss"]))
    if G.VARIANTS[variant].use_table:
        assert bool(new_state.table.initialized.any())
    else:
        assert not bool(new_state.table.initialized.any())


def test_gst_equals_full_when_sampling_everything():
    """With S=J and fresh recompute, gst's loss == full's loss on the same
    batch (the stale set is empty)."""
    from repro.optim import make_optimizer
    J = 3
    state, batch, _, encode, opt = _tiny_setup("gst", J=J)
    full_step = G.make_train_step(encode, opt, G.VARIANTS["full"])
    gst_step = G.make_train_step(encode, opt, G.VARIANTS["gst"], num_sampled=J)
    _, m_full = jax.jit(full_step)(state, batch, jax.random.key(0))
    _, m_gst = jax.jit(gst_step)(state, batch, jax.random.key(0))
    np.testing.assert_allclose(float(m_full["loss"]), float(m_gst["loss"]),
                               rtol=1e-5)


def test_finetune_trains_head_only():
    state, batch, step, encode, opt = _tiny_setup("gst_efd")
    state, _ = jax.jit(step)(state, batch, jax.random.key(0))
    refresh = jax.jit(G.make_refresh_step(encode))
    state = refresh(state, batch)
    assert bool(state.table.initialized[:4].all())
    from repro.optim import make_optimizer
    ft_opt = make_optimizer("adam", lr=1e-2)
    state = state._replace(opt_state=ft_opt.init(state.head))
    ft = jax.jit(G.make_finetune_step(ft_opt))
    bb_before = state.backbone
    head_before = state.head
    state, m = ft(state, batch)
    assert np.isfinite(float(m["loss"]))
    np.testing.assert_array_equal(np.asarray(bb_before),
                                  np.asarray(state.backbone))
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), head_before, state.head)
    assert max(jax.tree_util.tree_leaves(diffs)) > 0
