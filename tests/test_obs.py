"""Observability spine (src/repro/obs/) — ISSUE 7 contract.

  * the registry is thread-safe (store publishes from the feeder,
    write-back, and consumer threads at once), rejects kind collisions,
    and its delta()/reset() give honest per-interval rates;
  * the disabled path is a true no-op AND invisible to jit: the jaxpr of
    the gst_efd train step is identical with telemetry installed or not
    (the host-side-only rule that keeps --metrics off zero-cost);
  * summarize() is the one percentile implementation — histogram
    percentiles agree with numpy's within the bucket resolution;
  * spans recorded from multiple threads export structurally valid
    Chrome-trace JSON (validate_chrome_trace);
  * the StalenessProbe row-age histogram is bit-consistent with
    store.snapshot() ages once write-backs are flushed;
  * store.publish_counters mirrors the counter dict into the registry
    exactly once per increment, surviving the counters-reset idiom;
  * the serve engine publishes latency and prediction-staleness;
  * Obs round-trips meta/tick/summary through the JSONL stream and
    restores the process-wide globals on close.
"""
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gst as G
from repro.dist import pipeline as DP
from repro.graphs import data as D
from repro.graphs.gnn import GNNConfig, gnn_init, make_encode_fn
from repro.obs import (MetricsRegistry, NullRegistry, Obs, StalenessProbe,
                       dict_delta, get_registry, get_tracer, null_registry,
                       sed_age_bound, sed_drop_stats, set_registry, summarize,
                       validate_chrome_trace, wb_skip_rate)
from repro.obs.gate import GateFailure, require_families
from repro.obs.metrics import Histogram, exponential_buckets
from repro.obs.trace import NullTracer, Tracer, null_tracer, set_tracer
from repro.optim import make_optimizer
from repro.store import StoreCounters, TieredStore

HID = 8


@pytest.fixture(scope="module")
def dataset():
    graphs = D.make_malnet_like(n_graphs=24, seed=0)
    ds, _ = DP.segment_dataset_shared(graphs, 16, seed=0)
    return ds


@pytest.fixture(autouse=True)
def _clean_globals():
    """Every test starts and ends with the null registry/tracer installed
    (the process default) — no cross-test telemetry bleed."""
    set_registry(null_registry())
    set_tracer(null_tracer())
    yield
    set_registry(null_registry())
    set_tracer(null_tracer())


def _state(ds):
    cfg = GNNConfig(backbone="sage", n_feat=ds.x.shape[-1], hidden=HID)
    enc = make_encode_fn(cfg)
    key = jax.random.key(0)
    bb = gnn_init(key, cfg)
    head = G.head_init(jax.random.fold_in(key, 1), HID, 5, "mlp")
    opt = make_optimizer("adam", lr=5e-3)
    from repro.core import embedding_table as tbl
    return enc, opt, G.TrainState(bb, head, opt.init((bb, head)),
                                  tbl.init_table(ds.n, ds.j_max, HID),
                                  jnp.zeros((), jnp.int32))


def _batch(ds, ids):
    return jax.tree_util.tree_map(jnp.asarray, DP._assemble(ds, ids))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_metric_kinds_and_collisions():
    reg = MetricsRegistry()
    reg.inc("store.faults", 3, unit="rows")
    reg.inc("store.faults", 2)
    reg.set("store.occupancy", 7)
    h = reg.histogram("lat", buckets=(1.0, 2.0, 4.0), unit="ms")
    h.observe(1.5)
    snap = reg.snapshot()
    assert snap["store.faults"]["value"] == 5
    assert snap["store.faults"]["type"] == "counter"
    assert snap["store.occupancy"]["value"] == 7
    assert snap["lat"]["count"] == 1
    # a name is one kind forever — silent shadowing would corrupt deltas
    with pytest.raises(TypeError):
        reg.set("store.faults", 1)
    with pytest.raises(TypeError):
        reg.histogram("store.occupancy")


def test_registry_thread_safety():
    reg = MetricsRegistry()
    N_THREADS, N_OPS = 8, 500

    def work(t):
        h = reg.histogram("h", buckets=tuple(float(2 ** i) for i in range(8)))
        for i in range(N_OPS):
            reg.inc("c")                       # get-or-create under race
            h.observe(float(i % 100))
    threads = [threading.Thread(target=work, args=(t,))
               for t in range(N_THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert reg.get("c").value == N_THREADS * N_OPS
    assert reg.get("h").count == N_THREADS * N_OPS


def test_histogram_percentiles_within_bucket_resolution():
    rng = np.random.default_rng(0)
    data = rng.exponential(scale=20.0, size=5000)
    buckets = exponential_buckets(0.1, 2.0, 20)
    h = Histogram("x", buckets=buckets)
    h.observe_many(data)
    for q in (50, 99):
        exact = float(np.percentile(data, q))
        approx = h.percentile(q)
        # within the containing bucket: the bucket's full width is the
        # resolution bound
        idx = np.searchsorted(buckets, exact)
        lo = buckets[idx - 1] if idx > 0 else 0.0
        hi = buckets[idx] if idx < len(buckets) else data.max()
        assert lo <= approx <= hi + 1e-9, (q, exact, approx, lo, hi)


def test_summarize_list_and_histogram_agree():
    data = list(np.linspace(1.0, 400.0, 777))
    h = Histogram("x", buckets=exponential_buckets(0.5, 2.0, 16))
    h.observe_many(data)
    s_list, s_hist = summarize(data), summarize(h)
    assert s_list["count"] == s_hist["count"] == 777
    assert s_list["min"] == s_hist["min"] and s_list["max"] == s_hist["max"]
    assert np.isclose(s_list["mean"], s_hist["mean"])
    # percentiles agree to bucket resolution (factor-2 ladder)
    assert s_hist["p50"] / s_list["p50"] < 2.0
    assert s_list["p50"] / s_hist["p50"] < 2.0


def test_delta_and_reset_semantics():
    reg = MetricsRegistry()
    reg.inc("c", 10)
    reg.histogram("h", buckets=(1.0, 2.0)).observe(1.5)
    d1 = reg.delta()
    assert d1["c"] == 10 and d1["h.count"] == 1
    reg.inc("c", 3)
    d2 = reg.delta()
    assert d2["c"] == 3 and d2["h.count"] == 0   # only the interval's change
    reg.reset()                                   # a fresh run phase:
    assert reg.get("c") is None                   # metrics AND marks drop
    reg.inc("c", 2)
    assert reg.delta()["c"] == 2                  # no stale baseline
    assert dict_delta({"a": 5, "b": 1}, {"a": 2}) == {"a": 3, "b": 1}


def test_null_registry_is_noop_and_shared():
    reg = NullRegistry()
    assert not reg.enabled
    reg.inc("x", 5)
    reg.set("y", 2)
    reg.histogram("z").observe(1.0)
    assert reg.snapshot() == {} and reg.summary() == {}
    # handles are shared singletons — no allocation on the disabled path
    assert reg.counter("a") is reg.histogram("b")
    assert null_registry() is null_registry()


# ---------------------------------------------------------------------------
# disabled-path invariant: telemetry never touches the jaxpr
# ---------------------------------------------------------------------------


def test_train_step_jaxpr_identical_with_obs_installed(dataset):
    """The host-side-only rule, asserted: installing a live registry +
    tracer changes NOTHING inside jit — same jaxpr, bit for bit."""
    ds = dataset
    enc, opt, state = _state(ds)
    step = G.make_train_step(enc, opt, G.VARIANTS["gst_efd"], keep_prob=0.5)
    batch = _batch(ds, np.arange(4, dtype=np.int64))
    rng = jax.random.PRNGKey(0)

    baseline = str(jax.make_jaxpr(step)(state, batch, rng))
    obs = Obs(metrics=True, trace_out="unused.json", install=True)
    try:
        assert get_registry() is obs.registry and get_registry().enabled
        instrumented = str(jax.make_jaxpr(step)(state, batch, rng))
    finally:
        obs.uninstall()
    assert instrumented == baseline


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------


def test_tracer_multithreaded_export_is_valid_chrome_trace(tmp_path):
    tr = Tracer()
    set_tracer(tr)
    gate = threading.Barrier(3)   # overlap lifetimes: distinct thread ids

    def worker():
        gate.wait()
        for i in range(5):
            with tr.span("feeder.assemble", batch=i):
                pass
    threads = [threading.Thread(target=worker, name=f"w{k}")
               for k in range(3)]
    for t in threads:
        t.start()
    with tr.span("train.step", epoch=0):
        with tr.span("store.commit"):
            pass
    tr.instant("epoch.end", epoch=0)
    for t in threads:
        t.join()

    path = tmp_path / "trace.json"
    tr.export(str(path))
    payload = json.loads(path.read_text())
    assert validate_chrome_trace(payload) == []
    evs = payload["traceEvents"]
    names = {e["args"]["name"] for e in evs if e.get("ph") == "M"}
    assert {"w0", "w1", "w2"} <= names           # thread_name metadata
    xs = [e for e in evs if e.get("ph") == "X"]
    assert len(xs) == 3 * 5 + 2
    assert all(e["dur"] >= 1 for e in xs)
    # spans from 4 distinct threads landed in one stream
    assert len({e["tid"] for e in xs}) == 4


def test_null_tracer_refuses_export():
    nt = NullTracer()
    assert nt.span("x") is nt.span("y")          # one shared no-op span
    assert len(nt) == 0
    with pytest.raises(RuntimeError):
        nt.export("/tmp/never.json")


def test_validate_chrome_trace_catches_breakage():
    bad = {"traceEvents": [
        {"name": "a", "ph": "X", "ts": 10, "dur": 1, "pid": 1, "tid": 1},
        {"name": "b", "ph": "X", "ts": 5, "dur": -1, "pid": 1, "tid": 1},
        {"name": "c", "ph": "E", "ts": 20, "pid": 1, "tid": 1},
    ]}
    problems = validate_chrome_trace(bad)
    assert any("monotonic" in p for p in problems)
    assert any("bad dur" in p for p in problems)
    assert any("E without matching B" in p for p in problems)


# ---------------------------------------------------------------------------
# staleness
# ---------------------------------------------------------------------------


def test_sed_drop_stats_hand_case():
    seg_valid = np.array([[1, 1, 1, 0]])
    init = np.array([[True, True, False, False]])
    s = sed_drop_stats(seg_valid, init, num_sampled=1, keep_prob=0.5)
    # 3 valid slots, 2 initialized, 1 fresh -> 1 SED-eligible stale slot
    assert s["valid_segments"] == 3
    assert s["sed_eligible"] == 1
    assert s["sed_dropped_expected"] == 0.5
    assert np.isclose(s["sed_drop_rate"], 0.5 / 3)


def test_sed_age_bound_formula():
    b = sed_age_bound(j_max=4, num_sampled=1, steps_per_epoch=10, safety=2.0)
    assert np.isclose(b, np.log(100.0) * 4 * 10 * 2.0)
    # more sampling -> fresher rows -> tighter bound
    assert sed_age_bound(j_max=4, num_sampled=2, steps_per_epoch=10) < b


def test_staleness_histogram_bit_consistent_with_snapshot(dataset):
    """ages_init (the probe's view, host tier included) must agree with
    the flushed snapshot() ages — the histogram built from either is
    identical bucket for bucket."""
    ds = dataset
    enc, opt, state = _state(ds)
    cap = max(-(-ds.n // 4), 4)
    store = TieredStore(ds.n, ds.j_max, HID, device_rows=cap)
    state = state._replace(table=store.init_device_table())
    step = jax.jit(G.make_train_step(enc, opt, G.VARIANTS["gst_efd"],
                                     keep_prob=0.5))
    try:
        rng = np.random.default_rng(0)
        for t in range(6):
            ids = rng.choice(ds.n, size=4, replace=False).astype(np.int64)
            table, slots = store.prepare(state.table, ids)
            state = state._replace(table=table)
            state, _ = step(state, _batch(ds, ids)._replace(
                graph_ids=jnp.asarray(slots)), jax.random.PRNGKey(t))
        store.flush_writebacks()
        step_now = int(jax.device_get(state.step))

        probe_live = StalenessProbe(seg_valid=ds.seg_valid,
                                    registry=MetricsRegistry())
        live = probe_live.observe(store, state.table, step_now)
        snap = store.snapshot(state.table)
        probe_snap = StalenessProbe(seg_valid=ds.seg_valid,
                                    registry=MetricsRegistry())
        again = probe_snap.observe_ages(np.asarray(snap.age),
                                        np.asarray(snap.initialized),
                                        step_now)
        h1 = probe_live.registry.get("staleness.row_age").snapshot()
        h2 = probe_snap.registry.get("staleness.row_age").snapshot()
        assert h1["counts"] == h2["counts"] and h1["count"] == h2["count"]
        assert live["row_age_steps"] == again["row_age_steps"]
        assert live["init_fraction"] > 0
        assert h1["count"] > 0, "training must have initialized rows"
    finally:
        store.close()


# ---------------------------------------------------------------------------
# store publication
# ---------------------------------------------------------------------------


def test_store_publish_counters_mirrors_and_survives_reset(dataset):
    ds = dataset
    enc, opt, state = _state(ds)
    cap = max(-(-ds.n // 4), 4)
    store = TieredStore(ds.n, ds.j_max, HID, device_rows=cap)
    state = state._replace(table=store.init_device_table())
    step = jax.jit(G.make_train_step(enc, opt, G.VARIANTS["gst_efd"],
                                     keep_prob=0.5))
    reg = MetricsRegistry()
    set_registry(reg)
    try:
        rng = np.random.default_rng(1)
        for t in range(4):
            ids = rng.choice(ds.n, size=4, replace=False).astype(np.int64)
            table, slots = store.prepare(state.table, ids)
            state = state._replace(table=table)
            state, _ = step(state, _batch(ds, ids)._replace(
                graph_ids=jnp.asarray(slots)), jax.random.PRNGKey(t))
        store.flush_writebacks()
        store.publish_counters()
        c = store.counters
        snap = reg.snapshot()
        assert snap["store.lookups"]["value"] == c.lookups
        assert snap["store.faults"]["value"] == c.misses
        assert snap["store.evictions"]["value"] == c.evictions
        assert snap["store.bytes_h2d"]["value"] == c.bytes_h2d
        # publishing again without new work is a no-op (diff-publish)
        store.publish_counters()
        assert reg.snapshot()["store.lookups"]["value"] == c.lookups
        # the counters-reset idiom (bench_store, cache.flush) re-baselines:
        # registry values stay cumulative, no double count, no negatives
        before = reg.snapshot()["store.lookups"]["value"]
        store.counters = StoreCounters()
        store.publish_counters()
        assert reg.snapshot()["store.lookups"]["value"] == before
        assert wb_skip_rate({"evictions": 10, "wb_skipped_rows": 4}) == 0.4
    finally:
        store.close()


# ---------------------------------------------------------------------------
# serve publication
# ---------------------------------------------------------------------------


def test_serve_engine_publishes_latency_and_prediction_staleness():
    from repro.serve import (ServeConfig, ServeEngine, TrafficConfig,
                             make_request_stream)
    reg = MetricsRegistry()
    set_registry(reg)
    cfg = ServeConfig(backbone="sage", hidden=32, max_seg_nodes=32,
                      cache_capacity=128, cache_enabled=True, stream_chunk=4)
    engine = ServeEngine(cfg, seed=0)
    try:
        tc = TrafficConfig(n_unique=3, n_requests=8, duplicate_rate=0.7,
                           seed=3)
        engine.process(make_request_stream(tc), window=4)
        snap = reg.snapshot()
        assert snap["serve.requests"]["value"] == 8
        assert snap["serve.latency_ms"]["count"] == 8
        ps = snap["serve.prediction_staleness"]
        assert ps["count"] > 0, "duplicate traffic must read cached rows"
        # engine-local histogram and registry histogram see the same events
        assert engine.stats.latency.count == 8
    finally:
        engine.close()


# ---------------------------------------------------------------------------
# export / lifecycle / gate
# ---------------------------------------------------------------------------


def test_obs_jsonl_roundtrip_and_uninstall(tmp_path):
    out = tmp_path / "obs.jsonl"
    obs = Obs(metrics_out=str(out), trace_out=str(tmp_path / "t.json"))
    assert get_registry() is obs.registry
    obs.exporter.meta(run="unit")
    obs.registry.inc("store.faults", 4)
    with get_tracer().span("train.step"):
        pass
    rec = obs.tick(step=1, epoch=0)
    assert rec["delta"]["store.faults"] == 4
    obs.registry.inc("store.faults", 1)
    summary = obs.close(wall_s=1.0)
    assert summary["metrics"]["store.faults"] == 5

    lines = [json.loads(l) for l in out.read_text().splitlines()]
    assert [l["type"] for l in lines] == ["meta", "tick", "summary"]
    assert lines[1]["step"] == 1 and lines[1]["delta"]["store.faults"] == 4
    assert lines[2]["n_ticks"] == 1
    # close() exported the trace and restored the process globals
    trace = json.loads((tmp_path / "t.json").read_text())
    assert validate_chrome_trace(trace) == []
    assert not get_registry().enabled and not get_tracer().enabled
    assert obs.close() is None                   # idempotent


def test_obs_disabled_is_null(tmp_path):
    obs = Obs()          # no flags: everything off
    assert not obs.enabled
    assert isinstance(obs.registry, NullRegistry)
    assert obs.tick(step=0) is None
    assert obs.close() is None


def test_gate_require_families_prefix_match():
    summary = {"metrics": {"staleness.row_age": {"count": 3},
                           "exchange.bytes.ring.f32": 100}}
    names = require_families(
        summary, ("staleness.row_age", "exchange.bytes."), "t.jsonl")
    assert names == ["exchange.bytes.ring.f32", "staleness.row_age"]
    with pytest.raises(GateFailure):
        require_families(summary, ("serve.latency_ms",), "t.jsonl")
