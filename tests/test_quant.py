"""Payload pack/unpack kernels (kernels/quant.py) vs the jnp reference,
plus the stochastic-rounding statistical contracts the compressed exchange
wire format (dist/exchange.PayloadCodec) relies on.

Pallas kernels run in interpret mode on CPU, same validation method as
test_kernels.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.ops import dequantize_payload, quantize_payload
from repro.kernels.quant import (PAYLOAD_DTYPES, dequantize_rows,
                                 dequantize_rows_ref, quantize_rows,
                                 quantize_rows_ref)

HSET = settings(max_examples=8, deadline=None)

COMPRESSED = [d for d in PAYLOAD_DTYPES if d != "f32"]


def _bits(shape, seed=0):
    return jax.random.bits(jax.random.PRNGKey(seed), shape, jnp.uint32)


# ---------------------------------------------------------------------------
# pallas kernel vs jnp reference: bit parity (same rounding bits in, same
# payload out — both rounding modes, both dtypes)
# ---------------------------------------------------------------------------


@given(r=st.integers(1, 70), n=st.sampled_from([4, 32, 128, 130]),
       dtype=st.sampled_from(COMPRESSED),
       stochastic=st.booleans(), seed=st.integers(0, 10_000))
@HSET
def test_pack_pallas_matches_ref(r, n, dtype, stochastic, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(r, n)), jnp.float32) * 3.0
    bits = _bits((r, n), seed) if stochastic else None
    got = quantize_rows(x, dtype, bits, use_pallas=True, interpret=True)
    want = quantize_rows_ref(x, dtype, bits)
    assert len(got) == len(want)
    for g, w in zip(got, want):
        assert g.dtype == w.dtype
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    # and the unpack side round-trips identically through both paths
    back = dequantize_rows(got, dtype, use_pallas=True, interpret=True)
    back_ref = dequantize_rows_ref(want, dtype)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(back_ref))


def test_ops_wrappers_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 3, 16)),
                    jnp.float32)
    for dtype in COMPRESSED:
        parts = quantize_payload(x, dtype=dtype, use_pallas=True)
        back = dequantize_payload(parts, dtype=dtype, use_pallas=True)
        assert back.shape == x.shape and back.dtype == x.dtype
        ref_parts = quantize_rows_ref(x, dtype)
        for g, w in zip(parts, ref_parts):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# ---------------------------------------------------------------------------
# exact preservation: values the compressed grid can represent must
# round-trip bit-for-bit under BOTH rounding modes — stochastic rounding
# must never perturb a representable value (its fraction is exactly 0)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("stochastic", [False, True])
def test_bf16_preserves_representable(stochastic):
    vals = np.asarray([[0.0, -0.0, 1.0, -1.0, 0.5, -2.0, 384.0, 2.0 ** -20]],
                      np.float32)
    x = jnp.asarray(np.asarray(jnp.asarray(vals, jnp.bfloat16), np.float32))
    bits = _bits(x.shape, 3) if stochastic else None
    (q,) = quantize_rows_ref(x, "bf16", bits)
    back = np.asarray(dequantize_rows_ref((q,), "bf16"))
    np.testing.assert_array_equal(back, np.asarray(x))
    # the sign bit of -0.0 survives (round-trip is a bitcast, not math)
    assert np.signbit(back[0, 1]) and not np.signbit(back[0, 0])


@pytest.mark.parametrize("stochastic", [False, True])
def test_int8_preserves_grid_points(stochastic):
    # rows whose values all sit on the k * amax/127 grid decode exactly
    scale = 0.25
    ks = np.asarray([[-127, -64, -1, 0, 1, 3, 64, 127]], np.float32)
    x = jnp.asarray(ks * scale)
    bits = _bits(x.shape, 7) if stochastic else None
    q, s = quantize_rows_ref(x, "int8", bits)
    np.testing.assert_array_equal(np.asarray(q), ks.astype(np.int8))
    np.testing.assert_allclose(np.asarray(s), [scale], rtol=1e-6)
    back = np.asarray(dequantize_rows_ref((q, s), "int8"))
    np.testing.assert_allclose(back, np.asarray(x), rtol=1e-6)


def test_int8_zero_row_decodes_exact_zeros():
    # amax = 0 -> scale 0 -> decode is exactly 0.0: the property ragged
    # sentinel rows in the bucketed exchange depend on (int8 carries no
    # sign bit for -0.0; it maps to +0.0, documented in kernels/quant.py)
    x = jnp.zeros((3, 8), jnp.float32)
    for stochastic in (False, True):
        bits = _bits(x.shape, 11) if stochastic else None
        q, s = quantize_rows_ref(x, "int8", bits)
        assert np.all(np.asarray(q) == 0) and np.all(np.asarray(s) == 0.0)
        back = np.asarray(dequantize_rows_ref((q, s), "int8"))
        assert np.all(back == 0.0)


# ---------------------------------------------------------------------------
# stochastic rounding is unbiased: E[decode(encode(x))] == x, unlike
# round-to-nearest whose systematic bias accumulates across write-backs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype,value,rel_tol", [
    ("bf16", 0.3, 2e-4),     # 0.3 is not bf16-representable
    ("int8", 0.35, 2e-4),    # 0.35 * 127 = 44.45 is off-grid
])
def test_stochastic_rounding_unbiased(dtype, value, rel_tol):
    n = 20_000
    x = jnp.full((n, 4), value, jnp.float32)
    # pin amax so the int8 grid does not move with the samples
    x = x.at[:, 0].set(1.0)
    parts = quantize_rows_ref(x, dtype, _bits(x.shape, 123))
    back = np.asarray(dequantize_rows_ref(parts, dtype), np.float64)
    mean = back[:, 1:].mean()
    assert abs(mean - value) < rel_tol * value, \
        f"SR mean {mean} drifted from {value}"
    # deterministic RNE is NOT an unbiased estimator here: every sample
    # lands on the same side, so the error is the full rounding offset
    det = np.asarray(dequantize_rows_ref(quantize_rows_ref(x, dtype), dtype),
                     np.float64)
    assert abs(det[:, 1:].mean() - value) > rel_tol * value


@given(dtype=st.sampled_from(COMPRESSED), seed=st.integers(0, 10_000))
@HSET
def test_error_bound_one_ulp(dtype, seed):
    # SR lands within ONE grid step of the input (RNE within half) —
    # the bound the exchange parity tests budget against
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
    parts = quantize_rows_ref(x, dtype, _bits(x.shape, seed))
    back = np.asarray(dequantize_rows_ref(parts, dtype))
    amax = np.abs(np.asarray(x)).max(axis=1, keepdims=True)
    step = amax * (2.0 ** -7 if dtype == "bf16" else 1.0 / 127.0)
    assert np.all(np.abs(back - np.asarray(x)) <= step + 1e-7)
