"""Strategy-parity harness for the pluggable table exchange (ISSUE 5).

Contract under test (dist/exchange.py):
  * every strategy (ring | alltoall | bucketed) computes BIT-exact
    lookups/write-backs vs the dense single-device table ops — pure row
    selection / single-owner scatter, no reductions — across shard
    counts {1, 2, 4, 8} and ADVERSARIAL row distributions: every row on
    one owner shard, duplicate rows within one batch, a shard that owns
    nothing in the batch;
  * all 7 GST variants train to oracle parity through any strategy
    (ages/init bit-exact, params within ~1 ulp at 8 shards);
  * each strategy's analytic bytes-per-exchange model equals the
    collective traffic counted in its own jaxpr
    (measured_exchange_bytes);
  * ragged global batches (size not divisible by the shard count) are
    guarded by ``pad_ragged``: sentinel pad rows read as zeros and are
    dropped by writes, end to end through every strategy;
  * ``required_capacity``/``plan_capacity`` size the bucketed buckets,
    and ``select_exchange`` ("auto") picks the min-bytes strategy.

Runs at whatever device count the host exposes: tier-1 sees 1 device
(degenerate mesh, bitwise parity); the exchange-matrix CI job re-runs a
per-strategy subset under XLA_FLAGS=--xla_force_host_platform_device_
count=8 (-k ring / alltoall / bucketed).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import dist as DT
from repro.core import embedding_table as tbl
from repro.core import gst as G
from repro.core.embedding_table import init_table
from repro.dist import exchange as EX
from repro.dist import pipeline as DP
from repro.graphs import data as D
from repro.graphs.gnn import GNNConfig, gnn_init, make_encode_fn
from repro.optim import make_optimizer

N_DEV = jax.device_count()
SHARD_COUNTS = [d for d in (1, 2, 4, 8) if d <= N_DEV]
STRATEGIES = list(EX.EXCHANGES)
HID = 8

# raw-op geometry: n divisible by every shard count so "one owner" can
# fill a whole batch with unique rows of shard 0 (rows_per_shard = 8 at
# 8 shards)
N_ROWS, J, DH = 64, 2, 4
B_GLOBAL = 8


def _random_table(n, J, d, seed=0):
    rng = np.random.default_rng(seed)
    return tbl.EmbeddingTable(
        emb=jnp.asarray(rng.normal(size=(n, J, d)), jnp.float32),
        age=jnp.asarray(rng.integers(0, 9, (n, J)), jnp.int32),
        initialized=jnp.asarray(rng.integers(0, 2, (n, J)), bool))


def _ctx(n_shards, n_rows=N_ROWS, **kw):
    return DT.make_context(DT.make_dist_mesh(n_shards), n_rows, **kw)


def _exchange(name, ctx, cap=None):
    return EX.make_exchange(name, axis_name=DT.AXIS,
                            num_shards=ctx.num_shards,
                            rows=ctx.rows_per_shard, cap=cap)


def _tspec():
    return tbl.EmbeddingTable(P(DT.AXIS), P(DT.AXIS), P(DT.AXIS))


def _put(ctx, x):
    return jax.device_put(x, NamedSharding(ctx.mesh, P(DT.AXIS)))


# ---------------------------------------------------------------------------
# adversarial row distributions
# ---------------------------------------------------------------------------


def _id_cases(n, rows, num_shards, B, seed=0):
    """Global id batches keyed by distribution name.  All cases keep ids
    unique except "duplicates", whose write payloads are derived from the
    id so duplicate writes are order-independent (same cells, same
    values) — matching what the dense oracle scatter sees."""
    rng = np.random.default_rng(seed)
    cases = {
        "uniform": rng.permutation(n)[:B],
        # every row owned by shard 0: one device's buckets all target one
        # owner, every other shard's table sees only pass-through traffic
        "one_owner": rng.permutation(min(rows, n))[:B],
        "duplicates": np.concatenate(
            [rng.permutation(n)[:B // 2]] * 2)[:B],
    }
    if num_shards > 1:
        # last shard owns nothing in the batch (empty local shard)
        lo = (num_shards - 1) * rows
        pool = np.concatenate([np.arange(0, min(lo, n))])
        cases["empty_shard"] = rng.permutation(pool)[:B]
    return {k: np.sort(v)[rng.permutation(len(v))].astype(np.int32)
            for k, v in cases.items()}


CASE_NAMES = ("uniform", "one_owner", "duplicates", "empty_shard")


def _case(name, n, rows, num_shards, B, seed=0):
    cases = _id_cases(n, rows, num_shards, B, seed)
    if name not in cases:
        pytest.skip("empty_shard needs >= 2 shards")
    return cases[name]


# write payloads derived from the id => duplicate-row writes are
# order-independent (identical cells, identical values)
def _payloads_sampled(ids, S=1):
    rng = np.random.default_rng(7)
    key = rng.normal(size=(N_ROWS + 1, S, DH)).astype(np.float32)
    sidx = (ids[:, None] + np.arange(S)[None, :]) % J
    return sidx.astype(np.int32), key[ids]


def _payloads_all(ids):
    rng = np.random.default_rng(8)
    h = rng.normal(size=(N_ROWS + 1, J, DH)).astype(np.float32)
    sv = ((ids[:, None] + np.arange(J)[None, :]) % 2).astype(np.float32)
    return h[ids], sv


# ---------------------------------------------------------------------------
# raw-op parity: every strategy ≡ dense ops, bit-exact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case", CASE_NAMES)
@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_lookup_parity(strategy, n_shards, case):
    ctx = _ctx(n_shards)
    ids = _case(case, N_ROWS, ctx.rows_per_shard, n_shards, B_GLOBAL)
    table = _random_table(N_ROWS, J, DH)
    cap = EX.required_capacity(ids, num_shards=n_shards,
                               rows=ctx.rows_per_shard)
    ex = _exchange(strategy, ctx, cap=cap)
    f = shard_map(ex.lookup, mesh=ctx.mesh, in_specs=(_tspec(), P(DT.AXIS)),
                  out_specs=(P(DT.AXIS), P(DT.AXIS)), check_rep=False)
    emb_d, init_d = jax.jit(f)(DT.device_table(ctx, table),
                               _put(ctx, jnp.asarray(ids)))
    emb, init = tbl.lookup(table, jnp.asarray(ids))
    assert (np.asarray(emb_d) == np.asarray(emb)).all()
    assert (np.asarray(init_d) == np.asarray(init)).all()


@pytest.mark.parametrize("case", CASE_NAMES)
@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_update_sampled_parity(strategy, n_shards, case):
    ctx = _ctx(n_shards)
    ids = _case(case, N_ROWS, ctx.rows_per_shard, n_shards, B_GLOBAL)
    sidx, h = _payloads_sampled(ids)
    table = _random_table(N_ROWS, J, DH)
    step = jnp.asarray(5, jnp.int32)
    cap = EX.required_capacity(ids, num_shards=n_shards,
                               rows=ctx.rows_per_shard)
    ex = _exchange(strategy, ctx, cap=cap)
    f = shard_map(ex.update_sampled, mesh=ctx.mesh,
                  in_specs=(_tspec(), P(DT.AXIS), P(DT.AXIS), P(DT.AXIS),
                            P()),
                  out_specs=_tspec(), check_rep=False)
    got = jax.jit(f)(DT.device_table(ctx, table), _put(ctx, jnp.asarray(ids)),
                     _put(ctx, jnp.asarray(sidx)), _put(ctx, jnp.asarray(h)),
                     step)
    want = tbl.update_sampled(table, jnp.asarray(ids), jnp.asarray(sidx),
                              jnp.asarray(h), step)
    got = DT.host_table(ctx, got)
    for a, b in zip(got, want):
        assert (np.asarray(a) == np.asarray(b)).all()


@pytest.mark.parametrize("case", ("uniform", "one_owner", "empty_shard"))
@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_update_all_parity(strategy, n_shards, case):
    ctx = _ctx(n_shards)
    ids = _case(case, N_ROWS, ctx.rows_per_shard, n_shards, B_GLOBAL)
    h, sv = _payloads_all(ids)
    table = _random_table(N_ROWS, J, DH)
    step = jnp.asarray(9, jnp.int32)
    cap = EX.required_capacity(ids, num_shards=n_shards,
                               rows=ctx.rows_per_shard)
    ex = _exchange(strategy, ctx, cap=cap)
    f = shard_map(ex.update_all, mesh=ctx.mesh,
                  in_specs=(_tspec(), P(DT.AXIS), P(DT.AXIS), P(DT.AXIS),
                            P()),
                  out_specs=_tspec(), check_rep=False)
    got = jax.jit(f)(DT.device_table(ctx, table), _put(ctx, jnp.asarray(ids)),
                     _put(ctx, jnp.asarray(h)), _put(ctx, jnp.asarray(sv)),
                     step)
    want = tbl.update_all(table, jnp.asarray(ids), jnp.asarray(h),
                          jnp.asarray(sv), step)
    got = DT.host_table(ctx, got)
    for a, b in zip(got, want):
        assert (np.asarray(a) == np.asarray(b)).all()


# ---------------------------------------------------------------------------
# ragged batches: pad_ragged guards the non-divisible case end to end
# ---------------------------------------------------------------------------


def test_pad_ragged_shapes_and_sentinel():
    ids = np.arange(10, dtype=np.int32)
    h = np.ones((10, 3), np.float32)
    ids_p, h_p, n = EX.pad_ragged(4, 8, ids, h)
    assert n == 10 and ids_p.shape == (12,) and h_p.shape == (12, 3)
    assert (ids_p[:10] == ids).all() and (ids_p[10:] == 4 * 8).all()
    assert (h_p[10:] == 0).all()
    # already divisible: untouched
    ids_q, n2 = EX.pad_ragged(2, 8, ids)
    assert n2 == 10 and ids_q.shape == (10,)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_ragged_batch_lookup_and_update_end_to_end(strategy):
    """A global batch of 2·D+3 rows over D shards: padded by pad_ragged,
    exchanged, results sliced back — lookups match the oracle on the real
    rows and read zeros on the pad rows; the sentinel writes land
    nowhere (table equals the oracle's everywhere)."""
    n_shards = SHARD_COUNTS[-1]
    ctx = _ctx(n_shards)
    rng = np.random.default_rng(3)
    B = 2 * n_shards + 3 if n_shards > 1 else 5
    ids = rng.permutation(N_ROWS)[:B].astype(np.int32)
    sidx, h = _payloads_sampled(ids)
    ids_p, sidx_p, h_p, n_real = EX.pad_ragged(
        n_shards, ctx.rows_per_shard, ids, sidx, h)
    assert n_real == B and ids_p.shape[0] % n_shards == 0
    cap = EX.required_capacity(ids_p, num_shards=n_shards,
                               rows=ctx.rows_per_shard)
    ex = _exchange(strategy, ctx, cap=cap)

    table = _random_table(N_ROWS, J, DH)
    look = shard_map(ex.lookup, mesh=ctx.mesh,
                     in_specs=(_tspec(), P(DT.AXIS)),
                     out_specs=(P(DT.AXIS), P(DT.AXIS)), check_rep=False)
    emb_d, init_d = jax.jit(look)(DT.device_table(ctx, table),
                                  _put(ctx, jnp.asarray(ids_p)))
    emb, init = tbl.lookup(table, jnp.asarray(ids))
    assert (np.asarray(emb_d)[:n_real] == np.asarray(emb)).all()
    assert (np.asarray(init_d)[:n_real] == np.asarray(init)).all()
    assert (np.asarray(emb_d)[n_real:] == 0).all()       # pad rows: zeros
    assert not np.asarray(init_d)[n_real:].any()

    upd = shard_map(ex.update_sampled, mesh=ctx.mesh,
                    in_specs=(_tspec(), P(DT.AXIS), P(DT.AXIS), P(DT.AXIS),
                              P()),
                    out_specs=_tspec(), check_rep=False)
    got = jax.jit(upd)(DT.device_table(ctx, table),
                       _put(ctx, jnp.asarray(ids_p)),
                       _put(ctx, jnp.asarray(sidx_p)),
                       _put(ctx, jnp.asarray(h_p)),
                       jnp.asarray(3, jnp.int32))
    want = tbl.update_sampled(table, jnp.asarray(ids), jnp.asarray(sidx),
                              jnp.asarray(h), jnp.asarray(3, jnp.int32))
    got = DT.host_table(ctx, got)
    for a, b in zip(got, want):
        assert (np.asarray(a) == np.asarray(b)).all()


# ---------------------------------------------------------------------------
# analytic bytes models == measured collective traffic in the jaxpr
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_bytes_model_matches_measured_jaxpr(strategy):
    n_shards = SHARD_COUNTS[-1]
    ctx = _ctx(n_shards)
    B_local = 4
    cap = 2 if n_shards > 1 else None
    S = 2
    ex = _exchange(strategy, ctx, cap=cap)
    table = _random_table(N_ROWS, J, DH)
    dev = DT.device_table(ctx, table)
    ids = jnp.zeros(B_local * n_shards, jnp.int32)
    sidx = jnp.zeros((B_local * n_shards, S), jnp.int32)
    h = jnp.zeros((B_local * n_shards, S, DH), jnp.float32)
    h_all = jnp.zeros((B_local * n_shards, J, DH), jnp.float32)
    sv = jnp.zeros((B_local * n_shards, J), jnp.float32)
    step = jnp.asarray(0, jnp.int32)

    look = shard_map(ex.lookup, mesh=ctx.mesh,
                     in_specs=(_tspec(), P(DT.AXIS)),
                     out_specs=(P(DT.AXIS), P(DT.AXIS)), check_rep=False)
    assert EX.measured_exchange_bytes(look, n_shards, dev, ids) == \
        ex.lookup_bytes(B_local, J, DH)

    upd = shard_map(ex.update_sampled, mesh=ctx.mesh,
                    in_specs=(_tspec(), P(DT.AXIS), P(DT.AXIS), P(DT.AXIS),
                              P()),
                    out_specs=_tspec(), check_rep=False)
    assert EX.measured_exchange_bytes(upd, n_shards, dev, ids, sidx, h,
                                      step) == \
        ex.update_sampled_bytes(B_local, S, DH)

    upa = shard_map(ex.update_all, mesh=ctx.mesh,
                    in_specs=(_tspec(), P(DT.AXIS), P(DT.AXIS), P(DT.AXIS),
                              P()),
                    out_specs=_tspec(), check_rep=False)
    assert EX.measured_exchange_bytes(upa, n_shards, dev, ids, h_all, sv,
                                      step) == \
        ex.update_all_bytes(B_local, J, DH)


# ---------------------------------------------------------------------------
# train-step parity: every strategy × all 7 variants vs the oracle
# ---------------------------------------------------------------------------


def _tree_max_diff(a, b):
    diffs = jax.tree_util.tree_map(
        lambda x, y: float(np.max(np.abs(np.asarray(x) - np.asarray(y)))),
        a, b)
    return max(jax.tree_util.tree_leaves(diffs), default=0.0)


@pytest.fixture(scope="module")
def dataset():
    graphs = D.make_malnet_like(n_graphs=16, seed=0)
    ds, spec = DP.segment_dataset_shared(graphs, 16, seed=0)
    return ds


def _state(ds):
    cfg = GNNConfig(backbone="sage", n_feat=ds.x.shape[-1], hidden=HID)
    enc = make_encode_fn(cfg)
    key = jax.random.key(0)
    bb = gnn_init(key, cfg)
    head = G.head_init(jax.random.fold_in(key, 1), HID, 5, "mlp")
    opt = make_optimizer("adam", lr=5e-3)
    return enc, opt, G.TrainState(bb, head, opt.init((bb, head)),
                                  init_table(ds.n, ds.j_max, HID),
                                  jnp.zeros((), jnp.int32))


_ORACLE_CACHE = {}


def _oracle_run(ds, variant):
    """5 oracle steps per variant, computed once and shared across the
    strategy parametrization."""
    if variant not in _ORACLE_CACHE:
        enc, opt, state0 = _state(ds)
        batch = jax.tree_util.tree_map(
            jnp.asarray,
            DP._assemble(ds, DP.epoch_ids(ds, 8,
                                          rng=np.random.default_rng(0),
                                          shuffle=False)[0]))
        step = jax.jit(G.make_train_step(enc, opt, G.VARIANTS[variant],
                                         keep_prob=0.5))
        s = state0
        for _ in range(5):
            s, m = step(s, batch, jax.random.PRNGKey(3))
        _ORACLE_CACHE[variant] = (s, m, batch, state0)
    return _ORACLE_CACHE[variant]


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("variant", list(G.VARIANTS))
def test_train_step_parity_all_variants(dataset, variant, strategy):
    ds = dataset
    if N_DEV == 1 and variant != "gst_efd":
        pytest.skip("single-device host: the degenerate mesh is covered by "
                    "the complete method; the full 7x3 matrix runs in the "
                    "exchange-matrix CI job at 8 forced devices")
    s1, m1, batch, state0 = _oracle_run(ds, variant)
    n_shards = SHARD_COUNTS[-1]
    enc, opt, _ = _state(ds)
    cap = EX.required_capacity(np.asarray(batch.graph_ids),
                               num_shards=n_shards,
                               rows=DT.make_context(
                                   DT.make_dist_mesh(n_shards),
                                   ds.n).rows_per_shard)
    ctx = DT.make_context(DT.make_dist_mesh(n_shards), ds.n,
                          exchange=strategy,
                          exchange_cap=cap if strategy == "bucketed"
                          else None)
    dstep = DT.make_dist_train_step(enc, opt, G.VARIANTS[variant], ctx=ctx,
                                    keep_prob=0.5, donate=False)
    s2 = DT.device_state(ctx, state0)
    b2 = DT.shard_batch(ctx, batch)
    for _ in range(5):
        s2, m2 = dstep(s2, b2, jax.random.PRNGKey(3))

    t2 = DT.host_table(ctx, s2.table)
    # bookkeeping is pure row selection — identical segment sampling means
    # identical ages and init flags, bit for bit, through ANY strategy
    assert (np.asarray(s1.table.age) == np.asarray(t2.age)).all()
    assert (np.asarray(s1.table.initialized) ==
            np.asarray(t2.initialized)).all()
    tol = 0.0 if ctx.num_shards == 1 else 1e-5
    assert _tree_max_diff(s1.table.emb, t2.emb) <= tol
    assert _tree_max_diff((s1.backbone, s1.head),
                          jax.device_get((s2.backbone, s2.head))) <= tol
    assert abs(float(m1["loss"]) - float(m2["loss"])) <= tol


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_refresh_step_bit_exact_any_strategy(dataset, strategy):
    ds = dataset
    enc, opt, state0 = _state(ds)
    batch = jax.tree_util.tree_map(
        jnp.asarray,
        DP._assemble(ds, DP.epoch_ids(ds, 8, rng=np.random.default_rng(0),
                                      shuffle=False)[0]))
    s1 = jax.jit(G.make_refresh_step(enc))(state0, batch)
    ctx = DT.make_context(DT.make_dist_mesh(SHARD_COUNTS[-1]), ds.n,
                          exchange=strategy)
    s2 = DT.make_dist_refresh_step(enc, ctx=ctx, donate=False)(
        DT.device_state(ctx, state0), DT.shard_batch(ctx, batch))
    t2 = DT.host_table(ctx, s2.table)
    # refresh is encode + row writes, no cross-row reductions: bit-exact
    assert (np.asarray(s1.table.emb) == np.asarray(t2.emb)).all()
    assert (np.asarray(s1.table.initialized) ==
            np.asarray(t2.initialized)).all()


# ---------------------------------------------------------------------------
# host-side planning: capacity + auto selection
# ---------------------------------------------------------------------------


def test_required_capacity_counts_owner_buckets():
    # 4 shards x 8 rows/shard; device slices of 2: device 0 sends both its
    # rows to owner 0, device 1 splits across owners 2 and 3
    ids = np.asarray([0, 7, 16, 25, 8, 9, 30, 31])
    assert EX.required_capacity(ids, num_shards=4, rows=8) == 2
    # 2 shards x 8 rows/shard: each device's whole 4-row slice targets one
    # owner — the worst case pins the capacity at B_local
    ids = np.asarray([1, 2, 3, 4, 8, 9, 10, 11])
    assert EX.required_capacity(ids, num_shards=2, rows=8) == 4
    # perfectly owner-aligned: one row per (device, owner) bucket
    ids = np.asarray([0, 8, 1, 9])
    assert EX.required_capacity(ids, num_shards=2, rows=8) == 1
    # ragged input is padded internally; sentinel counts against the last
    # shard's bucket
    assert EX.required_capacity(np.asarray([0, 1, 2]), num_shards=2,
                                rows=8) == 2
    # plan over a schedule = max over its batches
    sched = [np.asarray([0, 8, 1, 9]), np.asarray([0, 1, 2, 3])]
    assert EX.plan_capacity(sched, num_shards=2, rows=8) == 2


def test_select_exchange_picks_min_bytes():
    # 1 shard: everything is local, ring by convention
    assert EX.select_exchange(1, 8, 4, 1, 16) == "ring"
    # many shards, uniform cap estimate: bucketed moves the least
    assert EX.select_exchange(16, 32, 4, 1, 16) == "bucketed"
    # planned cap == b_local (fully skewed batches): bucketed degenerates
    # to the alltoall block, which beats the ring's extra lookup hop
    assert EX.select_exchange(16, 32, 4, 1, 16, cap=32) == "alltoall"
    # the pick is exactly the analytic argmin over the strategy models
    for d, b in ((2, 8), (4, 8), (8, 16)):
        cap = -(-b // d)
        picked = EX.select_exchange(d, b, 4, 1, 16, cap=cap)
        by_bytes = {
            name: EX.make_exchange(
                name, axis_name="x", num_shards=d, rows=1,
                cap=cap).train_step_bytes(b, 4, 1, 16, use_table=True)
            for name in EX.EXCHANGES}
        assert by_bytes[picked] == min(by_bytes.values())


def test_make_exchange_rejects_unknown_and_auto():
    with pytest.raises(ValueError, match="auto"):
        EX.make_exchange("auto", axis_name="x", num_shards=2, rows=4)
    with pytest.raises(ValueError, match="unknown"):
        EX.make_exchange("teleport", axis_name="x", num_shards=2, rows=4)
    with pytest.raises(ValueError, match="unknown"):
        DT.make_context(DT.make_dist_mesh(1), 8, exchange="teleport")


# ---------------------------------------------------------------------------
# compressed payloads (--payload-dtype): every strategy rides the codec's
# wire format; f32 stays bit-exact, bf16/int8 within one grid step
# ---------------------------------------------------------------------------

DTYPES = list(EX.PAYLOAD_DTYPES)
# one full grid step of the per-row quantization grid — the write path's
# stochastic rounding can land a full ULP away (the deterministic read
# path stays within half); tests/test_quant.py pins these bounds
REL = {"f32": 0.0, "bf16": 2.0 ** -7, "int8": 1.0 / 127.0}


def _exchange_dt(name, ctx, cap=None, dtype="f32"):
    return EX.make_exchange(name, axis_name=DT.AXIS,
                            num_shards=ctx.num_shards,
                            rows=ctx.rows_per_shard, cap=cap,
                            payload_dtype=dtype)


def _payload_tol(ex, reference):
    """Worst-case absolute decode error for payloads drawn from
    ``reference``: REL is relative to each row's amax; bound globally."""
    return REL[ex.payload_dtype] * float(np.abs(np.asarray(reference)).max())


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_compressed_lookup_within_one_step(strategy, dtype):
    n_shards = SHARD_COUNTS[-1]
    ctx = _ctx(n_shards)
    ids = _case("uniform", N_ROWS, ctx.rows_per_shard, n_shards, B_GLOBAL)
    table = _random_table(N_ROWS, J, DH)
    cap = EX.required_capacity(ids, num_shards=n_shards,
                               rows=ctx.rows_per_shard)
    ex = _exchange_dt(strategy, ctx, cap=cap, dtype=dtype)
    f = shard_map(ex.lookup, mesh=ctx.mesh, in_specs=(_tspec(), P(DT.AXIS)),
                  out_specs=(P(DT.AXIS), P(DT.AXIS)), check_rep=False)
    emb_d, init_d = jax.jit(f)(DT.device_table(ctx, table),
                               _put(ctx, jnp.asarray(ids)))
    emb, init = tbl.lookup(table, jnp.asarray(ids))
    # init bits never ride the codec — bit-exact at every dtype
    assert (np.asarray(init_d) == np.asarray(init)).all()
    tol = _payload_tol(ex, emb)
    if ex.payload_dtype == "f32":
        assert (np.asarray(emb_d) == np.asarray(emb)).all()
    else:
        assert float(np.abs(np.asarray(emb_d) -
                            np.asarray(emb)).max()) <= tol


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_compressed_update_sampled_within_one_step(strategy, dtype):
    n_shards = SHARD_COUNTS[-1]
    ctx = _ctx(n_shards)
    ids = _case("uniform", N_ROWS, ctx.rows_per_shard, n_shards, B_GLOBAL)
    sidx, h = _payloads_sampled(ids)
    table = _random_table(N_ROWS, J, DH)
    step = jnp.asarray(5, jnp.int32)
    cap = EX.required_capacity(ids, num_shards=n_shards,
                               rows=ctx.rows_per_shard)
    ex = _exchange_dt(strategy, ctx, cap=cap, dtype=dtype)
    f = shard_map(ex.update_sampled, mesh=ctx.mesh,
                  in_specs=(_tspec(), P(DT.AXIS), P(DT.AXIS), P(DT.AXIS),
                            P()),
                  out_specs=_tspec(), check_rep=False)
    got = DT.host_table(ctx, jax.jit(f)(
        DT.device_table(ctx, table), _put(ctx, jnp.asarray(ids)),
        _put(ctx, jnp.asarray(sidx)), _put(ctx, jnp.asarray(h)), step))
    want = tbl.update_sampled(table, jnp.asarray(ids), jnp.asarray(sidx),
                              jnp.asarray(h), step)
    # bookkeeping is uncompressed: ages and init flags stay bit-exact
    assert (np.asarray(got.age) == np.asarray(want.age)).all()
    assert (np.asarray(got.initialized) ==
            np.asarray(want.initialized)).all()
    ge, we, orig = (np.asarray(x) for x in (got.emb, want.emb, table.emb))
    untouched = (we == orig)
    # rows the oracle did not write must come back bit-identical
    assert (ge[untouched] == we[untouched]).all()
    if ex.payload_dtype == "f32":
        assert (ge == we).all()
    else:
        assert float(np.abs(ge - we).max()) <= _payload_tol(ex, h)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_compressed_bytes_model_matches_measured(strategy, dtype):
    """The analytic per-dtype bytes models stay EXACTLY equal to the
    collective traffic counted in the jaxpr — compression included."""
    n_shards = SHARD_COUNTS[-1]
    ctx = _ctx(n_shards)
    B_local = 4
    cap = 2 if n_shards > 1 else None
    S = 2
    ex = _exchange_dt(strategy, ctx, cap=cap, dtype=dtype)
    dev = DT.device_table(ctx, _random_table(N_ROWS, J, DH))
    ids = jnp.zeros(B_local * n_shards, jnp.int32)
    sidx = jnp.zeros((B_local * n_shards, S), jnp.int32)
    h = jnp.zeros((B_local * n_shards, S, DH), jnp.float32)
    step = jnp.asarray(0, jnp.int32)

    look = shard_map(ex.lookup, mesh=ctx.mesh,
                     in_specs=(_tspec(), P(DT.AXIS)),
                     out_specs=(P(DT.AXIS), P(DT.AXIS)), check_rep=False)
    assert EX.measured_exchange_bytes(look, n_shards, dev, ids) == \
        ex.lookup_bytes(B_local, J, DH)

    upd = shard_map(ex.update_sampled, mesh=ctx.mesh,
                    in_specs=(_tspec(), P(DT.AXIS), P(DT.AXIS), P(DT.AXIS),
                              P()),
                    out_specs=_tspec(), check_rep=False)
    assert EX.measured_exchange_bytes(upd, n_shards, dev, ids, sidx, h,
                                      step) == \
        ex.update_sampled_bytes(B_local, S, DH)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_ragged_batch_compressed_end_to_end(strategy, dtype):
    """Satellite: the ragged guard survives compression — pad-row lookups
    decode to EXACT zeros (a zero row quantizes to scale 0) and sentinel
    writes land nowhere, through every strategy at every dtype."""
    n_shards = SHARD_COUNTS[-1]
    ctx = _ctx(n_shards)
    rng = np.random.default_rng(3)
    B = 2 * n_shards + 3 if n_shards > 1 else 5
    ids = rng.permutation(N_ROWS)[:B].astype(np.int32)
    sidx, h = _payloads_sampled(ids)
    ids_p, sidx_p, h_p, n_real = EX.pad_ragged(
        n_shards, ctx.rows_per_shard, ids, sidx, h)
    cap = EX.required_capacity(ids_p, num_shards=n_shards,
                               rows=ctx.rows_per_shard)
    ex = _exchange_dt(strategy, ctx, cap=cap, dtype=dtype)

    table = _random_table(N_ROWS, J, DH)
    look = shard_map(ex.lookup, mesh=ctx.mesh,
                     in_specs=(_tspec(), P(DT.AXIS)),
                     out_specs=(P(DT.AXIS), P(DT.AXIS)), check_rep=False)
    emb_d, init_d = jax.jit(look)(DT.device_table(ctx, table),
                                  _put(ctx, jnp.asarray(ids_p)))
    emb, init = tbl.lookup(table, jnp.asarray(ids))
    assert (np.asarray(init_d)[:n_real] == np.asarray(init)).all()
    assert not np.asarray(init_d)[n_real:].any()
    assert (np.asarray(emb_d)[n_real:] == 0).all()      # EXACT zeros
    tol = _payload_tol(ex, emb)
    if ex.payload_dtype == "f32":
        assert (np.asarray(emb_d)[:n_real] == np.asarray(emb)).all()
    else:
        assert float(np.abs(np.asarray(emb_d)[:n_real] -
                            np.asarray(emb)).max()) <= tol

    upd = shard_map(ex.update_sampled, mesh=ctx.mesh,
                    in_specs=(_tspec(), P(DT.AXIS), P(DT.AXIS), P(DT.AXIS),
                              P()),
                    out_specs=_tspec(), check_rep=False)
    got = DT.host_table(ctx, jax.jit(upd)(
        DT.device_table(ctx, table), _put(ctx, jnp.asarray(ids_p)),
        _put(ctx, jnp.asarray(sidx_p)), _put(ctx, jnp.asarray(h_p)),
        jnp.asarray(3, jnp.int32)))
    want = tbl.update_sampled(table, jnp.asarray(ids), jnp.asarray(sidx),
                              jnp.asarray(h), jnp.asarray(3, jnp.int32))
    assert (np.asarray(got.age) == np.asarray(want.age)).all()
    assert (np.asarray(got.initialized) ==
            np.asarray(want.initialized)).all()
    ge, we, orig = (np.asarray(x) for x in (got.emb, want.emb, table.emb))
    untouched = (we == orig)        # includes every sentinel-targeted cell
    assert (ge[untouched] == we[untouched]).all()
    if ex.payload_dtype != "f32":
        assert float(np.abs(ge - we).max()) <= _payload_tol(ex, h)


# documented end-of-run loss deltas vs the f32 oracle after 5 steps of the
# complete method at the max shard count: one quantization step per table
# read/write, amplified through adam — bounded, not bit-exact
LOSS_TOL = {"bf16": 5e-2, "int8": 2e-1}


@pytest.mark.parametrize("dtype", ["bf16", "int8"])
@pytest.mark.parametrize("variant", list(G.VARIANTS))
def test_train_step_compressed_loss_bounded(dataset, variant, dtype):
    ds = dataset
    if N_DEV == 1:
        pytest.skip("one shard never crosses the wire (codec pins f32); "
                    "the compressed matrix runs in the exchange-matrix CI "
                    "job at 8 forced devices")
    s1, m1, batch, state0 = _oracle_run(ds, variant)
    n_shards = SHARD_COUNTS[-1]
    enc, opt, _ = _state(ds)
    ctx = DT.make_context(DT.make_dist_mesh(n_shards), ds.n,
                          exchange="ring", payload_dtype=dtype)
    dstep = DT.make_dist_train_step(enc, opt, G.VARIANTS[variant], ctx=ctx,
                                    keep_prob=0.5, donate=False)
    s2 = DT.device_state(ctx, state0)
    b2 = DT.shard_batch(ctx, batch)
    for _ in range(5):
        s2, m2 = dstep(s2, b2, jax.random.PRNGKey(3))
    t2 = DT.host_table(ctx, s2.table)
    # sampling bookkeeping never rides the codec: still bit-exact
    assert (np.asarray(s1.table.age) == np.asarray(t2.age)).all()
    assert (np.asarray(s1.table.initialized) ==
            np.asarray(t2.initialized)).all()
    d = abs(float(m1["loss"]) - float(m2["loss"]))
    assert d <= LOSS_TOL[dtype], \
        f"{variant}/{dtype}: loss delta {d} > documented {LOSS_TOL[dtype]}"


def test_select_exchange_precision_aware():
    # the pick is the analytic argmin at EVERY payload dtype — compression
    # shrinks only the payload term, so the crossover moves with the dtype
    for dtype in DTYPES:
        for d, b in ((2, 8), (4, 8), (8, 16), (16, 32)):
            cap = -(-b // d)
            picked = EX.select_exchange(d, b, 4, 1, 16, cap=cap,
                                        payload_dtype=dtype)
            by_bytes = {
                name: EX.make_exchange(
                    name, axis_name="x", num_shards=d, rows=1, cap=cap,
                    payload_dtype=dtype).train_step_bytes(
                        b, 4, 1, 16, use_table=True)
                for name in EX.EXCHANGES}
            assert by_bytes[picked] == min(by_bytes.values()), \
                (dtype, d, b, picked, by_bytes)
    # compressing the payload must never INCREASE a strategy's step bytes
    for name in EX.EXCHANGES:
        mk = lambda dt: EX.make_exchange(
            name, axis_name="x", num_shards=8, rows=8, cap=4,
            payload_dtype=dt).train_step_bytes(16, 4, 1, 16, use_table=True)
        assert mk("int8") < mk("bf16") < mk("f32")


def test_codec_pins_f32_on_one_shard():
    ex = EX.make_exchange("ring", axis_name="x", num_shards=1, rows=8,
                          payload_dtype="int8")
    assert ex.payload_dtype == "f32"
    with pytest.raises(ValueError, match="payload"):
        EX.make_exchange("ring", axis_name="x", num_shards=2, rows=4,
                         payload_dtype="fp4")
