"""Lookahead prefetch lane (ISSUE 9): hide the exchange behind compute.

Contract under test (dist/exchange.py + dist/train.py + dist/pipeline.py):
  * ``prefetch_lookup`` is the strategy's lookup verbatim — same
    collectives, same bytes, just dispatched as its own jitted program
    while the previous step runs;
  * every strategy's fused ``update_sampled_patch`` applies the sampled
    write-back exactly like ``update_sampled`` AND repairs the next
    batch's prefetched buffer so it equals a lookup of the POST-write
    table, bit-exact at f32, across adversarial overlap schedules
    (all-overlap | zero-overlap | partial) and shard counts;
  * ring/alltoall patch for free (0 extra wire bytes — asserted against
    the jaxpr); bucketed pays exactly its analytic ``patch_bytes``;
  * end to end, prefetched training is BIT-exact vs the inline dist
    oracle at f32 (params, table emb, ages, init) for all 7 GST
    variants x 3 strategies;
  * ragged/sentinel next batches read zeros and are never patched;
  * ``PrefetchLane`` dispatches each item once, before the previous
    item's step launches, and propagates errors/close;
  * ``TieredStore`` lookahead pinning keeps prefetched batches resident
    (release frees them; exhaustion raises, not corrupts);
  * the obs gate requires the ``exchange.prefetch.*`` families whenever
    a stream advertises the lane.

Runs at whatever device count the host exposes: tier-1 sees 1 device;
the exchange-matrix CI prefetch leg re-runs under
XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import dist as DT
from repro.core import embedding_table as tbl
from repro.core import gst as G
from repro.core.embedding_table import init_table
from repro.dist import exchange as EX
from repro.dist import pipeline as DP
from repro.graphs import data as D
from repro.graphs.gnn import GNNConfig, gnn_init, make_encode_fn
from repro.obs.gate import main as gate_main
from repro.obs.metrics import MetricsRegistry
from repro.obs.staleness import record_prefetch_exchange
from repro.optim import make_optimizer

N_DEV = jax.device_count()
SHARD_COUNTS = [d for d in (1, 2, 4, 8) if d <= N_DEV]
# the ISSUE's adversarial grid: shards {2, 8} (intersected with the host)
MULTI_SHARDS = [d for d in (2, 8) if d <= N_DEV] or [1]
STRATEGIES = list(EX.EXCHANGES)
OVERLAPS = ("all", "none", "partial")
HID = 8

N_ROWS, J, DH = 64, 2, 4
B_GLOBAL = 8


def _random_table(n, J, d, seed=0):
    rng = np.random.default_rng(seed)
    return tbl.EmbeddingTable(
        emb=jnp.asarray(rng.normal(size=(n, J, d)), jnp.float32),
        age=jnp.asarray(rng.integers(0, 9, (n, J)), jnp.int32),
        initialized=jnp.asarray(rng.integers(0, 2, (n, J)), bool))


def _ctx(n_shards, n_rows=N_ROWS, **kw):
    return DT.make_context(DT.make_dist_mesh(n_shards), n_rows, **kw)


def _tspec():
    return tbl.EmbeddingTable(P(DT.AXIS), P(DT.AXIS), P(DT.AXIS))


def _put(ctx, x):
    return jax.device_put(x, NamedSharding(ctx.mesh, P(DT.AXIS)))


def _exchange(name, ctx, cap=None, patch_cap=None, dtype="f32"):
    return EX.make_exchange(name, axis_name=DT.AXIS,
                            num_shards=ctx.num_shards,
                            rows=ctx.rows_per_shard, cap=cap,
                            payload_dtype=dtype, patch_cap=patch_cap)


def _overlap_ids(mode, rng):
    """(cur_ids, next_ids): unique global batches with controlled overlap."""
    pool = rng.permutation(N_ROWS).astype(np.int32)
    cur = pool[:B_GLOBAL]
    if mode == "all":
        nxt = rng.permutation(cur)
    elif mode == "none":
        nxt = pool[B_GLOBAL:2 * B_GLOBAL]
    else:
        nxt = rng.permutation(np.concatenate(
            [cur[:B_GLOBAL // 2], pool[B_GLOBAL:B_GLOBAL + B_GLOBAL // 2]]))
    return cur, nxt.astype(np.int32)


def _payloads_sampled(ids, S=1):
    rng = np.random.default_rng(7)
    key = rng.normal(size=(N_ROWS + 1, S, DH)).astype(np.float32)
    sidx = (ids[:, None] + np.arange(S)[None, :]) % J
    return sidx.astype(np.int32), key[ids]


def _patch_callable(ex, with_dest):
    """update_sampled_patch flattened for shard_map (tuple args unpacked)."""
    if with_dest:
        def f(table, ids, sidx, h, step, pe, pi, nids, dest):
            t, (e, i) = ex.update_sampled_patch(table, ids, sidx, h, step,
                                                (pe, pi), nids, dest)
            return t, e, i
        return f

    def f(table, ids, sidx, h, step, pe, pi, nids):
        t, (e, i) = ex.update_sampled_patch(table, ids, sidx, h, step,
                                            (pe, pi), nids)
        return t, e, i
    return f


def _patch_specs(with_dest):
    ins = [_tspec(), P(DT.AXIS), P(DT.AXIS), P(DT.AXIS), P(),
           P(DT.AXIS), P(DT.AXIS), P(DT.AXIS)]
    if with_dest:
        ins.append(P(DT.AXIS))
    return tuple(ins), (_tspec(), P(DT.AXIS), P(DT.AXIS))


def _run_patch(ctx, ex, table, ids, sidx, h, step, pref, next_ids,
               dest=None):
    with_dest = dest is not None
    in_specs, out_specs = _patch_specs(with_dest)
    f = shard_map(_patch_callable(ex, with_dest), mesh=ctx.mesh,
                  in_specs=in_specs, out_specs=out_specs, check_rep=False)
    args = [DT.device_table(ctx, table), _put(ctx, jnp.asarray(ids)),
            _put(ctx, jnp.asarray(sidx)), _put(ctx, jnp.asarray(h)), step,
            _put(ctx, pref[0]), _put(ctx, pref[1]),
            _put(ctx, jnp.asarray(next_ids))]
    if with_dest:
        args.append(_put(ctx, jnp.asarray(dest)))
    got_t, got_e, got_i = jax.jit(f)(*args)
    return DT.host_table(ctx, got_t), np.asarray(got_e), np.asarray(got_i)


# ---------------------------------------------------------------------------
# fused-op parity: write-back == dense oracle AND the patched buffer ==
# a lookup of the post-write table, bit-exact, every overlap schedule
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("overlap", OVERLAPS)
@pytest.mark.parametrize("n_shards", MULTI_SHARDS + ([1] if 1 in SHARD_COUNTS else []))
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_update_sampled_patch_parity(strategy, n_shards, overlap):
    ctx = _ctx(n_shards)
    rng = np.random.default_rng(11)
    ids, next_ids = _overlap_ids(overlap, rng)
    sidx, h = _payloads_sampled(ids)
    table = _random_table(N_ROWS, J, DH)
    step = jnp.asarray(5, jnp.int32)
    cap = EX.required_capacity(ids, num_shards=n_shards,
                               rows=ctx.rows_per_shard)
    pcap = EX.required_patch_capacity(ids, next_ids, num_shards=n_shards,
                                      rows=ctx.rows_per_shard)
    ex = _exchange(strategy, ctx, cap=cap, patch_cap=pcap)
    pref = tbl.lookup(table, jnp.asarray(next_ids))
    dest = EX.consumer_shards(ids, next_ids, num_shards=n_shards,
                              rows=ctx.rows_per_shard) \
        if strategy == "bucketed" else None

    got_t, got_e, got_i = _run_patch(ctx, ex, table, ids, sidx, h, step,
                                     pref, next_ids, dest)
    want_t = tbl.update_sampled(table, jnp.asarray(ids), jnp.asarray(sidx),
                                jnp.asarray(h), step)
    # the table write is update_sampled verbatim
    for a, b in zip(got_t, want_t):
        assert (np.asarray(a) == np.asarray(b)).all()
    # the patched buffer equals a fresh lookup of the POST-write table —
    # the invariant that makes the next prefetched step read-correct
    want_e, want_i = tbl.lookup(want_t, jnp.asarray(next_ids))
    assert (got_e == np.asarray(want_e)).all(), overlap
    assert (got_i == np.asarray(want_i)).all(), overlap


def test_bucketed_patch_requires_next_dest():
    ctx = _ctx(SHARD_COUNTS[-1])
    ex = _exchange("bucketed", ctx, cap=2, patch_cap=1)
    if ctx.num_shards == 1:
        pytest.skip("one shard: the local fused path needs no routing")
    with pytest.raises(ValueError, match="next_dest"):
        ids = jnp.zeros(B_GLOBAL // ctx.num_shards, jnp.int32)
        ex.update_sampled_patch(
            _random_table(N_ROWS, J, DH), ids, jnp.zeros_like(ids[:, None]),
            jnp.zeros((ids.shape[0], 1, DH)), jnp.asarray(0, jnp.int32),
            (jnp.zeros((4, J, DH)), jnp.zeros((4, J), bool)),
            jnp.zeros(4, jnp.int32))


# ---------------------------------------------------------------------------
# sentinel / ragged next batches: pad slots read zeros, never patched
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_ragged_next_batch_sentinels_never_patched(strategy):
    """Next global batch of 2·D+3 rows, sentinel-padded: real slots are
    patched exactly like the dense case, pad slots keep their prefetched
    zeros, and the table write is untouched by the padding."""
    n_shards = SHARD_COUNTS[-1]
    ctx = _ctx(n_shards)
    rng = np.random.default_rng(3)
    ids = rng.permutation(N_ROWS)[:B_GLOBAL].astype(np.int32)
    sidx, h = _payloads_sampled(ids)
    B_next = 2 * n_shards + 3 if n_shards > 1 else 5
    # overlap the current batch so real patches actually happen
    next_real = np.concatenate(
        [ids[:B_next // 2],
         np.setdiff1d(rng.permutation(N_ROWS), ids)[:B_next - B_next // 2]]
    ).astype(np.int32)[:B_next]
    next_p, n_real = EX.pad_ragged(n_shards, ctx.rows_per_shard, next_real)
    # bucket capacity must cover BOTH batches: the current batch's write
    # and the next batch's prefetched lookup (the launcher plans over
    # the whole schedule with plan_capacity)
    cap = EX.plan_capacity([ids, next_p], num_shards=n_shards,
                           rows=ctx.rows_per_shard)
    pcap = EX.required_patch_capacity(ids, next_p, num_shards=n_shards,
                                      rows=ctx.rows_per_shard)
    ex = _exchange(strategy, ctx, cap=cap, patch_cap=pcap)
    table = _random_table(N_ROWS, J, DH)
    step = jnp.asarray(4, jnp.int32)
    # prefetched buffer for the padded batch: pad rows read EXACT zeros
    look = shard_map(ex.prefetch_lookup, mesh=ctx.mesh,
                     in_specs=(_tspec(), P(DT.AXIS)),
                     out_specs=(P(DT.AXIS), P(DT.AXIS)), check_rep=False)
    pe, pi = jax.jit(look)(DT.device_table(ctx, table),
                           _put(ctx, jnp.asarray(next_p)))
    assert (np.asarray(pe)[n_real:] == 0).all()
    assert not np.asarray(pi)[n_real:].any()

    dest = EX.consumer_shards(ids, next_p, num_shards=n_shards,
                              rows=ctx.rows_per_shard) \
        if strategy == "bucketed" else None
    got_t, got_e, got_i = _run_patch(
        ctx, ex, table, ids, sidx, h, step,
        (np.asarray(pe), np.asarray(pi)), next_p, dest)
    want_t = tbl.update_sampled(table, jnp.asarray(ids), jnp.asarray(sidx),
                                jnp.asarray(h), step)
    for a, b in zip(got_t, want_t):
        assert (np.asarray(a) == np.asarray(b)).all()
    want_e, want_i = tbl.lookup(want_t, jnp.asarray(next_real))
    assert (got_e[:n_real] == np.asarray(want_e)).all()
    assert (got_i[:n_real] == np.asarray(want_i)).all()
    # sentinel pad slots: never patched, still exact zeros
    assert (got_e[n_real:] == 0).all()
    assert not got_i[n_real:].any()


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_all_sentinel_next_batch_patch_noop(strategy):
    """The epoch tail: every next id is the sentinel — the patch must be
    a pure no-op on the throwaway buffer at every strategy."""
    n_shards = SHARD_COUNTS[-1]
    ctx = _ctx(n_shards)
    rng = np.random.default_rng(5)
    ids = rng.permutation(N_ROWS)[:B_GLOBAL].astype(np.int32)
    sidx, h = _payloads_sampled(ids)
    sent = n_shards * ctx.rows_per_shard
    next_ids = np.full(B_GLOBAL, sent, np.int32)
    ex = _exchange(strategy, ctx,
                   cap=EX.required_capacity(ids, num_shards=n_shards,
                                            rows=ctx.rows_per_shard),
                   patch_cap=1)
    table = _random_table(N_ROWS, J, DH)
    zeros = (np.zeros((B_GLOBAL, J, DH), np.float32),
             np.zeros((B_GLOBAL, J), bool))
    dest = np.full(B_GLOBAL, n_shards, np.int32) \
        if strategy == "bucketed" else None
    got_t, got_e, got_i = _run_patch(ctx, ex, table, ids, sidx, h,
                                     jnp.asarray(2, jnp.int32), zeros,
                                     next_ids, dest)
    want_t = tbl.update_sampled(table, jnp.asarray(ids), jnp.asarray(sidx),
                                jnp.asarray(h), jnp.asarray(2, jnp.int32))
    for a, b in zip(got_t, want_t):
        assert (np.asarray(a) == np.asarray(b)).all()
    assert (got_e == 0).all() and not got_i.any()


# ---------------------------------------------------------------------------
# bytes: prefetch_lookup == lookup traffic; the fused patch costs exactly
# patch_bytes extra (0 for ring/alltoall) — asserted against the jaxpr
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", list(EX.PAYLOAD_DTYPES))
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_prefetch_bytes_model_matches_measured(strategy, dtype):
    n_shards = SHARD_COUNTS[-1]
    ctx = _ctx(n_shards)
    B_local, S = 4, 2
    B = B_local * n_shards
    cap = 2 if n_shards > 1 else None
    pcap = 2 if n_shards > 1 else None
    ex = _exchange(strategy, ctx, cap=cap, patch_cap=pcap, dtype=dtype)
    dev = DT.device_table(ctx, _random_table(N_ROWS, J, DH))
    ids = jnp.zeros(B, jnp.int32)
    sidx = jnp.zeros((B, S), jnp.int32)
    h = jnp.zeros((B, S, DH), jnp.float32)
    step = jnp.asarray(0, jnp.int32)
    pe = jnp.zeros((B, J, DH), jnp.float32)
    pi = jnp.zeros((B, J), bool)
    dest = jnp.zeros(B, jnp.int32)

    look = shard_map(ex.prefetch_lookup, mesh=ctx.mesh,
                     in_specs=(_tspec(), P(DT.AXIS)),
                     out_specs=(P(DT.AXIS), P(DT.AXIS)), check_rep=False)
    measured_look = EX.measured_exchange_bytes(look, n_shards, dev, ids)
    assert measured_look == ex.prefetch_lookup_bytes(B_local, J, DH)
    assert measured_look == ex.lookup_bytes(B_local, J, DH)

    with_dest = strategy == "bucketed"
    in_specs, out_specs = _patch_specs(with_dest)
    patch = shard_map(_patch_callable(ex, with_dest), mesh=ctx.mesh,
                      in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
    args = (dev, ids, sidx, h, step, pe, pi, ids) + \
        ((dest,) if with_dest else ())
    measured = EX.measured_exchange_bytes(patch, n_shards, *args)
    assert measured == ex.update_sampled_patch_bytes(B_local, S, DH)
    # the surcharge over the inline write-back is exactly patch_bytes:
    # zero for ring/alltoall (fused into existing hops), the tiny
    # consumer-direct hop for bucketed
    surcharge = measured - ex.update_sampled_bytes(B_local, S, DH)
    assert surcharge == ex.patch_bytes(B_local, S, DH)
    if strategy in ("ring", "alltoall"):
        assert surcharge == 0


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_prefetch_train_step_bytes_model(strategy):
    ex = EX.make_exchange(strategy, axis_name="x", num_shards=8, rows=8,
                          cap=4, patch_cap=2)
    b, j, s, d = 16, 4, 1, 16
    assert ex.prefetch_train_step_bytes(b, j, s, d, use_table=True) == \
        ex.train_step_bytes(b, j, s, d, use_table=True) + \
        ex.patch_bytes(b, s, d)
    assert ex.prefetch_train_step_bytes(b, j, s, d, use_table=False) == 0
    if strategy != "bucketed":
        assert ex.patch_bytes(b, s, d) == 0


# ---------------------------------------------------------------------------
# host planners: consumer routing + patch capacity
# ---------------------------------------------------------------------------


def test_consumer_shards_routing():
    # 2 shards x 8 rows; next batch [1, 9, 2, 3]: positions 0-1 live on
    # shard 0, positions 2-3 on shard 1
    cur = np.asarray([1, 2, 7, 9])
    nxt = np.asarray([1, 9, 2, 3])
    dest = EX.consumer_shards(cur, nxt, num_shards=2, rows=8)
    assert dest.tolist() == [0, 1, 2, 0]    # 7 has no consumer
    # zero overlap: nobody travels
    assert (EX.consumer_shards(np.arange(4), np.arange(8, 12),
                               num_shards=2, rows=8) == 2).all()
    # ragged current batch is sentinel-padded; the pad row never matches
    d = EX.consumer_shards(np.asarray([0, 1, 2]), np.asarray([0, 1, 2, 3]),
                           num_shards=2, rows=8)
    assert d.shape[0] == 4 and d[3] == 2
    # sentinel ids in the NEXT batch are not consumers
    d = EX.consumer_shards(np.asarray([0, 16, 1, 3]),
                           np.asarray([0, 16, 3, 16]), num_shards=2, rows=8)
    assert d.tolist() == [0, 2, 2, 1]


def test_required_and_plan_patch_capacity():
    # all-overlap, contiguous halves: both of device 0's consumers live
    # on shard 0 => capacity 2
    ids = np.asarray([0, 1, 8, 9])
    assert EX.required_patch_capacity(ids, ids, num_shards=2, rows=8) == 2
    # zero overlap plans to the minimum bucket of 1
    assert EX.required_patch_capacity(np.arange(4), np.arange(8, 12),
                                      num_shards=2, rows=8) == 1
    # plan over a schedule = max over consecutive pairs only
    a, b = np.asarray([0, 1, 8, 9]), np.asarray([4, 5, 12, 13])
    assert EX.plan_patch_capacity([a, b, a], num_shards=2, rows=8) == 1
    assert EX.plan_patch_capacity([a, a, b], num_shards=2, rows=8) == 2
    # re-exported through dist.table like the other planners
    from repro.dist import table as dtbl
    assert dtbl.plan_patch_capacity is EX.plan_patch_capacity
    assert dtbl.consumer_shards is EX.consumer_shards


# ---------------------------------------------------------------------------
# end to end: prefetched training == the inline dist oracle, bit for bit
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def dataset():
    graphs = D.make_malnet_like(n_graphs=16, seed=0)
    ds, spec = DP.segment_dataset_shared(graphs, 16, seed=0)
    return ds


def _state(ds):
    cfg = GNNConfig(backbone="sage", n_feat=ds.x.shape[-1], hidden=HID)
    enc = make_encode_fn(cfg)
    key = jax.random.key(0)
    bb = gnn_init(key, cfg)
    head = G.head_init(jax.random.fold_in(key, 1), HID, 5, "mlp")
    opt = make_optimizer("adam", lr=5e-3)
    return enc, opt, G.TrainState(bb, head, opt.init((bb, head)),
                                  init_table(ds.n, ds.j_max, HID),
                                  jnp.zeros((), jnp.int32))


def _schedule(ds, mode, steps=4, seed=0):
    rng = np.random.default_rng(seed)
    if mode == "all":
        base = rng.permutation(ds.n)[:B_GLOBAL].astype(np.int32)
        return [rng.permutation(base) for _ in range(steps)]
    if mode == "none":
        a = np.arange(B_GLOBAL, dtype=np.int32)
        b = np.arange(B_GLOBAL, 2 * B_GLOBAL, dtype=np.int32)
        return [a, b] * (steps // 2)
    raise ValueError(mode)


def _mk_ctxs(ds, n_shards, strategy, sched):
    rows = _ctx(n_shards, ds.n).rows_per_shard
    cap = EX.plan_capacity(sched, num_shards=n_shards, rows=rows) \
        if strategy == "bucketed" else None
    pcap = EX.plan_patch_capacity(sched, num_shards=n_shards, rows=rows) \
        if strategy == "bucketed" else None
    mk = lambda **kw: DT.make_context(DT.make_dist_mesh(n_shards), ds.n,
                                      exchange=strategy, exchange_cap=cap,
                                      **kw)
    return mk(), mk(prefetch=True, patch_cap=pcap)


def _assemble_padded(ds, ids):
    """Like the feeder's _assemble, but sentinel-tolerant: pad rows gather
    graph 0's inputs (identical garbage on both runs) while graph_ids
    keeps the sentinel so the table ops drop their reads and writes."""
    real = np.where(ids < ds.n, ids, 0).astype(np.int32)
    return DP._assemble(ds, real)._replace(graph_ids=ids.astype(np.int32))


def _run_inline(ds, enc, opt, state0, variant, ctx, sched):
    step = DT.make_dist_train_step(enc, opt, G.VARIANTS[variant], ctx=ctx,
                                   keep_prob=0.5, donate=False)
    state = DT.device_state(ctx, state0)
    m = None
    for ids in sched:
        state, m = step(state,
                        DT.shard_batch(ctx, _assemble_padded(ds, ids)),
                        jax.random.PRNGKey(3))
    return state, m


def _run_prefetched(ds, enc, opt, state0, variant, ctx, sched):
    """The launcher's prefetch loop, driven by hand over a schedule."""
    pstep = DT.make_dist_train_step(enc, opt, G.VARIANTS[variant], ctx=ctx,
                                    keep_prob=0.5, donate=False)
    pf = DT.make_prefetch_lookup(ctx)
    bsh = DT.batch_sharding(ctx)
    sent = ctx.num_shards * ctx.table_rows
    batches = [(ids, DT.shard_batch(ctx, _assemble_padded(ds, ids)))
               for ids in sched]
    state = DT.device_state(ctx, state0)
    pref, m = None, None
    for k, (ids, b) in enumerate(batches):
        if pref is None:
            pref = pf(state.table, b.graph_ids)
        if k + 1 < len(batches):
            nids, nb = batches[k + 1]
            nxt, next_ids = pf(state.table, nb.graph_ids), nb.graph_ids
            dest = EX.consumer_shards(ids, nids, num_shards=ctx.num_shards,
                                      rows=ctx.table_rows)
        else:
            B = ids.shape[0]
            next_ids = jax.device_put(np.full(B, sent, np.int32), bsh)
            nxt = (jax.device_put(np.zeros((B, ds.j_max, HID), np.float32),
                                  bsh),
                   jax.device_put(np.zeros((B, ds.j_max), bool), bsh))
            dest = np.full(B, ctx.num_shards, np.int32)
        state, m, pref = pstep(state, b, jax.random.PRNGKey(3), pref, nxt,
                               next_ids,
                               jax.device_put(np.asarray(dest, np.int32),
                                              bsh))
    return state, m


def _assert_bit_exact(ctx_a, s_a, m_a, ctx_b, s_b, m_b):
    ta, tb = DT.host_table(ctx_a, s_a.table), DT.host_table(ctx_b, s_b.table)
    assert (np.asarray(ta.age) == np.asarray(tb.age)).all()
    assert (np.asarray(ta.initialized) ==
            np.asarray(tb.initialized)).all()
    assert (np.asarray(ta.emb) == np.asarray(tb.emb)).all()
    pa = jax.device_get((s_a.backbone, s_a.head))
    pb = jax.device_get((s_b.backbone, s_b.head))
    for x, y in zip(jax.tree_util.tree_leaves(pa),
                    jax.tree_util.tree_leaves(pb)):
        assert (np.asarray(x) == np.asarray(y)).all()
    assert float(m_a["loss"]) == float(m_b["loss"])


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("variant", list(G.VARIANTS))
def test_prefetched_training_bit_exact_all_variants(dataset, variant,
                                                    strategy):
    ds = dataset
    if N_DEV == 1 and variant != "gst_efd":
        pytest.skip("single-device host: the degenerate mesh is covered by "
                    "the complete method; the full 7x3 matrix runs in the "
                    "exchange-matrix CI prefetch leg at 8 forced devices")
    n_shards = SHARD_COUNTS[-1]
    sched = _schedule(ds, "all", steps=4, seed=1)
    ctx_i, ctx_p = _mk_ctxs(ds, n_shards, strategy, sched)
    enc, opt, state0 = _state(ds)
    s1, m1 = _run_inline(ds, enc, opt, state0, variant, ctx_i, sched)
    s2, m2 = _run_prefetched(ds, enc, opt, state0, variant, ctx_p, sched)
    _assert_bit_exact(ctx_i, s1, m1, ctx_p, s2, m2)


@pytest.mark.parametrize("overlap", ("all", "none"))
@pytest.mark.parametrize("n_shards", MULTI_SHARDS)
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_prefetched_training_adversarial_schedules(dataset, strategy,
                                                   n_shards, overlap):
    """All-overlap (every row patched every step) and zero-overlap (the
    patch must be a perfect no-op) schedules, shards {2, 8}."""
    ds = dataset
    sched = _schedule(ds, overlap, steps=4, seed=2)
    ctx_i, ctx_p = _mk_ctxs(ds, n_shards, strategy, sched)
    enc, opt, state0 = _state(ds)
    s1, m1 = _run_inline(ds, enc, opt, state0, "gst_efd", ctx_i, sched)
    s2, m2 = _run_prefetched(ds, enc, opt, state0, "gst_efd", ctx_p, sched)
    _assert_bit_exact(ctx_i, s1, m1, ctx_p, s2, m2)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_prefetched_training_ragged_tail(dataset, strategy):
    """A ragged LAST batch (size not divisible by the shard count) rides
    the prefetch lane via pad_ragged: sentinel rows read zeros, writes
    land nowhere, the run stays bit-exact vs inline on the same padded
    schedule."""
    ds = dataset
    n_shards = SHARD_COUNTS[-1]
    if n_shards == 1:
        pytest.skip("raggedness needs a multi-shard batch split")
    rng = np.random.default_rng(9)
    rows = _ctx(n_shards, ds.n).rows_per_shard
    full = rng.permutation(ds.n)[:B_GLOBAL].astype(np.int32)
    tail = rng.permutation(ds.n)[:n_shards + 1].astype(np.int32)
    tail_p, _ = EX.pad_ragged(n_shards, rows, tail)
    sched = [full, tail_p]
    ctx_i, ctx_p = _mk_ctxs(ds, n_shards, strategy, sched)
    enc, opt, state0 = _state(ds)
    s1, m1 = _run_inline(ds, enc, opt, state0, "gst_efd", ctx_i, sched)
    s2, m2 = _run_prefetched(ds, enc, opt, state0, "gst_efd", ctx_p, sched)
    _assert_bit_exact(ctx_i, s1, m1, ctx_p, s2, m2)


# ---------------------------------------------------------------------------
# PrefetchLane mechanics
# ---------------------------------------------------------------------------


class _FakeFeeder:
    def __init__(self, items):
        self.items = list(items)
        self.closed = False
        self.stats = "the-stats"

    def __iter__(self):
        for it in self.items:
            if isinstance(it, Exception):
                raise it
            yield it

    def close(self):
        self.closed = True


def test_prefetch_lane_dispatch_order_and_pairing():
    events = []
    feeder = _FakeFeeder(["a", "b", "c"])
    lane = DP.PrefetchLane(feeder,
                           lambda it: events.append(("d", it)) or f"h:{it}")
    out = []
    for cur, cur_h, nxt, nxt_h in lane:
        events.append(("y", cur))
        out.append((cur, cur_h, nxt, nxt_h))
    # every item dispatched exactly once, BEFORE the step that runs while
    # its lookup is in flight: d(a), d(b) precede y(a)
    assert events == [("d", "a"), ("d", "b"), ("y", "a"),
                      ("d", "c"), ("y", "b"), ("y", "c")]
    assert out == [("a", "h:a", "b", "h:b"), ("b", "h:b", "c", "h:c"),
                   ("c", "h:c", None, None)]
    assert lane.prefetch_batches == 3
    assert feeder.closed
    assert lane.stats == "the-stats"


def test_prefetch_lane_single_and_empty():
    lane = DP.PrefetchLane(_FakeFeeder(["only"]), lambda it: "h")
    assert list(lane) == [("only", "h", None, None)]
    feeder = _FakeFeeder([])
    lane = DP.PrefetchLane(feeder, lambda it: pytest.fail("no dispatch"))
    assert list(lane) == []
    assert feeder.closed


def test_prefetch_lane_error_propagates_and_closes():
    feeder = _FakeFeeder(["a", RuntimeError("boom")])
    lane = DP.PrefetchLane(feeder, lambda it: "h")
    with pytest.raises(RuntimeError, match="boom"):
        list(lane)
    assert feeder.closed


# ---------------------------------------------------------------------------
# tiered-store lookahead pinning
# ---------------------------------------------------------------------------


def test_tiered_store_lookahead_pinning():
    ctx = _ctx(1, n_rows=16, device_rows=8)
    store = DT.make_dist_store(ctx, J, DH)
    try:
        store.restore(init_table(16, J, DH))
        prep_a = store.begin(np.arange(6, dtype=np.int32), pin=True)
        # pinned rows shrink the displaceable pool: 6 pinned + 6 new > 8
        with pytest.raises(RuntimeError, match="lookahead pinning"):
            store.begin(np.arange(6, 12, dtype=np.int32))
        # releasing the pin frees the tier again
        store.release(prep_a)
        store.begin(np.arange(6, 12, dtype=np.int32))
    finally:
        store.close()


def test_tiered_store_unpinned_begins_unaffected():
    ctx = _ctx(1, n_rows=16, device_rows=8)
    store = DT.make_dist_store(ctx, J, DH)
    try:
        store.restore(init_table(16, J, DH))
        store.begin(np.arange(6, dtype=np.int32))          # no pin
        store.begin(np.arange(6, 12, dtype=np.int32))      # fine
    finally:
        store.close()


def test_device_store_accepts_pin_noop():
    ctx = _ctx(1, n_rows=16)
    store = DT.make_dist_store(ctx, J, DH)
    try:
        store.restore(init_table(16, J, DH))
        prep = store.begin(np.arange(4, dtype=np.int32), pin=True)
        store.release(prep)     # base release: no-op, never raises
    finally:
        store.close()


# ---------------------------------------------------------------------------
# observability: recorder families + the CI gate contract
# ---------------------------------------------------------------------------


def test_record_prefetch_exchange_families():
    reg = MetricsRegistry()
    record_prefetch_exchange("ring", "f32", 1234, 3, registry=reg)
    record_prefetch_exchange("ring", "f32", 1234, 0, registry=reg)
    snap = reg.snapshot()
    assert snap["exchange.prefetch.bytes.ring.f32"]["value"] == 2468
    hist = snap["exchange.prefetch.patched_rows"]
    assert hist["count"] == 2


def _gate_stream(tmp_path, metrics, name="s.jsonl"):
    p = tmp_path / name
    p.write_text(json.dumps({"type": "summary", "metrics": metrics}) + "\n")
    return str(p)


_BASE_METRICS = {"staleness.row_age": {"p99": 1.0},
                 "staleness.sed_drop_rate": 0.0}
_DIST_METRICS = {**_BASE_METRICS, "store.wb_skip_rate": 0.0,
                 "exchange.bytes.ring.f32": 10.0}
_PREFETCH_METRICS = {**_DIST_METRICS,
                     "exchange.prefetch.bytes.ring.f32": 10.0,
                     "exchange.prefetch.patched_rows": {"count": 4}}


def test_gate_requires_prefetch_families(tmp_path):
    # a stream advertising the lane with ALL its families passes
    ok = _gate_stream(tmp_path, _PREFETCH_METRICS, "ok.jsonl")
    assert gate_main(["--train-jsonl", ok]) == 0
    assert gate_main(["--train-jsonl", ok, "--expect-prefetch"]) == 0
    # half-wired lane (bytes counter without the patched-rows histogram)
    # fails even WITHOUT the flag: advertising any exchange.prefetch.*
    # metric pins the whole family set
    half = dict(_PREFETCH_METRICS)
    del half["exchange.prefetch.patched_rows"]
    bad = _gate_stream(tmp_path, half, "half.jsonl")
    assert gate_main(["--train-jsonl", bad]) == 1
    # a non-prefetch dist stream passes bare but fails the pinned flag
    plain = _gate_stream(tmp_path, _DIST_METRICS, "plain.jsonl")
    assert gate_main(["--train-jsonl", plain]) == 0
    assert gate_main(["--train-jsonl", plain, "--expect-prefetch"]) == 1
