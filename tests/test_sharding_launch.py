"""Sharding rules + launch specs: rules produce valid divisible specs, and
every step spec lowers on the 1-device debug mesh (structure correctness;
the 256/512-chip lowering is the dry-run's job)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config, reduced
from repro.launch import sharding as SH
from repro.launch.mesh import make_debug_mesh
from repro.launch.specs import build_step_spec, decode_plan


@pytest.fixture(scope="module")
def mesh():
    return make_debug_mesh()


def test_rules_match_expected_roles(mesh):
    spec = SH.spec_for_path(mesh, "runs/0/attn/wq", (4, 256, 512))
    assert len(spec) == 3  # padded to ndim with leading None
    spec2 = SH.spec_for_path(mesh, "norm1/scale", (256,))
    assert spec2 == P()


def test_divisibility_fallback():
    """On a mesh whose axes don't divide a dim, the spec falls back to None
    instead of producing an invalid sharding."""
    mesh = make_debug_mesh()  # sizes 1 -> everything divides; check helper
    # craft: model axis size 1 -> resolved axis must be 'model' or None but
    # spec construction never raises
    s = SH.spec_for_path(mesh, "experts/w_in", (3, 50, 77))
    assert len(s) == 3


def test_cache_spec_layer_axis_replicated(mesh):
    # stacked per-layer kv cache: layer axis must be None
    s = SH.cache_spec(mesh, "0/k", (16, 128, 32768, 8, 128))
    assert s[0] is None
    # batch axis may shard (size-1 mesh -> None here, but index position holds)
    s2 = SH.cache_spec(mesh, "0/ssm", (38, 8, 32, 128, 64))
    assert s2[0] is None


def test_seq_shard_targets_sequence_dim(mesh):
    s = SH.cache_spec(mesh, "0/ckv", (61, 1, 524288, 512), seq_shard=True)
    assert len(s) == 4


ARCHS_FAST = ["internlm2-1.8b", "zamba2-1.2b", "rwkv6-7b"]


@pytest.mark.parametrize("arch", ARCHS_FAST)
@pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
def test_step_specs_lower_on_debug_mesh(arch, shape, mesh):
    """Reduced configs × real shapes-machinery: lower() must succeed.
    (Full-size lowering on the production meshes is launch/dryrun.py.)"""
    cfg = reduced(get_config(arch))
    # shrink the shape for CPU lowering speed
    import dataclasses
    from repro.configs.base import InputShape
    import repro.launch.specs as specs_mod
    small = {
        "train_4k": InputShape("train_4k", 64, 4, "train"),
        "decode_32k": InputShape("decode_32k", 64, 2, "decode"),
    }[shape]
    orig = specs_mod.INPUT_SHAPES[shape]
    specs_mod.INPUT_SHAPES[shape] = small
    try:
        spec = build_step_spec(cfg, shape, mesh, dtype=jnp.float32)
        with mesh:
            lowered = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                              out_shardings=spec.out_shardings,
                              donate_argnums=spec.donate_argnums).lower(*spec.args)
            assert lowered is not None
    finally:
        specs_mod.INPUT_SHAPES[shape] = orig


def test_decode_plans():
    assert decode_plan(get_config("rwkv6-7b"), INPUT_SHAPES["long_500k"]).cache_len == 1
    p = decode_plan(get_config("deepseek-v3-671b"), INPUT_SHAPES["long_500k"])
    assert p.cache_len == 524_288 and p.seq_shard
    p2 = decode_plan(get_config("internlm2-20b"), INPUT_SHAPES["long_500k"])
    assert p2.ring and p2.window == p2.cache_len
    p3 = decode_plan(get_config("olmo-1b"), INPUT_SHAPES["decode_32k"])
    assert p3.cache_len == 32_768 and not p3.ring


def test_whisper_skips_long_500k():
    cfg = get_config("whisper-large-v3")
    assert not cfg.supports_shape(INPUT_SHAPES["long_500k"])
    assert cfg.supports_shape(INPUT_SHAPES["decode_32k"])
