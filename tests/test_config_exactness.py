"""The 10 assigned architecture configs must match the assignment table
LITERALLY — layer count, d_model, heads, kv heads, d_ff, vocab, family
extras.  This is the executable form of deliverable (f)'s spec."""
import pytest

from repro.configs import get_config

# (family, L, d_model, H, kv, d_ff, vocab)
SPEC = {
    "arctic-480b":        ("moe",    35, 7168, 56, 8, 4864, 32000),
    "internlm2-1.8b":     ("dense",  24, 2048, 16, 8, 8192, 92544),
    "internlm2-20b":      ("dense",  48, 6144, 48, 8, 16384, 92544),
    "zamba2-1.2b":        ("hybrid", 38, 2048, 32, 32, 8192, 32000),
    "olmo-1b":            ("dense",  16, 2048, 16, 16, 8192, 50304),
    "rwkv6-7b":           ("ssm",    32, 4096, 0, 0, 14336, 65536),
    "deepseek-v3-671b":   ("moe",    61, 7168, 128, 128, None, 129280),
    "deepseek-coder-33b": ("dense",  62, 7168, 56, 8, 19200, 32256),
    "whisper-large-v3":   ("audio",  32, 1280, 20, 20, 5120, 51866),
    "qwen2-vl-7b":        ("vlm",    28, 3584, 28, 4, 18944, 152064),
}


@pytest.mark.parametrize("arch", list(SPEC))
def test_config_matches_assignment(arch):
    fam, L, d, H, kv, ff, V = SPEC[arch]
    cfg = get_config(arch)
    assert cfg.family == fam
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == H
    assert cfg.num_kv_heads == kv
    if ff is not None:
        assert cfg.d_ff == ff
    assert cfg.vocab_size == V
    assert cfg.source, f"{arch}: missing citation"


def test_family_extras():
    a = get_config("arctic-480b")
    assert a.moe.num_experts == 128 and a.moe.top_k == 2
    assert a.moe.dense_d_ff == 4864          # dense residual path
    d = get_config("deepseek-v3-671b")
    assert d.moe.num_experts == 256 and d.moe.top_k == 8
    assert d.moe.num_shared_experts == 1 and d.moe.expert_d_ff == 2048
    assert d.use_mla
    z = get_config("zamba2-1.2b")
    assert z.ssm.state_size == 64
    assert "shared_attn" in z.block_pattern and "mamba" in z.block_pattern
    r = get_config("rwkv6-7b")
    assert r.attn_free
    w = get_config("whisper-large-v3")
    assert w.is_encoder_decoder and w.encoder_seq_len == 1500
    q = get_config("qwen2-vl-7b")
    assert sum(q.mrope_sections) == q.resolved_head_dim // 2
    assert q.vision_prefix_len > 0


def test_every_arch_covers_its_shapes():
    """supports_shape must allow everything except the documented skip."""
    from repro.configs import ARCH_IDS, INPUT_SHAPES
    skips = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in INPUT_SHAPES.values():
            if not cfg.supports_shape(s):
                skips.append((a, s.name))
    assert skips == [("whisper-large-v3", "long_500k")]
