import os

# Tests must see the real single-CPU environment (the 512-device override is
# exclusively for launch/dryrun.py per the assignment).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)
