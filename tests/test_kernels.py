"""Pallas kernels vs pure-jnp oracles: fixed cases + hypothesis shape sweeps.

All kernels run in interpret mode on CPU (the kernels target TPU; interpret
executes the kernel body in Python — the assignment's validation method).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.sed_pool import sed_pool
from repro.kernels.segment_spmm import segment_spmm
from repro.kernels.swa_attention import swa_attention

HSET = settings(max_examples=8, deadline=None)


# ---------------------------------------------------------------------------
# segment_spmm
# ---------------------------------------------------------------------------


@given(m=st.sampled_from([16, 64, 128, 256]),
       d=st.sampled_from([8, 64, 130, 256]),
       e=st.integers(1, 600),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
       seed=st.integers(0, 10_000))
@HSET
def test_spmm_matches_oracle(m, d, e, dtype, seed):
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.normal(size=(m, d)), dtype)
    src = jnp.asarray(rng.integers(0, m, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, m, e), jnp.int32)
    w = jnp.asarray(rng.uniform(0, 1, e) * (rng.uniform(size=e) > 0.3), dtype)
    out = segment_spmm(h, src, dst, w, interpret=True)
    want = ref.segment_spmm_ref(h.astype(jnp.float32), src, dst,
                                w.astype(jnp.float32), m)
    tol = 1e-5 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(want),
                               rtol=tol, atol=tol)


def test_spmm_zero_weights_give_zero():
    h = jnp.ones((32, 16))
    src = jnp.zeros((10,), jnp.int32)
    dst = jnp.arange(10, dtype=jnp.int32)
    out = segment_spmm(h, src, dst, jnp.zeros((10,)), interpret=True)
    assert float(jnp.abs(out).max()) == 0.0


# ---------------------------------------------------------------------------
# sed_pool
# ---------------------------------------------------------------------------


@given(B=st.integers(1, 17), J=st.integers(1, 24),
       d=st.sampled_from([8, 64, 128, 200]),
       p=st.sampled_from([0.0, 0.3, 0.5, 1.0]),
       S=st.integers(1, 3), agg=st.sampled_from(["mean", "sum"]),
       seed=st.integers(0, 10_000))
@HSET
def test_sed_pool_matches_oracle(B, J, d, p, S, agg, seed):
    rng = np.random.default_rng(seed)
    S = min(S, J)
    h = jnp.asarray(rng.normal(size=(B, J, d)), jnp.float32)
    valid = jnp.asarray(rng.uniform(size=(B, J)) < 0.8, jnp.float32)
    valid = valid.at[:, 0].set(1.0)
    fresh = jnp.zeros((B, J)).at[jnp.arange(B), rng.integers(0, J, B)].set(1.0)
    fresh = fresh * valid
    drop = jnp.asarray(rng.uniform(size=(B, J)) < 0.5, jnp.float32)
    out = sed_pool(h, valid, fresh, drop, keep_prob=p, num_sampled=S, agg=agg,
                   interpret=True)
    want = ref.sed_pool_ref(h, valid, fresh, drop, p, S, agg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_sed_pool_matches_core_composition():
    """Kernel == segment.sed_weights + segment.aggregate on the same draw."""
    from repro.core import segment as seg
    rng = np.random.default_rng(7)
    B, J, d, p = 6, 9, 32, 0.4
    h = jnp.asarray(rng.normal(size=(B, J, d)), jnp.float32)
    valid = jnp.ones((B, J))
    fresh = jnp.zeros((B, J)).at[jnp.arange(B), rng.integers(0, J, B)].set(1.0)
    key = jax.random.key(3)
    eta, drop = seg.sed_weights(key, valid, fresh, p, 1)
    via_core = seg.aggregate(h, eta, valid, "mean")
    via_kernel = sed_pool(h, valid, fresh, drop, keep_prob=p, num_sampled=1,
                          agg="mean", interpret=True)
    np.testing.assert_allclose(np.asarray(via_kernel), np.asarray(via_core),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# swa_attention
# ---------------------------------------------------------------------------


@given(B=st.integers(1, 3), S=st.sampled_from([128, 256, 512]),
       H=st.sampled_from([1, 2, 4]), D=st.sampled_from([64, 128]),
       Wb=st.sampled_from([1, 2, 4, 100]),  # window in blocks
       seed=st.integers(0, 10_000))
@HSET
def test_swa_matches_oracle(B, S, H, D, Wb, seed):
    rng = np.random.default_rng(seed)
    blk = 128
    W = min(Wb * blk, S) if Wb != 100 else S  # 100 => full-causal window
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    out = swa_attention(q, k, v, window=W, blk=blk, interpret=True)
    want = ref.swa_attention_ref(q, k, v, W)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_swa_full_window_equals_causal_attention():
    """window >= S must reproduce plain causal attention (common.sdpa)."""
    from repro.models.common import sdpa
    rng = np.random.default_rng(11)
    B, S, H, D = 2, 256, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    out = swa_attention(q, k, v, window=S, blk=128, interpret=True)
    want = sdpa(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_gnn_pallas_path_matches_jnp_path():
    """segment_spmm wired into the SAGE backbone (vmapped over segments)
    must reproduce the jax.ops.segment_sum path exactly."""
    import numpy as np
    from repro.graphs import data as D, batching as Bt
    from repro.graphs.gnn import GNNConfig, gnn_init, make_encode_fn
    graphs = D.make_malnet_like(n_graphs=2, seed=0)
    ds = Bt.segment_dataset(graphs, max_seg_nodes=48)
    seg = {k: jnp.asarray(v.reshape((-1,) + v.shape[2:]))
           for k, v in ds.seg_inputs(np.arange(2)).items()}
    cfg0 = GNNConfig(backbone="sage", n_feat=8, hidden=32, use_pallas=False)
    cfg1 = GNNConfig(backbone="sage", n_feat=8, hidden=32, use_pallas=True)
    params = gnn_init(jax.random.key(0), cfg0)
    e0, _ = make_encode_fn(cfg0)(params, seg)
    e1, _ = make_encode_fn(cfg1)(params, seg)
    np.testing.assert_allclose(np.asarray(e0), np.asarray(e1),
                               rtol=2e-5, atol=2e-5)
