"""Roofline machinery: HLO collective parsing + cost-analysis calibration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import (HW, analyze_compiled, collective_bytes,
                                     count_collective_ops, model_flops,
                                     param_counts)


SAMPLE_HLO = """
HloModule test
ENTRY %main {
  %p0 = bf16[8,128]{1,0} parameter(0)
  %ag = bf16[64,128]{1,0} all-gather(bf16[8,128]{1,0} %p0), dimensions={0}
  %ar = f32[256,64]{1,0} all-reduce(f32[256,64]{1,0} %x), to_apply=%sum
  %rs = bf16[4,32]{1,0} reduce-scatter(bf16[32,32]{1,0} %y), dimensions={0}
  %cp = bf16[16,16]{1,0} collective-permute(bf16[16,16]{1,0} %z)
  %noise = f32[2,2]{1,0} add(f32[2,2]{1,0} %a, f32[2,2]{1,0} %b)
}
"""


def test_collective_parsing_counts_and_bytes():
    by = collective_bytes(SAMPLE_HLO)
    assert by["all-gather"] == 64 * 128 * 2          # result bytes
    assert by["all-reduce"] == 256 * 64 * 4
    assert by["reduce-scatter"] == 32 * 32 * 2       # operand > result
    assert by["collective-permute"] == 16 * 16 * 2
    assert by["total"] == sum(by[k] for k in
                              ("all-gather", "all-reduce", "reduce-scatter",
                               "all-to-all", "collective-permute"))
    counts = count_collective_ops(SAMPLE_HLO)
    assert counts["all-gather"] == 1 and counts["all-to-all"] == 0


def test_cost_analysis_is_per_device_and_terms_scale():
    """Calibration: a known matmul on a 1-device mesh — flops must match the
    analytic 2MKN within a small tolerance, and the roofline terms follow."""
    M, K, N = 256, 128, 512
    comp = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((K, N), jnp.float32)).compile()
    rep = analyze_compiled(comp, chips=1, n_active=K * N, tokens=M,
                           kind="infer")
    analytic = 2 * M * K * N
    assert abs(rep["flops_global"] - analytic) / analytic < 0.1
    np.testing.assert_allclose(rep["terms_seconds"]["compute"],
                               rep["flops_global"] / HW().peak_flops,
                               rtol=1e-9)
    # useful-flops ratio: model_flops = 2*K*N*M == analytic -> ratio ~1
    np.testing.assert_allclose(rep["useful_flops_ratio"], 1.0, atol=0.1)


def test_param_counts_moe_active_scaling():
    shapes = {
        "attn": {"wq": jax.ShapeDtypeStruct((64, 64), jnp.float32)},
        "moe": {"experts": {"w_in": jax.ShapeDtypeStruct((8, 64, 128), jnp.float32)}},
    }
    total, active = param_counts(shapes, moe_top_k=2, moe_num_experts=8)
    assert total == 64 * 64 + 8 * 64 * 128
    assert active == 64 * 64 + 8 * 64 * 128 * (2 / 8)


def test_model_flops_formulas():
    assert model_flops(1e9, 100, "train") == 6e11
    assert model_flops(1e9, 100, "infer") == 2e11
