"""Graph substrate: partitioners, padded batching invariants, GNN encoders."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.graphs import data as D
from repro.graphs import partition as P
from repro.graphs import batching as Bt
from repro.graphs.gnn import GNNConfig, gnn_init, make_encode_fn

HSET = settings(max_examples=6, deadline=None)


def _graph(seed=0, n_graphs=3):
    return D.make_malnet_like(n_graphs=n_graphs, seed=seed)


@pytest.mark.parametrize("method", list(P.PARTITIONERS))
def test_partitioners_cover_all_nodes_and_respect_cap(method):
    g = _graph()[0]
    segs = P.partition_graph(len(g.x), g.edges, 48, method)
    covered = set()
    for s in segs:
        assert len(s) <= 48, f"{method} exceeded max size"
        covered.update(int(u) for u in s)
    assert covered == set(range(len(g.x))), f"{method} lost nodes"


def test_bfs_partition_preserves_locality_better_than_random():
    """Locality metric: fraction of edges kept inside segments — the paper's
    Table 6 mechanism (random edge-cut destroys structure)."""
    g = _graph(seed=3)[0]

    def kept_fraction(method):
        segs = P.partition_graph(len(g.x), g.edges, 48, method)
        assign = {}
        for si, s in enumerate(segs):
            for u in s:
                assign.setdefault(int(u), si)
        kept = sum(1 for a, b in g.edges if assign[int(a)] == assign[int(b)])
        return kept / len(g.edges)

    assert kept_fraction("bfs") > kept_fraction("random") + 0.2


@given(max_seg=st.sampled_from([32, 48, 64]), seed=st.integers(0, 100))
@HSET
def test_segment_dataset_masks_consistent(max_seg, seed):
    graphs = _graph(seed=seed, n_graphs=2)
    ds = Bt.segment_dataset(graphs, max_seg_nodes=max_seg)
    # segment validity implies node validity; edges index only valid nodes
    for gi in range(ds.n):
        for j in range(ds.j_max):
            if ds.seg_valid[gi, j] == 0:
                assert ds.node_valid[gi, j].sum() == 0
                continue
            nv = int(ds.node_valid[gi, j].sum())
            ev = ds.edge_valid[gi, j] > 0
            if ev.any():
                assert ds.edges[gi, j][ev].max() < nv
    # every graph's nodes are covered across segments
    for gi, g in enumerate(graphs):
        total_nodes = int(ds.node_valid[gi].sum())
        assert total_nodes >= len(g.x)  # >= because vertex-cut may duplicate


def test_padding_invariance_of_encoder():
    """Adding pad rows/edges must not change the segment embedding."""
    graphs = _graph(seed=1, n_graphs=1)
    ds_small = Bt.segment_dataset(graphs, max_seg_nodes=48)
    ds_big = Bt.segment_dataset(graphs, max_seg_nodes=48,
                                e_max=ds_small.e_max + 37)
    cfg = GNNConfig(backbone="sage", n_feat=graphs[0].x.shape[1], hidden=16)
    params = gnn_init(jax.random.key(0), cfg)
    enc = make_encode_fn(cfg)
    flat = lambda ds: {k: jnp.asarray(v[0]) for k, v in ds.seg_inputs(np.asarray([0])).items()}
    e1, _ = enc(params, flat(ds_small))
    e2, _ = enc(params, flat(ds_big))
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("backbone", ["gcn", "sage", "gps"])
def test_gnn_backbones_finite_and_shaped(backbone):
    graphs = _graph(seed=2, n_graphs=2)
    ds = Bt.segment_dataset(graphs, max_seg_nodes=48)
    cfg = GNNConfig(backbone=backbone, n_feat=graphs[0].x.shape[1], hidden=32)
    params = gnn_init(jax.random.key(0), cfg)
    enc = make_encode_fn(cfg)
    seg = {k: jnp.asarray(v.reshape((-1,) + v.shape[2:]))
           for k, v in ds.seg_inputs(np.arange(2)).items()}
    emb, aux = enc(params, seg)
    assert emb.shape == (2 * ds.j_max, 32)
    assert bool(jnp.isfinite(emb).all())


def test_malnet_label_requires_global_information():
    """No single community determines the majority label in general —
    sanity-check the dataset actually exercises GST's aggregation."""
    graphs = D.make_malnet_like(n_graphs=40, seed=0)
    disagree = 0
    for g in graphs:
        types = g.meta["types"]
        if any(int(t) != g.label for t in types):
            disagree += 1
    assert disagree > len(graphs) // 2


def test_tpugraphs_runtime_is_segment_decomposable():
    graphs = D.make_tpugraphs_like(n_graphs=8, seed=0)
    assert all(isinstance(g.label, float) for g in graphs)
    # same graph, different configs -> different runtimes (ranking signal)
    labels = [g.label for g in graphs[:4]]
    assert len(set(np.round(labels, 6))) > 1
