"""End-to-end behaviour tests for the GST system (paper claims, CPU scale).

The centerpiece is the paper's core claim: **training memory is constant in
the number of segments** (i.e. in graph size) for GST, but grows linearly
for full-graph training — checked on the compiled executable's temp buffer
sizes, the XLA analogue of the paper's GPU peak-memory measurements.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gst as G
from repro.core.embedding_table import init_table
from repro.graphs import data as D, batching as Bt
from repro.graphs.gnn import GNNConfig, gnn_init, make_encode_fn
from repro.optim import make_optimizer


def _setup(variant, J, m=48, B=4, hidden=32, n=16, seed=0):
    cfg = GNNConfig(backbone="sage", n_feat=8, hidden=hidden)
    enc = make_encode_fn(cfg)
    bb = gnn_init(jax.random.key(seed), cfg)
    head = G.head_init(jax.random.key(seed + 1), hidden, 5, "mlp")
    opt = make_optimizer("adam", lr=5e-3)
    state = G.TrainState(bb, head, opt.init((bb, head)),
                         init_table(n, J, hidden), jnp.zeros((), jnp.int32))
    step = G.make_train_step(enc, opt, G.VARIANTS[variant])
    rng = np.random.default_rng(seed)
    e = 64
    batch = G.GSTBatch(
        {"x": jnp.asarray(rng.normal(size=(B, J, m, 8)), jnp.float32),
         "edges": jnp.asarray(rng.integers(0, m, (B, J, e, 2)), jnp.int32),
         "edge_valid": jnp.ones((B, J, e), jnp.float32),
         "node_valid": jnp.ones((B, J, m), jnp.float32)},
        jnp.ones((B, J), jnp.float32), jnp.arange(B, dtype=jnp.int32),
        jnp.asarray(rng.integers(0, 5, B), jnp.int32))
    return state, batch, step


def _compiled_temp_bytes(variant, J):
    state, batch, step = _setup(variant, J)
    compiled = jax.jit(step).lower(state, batch, jax.random.key(0)).compile()
    ma = compiled.memory_analysis()
    return int(ma.temp_size_in_bytes)


def test_gst_memory_constant_in_segments_full_grows():
    """THE paper claim (Fig. 1): GST's activation memory is bounded by the
    segment size regardless of how many segments (how large) the graph is;
    full-graph training grows ~linearly with J."""
    gst_4 = _compiled_temp_bytes("gst_efd", 4)
    gst_16 = _compiled_temp_bytes("gst_efd", 16)
    full_4 = _compiled_temp_bytes("full", 4)
    full_16 = _compiled_temp_bytes("full", 16)
    growth_full = full_16 / full_4
    growth_gst = gst_16 / gst_4
    assert growth_full > 2.5, f"full should grow ~4x, got {growth_full:.2f}"
    assert growth_gst < 1.6, f"gst should stay ~flat, got {growth_gst:.2f}"
    # and at J=16 GST uses far less memory than full graph training
    assert gst_16 < full_16 / 2


def test_gst_e_avoids_stale_recompute_flops():
    """GST+E replaces the stop-grad forward over J-1 segments with table
    lookups: compiled FLOPs must drop accordingly (Table 3 mechanism)."""
    def flops(variant):
        state, batch, step = _setup(variant, J=12)
        c = jax.jit(step).lower(state, batch, jax.random.key(0)).compile()
        ca = c.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return float(ca["flops"])
    assert flops("gst") > 3.0 * flops("gst_e")


def test_training_learns_on_malnet_like():
    """A short GST run must beat chance (5 classes -> 20%) on train data."""
    graphs = D.make_malnet_like(n_graphs=24, seed=0)
    ds = Bt.segment_dataset(graphs, max_seg_nodes=48)
    cfg = GNNConfig(backbone="sage", n_feat=8, hidden=32)
    enc = make_encode_fn(cfg)
    bb = gnn_init(jax.random.key(0), cfg)
    head = G.head_init(jax.random.key(1), 32, 5, "mlp")
    opt = make_optimizer("adam", lr=5e-3)
    state = G.TrainState(bb, head, opt.init((bb, head)),
                         init_table(ds.n, ds.j_max, 32), jnp.zeros((), jnp.int32))
    step = jax.jit(G.make_train_step(enc, opt, G.VARIANTS["gst"]))
    rng = np.random.default_rng(0)
    accs = []
    for epoch in range(15):
        for tup in Bt.batch_iterator(ds, 8, rng=rng):
            batch = G.GSTBatch({k: jnp.asarray(v) for k, v in tup[0].items()},
                               jnp.asarray(tup[1]), jnp.asarray(tup[2]),
                               jnp.asarray(tup[3]))
            state, m = step(state, batch, jax.random.key(epoch))
            accs.append(float(m["metric"]))
    assert np.mean(accs[-6:]) > 0.35, f"no learning: {np.mean(accs[-6:])}"


def test_eval_uses_fresh_embeddings_only():
    """Eval must not read the stale table: corrupting the table must not
    change eval metrics (paper's test distribution P(⊕ h_j, y))."""
    state, batch, _ = _setup("gst_efd", J=6)
    cfg = GNNConfig(backbone="sage", n_feat=8, hidden=32)
    enc = make_encode_fn(cfg)
    ev = jax.jit(G.make_eval_step(enc))
    m1 = ev(state, batch)
    bad_table = state.table._replace(emb=state.table.emb + 1e6)
    m2 = ev(state._replace(table=bad_table), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-6)


def test_seq_track_gst_runs_with_transformer_backbone():
    """The sequence track (assigned archs as GST backbone F) end-to-end."""
    from repro.configs import get_config, reduced
    from repro.models import build_model
    from repro.data.tokens import make_property_docs
    cfg = reduced(get_config("internlm2-1.8b"))
    model = build_model(cfg)
    docs = make_property_docs(n_docs=8, n_segments=4, seg_len=16,
                              vocab=cfg.vocab_size, n_topics=5)
    params = model.init(jax.random.key(0))
    head = G.head_init(jax.random.key(1), cfg.d_model, 5, "mlp")
    opt = make_optimizer("adamw", lr=1e-3)
    state = G.TrainState(params, head, opt.init((params, head)),
                         init_table(8, 4, cfg.d_model), jnp.zeros((), jnp.int32))
    step = jax.jit(G.make_train_step(
        lambda p, s: model.encode_segment(p, s), opt, G.VARIANTS["gst_efd"]))
    batch = G.GSTBatch({"tokens": jnp.asarray(docs["tokens"])},
                       jnp.asarray(docs["seg_valid"]),
                       jnp.arange(8, dtype=jnp.int32),
                       jnp.asarray(docs["labels"]))
    s1, m1 = step(state, batch, jax.random.key(0))
    s2, m2 = step(s1, batch, jax.random.key(1))
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    assert int(s2.step) == 2
