"""Tiered embedding store (src/repro/store/) — the unified table backend.

Contract under test (ISSUE 4):
  * a TieredStore whose device tier holds ~10% of the table rows trains
    ALL SEVEN GST variants bit-identically to the device-resident oracle
    (params, table embeddings, ages, init flags, refresh behavior) —
    single-device and through the shard_map dist steps (each shard owns a
    tiered slice; ring exchange unchanged, routing on device-row ids);
  * store checkpointing (checkpoint/io.py) round-trips BOTH backends —
    host tier included — and a resumed run continues bit-exactly;
  * the serving cache layered over a TieredStore returns bit-identical
    embeddings for entries that were spilled to host RAM and faulted back;
  * eviction write-backs run asynchronously (AsyncHostWriter) and a fetch
    of a still-pending row waits for its write-back instead of reading a
    stale host copy;
  * empty row sets are no-ops on update_rows/evict_rows (no zero-size
    scatter is ever compiled).

Runs at whatever device count the host exposes: tier-1 sees 1 device; the
CI store-smoke job re-runs this file under
XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import dist as DT
from repro.checkpoint import load_store_checkpoint, save_store_checkpoint
from repro.core import embedding_table as tbl
from repro.core import gst as G
from repro.dist import pipeline as DP
from repro.graphs import data as D
from repro.graphs.gnn import GNNConfig, gnn_init, make_encode_fn
from repro.optim import make_optimizer
from repro.serve.cache import SegmentCache
from repro.store import (AsyncHostWriter, DeviceStore, SlotMap, TieredStore,
                         rows_per_shard)

N_DEV = jax.device_count()
DIST_SHARDS = [d for d in (1, 8) if d <= N_DEV]
HID = 8


def _tree_bitwise(a, b):
    eq = jax.tree_util.tree_map(
        lambda x, y: bool((np.asarray(x) == np.asarray(y)).all()), a, b)
    return all(jax.tree_util.tree_leaves(eq))


def _table_bitwise(a: tbl.EmbeddingTable, b: tbl.EmbeddingTable):
    return _tree_bitwise(tuple(a), tuple(b))


@pytest.fixture(scope="module")
def dataset():
    graphs = D.make_malnet_like(n_graphs=48, seed=0)
    ds, _ = DP.segment_dataset_shared(graphs, 16, seed=0)
    return ds


def _state(ds):
    cfg = GNNConfig(backbone="sage", n_feat=ds.x.shape[-1], hidden=HID)
    enc = make_encode_fn(cfg)
    key = jax.random.key(0)
    bb = gnn_init(key, cfg)
    head = G.head_init(jax.random.fold_in(key, 1), HID, 5, "mlp")
    opt = make_optimizer("adam", lr=5e-3)
    return enc, opt, G.TrainState(bb, head, opt.init((bb, head)),
                                  tbl.init_table(ds.n, ds.j_max, HID),
                                  jnp.zeros((), jnp.int32))


def _spread_batches(n, num_shards, batch, steps):
    """Batch id schedules whose rows spread evenly over the shards, so a
    device tier of batch/num_shards rows per shard suffices while every
    step still churns the LRU (each batch faults fresh rows)."""
    R = rows_per_shard(n, num_shards)
    per = batch // num_shards
    assert per >= 1 and per <= R
    out = []
    for t in range(steps):
        ids = [min(s * R + (t * per + j) % R, n - 1)
               for s in range(num_shards) for j in range(per)]
        assert len(set(ids)) == len(ids)
        out.append(np.asarray(ids, np.int64))
    return out


def _batch(ds, ids):
    return jax.tree_util.tree_map(jnp.asarray, DP._assemble(ds, ids))


# ---------------------------------------------------------------------------
# single-device: TieredStore at ~10% device capacity == oracle, 7 variants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", list(G.VARIANTS))
def test_tiered_train_bit_identical_all_variants(dataset, variant):
    ds = dataset
    B, steps = 4, 6
    cap = max(-(-ds.n // 10), B)          # ~10% of rows, >= one batch
    assert cap < ds.n // 2, "capacity must really be a small fraction"
    enc, opt, state0 = _state(ds)
    var = G.VARIANTS[variant]
    rng = jax.random.PRNGKey(3)
    scheds = _spread_batches(ds.n, 1, B, steps)

    step = G.make_train_step(enc, opt, var, keep_prob=0.5)
    oracle = jax.jit(step)
    s1 = state0
    for ids in scheds:
        s1, m1 = oracle(s1, _batch(ds, ids), rng)

    store = TieredStore(ds.n, ds.j_max, HID, device_rows=cap)
    tiered = jax.jit(step)   # same step body, smaller table shape
    s2 = state0._replace(table=store.init_device_table())
    for ids in scheds:
        table, slots = store.prepare(s2.table, ids)
        s2 = s2._replace(table=table)
        s2, m2 = tiered(s2, _batch(ds, ids)._replace(
            graph_ids=jnp.asarray(slots)), rng)

    # the full logical table — embeddings, ages, init flags — is bitwise
    # identical to the oracle's, as are params and metrics
    assert _table_bitwise(s1.table, store.snapshot(s2.table))
    assert _tree_bitwise((s1.backbone, s1.head), (s2.backbone, s2.head))
    assert float(m1["loss"]) == float(m2["loss"])
    if var.use_table:
        assert store.counters.evictions > 0, \
            "capacity below the working set must actually churn the tier"
    store.close()


def test_tiered_refresh_and_finetune_bit_identical(dataset):
    """Algorithm 2's refresh + head-finetune phases through the store."""
    ds = dataset
    B = 4
    cap = max(-(-ds.n // 10), B)
    enc, opt, state0 = _state(ds)
    scheds = _spread_batches(ds.n, 1, B, 12)   # covers every row
    refresh = jax.jit(G.make_refresh_step(enc))
    ft_opt = make_optimizer("adam", lr=1e-3)
    ft = jax.jit(G.make_finetune_step(ft_opt))

    s1 = state0
    for ids in scheds:
        s1 = refresh(s1, _batch(ds, ids))
    s1 = s1._replace(opt_state=ft_opt.init(s1.head))
    for ids in scheds[:4]:
        s1, m1 = ft(s1, _batch(ds, ids))

    store = TieredStore(ds.n, ds.j_max, HID, device_rows=cap)
    s2 = state0._replace(table=store.init_device_table())
    for ids in scheds:
        table, slots = store.prepare(s2.table, ids)
        s2 = s2._replace(table=table)
        s2 = refresh(s2, _batch(ds, ids)._replace(graph_ids=jnp.asarray(slots)))
    s2 = s2._replace(opt_state=ft_opt.init(s2.head))
    for ids in scheds[:4]:
        table, slots = store.prepare(s2.table, ids)
        s2 = s2._replace(table=table)
        s2, m2 = ft(s2, _batch(ds, ids)._replace(graph_ids=jnp.asarray(slots)))

    assert _table_bitwise(s1.table, store.snapshot(s2.table))
    assert _tree_bitwise(s1.head, s2.head)
    assert float(m1["loss"]) == float(m2["loss"])
    store.close()


# ---------------------------------------------------------------------------
# dist: each shard owns a tiered slice; ring exchange unchanged
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", list(G.VARIANTS))
def test_dist_tiered_parity_all_variants(dataset, variant):
    """shard_map steps over per-shard tiered slices track the single-device
    dense oracle: ages/init/refresh bit-exact, params/loss bitwise at 1
    shard and <= a few ulps at 8 (cross-shard pmean order, same tolerance
    as tests/test_dist.py)."""
    ds = dataset
    n_shards = DIST_SHARDS[-1]
    B, steps = 8, 5
    enc, opt, state0 = _state(ds)
    var = G.VARIANTS[variant]
    rng = jax.random.PRNGKey(3)
    scheds = _spread_batches(ds.n, n_shards, B, steps)

    oracle = jax.jit(G.make_train_step(enc, opt, var, keep_prob=0.5))
    s1 = state0
    for ids in scheds:
        s1, m1 = oracle(s1, _batch(ds, ids), rng)

    # device tier: exactly one batch row per shard — the smallest legal
    # tier, ~B/n of the table
    ctx = DT.make_context(DT.make_dist_mesh(n_shards), ds.n,
                          device_rows=B)
    store = DT.make_dist_store(ctx, ds.j_max, HID)
    assert isinstance(store, TieredStore)
    dstep = DT.make_dist_train_step(enc, opt, var, ctx=ctx, keep_prob=0.5,
                                    donate=False)
    s2 = DT.device_state(ctx, state0, store=store)
    for ids in scheds:
        host = DP._assemble(ds, ids)
        prep = store.begin(np.asarray(host.graph_ids))
        b2 = DT.shard_batch(ctx, host._replace(graph_ids=prep.slots))
        s2 = s2._replace(table=store.commit(s2.table, prep))
        s2, m2 = dstep(s2, b2, rng)

    t2 = store.snapshot(s2.table)
    assert (np.asarray(s1.table.age) == np.asarray(t2.age)).all()
    assert (np.asarray(s1.table.initialized) ==
            np.asarray(t2.initialized)).all()
    tol = 0.0 if ctx.num_shards == 1 else 1e-5
    emb_diff = float(np.max(np.abs(np.asarray(s1.table.emb) -
                                   np.asarray(t2.emb))))
    assert emb_diff <= tol
    diffs = jax.tree_util.tree_map(
        lambda x, y: float(np.max(np.abs(np.asarray(x) - np.asarray(y)))),
        (s1.backbone, s1.head), jax.device_get((s2.backbone, s2.head)))
    assert max(jax.tree_util.tree_leaves(diffs)) <= tol
    assert abs(float(m1["loss"]) - float(m2["loss"])) <= tol
    store.close()


def test_dist_context_table_rows():
    mesh = DT.make_dist_mesh(1)
    dense = DT.make_context(mesh, 40)
    assert dense.table_rows == dense.rows_per_shard == 40
    assert isinstance(DT.make_dist_store(dense, 2, 4), DeviceStore)
    tiered = DT.make_context(mesh, 40, device_rows=8)
    assert tiered.table_rows == 8 and tiered.rows_per_shard == 40
    assert isinstance(DT.make_dist_store(tiered, 2, 4), TieredStore)


# ---------------------------------------------------------------------------
# checkpointing: save/restore both backends, host tier included
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["device", "tiered"])
def test_checkpoint_roundtrip_resumes_bit_exact(dataset, backend, tmp_path):
    ds = dataset
    B = 4
    enc, opt, state0 = _state(ds)
    scheds = _spread_batches(ds.n, 1, B, 6)
    step = jax.jit(G.make_train_step(enc, opt, G.VARIANTS["gst_efd"],
                                     keep_prob=0.5))
    rng = jax.random.PRNGKey(7)

    def make_store():
        if backend == "tiered":
            return TieredStore(ds.n, ds.j_max, HID, device_rows=B + 1)
        return DeviceStore(ds.n, ds.j_max, HID)

    def run(store, state, sched):
        for ids in sched:
            table, slots = store.prepare(state.table, ids)
            state = state._replace(table=table)
            state, _ = step(state, _batch(ds, ids)._replace(
                graph_ids=jnp.asarray(slots)), rng)
        return state

    # uninterrupted reference: 6 steps
    ref_store = make_store()
    ref = run(ref_store, state0._replace(table=ref_store.init_device_table()),
              scheds)

    # interrupted run: 3 steps -> checkpoint -> fresh store -> 3 more
    st1 = make_store()
    s = run(st1, state0._replace(table=st1.init_device_table()), scheds[:3])
    path = save_store_checkpoint(
        str(tmp_path), 3, st1, s.table,
        extra={"backbone": s.backbone, "head": s.head,
               "opt_state": s.opt_state, "step": s.step})
    st1.close()

    st2 = make_store()
    table, extra = load_store_checkpoint(
        path, st2, extra_like={"backbone": s.backbone, "head": s.head,
                               "opt_state": s.opt_state, "step": s.step})
    resumed = G.TrainState(extra["backbone"], extra["head"],
                           extra["opt_state"], table, extra["step"])
    resumed = run(st2, resumed, scheds[3:])

    assert _table_bitwise(ref_store.snapshot(ref.table),
                          st2.snapshot(resumed.table))
    assert _tree_bitwise((ref.backbone, ref.head),
                         (resumed.backbone, resumed.head))
    ref_store.close()
    st2.close()


def test_checkpoint_with_pending_writebacks_flushes_and_resumes(tmp_path):
    """Checkpointing while AsyncHostWriter still has eviction write-backs
    in flight: ``snapshot`` must flush them before merging tiers, so the
    file carries the evicted rows' content and a resumed run continues
    bit-exactly vs the uninterrupted one."""
    import threading

    n, J, d = 6, 1, 4
    rng_vals = np.random.default_rng(11)
    vals = rng_vals.normal(size=(32, 1, 1, d)).astype(np.float32)
    sched = [0, 1, 2, 3, 4, 5, 0, 1, 2, 3, 4, 5]  # C=2 -> constant churn

    def make_store():
        return TieredStore(n, J, d, device_rows=2)

    def run(store, table, oracle, steps):
        for t in steps:
            row = sched[t]
            table, slots = store.prepare(table, np.asarray([row]))
            z = jnp.zeros((1, 1), jnp.int32)
            table = tbl.update_sampled(table, jnp.asarray(slots), z,
                                       jnp.asarray(vals[t]), t)
            oracle = tbl.update_sampled(oracle, jnp.asarray([row]), z,
                                        jnp.asarray(vals[t]), t)
        return table, oracle

    # uninterrupted reference
    ref = make_store()
    ref_table, oracle = run(ref, ref.init_device_table(),
                            tbl.init_table(n, J, d), range(len(sched)))

    # interrupted run: half the steps, then BLOCK the write-back lane and
    # trigger one more eviction so its write-back is genuinely pending at
    # save time
    st1 = make_store()
    table1, _ = run(st1, st1.init_device_table(), tbl.init_table(n, J, d),
                    range(6))
    gate = threading.Event()
    st1._writer.submit(lambda: gate.wait(timeout=10.0))
    table1, slots = st1.prepare(table1, np.asarray([sched[6]]))  # evicts
    assert st1._writer.pending >= 1
    threading.Timer(0.2, gate.set).start()
    path = save_store_checkpoint(str(tmp_path), 6, st1, table1)
    assert st1._writer.pending == 0          # snapshot flushed the lane
    st1.close()

    # resume: finish the schedule on a fresh store.  Step 6's prepare ran
    # before the save but its update didn't — replay from step 6.
    st2 = make_store()
    table2, extra = load_store_checkpoint(path, st2)
    table2, oracle2 = run(st2, table2, tbl.init_table(n, J, d),
                          range(6, len(sched)))
    st2.flush_writebacks()
    snap_ref = ref.snapshot(ref_table)
    snap_res = st2.snapshot(table2)
    assert _table_bitwise(snap_ref, snap_res)
    assert _tree_bitwise(tuple(snap_ref), tuple(oracle))
    ref.close()
    st2.close()


def test_snapshot_restore_preserves_host_tier():
    """Rows living ONLY in the host tier at save time must round-trip."""
    rng = np.random.default_rng(0)
    store = TieredStore(12, 2, 4, device_rows=3)
    table = store.init_device_table()
    for t in range(8):
        ids = rng.permutation(12)[:3]
        table, slots = store.prepare(table, ids)
        table = tbl.update_sampled(
            table, jnp.asarray(slots), jnp.zeros((3, 1), jnp.int32),
            jnp.asarray(rng.normal(size=(3, 1, 4)), jnp.float32), t)
    snap = store.snapshot(table)
    assert np.asarray(snap.initialized).any()
    store2 = TieredStore(12, 2, 4, device_rows=3)
    table2 = store2.restore(snap)
    assert store2.occupancy() == 0          # residency reset, data in host
    table2, slots = store2.prepare(table2, np.arange(3))
    e2, _ = tbl.lookup(table2, jnp.asarray(slots))
    e1, _ = tbl.lookup(jax.tree_util.tree_map(jnp.asarray, snap),
                       jnp.arange(3))
    assert np.array_equal(np.asarray(e1), np.asarray(e2))
    store.close()
    store2.close()


# ---------------------------------------------------------------------------
# serving over the shared store
# ---------------------------------------------------------------------------


def test_serve_cache_over_tiered_store_bit_identical():
    """Entries spilled to the host tier fault back bit-identically; the
    keying layer's capacity is the TOTAL (both-tier) row count."""
    rng = np.random.default_rng(0)
    store = TieredStore(32, 1, HID, device_rows=8)
    cache = SegmentCache(32, HID, store=store)
    keys = [bytes([i]) * 4 for i in range(24)]
    embs = rng.normal(size=(24, HID)).astype(np.float32)
    for i in range(0, 24, 6):
        cache.put(keys[i:i + 6], embs[i:i + 6])
    assert len(cache) == 24                  # all keys live
    assert store.occupancy() == 8           # only a tier's worth on device
    slots = [cache.get(k) for k in keys]
    assert all(s is not None for s in slots)
    got = np.asarray(cache.gather(slots[:8]))
    assert np.array_equal(got, embs[:8]), "spill+refault must be bit-exact"
    assert store.counters.evictions > 0
    assert cache.stats()["store"]["backend"] == "TieredStore"
    store.close()


def test_serve_engine_with_device_row_cap_matches_uncapped():
    from repro.serve import ServeConfig, ServeEngine, TrafficConfig, \
        make_request_stream

    tc = TrafficConfig(n_unique=6, n_requests=12, duplicate_rate=0.5,
                       comm_range=(2, 5), comm_size_range=(8, 20), seed=3)
    stream = make_request_stream(tc)

    def engine(table_device_rows):
        cfg = ServeConfig(backbone="sage", hidden=32, max_seg_nodes=32,
                          cache_capacity=128, stream_chunk=4,
                          table_device_rows=table_device_rows)
        return ServeEngine(cfg, seed=0)

    full = engine(None)
    capped = engine(8)
    p1 = full.process(stream, window=4)
    p2 = capped.process(stream, window=4)
    for a, b in zip(p1, p2):
        assert np.array_equal(a.pred, b.pred), \
            "device-row cap must not change a single prediction bit"
    st = capped.stats.summary()["cache"]["store"]
    assert st["backend"] == "TieredStore"
    assert st["evictions"] > 0, "the cap must actually spill"
    full.close()
    capped.close()


# ---------------------------------------------------------------------------
# write-back machinery
# ---------------------------------------------------------------------------


def test_pending_writeback_blocks_refetch():
    """Evict a row and fault it straight back: the fetch must wait for the
    async write-back so the host tier is never read stale."""
    rng = np.random.default_rng(0)
    store = TieredStore(4, 1, 4, device_rows=1)
    table = store.init_device_table()
    vals = {}
    for t, row in enumerate([0, 1, 0, 1, 0, 1]):
        table, slots = store.prepare(table, np.asarray([row]))
        v = rng.normal(size=(1, 1, 4)).astype(np.float32)
        vals[row] = v
        table = tbl.update_sampled(table, jnp.asarray(slots),
                                   jnp.zeros((1, 1), jnp.int32),
                                   jnp.asarray(v), t)
        # the OTHER row's last value must have survived the round trip
        other = 1 - row
        if other in vals:
            table, oslots = store.prepare(table, np.asarray([other]))
            e, _ = tbl.lookup(table, jnp.asarray(oslots))
            assert np.array_equal(np.asarray(e), vals[other])
            table, slots = store.prepare(table, np.asarray([row]))
    assert store.counters.evictions >= 4
    store.close()


def test_async_writer_propagates_thunk_errors():
    w = AsyncHostWriter()

    def boom():
        raise RuntimeError("writeback exploded")

    w.submit(boom)
    with pytest.raises(RuntimeError, match="writeback exploded"):
        w.flush()
    w.close()


def test_commit_order_enforced():
    store = TieredStore(8, 1, 2, device_rows=2)
    table = store.init_device_table()
    p1 = store.begin(np.asarray([0]))
    p2 = store.begin(np.asarray([1]))
    with pytest.raises(RuntimeError, match="commit order"):
        store.commit(table, p2)
    table = store.commit(table, p1)
    store.commit(table, p2)
    store.close()


def test_capacity_exhaustion_raises_before_mutating():
    store = TieredStore(8, 1, 2, device_rows=2)
    with pytest.raises(RuntimeError, match="device tier exhausted"):
        store.begin(np.arange(5))
    with pytest.raises(IndexError, match="outside table"):
        store.begin(np.asarray([0, 99]))
    # the failed begins must not have reserved slots or consumed tickets —
    # the store stays fully usable
    assert store.occupancy() == 0
    table = store.init_device_table()
    table, slots = store.prepare(table, np.asarray([0, 1]))
    assert store.occupancy() == 2
    store.close()


def test_failed_writeback_raises_instead_of_hanging():
    """A write-back that dies (host tier unwritable) must surface as an
    error on the next fetch of the evicted row, not spin forever."""
    store = TieredStore(4, 1, 2, device_rows=1)
    table = store.init_device_table()
    table, _ = store.prepare(table, np.asarray([0]))
    store._host.emb.setflags(write=False)   # break the host tier
    table, _ = store.prepare(table, np.asarray([1]))   # evicts row 0
    with pytest.raises(RuntimeError, match="write-back failed"):
        store.prepare(table, np.asarray([0]))          # refetch row 0
    store._host.emb.setflags(write=True)
    store._writer._exc = None   # drop the writer's copy of the failure
    store.close()


# ---------------------------------------------------------------------------
# satellite: empty row sets are no-ops; slot machinery basics
# ---------------------------------------------------------------------------


def test_update_evict_rows_empty_noop():
    table = tbl.init_table(4, 1, 2)
    empty = jnp.zeros((0,), jnp.int32)
    assert tbl.update_rows(table, empty, jnp.zeros((0, 2)), 0) is table
    assert tbl.evict_rows(table, empty) is table


# ---------------------------------------------------------------------------
# delta-gated write-back (ISSUE 6): evictions of rows that barely moved
# skip the device->host emb copy; ages/init always land
# ---------------------------------------------------------------------------


def test_delta_gate_admission_rules():
    from repro.store import delta_gate
    old = np.zeros((4, 1, 3), np.float32)
    new = old.copy()
    new[0] += 0.5            # moved past the threshold
    new[1] += 0.09           # moved, but under it
    init_old = np.ones((4, 1), bool)
    init_new = init_old.copy()
    init_new[2, 0] = False   # bookkeeping flip on an otherwise static row
    admit = delta_gate(new, old, init_new, init_old, 0.1)
    # movement >= threshold admits (inclusive); an init flip forces
    # admission regardless of movement; static rows are skipped
    assert admit.tolist() == [True, False, True, False]
    new[1] += 0.01           # exactly at the threshold now
    assert delta_gate(new, old, init_new, init_old, 0.1).tolist() == \
        [True, True, True, False]


def test_tiered_delta_gate_skips_static_rows():
    store = TieredStore(4, 1, 4, device_rows=1, wb_threshold=0.5)
    table = store.init_device_table()
    v = np.full((1, 1, 4), 2.0, np.float32)

    def write(table, slots, val, t):
        return tbl.update_sampled(table, jnp.asarray(slots),
                                  jnp.zeros((1, 1), jnp.int32),
                                  jnp.asarray(val), t)

    # first residency: the init flip (False -> True) forces admission even
    # though the gate is on — first writes always reach the host tier
    table, slots = store.prepare(table, np.asarray([0]))
    table = write(table, slots, v, 0)
    table, _ = store.prepare(table, np.asarray([1]))     # evicts row 0
    store.flush_writebacks()
    assert store.counters.wb_skipped_rows == 0
    assert np.array_equal(store._host.emb[0], v[0])

    # second residency: a sub-threshold nudge — the eviction skips the
    # host emb write (stale by < wb_threshold) but still lands the age.
    # (Refetching row 0 evicts the never-written row 1, whose delta is 0
    # and init unchanged — also skipped, hence the count of 2.)
    table, slots = store.prepare(table, np.asarray([0]))
    table = write(table, slots, v + 0.1, 7)
    table, _ = store.prepare(table, np.asarray([1]))     # evicts row 0
    store.flush_writebacks()
    assert store.counters.wb_skipped_rows == 2
    assert store.counters.wb_skipped_bytes == 2 * 1 * 4 * 4
    assert np.array_equal(store._host.emb[0], v[0])      # stale, bounded
    assert store._host.age[0, 0] == 7                    # bookkeeping exact

    # third residency: movement past the threshold is admitted (the
    # static row 1 eviction in between is skipped again)
    table, slots = store.prepare(table, np.asarray([0]))
    table = write(table, slots, v + 3.0, 9)
    table, _ = store.prepare(table, np.asarray([1]))
    store.flush_writebacks()
    assert store.counters.wb_skipped_rows == 3
    assert np.array_equal(store._host.emb[0], v[0] + 3.0)
    assert store.stats()["wb_threshold"] == 0.5
    store.close()


def test_tiered_gate_off_by_default_and_counts_zero():
    store = TieredStore(4, 1, 4, device_rows=1)
    assert store.wb_threshold == 0.0
    table = store.init_device_table()
    for t, row in enumerate([0, 1, 0, 1]):               # churn the tier
        table, slots = store.prepare(table, np.asarray([row]))
    store.flush_writebacks()
    # gate off: every eviction writes through, nothing is ever skipped
    assert store.counters.evictions >= 2
    assert store.counters.wb_skipped_rows == 0
    assert store.counters.wb_skipped_bytes == 0
    assert store.stats()["wb_skipped_rows"] == 0
    store.close()


def test_cache_gather_empty_returns_empty():
    store = TieredStore(8, 1, HID, device_rows=3)
    cache = SegmentCache(8, HID, store=store)
    out = np.asarray(cache.gather([]))
    assert out.shape == (0, HID)
    cache.close()


def test_cache_over_trainer_shaped_store():
    """A store with trainer geometry (j_max > 1) backs the cache: entries
    live in segment-slot 0 of each row, spill/refault stays bit-exact."""
    rng = np.random.default_rng(0)
    store = TieredStore(16, 3, HID, device_rows=4)   # j_max=3, like training
    cache = SegmentCache(16, HID, store=store)
    keys = [bytes([i]) * 4 for i in range(12)]
    embs = rng.normal(size=(12, HID)).astype(np.float32)
    for i in range(0, 12, 4):
        cache.put(keys[i:i + 4], embs[i:i + 4])
    slots = [cache.get(k) for k in keys]
    got = np.asarray(cache.gather(slots[:4]))
    assert np.array_equal(got, embs[:4])
    assert store.counters.evictions > 0
    cache.flush()
    assert len(cache) == 0
    cache.put([keys[0]], embs[:1])
    assert np.array_equal(np.asarray(cache.gather([cache.get(keys[0])])),
                          embs[:1])
    cache.close()


def test_slotmap_lru_and_pinning():
    m = SlotMap(2)
    s_a, ev = m.reserve("a")
    s_b, _ = m.reserve("b")
    assert ev is None and {s_a, s_b} == {0, 1}
    assert m.get("a") == s_a                   # touch: b becomes LRU
    s_c, ev = m.reserve("c")
    assert ev == ("b", s_b) and s_c == s_b
    # pinned keys are never displaced
    slot, ev = m.reserve("d", pinned={"a", "c"})
    assert slot is None and ev is None
    assert m.release("a") == s_a
    slot, ev = m.reserve("d", pinned={"c"})
    assert slot == s_a and ev is None
