"""Model-substrate invariants: decode/forward consistency, SSM scan
equivalences, MLA absorbed-vs-naive decode, sliding-window semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.models.common import sdpa


ARCHS_INCREMENTAL = ["internlm2-1.8b", "olmo-1b", "zamba2-1.2b", "rwkv6-7b",
                     "deepseek-v3-671b", "qwen2-vl-7b"]


@pytest.mark.parametrize("arch", ARCHS_INCREMENTAL)
def test_incremental_decode_matches_full_forward(arch):
    """Token-by-token decode from an empty cache must equal the teacher-forced
    full forward — the strongest cache-correctness property."""
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    B, S = 1, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    inputs = {"tokens": toks}
    if cfg.family == "vlm":
        # decode path has no patch injection; keep the text-only case here
        inputs = {"tokens": toks}
    full = model.logits(params, model.forward(params, inputs))
    caches = model.init_cache(B, S, jnp.float32)
    outs = []
    for t in range(S):
        lg, caches = model.decode_step(params, toks[:, t:t + 1], caches,
                                       jnp.full((B,), t, jnp.int32))
        outs.append(lg[:, 0])
    inc = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(inc),
                               rtol=5e-4, atol=5e-4)


def test_mamba_chunked_scan_equals_stepwise():
    """ssd_chunked == the per-token recurrence it implements."""
    from repro.models.mamba2 import ssd_chunked
    rng = np.random.default_rng(3)
    B, S, H, P, N = 2, 64, 2, 4, 8
    xs = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 0.5, size=(B, S, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, H), jnp.float32)
    y_chunk, state_chunk = ssd_chunked(xs, Bm, Cm, dt, A, chunk=16)
    # stepwise reference
    h = np.zeros((B, H, P, N), np.float64)
    ys = np.zeros((B, S, H, P))
    for t in range(S):
        a = np.exp(np.asarray(A)[None, :] * np.asarray(dt)[:, t])  # (B,H)
        upd = np.einsum("bhp,bn->bhpn",
                        np.asarray(xs)[:, t] * np.asarray(dt)[:, t][..., None],
                        np.asarray(Bm)[:, t])
        h = h * a[:, :, None, None] + upd
        ys[:, t] = np.einsum("bhpn,bn->bhp", h, np.asarray(Cm)[:, t])
    np.testing.assert_allclose(np.asarray(y_chunk), ys, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state_chunk), h, rtol=2e-4, atol=2e-4)


def test_mamba_chunk_size_invariance():
    from repro.models.mamba2 import ssd_chunked
    rng = np.random.default_rng(4)
    B, S, H, P, N = 1, 128, 2, 4, 8
    xs = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 0.5, size=(B, S, H)), jnp.float32)
    A = -jnp.ones((H,), jnp.float32)
    y1, s1 = ssd_chunked(xs, Bm, Cm, dt, A, chunk=16)
    y2, s2 = ssd_chunked(xs, Bm, Cm, dt, A, chunk=64)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4,
                               atol=2e-4)


def test_mla_absorbed_equals_naive_decode():
    """The absorbed decode (latent-space attention) must equal the naive
    expanded decode — the §Perf optimization is exact, not approximate."""
    from repro.models import mla as M
    cfg = reduced(get_config("deepseek-v3-671b"))
    rng = np.random.default_rng(5)
    p = M.mla_params(jax.random.key(0), cfg)
    B, C = 2, 8
    x = jnp.asarray(rng.normal(size=(B, 1, cfg.d_model)), jnp.float32)
    ckv = jnp.asarray(rng.normal(size=(B, C, cfg.mla_kv_lora_rank)), jnp.float32)
    kr = jnp.asarray(rng.normal(size=(B, C, cfg.mla_rope_head_dim)), jnp.float32)
    pos = jnp.full((B,), 5, jnp.int32)
    o1, c1, k1 = M.mla_decode(p, x, ckv, kr, pos, cfg, absorbed=True)
    o2, c2, k2 = M.mla_decode(p, x, ckv, kr, pos, cfg, absorbed=False)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-6)


def test_ring_buffer_window_decode_matches_reference():
    """Ring-buffer sliding-window decode == full-cache attention restricted
    to the window, once the ring has wrapped."""
    cfg = reduced(get_config("internlm2-1.8b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(6)
    B, total, W = 1, 24, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, total)), jnp.int32)

    # reference: full cache, window mask via full forward + sdpa window arg
    hidden_ref = model.forward(params, {"tokens": toks}, window=W)
    ref_logits = model.logits(params, hidden_ref)

    caches = model.init_cache(B, W, jnp.float32)  # ring cache of size W
    outs = []
    for t in range(total):
        lg, caches = model.decode_step(params, toks[:, t:t + 1], caches,
                                       jnp.full((B,), t, jnp.int32),
                                       window=W, ring=True)
        outs.append(lg[:, 0])
    inc = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(ref_logits), np.asarray(inc),
                               rtol=5e-4, atol=5e-4)


def test_whisper_decode_matches_teacher_forcing():
    cfg = reduced(get_config("whisper-large-v3"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(7)
    B, S = 1, 6
    frames = jnp.asarray(rng.normal(size=(B, cfg.encoder_seq_len, cfg.d_model)),
                         jnp.float32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    full = model.logits(params, model.forward(
        params, {"tokens": toks, "frames": frames}))
    from repro.models import encdec
    enc_out = encdec.encode(params, cfg, frames)
    caches = {"self": model.init_cache(B, S, jnp.float32),
              "cross": encdec.cross_kv(params, cfg, enc_out)}
    outs = []
    for t in range(S):
        lg, caches = model.decode_step(params, toks[:, t:t + 1], caches,
                                       jnp.full((B,), t, jnp.int32))
        outs.append(lg[:, 0])
    inc = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(inc),
                               rtol=5e-4, atol=5e-4)


def test_moe_capacity_drops_tokens_but_stays_finite():
    from repro.configs.base import MoEConfig
    from repro.models.moe import moe_params, moe_forward
    cfg = MoEConfig(num_experts=4, top_k=2, expert_d_ff=32, capacity_factor=0.5)
    p = moe_params(jax.random.key(0), 16, cfg, "silu")
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 8, 16)), jnp.float32)
    out, aux = moe_forward(p, x, cfg, "silu")
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all()) and bool(jnp.isfinite(aux))


def test_moe_decode_reproduces_forward_capacity_dropping():
    """Token-by-token moe_decode with routed-token counters must equal the
    teacher-forced moe_forward EXACTLY where capacity dropping occurs — the
    property behind deepseek-v3's decode/forward parity (B > 1 here, so the
    per-row accounting is exercised across rows)."""
    from repro.configs.base import MoEConfig
    from repro.models.moe import capacity, moe_decode, moe_forward, moe_params
    cfg = MoEConfig(num_experts=4, top_k=2, expert_d_ff=32, capacity_factor=0.5)
    p = moe_params(jax.random.key(0), 16, cfg, "silu")
    B, S = 3, 8
    x = jnp.asarray(np.random.default_rng(0).normal(size=(B, S, 16)), jnp.float32)
    out_fwd, _, counts_fwd = moe_forward(p, x, cfg, "silu", with_counts=True)
    cap = capacity(S, cfg)
    assert int(jnp.max(counts_fwd)) > cap, "test must exercise actual dropping"
    counts = jnp.zeros((B, cfg.num_experts), jnp.int32)
    outs = []
    for t in range(S):
        o, _, counts = moe_decode(p, x[:, t:t + 1], cfg, "silu", counts, cap)
        outs.append(o[:, 0])
    np.testing.assert_allclose(np.asarray(out_fwd),
                               np.asarray(jnp.stack(outs, axis=1)),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(counts_fwd), np.asarray(counts))


def test_moe_decode_with_overallocated_cache_matches_forward():
    """Serving allocates the cache at max generation length, not the exact
    sequence length; pinning moe_cap_len to the reference length keeps
    decode parity with the teacher-forced forward."""
    cfg = reduced(get_config("deepseek-v3-671b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(8)
    B, S = 1, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    full = model.logits(params, model.forward(params, {"tokens": toks}))
    caches = model.init_cache(B, 2 * S, jnp.float32)  # over-allocated
    outs = []
    for t in range(S):
        lg, caches = model.decode_step(params, toks[:, t:t + 1], caches,
                                       jnp.full((B,), t, jnp.int32),
                                       moe_cap_len=S)
        outs.append(lg[:, 0])
    inc = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(inc),
                               rtol=5e-4, atol=5e-4)


def test_moe_aux_loss_balanced_routing_is_minimal():
    """Uniform router → aux == 1 (its minimum for top-1-normalized Switch
    loss scaled by E/K); peaked router → larger."""
    from repro.models.moe import _top_k_gating
    T, E, K = 256, 8, 2
    uniform = jnp.zeros((T, E))
    gates, mask, probs = _top_k_gating(uniform, K)
    aux_u = float(jnp.sum(jnp.mean(mask, 0) * jnp.mean(probs, 0)) * E / K)
    peaked = jnp.zeros((T, E)).at[:, 0].set(10.0).at[:, 1].set(9.0)
    gates, mask, probs = _top_k_gating(peaked, K)
    aux_p = float(jnp.sum(jnp.mean(mask, 0) * jnp.mean(probs, 0)) * E / K)
    assert aux_p > aux_u
    np.testing.assert_allclose(aux_u, 1.0, atol=0.2)
